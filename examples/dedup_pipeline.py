"""Data-pipeline dedup with Dash-LH: the paper's sustained-insert workload
as a production pipeline stage.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
from repro.data import DedupFilter, PackedBatcher, PipelineConfig

pc = PipelineConfig(vocab_size=32000, seq_len=512, batch_size=8,
                    dup_fraction=0.25, doc_len_min=32, doc_len_max=96)
dedup = DedupFilter()
batcher = PackedBatcher(pc, dedup=dedup)

for i in range(30):
    batcher.next_batch()
    if i % 10 == 9:
        print(f"batch {i+1}: docs seen {batcher.docs_seen}, "
              f"duplicates skipped {batcher.docs_skipped} "
              f"({batcher.docs_skipped/max(batcher.docs_seen,1):.1%}), "
              f"dash-lh items {dedup.unique_docs} "
              f"lf={dedup.table.load_factor:.2f} "
              f"segments={dedup.table.n_segments}")
