"""Quickstart: Dash hash tables on JAX in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DashConfig, DashEH, DashLH, INSERTED

# 1. build a Dash extendible-hashing table (fingerprints + balanced insert +
#    displacement + stashing all on, as in the paper)
table = DashEH(DashConfig(max_segments=128, dir_depth_max=10, num_stash=2))

rng = np.random.default_rng(0)
keys = np.unique(rng.integers(1, 2**63, 30_000, dtype=np.uint64))[:20_000]
values = np.arange(20_000, dtype=np.uint32)

statuses = table.insert(keys, values)
assert (statuses == INSERTED).all()
print(f"inserted {table.n_items} records into {table.n_segments} segments "
      f"(load factor {table.load_factor:.2f}, global depth {table.global_depth})")

found, vals = table.search(keys[:1000])
assert found.all() and (vals == values[:1000]).all()
print("positive search: all found")

# 2. crash it, restart instantly, keep serving (Sec. 4.8)
table.crash(np.random.default_rng(1), n_dups=4)
work = table.restart()
print(f"instant restart took {work['seconds']*1e3:.1f} ms (constant in size)")
found, _ = table.search(keys)
print(f"after lazy recovery: {found.sum()}/{len(keys)} found, "
      f"{table.recovered_segments} segments recovered on access")

# 3. variable-length keys (pointer mode, Sec. 4.5)
var = DashEH(DashConfig(max_segments=64, dir_depth_max=9, pointer_mode=True,
                        key_heap_size=8192, key_heap_words=4))
words = rng.integers(0, 2**32, (1000, 4), dtype=np.uint64).astype(np.uint32)
var.insert(values=np.arange(1000, dtype=np.uint32), words=words)
f, v = var.search(words=words[:10])
print(f"variable-length keys: {f.sum()}/10 found")

# 4. linear hashing variant (Sec. 5)
lh = DashLH(DashConfig(max_segments=128, num_stash=4))
lh.insert(keys[:5000], values[:5000])
print(f"Dash-LH: {lh.n_items} items across {lh.active_segments} segments")
