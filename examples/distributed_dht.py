"""Distributed Dash across devices (shard_map + all_to_all routing).

Run with fake devices to see the multi-shard path on CPU:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/distributed_dht.py
"""
import numpy as np
import jax

from repro.core import DashConfig
from repro.distributed import DistributedDash
from jax.sharding import Mesh

devs = np.array(jax.devices())
n = len(devs)
mesh = Mesh(devs.reshape(n, 1), ("data", "model"))
print(f"devices: {n}; shards: {n}")

d = DistributedDash(DashConfig(max_segments=64, dir_depth_max=9), mesh,
                    axes=("data",))
rng = np.random.default_rng(0)
keys = np.unique(rng.integers(1, 2**63, 40_000, dtype=np.uint64))[:16_000]
d.insert(keys, np.arange(16_000, dtype=np.uint32))
f, v = d.search(keys[:4096])
print(f"inserted {d.n_items} across {d.n_shards} shards; "
      f"search hit {f.sum()}/4096 with 2 all_to_alls per batch")
