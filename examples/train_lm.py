"""End-to-end training: a ~100M-param dense LM through the fault-tolerant
trainer (checkpointing, straggler monitor, dedup'd data pipeline).

On real accelerators run with --steps 300; the CPU container default is a
smoke-scale pass. Full-size assigned archs are exercised (lower+compile)
by the multi-pod dry-run: `python -m repro.launch.dryrun --all`.

    PYTHONPATH=src python examples/train_lm.py [--steps 30] [--d-model 512]
"""
import argparse

from repro.models.transformer import ModelConfig
from repro.launch.train import batch_iter
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    # ~100M params at --d-model 768 --layers 12 (GPT-2-small-ish shape)
    cfg = ModelConfig(
        name="example-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128), d_ff=args.d_model * 4,
        vocab_size=8192, head_dim=64, remat="none", q_chunk=128, kv_chunk=256)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params")

    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=10,
                         checkpoint_dir=args.ckpt)
    trainer = Trainer(cfg, tcfg,
                      batch_iter(cfg, args.batch, args.seq, dedup=True))
    result = trainer.run()
    losses = [m["loss"] for m in result["log"] if "loss" in m]
    print(f"steps={result['final_step']} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"restarts={result['restarts']} stragglers={len(result['stragglers'])}")


if __name__ == "__main__":
    main()
