"""End-to-end serving driver: batched requests with Dash prefix-cache reuse
(the paper's hash table as the serving KV-page directory).

    PYTHONPATH=src python examples/serve_prefix_cache.py [--arch yi-6b]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, cache_len=256, num_pages=256,
                           batch_size=4)
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, cfg.vocab_size, 64)   # shared prefix

    rid = 0
    for round_i in range(args.rounds):
        reqs = []
        for _ in range(4):
            user = rng.integers(1, cfg.vocab_size, 32)
            reqs.append(Request(rid, np.concatenate([system_prompt, user]),
                                max_new_tokens=8))
            rid += 1
        engine.run(reqs)
        s = engine.prefix.stats
        print(f"round {round_i}: hit-rate {s.hit_rate:.1%}, "
              f"prefill tokens saved so far {engine.flops_saved_tokens}, "
              f"dash directory load factor {engine.prefix.load_factor:.3f}")
    print("done — the shared system prompt is prefilled once, then every "
          "request reuses its pages via Dash probes")


if __name__ == "__main__":
    main()
