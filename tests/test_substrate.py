"""Data pipeline, dedup, checkpoint manager, optimizer, compression."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DedupFilter, PackedBatcher, PipelineConfig
from repro.optim import adamw
from repro.optim.schedule import cosine_warmup


def test_pipeline_deterministic_and_checkpointable():
    pc = PipelineConfig(vocab_size=1000, seq_len=128, batch_size=4)
    a, b = PackedBatcher(pc), PackedBatcher(pc)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    # resume from cursor state
    state = a.state_dict()
    nxt = a.next_batch()
    c = PackedBatcher(pc)
    c.load_state_dict(state)
    np.testing.assert_array_equal(c.next_batch()["tokens"], nxt["tokens"])
    # labels are next-token shifted
    assert ba["tokens"].shape == (4, 128)


def test_dedup_filters_duplicates():
    pc = PipelineConfig(vocab_size=1000, seq_len=128, batch_size=2,
                        dup_fraction=0.3, doc_len_min=16, doc_len_max=48)
    dd = DedupFilter()
    b = PackedBatcher(pc, dedup=dd)
    for _ in range(20):
        b.next_batch()
    assert b.docs_skipped > 0
    assert dd.unique_docs == b.docs_seen - b.docs_skipped


def test_checkpoint_atomic_commit_and_instant_restore(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10_000, dtype=jnp.float32),
            "b": {"c": jnp.ones((64, 64))}}
    cm.save(10, tree, clean=False, version=1)
    cm.save(20, tree, clean=True, version=1)
    assert cm.latest_step() == 20
    manifest, lazy, secs = cm.restore_manifest()
    assert secs < 0.1                      # instant: manifest only
    assert manifest["version"] == 1        # clean -> no bump
    restored = cm.restore_tree(tree, lazy)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10_000))
    # dirty restart bumps version (paper's V)
    cm.mark_dirty(20)
    m2, _, _ = cm.restore_manifest()
    assert m2["version"] == 2
    # retention
    cm.save(30, tree); cm.save(40, tree)
    steps = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step"))
    assert len(steps) == 2


def test_adamw_descends_quadratic():
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = adamw.init(p)
    cfg = adamw.AdamWConfig(weight_decay=0.0)
    for i in range(200):
        g = jax.tree.map(lambda w: 2 * w, p)
        p, st, m = adamw.update(cfg, g, st, p, lr=0.05)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_schedule_shapes():
    s0 = float(cosine_warmup(jnp.asarray(0), peak_lr=1.0, warmup=10, total=100))
    s10 = float(cosine_warmup(jnp.asarray(10), peak_lr=1.0, warmup=10, total=100))
    s100 = float(cosine_warmup(jnp.asarray(100), peak_lr=1.0, warmup=10, total=100))
    assert s0 == 0.0 and abs(s10 - 1.0) < 1e-6 and s100 < 0.2


def test_compression_error_feedback_is_unbiased_over_steps():
    """int8+EF: the *cumulative* update converges to the true mean."""
    from repro.parallel import compression
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    # single-device psum == identity: check quantize/residual telescoping
    res = {"g": jnp.zeros((256,), jnp.float32)}
    acc = jnp.zeros((256,))
    import jax as _jax
    def fake(grads, residuals):
        def one(g, r):
            e = g + r
            q, scale = compression._quantize(e)
            deq = compression._dequantize(q, scale)
            return deq, e - deq
        out = _jax.tree.map(one, grads, residuals)
        return (_jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)),
                _jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple)))
    for _ in range(20):
        out, res = fake({"g": g_true}, res)
        acc = acc + out["g"]
    np.testing.assert_allclose(np.asarray(acc / 20), np.asarray(g_true),
                               atol=2e-3)
