"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The container image does not ship hypothesis, which made two test modules
fail at *collection* time. This shim implements just the surface those
tests use — ``given``/``settings`` decorators plus the ``integers``,
``sampled_from``, ``tuples`` and ``lists`` strategies — as a seeded
random-example runner. With the real package present it is bypassed
entirely, so CI environments that do have hypothesis keep full shrinking
and edge-case generation.
"""
from __future__ import annotations


import random

try:                                    # pragma: no cover - exercised when installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def tuples(*parts):
            return _Strategy(lambda rng: tuple(p.example(rng) for p in parts))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elem.example(rng)
                for _ in range(rng.randint(min_size, max_size))])

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # no functools.wraps: pytest must see a zero-arg signature, not
            # the strategy parameters (it would treat them as fixtures)
            def run(*args, **kwargs):
                # read at call time so @settings works above or below @given
                n = getattr(run, "_max_examples", 10)
                rng = random.Random(0xDA5 + n)
                for _ in range(n):
                    fn(*args, *(s.example(rng) for s in strategies), **kwargs)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run._max_examples = getattr(fn, "_max_examples", 10)
            return run
        return deco
