"""Hash function properties + jnp/numpy bit-exactness."""
import jax.numpy as jnp
import numpy as np
from tests._hypothesis_compat import given, settings, st

from repro.core import hashing


@given(st.integers(0, 2**64 - 1))
@settings(max_examples=200, deadline=None)
def test_np_jnp_bit_exact(key):
    hi, lo = hashing.split_key(key)
    for fn_np, fn_j in ((hashing.np_hash1, hashing.hash1),
                        (hashing.np_hash2, hashing.hash2)):
        a = fn_np(np.uint32(hi), np.uint32(lo))
        b = np.asarray(fn_j(jnp.uint32(hi), jnp.uint32(lo)))
        assert np.uint32(a) == b


def test_avalanche():
    """Flipping one input bit flips ~half the output bits on average."""
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 2**63, 500, dtype=np.uint64)
    hi, lo = hashing.np_split_keys(keys)
    base = hashing.np_hash1(hi, lo)
    flipped = hashing.np_hash1(hi, lo ^ np.uint32(1))
    dist = np.unpackbits((base ^ flipped).view(np.uint8)).mean() * 8
    assert 3.2 < dist < 4.8, dist


def test_fingerprint_distribution():
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 2**63, 20000, dtype=np.uint64)
    hi, lo = hashing.np_split_keys(keys)
    fps = hashing.np_hash2(hi, lo) & 0xFF
    counts = np.bincount(fps.astype(int), minlength=256)
    assert counts.min() > 20 and counts.max() < 180    # ~78 +- noise


def test_fold_words_identity_stable():
    rng = np.random.default_rng(3)
    w = rng.integers(0, 2**32, (50, 4), dtype=np.uint64).astype(np.uint32)
    h1 = hashing.np_fold_words(w, hashing.FOLD_SEED_HI)
    h2 = np.asarray(hashing.fold_words(jnp.asarray(w), hashing.FOLD_SEED_HI))
    assert (h1 == h2).all()
