"""Per-arch smoke: reduced config, one train grad step + one decode step on
CPU, asserting output shapes + finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, concrete_inputs
from repro.models import (decode_state_init, init_params, loss_fn, serve_step)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_and_decode(arch, rng):
    cfg = get_config(arch, reduced=True)
    params, specs = init_params(jax.random.PRNGKey(0), cfg)
    # specs mirror params
    assert set(specs) == set(params)

    ci = concrete_inputs(cfg, "train_4k")
    batch = ci["batch"]

    def shrink(x):
        x = x[:2]
        if x.ndim >= 2 and x.shape[1] > 128:
            x = x[:, :128]
        return x

    batch = jax.tree.map(shrink, batch)
    if cfg.family == "vlm":
        batch["patch_embeds"] = ci["batch"]["patch_embeds"][:2, :cfg.num_patches]
        batch["tokens"] = batch["tokens"][:, :128 - cfg.num_patches]
        batch["labels"] = batch["labels"][:, :128 - cfg.num_patches]

    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

    state = decode_state_init(cfg, 2, 64)
    inputs = ({"frame_embeds": jnp.zeros((2, 1, cfg.d_model), jnp.bfloat16)}
              if cfg.family == "audio" else {"token": jnp.zeros((2,), jnp.int32)})
    logits, state2 = serve_step(params, cfg, state, inputs)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(state2["pos"][0]) == 1


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "recurrentgemma-9b", "rwkv6-7b"])
def test_decode_matches_prefill_logits(arch):
    """Prefill logits at position t == decode logits after feeding t tokens
    (cache/state handoff correctness)."""
    from repro.models.transformer import forward_prefill
    cfg = get_config(arch, reduced=True)
    params, _ = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    T = 24
    toks = rng.integers(1, cfg.vocab_size, (1, T)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.zeros((1, T), jnp.int32)}
    plogits, pstate = forward_prefill(params, cfg, batch, cache_len=64)

    state = decode_state_init(cfg, 1, 64)
    logits = None
    for t in range(T):
        logits, state = serve_step(params, cfg, state,
                                   {"token": jnp.asarray(toks[:, t])})
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(plogits[0, -1]), rtol=0.12, atol=0.6)
