"""Fault-tolerant trainer: failure -> instant restore -> continue; straggler
flagging; loss goes down on the reduced model."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.train import batch_iter
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig


def test_failure_restart_and_progress(tmp_path):
    cfg = get_config("yi-6b", reduced=True)
    tcfg = TrainerConfig(total_steps=14, checkpoint_every=4,
                         checkpoint_dir=str(tmp_path), async_checkpoint=True)

    class Fault:
        fired = False
        def __call__(self, step):
            if step == 9 and not self.fired:
                self.fired = True
                raise RuntimeError("injected")

    t = Trainer(cfg, tcfg, batch_iter(cfg, 2, 128, dedup=False),
                fault_hook=Fault())
    res = t.run()
    assert res["final_step"] == 14
    assert res["restarts"] == 1
    ev = [m for m in res["log"] if m.get("event") == "restart"][0]
    assert ev["restored_step"] == 8
    assert ev["manifest_restore_s"] < 0.1      # instant restore
    losses = [m["loss"] for m in res["log"] if "loss" in m]
    assert losses[-1] < losses[0]

    # resume across process restarts
    t2 = Trainer(cfg, tcfg, batch_iter(cfg, 2, 128, dedup=False))
    assert t2.resume_if_possible() == 14


def test_straggler_monitor():
    m = StragglerMonitor(window=20, sigma=3.0)
    for i in range(15):
        m.record(i, 0.10 + 0.001 * (i % 3))
    assert m.record(15, 0.5) is True
    assert not m.record(16, 0.101)
    assert len(m.flagged) == 1
