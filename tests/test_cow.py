"""Copy-on-write segment-plane snapshots (ISSUE 4): plane aliasing,
in-place donation, refcounted plane-level reclamation, version-bump
completeness, and the host dirty-tracker audit.

The contracts under test:

  * a published snapshot is bit-identical to the live state at publish time
    even though only dirty bucket rows were copied;
  * unchanged planes are SHARED between consecutive versions (object/buffer
    identity), and reclaiming an old version never invalidates a plane a
    newer version (or the live state) still uses;
  * a pinned version's buffers are never donated away;
  * every plane mutation bumps its bucket's version word (the COW publish's
    ground truth) across insert/delete/update/SMO workloads;
  * the host DirtyTracker reports a superset of the device dirty segments
    (``hint_misses == 0``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DashConfig, DashEH, DashLH, engine as dash_engine
from repro.core import layout
from repro.core.epoch import DirtyHint, PlanePool, SnapshotRegistry
from repro.serving.frontend import (DELETE, INSERT, READ, RMW, UPDATE,
                                    DashFrontend, Op)
from repro.workloads import ycsb
from tests.conftest import unique_keys

CFG = DashConfig(max_segments=32, dir_depth_max=7, num_buckets=16,
                 num_slots=8)


def _assert_state_equal(sa, sb):
    for name in sa._fields:
        a, b = np.asarray(getattr(sa, name)), np.asarray(getattr(sb, name))
        assert (a == b).all(), name


def _loaded_table(n=800, cls=DashEH, cfg=CFG, seed=0xC0):
    t = cls(cfg)
    keys = unique_keys(np.random.default_rng(seed), n + 400)
    t.insert(keys[:n], np.arange(n, dtype=np.uint32))
    return t, keys, n


# ---------------------------------------------------------------------------
# plane pool
# ---------------------------------------------------------------------------

def test_plane_pool_refcounts():
    pool = PlanePool()
    a = jnp.arange(16)
    pool.incref(a)
    pool.incref(a)              # second snapshot aliases the same plane
    assert pool.refcount(a) == 2
    assert not pool.decref(a)   # first release: still referenced
    assert not a.is_deleted()
    assert pool.decref(a)       # last release frees the buffer
    assert a.is_deleted()
    assert pool.live_planes == 0


# ---------------------------------------------------------------------------
# COW publish: aliasing + donation + bit-exactness
# ---------------------------------------------------------------------------

def test_cow_publish_is_bit_exact_and_o_dirty():
    t, keys, n = _loaded_table()
    reg = SnapshotRegistry()
    s0 = reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    whole = layout.state_nbytes(t.state)
    assert reg.last_publish_bytes == whole          # first publish: full copy

    t.insert(keys[n:n + 64], np.arange(64, dtype=np.uint32) + n)
    s1 = reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    _assert_state_equal(s1.state, t.state)          # snapshot == live
    assert reg.last_publish_bytes < 0.5 * whole     # O(dirty), not O(table)
    assert reg.hint_misses == 0

    # a logically-pinnable workload: updates dirty only val+version rows
    t.update(keys[:32], np.arange(32, dtype=np.uint32) + 7000)
    s2 = reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    _assert_state_equal(s2.state, t.state)
    assert reg.last_publish_bytes < 0.5 * whole


def test_cow_unchanged_planes_share_buffers():
    """Satellite: unchanged segments share device buffers across consecutive
    versions — by object identity for fully-clean planes (the directory
    after a non-SMO batch) and by buffer identity for record planes whose
    untouched rows rode an in-place donated scatter."""
    t, keys, n = _loaded_table()
    reg = SnapshotRegistry()
    s0 = reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    dir0 = s0.state.dir
    key_hi_ptr = s0.state.key_hi.unsafe_buffer_pointer()

    splits0 = int(np.asarray(t.state.n_splits))
    t.insert(keys[n:n + 32], np.arange(32, dtype=np.uint32))
    assert int(np.asarray(t.state.n_splits)) == splits0   # no SMO this batch
    s1 = reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())

    assert s1.state.dir is dir0                       # aliased, refcounted
    assert reg.pool.refcount(dir0) == 2
    # donated in place: same underlying buffer carried the untouched rows
    assert s1.state.key_hi.unsafe_buffer_pointer() == key_hi_ptr
    assert s0.state.key_hi.is_deleted()               # consumed, not leaked
    assert reg.planes_aliased >= 1 and reg.planes_copied > 0


def test_cow_smo_republishes_directory_plane():
    t, keys, n = _loaded_table(n=600)
    fe = DashFrontend(t, max_batch=128, queue_depth=1 << 14)
    dir_before = fe.registry.current.state.dir
    splits0 = int(np.asarray(t.state.n_splits))
    # storm: enough fresh keys to force deferred bulk splits
    for k in keys[600:1000]:
        fe.submit(Op(INSERT, int(k), ycsb.expected_value(int(k))))
    fe.drain()
    assert int(np.asarray(t.state.n_splits)) > splits0
    assert fe.registry.current.state.dir is not dir_before
    _assert_state_equal(fe.registry.current.state, t.state)
    assert fe.stats()["hint_misses"] == 0


# ---------------------------------------------------------------------------
# reclamation safety
# ---------------------------------------------------------------------------

def test_pinned_version_planes_are_never_donated():
    t, keys, n = _loaded_table()
    reg = SnapshotRegistry()
    reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    with reg.acquire() as snap:
        t.update(keys[:64], np.arange(64, dtype=np.uint32) + 5000)
        s1 = reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
        # the pinned version keeps its planes...
        assert not snap.state.val.is_deleted()
        # ...and still reads its own (pre-update) values
        from repro.core.hashing import np_split_keys
        hi, lo = np_split_keys(keys[:64])
        f, v = dash_engine.search_batch(CFG, "eh", snap.state,
                                        jnp.asarray(hi), jnp.asarray(lo))
        assert np.asarray(f).all()
        assert (np.asarray(v) == np.arange(64)).all()
    _assert_state_equal(s1.state, t.state)


def test_reclaiming_old_versions_never_invalidates_newer_ones():
    """Regression for the acceptance criterion: no plane is reclaimed while
    aliased by the live state or any pinned/newer snapshot. The directory
    plane is aliased by every non-SMO version in the chain; reclaiming the
    oldest versions must only drop references."""
    t, keys, n = _loaded_table()
    reg = SnapshotRegistry()
    reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    dir_plane = reg.current.state.dir
    for i in range(8):                     # supersede -> retire -> reclaim
        t.update(keys[:16], np.arange(16, dtype=np.uint32) + i)
        reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    assert reg.reclaimed >= 4              # old versions really were freed
    cur = reg.current.state
    assert cur.dir is dir_plane            # aliased through the whole chain
    assert not cur.dir.is_deleted()        # ...and still alive
    _assert_state_equal(cur, t.state)      # newest snapshot fully intact
    f, _ = t.search(keys[:n])              # live state untouched by reclaims
    assert f.all()
    reg.flush()
    assert not reg.current.state.dir.is_deleted()   # current never reclaimed


def test_cow_force_full_after_crash():
    """Crash surgery bypasses the version discipline; the dirty tracker's
    force-full escape must make the next publish copy the whole state."""
    t, keys, n = _loaded_table()
    reg = SnapshotRegistry()
    reg.publish_cow(CFG, t.state, dirty_hint=t.dirty.drain())
    t.crash(np.random.default_rng(3), interrupt_smo=False)
    t.restart()
    hint = t.dirty.drain()
    assert hint.full
    s = reg.publish_cow(CFG, t.state, dirty_hint=hint)
    _assert_state_equal(s.state, t.state)
    assert reg.last_publish_bytes == layout.state_nbytes(t.state)


# ---------------------------------------------------------------------------
# version-bump completeness: content change implies version change
# ---------------------------------------------------------------------------

def _missed_rows(cfg, old, new):
    """Bucket rows whose content changed without a version-word bump."""
    BT, NB = cfg.buckets_total, cfg.num_buckets
    vm = np.asarray(old.version).reshape(-1) != \
        np.asarray(new.version).reshape(-1)
    lead = old.version.shape[:-1]
    vm_nb = (np.asarray(old.version) != np.asarray(new.version))[..., :NB] \
        .reshape(-1)
    missed = 0
    for name in layout.BT_PLANES:
        if name == "version":
            continue
        a = np.asarray(getattr(old, name)).reshape(len(vm), -1)
        b = np.asarray(getattr(new, name)).reshape(len(vm), -1)
        missed += int(((a != b).any(axis=1) & ~vm).sum())
    for name in layout.NB_PLANES:
        a = np.asarray(getattr(old, name)).reshape(len(vm_nb), -1)
        b = np.asarray(getattr(new, name)).reshape(len(vm_nb), -1)
        missed += int(((a != b).any(axis=1) & ~vm_nb).sum())
    return missed


@pytest.mark.parametrize("mode", ["eh", "lh"])
def test_every_plane_mutation_bumps_its_version_row(mode):
    """The COW ground truth: across insert (plain/displace/stash), delete
    (incl. overflow-metadata clears), update, and split-heavy batches, no
    record/metadata row ever changes without its version word changing."""
    cls = DashEH if mode == "eh" else DashLH
    t = cls(CFG)
    keys = unique_keys(np.random.default_rng(0xBEEF + (mode == "lh")), 2200)
    rng = np.random.default_rng(7)
    cursor = 0
    for step in range(10):
        before = jax.tree.map(jnp.copy, t.state)
        op = step % 5
        if op in (0, 1, 3):            # inserts drive stash + splits
            n = int(rng.integers(100, 260))
            batch = keys[cursor:cursor + n]
            cursor += n
            t.insert(batch, np.arange(batch.size, dtype=np.uint32))
        elif op == 2:
            sel = keys[rng.integers(0, cursor, 80)]
            t.update(sel, np.arange(80, dtype=np.uint32) + 9000)
        else:
            sel = keys[rng.integers(0, cursor, 80)]
            t.delete(sel)
        assert _missed_rows(CFG, before, t.state) == 0, (mode, step, op)


def test_cow_frontend_mixed_workload_end_to_end():
    """A mixed insert/read/update/delete/RMW stream through the COW
    frontend: every publish stays bit-exact (reads come off snapshots), the
    dirty-hint audit stays clean, and publish volume stays O(dirty)."""
    t = DashEH(CFG)
    fe = DashFrontend(t, max_batch=64, queue_depth=1 << 15)
    keys = unique_keys(np.random.default_rng(0xF00), 1200)
    rng = np.random.default_rng(11)
    for k in keys[:700]:
        fe.submit(Op(INSERT, int(k), ycsb.expected_value(int(k))))
    fe.drain()
    for i, k in enumerate(keys[700:1000]):
        fe.submit(Op(INSERT, int(k), ycsb.expected_value(int(k))))
        fe.submit(Op(READ, int(keys[rng.integers(0, 700)])))
        if i % 3 == 0:
            kk = int(keys[rng.integers(0, 700)])
            fe.submit(Op(UPDATE, kk, ycsb.updated_value(kk)))
        if i % 7 == 0:
            fe.submit(Op(RMW, int(keys[rng.integers(0, 700)]), 123))
        if i % 11 == 0:
            fe.submit(Op(DELETE, int(keys[rng.integers(0, 700)])))
    fe.drain()
    _assert_state_equal(fe.registry.current.state, t.state)
    s = fe.stats()
    assert s["hint_misses"] == 0
    assert s["published"] > 10
    # steady-state publishes move far less than the whole state each
    whole = layout.state_nbytes(t.state)
    assert s["publish_bytes"] < 0.6 * s["published"] * whole
    assert s["planes_aliased"] > 0 and s["reclaimed"] > 0
