"""Checkpoint commit protocol under crash injection (ISSUE-5 satellite):
a crash anywhere between the first tensor write and the LATEST repoint must
restore the PREVIOUS step. (The docstring of checkpoint/manager.py contrasts
this generic async-tree-snapshot design with the in-place incremental PM
pool of src/repro/persist/.)"""
import os
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


class Boom(RuntimeError):
    pass


def _tree(step):
    return {"w": np.full((4, 4), step, np.float32),
            "opt": {"m": np.full(3, step * 10, np.float32)}}


def _restore_step(mgr):
    manifest, lazy, _ = mgr.restore_manifest()
    assert manifest is not None
    tree = mgr.restore_tree(_tree(0), lazy)
    return manifest["step"], tree


def test_commit_then_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    step, tree = _restore_step(mgr)
    assert step == 2 and (tree["w"] == 2).all() and (tree["opt"]["m"] == 20).all()


def test_crash_between_data_write_and_commit_rename(tmp_path, monkeypatch):
    """Tensors + manifest staged, crash BEFORE the atomic rename: the stage
    dir is garbage, the previous commit is untouched and restored."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))

    real_rename = Path.rename

    def exploding_rename(self, target):
        if ".stage_" in self.name or ".stage_" in str(self):
            raise Boom("crash before commit rename")
        return real_rename(self, target)

    monkeypatch.setattr(Path, "rename", exploding_rename)
    with pytest.raises(Boom):
        mgr.save(2, _tree(2))
    monkeypatch.undo()

    step, tree = _restore_step(mgr)
    assert step == 1 and (tree["w"] == 1).all()
    # recovery: a later save of the same step succeeds and wins
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    mgr2.save(2, _tree(2))
    step, tree = _restore_step(mgr2)
    assert step == 2 and (tree["w"] == 2).all()


def test_crash_between_rename_and_latest_repoint(tmp_path, monkeypatch):
    """The commit rename landed but LATEST was not repointed: the commit is
    valid (rename is the atomic point) and the fallback scan finds it."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))

    def exploding_replace(src, dst):
        raise Boom("crash before LATEST repoint")

    monkeypatch.setattr(os, "replace", exploding_replace)
    with pytest.raises(Boom):
        mgr.save(2, _tree(2))
    monkeypatch.undo()

    # LATEST still names step 1, but step 2's rename committed — the
    # fallback never REGRESSES: LATEST's target is valid, so it is honored
    assert (tmp_path / "LATEST").read_text().strip().endswith("0000000001")
    step, _ = _restore_step(mgr)
    assert step == 1
    # destroy LATEST entirely: the scan finds the newest valid manifest
    (tmp_path / "LATEST").unlink()
    step, tree = _restore_step(mgr)
    assert step == 2 and (tree["w"] == 2).all()


def test_resave_same_step_never_loses_only_copy(tmp_path, monkeypatch):
    """Re-saving an existing step moves the old commit aside (no rmtree
    window): a crash at the rename leaves either the old or the new commit
    restorable — never neither."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree(5))

    real_rename = Path.rename

    def exploding_rename(self, target):
        if ".stage_" in str(self):
            raise Boom("crash mid re-save")
        return real_rename(self, target)

    monkeypatch.setattr(Path, "rename", exploding_rename)
    with pytest.raises(Boom):
        mgr.save(5, {"w": np.zeros((4, 4), np.float32),
                     "opt": {"m": np.zeros(3, np.float32)}})
    monkeypatch.undo()

    # restart: the manager's crash sweep restores the moved-aside commit
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    step, tree = _restore_step(mgr2)
    assert step == 5
    assert (tree["w"] == 5).all()      # the original commit survived


def test_torn_manifest_ignored_by_fallback(tmp_path):
    """A directory with a corrupt manifest (torn write) is skipped by the
    fallback scan."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    fake = tmp_path / "step_0000000009"
    fake.mkdir()
    (fake / "manifest.json").write_text('{"step": 9, "clean":')   # torn
    (tmp_path / "LATEST").unlink()
    step, _ = _restore_step(mgr)
    assert step == 1


def test_dirty_restart_bumps_version(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(3), clean=True, version=7)
    mgr.mark_dirty(3)
    manifest, _, seconds = mgr.restore_manifest()
    assert manifest["version"] == 8 and not manifest["clean"]
    assert seconds < 1.0
