"""Durable PM-pool persistence (ISSUE 5): round-trip, O(dirty) flush
accounting, flush-on-publish through the serving frontend, per-shard pools,
and the crash matrix — a torn flush killed at EVERY emulated store boundary
must reopen to a pool where every previously-acknowledged key is found."""
import os
import shutil

import numpy as np
import pytest

from repro import persist
from repro.core import DashConfig, layout
from repro.persist import PoolError, SimulatedCrash, WritebackEngine
from repro.persist.pool import PmPool
from tests.conftest import unique_keys

SMALL = DashConfig(max_segments=16, dir_depth_max=8, num_buckets=16,
                   num_slots=8)


def _vals(n, base=1):
    return (np.arange(n) % 2**31).astype(np.uint32) + base


# -- pool + layout ------------------------------------------------------------

def test_plane_offset_map_covers_state():
    specs, log, csum, total = layout.pool_plane_specs(SMALL, "eh")
    names = [s.name for s in specs]
    assert names == list(layout.DashState._fields)
    # regions are disjoint, ordered, aligned, and inside the file
    prev_end = csum.offset + csum.nbytes
    assert csum.offset >= layout.SUPERBLOCK_BYTES + log.nbytes
    for s in specs:
        assert s.offset % layout.POOL_ALIGN == 0
        assert s.offset >= prev_end
        prev_end = s.offset + s.nbytes
    assert prev_end <= total
    # the checksum region covers exactly the record-row planes
    assert {n for n, _, _ in csum.entries} == set(layout.CSUM_PLANES)
    by = {s.name: s for s in specs}
    for n, _, rows in csum.entries:
        assert rows == by[n].rows
    # row addressing matches the COW publish's row index space
    bt = {s.name: s for s in specs}
    S, BT = SMALL.max_segments, SMALL.buckets_total
    assert bt["version"].rows == S * BT == bt["key_hi"].rows == bt["fp"].rows
    assert bt["ometa"].rows == S * SMALL.num_buckets


def test_superblock_torn_slot_detected(tmp_path):
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    t.insert(unique_keys(np.random.default_rng(0), 100), _vals(100))
    t.flush()
    t.close()
    # corrupt the newest slot: open() must fall back to the older valid one
    seq = PmPool.open(p).sb.flush_seq
    with open(p, "r+b") as f:
        f.seek((seq % 2) * 2048 + 20)
        f.write(b"\xff" * 32)
    pool = PmPool.open(p)
    assert pool.sb.flush_seq == seq - 1
    # a pool with BOTH slots destroyed refuses to open with a diagnosable
    # error (names the superblock validation, not a stack trace)
    with open(p, "r+b") as f:
        f.write(b"\x00" * 4096)
    with pytest.raises(PoolError, match="superblock"):
        PmPool.open(p)


def test_truncated_pool_file_diagnosed(tmp_path):
    """A pool file cut short — below the superblock region or anywhere
    inside the plane regions — must raise a clean, diagnosable PoolError
    instead of a numpy mapping error or (worse) serving garbage."""
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    t.insert(unique_keys(np.random.default_rng(1), 100), _vals(100))
    t.flush()
    t.close()
    full = os.path.getsize(p)
    # cut inside the plane region: superblocks are intact and valid
    with open(p, "r+b") as f:
        f.truncate(full - 4096)
    with pytest.raises(PoolError, match="truncated"):
        PmPool.open(p)
    with pytest.raises(PoolError, match="truncated"):
        persist.reopen(p)
    # cut below even the superblock slots
    with open(p, "r+b") as f:
        f.truncate(1024)
    with pytest.raises(PoolError, match="truncated"):
        PmPool.open(p)


def test_pointer_mode_flush_is_o_dirty_plus_heap_tail(tmp_path):
    """ISSUE 6 satellite: the append-only key heap's durable high-water
    mark bounds pointer-mode flushes to O(dirty rows + heap tail) — a
    small insert batch must not rewrite the whole pool (pre-PR-6 pointer
    mode forced full flushes)."""
    import dataclasses as dc
    cfg = dc.replace(SMALL, pointer_mode=True, key_heap_size=4096,
                     key_heap_words=2)

    def words_of(lo, hi):
        ks = np.arange(lo, hi, dtype=np.uint64)
        out = np.zeros((ks.size, 2), np.uint32)
        out[:, 0] = (ks & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        out[:, 1] = (ks >> np.uint64(32)).astype(np.uint32)
        return out

    p = str(tmp_path / "t.pool")
    t = persist.create(p, cfg)
    t.insert(values=_vals(600), words=words_of(1, 601))
    t.flush()
    wb = t.writeback
    # incremental batch: flushed bytes ≪ pool, heap tail exactly the batch
    t.insert(values=_vals(48, base=9000), words=words_of(601, 649))
    before = wb.flushed_bytes
    t.flush()
    delta = wb.flushed_bytes - before
    assert wb.last_heap_tail_rows == 48
    assert delta < wb.pool.plane_bytes // 4, \
        f"pointer-mode flush not incremental: {delta} bytes"
    # the heap is device-sliced at its tail: host staging stays O(dirty
    # rows + heap tail) too, never a whole-heap/whole-pool copy
    assert wb.last_staged_bytes < wb.pool.plane_bytes // 4, \
        f"pointer-mode flush staged {wb.last_staged_bytes} host bytes"
    t.close()
    t2, info = persist.reopen(p)
    f, v = t2.search(words=words_of(1, 649))
    assert f.all()
    assert (v == np.concatenate([_vals(600), _vals(48, base=9000)])).all()


@pytest.mark.parametrize("mode,cfg", [
    ("eh", SMALL),
    ("lh", DashConfig(max_segments=32, num_stash=4, num_buckets=16,
                      num_slots=8)),
])
def test_roundtrip_clean(tmp_path, mode, cfg, rng):
    p = str(tmp_path / "t.pool")
    t = persist.create(p, cfg, mode=mode)
    keys = unique_keys(rng, 1500)
    t.insert(keys, _vals(1500))
    t.flush()
    t.close()
    t2, info = persist.reopen(p)
    assert info["clean"] and t2.mode == mode and t2.cfg == cfg
    f, v = t2.search(keys)
    assert f.all() and (v == _vals(1500)).all()
    assert t2.recovered_segments == 0          # clean reopen: no recovery
    assert t2.n_items == 1500
    neg = np.setdiff1d(unique_keys(rng, 2000), keys)[:300]
    f2, _ = t2.search(neg)
    assert f2.sum() == 0


def test_flush_is_o_dirty(tmp_path, rng):
    p = str(tmp_path / "t.pool")
    cfg = DashConfig(max_segments=64, dir_depth_max=10)
    t = persist.create(p, cfg)
    keys = unique_keys(rng, 1200)
    t.insert(keys[:1000], _vals(1000))
    t.flush()
    pool_bytes = t.writeback.pool.plane_bytes
    # an update burst touches exactly its keys' bucket rows: the flush is
    # row-granular, a tiny fraction of the pool
    t.update(keys[:64], _vals(64, base=7777))
    b = t.flush()
    assert b == t.writeback.last_flush_bytes
    assert t.writeback.last_dirty_rows <= 64 + cfg.num_stash * t.n_segments
    assert b < 0.05 * pool_bytes
    # host staging is O(dirty) like the pool I/O: bytes materialized from
    # device ≈ bytes flushed, plus the always-copied narrow planes (4-byte
    # publish words + routing + scalars) and the pow2 gather padding —
    # never a whole-pool copy
    from repro.persist.writeback import GATHER_BT, GATHER_NB
    wide = set(GATHER_BT + GATHER_NB)
    narrow = sum(t.writeback.pool.spec(n).nbytes
                 for n in layout.DashState._fields if n not in wide)
    staged = t.writeback.last_staged_bytes
    assert staged <= narrow + 4 * b, \
        f"flush staged {staged} host bytes for {b} flushed (narrow={narrow})"
    assert staged < 0.25 * pool_bytes
    # a small insert batch (may split) still flushes O(dirty), not O(pool)
    t.insert(keys[1000:1064], _vals(64))
    b1 = t.flush()
    assert b1 < 0.5 * pool_bytes
    assert t.writeback.flush_hint_misses == 0
    # an untouched table flushes scalars only (no dirty rows)
    b2 = t.flush()
    assert t.writeback.last_flush_rows == 0
    assert b2 < 2048
    # the flush is the acknowledgment point: reopen sees everything flushed
    t2, _ = persist.reopen(p)
    f, v = t2.search(keys[:1064])
    assert f.all() and (v[:64] == _vals(64, base=7777)).all()


def test_crash_artifacts_in_pool_lazily_recovered(tmp_path, rng):
    """crash(); flush() emulates the paper's crash-with-artifacts-in-PM:
    locks, dup records, wiped overflow metadata, an interrupted SMO — all
    land durably and the reopened table recovers them on first access."""
    p = str(tmp_path / "t.pool")
    cfg = DashConfig(max_segments=32, dir_depth_max=8)
    t = persist.create(p, cfg)
    keys = unique_keys(rng, 4000)
    t.insert(keys, _vals(4000))
    t.flush()
    t.crash(np.random.default_rng(3), lock_frac=0.2, n_dups=6,
            wipe_overflow=True, interrupt_smo=True)
    t.flush()
    t2, info = persist.reopen(p)
    assert not info["clean"]
    f, v = t2.search(keys)
    assert f.all() and (v == _vals(4000)).all()
    assert t2.recovered_segments > 0
    assert t2.n_items == 4000                   # dups removed exactly
    s = t2.insert(keys[:64], _vals(64))
    assert (s == layout.EXISTS).all()


def test_reopen_marks_serving_dirty(tmp_path, rng):
    """After a clean reopen the pool must be dirty again BEFORE new work is
    acknowledged: a crash right after reopen recovers."""
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    t.insert(unique_keys(rng, 200), _vals(200))
    t.flush()
    t.close()
    t2, info = persist.reopen(p)
    assert info["clean"]
    del t2                                      # crash: no close()
    t3, info3 = persist.reopen(p)
    assert not info3["clean"]                   # reopen committed dirty


# -- the crash matrix ---------------------------------------------------------

def _flush_ops(base_path, scratch, state):
    shutil.copyfile(base_path, scratch)
    wb = WritebackEngine(PmPool.open(scratch))
    wb.inject_crash(1 << 30)
    wb.flush(state)
    return (1 << 30) - wb._ops_budget


@pytest.mark.parametrize("workload", ["inserts_smo", "mixed"])
def test_torn_flush_matrix(tmp_path, workload):
    """Kill the flush at EVERY store boundary; each torn pool must reopen
    with all previously-acknowledged keys (and values) intact. The
    inserts_smo batch drives bulk splits (rebuilt rows -> redo log); the
    mixed batch adds deletes and updates on acked keys (their torn effects
    are in-flight-op indeterminacy, but surviving acked keys must keep a
    consistent value)."""
    rng = np.random.default_rng(11)
    keys = unique_keys(rng, 2000)
    acked = keys[:800]
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    t.insert(acked, _vals(800))
    t.flush()
    base = p + ".base"
    shutil.copyfile(p, base)

    deleted = updated = np.array([], np.uint64)
    if workload == "inserts_smo":
        t.insert(keys[800:1200], _vals(400, base=5000))
    else:
        deleted = acked[::7]
        updated = acked[3::7]
        t.delete(deleted)
        t.update(updated, _vals(updated.size, base=9000))
        t.insert(keys[800:1000], _vals(200, base=5000))
    survivors = np.setdiff1d(acked, np.concatenate([deleted, updated]))

    ops_total = _flush_ops(base, p + ".scratch", t.state)
    assert ops_total > 5
    for k in range(ops_total + 1):
        shutil.copyfile(base, p)
        wb = WritebackEngine(PmPool.open(p))
        wb.inject_crash(k)
        try:
            wb.flush(t.state)
            assert k >= ops_total               # full budget completes
        except SimulatedCrash:
            assert k < ops_total
        t2, info = persist.reopen(p)
        assert not info["clean"]
        f, v = t2.search(acked)
        # every acked key not acked-deleted must be found; the torn batch's
        # deletes are unacked so either outcome is consistent
        mask = np.isin(acked, survivors)
        assert f[mask].all(), \
            f"cut {k}: lost {int((~f[mask]).sum())} acked keys"
        idx = np.arange(acked.size)[mask]
        assert (v[mask] == _vals(800)[idx]).all(), f"cut {k}: torn values"
        if k >= ops_total:                      # completed flush: all of it
            f3, _ = t2.search(np.setdiff1d(
                keys[800:1200] if workload == "inserts_smo"
                else keys[800:1000], deleted))
            assert f3.all()


def test_torn_flush_after_logged_flush(tmp_path):
    """Two consecutive SMO-logged flushes. Since the phase-8 retiring
    commit (PR 6) a COMPLETED logged flush leaves no descriptor behind
    (``sb.log_bt == 0`` — a descriptor that fails its CRC at open is
    therefore real media loss, ``pool.log_lost``, never staleness). The
    second flush's cut sweep still covers every commit/apply/retire
    window: reopen must never refuse the pool and never lose acked keys."""
    rng = np.random.default_rng(23)
    keys = unique_keys(rng, 2200)
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    t.insert(keys[:500], _vals(500))
    t.flush()
    t.insert(keys[500:1100], _vals(600, base=3000))   # drives bulk splits
    t.flush()
    assert t.writeback.logged_rows > 0               # base commit was logged
    assert t.writeback.pool.sb.log_bt == 0           # ...and retired (ph. 8)
    assert not t.writeback.pool.log_lost
    base = p + ".base"
    shutil.copyfile(p, base)
    acked = keys[:1100]
    acked_vals = np.concatenate([_vals(500), _vals(600, base=3000)])

    t.insert(keys[1100:1700], _vals(600, base=7000))  # more splits -> log
    ops_total = _flush_ops(base, p + ".scratch", t.state)
    for k in range(ops_total + 1):
        shutil.copyfile(base, p)
        wb = WritebackEngine(PmPool.open(p))
        assert wb.pool.sb.log_bt == 0                # no stale descriptor
        wb.inject_crash(k)
        try:
            wb.flush(t.state)
            assert k >= ops_total
        except SimulatedCrash:
            assert k < ops_total
        t2, info = persist.reopen(p)                 # must never PoolError
        assert not info["log_lost"]                  # crash-only: no media rot
        f, v = t2.search(acked)
        assert f.all(), f"cut {k}: lost {int((~f).sum())} acked keys"
        assert (v == acked_vals).all(), f"cut {k}: torn values"


def test_torn_flush_then_more_work(tmp_path, rng):
    """A reopened torn pool keeps working: inserts, splits, flushes, and a
    second reopen — the redo log and version diff stay coherent."""
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    keys = unique_keys(rng, 1500)
    t.insert(keys[:600], _vals(600))
    t.flush()
    base = p + ".base"
    shutil.copyfile(p, base)
    t.insert(keys[600:1100], _vals(500, base=2000))
    ops = _flush_ops(base, p + ".scratch", t.state)
    shutil.copyfile(base, p)
    wb = WritebackEngine(PmPool.open(p))
    wb.inject_crash(max(ops - 2, 1))
    with pytest.raises(SimulatedCrash):
        wb.flush(t.state)
    t2, _ = persist.reopen(p)
    t2.insert(keys[1100:], _vals(400, base=8000))
    t2.flush()
    t2.close()
    t3, info = persist.reopen(p)
    assert info["clean"]
    f, _ = t3.search(np.concatenate([keys[:600], keys[1100:]]))
    assert f.all()


# -- serving integration ------------------------------------------------------

def test_frontend_flush_on_publish_and_reopen(tmp_path, rng):
    from repro.serving.frontend import INSERT, READ, DashFrontend, Op
    p = str(tmp_path / "t.pool")
    t = persist.create(p, SMALL)
    fe = DashFrontend(t, max_batch=128)
    keys = unique_keys(rng, 1500)
    ops = [Op(INSERT, int(k), int(i + 1)) for i, k in enumerate(keys)]
    for op in ops:
        assert fe.submit(op)
    fe.drain()
    st = fe.stats()
    # one flush per publish (plus the create-time full flush), hints audited
    assert st["flushes"] == st["published"] + 1
    assert st["flush_hint_misses"] == 0 and st["hint_misses"] == 0
    # flush volume tracks publish volume: both O(dirty), not O(pool)
    assert st["flushed_bytes"] < 4 * st["publish_bytes"] \
        + st["flushes"] * 4096 + st["pool_bytes"]
    del fe, t                                   # crash (no close)
    t2, info = persist.reopen(p)
    fe2 = DashFrontend(t2, max_batch=128)
    rops = [Op(READ, int(k)) for k in keys[:128]]
    for op in rops:
        fe2.submit(op)
    fe2.drain()
    assert all(op.found for op in rops)
    assert all(op.result == i + 1 for i, op in enumerate(rops))


def test_frontend_reads_recover_dirty_reopen(tmp_path, rng):
    """Frontend READS must lazily recover a dirty-reopened table: crash
    artifacts (wiped overflow metadata, dup records, held locks) are
    flushed durably, the pool reopens, and the frontend serves correct
    results on the read path alone — no table-API call ever runs."""
    from repro.serving.frontend import READ, DashFrontend, Op
    p = str(tmp_path / "t.pool")
    cfg = DashConfig(max_segments=32, dir_depth_max=8)
    t = persist.create(p, cfg)
    keys = unique_keys(rng, 4000)
    t.insert(keys, _vals(4000))
    t.flush()
    t.crash(np.random.default_rng(5), lock_frac=0.2, n_dups=6,
            wipe_overflow=True)
    t.flush()
    del t
    t2, info = persist.reopen(p)
    assert not info["clean"]
    fe = DashFrontend(t2, max_batch=256)
    for i in range(0, 4000, 256):
        ops = [Op(READ, int(k)) for k in keys[i:i + 256]]
        for op in ops:
            assert fe.submit(op)
        fe.drain()
        assert all(op.found for op in ops)
        vals = _vals(4000)[i:i + 256]
        assert all(op.result == int(v) for op, v in zip(ops, vals))
    assert t2.recovered_segments > 0            # reads drove the recovery
    assert fe.stats()["flush_hint_misses"] == 0


def test_shard_pools_reopen_independently(tmp_path, rng):
    """One pool per shard: flush a sharded state, corrupt/clean-close
    nothing, reopen each pool independently and verify the stacked state is
    bit-identical per plane."""
    from repro.distributed.dht import make_sharded_state
    cfg = SMALL
    n_shards = 4
    d = str(tmp_path / "shards")
    wbs = persist.create_shard_pools(d, cfg, n_shards)
    sh = make_sharded_state(cfg, n_shards)
    # make the shards distinct: different watermarks via direct plane edits
    import jax.numpy as jnp
    sh = sh._replace(
        n_items=jnp.asarray(np.arange(n_shards, dtype=np.int32) * 10),
        clean=jnp.zeros(n_shards, bool))
    persist.flush_shards(sh, wbs)
    stacked, wbs2, info = persist.reopen_shards(d)
    assert info["n_shards"] == n_shards
    assert info["dirty_shards"] == n_shards     # never closed cleanly
    for n in layout.DashState._fields:
        if n in ("clean", "gver", "seg_version", "version"):
            continue                            # restart bumps these
        assert np.array_equal(np.asarray(getattr(stacked, n)),
                              np.asarray(getattr(sh, n))), n
    # each shard's pool committed its own flush_seq independently
    assert all(w.pool.sb.flush_seq >= 2 for w in wbs2)
