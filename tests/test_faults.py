"""Media-failure hardening (ISSUE 6): deterministic fault injection,
checksummed planes, quarantine, scrubbing, degraded-mode serving, and the
chaos matrix safety property — every acked key served correctly or
explicitly reported lost, never a silent wrong read."""
import os

import numpy as np
import pytest

from repro import persist
from repro.core import DashConfig, recovery
from repro.core.table import DashEH
from repro.persist import (FaultPlan, FlushError, PoolError, Scrubber,
                           SimulatedCrash, TornPersist, WritebackDegraded)
from repro.persist.chaos import CHAOS_CFG, run_many, run_schedule
from repro.serving import frontend as fe
from repro.serving.frontend import INSERT, READ, DashFrontend, Op
from tests.conftest import unique_keys

SMALL = CHAOS_CFG


def _vals(n, base=1):
    return (np.arange(n) % 2**31).astype(np.uint32) + base


def _fill(path, n=400, faults=None, seed=0):
    t = persist.create(path, SMALL, faults=faults)
    keys = unique_keys(np.random.default_rng(seed), n)
    t.insert(keys, _vals(n))
    t.flush()
    return t, keys


# -- fault primitives ---------------------------------------------------------

def test_enospc_create_fails_clean(tmp_path):
    p = str(tmp_path / "t.pool")
    plan = FaultPlan(seed=1, enospc_creates=1)
    with pytest.raises(PoolError, match="[Nn]o space"):
        persist.create(p, SMALL, faults=plan)
    assert not os.path.exists(p)          # no partial file left behind
    assert plan.enospc_raised == 1
    t = persist.create(p, SMALL, faults=plan)   # same path, budget drained
    t.insert(unique_keys(np.random.default_rng(0), 50), _vals(50))
    t.flush()
    t.close()


def test_transient_eio_burst_absorbed(tmp_path):
    """A burst within the retry budget is invisible to the caller."""
    p = str(tmp_path / "t.pool")
    plan = FaultPlan(seed=2)
    t, keys = _fill(p, faults=plan)
    plan.eio_fences[plan.fence_calls] = 2
    t.insert(unique_keys(np.random.default_rng(7), 60, lo=2**62), _vals(60))
    t.flush()                             # retries eat the burst silently
    wb = t.writeback
    assert plan.eio_raised == 2 and wb.flush_retries >= 2
    assert not wb.degraded and wb.flush_io_errors == 2
    t.close()


def test_eio_burst_past_budget_degrades_then_recovers(tmp_path):
    p = str(tmp_path / "t.pool")
    plan = FaultPlan(seed=3)
    t, keys = _fill(p, faults=plan)
    plan.eio_fences[plan.fence_calls] = 9     # > retry budget
    t.insert(unique_keys(np.random.default_rng(8), 60, lo=2**62), _vals(60))
    with pytest.raises(WritebackDegraded):
        t.flush()
    wb = t.writeback
    assert wb.degraded
    with pytest.raises(WritebackDegraded):    # degraded engine refuses work
        t.flush()
    f, _ = t.search(keys)
    assert f.all()                            # serving continues volatile
    for _ in range(10):                       # probe until the burst drains
        if wb.try_recover(t.state):
            break
    assert not wb.degraded and wb.recoveries == 1
    t.close()
    t2, info = persist.reopen(p)              # recovery resynced the pool
    f, _ = t2.search(keys)
    assert f.all()


def test_torn_persist_quarantines_and_reports(tmp_path):
    """A torn msync reverts seeded cachelines mid-flush: reopen must
    quarantine every row whose checksum disagrees, serve all acked keys
    correctly or list them in the lost report, and heal the checksums."""
    p = str(tmp_path / "t.pool")
    plan = FaultPlan(seed=11, torn_line_frac=0.5)
    t, keys = _fill(p, faults=plan)
    plan.torn_fences = frozenset([plan.fence_calls + 1])
    t.insert(unique_keys(np.random.default_rng(9), 300, lo=2**62),
             _vals(300, base=5000))
    with pytest.raises(TornPersist):
        t.flush()
    assert plan.tears == 1 and plan.torn_bytes > 0
    t2, info = persist.reopen(p, faults=plan)
    f, v = t2.search(keys)
    wrong = int((f & (v != _vals(keys.size))).sum())
    assert wrong == 0                         # NEVER a silent wrong read
    for i in np.flatnonzero(~f):              # every miss explicitly lost
        assert _lost_covers(t2, int(keys[i])), \
            f"acked key {keys[i]} silently lost"
    bad = t2.writeback.pool.verify_checksums()
    assert bad["bt"].size == 0 and bad["nb"].size == 0   # healed


def _lost_covers(table, key) -> bool:
    from repro.persist.chaos import _reported_lost
    return _reported_lost(table.cfg, table.state, table.lost_report, key)


def test_bit_rot_quarantined_at_reopen(tmp_path):
    p = str(tmp_path / "t.pool")
    t, keys = _fill(p)
    t.close()
    plan = FaultPlan(seed=5, flip_csum_frac=0.3)
    pool = persist.PmPool.open(p, faults=plan)
    plan.flip_bits(pool, n=6)
    pool.close()
    t2, info = persist.reopen(p)
    assert info["quarantined_bt"] + info["quarantined_nb"] > 0
    assert len(t2.lost_report) > 0
    f, v = t2.search(keys)
    assert int((f & (v != _vals(keys.size))).sum()) == 0
    for i in np.flatnonzero(~f):
        assert _lost_covers(t2, int(keys[i]))
    # quarantined-row healing is durable: a second reopen verifies clean
    t2.close()
    t3, info3 = persist.reopen(p)
    assert info3["quarantined_bt"] == info3["quarantined_nb"] == 0


def test_scrubber_repairs_live_media_rot(tmp_path):
    p = str(tmp_path / "t.pool")
    plan = FaultPlan(seed=6)
    t, keys = _fill(p, faults=plan)
    scrub = Scrubber(t.writeback, rows_per_tick=512)
    plan.flip_bits(t.writeback.pool, n=4)
    pool = t.writeback.pool
    bad0 = sum(v.size for k, v in pool.verify_checksums().items()
               if k != "planes")
    assert bad0 > 0
    while scrub.cycles == 0:
        scrub.tick(t.state)
    assert scrub.repaired_rows == scrub.mismatched_rows >= 1
    bad1 = sum(v.size for k, v in pool.verify_checksums().items()
               if k != "planes")
    assert bad1 == 0                          # live state healed the media
    st = scrub.stats()
    assert st["scrub_scanned_rows"] >= bad0
    t.close()
    t2, info = persist.reopen(p)              # nothing left to quarantine
    assert info["quarantined_bt"] == 0 and len(t2.lost_report) == 0
    f, v = t2.search(keys)
    assert f.all() and (v == _vals(keys.size)).all()


# -- frontend health states ---------------------------------------------------

def test_frontend_degrades_and_recovers(tmp_path):
    p = str(tmp_path / "t.pool")
    plan = FaultPlan(seed=7)
    t = persist.create(p, SMALL, faults=plan)
    f = DashFrontend(t)
    keys = unique_keys(np.random.default_rng(1), 200)
    for k in keys[:120]:
        f.submit(Op(INSERT, int(k), int(k & 0x7FFFFFFF)))
    f.drain()
    assert f.health == fe.HEALTHY
    plan.eio_fences[plan.fence_calls] = 9
    for k in keys[120:]:
        f.submit(Op(INSERT, int(k), int(k & 0x7FFFFFFF)))
    f.drain()
    assert f.health == fe.DEGRADED and f.degraded_events == 1
    assert f.stats()["health"] == fe.DEGRADED
    assert f.unflushed_publishes >= 1
    r = Op(READ, int(keys[0]))
    f.submit(r)
    f.drain()
    assert r.found                            # reads keep serving
    for _ in range(10):
        if f.try_recover():
            break
    assert f.health == fe.HEALTHY and t.writeback.recoveries == 1
    f.shutdown()
    t.close()
    t2, _ = persist.reopen(p)                 # degraded-window keys resynced
    fo, _ = t2.search(keys)
    assert fo.all()


def test_frontend_readonly_on_capacity(tmp_path):
    cfg = DashConfig(max_segments=2, dir_depth_max=1, num_buckets=4,
                     num_slots=4, num_stash=1)
    f = DashFrontend(DashEH(cfg), readonly_on_full=True)
    acked = []
    for k in unique_keys(np.random.default_rng(3), 600):
        op = Op(INSERT, int(k), int(k & 0x7FFFFFFF))
        if f.submit(op):
            acked.append(op)
        f.step()
    f.drain()
    assert f.health == fe.READONLY
    ok = [op for op in acked if op.status == 0]
    # every admitted op resolved explicitly: OK or DROPPED, never stranded
    assert all(op.status >= 0 for op in acked)
    assert any(op.status != 0 for op in acked)
    r = Op(READ, ok[0].key)
    assert f.submit(r)                        # reads still admitted
    f.drain()
    assert r.found and r.result == ok[0].value
    assert not f.submit(Op(INSERT, 123, 1))   # writes rejected at admission
    assert not f.try_recover()                # READONLY is terminal
    kk = np.array([op.key for op in ok], np.uint64)
    fo, vv = f.table.search(kk)
    assert fo.all()
    assert (vv == np.array([op.value for op in ok], np.uint32)).all()


# -- per-shard fault isolation (host-level, no mesh needed) -------------------

def _stacked_state(tables):
    import jax.numpy as jnp
    from repro.core.layout import DashState
    return DashState(*[jnp.stack([np.asarray(getattr(t.state, n))
                                  for t in tables])
                       for n in DashState._fields])


def test_shard_fault_isolation(tmp_path):
    n_shards = 3
    plans = [FaultPlan(seed=40 + i) for i in range(n_shards)]
    wbs = persist.create_shard_pools(str(tmp_path), SMALL, n_shards,
                                     faults=plans)
    tables = [DashEH(SMALL) for _ in range(n_shards)]
    rng = np.random.default_rng(4)
    per = [unique_keys(rng, 200, lo=1 + i * 2**61, hi=(i + 1) * 2**61)
           for i in range(n_shards)]
    for t, keys in zip(tables, per):
        t.insert(keys, _vals(200))
    st = _stacked_state(tables)
    persist.flush_shards(st, wbs)
    # shard 1's device fails hard: only IT degrades, neighbors still flush
    plans[1].eio_fences[plans[1].fence_calls] = 99
    tables[0].insert(unique_keys(rng, 50, lo=2**60, hi=2**61), _vals(50))
    st = _stacked_state(tables)
    persist.flush_shards(st, wbs)
    assert [w.degraded for w in wbs] == [False, True, False]
    n0 = wbs[0].flushes
    persist.flush_shards(st, wbs)             # degraded shard is skipped
    assert wbs[0].flushes == n0 + 1 and wbs[1].degraded_flushes >= 1
    plans[1].eio_fences.clear()
    assert persist.recover_shards(st, wbs) == 1
    assert not any(w.degraded for w in wbs)
    for w in wbs:
        w.pool.close()
    # rot shard 0's closed pool (no faults armed while flipping)
    pools = persist.open_shard_pools(str(tmp_path))
    FaultPlan(seed=50).flip_bits(pools[0].pool, n=3)
    for w in pools:
        w.pool.close()
    # reopen: transient EIO on one shard is retried away; the flipped shard
    # quarantines locally and reports ONLY its own keys
    plans2 = [FaultPlan(seed=50 + i) for i in range(n_shards)]
    plans2[2].eio_fences[0] = 1
    st2, wbs2, info = persist.reopen_shards(str(tmp_path), faults=plans2)
    assert plans2[2].eio_raised == 1
    assert info["degraded_shards"] == 0
    assert set(info["lost_reports"]) <= {0}
    for w in wbs2:
        bad = w.pool.verify_checksums()
        assert bad["bt"].size == 0 and bad["nb"].size == 0


# -- the chaos matrix ---------------------------------------------------------

def test_chaos_matrix_quick(tmp_path):
    """Eight seeded schedules with forced tears + flips. ``run_schedule``
    raises on any safety violation; aggregate coverage is asserted here."""
    agg = run_many(range(8), str(tmp_path), min_tears=1, min_flips=1)
    assert agg["schedules"] == 8
    assert agg["wrong_reads"] == 0 and agg["silent_lost"] == 0
    assert agg["tears"] >= 8 and agg["flips"] >= 8 and agg["crashes"] >= 8
    assert agg["flushes"] > 0 and agg["ops"] > 0


@pytest.mark.slow
def test_chaos_matrix_full(tmp_path):
    """The wide sweep (part of the >=200-schedule evidence alongside
    benchmarks/chaos.py): 64 seeds, EIO + ENOSPC + tears + flips + scrub +
    pointer-mode lineages, zero silent wrong reads."""
    agg = run_many(range(100, 164), str(tmp_path), min_tears=1, min_flips=1)
    assert agg["schedules"] == 64
    assert agg["wrong_reads"] == 0 and agg["silent_lost"] == 0
    assert agg["tears"] >= 64 and agg["eio_raised"] > 0
    assert agg["degraded_events"] > 0 and agg["pointer_mode"] > 0


# -- pointer-mode allocator safety (regression for the heap_top floor) --------

def test_heap_top_floor_guards_reopened_allocator(tmp_path):
    """Kill a pointer-mode flush at every store boundary; after each torn
    reopen, KEEP INSERTING. The bump allocator must never re-issue a heap
    row a published record references (reopen raises heap_top past the
    highest live handle), so acked keys survive the post-crash inserts."""
    import dataclasses as dc
    import shutil
    from repro.persist import PmPool, WritebackEngine
    cfg = dc.replace(SMALL, pointer_mode=True, key_heap_size=4096,
                     key_heap_words=2)
    from repro.persist.chaos import _words_of
    p = str(tmp_path / "t.pool")
    t = persist.create(p, cfg)
    acked = np.arange(1, 201, dtype=np.uint64)
    t.insert(values=_vals(200), words=_words_of(acked, 2))
    t.flush()
    base = p + ".base"
    shutil.copyfile(p, base)
    fresh = np.arange(201, 301, dtype=np.uint64)
    t.insert(values=_vals(100, base=9000), words=_words_of(fresh, 2))
    shutil.copyfile(base, p + ".scratch")
    wb = WritebackEngine(PmPool.open(p + ".scratch"))
    wb.inject_crash(1 << 30)
    wb.flush(t.state)
    ops_total = (1 << 30) - wb._ops_budget
    post = np.arange(1001, 1101, dtype=np.uint64)
    for k in range(0, ops_total + 1, 3):
        shutil.copyfile(base, p)
        wb = WritebackEngine(PmPool.open(p))
        wb.inject_crash(k)
        try:
            wb.flush(t.state)
        except SimulatedCrash:
            pass
        t2, _ = persist.reopen(p)
        top = int(np.asarray(t2.state.heap_top))
        t2.insert(values=_vals(100, base=7000), words=_words_of(post, 2))
        f, v = t2.search(words=_words_of(acked, 2))
        assert f.all(), f"cut {k}: post-reopen inserts ate acked keys"
        assert (v == _vals(200)).all(), f"cut {k}: torn values (top={top})"
