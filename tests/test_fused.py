"""Differential tests for the fused small-batch latency path (kernels/fused.py).

The fused mega-dispatch (route -> probe -> commit in ONE jitted call) is what
the table planner selects for batches at or under ``DashTable.fused_threshold``,
so its correctness contract is bit-identity with the reference engines on any
fill: ``fused_insert`` == the scan engine (table state + statuses + stash
activation) and ``fused_search`` == the per-key vmap path (found + values),
across the feature-flag matrix (balanced / displacement / fingerprints /
overflow-metadata / stash ablations), LH addressing, pointer mode, padding
(valid) masks, in-batch duplicate keys, stash overflow and NEED_SPLIT
pressure. The Pallas mega-kernel and its jnp lowering are differentially
checked against each other and the vmap reference too.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH, engine, hashing, layout
from repro.kernels import fused
from tests._hypothesis_compat import given, settings, st
from tests.conftest import unique_keys

B = 64            # one jit trace per (cfg, op) pair

#: feature-flag matrix — every ablation the fused commit mirrors branch-free
CONFIGS = {
    "default": DashConfig(max_segments=8, dir_depth_max=6, init_depth=1),
    "no_disp": DashConfig(max_segments=8, dir_depth_max=6, init_depth=1,
                          use_displacement=False),
    "no_fp": DashConfig(max_segments=8, dir_depth_max=6, init_depth=1,
                        use_fingerprints=False),
    "no_ometa": DashConfig(max_segments=8, dir_depth_max=6, init_depth=1,
                           use_overflow_meta=False),
    "no_stash": DashConfig(max_segments=8, dir_depth_max=6, init_depth=1,
                           num_stash=0),
    "no_ofp": DashConfig(max_segments=8, dir_depth_max=6, init_depth=1,
                         num_ofp=0),
    "small_buckets": DashConfig(max_segments=8, dir_depth_max=6,
                                init_depth=1, num_buckets=16, num_slots=8),
}


def _diverged(sa, sb):
    return [name for name, a, b in zip(sa._fields, jax.tree.leaves(sa),
                                       jax.tree.leaves(sb))
            if not (np.asarray(a) == np.asarray(b)).all()]


def _keys(rng, n):
    ks = unique_keys(rng, n)
    hi, lo = hashing.np_split_keys(ks)
    return jnp.asarray(hi), jnp.asarray(lo)


def _check_search(cfg, mode, state, hi, lo):
    f_v, v_v = engine.search_batch(cfg, mode, state, hi, lo, batching="vmap")
    f_f, v_f = engine.search_batch(cfg, mode, state, hi, lo, batching="fused")
    assert (np.asarray(f_v) == np.asarray(f_f)).all()
    assert (np.asarray(v_v) == np.asarray(v_f)).all()


def _drive(cfg, mode, rng, rounds=4, mask_round=2):
    """Fill a tiny table through both engines round by round; the small
    geometry reaches stash overflow and NEED_SPLIT within a few batches."""
    st_scan = layout.make_state(cfg, mode)
    st_fus = jax.tree.map(jnp.copy, st_scan)
    hi_all, lo_all = _keys(rng, rounds * B)
    saw_split = saw_stash = False
    for r in range(rounds):
        hi, lo = hi_all[r * B:(r + 1) * B], lo_all[r * B:(r + 1) * B]
        # in-batch duplicates: repeat a quarter of the lanes
        hi = hi.at[B // 2:B // 2 + B // 4].set(hi[:B // 4])
        lo = lo.at[B // 2:B // 2 + B // 4].set(lo[:B // 4])
        vals = jnp.asarray(rng.integers(1, 2**32, B).astype(np.uint32))
        valid = jnp.asarray(np.arange(B) < B // 2) if r == mask_round else None
        st_scan, s1, a1 = engine.insert_batch(
            cfg, mode, st_scan, hi, lo, vals, None, valid, batching="scan")
        st_fus, s2, a2 = engine.insert_batch(
            cfg, mode, st_fus, hi, lo, vals, None, valid, batching="fused")
        assert (np.asarray(s1) == np.asarray(s2)).all(), r
        assert bool(a1) == bool(a2), r
        bad = _diverged(st_scan, st_fus)
        assert not bad, (r, bad)
        saw_split |= bool((np.asarray(s1) == layout.NEED_SPLIT).any())
        if cfg.num_stash:             # records actually landed in stash rows
            stash_alloc = layout.meta_alloc(
                jnp.asarray(np.asarray(st_scan.meta)[:, cfg.num_buckets:]))
            saw_stash |= bool((np.asarray(stash_alloc) != 0).any())
        # read paths agree on the (identical) state, hits and misses both
        _check_search(cfg, mode, st_scan, hi, lo)
    miss_hi, miss_lo = _keys(np.random.default_rng(999), B)
    _check_search(cfg, mode, st_scan, miss_hi, miss_lo)
    return saw_split, saw_stash


def test_fused_matches_scan_across_feature_matrix():
    for name, cfg in CONFIGS.items():
        rng = np.random.default_rng(abs(hash(name)) % 2**32)
        _drive(cfg, "eh", rng)


def test_fused_matches_scan_under_pressure():
    """Drive the small geometry past capacity: stash activation and
    NEED_SPLIT pressure must actually occur AND stay bit-identical."""
    cfg = CONFIGS["small_buckets"]
    saw_split, saw_stash = _drive(cfg, "eh", np.random.default_rng(0xE0),
                                  rounds=8, mask_round=5)
    assert saw_split and saw_stash


def test_fused_matches_scan_under_lh_mode():
    cfg = DashConfig(max_segments=32, num_stash=4, lh_base_log2=2)
    _drive(cfg, "lh", np.random.default_rng(0x1A))


def test_fused_search_pointer_mode():
    """Pointer mode: query identity folds the full key words, and the probe
    dereferences heap handles — the fused gather must match vmap on both
    hit and miss lanes. (Fused INSERT is ineligible in pointer mode and
    falls back to the scan engine inside fused_insert — also checked.)"""
    cfg = DashConfig(max_segments=16, dir_depth_max=8, pointer_mode=True,
                     key_heap_size=4096, key_heap_words=3)
    rng = np.random.default_rng(0xF0)
    state = layout.make_state(cfg, "eh")
    words = jnp.asarray(
        rng.integers(1, 2**32, (2 * B, cfg.key_heap_words)).astype(np.uint32))
    vals = jnp.asarray(np.arange(2 * B, dtype=np.uint32) + 1)
    hi, lo = hashing.key_identity_from_words(words)
    state, s1, _ = engine.insert_batch(cfg, "eh", state, hi, lo, vals,
                                       words, batching="scan")
    st2 = jax.tree.map(jnp.copy, layout.make_state(cfg, "eh"))
    st2, s2, _ = engine.insert_batch(cfg, "eh", st2, hi, lo, vals,
                                     words, batching="fused")
    assert (np.asarray(s1) == np.asarray(s2)).all()
    assert not _diverged(state, st2)
    # hits: same words; misses: fresh words never inserted
    miss = jnp.asarray(
        rng.integers(1, 2**32, (B, cfg.key_heap_words)).astype(np.uint32))
    for w in (words[:B], miss):
        qh, ql = hashing.key_identity_from_words(w)
        f_v, v_v = engine.search_batch(cfg, "eh", state, qh, ql, words=w,
                                       batching="vmap")
        f_f, v_f = engine.search_batch(cfg, "eh", state, qh, ql, words=w,
                                       batching="fused")
        assert (np.asarray(f_v) == np.asarray(f_f)).all()
        assert (np.asarray(v_v) == np.asarray(v_f)).all()


def test_fused_kernel_matches_lowering_and_vmap():
    """The Pallas mega-kernel (interpret mode on CPU) and its jnp lowering
    must agree lane-for-lane, and both must agree with the per-key vmap
    reference on every kept (routed) lane."""
    cfg = DashConfig(max_segments=8, dir_depth_max=6, init_depth=1)
    rng = np.random.default_rng(0xCAFE)
    state = layout.make_state(cfg, "eh")
    hi, lo = _keys(rng, 256)
    vals = jnp.asarray(np.arange(256, dtype=np.uint32) + 1)
    state, _, _ = engine.insert_batch(cfg, "eh", state, hi, lo, vals,
                                      batching="scan")
    # queries: half hits, half misses
    mh, ml = _keys(np.random.default_rng(7), 128)
    qhi = jnp.concatenate([hi[:128], mh])
    qlo = jnp.concatenate([lo[:128], ml])

    from repro.kernels import ops
    h1 = hashing.hash1(qhi, qlo)
    h2 = hashing.hash2(qhi, qlo)
    fpv = (h2 & jnp.uint32(0xFF)).astype(jnp.int32)
    seg, b = ops.locate_batch(cfg, "eh", state, h1)
    NB = cfg.num_buckets
    capacity = 256                      # BQ-aligned
    lanes, src, keep = ops.route_lanes(
        seg, (fpv, b.astype(jnp.int32), qhi, qlo, seg >= 0),
        cfg.max_segments, capacity, (0, -1, 0, 0, False))
    q_fp, q_b, q_hi, q_lo, q_valid = lanes
    q_b = jnp.where(q_valid, q_b, -1)
    q_pb = jnp.where(q_valid, (q_b + 1) & (NB - 1), -1)
    q_fp = jnp.where(q_valid, q_fp, -1)
    planes = fused.fused_plane_views(
        cfg, state, jnp.arange(cfg.max_segments, dtype=jnp.int32))
    f_k, v_k = fused.fused_probe(planes, q_fp, q_b, q_pb, q_hi, q_lo,
                                 nb=NB, ns=cfg.num_stash, interpret=True)
    f_j, v_j = fused.fused_probe_jnp(planes, q_fp, q_b, q_pb, q_hi, q_lo,
                                     nb=NB, ns=cfg.num_stash)
    assert (np.asarray(f_k) == np.asarray(f_j)).all()
    assert (np.asarray(v_k) == np.asarray(v_j)).all()
    # scatter back and compare with vmap on kept lanes
    f_ref, v_ref = engine.search_batch(cfg, "eh", state, qhi, qlo,
                                       batching="vmap")
    flatf, flatv = np.asarray(f_j).reshape(-1), np.asarray(v_j).reshape(-1)
    srcf = np.asarray(src).reshape(-1)
    keep_np = np.asarray(keep)
    got_f = np.zeros(qhi.shape[0], bool)
    got_v = np.zeros(qhi.shape[0], np.uint32)
    m = srcf >= 0
    got_f[srcf[m]] = flatf[m] != 0
    got_v[srcf[m]] = flatv[m]
    assert (got_f[keep_np] == np.asarray(f_ref)[keep_np]).all()
    assert (got_v[keep_np] == np.asarray(v_ref)[keep_np]).all()


OPS = st.lists(st.sampled_from(["ins", "mask", "dup"]), min_size=1,
               max_size=5)


@given(OPS)
@settings(max_examples=4, deadline=None)
def test_fused_randomized_fills(ops):
    """Hypothesis-style op mixes: fused vs scan stay bit-identical through
    arbitrary insert/mask/duplicate sequences, reads checked every step."""
    cfg = DashConfig(max_segments=8, dir_depth_max=6, init_depth=1)
    rng = np.random.default_rng(abs(hash(tuple(ops))) % 2**32)
    keyspace = np.unique(rng.integers(1, 2**63, 500, dtype=np.uint64))
    st_scan = layout.make_state(cfg, "eh")
    st_fus = jax.tree.map(jnp.copy, st_scan)
    for step, op in enumerate(ops):
        ks = keyspace[rng.integers(0, keyspace.size, B)]
        if op == "dup":               # heavy duplication inside one batch
            ks = np.repeat(ks[:B // 8], 8)[:B]
        hi, lo = hashing.np_split_keys(ks)
        hi, lo = jnp.asarray(hi), jnp.asarray(lo)
        vals = jnp.asarray(rng.integers(1, 2**32, B).astype(np.uint32))
        valid = jnp.asarray(rng.random(B) < 0.6) if op == "mask" else None
        st_scan, s1, a1 = engine.insert_batch(
            cfg, "eh", st_scan, hi, lo, vals, None, valid, batching="scan")
        st_fus, s2, a2 = engine.insert_batch(
            cfg, "eh", st_fus, hi, lo, vals, None, valid, batching="fused")
        assert (np.asarray(s1) == np.asarray(s2)).all(), (step, op)
        assert bool(a1) == bool(a2)
        bad = _diverged(st_scan, st_fus)
        assert not bad, (step, op, bad)
        _check_search(cfg, "eh", st_scan, hi, lo)


def test_table_planner_selects_fused():
    """The table routes small batches to the fused path and the threshold
    knob forces either side; end-to-end results are identical."""
    cfg = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1)
    rng = np.random.default_rng(3)
    keys = unique_keys(rng, 2000)
    vals = np.arange(2000, dtype=np.uint32)
    t_fused = DashEH(cfg)                       # default threshold: fused
    t_off = DashEH(cfg, fused_threshold=0)      # forced routed/scan
    hi, lo = hashing.np_split_keys(keys[:256])
    seg = t_fused._segments_of(hi, lo)
    assert t_fused._write_plan(seg, 256)[0] == "fused"
    assert t_fused._search_plan(seg)[0] == "fused"
    assert t_off._write_plan(seg, 256)[0] != "fused"
    assert t_off._search_plan(seg)[0] != "fused"
    # delete/update never take the fused path (no fused engine for them)
    assert t_fused._write_plan(seg, 256, fused_ok=False)[0] != "fused"
    s1 = t_fused.insert(keys, vals)
    s2 = t_off.insert(keys, vals)
    assert (s1 == s2).all()
    assert not _diverged(t_fused.state, t_off.state)
    f1, v1 = t_fused.search(keys)
    f2, v2 = t_off.search(keys)
    assert f1.all() and (np.asarray(f1) == np.asarray(f2)).all()
    assert (np.asarray(v1) == np.asarray(v2)).all()
