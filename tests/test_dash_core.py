"""Dash-EH/LH correctness: dict-oracle property tests + invariants."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import (DashConfig, DashEH, DashLH, EXISTS, INSERTED,
                        NOT_FOUND)
from tests.conftest import unique_keys

SMALL = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1)


@pytest.mark.parametrize("cls,cfg", [
    (DashEH, SMALL),
    (DashLH, DashConfig(max_segments=64, num_stash=4, lh_base_log2=2)),
])
def test_insert_search_delete_roundtrip(cls, cfg, rng):
    t = cls(cfg)
    keys = unique_keys(rng, 3000)
    vals = (np.arange(3000) % 2**32).astype(np.uint32)
    st_ = t.insert(keys, vals)
    assert (st_ == INSERTED).all()
    f, v = t.search(keys)
    assert f.all() and (v == vals).all()
    # negatives
    neg = np.setdiff1d(unique_keys(rng, 2000), keys)[:500]
    f2, _ = t.search(neg)
    assert f2.sum() == 0
    # duplicate insert
    st2 = t.insert(keys[:100], vals[:100])
    assert (st2 == EXISTS).all()
    # delete half, check both sides
    d = t.delete(keys[:1500])
    assert (d == INSERTED).all()
    f3, _ = t.search(keys[:1500])
    assert f3.sum() == 0
    f4, v4 = t.search(keys[1500:])
    assert f4.all() and (v4 == vals[1500:]).all()
    assert t.n_items == 1500
    # delete absent -> NOT_FOUND
    d2 = t.delete(neg[:50])
    assert (d2 == NOT_FOUND).all()


OPS = st.lists(
    st.tuples(st.sampled_from(["ins", "del", "get"]), st.integers(0, 120)),
    min_size=1, max_size=120)


@given(OPS)
@settings(max_examples=12, deadline=None)
def test_oracle_random_ops(ops):
    """Arbitrary op sequences match a python dict oracle."""
    cfg = DashConfig(max_segments=16, dir_depth_max=6, init_depth=1)
    t = DashEH(cfg)
    oracle = {}
    keyspace = np.random.default_rng(7).integers(
        1, 2**63, 200, dtype=np.uint64)
    for op, ki in ops:
        k = keyspace[ki % keyspace.size]
        karr = np.array([k], np.uint64)
        if op == "ins":
            v = np.array([ki + 1], np.uint32)
            s = t.insert(karr, v)
            if int(k) in oracle:
                assert s[0] == EXISTS
            else:
                assert s[0] == INSERTED
                oracle[int(k)] = ki + 1
        elif op == "del":
            s = t.delete(karr)
            if int(k) in oracle:
                assert s[0] == INSERTED
                del oracle[int(k)]
            else:
                assert s[0] == NOT_FOUND
        else:
            f, v = t.search(karr)
            assert bool(f[0]) == (int(k) in oracle)
            if f[0]:
                assert int(v[0]) == oracle[int(k)]
    assert t.n_items == len(oracle)


def test_eh_directory_invariants(rng):
    """local_depth <= global_depth; each segment owns exactly
    2^(dir_max - local_depth) contiguous directory entries."""
    cfg = SMALL
    t = DashEH(cfg)
    keys = unique_keys(rng, 6000)
    t.insert(keys, np.zeros(6000, np.uint32))
    dirv = np.asarray(t.state.dir)
    depths = np.asarray(t.state.local_depth)
    gd = t.global_depth
    wm = t.n_segments
    for seg in range(wm):
        entries = np.where(dirv == seg)[0]
        assert depths[seg] <= gd
        assert entries.size == 1 << (cfg.dir_depth_max - depths[seg])
        assert (np.diff(entries) == 1).all()      # contiguous (MSB indexing)


def test_lh_round_advance(rng):
    cfg = DashConfig(max_segments=64, num_stash=4, lh_base_log2=1)
    t = DashLH(cfg)
    keys = unique_keys(rng, 6000)
    t.insert(keys, np.zeros(6000, np.uint32))
    assert t.active_segments == t.n_segments
    f, _ = t.search(keys)
    assert f.all()


def test_load_factor_exceeds_80pct_with_4_stash(rng):
    """Paper Fig. 12: Dash-EH(4 stash) reaches ~90% peak; assert >= 75%
    at the moment before a split (conservative CI bound)."""
    cfg = DashConfig(max_segments=4, dir_depth_max=4, init_depth=1,
                     num_stash=4)
    t = DashEH(cfg)
    keys = unique_keys(rng, 4000)
    peak = 0.0
    i = 0
    try:
        while i < 4000:
            t.insert(keys[i:i + 64], np.zeros(64, np.uint32))
            peak = max(peak, t.load_factor)
            i += 64
    except Exception:
        pass
    assert peak >= 0.75, peak


def test_merge_shrinks_after_deletes(rng):
    """Paper Sec. 4.7 merge: delete most records, shrink, verify integrity
    and that freed segments are recycled by later splits."""
    cfg = DashConfig(max_segments=64, dir_depth_max=9, init_depth=1)
    t = DashEH(cfg)
    keys = unique_keys(rng, 10_000)
    vals = np.arange(10_000, dtype=np.uint32)
    t.insert(keys, vals)
    segs_before = len(np.unique(np.asarray(t.state.dir)))
    t.delete(keys[1000:])
    merges = t.shrink(target_fill=0.8)
    assert merges > 0
    segs_after = len(np.unique(np.asarray(t.state.dir)))
    assert segs_after < segs_before
    # survivors intact, deleted keys gone, counts exact
    f, v = t.search(keys[:1000])
    assert f.all() and (v == vals[:1000]).all()
    f2, _ = t.search(keys[1000:2000])
    assert f2.sum() == 0
    assert t.n_items == 1000
    # directory invariants hold after merging
    dirv = np.asarray(t.state.dir)
    depths = np.asarray(t.state.local_depth)
    for seg in np.unique(dirv):
        entries = np.where(dirv == seg)[0]
        assert entries.size == 1 << (cfg.dir_depth_max - depths[seg])
        assert (np.diff(entries) == 1).all()
    # freed ids get recycled on regrowth
    freed = set(t.free_segments)
    assert freed
    t.insert(keys[1000:6000], vals[1000:6000])
    assert not (set(t.free_segments) & freed) or len(t.free_segments) < len(freed)


def test_hybrid_expansion_directory_claim():
    """Paper Sec. 5.2: '16KB segments, first array 64 segments, stride 4 =>
    TB-level data with a directory less than 1KB'."""
    from repro.core.dash_lh import hybrid_expansion_directory
    tb_segments = (1 << 40) // (16 * 1024)      # segments for 1 TB
    entries, dir_bytes, largest = hybrid_expansion_directory(
        tb_segments, stride=4, first_array=64)
    assert dir_bytes < 1024, dir_bytes
    # flat directory for comparison would need 8B per segment
    assert tb_segments * 8 > 500 * dir_bytes


def test_epoch_reclamation():
    from repro.core.epoch import EpochManager
    freed = []
    em = EpochManager(reclaim=freed.append)
    with em.pin():
        em.retire("v1")                 # reader pinned: must not reclaim yet
        assert freed == []
    em.retire("v2")
    em.retire("v3")
    em.flush()
    assert set(freed) == {"v1", "v2", "v3"}
    assert em.reclaimed == 3
