"""Unit tests for the roofline tooling: HLO parser trip-count correction and
collective wire-byte accounting (the numbers EXPERIMENTS.md relies on)."""
import textwrap

from repro.launch import hlo_analysis


HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %a = f32[8,8]{1,0} get-tuple-element(%p), index=1
      %b = f32[8,8]{1,0} parameter(1)
      %dot.1 = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256], to_apply=%add
      ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %p = (s32[], f32[8,8]) parameter(0)
      %c = s32[] constant(4)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (x: f32[8,8]) -> f32[8,8] {
      %x = f32[8,8]{1,0} parameter(0)
      %w = f32[8,8]{1,0} parameter(1)
      %dot.0 = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %wl = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
      %ag = f32[8,8]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
      ROOT %r = f32[8,8]{1,0} get-tuple-element(%wl), index=1
    }
""")


def test_trip_count_correction():
    res = hlo_analysis.analyze(HLO)
    one_dot = 2 * 8 * 8 * 8            # 2*M*N*K
    # entry dot once + body dot x4 trips
    assert res["dot_flops"] == one_dot * (1 + 4)


def test_collective_wire_accounting():
    res = hlo_analysis.analyze(HLO)
    sz = 8 * 8 * 4                     # f32[8,8]
    n = 16                             # groups of 16
    # body all-reduce x4 trips (2*size*(n-1)/n) + entry all-gather once
    want_ar = 4 * 2 * sz * (n - 1) / n
    want_ag = sz * (n - 1) / n
    assert abs(res["collectives"]["all-reduce"] - want_ar) < 1e-6
    assert abs(res["collectives"]["all-gather"] - want_ag) < 1e-6
    assert res["collective_counts"]["all-reduce"] == 4


def test_roofline_loader_on_artifacts():
    import glob
    if not glob.glob("experiments/dryrun/pod/*.json"):
        import pytest
        pytest.skip("no sweep artifacts")
    from benchmarks import roofline
    recs = roofline.load_records("pod")
    ok = [r for r in recs if r["status"] == "ok"]
    assert len(ok) == 34
    for r in ok:
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
