"""Unified observability layer (ISSUE 8): metrics registry accuracy,
op-lifecycle span causality across publish/flush/SMO, bounded trace
memory, Chrome-trace export schema, SLO windows + rules, and the one-clock
sojourn unification in the serving frontend."""
import json
import math

import numpy as np
import pytest

from repro import obs as obs_mod
from repro import persist
from repro.core import DashConfig
from repro.core.table import DashEH
from repro.obs import (Histogram, Observability, Registry, SloRule, Tracer,
                       export_chrome_trace)
from repro.persist.chaos import CHAOS_CFG
from repro.serving import frontend as fe
from repro.serving.frontend import INSERT, READ, DashFrontend, Op
from tests.conftest import unique_keys

CFG = DashConfig(max_segments=32, dir_depth_max=7, num_buckets=16,
                 num_slots=8)

#: log-bucket geometry bound: half-bucket ratio at 16 buckets/octave
BUCKET_ERR = 2.0 ** (1.0 / (2 * 16)) - 1          # ~2.2%


# ---------------------------------------------------------------------------
# histogram accuracy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_histogram_percentiles_match_numpy(dist):
    rng = np.random.default_rng(hash(dist) % 2**31)
    if dist == "lognormal":
        vs = rng.lognormal(-9.0, 1.5, 20_000)            # us..ms sojourns
    elif dist == "uniform":
        vs = rng.uniform(1e-6, 1e-2, 20_000)
    else:
        # 12k/8k mix keeps p50 inside the fast mode (a 50/50 split would
        # put the median rank exactly at the mode boundary, where exact
        # interpolation and bucket extraction legitimately diverge)
        vs = np.concatenate([rng.normal(50e-6, 5e-6, 12_000),
                             rng.normal(5e-3, 5e-4, 8_000)])
        vs = np.abs(vs) + 1e-9
    h = Histogram("t")
    h.observe_many(vs)
    assert h.n == vs.size
    for q in (50, 90, 99):
        exact = float(np.percentile(vs, q))
        approx = h.percentile(q)
        # geometric buckets + midpoint extraction: half-bucket worst case,
        # plus sample-vs-bucket rank rounding — 2x the geometry bound is a
        # comfortable yet tight envelope
        assert abs(approx - exact) / exact <= 2 * BUCKET_ERR + 0.01, \
            (dist, q, approx, exact)
    assert h.percentile(100) == vs.max()
    snap = h.snapshot()
    assert snap["n"] == vs.size
    assert snap["mean"] == pytest.approx(vs.mean())
    assert snap["max"] == vs.max()


def test_histogram_scalar_and_vector_paths_agree():
    rng = np.random.default_rng(7)
    vs = rng.lognormal(-8, 2, 500)
    h1, h2 = Histogram("a"), Histogram("b")
    for v in vs:
        h1.observe(float(v))
    h2.observe_many(vs)
    assert (h1.counts == h2.counts).all()
    assert h1.n == h2.n and h1.vmin == h2.vmin and h1.vmax == h2.vmax


def test_histogram_merge_and_empty():
    h = Histogram("e")
    assert math.isnan(h.percentile(50))
    a, b = Histogram("a"), Histogram("b")
    a.observe_many([1e-5] * 10)
    b.observe_many([1e-3] * 10)
    a.merge(b)
    assert a.n == 20
    assert a.percentile(50) == pytest.approx(1e-5, rel=3 * BUCKET_ERR)
    assert a.percentile(99) == pytest.approx(1e-3, rel=3 * BUCKET_ERR)


# ---------------------------------------------------------------------------
# registry: scopes, ingest, shard aggregation
# ---------------------------------------------------------------------------

def test_registry_scope_ingest_aggregate():
    r = Registry()
    s = r.scope("frontend")
    s.counter("acks").inc(5)
    s.gauge("depth").set(3)
    r.ingest({"published": 7, "degraded": False, "name": "x"},
             prefix="stats.")
    snap = r.snapshot()
    assert snap["frontend.acks"] == 5
    assert snap["stats.published"] == 7
    assert snap["stats.degraded"] == 0
    assert "stats.name" not in snap                    # strings skipped
    # per-shard mirrors: counters=True lands values in Counters so the
    # fleet aggregate SUMS (gauges would take the last shard)
    shards = []
    for i in range(3):
        sr = Registry()
        sr.ingest({"flushed_bytes": 100 * (i + 1)}, prefix="wb.",
                  counters=True)
        shards.append(sr)
    agg = Registry.aggregate(shards)
    assert agg.snapshot()["wb.flushed_bytes"] == 600
    # type collisions are programming errors, caught loudly
    with pytest.raises(AssertionError):
        r.gauge("frontend.acks")


# ---------------------------------------------------------------------------
# tracer: ring bound, span stack, links
# ---------------------------------------------------------------------------

def test_tracer_ring_is_bounded():
    tr = Tracer(enabled=True, capacity=64)
    for i in range(1000):
        sp = tr.begin("op", "t", i=i)
        tr.end(sp)
    assert len(tr.spans()) == 64
    assert tr.recorded == 1000
    assert tr.dropped == 1000 - 64
    assert tr.spans()[-1].args["i"] == 999             # newest retained
    st = tr.stats()
    assert st["trace_buffered"] == 64 and st["trace_dropped"] == 936


def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    sp = tr.begin("x")
    assert sp is None
    tr.end(sp)                                          # None-safe
    tr.instant("y")
    with tr.span("z"):
        assert tr.current() is None
    assert tr.spans() == [] and tr.recorded == 0


def test_tracer_nesting_and_links():
    tr = Tracer(enabled=True)
    with tr.span("outer", "t") as out:
        with tr.span("inner", "t") as inn:
            assert inn.parent == out.sid
        det = tr.begin("detached", "t")
        assert det.parent == out.sid                    # stack-top parent
        tr.end(det)
    ack = tr.begin("ack", "t", parent=None)
    Tracer.link(ack, out, None, det.sid)                # Nones skipped
    tr.end(ack)
    assert set(ack.links) == {out.sid, det.sid}


# ---------------------------------------------------------------------------
# chrome trace export schema
# ---------------------------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("parent", "cat") as p:
        with tr.span("child", "cat"):
            pass
    tr.instant("mark", "cat", note=1)
    ack = tr.begin("ack", "cat")
    Tracer.link(ack, p)
    tr.end(ack)
    path = str(tmp_path / "trace.json")
    doc = export_chrome_trace(tr, path)
    on_disk = json.load(open(path))
    assert on_disk == doc
    evs = doc["traceEvents"]
    assert doc["metadata"]["recorded"] == 4
    for e in evs:
        assert e["ph"] in ("X", "i", "s", "f")
        assert isinstance(e["ts"], (int, float))
        assert "pid" in e and "tid" in e and "name" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
    # each link renders as a flow start/finish pair with matching id
    starts = [e for e in evs if e["ph"] == "s"]
    finishes = [e for e in evs if e["ph"] == "f"]
    assert len(starts) == len(finishes) == 1
    assert starts[0]["id"] == finishes[0]["id"]
    # args carry the span graph for programmatic verification
    by_sid = {e["args"]["sid"]: e for e in evs if e["ph"] in ("X", "i")}
    child = next(e for e in by_sid.values() if e["name"] == "child")
    assert by_sid[child["args"]["parent"]]["name"] == "parent"
    ack_ev = next(e for e in by_sid.values() if e["name"] == "ack")
    assert p.sid in ack_ev["args"]["links"]


# ---------------------------------------------------------------------------
# SLO monitor: windows, rates, rules, health dwell
# ---------------------------------------------------------------------------

def test_slo_windows_rates_and_rules():
    clk = [0.0]
    reg = Registry()
    mon = obs_mod.SloMonitor(
        reg, rules=[SloRule("p99_read", "read_sojourn.p99_s", max=1e-3),
                    SloRule("flush_rate", "rates.fb_per_s", min=1.0)],
        eval_interval=4, clock=lambda: clk[0])
    h = reg.histogram("frontend.read_sojourn_s")
    c = reg.counter("frontend.flush_bytes")
    mon.watch_histogram("read_sojourn", h)
    mon.watch_rate("fb_per_s", c)
    # window 1: fast reads, healthy flush rate -> no violations
    h.observe_many([50e-6] * 100)
    c.inc(1000)
    for _ in range(4):
        clk[0] += 0.25
        mon.tick()
    snap = mon.snapshot()
    assert snap["read_sojourn"]["n"] == 100
    assert snap["read_sojourn"]["p99_s"] < 1e-3
    assert snap["rates"]["fb_per_s"] == pytest.approx(1000.0, rel=0.01)
    assert snap["violations"] == []
    # window 2: slow tail + stalled flushes -> both rules fire
    h.observe_many([5e-3] * 100)
    for _ in range(4):
        clk[0] += 0.25
        mon.tick()
    snap = mon.snapshot()
    assert snap["read_sojourn"]["n"] == 100             # windowed, not cum.
    names = {v["rule"] for v in snap["violations"]}
    assert names == {"p99_read", "flush_rate"}
    assert snap["violation_count"] == 2
    # callable extra evaluated only on eval ticks
    calls = []
    for _ in range(4):
        clk[0] += 0.25
        mon.tick(lambda: calls.append(1) or {"queue_depth": 9})
    assert len(calls) == 1
    assert mon.snapshot()["queue_depth"] == 9


def test_slo_health_dwell():
    clk = [0.0]
    reg = Registry()
    mon = obs_mod.SloMonitor(reg, eval_interval=1, clock=lambda: clk[0])
    mon.note_health(0)
    clk[0] = 2.0
    mon.note_health(1)                                  # 2 s at state 0
    clk[0] = 3.0
    mon.tick({"health": 1})
    snap = mon.snapshot()
    assert snap["health"] == 1
    assert snap["health_dwell_s"][0] == pytest.approx(2.0)
    assert snap["health_dwell_s"][1] == pytest.approx(1.0)
    assert snap["health_dwell_s"][1] >= 0               # never negative


def test_slo_rule_missing_field_never_fires():
    r = SloRule("x", "a.b.c", max=1.0)
    assert r.check({}) is None
    assert r.check({"a": {"b": {"c": float("nan")}}}) is None
    hit = r.check({"a": {"b": {"c": 2.0}}})
    assert hit["rule"] == "x" and hit["value"] == 2.0


# ---------------------------------------------------------------------------
# frontend integration: one clock, histograms mirror exact samples
# ---------------------------------------------------------------------------

def test_frontend_sojourn_unified_through_obs_clock():
    t = DashEH(CFG)
    f = DashFrontend(t)
    keys = unique_keys(np.random.default_rng(5), 600)
    for k in keys:
        f.submit(Op(INSERT, int(k), int(k & 0x7FFFFFFF)))
    for k in keys[:200]:
        f.submit(Op(READ, int(k)))
    f.drain()
    # every completed op went through obs.now() twice; the registry
    # histograms saw exactly the same samples the latency lists keep
    rh = f.obs.registry.get("frontend.read_sojourn_s")
    wh = f.obs.registry.get("frontend.write_sojourn_s")
    assert rh.n == len(f.read_latencies) == 200
    assert wh.n == len(f.write_latencies) == 600
    assert rh.total == pytest.approx(sum(f.read_latencies))
    assert wh.vmax == max(f.write_latencies)
    snap = f.obs_snapshot()
    assert snap["metrics"]["stats.published"] == f.stats()["published"]
    assert snap["slo"]["tick"] > 0
    assert "read_sojourn" in snap["slo"]


def test_frontend_slo_extra_and_stats_fields():
    # slo_interval=1 forces an evaluation (with the frontend's extra) on
    # every tick — the extra fields must land in the snapshot
    f = DashFrontend(DashEH(CFG), obs=Observability(slo_interval=1))
    ks = unique_keys(np.random.default_rng(9), 400)
    for k in ks:
        f.submit(Op(INSERT, int(k), 1))
    f.drain()
    st = f.stats()
    assert st["readonly_events"] == 0
    snap = f.obs.slo.snapshot()
    assert snap["health"] == fe.HEALTHY
    assert "limbo_depth" in snap and "queue_depth" in snap


# ---------------------------------------------------------------------------
# span causality across publish + flush + SMO (durable split storm)
# ---------------------------------------------------------------------------

def _storm_frontend(tmp_path, n=900):
    p = str(tmp_path / "t.pool")
    t = persist.create(p, CHAOS_CFG)
    obs = Observability(trace=True)
    f = DashFrontend(t, obs=obs)
    keys = unique_keys(np.random.default_rng(11), n)
    for k in keys:
        f.submit(Op(INSERT, int(k), int(k & 0x7FFFFFFF)))
    for k in keys[:64]:
        f.submit(Op(READ, int(k)))
    f.drain()
    return f, keys


def test_span_causality_publish_flush_smo(tmp_path):
    f, _ = _storm_frontend(tmp_path)
    assert f.smo_stages > 0                      # the storm actually split
    spans = f.obs.tracer.spans()
    by_sid = {s.sid: s for s in spans}
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s)
    # flush-on-publish rendered literally: every flush nests in a publish
    assert by_name["flush"], "durable storm produced no flush spans"
    for fl in by_name["flush"]:
        assert by_sid[fl.parent].name == "publish"
        if "bytes" in fl.args:
            assert fl.args["bytes"] >= 0
    # redo-log commit instants parent to their flush span
    for rl in by_name.get("redo_log_commit", []):
        assert by_sid[rl.parent].name == "flush"
    # staged SMO: every smo_stage belongs to one smo umbrella span carrying
    # the task descriptor, and the umbrella outlives all its stages
    assert by_name.get("smo"), "no smo umbrella spans"
    for um in by_name["smo"]:
        assert um.args["kind"] in ("eh_bulk_split", "lh_split_next")
    for st in by_name["smo_stage"]:
        um = by_sid[st.parent]
        assert um.name == "smo"
        assert um.t0 <= st.t0 and st.t1 <= um.t1
    # every ack links back to its batch span; write acks additionally link
    # the publish (and flush, when one ran) that made the batch durable
    acks = by_name["ack"]
    assert acks
    write_acks = 0
    for a in acks:
        linked = [by_sid[l] for l in a.links if l in by_sid]
        names = {s.name for s in linked}
        assert names & {"read_batch", "write_batch"}, a.args
        if a.args.get("kind") == INSERT:
            write_acks += 1
            assert "publish" in names, a.args
            assert "flush" in names, a.args
    assert write_acks > 0


def test_chrome_export_of_storm_is_valid(tmp_path):
    f, _ = _storm_frontend(tmp_path, n=600)
    path = str(tmp_path / "storm.json")
    doc = f.obs.tracer.export_chrome_trace(path)
    reparsed = json.load(open(path))
    assert reparsed["traceEvents"] == doc["traceEvents"]
    kinds = {e["ph"] for e in doc["traceEvents"]}
    assert "X" in kinds and "s" in kinds and "f" in kinds
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"publish", "flush", "ack"} <= names


def test_tracing_disabled_by_default_and_free(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    p = str(tmp_path / "t.pool")
    f = DashFrontend(persist.create(p, CHAOS_CFG))
    assert not f.obs.tracer.enabled
    for k in unique_keys(np.random.default_rng(2), 300):
        f.submit(Op(INSERT, int(k), 1))
    f.drain()
    assert f.obs.tracer.recorded == 0
    # metrics still flow with tracing off
    assert f.obs.registry.get("frontend.write_sojourn_s").n == 300
