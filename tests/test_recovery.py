"""Crash-consistency + instant/lazy recovery (paper Sec. 4.8, Table 1, Fig 14)."""
import numpy as np
import pytest

from repro.core import DashConfig, DashEH, DashLH, EXISTS, INSERTED, recovery
from tests.conftest import unique_keys


@pytest.mark.parametrize("cls,cfg", [
    (DashEH, DashConfig(max_segments=32, dir_depth_max=8)),
    (DashLH, DashConfig(max_segments=64, num_stash=4)),
])
def test_crash_recovery_full(cls, cfg, rng):
    t = cls(cfg)
    keys = unique_keys(rng, 5000)
    vals = (np.arange(5000) % 2**32).astype(np.uint32)
    t.insert(keys, vals)
    t.crash(np.random.default_rng(1), lock_frac=0.2, n_dups=8,
            wipe_overflow=True, interrupt_smo=(cls is DashEH))
    work = t.restart()
    assert work["seconds"] < 0.5          # instant: O(1)
    f, v = t.search(keys)                  # lazy recovery on access
    assert f.all() and (v == vals).all()
    assert t.n_items == 5000               # duplicates removed exactly
    neg = np.setdiff1d(unique_keys(rng, 3000), keys)[:500]
    f2, _ = t.search(neg)
    assert f2.sum() == 0                   # no phantoms from stale overflow
    s = t.insert(keys[:64], vals[:64])
    assert (s == EXISTS).all()             # uniqueness intact


def test_instant_restart_constant_in_size(rng):
    """Table 1: restart work must not scale with data size."""
    times = []
    for n in (500, 2000, 8000):
        t = DashEH(DashConfig(max_segments=64, dir_depth_max=10))
        t.insert(unique_keys(rng, n), np.zeros(n, np.uint32))
        t.crash(np.random.default_rng(0), n_dups=0)
        times.append(t.restart()["seconds"])
    assert max(times) < 0.25
    assert max(times) < 50 * max(min(times), 1e-5)   # no linear blowup


def test_clean_shutdown_skips_recovery(rng):
    t = DashEH(DashConfig(max_segments=16, dir_depth_max=6))
    keys = unique_keys(rng, 1000)
    t.insert(keys, np.zeros(1000, np.uint32))
    t.graceful_shutdown()
    t.restart()
    t.search(keys[:50])
    assert t.recovered_segments == 0


def test_lazy_recovery_amortized(rng):
    """Fig. 14: only touched segments are recovered."""
    t = DashEH(DashConfig(max_segments=32, dir_depth_max=8))
    keys = unique_keys(rng, 6000)
    t.insert(keys, np.zeros(6000, np.uint32))
    segs_total = t.n_segments
    t.crash(np.random.default_rng(2), n_dups=2)
    t.restart()
    t.search(keys[:8])          # touches few segments
    assert 0 < t.recovered_segments < segs_total


def test_smo_continuation(rng):
    """A split interrupted between phases is finished on first access."""
    from repro.core import dash_eh, layout
    import jax.numpy as jnp
    cfg = DashConfig(max_segments=16, dir_depth_max=6)
    t = DashEH(cfg)
    keys = unique_keys(rng, 1200)
    t.insert(keys, np.arange(1200, dtype=np.uint32))
    # force a mid-SMO crash on segment 0
    t.state, _ = dash_eh.split_phase1(cfg, t.state, jnp.asarray(0, jnp.int32))
    t.state = t.state._replace(clean=jnp.asarray(False))
    t.restart()
    f, v = t.search(keys)
    assert f.all() and (v == np.arange(1200, dtype=np.uint32)).all()
    assert (np.asarray(t.state.seg_state) == layout.SEG_NORMAL).all()
