"""Multi-device tests (subprocess with fake devices): DHT + shard_map +
elastic resize + compression psum."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}


def run_sub(code: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".",
                       timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_dht_8_shards():
    out = run_sub("""
        import numpy as np
        from repro.core import DashConfig, INSERTED, EXISTS
        from repro.distributed import DistributedDash
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 4)
        d = DistributedDash(DashConfig(max_segments=32, dir_depth_max=8),
                            mesh, axes=("data", "model"), capacity=256)
        rng = np.random.default_rng(5)
        keys = np.unique(rng.integers(1, 2**63, 8000, dtype=np.uint64))[:4000]
        vals = np.arange(4000, dtype=np.uint32) % 1000 + 1
        st = d.insert(keys, vals)
        assert (st == INSERTED).all()
        assert (d.insert(keys[:64], vals[:64]) == EXISTS).all()
        f, v = d.search(keys)
        assert f.all() and (v == vals).all()
        neg = np.setdiff1d(np.unique(rng.integers(1, 2**63, 2000, dtype=np.uint64)), keys)[:500]
        f2, _ = d.search(neg); assert f2.sum() == 0
        print("OK items", d.n_items)
    """)
    assert "OK items 4000" in out


def test_dht_shard_splits_bulk():
    """Split-heavy DHT workload: small segments force NEED_SPLIT retry
    rounds, so owners run the bulk shard-local SMO dispatch and the retry
    batches are padded (regression: padded lanes must never insert the zero
    key — n_items has to agree with a meta recount)."""
    out = run_sub("""
        import numpy as np
        from repro.core import DashConfig, INSERTED, layout
        from repro.distributed import DistributedDash
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 4)
        cfg = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1,
                         num_buckets=16, num_slots=8)
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
        rng = np.random.default_rng(9)
        keys = np.unique(rng.integers(1, 2**63, 8000, dtype=np.uint64))[:3001]
        vals = np.arange(3001, dtype=np.uint32) % 1000 + 1
        st = d.insert(keys, vals)
        assert (st == INSERTED).all()
        wm = np.asarray(d.state.watermark)
        assert wm.max() > 2, wm          # splits actually happened
        f, v = d.search(keys)
        assert f.all() and (v == vals).all()
        meta = np.asarray(d.state.meta)
        recount = int(((meta >> layout.COUNT_SHIFT) & 0xF).sum())
        assert d.n_items == 3001 == recount, (d.n_items, recount)
        print("OK items", d.n_items, "max wm", int(wm.max()))
    """)
    assert "OK items 3001" in out


def test_dht_shard_frontend():
    """Epoch-guarded shard frontend: reads pin a published snapshot of the
    sharded state and verify owner-shard version planes; pressured owners'
    bulk splits run deferred between read batches. Reads must stay pre- or
    post-split-consistent and every insert must land."""
    out = run_sub("""
        import numpy as np
        from repro.core import DashConfig, INSERTED, layout
        from repro.distributed import DistributedDash
        from repro.distributed.dht import ShardFrontend
        from repro.launch.mesh import make_test_mesh
        from repro.serving.frontend import Op, READ, INSERT
        from repro.workloads import ycsb
        mesh = make_test_mesh(2, 4)
        cfg = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1,
                         num_buckets=16, num_slots=8)
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
        rng = np.random.default_rng(77)
        keys = np.unique(rng.integers(1, 2**63, 9000, dtype=np.uint64))[:3600]
        loaded, fresh = keys[:1800], keys[1800:]
        d.insert(loaded, np.asarray(
            [ycsb.expected_value(int(k)) for k in loaded], np.uint32))
        fe = ShardFrontend(d, max_batch=256, queue_depth=1 << 14)
        ridx = rng.integers(0, loaded.size, fresh.size)
        ops = []
        for i, k in enumerate(fresh):          # storm: inserts + racing reads
            ops.append(Op(INSERT, int(k), ycsb.expected_value(int(k))))
            ops.append(Op(READ, int(loaded[ridx[i]])))
        for op in ops:
            assert fe.submit(op)
        fe.drain()
        for op in ops:
            if op.kind == INSERT:
                assert op.status == INSERTED, op
            else:
                assert op.found and op.result == ycsb.expected_value(op.key), op
        wm = np.asarray(d.state.watermark)
        assert wm.max() > 2                    # splits ran during serving
        f, _ = d.search(keys)
        assert f.all()
        meta = np.asarray(d.state.meta)
        recount = int(((meta >> layout.COUNT_SHIFT) & 0xF).sum())
        assert d.n_items == 3600 == recount, (d.n_items, recount)
        print("SHARD FRONTEND OK", fe.snapshot_reads, fe.retried_reads,
              fe.registry.published)
    """)
    assert "SHARD FRONTEND OK" in out


def test_elastic_shrink_and_reshard():
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch import elastic
        from repro.models import init_params, param_specs
        from repro.parallel import sharding
        from repro.train.steps import train_state_init

        cfg = get_config("yi-6b", reduced=True)
        mesh = make_test_mesh(2, 4)
        params, specs = init_params(jax.random.PRNGKey(0), cfg)
        with sharding.use(mesh, "train"):
            sh = sharding.tree_shardings(specs, mesh, shape_tree=params)
            params = jax.device_put(params, sh)
        # host failure: drop one data column -> (1, 4) mesh
        small = elastic.shrink_mesh(mesh, "data", 1)
        params2 = elastic.reshard_tree(params, small, specs)
        step = elastic.relower_for_mesh(cfg, small)
        state = train_state_init(params2)
        batch = {"tokens": jnp.zeros((2, 64), jnp.int32),
                 "labels": jnp.zeros((2, 64), jnp.int32)}
        with small:
            state2, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        plan = elastic.rescale_batch_plan(256, 16, 15)
        assert plan["global_batch"] in (255, 256)
        print("ELASTIC OK", float(metrics["loss"]))
    """)
    assert "ELASTIC OK" in out


def test_compressed_psum_over_pod_axis():
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.parallel import compression

        mesh = make_test_mesh(8, 1)
        g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 512)).astype(np.float32))

        def sync(gs):
            grads = {"w": gs[0]}
            res = compression.init_residuals(grads)
            out, res = compression.compressed_psum(grads, res, "data")
            return out["w"][None], res["w"][None]

        f = shard_map(sync, mesh=mesh, in_specs=(P("data"),),
                      out_specs=(P("data"), P("data")), check_rep=False)
        mean_c, residual = f(g)
        true_mean = np.asarray(g).mean(axis=0)
        got = np.asarray(mean_c)[0]
        err = np.abs(got - true_mean).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err < 3 * scale, (err, scale)
        print("COMPRESS OK", err)
    """)
    assert "COMPRESS OK" in out


def test_dht_durable_shard_pools(tmp_path):
    """One durable pool per shard under the real 8-device shard_map path:
    insert through the DHT, flush every shard's pool, 'kill' the process
    (subprocess exits), then a SECOND subprocess reopens the pools into a
    fresh DistributedDash and every acknowledged key is found."""
    d = str(tmp_path / "shards")
    common = f"""
        import numpy as np
        from repro.core import DashConfig
        from repro.distributed import DistributedDash
        from repro.launch.mesh import make_test_mesh
        from repro import persist
        cfg = DashConfig(max_segments=32, dir_depth_max=8)
        mesh = make_test_mesh(2, 4)
        rng = np.random.default_rng(5)
        keys = np.unique(rng.integers(1, 2**63, 8000, dtype=np.uint64))[:3000]
        vals = np.arange(3000, dtype=np.uint32) % 1000 + 1
    """
    run_sub(common + f"""
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
        d.attach_pools(persist.create_shard_pools({d!r}, cfg, d.n_shards))
        st = d.insert(keys, vals)
        assert (st == 0).all()
        n = d.flush_pools()
        print("WRITER OK", d.n_items, "flushed", n)
    """)
    out = run_sub(common + f"""
        stacked, wbs, info = persist.reopen_shards({d!r})
        assert info["n_shards"] == 8 and info["dirty_shards"] == 8
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256,
                            state=stacked)
        d.attach_pools(wbs)
        f, v = d.search(keys)
        assert f.all() and (v == vals).all()
        assert d.n_items == 3000
        d.close_pools()
        # clean reopen after close: no shard recovers
        stacked2, wbs2, info2 = persist.reopen_shards({d!r})
        assert info2["dirty_shards"] == 0
        print("REOPEN OK", int(f.sum()))
    """)
    assert "REOPEN OK 3000" in out


def test_dht_device_retry_never_inserts_zero_key():
    """Satellite regression for the shard_map *device* retry path: the
    all_to_all routing pads empty lanes with key 0, and the batch shaper
    pads the tail when the batch doesn't divide the shard count. Under
    forced split retries (tiny segments) those padded lanes loop through
    ``insert_round_fn`` many times — none may ever land key 0."""
    out = run_sub("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import DashConfig, INSERTED, layout
        from repro.distributed import DistributedDash
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 4)
        cfg = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1,
                         num_buckets=16, num_slots=8)
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
        rng = np.random.default_rng(23)
        # 2777 % 8 != 0 -> tail padding on top of routing padding
        keys = np.unique(rng.integers(1, 2**63, 8000, dtype=np.uint64))[:2777]
        vals = np.arange(2777, dtype=np.uint32) % 1000 + 1
        # device loop (insert_round_fn + split_fn), NOT the host-sync path
        st = d.insert(keys, vals)
        assert (st == INSERTED).all()
        assert np.asarray(d.state.watermark).max() > 2   # splits forced
        f0, _ = d.search(np.zeros(8, np.uint64))
        assert f0.sum() == 0, "padded lane inserted key 0"
        meta = np.asarray(d.state.meta)
        recount = int(((meta >> layout.COUNT_SHIFT) & 0xF).sum())
        assert d.n_items == 2777 == recount, (d.n_items, recount)
        # a phantom zero-key would also surface as a stored fp for key 0:
        f, v = d.search(keys)
        assert f.all() and (v == vals).all()
        print("ZERO KEY OK", d.n_items)
    """)
    assert "ZERO KEY OK 2777" in out


def test_dht_device_verify_matches_host_mirror():
    """Satellite differential: the device-resident retry mask produced
    inside the shard_map program (``snap_search_fn``'s changed word) must
    equal the host-mirror plane diff (``ShardFrontend._changed_mask``)
    across randomized SMO/read interleavings on 8 shards."""
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import DashConfig
        from repro.distributed import DistributedDash, ShardFrontend
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 4)
        cfg = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1,
                         num_buckets=16, num_slots=8)
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
        fe = ShardFrontend(d, max_batch=256, verify_mode="host")
        rng = np.random.default_rng(41)
        keys = np.unique(rng.integers(1, 2**63, 24000, dtype=np.uint64))[:9000]
        vals = (np.arange(9000) % 1000 + 1).astype(np.uint32)
        d.insert(keys[:1500], vals[:1500])
        cursor, total = 1500, 0
        for step in range(50):
            old = jax.tree.map(jnp.copy, d.state)
            n = int(rng.integers(0, 140))   # 0 => read-only interleaving
            if n:
                d.insert(keys[cursor:cursor + n], vals[cursor:cursor + n])
                cursor += n
            probe = keys[rng.integers(0, cursor, 512)]
            _, _, dev, stale = d.snap_search_on(old, probe)
            assert not stale.any()
            host = fe._changed_mask(old, probe)
            assert (dev.astype(bool) == host).all(), step
            total += int(host.sum())
        assert total > 0              # the interleavings actually raced
        print("VERIFY DIFF OK", cursor, total)
    """)
    assert "VERIFY DIFF OK" in out


def test_buckets_changed_lh_device_matches_host_mirror():
    """The LH half of the differential satellite: DHT shards are EH tables,
    so LH is exercised at the per-shard level — the traceable
    ``buckets_changed_local`` (what the shard program inlines) against an
    independent numpy mirror of the LH addressing + version-plane diff."""
    out = run_sub("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import DashConfig, DashLH, hashing, layout
        from repro.serving.engine import buckets_changed
        cfg = DashConfig(max_segments=64, num_stash=4, num_buckets=16,
                         num_slots=8, lh_base_log2=2)
        t = DashLH(cfg)
        rng = np.random.default_rng(57)
        keys = np.unique(rng.integers(1, 2**63, 16000, dtype=np.uint64))[:6000]

        def host_mask(old, new, probe):
            hi, lo = hashing.np_split_keys(probe)
            h1 = hashing.np_hash1(hi, lo)
            def seg_of(st):
                w = int(np.asarray(st.lh_word))
                level, nxt = w >> 24, w & 0xFFFFFF
                mask_lo = (np.uint32(1) << np.uint32(cfg.lh_base_log2 + level)) - 1
                seg = (h1 & mask_lo).astype(np.int64)
                mask_hi = (mask_lo << np.uint32(1)) | np.uint32(1)
                logical = np.where(seg < nxt, (h1 & mask_hi).astype(np.int64), seg)
                return np.asarray(st.lh_dir)[logical]   # logical -> physical
            so, sn = seg_of(old), seg_of(new)
            changed = so != sn
            b = ((h1 >> np.uint32(24)) & np.uint32(cfg.num_buckets - 1)).astype(np.int64)
            ov, nv = np.asarray(old.version), np.asarray(new.version)
            for w in range(cfg.probe_window):
                bw = (b + w) & (cfg.num_buckets - 1)
                changed |= ov[so, bw] != nv[so, bw]
            for s in range(cfg.num_stash):
                changed |= ov[so, cfg.num_buckets + s] != nv[so, cfg.num_buckets + s]
            return changed

        cursor, total = 0, 0
        for step in range(50):
            old = jax.tree.map(jnp.copy, t.state)
            n = min(int(rng.integers(0, 220)),   # big batches drive
                    keys.size - cursor)          # lh_split_next
            if n:
                t.insert(keys[cursor:cursor + n],
                         np.arange(n, dtype=np.uint32) + 1)
                cursor += n
            probe = keys[rng.integers(0, max(cursor, 1), 512)]
            hi, lo = hashing.np_split_keys(probe)
            dev = np.asarray(buckets_changed(cfg, "lh", old, t.state,
                                             jnp.asarray(hi), jnp.asarray(lo)))
            host = host_mask(old, t.state, probe)
            assert (dev.astype(bool) == host).all(), step
            total += int(host.sum())
        assert total > 0
        print("LH DIFF OK", cursor, total)
    """)
    assert "LH DIFF OK" in out
