"""Differential tests: segment-parallel engine == sequential scan engine.

The segment-parallel engine (vmap over segments, scan over intra-segment
lanes) must be *bit-identical* to the sequential reference on table state
and statuses for any op mix — that is the correctness contract that lets it
be the default write path. Randomized (hypothesis-style) op sequences cover
insert/delete/update/search mixes including duplicate keys inside one
batch, stash overflow, padding (valid) masks and NEED_SPLIT batches.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH, engine, hashing, layout
from repro.core.layout import DROPPED, NEED_SPLIT
from tests._hypothesis_compat import given, settings, st
from tests.conftest import unique_keys

B = 64           # fixed batch size -> one jit trace per (op, engine) pair


def _states_equal(sa, sb):
    bad = [name for name, a, b in zip(sa._fields, jax.tree.leaves(sa),
                                      jax.tree.leaves(sb))
           if not (np.asarray(a) == np.asarray(b)).all()]
    return bad


def _batch(rng_np, keyspace):
    """Batch with duplicates (same-key ordering must be preserved)."""
    ks = keyspace[rng_np.integers(0, keyspace.size, B)]
    hi, lo = hashing.np_split_keys(ks)
    return jnp.asarray(hi), jnp.asarray(lo)


OPS = st.lists(st.sampled_from(["ins", "del", "upd", "mask"]),
               min_size=1, max_size=6)


@given(OPS)
@settings(max_examples=5, deadline=None)
def test_engines_bit_identical_random_ops(ops):
    # tiny table: stash overflow and NEED_SPLIT occur within a few batches
    cfg = DashConfig(max_segments=8, dir_depth_max=6, init_depth=1)
    rng_np = np.random.default_rng(hash(tuple(ops)) % 2**32)
    keyspace = np.unique(rng_np.integers(1, 2**63, 400, dtype=np.uint64))
    st_scan = layout.make_state(cfg, "eh")
    st_seg = jax.tree.map(jnp.copy, st_scan)
    saw_split = False

    for step, op in enumerate(ops):
        hi, lo = _batch(rng_np, keyspace)
        vals = jnp.asarray(rng_np.integers(1, 2**32, B).astype(np.uint32))
        valid = None
        if op == "mask":          # padded retry-batch shape: half the lanes
            valid = jnp.asarray(np.arange(B) < B // 2)
            op = "ins"
        if op == "ins":
            st_scan, s1, a1 = engine.insert_batch(
                cfg, "eh", st_scan, hi, lo, vals, None, valid, batching="scan")
            st_seg, s2, a2 = engine.insert_batch(
                cfg, "eh", st_seg, hi, lo, vals, None, valid,
                batching="segment", capacity=B)
            assert bool(a1) == bool(a2)
            saw_split |= (np.asarray(s1) == NEED_SPLIT).any()
        elif op == "del":
            st_scan, s1 = engine.delete_batch(cfg, "eh", st_scan, hi, lo,
                                              batching="scan")
            st_seg, s2 = engine.delete_batch(cfg, "eh", st_seg, hi, lo,
                                             batching="segment", capacity=B)
        else:
            st_scan, s1 = engine.update_batch(cfg, "eh", st_scan, hi, lo,
                                              vals, batching="scan")
            st_seg, s2 = engine.update_batch(cfg, "eh", st_seg, hi, lo, vals,
                                             batching="segment", capacity=B)
        assert (np.asarray(s1) == np.asarray(s2)).all(), (step, op)
        bad = _states_equal(st_scan, st_seg)
        assert not bad, (step, op, bad)

        # read paths agree on the (identical) state
        f1, v1 = engine.search_batch(cfg, "eh", st_scan, hi, lo,
                                     batching="vmap")
        f2, v2 = engine.search_batch(cfg, "eh", st_seg, hi, lo,
                                     batching="pallas", capacity=128)
        assert (np.asarray(f1) == np.asarray(f2)).all(), (step, op)
        assert (np.asarray(v1) == np.asarray(v2)).all(), (step, op)


def test_engines_identical_under_lh_mode(rng):
    """LH addressing (level/next word + stash chaining) through both engines."""
    cfg = DashConfig(max_segments=32, num_stash=4, lh_base_log2=2)
    keys = unique_keys(rng, 4 * B)
    st_scan = layout.make_state(cfg, "lh")
    st_seg = jax.tree.map(jnp.copy, st_scan)
    for i in range(4):
        hi, lo = hashing.np_split_keys(keys[i * B:(i + 1) * B])
        hi, lo = jnp.asarray(hi), jnp.asarray(lo)
        vals = jnp.asarray(np.arange(B, dtype=np.uint32))
        st_scan, s1, a1 = engine.insert_batch(cfg, "lh", st_scan, hi, lo,
                                              vals, batching="scan")
        st_seg, s2, a2 = engine.insert_batch(cfg, "lh", st_seg, hi, lo, vals,
                                             batching="segment", capacity=B)
        assert (np.asarray(s1) == np.asarray(s2)).all()
        assert bool(a1) == bool(a2)
        assert not _states_equal(st_scan, st_seg)


def test_table_end_to_end_equivalence(rng):
    """Full DashTable flows (splits + retries) with each engine forced."""
    cfg = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1)
    keys = unique_keys(rng, 3000)
    vals = np.arange(3000, dtype=np.uint32)

    t_scan, t_seg = DashEH(cfg), DashEH(cfg)
    t_scan._write_plan = lambda seg, n, fused_ok=True: ("scan", None)
    seg_plan = type(t_seg)._write_plan

    def forced_segment(seg, n, fused_ok=True, _self=t_seg):
        _, cap = seg_plan(_self, seg, n, fused_ok=False)
        return "segment", cap or _self._lane_quantum(_self._max_per_segment(seg))
    t_seg._write_plan = forced_segment

    s1 = t_scan.insert(keys, vals)
    s2 = t_seg.insert(keys, vals)
    assert (s1 == s2).all()
    assert not _states_equal(t_scan.state, t_seg.state)

    d1 = t_scan.delete(keys[:1000])
    d2 = t_seg.delete(keys[:1000])
    assert (d1 == d2).all()
    u1 = t_scan.update(keys[1000:2000], vals[1000:2000] + 7)
    u2 = t_seg.update(keys[1000:2000], vals[1000:2000] + 7)
    assert (u1 == u2).all()
    assert not _states_equal(t_scan.state, t_seg.state)

    f1, v1 = t_scan.search(keys)
    f2, v2 = t_seg.search(keys)
    assert (f1 == f2).all() and (v1 == v2).all()


def test_update_batch_valid_mask():
    """update_batch takes the same padding mask as insert_batch: masked
    lanes come back DROPPED and write nothing (host retry subsets can pad
    to pow2 without recompiling on shape changes)."""
    cfg = DashConfig(max_segments=8, dir_depth_max=6)
    t = DashEH(cfg)
    keys = unique_keys(np.random.default_rng(3), B)
    t.insert(keys, np.arange(B, dtype=np.uint32))
    hi, lo = hashing.np_split_keys(keys)
    hi, lo = jnp.asarray(hi), jnp.asarray(lo)
    newv = jnp.asarray(np.full(B, 777, np.uint32))
    valid = jnp.asarray(np.arange(B) < B // 2)
    for batching in ("scan", "segment"):
        st2, statuses = engine.update_batch(
            cfg, "eh", jax.tree.map(jnp.copy, t.state), hi, lo, newv,
            None, valid, batching=batching)
        statuses = np.asarray(statuses)
        assert (statuses[B // 2:] == DROPPED).all(), batching
        f, v = engine.search_batch(cfg, "eh", st2, hi, lo)
        v = np.asarray(v)
        assert (v[:B // 2] == 777).all(), batching
        assert (v[B // 2:] == np.arange(B // 2, B)).all(), batching
