"""Serving engine: prefix-cache reuse correctness + OCC snapshot search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import DashConfig, engine as dash_engine, layout
from repro.core.hashing import np_split_keys
from repro.core.table import DashEH
from repro.models import init_params
from repro.serving import Request, ServingEngine, snapshot_search
from tests.conftest import unique_keys


@pytest.fixture(scope="module")
def served():
    cfg = get_config("yi-6b", reduced=True)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prefix_cache_hits_and_saved_prefill(served):
    cfg, params = served
    eng = ServingEngine(cfg, params, cache_len=256, num_pages=128, batch_size=2)
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, 64)
    r1 = Request(0, np.concatenate([shared, rng.integers(1, cfg.vocab_size, 32)]),
                 max_new_tokens=4)
    r2 = Request(1, np.concatenate([shared, rng.integers(1, cfg.vocab_size, 32)]),
                 max_new_tokens=4)
    eng.run([r1])
    eng.run([r2])
    assert r1.cached_tokens == 0
    assert r2.cached_tokens == 64          # shared prefix reused
    assert r2.prefilled_tokens == 32
    assert eng.prefix.stats.hit_rate > 0.2
    assert len(r2.generated) == 4


def test_prefix_cache_eviction_bounded(served):
    cfg, params = served
    eng = ServingEngine(cfg, params, cache_len=128, num_pages=8, batch_size=1)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.run([Request(i, rng.integers(1, cfg.vocab_size, 64),
                         max_new_tokens=2)])
    assert eng.prefix.stats.evictions > 0
    assert len(eng.prefix.free) + len(eng.prefix.lru) <= 8 + 1


def test_snapshot_search_occ(rng):
    """Optimistic composition: searches on a stale snapshot are retried
    exactly for buckets whose versions changed (Sec. 4.4 at system level)."""
    cfg = DashConfig(max_segments=16, dir_depth_max=7)
    t = DashEH(cfg)
    keys = unique_keys(rng, 1200)
    t.insert(keys[:800], np.arange(800, dtype=np.uint32))
    # a real snapshot: copies, because the write path donates its buffers
    old_state = jax.tree.map(jnp.copy, t.state)
    t.insert(keys[800:], np.arange(800, 1200, dtype=np.uint32))
    hi, lo = np_split_keys(keys)
    f, v, retried = snapshot_search(cfg, old_state, t.state,
                                    jnp.asarray(hi), jnp.asarray(lo))
    f, v = np.asarray(f), np.asarray(v)
    assert f.all()                         # new keys found via retry path
    assert (v == np.arange(1200)).all()
    assert int(retried) >= 400             # at least the new keys' buckets
