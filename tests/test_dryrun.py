"""Dry-run integration: lower+compile a cell on a small mesh in a subprocess
(the full 256/512-chip sweep runs via `python -m repro.launch.dryrun --all`;
its committed artifacts are validated here too)."""
import glob
import json
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}


def test_dryrun_cell_small():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "yi-6b",
         "--shape", "decode_32k", "--mesh", "pod", "--out",
         "/tmp/dryrun_test"],
        capture_output=True, text=True, env=ENV, timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open("/tmp/dryrun_test/pod/yi-6b__decode_32k.json"))
    assert rec["status"] == "ok"
    assert rec["dot_flops_per_device"] > 0
    assert rec["static_bytes_per_device"] < 16 * 2**30   # fits v5e HBM


def test_sweep_artifacts_complete():
    """All 40 cells x 2 meshes must exist: ok or documented skip."""
    recs = [json.load(open(f))
            for f in glob.glob("experiments/dryrun/*/*.json")]
    if len(recs) < 80:
        pytest.skip("full sweep not yet run (python -m repro.launch.dryrun --all)")
    ok = sum(r["status"] == "ok" for r in recs)
    skipped = [r for r in recs if r["status"] == "skipped"]
    errors = [r for r in recs if r["status"] == "error"]
    assert not errors, errors
    assert ok == 68 and len(skipped) == 12       # 6 long_500k skips per mesh
    for r in skipped:
        assert r["shape"] == "long_500k"
    # every ok cell fits HBM and has roofline inputs
    for r in recs:
        if r["status"] == "ok":
            assert r["static_bytes_per_device"] < 16 * 2**30, (
                r["arch"], r["shape"], r["static_bytes_per_device"])
            assert r["dot_flops_per_device"] > 0
