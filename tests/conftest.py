"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests run in subprocesses (test_dryrun/test_dht)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xDA5)


def unique_keys(rng, n, lo=1, hi=2**63):
    out = np.unique(rng.integers(lo, hi, size=int(n * 2.2) + 16, dtype=np.uint64))
    assert out.size >= n
    return out[:n]
