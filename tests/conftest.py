"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests run in subprocesses (test_dryrun/test_dht).

The JAX persistent compilation cache is wired up repo-locally (.jax_cache/,
gitignored) through benchmarks.common.enable_compilation_cache, which is
OPT-IN via REPRO_COMPILATION_CACHE=1: on this container's jaxlib
(0.4.36/CPU), executables deserialized from the cache mishandle buffer
donation — donated pass-through planes come back corrupted
nondeterministically (test_batch_parallel's scan-vs-segment differential
caught lh_dir diverging on a delete that touches neither) and large cached
SMO dispatches can crash. See benchmarks/common.py for the full note; flip
the env var once the deployment jaxlib handles donation in deserialized
executables."""
import numpy as np
import pytest

from benchmarks.common import enable_compilation_cache

enable_compilation_cache()      # no-op unless REPRO_COMPILATION_CACHE=1


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xDA5)


def unique_keys(rng, n, lo=1, hi=2**63):
    out = np.unique(rng.integers(lo, hi, size=int(n * 2.2) + 16, dtype=np.uint64))
    assert out.size >= n
    return out[:n]
