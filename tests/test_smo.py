"""SMO engine differential tests: vectorized rebuild == scan rehash.

The vectorized segment rebuild (core/smo.py) must be *logically* identical
to the retained per-record scan rehash on every SMO: set-equality of each
segment's records, identical directory / local-depth / segment statuses /
lh-word / watermark / item counts. Placement inside a segment is allowed to
differ (the rebuild is a one-pass EDF schedule, the scan path is
insert-order greedy + displacement) — Dash's correctness contract is the
record set per segment, not the slot layout.

Also pins the incremental ``n_items`` accounting (satellite: no whole-table
recount per SMO) against the full recount, and crash-recovery of a bulk
multi-segment SMO (redo-with-uniqueness-check, paper Sec. 4.8).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DashConfig, DashEH, DashLH, EXISTS, dash_eh, dash_lh,
                        engine, layout, smo)
from tests._hypothesis_compat import given, settings, st
from tests.conftest import unique_keys

SMALL = DashConfig(max_segments=32, dir_depth_max=8, init_depth=1)


def _copy(state):
    return jax.tree.map(jnp.copy, state)


_recset = smo.segment_record_set   # the engine's logical-equivalence contract


def _assert_logical_equal(cfg, sa, sb, n_segs, tag=""):
    assert (np.asarray(sa.dir) == np.asarray(sb.dir)).all(), tag
    assert (np.asarray(sa.local_depth) == np.asarray(sb.local_depth)).all(), tag
    assert (np.asarray(sa.seg_state) == np.asarray(sb.seg_state)).all(), tag
    assert (np.asarray(sa.stash_active) == np.asarray(sb.stash_active)).all(), tag
    assert int(sa.n_items) == int(sb.n_items), tag
    assert int(sa.watermark) == int(sb.watermark), tag
    for seg in range(n_segs):
        assert _recset(cfg, sa, seg) == _recset(cfg, sb, seg), (tag, seg)


def _grown_eh(rng, n_keys, cfg=SMALL, smo_mode="scalar"):
    t = DashEH(cfg, smo_mode=smo_mode)
    keys = unique_keys(rng, n_keys)
    vals = (np.arange(n_keys) % 2**32).astype(np.uint32)
    t.insert(keys, vals)
    return t, keys, vals


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_eh_split_rebuild_matches_scan(seed):
    """Every live segment: scan split and rebuild split produce the same
    record sets, directory, depths, statuses and counts."""
    rng = np.random.default_rng(seed)
    t, _, _ = _grown_eh(rng, 1500 + int(rng.integers(0, 1500)))
    base = t.state
    wm = int(np.asarray(base.watermark))
    depths = np.asarray(base.local_depth)
    for seg in np.unique(np.asarray(base.dir)):
        if depths[seg] >= SMALL.dir_depth_max:
            continue
        s_scan, ok1 = dash_eh.split_segment(SMALL, _copy(base), int(seg), wm,
                                            impl="scan")
        s_reb, ok2 = dash_eh.split_segment(SMALL, _copy(base), int(seg), wm,
                                           impl="rebuild")
        assert bool(ok1) and bool(ok2)
        _assert_logical_equal(SMALL, s_scan, s_reb, wm + 1, f"seg={seg}")
        assert int(np.asarray(engine.recount_items(s_reb))) == int(base.n_items)


def test_eh_bulk_split_matches_scalar_loop(rng):
    """K pressured segments in ONE bulk dispatch == K sequential scan SMOs."""
    t, _, _ = _grown_eh(rng, 4000)
    base = t.state
    wm = int(np.asarray(base.watermark))
    depths = np.asarray(base.local_depth)
    segs = [int(s) for s in np.unique(np.asarray(base.dir))
            if depths[s] < SMALL.dir_depth_max][:6]
    news = list(range(wm, wm + len(segs)))
    s_sc = _copy(base)
    for o, n in zip(segs, news):
        s_sc, ok = dash_eh.split_segment(SMALL, s_sc, o, n, impl="scan")
        assert bool(ok)
    s_blk, _ = smo.bulk_split(SMALL, _copy(base), segs, news)
    _assert_logical_equal(SMALL, s_sc, s_blk, wm + len(segs))
    assert int(s_sc.global_depth) == int(s_blk.global_depth)
    assert int(s_sc.n_splits) == int(s_blk.n_splits)
    assert int(s_sc.n_doublings) == int(s_blk.n_doublings)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_lh_split_rebuild_matches_scan(seed):
    """split_next_scan == bulk_split_next(R=1) on randomized LH fills."""
    cfg = DashConfig(max_segments=64, num_stash=4, lh_base_log2=2)
    rng = np.random.default_rng(seed)
    n = 2000 + int(rng.integers(0, 2000))
    t = DashLH(cfg, smo_mode="scalar")
    t.insert(unique_keys(rng, n), (np.arange(n) % 2**32).astype(np.uint32))
    base = t.state
    l_sc, ok1 = dash_lh.split_next_scan(cfg, _copy(base))
    l_rb, ok2, _ = smo.bulk_split_next(cfg, _copy(base), 1)
    assert bool(ok1) and bool(np.asarray(ok2).all())
    assert int(l_sc.lh_word) == int(l_rb.lh_word)
    assert (np.asarray(l_sc.lh_dir) == np.asarray(l_rb.lh_dir)).all()
    assert (np.asarray(l_sc.stash_active) == np.asarray(l_rb.stash_active)).all()
    assert int(l_sc.n_items) == int(l_rb.n_items)
    for seg in range(int(np.asarray(l_rb.watermark))):
        assert _recset(cfg, l_sc, seg) == _recset(cfg, l_rb, seg), seg


def test_merge_rebuild_matches_scan(rng):
    """Buddy merge: scan vs bulk on every fitting pair of a shrunk table."""
    t, keys, _ = _grown_eh(rng, 3000)
    t.delete(keys[300:])
    base = t.state
    pairs = smo.find_buddy_pairs(SMALL, np.asarray(base.dir),
                                 np.asarray(base.local_depth))
    assert pairs.size > 0
    wm = int(np.asarray(base.watermark))
    for victim, keep in pairs:
        m_sc, ok1 = dash_eh.merge_segments_scan(SMALL, _copy(base),
                                                int(keep), int(victim))
        m_rb, ok2 = dash_eh.merge_segments(SMALL, _copy(base),
                                           int(keep), int(victim))
        assert bool(ok1) and bool(ok2)
        _assert_logical_equal(SMALL, m_sc, m_rb, wm, f"pair={victim},{keep}")


def test_table_bulk_vs_scalar_smo_logical_equivalence(rng):
    """Full table flows with each SMO mode agree on every lookup and count
    (structural history may differ: bulk splits whole pressure sets)."""
    keys = unique_keys(rng, 5000)
    vals = np.arange(5000, dtype=np.uint32)
    t_s = DashEH(SMALL, smo_mode="scalar")
    t_b = DashEH(SMALL, smo_mode="bulk")
    for t in (t_s, t_b):
        t.insert(keys, vals)
        t.delete(keys[:2000])
        t.shrink()
        t.insert(keys[:1000], vals[:1000])
    assert t_s.n_items == t_b.n_items
    for t in (t_s, t_b):
        f, v = t.search(keys)
        assert (f[:1000]).all() and (v[:1000] == vals[:1000]).all()
        assert not f[1000:2000].any()
        assert f[2000:].all() and (v[2000:] == vals[2000:]).all()
        assert t.n_items == 4000 == int(np.asarray(engine.recount_items(t.state)))


def test_n_items_incremental_matches_recount(rng):
    """Satellite: n_items is maintained from per-segment deltas through
    splits, merges, deletes and recovery — always equal to a full recount."""
    t = DashEH(SMALL, smo_mode="bulk")
    keys = unique_keys(rng, 6000)
    vals = np.arange(6000, dtype=np.uint32)

    def check(tag):
        assert t.n_items == int(np.asarray(engine.recount_items(t.state))), tag

    t.insert(keys[:4000], vals[:4000]); check("grow")
    t.delete(keys[:3500]); check("delete")
    t.shrink(); check("shrink")
    t.insert(keys[4000:], vals[4000:]); check("regrow")
    t.crash(np.random.default_rng(3), n_dups=4)
    t.restart()
    t.search(keys)                      # lazy recovery on access
    check("recovered")

    cfg = DashConfig(max_segments=64, num_stash=4, lh_base_log2=2)
    tl = DashLH(cfg, smo_mode="bulk")
    tl.insert(keys[:4000], vals[:4000])
    tl.delete(keys[:1000])
    assert tl.n_items == int(np.asarray(engine.recount_items(tl.state)))


def test_bulk_split_crash_recovery(rng):
    """Crash-injected bulk SMO: phase 1 committed for K segments, phase 2
    lost. Lazy recovery must finish every split via the uniqueness-checked
    rebuild, preserving all records and the directory invariants."""
    cfg = SMALL
    t = DashEH(cfg)
    keys = unique_keys(rng, 3000)
    vals = np.arange(3000, dtype=np.uint32)
    t.insert(keys, vals)
    wm = int(np.asarray(t.state.watermark))
    depths = np.asarray(t.state.local_depth)
    segs = [int(s) for s in np.unique(np.asarray(t.state.dir))
            if depths[s] < cfg.dir_depth_max][:3]
    assert len(segs) >= 2
    news = list(range(wm, wm + len(segs)))
    t.state = smo.bulk_split_phase1(
        cfg, t.state, jnp.asarray(segs, jnp.int32),
        jnp.asarray(news, jnp.int32), jnp.ones(len(segs), jnp.bool_))
    t.crash(np.random.default_rng(5), lock_frac=0.1, n_dups=5,
            wipe_overflow=True)
    t.restart()
    f, v = t.search(keys)
    assert f.all() and (v == vals).all()
    assert (np.asarray(t.state.seg_state) == layout.SEG_NORMAL).all()
    assert t.n_items == 3000 == int(np.asarray(engine.recount_items(t.state)))
    s = t.insert(keys[:64], vals[:64])
    assert (s == EXISTS).all()          # uniqueness survived the redo
    dirv = np.asarray(t.state.dir)
    dp = np.asarray(t.state.local_depth)
    for seg in np.unique(dirv):
        e = np.where(dirv == seg)[0]
        assert e.size == 1 << (cfg.dir_depth_max - dp[seg])
        assert (np.diff(e) == 1).all()


def test_find_buddy_pairs_matches_find_buddy(rng):
    """The vectorized all-pairs scan agrees with the per-segment helper."""
    t, _, _ = _grown_eh(rng, 4000)
    dirv = np.asarray(t.state.dir)
    depths = np.asarray(t.state.local_depth)
    pairs = {tuple(p) for p in
             smo.find_buddy_pairs(SMALL, dirv, depths).tolist()}
    expect = set()
    for seg in np.unique(dirv):
        buddy = dash_eh.find_buddy(SMALL, t.state, int(seg))
        if buddy is not None:
            expect.add((min(int(seg), buddy), max(int(seg), buddy)))
    assert pairs == expect


def test_scan_fallback_for_wide_probe_configs(rng):
    """CCEH-style probe-4 ablations are outside the rebuild's window; the
    dispatchers must keep them on the scan path and stay correct."""
    cfg = DashConfig(max_segments=32, dir_depth_max=8, num_stash=0,
                     use_fingerprints=False, use_balanced=False,
                     use_displacement=False, probe_len=4, num_slots=4)
    assert not smo.rebuild_eligible(cfg)
    t = DashEH(cfg, smo_mode="bulk")
    keys = unique_keys(rng, 1500)
    vals = np.arange(1500, dtype=np.uint32)
    t.insert(keys, vals)
    f, v = t.search(keys)
    assert f.all() and (v == vals).all()
    assert t.n_items == int(np.asarray(engine.recount_items(t.state)))
