"""Online-resize serving frontend: epoch/grace-period manager, admission
pipeline, snapshot-verify-retry reads, and the no-torn-reads interleaving
property (ISSUE 3 acceptance: >= 200 randomized query/SMO schedules)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DashConfig, engine as dash_engine, smo
from repro.core.epoch import EpochManager, Snapshot, SnapshotRegistry
from repro.core.hashing import np_split_keys
from repro.core.layout import INSERTED, NOT_FOUND
from repro.core.table import DashEH, DashLH
from repro.serving import buckets_changed, snapshot_search
from repro.serving.frontend import (INSERT, READ, RMW, UPDATE, AdmissionQueue,
                                    BatchFormer, DashFrontend, Op,
                                    StopTheWorldFrontend)
from repro.workloads import ycsb
from tests.conftest import unique_keys

CFG = DashConfig(max_segments=32, dir_depth_max=7, num_buckets=16,
                 num_slots=8)


# ---------------------------------------------------------------------------
# epoch manager + snapshot registry
# ---------------------------------------------------------------------------

def test_epoch_pin_blocks_reclamation():
    freed = []
    mgr = EpochManager(reclaim=freed.append)
    with mgr.pin():
        mgr.retire("v0")
        mgr.retire("v1")
        assert freed == []            # a pinned reader may still see them
        assert mgr.limbo_size == 2
    # after the reader exits, retire/advance cycles reclaim the limbo
    for _ in range(4):
        mgr.retire(object())
    assert "v0" in freed and "v1" in freed
    assert mgr.reclaimed >= 2


def test_snapshot_registry_versions_and_reclaim():
    freed = []
    reg = SnapshotRegistry(reclaim=lambda s: freed.append(s.version))
    reg.publish("s0")
    assert reg.version == 0
    with reg.acquire() as snap:
        assert snap.version == 0 and snap.state == "s0"
        reg.publish("s1")             # supersede while a reader is pinned
        assert reg.version == 1
        assert freed == []            # v0 protected by the pin
    for i in range(4):
        reg.publish(f"s{i + 2}")
    assert 0 in freed                 # reclaimed once the grace period passed
    assert reg.published == 6
    reg.flush()
    assert sorted(freed) == [0, 1, 2, 3, 4]   # all but current reclaimed


def test_snapshot_reclaim_deletes_buffers():
    reg = SnapshotRegistry()          # default reclaimer frees device buffers
    reg.publish(jnp.arange(4))
    old = reg.current
    for _ in range(5):
        reg.publish(jnp.arange(4))
    assert reg.reclaimed >= 1
    assert old.state.is_deleted()


# ---------------------------------------------------------------------------
# admission pipeline
# ---------------------------------------------------------------------------

def test_admission_queue_backpressure():
    q = AdmissionQueue(depth=2)
    assert q.offer(Op(READ, 1)) and q.offer(Op(READ, 2))
    assert not q.offer(Op(READ, 3))   # bounded: reject, don't grow
    assert q.rejected == 1 and q.admitted == 2
    q.pop()
    assert q.offer(Op(READ, 3))


def test_batch_former_homogeneous_runs():
    q = AdmissionQueue()
    for op in [Op(INSERT, 1, 1), Op(INSERT, 2, 2), Op(UPDATE, 1, 3),
               Op(INSERT, 3, 3)]:
        q.offer(op)
    f = BatchFormer(max_batch=8)
    b1 = f.form(q)
    assert [op.kind for op in b1] == [INSERT, INSERT]   # stops at kind change
    b2 = f.form(q)
    assert [op.kind for op in b2] == [UPDATE]
    assert [op.kind for op in f.form(q)] == [INSERT]
    assert f.form(q) == []


# ---------------------------------------------------------------------------
# frontend correctness vs the stop-the-world path
# ---------------------------------------------------------------------------

def _mixed_stream(rng, n_load=1200, n_fresh=600):
    keys = ycsb.load_keys(rng, n_load + n_fresh)
    loaded, fresh = keys[:n_load], keys[n_load:]
    ops = [Op(INSERT, int(k), ycsb.expected_value(int(k))) for k in loaded]
    # fill-driven storm: fresh inserts interleaved with reads + updates
    ridx = rng.integers(0, n_load, n_fresh)
    for i, k in enumerate(fresh):
        ops.append(Op(INSERT, int(k), ycsb.expected_value(int(k))))
        ops.append(Op(READ, int(loaded[ridx[i]])))
        if i % 3 == 0:
            kk = int(loaded[ridx[i]])
            ops.append(Op(UPDATE, kk, ycsb.updated_value(kk)))
    return keys, ops


def test_frontend_matches_stop_the_world(rng):
    keys, ops = _mixed_stream(np.random.default_rng(7))
    import copy
    ops_fe = copy.deepcopy(ops)

    t_stw = DashEH(CFG)
    stw = StopTheWorldFrontend(t_stw, max_batch=128, queue_depth=1 << 14)
    for op in ops:
        assert stw.submit(op)
    stw.drain()

    t_fe = DashEH(CFG)
    fe = DashFrontend(t_fe, max_batch=128, queue_depth=1 << 14)
    for op in ops_fe:
        assert fe.submit(op)
    fe.drain()

    # same acknowledged write outcomes, same final logical table (batch
    # formation differs across the lanes, so split *timing* may differ —
    # the record multiset is the contract, not the physical layout)
    assert t_fe.n_items == t_stw.n_items
    assert int(np.asarray(dash_engine.recount_items(t_fe.state))) == t_fe.n_items

    def all_records(t):
        recs = []
        for seg in range(t.n_segments):
            recs += smo.segment_record_set(CFG, t.state, seg)
        return sorted(recs)

    assert all_records(t_fe) == all_records(t_stw)
    st_fe = {(o.kind, o.key): o.status for o in ops_fe if o.kind != READ}
    st_stw = {(o.kind, o.key): o.status for o in ops if o.kind != READ}
    assert st_fe == st_stw
    # reads went through the snapshot path; some overlapped the storm
    assert fe.snapshot_reads > 0
    assert fe.smo_dispatches > 0      # splits actually ran deferred
    # every frontend read observed a pre- or post-write-consistent value
    for op in ops_fe:
        if op.kind != READ:
            continue
        pre, post = ycsb.expected_value(op.key), ycsb.updated_value(op.key)
        assert (not op.found) or op.result in (pre, post), op
    # acknowledged-write visibility: a drained frontend read sees the key
    f, v = t_fe.search(keys)
    assert f.all()


def test_frontend_ticks_identical_fused_on_off(rng):
    """Tick-for-tick equivalence of the fused read path: the same op
    stream through a fused-reads frontend and a routed-reads frontend
    produces identical per-op outcomes (found/value/status) AND the same
    final table — writes ride the planner's fused path in both, so this
    pins the serving-layer read selection specifically. The mixed stream
    drives splits mid-stream, so snapshot + verify-retry reads cross SMO
    boundaries under both paths."""
    import copy
    _, ops = _mixed_stream(np.random.default_rng(23))
    ops_on, ops_off = copy.deepcopy(ops), copy.deepcopy(ops)

    t_on = DashEH(CFG)
    fe_on = DashFrontend(t_on, max_batch=128, queue_depth=1 << 14,
                         fused_reads=True)
    t_off = DashEH(CFG)
    fe_off = DashFrontend(t_off, max_batch=128, queue_depth=1 << 14,
                          fused_reads=False)
    assert fe_on.read_batching == "fused"
    assert fe_off.read_batching == "auto"
    # interleave tick-for-tick so the two frontends see identical schedules
    for op_a, op_b in zip(ops_on, ops_off):
        assert fe_on.submit(op_a)
        assert fe_off.submit(op_b)
    while fe_on.step() | fe_off.step():
        pass
    for a, b in zip(ops_on, ops_off):
        assert (a.kind, a.key, a.status, a.found, a.result) == \
               (b.kind, b.key, b.status, b.found, b.result)
    from tests.test_fused import _diverged
    assert not _diverged(t_on.state, t_off.state)
    assert fe_on.snapshot_reads == fe_off.snapshot_reads
    assert fe_on.retried_reads == fe_off.retried_reads


def test_frontend_rmw_and_delete(rng):
    t = DashEH(CFG)
    fe = DashFrontend(t, max_batch=64, queue_depth=4096)
    keys = unique_keys(np.random.default_rng(11), 300)
    for k in keys:
        fe.submit(Op(INSERT, int(k), ycsb.expected_value(int(k))))
    fe.drain()
    for k in keys[:64]:
        fe.submit(Op(RMW, int(k), ycsb.updated_value(int(k))))
    fe.drain()
    # RMW observed the pre-image and installed the new value
    f, v = t.search(keys[:64])
    want = np.array([ycsb.updated_value(int(k)) for k in keys[:64]], np.uint32)
    assert f.all() and (v == want).all()


def test_frontend_lh_stride_expansion():
    cfg = DashConfig(max_segments=32, dir_depth_max=7, num_buckets=16,
                     num_slots=8, lh_base_log2=2)
    t = DashLH(cfg)
    fe = DashFrontend(t, max_batch=128, queue_depth=1 << 14)
    keys = ycsb.load_keys(np.random.default_rng(3), 1500)
    for k in keys:
        fe.submit(Op(INSERT, int(k), ycsb.expected_value(int(k))))
        fe.submit(Op(READ, int(k)))
    fe.drain()
    assert t.n_items == 1500
    assert t.active_segments > (1 << cfg.lh_base_log2)   # rounds expanded
    f, _ = t.search(keys)
    assert f.all()


# ---------------------------------------------------------------------------
# the interleaving property: no torn reads across randomized schedules
# ---------------------------------------------------------------------------

N_SCHEDULES = 200


def test_snapshot_search_no_torn_reads_under_smo_interleaving(rng):
    """>= N_SCHEDULES randomized schedules interleave ``snapshot_search``
    with a concurrent staged ``bulk_split`` (and concurrent inserts): every
    query must return either the pre-split-consistent or the
    post-split-consistent result — never a torn read (present key lost,
    value from nowhere, or phantom key).

    Shapes are pinned (fixed query batch, fixed split fan-out, fixed insert
    batch) so all schedules share one set of jit traces."""
    local = np.random.default_rng(0xE90C)
    base_keys = unique_keys(local, 1400)
    t = DashEH(CFG)
    t.insert(base_keys[:1000], np.arange(1000, dtype=np.uint32))
    base = t.state
    fresh_pool = base_keys[1000:]

    Q = 256                               # fixed probe batch (one jit trace)
    K = 2                                 # fixed split fan-out per schedule
    IN = 64                               # fixed concurrent-insert batch
    torn = 0
    for sched in range(N_SCHEDULES):
        state = jax.tree.map(jnp.copy, base)
        snapshot = jax.tree.map(jnp.copy, state)

        # --- concurrent writer: random interleave of SMO stages + inserts
        depths = np.asarray(state.local_depth)
        cand = [int(s) for s in np.unique(np.asarray(state.dir))
                if depths[s] < CFG.dir_depth_max]
        segs = list(local.choice(cand, size=K, replace=False))
        wm = int(np.asarray(state.watermark))
        task = smo.BulkSplitTask(CFG, segs, list(range(wm, wm + K)))
        n_stages = int(local.integers(0, 4))      # 0..3 of phase1/2/commit
        done = False
        for _ in range(n_stages):
            if not done:
                state, done = task.pump(state)
        ins_sel = local.integers(0, fresh_pool.size, IN)
        new_keys = fresh_pool[ins_sel]
        do_insert = bool(local.integers(0, 2))
        if do_insert:
            hi_n, lo_n = np_split_keys(new_keys)
            state, st_ins, _ = dash_engine.insert_batch(
                CFG, "eh", state, jnp.asarray(hi_n), jnp.asarray(lo_n),
                jnp.arange(IN, dtype=jnp.uint32) + 5000, batching="scan")

        # --- reader: base keys + the maybe-inserted keys + absent keys
        qsel = local.integers(0, 1000, Q - 2 * IN)
        q_keys = np.concatenate([base_keys[qsel], new_keys,
                                 fresh_pool[local.integers(0, fresh_pool.size,
                                                           IN)]])
        hi, lo = np_split_keys(q_keys)
        found, vals, retried = snapshot_search(
            CFG, snapshot, state, jnp.asarray(hi), jnp.asarray(lo))
        found, vals = np.asarray(found), np.asarray(vals)

        # --- consistency oracle -------------------------------------------
        # keys present at snapshot time must be found with their one value
        # (splits move records, they never change the mapping)
        base_mask = np.isin(q_keys, base_keys[:1000])
        val_of = {int(k): i for i, k in enumerate(base_keys[:1000])}
        for i in np.nonzero(base_mask)[0]:
            if not found[i] or int(vals[i]) != val_of[int(q_keys[i])]:
                torn += 1
        # concurrently-inserted keys: pre-consistent (absent) or
        # post-consistent (their new value) — never garbage
        ins_set = {int(k) for k in new_keys} if do_insert else set()
        for i in np.nonzero(~base_mask)[0]:
            k = int(q_keys[i])
            if k in ins_set:
                if found[i] and int(vals[i]) < 5000:
                    torn += 1
            elif found[i]:
                torn += 1              # phantom: never-inserted key found
    assert torn == 0, f"{torn} torn reads across {N_SCHEDULES} schedules"


def test_buckets_changed_flags_update_writes(rng):
    """An in-place update must be visible to the verify pass (version bump
    regression: silent payload rewrites would let snapshot readers serve
    stale values forever)."""
    t = DashEH(CFG)
    keys = unique_keys(np.random.default_rng(21), 500)
    t.insert(keys, np.arange(500, dtype=np.uint32))
    snap = jax.tree.map(jnp.copy, t.state)
    t.update(keys[:100], np.arange(100, dtype=np.uint32) + 7000)
    hi, lo = np_split_keys(keys[:100])
    changed = np.asarray(buckets_changed(
        CFG, "eh", snap, t.state, jnp.asarray(hi), jnp.asarray(lo)))
    assert changed.all()
    f, v, _ = snapshot_search(CFG, snap, t.state, jnp.asarray(hi),
                              jnp.asarray(lo))
    assert (np.asarray(v) == np.arange(100) + 7000).all()


# ---------------------------------------------------------------------------
# YCSB generator
# ---------------------------------------------------------------------------

def test_ycsb_mixes_and_distributions():
    rng = np.random.default_rng(1)
    loaded = ycsb.load_keys(rng, 512)
    fresh = ycsb.load_keys(np.random.default_rng(2), 2200)
    for mix, ratios in ycsb.MIXES.items():
        cfg = ycsb.YCSBConfig(mix=mix, n_ops=2000, seed=3)
        ops = ycsb.generate(cfg, loaded, insert_keys=fresh)
        kinds = {k: sum(op.kind == k for op in ops) / len(ops)
                 for k in set(op.kind for op in ops)}
        for k, r in ratios.items():
            if mix == "E":
                continue               # scan bursts reshape the ratio
            assert abs(kinds.get(k, 0.0) - r) < 0.08, (mix, kinds)
    # zipfian skews: the hottest key dominates a uniform draw
    z = ycsb.zipfian_ranks(np.random.default_rng(4), 512, 20000)
    counts = np.bincount(z, minlength=512)
    assert counts[0] > 4 * counts[256]
    # determinism
    a = ycsb.generate(ycsb.YCSBConfig(mix="A", n_ops=100, seed=9), loaded)
    b = ycsb.generate(ycsb.YCSBConfig(mix="A", n_ops=100, seed=9), loaded)
    assert [(o.kind, o.key, o.value) for o in a] == \
           [(o.kind, o.key, o.value) for o in b]
    # E's scan bursts count toward the op budget (size-comparable streams)
    e_ops = ycsb.generate(ycsb.YCSBConfig(mix="E", n_ops=100, seed=5),
                          loaded, insert_keys=fresh)
    assert len(e_ops) == 100
    # the pure-insert load mix works against an empty loaded space
    l_ops = ycsb.generate(ycsb.YCSBConfig(mix="load", n_ops=50, seed=5),
                          np.array([], np.uint64), insert_keys=fresh)
    assert len(l_ops) == 50 and all(o.kind == INSERT for o in l_ops)
    # distribution="latest" is honored: post-insert reads chase the front
    lat = ycsb.generate(ycsb.YCSBConfig(mix="D", n_ops=600, seed=5,
                                        distribution="latest"),
                        loaded, insert_keys=fresh)
    seen_ins = set()
    checked = 0
    for op in lat:
        if op.kind == INSERT:
            seen_ins.add(op.key)
        elif seen_ins:
            assert op.key in seen_ins, "latest read outside insert window"
            checked += 1
    assert checked > 50


def test_ycsb_e_scan_bursts():
    loaded = ycsb.load_keys(np.random.default_rng(5), 256)
    ops = ycsb.generate(ycsb.YCSBConfig(mix="E", n_ops=400, seed=6), loaded,
                        insert_keys=ycsb.load_keys(np.random.default_rng(7),
                                                   64))
    # consecutive-key runs of SCAN_LEN appear (the scan analog)
    runs = 0
    i = 0
    keyset = {int(k): i for i, k in enumerate(loaded)}
    while i < len(ops) - ycsb.SCAN_LEN:
        if all(ops[i + j].kind == READ for j in range(ycsb.SCAN_LEN)):
            idx = [keyset.get(ops[i + j].key, -1)
                   for j in range(ycsb.SCAN_LEN)]
            if -1 not in idx and all(
                    idx[j + 1] == (idx[j] + 1) % 256
                    for j in range(ycsb.SCAN_LEN - 1)):
                runs += 1
                i += ycsb.SCAN_LEN
                continue
        i += 1
    assert runs > 5


# ---------------------------------------------------------------------------
# full workload suite through the frontend (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("mix", ["A", "B", "C", "D", "E", "F"])
def test_ycsb_suite_through_frontend(mix):
    """Every YCSB mix end-to-end through the concurrent frontend: all ops
    acknowledged, reads always pre- or post-consistent, table audit clean."""
    rng = np.random.default_rng(0x5C + ord(mix))
    loaded = ycsb.load_keys(rng, 1500)
    fresh = ycsb.load_keys(np.random.default_rng(ord(mix)), 800)
    t = DashEH(CFG)
    t.insert(loaded, np.asarray([ycsb.expected_value(int(k)) for k in loaded],
                                dtype=np.uint32))
    fe = DashFrontend(t, max_batch=128, queue_depth=1 << 15)
    ops = ycsb.generate(ycsb.YCSBConfig(mix=mix, n_ops=4000, seed=13),
                        loaded, insert_keys=fresh)
    for op in ops:
        assert fe.submit(op)
    fe.drain()
    assert t.n_items == int(np.asarray(dash_engine.recount_items(t.state)))
    for op in ops:
        if op.kind == READ and op.found:
            k = op.key
            assert op.result in (ycsb.expected_value(k),
                                 ycsb.updated_value(k)), op
        if op.kind in (INSERT, UPDATE, RMW):
            assert op.status in (INSERTED, NOT_FOUND, 1), op   # 1 = EXISTS
