"""Pallas kernel sweeps vs pure-jnp oracles (exact integer equality)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DashConfig, DashEH, INSERTED
from repro.core.hashing import np_split_keys
from repro.kernels import ops, ref
from repro.kernels.hashmix import BLOCK, bulk_hash
from repro.kernels.probe import BQ, fingerprint_probe, fingerprint_probe_jnp
from tests.conftest import unique_keys


@pytest.mark.parametrize("n", [BLOCK, 4 * BLOCK, 16 * BLOCK])
@pytest.mark.parametrize("seed", [0, 1])
def test_bulk_hash_sweep(n, seed):
    rng = np.random.default_rng(seed)
    hi = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32))
    lo = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32))
    got = bulk_hash(hi, lo)
    want = ref.bulk_hash_ref(hi, lo)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("segments,capacity", [(4, BQ), (8, 2 * BQ), (16, 4 * BQ)])
@pytest.mark.parametrize("fill", [200, 2000])
def test_probe_kernel_sweep(segments, capacity, fill, rng):
    cfg = DashConfig(max_segments=segments, dir_depth_max=8)
    t = DashEH(cfg)
    keys = unique_keys(rng, fill)
    t.insert(keys, np.arange(fill, dtype=np.uint32))
    fp_pad, alloc = ops.plane_views(cfg, t.state)
    hi, lo = np_split_keys(keys[:256])
    qf, qb, qpb, qsrc, keep = ops.route_queries(
        cfg, t.state, jnp.asarray(hi), jnp.asarray(lo), capacity)
    rb, rp, rfb, rfp = ref.fingerprint_probe_ref(fp_pad, alloc, qf, qb, qpb)
    # both lowerings — the Pallas kernel (interpreted) and the jnp CPU path —
    # must match the oracle bit-for-bit
    for probe_fn in (fingerprint_probe, fingerprint_probe_jnp):
        kb, kp, kfb, kfp = probe_fn(fp_pad, alloc, qf, qb, qpb)
        np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))
        np.testing.assert_array_equal(np.asarray(kfb), np.asarray(rfb))
        np.testing.assert_array_equal(np.asarray(kfp), np.asarray(rfp))
    # free-slot bitmaps disjoint from the alloc bitmap of the same bucket
    qb_np, fb_np = np.asarray(qb), np.asarray(kfb)
    al = np.asarray(alloc)
    for s in range(qb_np.shape[0]):
        live = qb_np[s] >= 0
        got_alloc = al[s][np.clip(qb_np[s], 0, al.shape[1] - 1)]
        assert ((fb_np[s][live] & got_alloc[live]) == 0).all()
        np.testing.assert_array_equal(          # free = ~alloc within 14 bits
            fb_np[s][live], (~got_alloc[live]) & 0x3FFF)


def test_probe_routed_end_to_end(rng):
    cfg = DashConfig(max_segments=16, dir_depth_max=8)
    t = DashEH(cfg)
    keys = unique_keys(rng, 4000)
    vals = np.arange(4000, dtype=np.uint32)
    assert (t.insert(keys, vals) == INSERTED).all()
    hi, lo = np_split_keys(keys[:512])
    f, v, keep = ops.probe_routed(cfg, t.state, jnp.asarray(hi), jnp.asarray(lo))
    f, v, keep = map(np.asarray, (f, v, keep))
    assert f[keep].all()
    assert (v[keep] == vals[:512][keep]).all()
    neg = np.setdiff1d(unique_keys(rng, 2000), keys)[:512]
    nh, nl = np_split_keys(neg)
    nf, _, nkeep = ops.probe_routed(cfg, t.state, jnp.asarray(nh), jnp.asarray(nl))
    assert np.asarray(nf)[np.asarray(nkeep)].sum() == 0


def test_route_writes_hints_match_planes(rng):
    """Insert-router hints (match bits + free-slot bitmaps) come from the
    same plane views as the search path and match the oracle."""
    cfg = DashConfig(max_segments=8, dir_depth_max=7)
    t = DashEH(cfg)
    keys = unique_keys(rng, 1200)
    t.insert(keys, np.arange(1200, dtype=np.uint32))
    hi, lo = np_split_keys(keys[:256])
    hi, lo = jnp.asarray(hi), jnp.asarray(lo)
    payload = (hi, lo, jnp.zeros(256, jnp.uint32),
               jnp.zeros((256, cfg.key_heap_words), jnp.uint32),
               jnp.ones(256, jnp.bool_))
    lanes, src, keep, hints = ops.route_writes(cfg, "eh", t.state, payload,
                                               128, True)
    fp_pad, alloc = ops.plane_views(cfg, t.state)
    q_fp = (lanes["h2"] & jnp.uint32(0xFF)).astype(jnp.int32)
    q_b = jnp.where(lanes["valid"], lanes["b"], -1)
    q_pb = jnp.where(lanes["valid"], (lanes["b"] + 1) & (cfg.num_buckets - 1),
                     -1)
    want = ref.fingerprint_probe_ref(fp_pad, alloc, q_fp, q_b, q_pb)
    for got, wnt in zip(hints, want):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(wnt))
    # the inserted keys are present: every valid lane's match bits must hit
    bits = np.asarray(hints[0]) | np.asarray(hints[1])
    assert (bits[np.asarray(lanes["valid"])] != 0).all()


def test_probe_kernel_agrees_with_engine_search(rng):
    """Kernel fast path == engine slow path on the same table."""
    from repro.core import engine
    cfg = DashConfig(max_segments=8, dir_depth_max=7)
    t = DashEH(cfg)
    keys = unique_keys(rng, 1500)
    t.insert(keys, np.arange(1500, dtype=np.uint32))
    probe = np.concatenate([keys[:300], np.setdiff1d(unique_keys(rng, 1000), keys)[:200]])
    hi, lo = np_split_keys(probe)
    f1, v1 = engine.search_batch(cfg, "eh", t.state, jnp.asarray(hi), jnp.asarray(lo))
    f2, v2, keep = ops.probe_routed(cfg, t.state, jnp.asarray(hi), jnp.asarray(lo), capacity=512)
    keep = np.asarray(keep)
    np.testing.assert_array_equal(np.asarray(f1)[keep], np.asarray(f2)[keep])
    hit = np.asarray(f1) & keep
    np.testing.assert_array_equal(np.asarray(v1)[hit], np.asarray(v2)[hit])
