"""Pallas kernel sweeps vs pure-jnp oracles (exact integer equality)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DashConfig, DashEH, INSERTED
from repro.core.hashing import np_split_keys
from repro.kernels import ops, ref
from repro.kernels.hashmix import BLOCK, bulk_hash
from repro.kernels.probe import BQ, fingerprint_probe
from tests.conftest import unique_keys


@pytest.mark.parametrize("n", [BLOCK, 4 * BLOCK, 16 * BLOCK])
@pytest.mark.parametrize("seed", [0, 1])
def test_bulk_hash_sweep(n, seed):
    rng = np.random.default_rng(seed)
    hi = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32))
    lo = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32))
    got = bulk_hash(hi, lo)
    want = ref.bulk_hash_ref(hi, lo)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("segments,capacity", [(4, BQ), (8, 2 * BQ), (16, 4 * BQ)])
@pytest.mark.parametrize("fill", [200, 2000])
def test_probe_kernel_sweep(segments, capacity, fill, rng):
    cfg = DashConfig(max_segments=segments, dir_depth_max=8)
    t = DashEH(cfg)
    keys = unique_keys(rng, fill)
    t.insert(keys, np.arange(fill, dtype=np.uint32))
    fp_pad, alloc = ops.plane_views(cfg, t.state)
    hi, lo = np_split_keys(keys[:256])
    qf, qb, qpb, qsrc, keep = ops.route_queries(
        cfg, t.state, jnp.asarray(hi), jnp.asarray(lo), capacity)
    kb, kp = fingerprint_probe(fp_pad, alloc, qf, qb, qpb)
    rb, rp = ref.fingerprint_probe_ref(fp_pad, alloc, qf, qb, qpb)
    np.testing.assert_array_equal(np.asarray(kb), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(rp))


def test_probe_routed_end_to_end(rng):
    cfg = DashConfig(max_segments=16, dir_depth_max=8)
    t = DashEH(cfg)
    keys = unique_keys(rng, 4000)
    vals = np.arange(4000, dtype=np.uint32)
    assert (t.insert(keys, vals) == INSERTED).all()
    hi, lo = np_split_keys(keys[:512])
    f, v, keep = ops.probe_routed(cfg, t.state, jnp.asarray(hi), jnp.asarray(lo))
    f, v, keep = map(np.asarray, (f, v, keep))
    assert f[keep].all()
    assert (v[keep] == vals[:512][keep]).all()
    neg = np.setdiff1d(unique_keys(rng, 2000), keys)[:512]
    nh, nl = np_split_keys(neg)
    nf, _, nkeep = ops.probe_routed(cfg, t.state, jnp.asarray(nh), jnp.asarray(nl))
    assert np.asarray(nf)[np.asarray(nkeep)].sum() == 0


def test_probe_kernel_agrees_with_engine_search(rng):
    """Kernel fast path == engine slow path on the same table."""
    from repro.core import engine
    cfg = DashConfig(max_segments=8, dir_depth_max=7)
    t = DashEH(cfg)
    keys = unique_keys(rng, 1500)
    t.insert(keys, np.arange(1500, dtype=np.uint32))
    probe = np.concatenate([keys[:300], np.setdiff1d(unique_keys(rng, 1000), keys)[:200]])
    hi, lo = np_split_keys(probe)
    f1, v1 = engine.search_batch(cfg, "eh", t.state, jnp.asarray(hi), jnp.asarray(lo))
    f2, v2, keep = ops.probe_routed(cfg, t.state, jnp.asarray(hi), jnp.asarray(lo), capacity=512)
    keep = np.asarray(keep)
    np.testing.assert_array_equal(np.asarray(f1)[keep], np.asarray(f2)[keep])
    hit = np.asarray(f1) & keep
    np.testing.assert_array_equal(np.asarray(v1)[hit], np.asarray(v2)[hit])
