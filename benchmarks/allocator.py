"""Paper Fig. 15 analog: allocation strategy impact. PMDK-allocator stalls
become, on TPU/XLA, the cost of growing a statically-shaped pool: a bigger
pool must be re-materialized and every jitted op re-compiled (shape change).
Preallocation makes splits pure data movement."""
from __future__ import annotations

import time

import numpy as np

from repro.core import DashConfig, DashEH
from .common import Row, unique_keys

N = 16_000


def run():
    keys = unique_keys(np.random.default_rng(61), N)
    vals = np.zeros(N, np.uint32)

    # preallocated pool (production config)
    t0 = time.perf_counter()
    t = DashEH(DashConfig(max_segments=256, dir_depth_max=12))
    for i in range(0, N, 4000):
        t.insert(keys[i:i + 4000], vals[i:i + 4000])
    pre_s = time.perf_counter() - t0

    # grow-on-demand: start tiny, double max_segments when full (recompiles)
    t0 = time.perf_counter()
    grow_events = 0
    cap = 8
    t2 = DashEH(DashConfig(max_segments=cap, dir_depth_max=12))
    i = 0
    while i < N:
        try:
            t2.insert(keys[i:i + 4000], vals[i:i + 4000])
            i += 4000
        except Exception:
            # "allocate a bigger pool": copy into a 2x state (shape change =>
            # every jitted op recompiles; the Fig. 15 stall analog)
            import jax.numpy as jnp
            cap *= 2
            grow_events += 1
            big = DashEH(DashConfig(max_segments=cap, dir_depth_max=12))
            old = t2.state
            S_old = old.fp.shape[0]
            new_state = big.state
            for f in old._fields:
                o, nw = getattr(old, f), getattr(new_state, f)
                if hasattr(o, "shape") and o.ndim >= 1 and o.shape[:1] == (S_old,):
                    nw = nw.at[:S_old].set(o)
                    new_state = new_state._replace(**{f: nw})
                else:
                    new_state = new_state._replace(**{f: o})
            big.state = new_state
            t2 = big
    grow_s = time.perf_counter() - t0

    return [Row("fig15/prealloc_pool", pre_s / N * 1e6,
                f"total={pre_s:.2f}s"),
            Row("fig15/grow_on_demand", grow_s / N * 1e6,
                f"total={grow_s:.2f}s; regrows={grow_events}; "
                f"slowdown={grow_s / pre_s:.2f}x")]
