"""Paper Fig. 12: load factor vs items inserted — Dash-EH(2/4), Dash-LH,
CCEH-like, Level hashing. The 'dips' are splits/rehashes."""
from __future__ import annotations

import numpy as np

from repro.core import DashConfig, DashEH, DashLH
from repro.core.baselines import LevelConfig, LevelHashing, cceh_config
from .common import Row, unique_keys

N = 24_000
STEP = 2000


def curve(make):
    t = make()
    rng = np.random.default_rng(31)
    keys = unique_keys(rng, N)
    out = []
    for i in range(0, N, STEP):
        t.insert(keys[i:i + STEP],
                 (np.arange(i, i + STEP) % 2**32).astype(np.uint32))
        out.append(t.load_factor)
    return out


def run():
    tables = {
        "dash-eh-2": lambda: DashEH(DashConfig(max_segments=256, dir_depth_max=12, num_stash=2)),
        "dash-eh-4": lambda: DashEH(DashConfig(max_segments=256, dir_depth_max=12, num_stash=4)),
        "dash-lh": lambda: DashLH(DashConfig(max_segments=256, num_stash=4)),
        "cceh-like": lambda: DashEH(cceh_config(max_segments=1024, dir_depth_max=13)),
        "level": lambda: LevelHashing(LevelConfig(max_log2=14, init_log2=8)),
    }
    rows = []
    for name, make in tables.items():
        c = curve(make)
        rows.append(Row(f"fig12/{name}", 0.0,
                        f"peak={max(c):.3f}; mean={np.mean(c):.3f}; "
                        f"curve={'|'.join(f'{x:.2f}' for x in c)}"))
    return rows
