"""Chaos matrix benchmark: the >=200-seeded-schedule safety evidence, scrub
detection latency, and degraded-mode serving throughput.

Gated measurements (asserted before the artifact is written):

  * **matrix** — ``N_SCHEDULES`` (default 200, ``DASH_CHAOS_SCHEDULES`` to
    override) seeded fault schedules through ``repro.persist.chaos``: torn
    msyncs, bit rot, transient EIO bursts, ENOSPC rehearsals, crash + clean
    restarts, scrub ticks, pointer-mode lineages. ZERO wrong reads and ZERO
    silently-lost acked keys (``run_schedule`` additionally asserts the
    safety property per schedule; this gate re-checks the aggregate).
  * **scrub latency** — a planted media flip is detected AND repaired in
    place by the background scrubber within ONE full pass of the pool
    (``rows_total / rows_per_tick`` ticks); the pool verifies clean after.
  * **degraded serving** — with the flush path hard-failed the frontend
    keeps serving (health DEGRADED, volatile): every key inserted before
    and during the outage reads back, and ``try_recover`` restores HEALTHY
    once the fault clears. Healthy vs degraded throughput is recorded.

Emits ``BENCH_chaos.json``.
"""
from __future__ import annotations

import math
import os
import shutil
import tempfile
import time

import numpy as np

from repro import persist
from repro.core import DashConfig
from repro.persist import chaos
from repro.persist.faults import FaultPlan
from repro.persist.writeback import Scrubber
from repro.serving import frontend as fe_mod
from repro.serving.frontend import INSERT, READ, DashFrontend, Op
from .common import Row, enable_compilation_cache, unique_keys, write_artifact

ARTIFACT = "BENCH_chaos.json"

N_SCHEDULES = int(os.environ.get("DASH_CHAOS_SCHEDULES", "200"))
SEED_BASE = 1000
SCRUB_TRIALS = 4
SCRUB_ROWS_PER_TICK = 64
TP_BATCHES = 8
TP_BATCH = 256


def _matrix(tmp: str) -> dict:
    t0 = time.perf_counter()
    agg = chaos.run_many(range(SEED_BASE, SEED_BASE + N_SCHEDULES), tmp,
                         min_tears=1, min_flips=1)
    agg["seconds"] = time.perf_counter() - t0
    return agg


def _scrub(tmp: str) -> dict:
    """Plant seeded flips on a live pool and count scrubber ticks until the
    first detection; finish the pass and verify the pool healed."""
    path = os.path.join(tmp, "scrub.pool")
    t = persist.create(path, chaos.CHAOS_CFG)
    rng = np.random.default_rng(11)
    t.insert(unique_keys(rng, 600), np.arange(600, dtype=np.uint32) + 1)
    t.flush()
    wb = t.writeback
    trials = []
    for i in range(SCRUB_TRIALS):
        sc = Scrubber(wb, rows_per_tick=SCRUB_ROWS_PER_TICK)
        bound = math.ceil(sc.rows_total / SCRUB_ROWS_PER_TICK)
        FaultPlan(seed=70 + i).flip_bits(wb.pool, n=2)
        ticks, tick_s = 0, []
        while sc.mismatched_rows == 0:
            ticks += 1
            assert ticks <= bound, "flip not detected within one full pass"
            t1 = time.perf_counter()
            sc.tick(t.state)
            tick_s.append(time.perf_counter() - t1)
        assert sc.repaired_rows >= 1
        while sc.cycles == 0:          # repair any second flip this pass
            sc.tick(t.state)
        bad = wb.pool.verify_checksums()
        assert bad["bt"].size == 0 and bad["nb"].size == 0
        trials.append({"ticks_to_detect": ticks, "bound_ticks": bound,
                       "tick_seconds": float(np.mean(tick_s))})
    wb.pool.close()
    worst = max(tr["ticks_to_detect"] for tr in trials)
    return {"trials": trials, "rows_per_tick": SCRUB_ROWS_PER_TICK,
            "worst_ticks_to_detect": worst,
            "bound_ticks": trials[0]["bound_ticks"],
            "mean_tick_seconds": float(np.mean(
                [tr["tick_seconds"] for tr in trials]))}


def _degraded(tmp: str) -> dict:
    """Healthy vs degraded-mode serving throughput through the frontend."""
    cfg = DashConfig(max_segments=64, dir_depth_max=9)
    plan = FaultPlan(seed=5)
    path = os.path.join(tmp, "deg.pool")
    t = persist.create(path, cfg, faults=plan)
    rng = np.random.default_rng(12)
    keys = unique_keys(rng, (TP_BATCHES * 2 + 4) * TP_BATCH)
    fe = DashFrontend(t, max_batch=TP_BATCH, queue_depth=1 << 16)
    cursor = 0

    def pump(n_batches: int) -> float:
        nonlocal cursor
        served, t0 = 0, time.perf_counter()
        for _ in range(n_batches):
            ks = keys[cursor:cursor + TP_BATCH]
            cursor += TP_BATCH
            ops = [Op(INSERT, int(k), 1) for k in ks]
            for op in ops:
                assert fe.submit(op)
            fe.drain()
            served += len(ops)
        return served / (time.perf_counter() - t0)

    pump(4)                                    # compile + settle
    healthy = pump(TP_BATCHES)
    assert fe.health == fe_mod.HEALTHY
    plan.eio_fences[plan.fence_calls] = 1 << 30   # device fails hard
    degraded = pump(TP_BATCHES)
    assert fe.health == fe_mod.DEGRADED
    stats = fe.stats()
    # every key inserted before AND during the outage still serves
    probe = rng.choice(keys[:cursor], 512, replace=False)
    ops = [Op(READ, int(k)) for k in probe]
    for op in ops:
        assert fe.submit(op)
    fe.drain()
    assert all(op.found for op in ops)
    plan.eio_fences.clear()
    assert fe.try_recover()
    assert fe.health == fe_mod.HEALTHY
    t.writeback.pool.close()
    return {"healthy_ops_per_s": healthy, "degraded_ops_per_s": degraded,
            "ratio": degraded / healthy,
            "unflushed_publishes": int(stats.get("unflushed_publishes", 0)),
            "flush_io_errors": int(stats.get("flush_io_errors", 0))}


def run():
    enable_compilation_cache()
    rows = []
    report = {"config": {"n_schedules": N_SCHEDULES,
                         "seed_base": SEED_BASE}}
    tmp = tempfile.mkdtemp(prefix="dash_chaos_")
    try:
        agg = _matrix(tmp)
        report["matrix"] = agg
        assert agg["schedules"] == N_SCHEDULES
        assert agg["wrong_reads"] == 0, agg
        assert agg["silent_lost"] == 0, agg
        assert agg["tears"] >= N_SCHEDULES and agg["flips"] >= N_SCHEDULES
        assert agg["crashes"] > 0 and agg["eio_raised"] > 0
        rows.append(Row("chaos/schedules", agg["schedules"],
                        f"tears={agg['tears']} flips={agg['flips']} "
                        f"crashes={agg['crashes']} eio={agg['eio_raised']} "
                        f"wrong=0 silent_lost=0"))
        rows.append(Row("chaos/seconds_per_schedule",
                        agg["seconds"] / max(agg["schedules"], 1),
                        f"{agg['seconds']:.1f}s total, "
                        f"reported_lost={agg['reported_lost']} "
                        f"pending={agg['indeterminate_pending']}"))

        scrub = _scrub(tmp)
        report["scrub"] = scrub
        rows.append(Row("chaos/scrub_detect_ticks", scrub[
            "worst_ticks_to_detect"],
            f"bound={scrub['bound_ticks']} ticks/pass, "
            f"{scrub['mean_tick_seconds'] * 1e3:.2f}ms/tick"))

        deg = _degraded(tmp)
        report["degraded"] = deg
        rows.append(Row("chaos/degraded_throughput_ratio", deg["ratio"],
                        f"{deg['degraded_ops_per_s']:.0f} vs "
                        f"{deg['healthy_ops_per_s']:.0f} ops/s "
                        f"({deg['unflushed_publishes']} volatile acks)"))

        write_artifact(ARTIFACT, report)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
