"""Paper Figs. 1/8: scalability. Cores become shards: we measure (a) true
multi-shard execution on 8 fake devices (subprocess), (b) the routing
overhead that bounds scaling, and (c) the mixed 20/80 insert/search
workload. On one physical core, aggregate wall-clock cannot scale; the
derived column reports per-shard work and the fabric-vs-HBM byte ratio that
proves scaling headroom at pod scale (see EXPERIMENTS.md SSDry-run)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import DashConfig, DashEH
from .common import Row, ops_row, time_op, unique_keys

N = 16_000
BATCH = 4096


def _single_shard_rows():
    rng = np.random.default_rng(71)
    keys = unique_keys(rng, N)
    vals = (np.arange(N) % 2**32).astype(np.uint32)
    t = DashEH(DashConfig(max_segments=128, dir_depth_max=10))
    t.insert(keys[:N - BATCH], vals[:N - BATCH])
    rows = [ops_row("fig8/1shard/insert",
                    time_op(lambda: t.insert(keys[N - BATCH:], vals[N - BATCH:]),
                            repeats=1, warmup=0), BATCH)]
    s = time_op(lambda: t.search(keys[:BATCH]))
    rows.append(ops_row("fig8/1shard/search", s, BATCH))
    # mixed 20/80
    def mixed():
        t.search(keys[:BATCH])
        t.search(keys[BATCH:2 * BATCH])
        t.search(keys[2 * BATCH:3 * BATCH])
        t.search(keys[:BATCH])
        t.delete(keys[:BATCH // 4])
        t.insert(keys[:BATCH // 4], vals[:BATCH // 4])
    s = time_op(mixed, repeats=1)
    rows.append(ops_row("fig8/1shard/mixed_20_80", s, BATCH * 4 + BATCH // 2))
    return rows


def _dht_shards():
    code = textwrap.dedent("""
        import json, time
        import numpy as np
        from repro.core import DashConfig
        from repro.distributed import DistributedDash
        from repro.launch.mesh import make_test_mesh
        out = {}
        for shards, mesh in ((2, make_test_mesh(2, 1)), (4, make_test_mesh(4, 1)),
                             (8, make_test_mesh(8, 1))):
            d = DistributedDash(DashConfig(max_segments=64, dir_depth_max=9),
                                mesh, axes=("data",), capacity=512)
            rng = np.random.default_rng(5)
            keys = np.unique(rng.integers(1, 2**63, 40000, dtype=np.uint64))[:16000]
            d.insert(keys, np.zeros(16000, np.uint32))
            d.search(keys[:4096])
            t0 = time.perf_counter()
            for _ in range(3):
                d.search(keys[:4096])
            out[shards] = (time.perf_counter() - t0) / 3
        print("RESULT " + json.dumps(out))
    """)
    env = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    rows = []
    for ln in r.stdout.splitlines():
        if ln.startswith("RESULT "):
            res = json.loads(ln[len("RESULT "):])
            for shards, sec in res.items():
                rows.append(ops_row(f"fig8/dht_{shards}shards/search",
                                    float(sec), 4096,
                                    extra="1-core host: per-shard work constant"))
    if not rows:
        rows.append(Row("fig8/dht", 0.0, f"subprocess failed: {r.stderr[-200:]}"))
    return rows


def run():
    return _single_shard_rows() + _dht_shards()
