"""Paper Fig. 14: throughput timeline after a dirty restart — early batches
pay per-segment recovery, then throughput returns to normal.

Two timelines: the volatile in-memory restart (pre-PR-5 simulation) and the
durable one — the same crashed state flushed to a PM pool, the process
"killed", and the table reopened via ``persist.reopen`` (O(1)); the early
read batches then lazily recover exactly the segments they touch, straight
off the memory-mapped pool state."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro import persist
from repro.core import DashConfig, DashEH
from repro.persist import WritebackEngine
from repro.persist.pool import PmPool
from .common import Row, unique_keys

N = 30_000
BATCH = 1000


def _timeline(t, keys, rng, n_batches=12):
    tl = []
    for b in range(n_batches):
        q = rng.choice(keys, BATCH, replace=False)
        t0 = time.perf_counter()
        f, _ = t.search(q)
        dt = time.perf_counter() - t0
        assert f.all()
        tl.append(BATCH / dt)
    return tl


def _rows(tag, tl, recovered):
    normal = tl[-1]
    t_recovered = next((i for i, x in enumerate(tl) if x > 0.7 * normal), 0)
    return [Row(f"fig14/{tag}_timeline", 0.0,
                "ops_per_s=" + "|".join(f"{x:.0f}" for x in tl)),
            Row(f"fig14/{tag}_batches_to_normal", 0.0,
                f"{t_recovered} batches; segments_recovered={recovered}")]


def run():
    cfg = DashConfig(max_segments=256, dir_depth_max=12)
    t = DashEH(cfg)
    keys = unique_keys(np.random.default_rng(51), N)
    for i in range(0, N, 4000):
        t.insert(keys[i:i + 4000], np.zeros(min(4000, N - i), np.uint32))
    t.crash(np.random.default_rng(3), n_dups=4)

    # durable: flush the crashed state to a pool BEFORE the volatile restart
    # mutates it (both paths then recover the identical artifact set)
    tmp = tempfile.mkdtemp(prefix="dash_fig14_")
    path = os.path.join(tmp, "crashed.pool")
    t.attach_writeback(WritebackEngine(PmPool.create(path, cfg, "eh")))
    t.flush()

    t.restart()
    rows = _rows("volatile", _timeline(t, keys, np.random.default_rng(4)),
                 t.recovered_segments)

    td, info = persist.reopen(path)
    assert not info["clean"]
    rows += _rows("durable", _timeline(td, keys, np.random.default_rng(4)),
                  td.recovered_segments)
    rows.append(Row("fig14/durable_reopen_us", info["seconds"] * 1e6,
                    f"flush_seq={info['flush_seq']}"))
    shutil.rmtree(tmp, ignore_errors=True)
    return rows
