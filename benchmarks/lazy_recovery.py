"""Paper Fig. 14: throughput timeline after a dirty restart — early batches
pay per-segment recovery, then throughput returns to normal."""
from __future__ import annotations

import time

import numpy as np

from repro.core import DashConfig, DashEH
from .common import Row, unique_keys

N = 30_000
BATCH = 1000


def run():
    cfg = DashConfig(max_segments=256, dir_depth_max=12)
    t = DashEH(cfg)
    keys = unique_keys(np.random.default_rng(51), N)
    for i in range(0, N, 4000):
        t.insert(keys[i:i + 4000], np.zeros(min(4000, N - i), np.uint32))
    t.crash(np.random.default_rng(3), n_dups=4)
    t.restart()

    rng = np.random.default_rng(4)
    tl = []
    normal = None
    for b in range(12):
        q = rng.choice(keys, BATCH, replace=False)
        t0 = time.perf_counter()
        f, _ = t.search(q)
        dt = time.perf_counter() - t0
        assert f.all()
        tl.append(BATCH / dt)
        if b >= 9:
            normal = tl[-1]
    t_recovered = next((i for i, x in enumerate(tl) if x > 0.7 * normal), 0)
    return [Row("fig14/throughput_timeline", 0.0,
                "ops_per_s=" + "|".join(f"{x:.0f}" for x in tl)),
            Row("fig14/batches_to_normal", 0.0,
                f"{t_recovered} batches; segments_recovered={t.recovered_segments}")]
