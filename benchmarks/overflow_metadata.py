"""Paper Fig. 10: overflow metadata lets probes skip the stash buckets.

Like Fig. 9, the effect's currency is avoided traffic: without metadata every
probe scans all active stash buckets; with it, only the MEASURED fraction of
queries whose home bucket has a positive overflow counter or a matching
overflow fingerprint touches the stash. We report that fraction (from the
live structure, per real query batch) and the resulting bytes, plus CPU wall
time for transparency."""
from __future__ import annotations

import numpy as np

from repro.core import DashConfig, DashEH, layout
from repro.core.hashing import np_hash1, np_hash2, np_split_keys
from .common import Row, ops_row, time_op, unique_keys

N = 20_000
BATCH = 4096
STASH_BUCKET_BYTES = 16 + 4 + 14 * 12    # fp plane + meta + slots


def _stash_probe_fraction(t, queries):
    """Fraction of queries that must touch the stash under the metadata rules
    (ovf_count>0 forces a scan; else only matching overflow fingerprints)."""
    hi, lo = np_split_keys(queries)
    h1, h2 = np_hash1(hi, lo), np_hash2(hi, lo)
    cfg = t.cfg
    seg = np.asarray(t.state.dir)[h1 >> np.uint32(32 - cfg.dir_depth_max)]
    b = (h1 & np.uint32(cfg.num_buckets - 1)).astype(np.int64)
    pb = (b + 1) % cfg.num_buckets
    om = np.asarray(t.state.ometa)
    ofp = np.asarray(t.state.ofp)
    fpv = (h2 & np.uint32(0xFF)).astype(np.uint8)

    om_b = om[seg, b]
    ovf_cnt = (om_b >> np.uint32(layout.OVFC_SHIFT)) & np.uint32(0x7F)
    need = ovf_cnt > 0
    for bucket, member in ((b, 0), (pb, 1)):
        o = om[seg, bucket]
        oa = o & np.uint32(0xF)
        omem = (o >> np.uint32(4)) & np.uint32(0xF)
        for j in range(cfg.num_ofp):
            allocated = ((oa >> np.uint32(j)) & 1) == 1
            mm = ((omem >> np.uint32(j)) & 1) == member
            match = allocated & mm & (ofp[seg, bucket, j] == fpv)
            need = need | match
    return float(need.mean())


def _fill_to_capacity(cfg):
    """Fill a fixed table (no split headroom) to its natural limit, so the
    stash is genuinely populated — the regime Fig. 10 measures."""
    from repro.core import TableFullError
    t = DashEH(cfg)
    keys = unique_keys(np.random.default_rng(23), cfg.max_segments * cfg.seg_capacity)
    i = 0
    try:
        while i < keys.size:
            st = t.insert(keys[i:i + 128], np.zeros(128, np.uint32))
            if (st == 2).any():       # NEED_SPLIT surfaced => full
                break
            i += 128
    except TableFullError:
        pass
    return t, keys[:i]


def run():
    rng = np.random.default_rng(23)
    rows = []
    for stash in (2, 4):
        t, keys = _fill_to_capacity(DashConfig(
            max_segments=8, init_depth=3, dir_depth_max=8, num_stash=stash))
        neg = np.setdiff1d(unique_keys(np.random.default_rng(24), 8000), keys)[:BATCH]
        for op, q in (("search_pos", keys[:BATCH]), ("search_neg", neg)):
            frac = _stash_probe_fraction(t, q)
            with_meta = frac * stash * STASH_BUCKET_BYTES + 2 * 2  # +ometa words
            without = stash * STASH_BUCKET_BYTES
            rows.append(Row(
                f"fig10/bytes/{op}/stash{stash}", 0.0,
                f"meta_on={with_meta:.0f}B meta_off={without:.0f}B "
                f"saving={without/max(with_meta,1e-9):.2f}x "
                f"(stash-probe fraction={frac:.4f})"))
        rows.append(Row(f"fig10/load_factor/stash{stash}", 0.0,
                        f"{t.load_factor:.3f} with {keys.size} records"))
        # wall time comparison on the same populated table
        for meta in (True, False):
            tag = f"stash{stash}/{'meta_on' if meta else 'meta_off'}"
            import dataclasses
            t.cfg = dataclasses.replace(t.cfg, use_overflow_meta=meta)
            s = time_op(lambda: t.search(neg))
            rows.append(ops_row(f"fig10/walltime/search_neg/{tag}", s, BATCH))
        t.cfg = dataclasses.replace(t.cfg, use_overflow_meta=True)
    return rows
