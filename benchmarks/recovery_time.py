"""Paper Table 1: restart time vs data size. Dash restarts in O(1) (read
clean marker, bump V); the CCEH-style baseline scans the directory (and we
also show full eager recovery for contrast).

The volatile rows restart an in-memory state (the pre-PR-5 simulation); the
``dash_durable_reopen`` rows restart from a real pool file through
``persist.reopen`` — map, superblock, V bump, scalars-only flush — the same
O(1) claim measured against durable media (benchmarks/durable_restart.py
extends this end-to-end through the serving frontend)."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro import persist
from repro.core import DashConfig, DashEH, recovery
from repro.persist import WritebackEngine
from repro.persist.pool import PmPool
from .common import Row, unique_keys


def run():
    rows = []
    tmp = tempfile.mkdtemp(prefix="dash_table1_")
    for n in (5_000, 20_000, 60_000):
        cfg = DashConfig(max_segments=512, dir_depth_max=12)
        t = DashEH(cfg)
        keys = unique_keys(np.random.default_rng(n), n)
        for i in range(0, n, 4000):
            t.insert(keys[i:i + 4000], np.zeros(min(4000, n - i), np.uint32))
        t.crash(np.random.default_rng(1), n_dups=2)

        # Dash: instant
        work = t.restart()
        rows.append(Row(f"table1/dash_instant/n{n}", work["seconds"] * 1e6,
                        f"segments={t.n_segments}"))

        # Dash durable: the same restart from a pool file (crash artifacts
        # flushed durably; reopen = map + superblock + V bump)
        path = os.path.join(tmp, f"t{n}.pool")
        pool = PmPool.create(path, cfg, "eh")
        t.attach_writeback(WritebackEngine(pool))
        t.flush()
        t2, dwork = persist.reopen(path)
        rows.append(Row(f"table1/dash_durable_reopen/n{n}",
                        dwork["seconds"] * 1e6,
                        f"pool_bytes={pool.plane_bytes}"))
        assert not dwork["clean"]

        # CCEH-style: scan the whole directory validating depth/ownership
        t.crash(np.random.default_rng(2), n_dups=0)
        t0 = time.perf_counter()
        dirv = np.asarray(t.state.dir)
        depths = np.asarray(t.state.local_depth)
        gd = t.global_depth
        for i in range(dirv.size):                 # deliberate linear scan
            seg = dirv[i]
            assert depths[seg] <= gd
        scan_s = time.perf_counter() - t0
        rows.append(Row(f"table1/cceh_dir_scan/n{n}", scan_s * 1e6,
                        f"dir_entries={dirv.size}"))

        # eager full recovery for contrast (what lazy recovery amortizes)
        t.restart()
        t0 = time.perf_counter()
        t.state = recovery.recover_all(cfg, "eh", t.state)
        rows.append(Row(f"table1/eager_recover_all/n{n}",
                        (time.perf_counter() - t0) * 1e6, ""))
    shutil.rmtree(tmp, ignore_errors=True)
    return rows
