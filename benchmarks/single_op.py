"""Paper Fig. 7: single-shard per-op cost, fixed vs variable-length keys,
across Dash-EH / Dash-LH / CCEH-like / Level hashing."""
from __future__ import annotations

import numpy as np

from repro.core import DashConfig, DashEH, DashLH
from repro.core.baselines import LevelConfig, LevelHashing, cceh_config
from .common import Row, ops_row, time_op, unique_keys

N = 20_000
BATCH = 4096


def _mk_tables():
    return {
        "dash-eh": DashEH(DashConfig(max_segments=128, dir_depth_max=10)),
        "dash-lh": DashLH(DashConfig(max_segments=128, num_stash=4)),
        "cceh-like": DashEH(cceh_config(max_segments=512, dir_depth_max=12)),
        "level": LevelHashing(LevelConfig(max_log2=13, init_log2=8)),
    }


def run():
    rng = np.random.default_rng(7)
    keys = unique_keys(rng, N)
    vals = (np.arange(N) % 2**32).astype(np.uint32)
    neg = np.setdiff1d(unique_keys(np.random.default_rng(8), N), keys)[:BATCH]
    rows = []
    for name, t in _mk_tables().items():
        # measure steady-state insert on a preloaded table
        t.insert(keys[:N - BATCH], vals[:N - BATCH])
        s = time_op(lambda: t.insert(keys[N - BATCH:], vals[N - BATCH:]),
                    repeats=1, warmup=0)
        rows.append(ops_row(f"fig7/insert/{name}", s, BATCH))
        s = time_op(lambda: t.search(keys[:BATCH]))
        rows.append(ops_row(f"fig7/search_pos/{name}", s, BATCH))
        s = time_op(lambda: t.search(neg))
        rows.append(ops_row(f"fig7/search_neg/{name}", s, BATCH))
        if hasattr(t, "delete"):
            s = time_op(lambda: t.delete(keys[:BATCH]), repeats=1, warmup=0)
            rows.append(ops_row(f"fig7/delete/{name}", s, BATCH))

    # variable-length keys (pointer mode): dash-eh vs cceh-like (Fig. 7 right)
    for name, cfg in (("dash-eh", DashConfig(max_segments=128, dir_depth_max=10,
                                             pointer_mode=True,
                                             key_heap_size=N, key_heap_words=4)),
                      ("cceh-like", DashConfig(
                          num_buckets=64, num_stash=0, num_slots=4, num_ofp=0,
                          max_segments=512, dir_depth_max=12,
                          use_fingerprints=False, use_balanced=False,
                          use_displacement=False, probe_len=4,
                          pointer_mode=True, key_heap_size=N,
                          key_heap_words=4))):
        t = DashEH(cfg)
        words = np.unique(np.random.default_rng(9).integers(
            0, 2**32, (N, 4), dtype=np.uint64).astype(np.uint32), axis=0)[:N // 2]
        t.insert(values=np.arange(words.shape[0], dtype=np.uint32), words=words)
        s = time_op(lambda: t.search(words=words[:BATCH]))
        rows.append(ops_row(f"fig7var/search_pos/{name}", s, BATCH))
        negw = np.random.default_rng(10).integers(
            0, 2**32, (BATCH, 4), dtype=np.uint64).astype(np.uint32)
        s = time_op(lambda: t.search(words=negw))
        rows.append(ops_row(f"fig7var/search_neg/{name}", s, BATCH))
    return rows
