"""Distributed-Dash roofline on the production mesh: lower+compile the
shard_map DHT search for 256 fake devices and account fabric vs HBM bytes —
the scaling argument of DESIGN.md quantified from the compiled artifact.

Emits ``BENCH_dht_roofline.json`` (provenance-stamped like every artifact;
bounds registered in scripts/check_bench.py): the claim gated is that
right-sized routing lanes keep per-device fabric BYTES at the same order
as the local-HBM probe bytes (~24B/query each way vs ~256B of bucket
traffic) — a lane-sizing regression shows up as a 16x byte blow-up. The
time ratio at nominal bandwidths (fabric 50GB/s vs HBM 819GB/s) is
reported for context; both terms are sub-2us per 1024-query tick.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import Row, write_artifact

ARTIFACT = "BENCH_dht_roofline.json"

_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import DashConfig
    from repro.distributed import dht
    from repro.launch.mesh import make_production_mesh
    from repro.launch import hlo_analysis

    cfg = DashConfig(max_segments=64, dir_depth_max=10)
    mesh = make_production_mesh(multi_pod=False)
    with mesh:
        search_fn, insert_fn, n = dht.build_dht_ops(
            cfg, mesh, axes=("data", "model"), capacity=None, q_local_hint=1024)
        st = dht.make_abstract(cfg, n)
        q = jax.ShapeDtypeStruct((n, 1024), jnp.uint32)
        lowered = jax.jit(search_fn).lower(st, q, q)
        compiled = lowered.compile()
        res = hlo_analysis.analyze(compiled.as_text())
    queries_per_dev = 1024
    fabric = sum(res["collectives"].values())
    # local probe HBM bytes: 2 buckets x (fp 16B + meta 12B + hit slots)
    hbm = queries_per_dev * 2 * (16 + 12 + 16)
    print("RESULT " + json.dumps({
        "n_shards": n, "fabric_bytes_per_dev": fabric,
        "hbm_bytes_per_dev_est": hbm,
        "fabric_us_at_50GBs": fabric / 50e9 * 1e6,
        "hbm_us_at_819GBs": hbm / 819e9 * 1e6,
        "collective_counts": res["collective_counts"]}))
""")


def run():
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                       text=True, env=env, timeout=1800)
    for ln in r.stdout.splitlines():
        if ln.startswith("RESULT "):
            d = json.loads(ln[len("RESULT "):])
            # the roofline claim itself: fabric time (at pod ICI bandwidth)
            # must not dominate the local HBM probe term
            d["fabric_vs_hbm_us_ratio"] = (
                d["fabric_us_at_50GBs"] / d["hbm_us_at_819GBs"])
            write_artifact(ARTIFACT, d)
            return [Row("dht_roofline/256chips", 0.0,
                        f"fabric={d['fabric_bytes_per_dev']:.3g}B/dev "
                        f"({d['fabric_us_at_50GBs']:.1f}us@50GB/s) vs "
                        f"hbm~{d['hbm_bytes_per_dev_est']:.3g}B "
                        f"({d['hbm_us_at_819GBs']:.2f}us@819GB/s); "
                        f"colls={d['collective_counts']}")]
    return [Row("dht_roofline/256chips", 0.0,
                f"failed: {r.stderr[-200:]}")]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
