# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--only fig9]``.

Modules that emit a JSON artifact declare ``ARTIFACT``; the runner skips them
when the artifact is fresh (newer than the module source) unless ``--force``.

Modules map 1:1 to the paper's artifacts:
  fig7   single_op            per-op cost, 4 tables, fixed + var-len keys
  fig8   scalability          shard scaling + mixed workload + DHT
  fig9   fingerprint_effect   fingerprints on/off
  fig10  overflow_metadata    stash metadata on/off x stash count
  fig11  load_factor_stack    technique stack vs segment size
  fig12  load_factor_curve    load factor vs inserts, 5 schemes
  fig13  concurrency          optimistic vs pessimistic search
  table1 recovery_time        restart cost vs data size
  fig14  lazy_recovery        post-restart throughput timeline
  durable durable_restart     durable reopen ttfq + flush volume + torn crash
                              (+ JSON artifact)
  fig15  allocator            preallocated pool vs grow-on-demand
  extra  dht_roofline         256-chip DHT fabric-vs-HBM accounting
  extra  kernel_probe         Pallas probe path timing (interpret)
  extra  batch_parallel       segment-parallel vs scan engine + small-batch
                              fused-path p50/p99 latency rows — also under
                              the ``latency`` tag (+ JSON artifact)
  extra  smo                  bulk vs scalar split/merge SMOs (+ JSON artifact)
  extra  online_resize        frontend vs stop-the-world p50/p99 during a
                              split storm (+ JSON artifact)
  extra  chaos                >=200-seed fault matrix + scrub latency +
                              degraded-mode throughput (+ JSON artifact)
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

MODULES = [
    ("fig7", "benchmarks.single_op"),
    ("fig8", "benchmarks.scalability"),
    ("fig9", "benchmarks.fingerprint_effect"),
    ("fig10", "benchmarks.overflow_metadata"),
    ("fig11", "benchmarks.load_factor_stack"),
    ("fig12", "benchmarks.load_factor_curve"),
    ("fig13", "benchmarks.concurrency"),
    ("table1", "benchmarks.recovery_time"),
    ("fig14", "benchmarks.lazy_recovery"),
    ("durable", "benchmarks.durable_restart"),
    ("fig15", "benchmarks.allocator"),
    ("dht", "benchmarks.dht_roofline"),
    ("dhtpar", "benchmarks.dht_parallel"),
    ("kernel", "benchmarks.kernel_probe"),
    ("batchpar|latency", "benchmarks.batch_parallel"),
    ("smo", "benchmarks.smo"),
    ("resize", "benchmarks.online_resize"),
    ("chaos", "benchmarks.chaos"),
]


def _library_mtime() -> float:
    """Newest source mtime under the repro package — an artifact produced
    before a library change is stale even if the bench module is untouched
    (the acceptance asserts must re-run against the new code)."""
    import repro
    newest = 0.0
    for pkg_dir in repro.__path__:       # namespace package: no __file__
        for root, _, files in os.walk(pkg_dir):
            for f in files:
                if f.endswith(".py"):
                    newest = max(newest,
                                 os.path.getmtime(os.path.join(root, f)))
    return newest


def artifact_fresh(modname: str) -> bool:
    """True iff the module declares an ARTIFACT whose file is newer than
    both the module's own source and the library (re-running would just
    reproduce it)."""
    mod = importlib.import_module(modname)
    artifact = getattr(mod, "ARTIFACT", None)
    if artifact is None or not os.path.exists(artifact):
        return False
    src_mtime = max(os.path.getmtime(mod.__file__), _library_mtime())
    return os.path.getmtime(artifact) >= src_mtime


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated tags (fig7,fig9,...)")
    ap.add_argument("--force", action="store_true",
                    help="re-run benches even when their JSON artifact is fresh")
    ap.add_argument("--list", action="store_true",
                    help="list tags, modules and artifact freshness; run nothing")
    ap.add_argument("--trace", action="store_true",
                    help="capture op-lifecycle spans (obs/trace.py) in benches "
                         "that drive a frontend; writes TRACE_<bench>.json")
    args = ap.parse_args()
    if args.trace:
        os.environ["REPRO_TRACE"] = "1"
    only = set(args.only.split(",")) if args.only else None

    if args.list:
        print("tag,module,artifact,status")
        for tag, modname in MODULES:
            if only and not (set(tag.split("|")) & only):
                continue
            mod = importlib.import_module(modname)
            artifact = getattr(mod, "ARTIFACT", None)
            status = ("fresh" if artifact_fresh(modname) else "stale") \
                if artifact else "-"
            print(f"{tag},{modname},{artifact or '-'},{status}", flush=True)
        return

    print("name,us_per_call,derived")
    failures = []
    for tag, modname in MODULES:
        if only and not (set(tag.split("|")) & only):
            continue
        t0 = time.time()
        try:
            if not args.force and artifact_fresh(modname):
                print(f"# {tag} skipped (artifact fresh; --force to re-run)",
                      flush=True)
                continue
            mod = __import__(modname, fromlist=["run"])
            for row in mod.run():
                print(row.csv(), flush=True)
            print(f"# {tag} done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append((tag, repr(e)))
            print(f"{tag}/FAILED,0,{e!r}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
