"""Segment-parallel engine vs sequential scan engine (the PR's tentpole).

Measures ops/sec for batched inserts (scan vs segment-parallel routing) and
batched search (per-key vmap vs Pallas fingerprint-routed) at batch sizes
256/1k/4k on a pre-grown table (uniform keys -> many segments, which is the
regime the paper's per-segment concurrency argument addresses; a fresh
2-segment table has no parallelism to exploit and the host planner keeps it
on the scan engine).

Small-batch LATENCY rows (p50/p99 per dispatch at batch 64/256) compare the
fused single-dispatch path (kernels/fused.py) against the routed engines and
the per-key baselines — the regime ``DashTable.fused_threshold`` selects
for. Gated: at batch 256 the fused search must not lose to vmap (>= 1.0x at
p50) and the fused insert must beat the scan engine >= 1.5x at p50, with
fused-vs-scan bit-identity asserted before any timing.

Before timing, asserts the write engines produce bit-identical table
state + statuses and the read paths identical results — the bench is
also a differential check. Emits ``BENCH_batch_parallel.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH, engine, hashing
from .common import (Row, enable_compilation_cache, write_artifact,
                     ops_row, time_op, unique_keys)

ARTIFACT = "BENCH_batch_parallel.json"

BATCHES = (256, 1024, 4096)
#: small-batch latency regime (the fused path's home turf)
LAT_BATCHES = (64, 256)
LAT_REPS = 25


def _latencies(fn, reps: int = LAT_REPS, warmup: int = 3) -> np.ndarray:
    """Per-call wall seconds over ``reps`` dispatches (fn must block).
    More warmup than ``time_op``: the latency quantiles are about steady
    state, and the first post-trace calls still page executables in."""
    for _ in range(warmup):
        fn()
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    return np.asarray(out)


def _pctl(lat: np.ndarray) -> dict:
    return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3)}


def _copy_state(state):
    return jax.tree.map(jnp.copy, state)


def _assert_identical(sa, sb, tag):
    for name, a, b in zip(sa._fields, jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert (np.asarray(a) == np.asarray(b)).all(), (tag, name)


def run():
    enable_compilation_cache()
    cfg = DashConfig(max_segments=64, dir_depth_max=9)
    t = DashEH(cfg)
    rng = np.random.default_rng(0xBA7C)
    pool = unique_keys(rng, 40_000)
    warm, fresh = pool[:20_000], pool[20_000:]
    t.insert(warm, np.arange(20_000, dtype=np.uint32))
    base = t.state
    n_segs = len(np.unique(np.asarray(base.dir)))

    rows, report = [], {"segments": n_segs}
    for B in BATCHES:
        keys = fresh[:B]
        hi_np, lo_np = hashing.np_split_keys(keys)
        hi, lo = jnp.asarray(hi_np), jnp.asarray(lo_np)
        vals = jnp.asarray(np.arange(B, dtype=np.uint32))

        # host-side lane capacity through the table's own planner (one copy
        # of the directory mirror + capacity rule)
        seg = t._segments_of(hi_np, lo_np)
        cap = t._lane_quantum(t._max_per_segment(seg))

        # --- differential check before timing (bit-identical engines) ---
        s_scan, st_scan, _ = engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals, batching="scan")
        s_seg, st_seg, _ = engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals,
            batching="segment", capacity=cap)
        assert (np.asarray(st_scan) == np.asarray(st_seg)).all(), B
        _assert_identical(s_scan, s_seg, f"insert@{B}")
        f_v, v_v = engine.search_batch(cfg, "eh", s_scan, hi, lo,
                                       batching="vmap")
        f_p, v_p = engine.search_batch(cfg, "eh", s_scan, hi, lo,
                                       batching="pallas", capacity=cap_pallas(cap))
        assert (np.asarray(f_v) == np.asarray(f_p)).all(), B
        assert (np.asarray(v_v) == np.asarray(v_p)).all(), B

        # --- timings (state copy cost included identically in both) ---
        t_scan = time_op(lambda: jax.block_until_ready(engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals, batching="scan")[0].meta))
        t_seg = time_op(lambda: jax.block_until_ready(engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals,
            batching="segment", capacity=cap)[0].meta))
        t_vmap = time_op(lambda: jax.block_until_ready(engine.search_batch(
            cfg, "eh", base, hi, lo, batching="vmap")[0]))
        t_pall = time_op(lambda: jax.block_until_ready(engine.search_batch(
            cfg, "eh", base, hi, lo, batching="pallas",
            capacity=cap_pallas(cap))[0]))

        report[f"batch_{B}"] = {
            "lane_capacity": cap,
            "insert_scan_ops_per_s": B / t_scan,
            "insert_segment_ops_per_s": B / t_seg,
            "insert_speedup": t_scan / t_seg,
            "search_vmap_ops_per_s": B / t_vmap,
            "search_pallas_ops_per_s": B / t_pall,
            "search_speedup": t_vmap / t_pall,
        }
        rows += [
            ops_row(f"batchpar/insert_scan@{B}", t_scan, B),
            ops_row(f"batchpar/insert_segment@{B}", t_seg, B,
                    extra=f"cap={cap}; {t_scan / t_seg:.2f}x vs scan"),
            ops_row(f"batchpar/search_vmap@{B}", t_vmap, B),
            ops_row(f"batchpar/search_pallas@{B}", t_pall, B,
                    extra=f"{t_vmap / t_pall:.2f}x vs vmap"),
        ]

    # ----- small-batch latency: fused vs routed vs per-key baselines -----
    for B in LAT_BATCHES:
        keys = fresh[:B]
        hi_np, lo_np = hashing.np_split_keys(keys)
        hi, lo = jnp.asarray(hi_np), jnp.asarray(lo_np)
        vals = jnp.asarray(np.arange(B, dtype=np.uint32))
        seg = t._segments_of(hi_np, lo_np)
        cap = t._lane_quantum(t._max_per_segment(seg))

        # bit-identity BEFORE timing: the fused mega-dispatch must agree
        # with the scan engine (writes) and the vmap path (reads) exactly
        s_scan, st_scan, _ = engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals, batching="scan")
        s_fus, st_fus, _ = engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals,
            batching="fused", capacity=cap)
        assert (np.asarray(st_scan) == np.asarray(st_fus)).all(), B
        _assert_identical(s_scan, s_fus, f"fused_insert@{B}")
        f_v, v_v = engine.search_batch(cfg, "eh", s_scan, hi, lo,
                                       batching="vmap")
        f_f, v_f = engine.search_batch(cfg, "eh", s_scan, hi, lo,
                                       batching="fused")
        assert (np.asarray(f_v) == np.asarray(f_f)).all(), B
        assert (np.asarray(v_v) == np.asarray(v_f)).all(), B

        lat_ins = {
            "fused": _latencies(lambda: jax.block_until_ready(
                engine.insert_batch(cfg, "eh", _copy_state(base), hi, lo,
                                    vals, batching="fused",
                                    capacity=cap)[0].meta)),
            "routed": _latencies(lambda: jax.block_until_ready(
                engine.insert_batch(cfg, "eh", _copy_state(base), hi, lo,
                                    vals, batching="segment",
                                    capacity=cap)[0].meta)),
            "scan": _latencies(lambda: jax.block_until_ready(
                engine.insert_batch(cfg, "eh", _copy_state(base), hi, lo,
                                    vals, batching="scan")[0].meta)),
        }
        lat_sea = {
            "fused": _latencies(lambda: jax.block_until_ready(
                engine.search_batch(cfg, "eh", base, hi, lo,
                                    batching="fused")[0])),
            "routed": _latencies(lambda: jax.block_until_ready(
                engine.search_batch(cfg, "eh", base, hi, lo,
                                    batching="pallas",
                                    capacity=cap_pallas(cap))[0])),
            "vmap": _latencies(lambda: jax.block_until_ready(
                engine.search_batch(cfg, "eh", base, hi, lo,
                                    batching="vmap")[0])),
        }
        ins_x = float(np.percentile(lat_ins["scan"], 50)
                      / np.percentile(lat_ins["fused"], 50))
        sea_x = float(np.percentile(lat_sea["vmap"], 50)
                      / np.percentile(lat_sea["fused"], 50))
        report[f"latency_{B}"] = {
            "lane_capacity": cap,
            "insert": {k: _pctl(v) for k, v in lat_ins.items()},
            "search": {k: _pctl(v) for k, v in lat_sea.items()},
            "insert_fused_vs_scan_p50": ins_x,
            "search_fused_vs_vmap_p50": sea_x,
        }
        for op, lats in (("insert", lat_ins), ("search", lat_sea)):
            for path, lat in lats.items():
                q = _pctl(lat)
                rows.append(Row(
                    f"batchpar/latency_{op}_{path}@{B}",
                    q["p50_ms"] * 1e3,
                    f"p50={q['p50_ms']:.3f}ms p99={q['p99_ms']:.3f}ms"))
        if B == 256:
            # acceptance gates: the fused path must pay for itself exactly
            # where the threshold routes to it
            assert sea_x >= 1.0, \
                f"fused search {sea_x:.2f}x vmap at 256 (gate >= 1.0)"
            assert ins_x >= 1.5, \
                f"fused insert {ins_x:.2f}x scan at 256 (gate >= 1.5)"

    write_artifact(ARTIFACT, report)
    return rows


def cap_pallas(cap: int) -> int:
    """Pallas routing capacity: same per-segment bound, BQ-aligned (the
    kernel asserts C % 128 == 0; lane quanta like 192 are not)."""
    return -(-max(128, cap) // 128) * 128


if __name__ == "__main__":
    for r in run():
        print(r.csv())
