"""Segment-parallel engine vs sequential scan engine (the PR's tentpole).

Measures ops/sec for batched inserts (scan vs segment-parallel routing) and
batched search (per-key vmap vs Pallas fingerprint-routed) at batch sizes
256/1k/4k on a pre-grown table (uniform keys -> many segments, which is the
regime the paper's per-segment concurrency argument addresses; a fresh
2-segment table has no parallelism to exploit and the host planner keeps it
on the scan engine).

Before timing, asserts the two write engines produce bit-identical table
state + statuses and the two read paths identical results — the bench is
also a differential check. Emits ``BENCH_batch_parallel.json``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH, engine, hashing
from .common import (Row, enable_compilation_cache, write_artifact,
                     ops_row, time_op, unique_keys)

ARTIFACT = "BENCH_batch_parallel.json"

BATCHES = (256, 1024, 4096)


def _copy_state(state):
    return jax.tree.map(jnp.copy, state)


def _assert_identical(sa, sb, tag):
    for name, a, b in zip(sa._fields, jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert (np.asarray(a) == np.asarray(b)).all(), (tag, name)


def run():
    enable_compilation_cache()
    cfg = DashConfig(max_segments=64, dir_depth_max=9)
    t = DashEH(cfg)
    rng = np.random.default_rng(0xBA7C)
    pool = unique_keys(rng, 40_000)
    warm, fresh = pool[:20_000], pool[20_000:]
    t.insert(warm, np.arange(20_000, dtype=np.uint32))
    base = t.state
    n_segs = len(np.unique(np.asarray(base.dir)))

    rows, report = [], {"segments": n_segs}
    for B in BATCHES:
        keys = fresh[:B]
        hi_np, lo_np = hashing.np_split_keys(keys)
        hi, lo = jnp.asarray(hi_np), jnp.asarray(lo_np)
        vals = jnp.asarray(np.arange(B, dtype=np.uint32))

        # host-side lane capacity through the table's own planner (one copy
        # of the directory mirror + capacity rule)
        seg = t._segments_of(hi_np, lo_np)
        cap = t._lane_quantum(t._max_per_segment(seg))

        # --- differential check before timing (bit-identical engines) ---
        s_scan, st_scan, _ = engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals, batching="scan")
        s_seg, st_seg, _ = engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals,
            batching="segment", capacity=cap)
        assert (np.asarray(st_scan) == np.asarray(st_seg)).all(), B
        _assert_identical(s_scan, s_seg, f"insert@{B}")
        f_v, v_v = engine.search_batch(cfg, "eh", s_scan, hi, lo,
                                       batching="vmap")
        f_p, v_p = engine.search_batch(cfg, "eh", s_scan, hi, lo,
                                       batching="pallas", capacity=cap_pallas(cap))
        assert (np.asarray(f_v) == np.asarray(f_p)).all(), B
        assert (np.asarray(v_v) == np.asarray(v_p)).all(), B

        # --- timings (state copy cost included identically in both) ---
        t_scan = time_op(lambda: jax.block_until_ready(engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals, batching="scan")[0].meta))
        t_seg = time_op(lambda: jax.block_until_ready(engine.insert_batch(
            cfg, "eh", _copy_state(base), hi, lo, vals,
            batching="segment", capacity=cap)[0].meta))
        t_vmap = time_op(lambda: jax.block_until_ready(engine.search_batch(
            cfg, "eh", base, hi, lo, batching="vmap")[0]))
        t_pall = time_op(lambda: jax.block_until_ready(engine.search_batch(
            cfg, "eh", base, hi, lo, batching="pallas",
            capacity=cap_pallas(cap))[0]))

        report[f"batch_{B}"] = {
            "lane_capacity": cap,
            "insert_scan_ops_per_s": B / t_scan,
            "insert_segment_ops_per_s": B / t_seg,
            "insert_speedup": t_scan / t_seg,
            "search_vmap_ops_per_s": B / t_vmap,
            "search_pallas_ops_per_s": B / t_pall,
            "search_speedup": t_vmap / t_pall,
        }
        rows += [
            ops_row(f"batchpar/insert_scan@{B}", t_scan, B),
            ops_row(f"batchpar/insert_segment@{B}", t_seg, B,
                    extra=f"cap={cap}; {t_scan / t_seg:.2f}x vs scan"),
            ops_row(f"batchpar/search_vmap@{B}", t_vmap, B),
            ops_row(f"batchpar/search_pallas@{B}", t_pall, B,
                    extra=f"{t_vmap / t_pall:.2f}x vs vmap"),
        ]

    write_artifact(ARTIFACT, report)
    return rows


def cap_pallas(cap: int) -> int:
    """Pallas routing capacity: same per-segment bound, BQ-aligned (the
    kernel asserts C % 128 == 0; lane quanta like 192 are not)."""
    return -(-max(128, cap) // 128) * 128


if __name__ == "__main__":
    for r in run():
        print(r.csv())
