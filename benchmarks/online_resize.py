"""Online resize: read latency during a fill-driven split storm.

The ISSUE-3 acceptance scenario: a stream of insert bursts (fresh keys,
sized to drive bulk splits) interleaved with read bursts (zipfian over the
loaded keys) is served twice —

  * ``baseline``  — ``StopTheWorldFrontend``: one FIFO, writes run the
    inline ``DashTable.insert`` retry loop (split storms complete inside
    the write batch), reads behind a storm wait it out.
  * ``frontend``  — ``DashFrontend``: reads pin the epoch-published
    snapshot and are served between the staged SMO dispatches; only
    version-changed queries pay a live retry.

Reported: p50/p99 read sojourn latency (enqueue -> completion), offered
throughput, split/SMO counters, and the copy-on-write publish volume
(published bytes per write batch + publish wall time, vs the whole-state
copy the pre-COW frontend paid per publish). Acceptance gates, asserted
before the JSON artifact is written, at equal offered load and with
identical split count + final logical state:

  * frontend p99 read sojourn <= 0.5x the stop-the-world baseline;
  * COW publish volume <= 0.25x the whole-state-copy volume.

Emits ``BENCH_online_resize.json``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DashConfig, DashEH, layout
from repro.serving.frontend import (INSERT, READ, DashFrontend, Op,
                                    StopTheWorldFrontend)
from repro.workloads import ycsb
from .common import (Row, enable_compilation_cache, export_trace,
                     histogram_rows, write_artifact)

ARTIFACT = "BENCH_online_resize.json"

CFG = DashConfig(max_segments=64, dir_depth_max=9)
N_LOAD = 16_384          # pre-loaded key space the reads draw from
N_FRESH = 16_384         # fresh keys driving the storm
BATCH = 256              # admission batch size (both systems)
READS_PER_ROUND = 3      # read bursts per insert burst


def _stream(loaded: np.ndarray, fresh: np.ndarray, rng: np.random.Generator):
    """Rounds of one insert burst + READS_PER_ROUND read bursts (zipfian
    over the loaded space) — the arrival pattern both systems serve."""
    ranks = ycsb.zipfian_ranks(
        rng, loaded.size, (fresh.size // BATCH) * READS_PER_ROUND * BATCH)
    r = 0
    for i in range(0, fresh.size, BATCH):
        chunk = [Op(INSERT, int(k), ycsb.expected_value(int(k)))
                 for k in fresh[i:i + BATCH]]
        for _ in range(READS_PER_ROUND):
            chunk += [Op(READ, int(loaded[j]))
                      for j in ranks[r:r + BATCH]]
            r += BATCH
        yield chunk


def _drive(fe, loaded, fresh, rng):
    """Serve the stream chunk-by-chunk (closed loop: each round's ops are
    admitted together, the system drains before the next arrives — reads of
    a round race exactly that round's storm). Returns wall seconds."""
    t0 = time.perf_counter()
    n_ops = 0
    for chunk in _stream(loaded, fresh, rng):
        for op in chunk:
            assert fe.submit(op)
        n_ops += len(chunk)
        fe.drain()
    return time.perf_counter() - t0, n_ops


def _lat_stats(lat_s):
    lat = np.asarray(lat_s) * 1e6
    return {"p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
            "mean_us": float(lat.mean()), "n": int(lat.size)}


def run():
    enable_compilation_cache()
    rng = np.random.default_rng(0x0E51)
    space = ycsb.load_keys(rng, N_LOAD + N_FRESH)
    loaded, fresh = space[:N_LOAD], space[N_LOAD:]
    load_vals = np.asarray([ycsb.expected_value(int(k)) for k in loaded],
                           dtype=np.uint32)

    # --- warmup: compile every trace both paths use, at the measured table
    # scale (the retry-loop capacity traces depend on the directory size, so
    # a small warmup table would leave the first measured run paying jit)
    warm_keys = ycsb.load_keys(np.random.default_rng(1), 4096)
    for cls in (StopTheWorldFrontend, DashFrontend):
        t = DashEH(CFG)
        t.insert(loaded, load_vals)
        fe = cls(t, max_batch=BATCH, queue_depth=1 << 16)
        _drive(fe, loaded, warm_keys, np.random.default_rng(2))

    report = {"config": {"n_load": N_LOAD, "n_fresh": N_FRESH,
                         "batch": BATCH, "reads_per_round": READS_PER_ROUND,
                         "max_segments": CFG.max_segments}}
    rows = []
    tables = {}
    for tag, cls in (("baseline", StopTheWorldFrontend),
                     ("frontend", DashFrontend)):
        t = DashEH(CFG)
        t.insert(loaded, load_vals)
        fe = cls(t, max_batch=BATCH, queue_depth=1 << 16)
        wall, n_ops = _drive(fe, loaded, fresh, np.random.default_rng(3))
        stats = _lat_stats(fe.read_latencies)
        stats["write_p99_us"] = _lat_stats(fe.write_latencies)["p99_us"]
        stats["wall_s"] = wall
        stats["ops_per_s"] = n_ops / wall
        stats["splits"] = int(np.asarray(t.state.n_splits))
        if tag == "frontend":
            stats["snapshot_reads"] = fe.snapshot_reads
            stats["retried_reads"] = fe.retried_reads
            stats["smo_stages"] = fe.smo_stages
            stats["published_versions"] = fe.registry.published
            stats["reclaimed_versions"] = fe.registry.reclaimed
            # COW publish accounting (frontend.stats() is the one surface)
            fes = fe.stats()
            pub = max(fes["published"], 1)
            stats["publish_bytes"] = fes["publish_bytes"]
            stats["publish_bytes_per_batch"] = fes["publish_bytes"] / pub
            stats["publish_wall_s"] = fes["publish_seconds"]
            stats["planes_copied"] = fes["planes_copied"]
            stats["planes_aliased"] = fes["planes_aliased"]
            stats["hint_misses"] = fes["hint_misses"]
            # the counterfactual: what the pre-COW whole-state copy would
            # have moved for the same publish cadence at equal offered load
            whole = layout.state_nbytes(t.state)
            stats["whole_copy_bytes_per_batch"] = whole
            stats["publish_volume_ratio"] = (
                fes["publish_bytes"] / (pub * whole))
            # obs histogram rows (ISSUE-8): the registry's log-bucketed
            # sojourn histograms must agree with the exact-sample
            # percentiles above within 10% — the bucket geometry bounds
            # the error at ±2.2%, so a miss means the frontend stopped
            # feeding the histogram the same samples it keeps in
            # read_latencies
            h = fe.obs.registry.get("frontend.read_sojourn_s").snapshot()
            stats["read_sojourn_hist"] = {
                "n": h["n"], "p50_us": h["p50"] * 1e6,
                "p90_us": h["p90"] * 1e6, "p99_us": h["p99"] * 1e6,
                "max_us": h["max"] * 1e6}
            assert h["n"] == stats["n"], (h["n"], stats["n"])
            for q in ("p50", "p99"):
                exact = stats[f"{q}_us"]
                approx = h[q] * 1e6
                err = abs(approx - exact) / exact
                assert err <= 0.10, \
                    f"hist {q} {approx:.1f}us vs exact {exact:.1f}us " \
                    f"({err:.1%} > 10%)"
            report["histograms"] = histogram_rows(fe.obs, "frontend.")
            report["slo"] = fe.obs.slo.snapshot()
            tp = export_trace(fe.obs, "online_resize")
            if tp:
                stats["trace_path"] = tp
                stats["trace"] = fe.obs.tracer.stats()
        report[tag] = stats
        tables[tag] = t
        rows.append(Row(f"online_resize/{tag}_read", stats["p50_us"],
                        f"p99={stats['p99_us']:.0f}us "
                        f"{stats['ops_per_s']:.0f} ops/s"))

    # identical final logical state (same keys landed in both tables) and
    # identical structural work — asserted before any gate is quoted
    assert tables["baseline"].n_items == tables["frontend"].n_items
    assert report["baseline"]["splits"] == report["frontend"]["splits"], \
        (report["baseline"]["splits"], report["frontend"]["splits"])
    f_b, _ = tables["baseline"].search(space)
    f_f, _ = tables["frontend"].search(space)
    assert np.asarray(f_b).all() and np.asarray(f_f).all()

    ratio = report["frontend"]["p99_us"] / report["baseline"]["p99_us"]
    thr = report["frontend"]["ops_per_s"] / report["baseline"]["ops_per_s"]
    report["p99_ratio"] = ratio
    report["throughput_ratio"] = thr
    # acceptance gate 1: overlapping reads with the storm at equal offered
    # load must at least halve tail read latency
    assert ratio <= 0.5, f"p99 ratio {ratio:.3f} > 0.5"
    rows.append(Row("online_resize/p99_ratio", ratio,
                    f"frontend/baseline p99; throughput x{thr:.2f}"))
    # acceptance gate 2: COW publish volume is O(dirty segments) — <= 0.25x
    # the whole-state copy the pre-COW publish cadence would have moved
    vratio = report["frontend"]["publish_volume_ratio"]
    assert vratio <= 0.25, f"publish volume ratio {vratio:.3f} > 0.25"
    assert report["frontend"]["hint_misses"] == 0
    rows.append(Row("online_resize/publish_volume_ratio", vratio,
                    f"{report['frontend']['publish_bytes_per_batch']:.0f}B/"
                    f"batch vs {report['frontend']['whole_copy_bytes_per_batch']}B"
                    " whole-copy"))

    write_artifact(ARTIFACT, report)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
