"""Roofline report: three terms per (arch x shape x mesh) from the dry-run.

    compute term    = corrected HLO dot FLOPs / peak FLOP/s      (per chip)
    memory term     = HBM bytes per step / HBM bandwidth         (per chip)
    collective term = collective wire bytes / ICI link bandwidth (per chip)

Sources: ``dot_flops_per_device`` and ``collective_wire_bytes`` come from the
compiled dry-run artifact (launch/hlo_analysis.py corrects lax.scan bodies by
their trip counts — raw cost_analysis counts them once). HBM bytes use the
standard closed forms over the same compiled shardings:

  train:   3 passes over resident params (fwd read, bwd read, optimizer RW)
           + 2 x saved-activation bytes (write + read across fwd/bwd)
  prefill: 1 x params + activation writes
  decode:  1 x params + full KV-cache read + O(1) write   (classic decode
           roofline: cache streaming dominates)

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/ICI link.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_SEQ = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
        "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def _model_flops_per_device(arch: str, shape: str, n_dev: int) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D (+ attention term) per device."""
    from repro.configs import get_config
    cfg = get_config(arch)
    S, B = _SEQ[shape]
    n_active = cfg.active_param_count()
    if shape.startswith("train"):
        tokens = S * B
        flops = 6.0 * n_active * tokens
        # attention: fwd 4*S_eff*d per token, x3 for bwd
        w = cfg.sliding_window or S
        kinds = list(cfg.pattern) * cfg.n_blocks + list(cfg.tail)
        for k in kinds:
            s_eff = min(cfg.local_window if k == "local" else w, S) / 2
            if k == "rwkv":
                flops += 12.0 * tokens * 64 * cfg.d_model      # chunked WKV
            elif k == "rglru":
                flops += 40.0 * tokens * (cfg.d_rnn or cfg.d_model)
            else:
                flops += 12.0 * tokens * s_eff * cfg.n_heads * cfg.hd
        return flops / n_dev
    if shape.startswith("prefill"):
        tokens = S * B
        flops = 2.0 * n_active * tokens
        w = cfg.sliding_window or S
        kinds = list(cfg.pattern) * cfg.n_blocks + list(cfg.tail)
        for k in kinds:
            s_eff = min(cfg.local_window if k == "local" else w, S) / 2
            if k == "rwkv":
                flops += 4.0 * tokens * 64 * cfg.d_model
            elif k == "rglru":
                flops += 14.0 * tokens * (cfg.d_rnn or cfg.d_model)
            else:
                flops += 4.0 * tokens * s_eff * cfg.n_heads * cfg.hd
        return flops / n_dev
    # decode: one token per sequence
    flops = 2.0 * n_active * B
    return flops / n_dev


def _memory_bytes_per_device(rec: dict) -> float:
    """Closed-form HBM traffic per step per chip (see module docstring)."""
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    S, B = _SEQ[rec["shape"]]
    static = rec["static_bytes_per_device"]
    if rec["shape"].startswith("train"):
        params = static / 3.0              # params + m + v were counted
        act_bytes = _activation_bytes(cfg, S, B, rec["n_devices"])
        return 3.0 * params + 4.0 * params + 2.0 * act_bytes  # opt RW = 4x
    if rec["shape"].startswith("prefill"):
        return static + _activation_bytes(cfg, S, B, rec["n_devices"])
    # decode: params once + cache streamed once (+small writes)
    return static * 1.02


def _activation_bytes(cfg, S, B, n_dev) -> float:
    """Saved activations under the layer scan (bf16 carry per layer)."""
    layers = cfg.n_layers
    return 2.0 * B * S * cfg.d_model * layers / n_dev


def load_records(mesh: str = "pod", root: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(f"{root}/{mesh}/*.json")):
        r = json.load(open(f))
        if r["status"] != "ok":
            recs.append(r)
            continue
        n = r["n_devices"]
        compute_t = r["dot_flops_per_device"] / PEAK_FLOPS
        mem_t = _memory_bytes_per_device(r) / HBM_BW
        coll_bytes = sum(r["collective_wire_bytes"].values())
        coll_t = coll_bytes / ICI_BW
        terms = {"compute": compute_t, "memory": mem_t, "collective": coll_t}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        model_fl = _model_flops_per_device(r["arch"], r["shape"], n)
        r.update({
            "compute_s": compute_t, "memory_s": mem_t, "collective_s": coll_t,
            "dominant": dom,
            "roofline_fraction": compute_t / bound if bound else 0.0,
            "model_flops_per_device": model_fl,
            "useful_compute_ratio": (model_fl / r["dot_flops_per_device"]
                                     if r["dot_flops_per_device"] else 0.0),
        })
        recs.append(r)
    return recs


def render(recs, md=False):
    hdr = ("arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "roofline_frac", "useful_ratio")
    lines = []
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(",".join(hdr))
    for r in recs:
        if r["status"] == "skipped":
            row = (r["arch"], r["shape"], "-", "-", "-", "skipped(full-attn)",
                   "-", "-")
        else:
            row = (r["arch"], r["shape"], f"{r['compute_s']:.4f}",
                   f"{r['memory_s']:.4f}", f"{r['collective_s']:.4f}",
                   r["dominant"], f"{r['roofline_fraction']:.3f}",
                   f"{r['useful_compute_ratio']:.2f}")
        lines.append(("| " + " | ".join(row) + " |") if md else ",".join(row))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.mesh)
    print(render(recs, args.md))
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        most_coll = max(ok, key=lambda r: r["collective_s"])
        print(f"\n# worst roofline fraction: {worst['arch']} x {worst['shape']}"
              f" ({worst['roofline_fraction']:.3f})")
        print(f"# most collective-bound: {most_coll['arch']} x "
              f"{most_coll['shape']} ({most_coll['collective_s']:.3f}s)")


if __name__ == "__main__":
    main()
