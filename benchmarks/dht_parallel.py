"""Device-resident DHT hot path vs the host-mirror baseline (ISSUE-9 gate).

All measurements run in subprocesses with 8 fake CPU devices (the real
shard_map path, like tests/test_distributed.py). Four sections:

  * **verify storm** — the identical insert+read op schedule is served by
    ``ShardFrontend(verify_mode="device")`` (one-dispatch snapshot probe +
    in-program version verify + device-resident insert rounds) and by
    ``verify_mode="host"`` (host-mirrored plane diff per read batch,
    O(batch) statuses pulled per insert round). Final stacked states are
    asserted BIT-IDENTICAL before any number is quoted. Gates: device read
    p99 <= 0.5x host, device ``host_plane_bytes`` == 0 (the PR 8 counter
    meters every plane byte the host-mirror verify copies).
  * **bulk splits** — ``split_for`` (plan + phase1 + phase2 inside one
    shard_map dispatch) vs the retained per-shard host loop
    (``_split_for_host``: host sub-state rebuild per shard) from identical
    states, identical resulting states asserted. Gate: >= 2x.
  * **lazy reopen** — 8-shard write, ``os._exit`` kill, then
    ``persist.reopen_shards()`` (lazy default) + first query, timed
    end-to-end against a clean-close reopen; eager recovery reported as
    contrast. Gate: dirty time-to-first-query <= 1.5x clean.
  * **per-shard histograms** — the device frontend's per-shard
    read-sojourn registries (``Registry.aggregate`` fleet view) are
    cross-checked against the exact sample percentiles within 10%, like
    ``online_resize`` does for its frontend histogram.

Emits ``BENCH_dht_parallel.json`` (gated in scripts/check_bench.py).
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

import numpy as np

from .common import Row, write_artifact

ARTIFACT = "BENCH_dht_parallel.json"

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "JAX_PLATFORMS": "cpu", "PYTHONPATH": "src"}

CFG_KW = dict(max_segments=256, dir_depth_max=12, init_depth=1,
              num_buckets=16, num_slots=8)
BATCH = 256
N_LOAD = 8192
N_FRESH = 8192
# 3 read batches per round keeps the sojourn distribution's p50 strictly
# inside a mode: with 2, exactly half the reads land in the fast first
# batch and the median sits ON the mode boundary, where the histogram's
# inverted-CDF quantile and np.percentile's interpolation legitimately
# diverge by >10%
READS_PER_ROUND = 3

POOL_CFG_KW = dict(max_segments=32, dir_depth_max=8)
POOL_N = 3000
FIRST_QUERY = 64


def _sub(fn: str, *args, timeout=1800) -> dict:
    code = (f"from benchmarks.dht_parallel import {fn}; "
            f"{fn}({', '.join(map(repr, args))})")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=ENV, timeout=timeout)
    assert r.returncode == 0, f"{fn} failed:\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}"
    for ln in r.stdout.splitlines():
        if ln.startswith("RESULT "):
            return json.loads(ln[len("RESULT "):])
    raise AssertionError(f"{fn}: no RESULT line\n{r.stdout}\n{r.stderr}")


# ---------------------------------------------------------------------------
# worker: verify storm + bulk-split micro + per-shard histograms
# ---------------------------------------------------------------------------

def _storm_main():
    import time
    import jax
    import jax.numpy as jnp
    from repro.core import DashConfig, layout
    from repro.distributed import DistributedDash, ShardFrontend
    from repro.launch.mesh import make_test_mesh
    from repro.serving.frontend import INSERT, READ, Op
    from repro.workloads import ycsb

    cfg = DashConfig(**CFG_KW)
    mesh = make_test_mesh(2, 4)
    rng = np.random.default_rng(0xD47)
    space = np.unique(rng.integers(1, 2**63, 80000, dtype=np.uint64))
    loaded, fresh = space[:N_LOAD], space[N_LOAD:N_LOAD + N_FRESH]
    warm = space[N_LOAD + N_FRESH:N_LOAD + N_FRESH + 2 * BATCH]
    lvals = np.asarray([ycsb.expected_value(int(k)) for k in loaded],
                       np.uint32)

    def stream(keys_in, rng2):
        ranks = ycsb.zipfian_ranks(
            rng2, loaded.size,
            max(1, keys_in.size // BATCH) * READS_PER_ROUND * BATCH)
        r = 0
        for i in range(0, keys_in.size, BATCH):
            chunk = [Op(INSERT, int(k), ycsb.expected_value(int(k)))
                     for k in keys_in[i:i + BATCH]]
            for _ in range(READS_PER_ROUND):
                chunk += [Op(READ, int(loaded[j])) for j in ranks[r:r + BATCH]]
                r += BATCH
            yield chunk

    def drive(fe, keys_in, seed):
        t0 = time.perf_counter()
        n_ops = 0
        for chunk in stream(keys_in, np.random.default_rng(seed)):
            for op in chunk:
                assert fe.submit(op)
            n_ops += len(chunk)
            fe.drain()
        return time.perf_counter() - t0, n_ops

    def lat_stats(lat_s):
        lat = np.asarray(lat_s) * 1e6
        return {"p50_us": float(np.percentile(lat, 50)),
                "p90_us": float(np.percentile(lat, 90)),
                "p99_us": float(np.percentile(lat, 99)),
                "max_us": float(lat.max()),
                "mean_us": float(lat.mean()), "n": int(lat.size)}

    report = {"config": {**CFG_KW, "batch": BATCH, "n_load": N_LOAD,
                         "n_fresh": N_FRESH,
                         "reads_per_round": READS_PER_ROUND}}
    finals, fes = {}, {}
    for tag in ("device", "host"):
        d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
        d.insert(loaded, lvals)
        # warm through a THROWAWAY frontend: the jitted tick programs live
        # on the shared DistributedDash, but the warm-up sojourns (which
        # embed multi-second compile stalls) stay out of the measured
        # frontend's latency samples and per-shard histograms
        warm_fe = ShardFrontend(d, max_batch=BATCH, queue_depth=1 << 16,
                                verify_mode=tag)
        drive(warm_fe, warm, 2)
        # pre-warm this mode's split program with a BATCH-sized key set —
        # in-storm splits take the insert batch's (n_shards, q_local) query
        # shape, so a smaller warm set would leave the storm's first split
        # dispatch to compile inside the measured window — then put the
        # state back
        base = jax.tree.map(jnp.copy, d.state)
        if tag == "device":
            d.split_for(space[20000:20000 + BATCH])
        else:
            d._split_for_host(space[20000:20000 + BATCH])
        d.state = base
        fe = ShardFrontend(d, max_batch=BATCH, queue_depth=1 << 16,
                           verify_mode=tag)
        # settle: a duplicate-key insert (EXISTS — no state change) makes
        # the fresh frontend pay its one-time COW-baseline publish before
        # the clock starts; steady-state is what the gate is about
        assert fe.submit(Op(INSERT, int(warm[0]),
                            ycsb.expected_value(int(warm[0]))))
        fe.drain()
        # a single gen-2 GC pause (~0.5s against ~0.1s device ticks) would
        # own the p99 of whichever mode it lands in: collect now, then keep
        # the collector out of the measured window (both modes identically)
        import gc
        gc.collect()
        gc.disable()
        try:
            wall, n_ops = drive(fe, fresh, 3)     # measured storm
        finally:
            gc.enable()
        stats = lat_stats(fe.read_latencies)
        stats["wall_s"] = wall
        stats["ops_per_s"] = n_ops / wall
        stats["host_plane_bytes"] = int(fe._host_plane_bytes.value)
        stats["retried_reads"] = fe.retried_reads
        stats["snapshot_reads"] = fe.snapshot_reads
        report[tag] = stats
        finals[tag] = d.state
        fes[tag] = fe

    # identical final state, bit-for-bit, before any gate is quoted: the
    # device retry loop + device splits must land exactly where the
    # host-sync baseline lands (same routing, same round structure)
    for name in type(finals["device"])._fields:
        a = np.asarray(getattr(finals["device"], name))
        b = np.asarray(getattr(finals["host"], name))
        assert np.array_equal(a, b), f"final state diverged on plane {name}"
    report["states_identical"] = True
    d = fes["device"].dht
    meta = np.asarray(d.state.meta)
    recount = int(((meta >> layout.COUNT_SHIFT) & 0xF).sum())
    assert d.n_items == recount == N_LOAD + N_FRESH + warm.size, \
        (d.n_items, recount)

    report["p99_ratio"] = (report["device"]["p99_us"]
                           / report["host"]["p99_us"])
    assert report["device"]["host_plane_bytes"] == 0, \
        "device read tick copied plane bytes to host"
    assert report["host"]["host_plane_bytes"] > 0, \
        "host baseline never exercised the mirror verify"

    # per-shard read-sojourn histograms (device mode): the aggregate of the
    # per-shard registries must agree with the exact samples within 10%
    # (log-bucket geometry bounds the error at ~2.2%)
    from repro.obs import Registry
    regs = fes["device"].shard_registries()
    agg = Registry.aggregate(regs).get("shard.read_sojourn_s").snapshot()
    exact = report["device"]
    assert agg["n"] == exact["n"], (agg["n"], exact["n"])
    hist_agree = {"n": agg["n"]}
    for q in ("p50", "p99"):
        err = abs(agg[q] * 1e6 - exact[f"{q}_us"]) / exact[f"{q}_us"]
        hist_agree[f"{q}_err"] = err
        assert err <= 0.10, \
            f"shard hist {q} {agg[q]*1e6:.1f}us vs {exact[f'{q}_us']:.1f}us"
    report["hist_agree"] = hist_agree
    report["shard_hist"] = {
        "aggregate": {k: (v * 1e6 if k.startswith(("p", "m", "s")) else v)
                      for k, v in agg.items()},
        "per_shard_n": [r.get("shard.read_sojourn_s").snapshot()["n"]
                        for r in regs]}

    # ---- bulk-split micro: one device dispatch vs the per-shard host loop
    d2 = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256)
    d2.insert(space[30000:36000],
              (np.arange(6000) % 1000 + 1).astype(np.uint32))
    base = jax.tree.map(jnp.copy, d2.state)

    # probe keys touching <= split_lanes distinct segments per shard so the
    # capped device plan and the host loop split the exact same set
    from repro.core import hashing
    from repro.distributed.dht import np_owner_of
    cand = space[36000:44000]
    hi, lo = hashing.np_split_keys(cand)
    h1 = hashing.np_hash1(hi, lo)
    owner = np_owner_of(cand, d2.n_shards)
    dirs = np.asarray(base.dir)
    seg_of = dirs[owner, (h1 >> np.uint32(32 - cfg.dir_depth_max)).astype(
        np.int64)]
    keep = np.zeros(cand.size, bool)
    for s in range(d2.n_shards):
        m = owner == s
        segs = np.unique(seg_of[m])[:6]       # <= split_lanes per shard
        keep |= m & np.isin(seg_of, segs)
    probe = cand[keep]
    n_split = int(sum(np.unique(seg_of[keep & (owner == s)]).size
                      for s in range(d2.n_shards)))

    d2.state = jax.tree.map(jnp.copy, base)
    d2.split_for(probe)
    st_dev = d2.state
    d2.state = jax.tree.map(jnp.copy, base)
    d2._split_for_host(probe)
    for name in type(st_dev)._fields:
        assert np.array_equal(np.asarray(getattr(st_dev, name)),
                              np.asarray(getattr(d2.state, name))), \
            f"split paths diverged on plane {name}"

    def time_split(fn, reps=5):
        ts = []
        for _ in range(reps):
            d2.state = jax.tree.map(jnp.copy, base)
            t0 = time.perf_counter()
            fn(probe)
            jax.block_until_ready(d2.state)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    dev_s = time_split(d2.split_for)
    host_s = time_split(d2._split_for_host)
    report["splits"] = {"device_s": dev_s, "host_s": host_s,
                        "speedup": host_s / dev_s, "n_segments": n_split,
                        "identical_states": True}
    print("RESULT " + json.dumps(report))


# ---------------------------------------------------------------------------
# workers: durable reopen time-to-first-query
# ---------------------------------------------------------------------------

def _writer_main(dirpath: str, clean: bool):
    from repro import persist
    from repro.core import DashConfig
    from repro.distributed import DistributedDash
    from repro.launch.mesh import make_test_mesh
    cfg = DashConfig(**POOL_CFG_KW)
    d = DistributedDash(cfg, make_test_mesh(2, 4), axes=("data", "model"),
                        capacity=256)
    d.attach_pools(persist.create_shard_pools(dirpath, cfg, d.n_shards))
    rng = np.random.default_rng(5)
    keys = np.unique(rng.integers(1, 2**63, 8000, dtype=np.uint64))[:POOL_N]
    st = d.insert(keys, np.arange(POOL_N, dtype=np.uint32) % 1000 + 1)
    assert (st == 0).all()
    d.flush_pools()
    if clean:
        d.close_pools()
    print("RESULT " + json.dumps({"written": POOL_N}))
    sys.stdout.flush()
    os._exit(0)       # the kill: dirty dirs never see a clean close


def _reader_main(dirpath: str, eager: bool):
    import time
    from repro import persist
    from repro.core import DashConfig, layout, recovery
    from repro.distributed import DistributedDash
    from repro.launch.mesh import make_test_mesh
    cfg = DashConfig(**POOL_CFG_KW)
    mesh = make_test_mesh(2, 4)
    rng = np.random.default_rng(5)
    keys = np.unique(rng.integers(1, 2**63, 8000, dtype=np.uint64))[:POOL_N]
    # warm the recovery jit cache on a throwaway state with the same plane
    # shapes BEFORE the clock: only the lazy/eager readers run recovery, so
    # its one-time compile would otherwise masquerade as per-segment
    # recovery work in the ttfq ratio (the gated claim is about the
    # data-proportional part)
    recovery.recover_segment_host(cfg, "eh", layout.make_state(cfg, "eh"), 0)
    t0 = time.perf_counter()
    stacked, wbs, info = persist.reopen_shards(
        dirpath, eager_recover_dirty=eager)
    t_reopen = time.perf_counter() - t0
    d = DistributedDash(cfg, mesh, axes=("data", "model"), capacity=256,
                        state=stacked)
    d.attach_pools(wbs)
    f, v = d.search(keys[:FIRST_QUERY])
    ttfq = time.perf_counter() - t0
    assert f.all()
    print("RESULT " + json.dumps({
        "ttfq_s": ttfq, "reopen_s": t_reopen,
        "dirty_shards": info["dirty_shards"],
        "recovered_segments": d.recovered_segments}))


def run():
    storm = _sub("_storm_main")

    tmp = tempfile.mkdtemp(prefix="dash_dhtpar_")
    try:
        dirs = {k: os.path.join(tmp, k) for k in ("clean", "lazy", "eager")}
        _sub("_writer_main", dirs["clean"], True)
        _sub("_writer_main", dirs["lazy"], False)
        _sub("_writer_main", dirs["eager"], False)
        clean = _sub("_reader_main", dirs["clean"], False)
        lazy = _sub("_reader_main", dirs["lazy"], False)
        eager = _sub("_reader_main", dirs["eager"], True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    assert clean["dirty_shards"] == 0 and clean["recovered_segments"] == 0
    assert lazy["dirty_shards"] == 8
    assert lazy["recovered_segments"] > 0, \
        "lazy recovery never fired on first access"
    assert eager["recovered_segments"] == 0    # all work done at reopen

    report = dict(storm)
    report["verify"] = {"p99_ratio": report.pop("p99_ratio"),
                        "host_plane_bytes":
                            report["device"]["host_plane_bytes"]}
    report["reopen"] = {
        "clean": clean, "lazy": lazy, "eager": eager,
        "ttfq_ratio": lazy["ttfq_s"] / clean["ttfq_s"],
        "eager_ttfq_ratio": eager["ttfq_s"] / clean["ttfq_s"],
        "first_query": FIRST_QUERY, "n_keys": POOL_N}

    # the ISSUE-9 acceptance gates, asserted before the artifact is written
    # (scripts/check_bench.py re-checks them from the JSON)
    assert report["verify"]["p99_ratio"] <= 0.5, \
        (report["verify"], report["device"], report["host"])
    assert report["verify"]["host_plane_bytes"] == 0
    assert report["splits"]["speedup"] >= 2.0, report["splits"]
    assert report["reopen"]["ttfq_ratio"] <= 1.5, report["reopen"]

    write_artifact(ARTIFACT, report)
    return [
        Row("dht_parallel/device_read", report["device"]["p50_us"],
            f"p99={report['device']['p99_us']:.0f}us "
            f"{report['device']['ops_per_s']:.0f} ops/s"),
        Row("dht_parallel/host_read", report["host"]["p50_us"],
            f"p99={report['host']['p99_us']:.0f}us "
            f"plane_bytes={report['host']['host_plane_bytes']}"),
        Row("dht_parallel/p99_ratio", report["verify"]["p99_ratio"],
            "device/host read p99; device plane bytes = 0"),
        Row("dht_parallel/split_speedup", report["splits"]["speedup"],
            f"{report['splits']['n_segments']} segs: "
            f"{report['splits']['device_s']*1e3:.0f}ms vs "
            f"{report['splits']['host_s']*1e3:.0f}ms host loop"),
        Row("dht_parallel/reopen_ttfq_ratio", report["reopen"]["ttfq_ratio"],
            f"lazy {lazy['ttfq_s']:.1f}s vs clean {clean['ttfq_s']:.1f}s "
            f"(eager {eager['ttfq_s']:.1f}s), "
            f"recovered={lazy['recovered_segments']}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(r.csv())
