"""Paper Fig. 11: max load factor of ONE segment vs segment size, adding
techniques one by one: bucketized -> +probing -> +balanced/displacement ->
+stash(2/4). Segment size varies via bucket count (256B buckets)."""
from __future__ import annotations

import numpy as np

from repro.core import DashConfig, DashEH, TableFullError
from .common import Row, unique_keys

VARIANTS = {
    "bucketized": dict(use_balanced=False, use_displacement=False,
                       probe_len=1, num_stash=0),
    "+probing": dict(use_balanced=False, use_displacement=False,
                     probe_len=2, num_stash=0),
    "+balanced+displace": dict(use_balanced=True, use_displacement=True,
                               num_stash=0),
    "+stash2": dict(use_balanced=True, use_displacement=True, num_stash=2),
    "+stash4": dict(use_balanced=True, use_displacement=True, num_stash=4),
}


def max_load_factor_one_segment(num_buckets: int, variant: dict) -> float:
    cfg = DashConfig(num_buckets=num_buckets, max_segments=2, init_depth=0,
                     dir_depth_max=1, **variant)
    t = DashEH(cfg)
    rng = np.random.default_rng(num_buckets)
    keys = unique_keys(rng, cfg.seg_capacity * 2)
    peak, i = 0.0, 0
    try:
        while i < keys.size:
            st = t.insert(keys[i:i + 32], np.zeros(32, np.uint32))
            if t.n_segments > 1:            # first split = segment was full
                break
            peak = max(peak, t.load_factor)
            i += 32
    except TableFullError:
        pass
    return peak


def run():
    rows = []
    for nb in (4, 16, 64, 256):             # ~1KB, 4KB, 16KB, 64KB segments
        seg_kb = nb * 256 // 1024
        for name, variant in VARIANTS.items():
            if variant["num_stash"] > 0 and nb < 4:
                continue
            lf = max_load_factor_one_segment(nb, variant)
            rows.append(Row(f"fig11/seg{seg_kb}KB/{name}", 0.0,
                            f"max_load_factor={lf:.3f}"))
    return rows
