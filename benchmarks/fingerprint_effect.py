"""Paper Fig. 9: fingerprinting effect.

On PM the fingerprint win is *avoided memory traffic* (key loads skipped when
no 1-byte fingerprint matches) — the identical currency on a
bandwidth-bound TPU (HBM bytes). Our data-parallel JAX formulation computes
all lanes regardless (no data-dependent branching on CPU), so wall time here
is flat; the honest reproduction is the BYTES-TOUCHED accounting measured on
the live structure per real query batch:

  bytes/probe without fp = window x (meta 4B + slots*12B key+val) [+ heap rows]
  bytes/probe with fp    = window x (fp 16B + meta 4B) + matches x 12B [+ 1 heap row]

where `matches` is MEASURED per query from the table's fingerprint planes
(false-positive rate ~ slots/256). The TPU kernel (kernels/probe.py) turns
this accounting into DMA behavior; wall time is reported for transparency.
"""
from __future__ import annotations

import numpy as np

from repro.core import DashConfig, DashEH, layout
from repro.core.hashing import np_hash1, np_hash2, np_split_keys
from .common import Row, ops_row, time_op, unique_keys

N = 16_000
BATCH = 4096
SLOT_BYTES = 12         # 8B key + 4B value
HEAP_ROW = 16           # pointer-mode key bytes


def _measured_fp_matches(t, queries):
    """Mean fingerprint matches per probe over target+probing buckets."""
    hi, lo = np_split_keys(queries)
    h1, h2 = np_hash1(hi, lo), np_hash2(hi, lo)
    seg = np.asarray(t.state.dir)[h1 >> np.uint32(32 - t.cfg.dir_depth_max)]
    b = (h1 & np.uint32(t.cfg.num_buckets - 1)).astype(np.int64)
    fp = np.asarray(t.state.fp)
    meta = np.asarray(t.state.meta)
    fpv = (h2 & np.uint32(0xFF)).astype(np.uint8)
    total = 0
    for off in (0, 1):
        bb = (b + off) % t.cfg.num_buckets
        rows = fp[seg, bb, :t.cfg.num_slots]
        alloc = (meta[seg, bb] & np.uint32(layout.SLOT_MASK))[:, None]
        bits = (alloc >> np.arange(t.cfg.num_slots, dtype=np.uint32)) & 1
        total += ((rows == fpv[:, None]) & (bits == 1)).sum()
    return total / queries.size


def run():
    rng = np.random.default_rng(17)
    keys = unique_keys(rng, N)
    neg = np.setdiff1d(unique_keys(np.random.default_rng(18), N), keys)[:BATCH]
    t = DashEH(DashConfig(max_segments=128, dir_depth_max=10))
    t.insert(keys, (np.arange(N) % 2**32).astype(np.uint32))
    SL = t.cfg.num_slots
    rows = []

    for op, q, is_pos in (("search_pos", keys[:BATCH], True),
                          ("search_neg", neg, False)):
        m = _measured_fp_matches(t, q)       # includes the true hit for pos
        fp_on = 2 * (16 + 4) + m * SLOT_BYTES
        fp_off = 2 * (4 + SL * SLOT_BYTES)
        rows.append(Row(f"fig9/bytes/{op}", 0.0,
                        f"fp_on={fp_on:.0f}B fp_off={fp_off:.0f}B "
                        f"saving={fp_off/fp_on:.2f}x (measured matches/probe={m:.3f})"))
        # variable-length keys: every candidate costs a heap-row dereference
        fp_on_v = fp_on + m * HEAP_ROW
        fp_off_v = fp_off + 2 * SL * HEAP_ROW
        rows.append(Row(f"fig9/bytes/var_{op}", 0.0,
                        f"fp_on={fp_on_v:.0f}B fp_off={fp_off_v:.0f}B "
                        f"saving={fp_off_v/fp_on_v:.2f}x"))

    # wall time (CPU, value-level masking: expected ~flat; see docstring)
    for fp in (True, False):
        tag = "fp_on" if fp else "fp_off"
        tt = DashEH(DashConfig(max_segments=128, dir_depth_max=10,
                               use_fingerprints=fp))
        tt.insert(keys, (np.arange(N) % 2**32).astype(np.uint32))
        for op, q in (("search_pos", keys[:BATCH]), ("search_neg", neg)):
            s = time_op(lambda q=q: tt.search(q))
            rows.append(ops_row(f"fig9/walltime/{op}/{tag}", s, BATCH))
    return rows
