"""Shared benchmark harness: timing, key generation, CSV emission.

Every module exposes ``run() -> list[Row]``; benchmarks.run prints
``name,us_per_call,derived`` CSV (one row per measured configuration).
Sizes are tuned for the 1-core CPU container: the numbers demonstrate the
paper's RELATIVE effects (fingerprint speedups, load-factor stacks, O(1)
recovery); absolute Mops/s belongs to the TPU deployment.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# persistent compilation cache (ROADMAP: shrink/merge dispatches are
# compile-dominated on CPU hosts; cached executables amortize across runs)
#
# OPT-IN ONLY (`REPRO_COMPILATION_CACHE=1`). On this container's
# jaxlib 0.4.36 / CPU, executables DESERIALIZED from the persistent cache
# mishandle buffer donation: donated pass-through outputs (e.g. the engine's
# untouched `lh_dir` plane) nondeterministically come back corrupted, and
# large cached SMO dispatches can crash outright — a use-after-free of the
# donated input buffer. Fresh-compiled executables are unaffected, so only
# the SECOND-and-later processes ever see it, which is exactly what made it
# look like test flakiness (tests/test_batch_parallel caught it: `lh_dir`
# diverged between the scan and segment engines on a delete that touches
# neither). Until the deployment jaxlib handles donation in deserialized
# executables, the cache stays off by default; the plumbing + hit/miss
# accounting below is ready to flip on.
# ---------------------------------------------------------------------------

_CACHE_STATS = {"hits": 0, "misses": 0}
_cache_enabled = False

CACHE_OPT_IN_ENV = "REPRO_COMPILATION_CACHE"


def _cache_listener(event: str, **kwargs):
    if event == "/jax/compilation_cache/cache_hits":
        _CACHE_STATS["hits"] += 1
    elif event == "/jax/compilation_cache/cache_misses":
        _CACHE_STATS["misses"] += 1


def enable_compilation_cache(path: str | None = None,
                             force: bool = False) -> str | None:
    """Idempotent: point JAX's persistent compilation cache at a repo-local
    directory (``.jax_cache/``, gitignored) and start counting hits/misses.
    Call before the first jit dispatch; benches record ``cache_stats()`` in
    their JSON artifacts so a compile-dominated run is visible.

    No-op (returns None) unless ``REPRO_COMPILATION_CACHE=1`` or
    ``force=True`` — see the donation-corruption note above."""
    global _cache_enabled
    import jax
    if _cache_enabled:
        return jax.config.jax_compilation_cache_dir
    if not force and os.environ.get(CACHE_OPT_IN_ENV) != "1":
        return None
    if path is None:
        path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                            ".jax_cache"))
    jax.config.update("jax_compilation_cache_dir", path)
    # tiny kernels dominate this repo: cache everything, not just slow builds
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.monitoring.register_event_listener(_cache_listener)
    _cache_enabled = True
    return path


def cache_stats() -> dict:
    """Persistent-cache state + hit/miss counters (artifact field)."""
    return {"enabled": _cache_enabled, **_CACHE_STATS}


def provenance() -> dict:
    """Run provenance stamped into every ``BENCH_*.json`` artifact: git SHA
    (+dirty marker), jax/jaxlib versions, device kind, and a timestamp — so
    the perf trajectory across PRs is attributable to a code state and a
    substrate."""
    import subprocess
    import jax
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def _git(*args):
        try:
            return subprocess.run(("git",) + args, cwd=root, text=True,
                                  capture_output=True, timeout=10
                                  ).stdout.strip()
        except Exception:
            return ""
    try:
        import jaxlib
        jaxlib_v = jaxlib.__version__
    except Exception:          # pragma: no cover
        jaxlib_v = ""
    dev = jax.devices()[0]
    return {
        "git_sha": _git("rev-parse", "HEAD") or "unknown",
        "git_dirty": bool(_git("status", "--porcelain")),
        "jax": jax.__version__,
        "jaxlib": jaxlib_v,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "device_count": jax.device_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def write_artifact(path: str, report: dict):
    """One artifact writer for every bench: stamps ``provenance`` and the
    compilation-cache counters, then writes pretty JSON."""
    import json
    report.setdefault("provenance", provenance())
    report.setdefault("compilation_cache", cache_stats())
    with open(path, "w") as f:
        json.dump(report, f, indent=2)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def unique_keys(rng: np.random.Generator, n: int) -> np.ndarray:
    out = np.unique(rng.integers(1, 2**63, size=int(n * 2.2) + 16,
                                 dtype=np.uint64))
    assert out.size >= n
    return out[:n]


def time_op(fn: Callable[[], object], repeats: int = 3,
            warmup: int = 1) -> float:
    """Median wall seconds of fn() (fn must block on device results)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def ops_row(name: str, seconds: float, n_ops: int, extra: str = "") -> Row:
    us = seconds / n_ops * 1e6
    mops = n_ops / seconds / 1e6
    derived = f"{mops:.3f} Mops/s"
    if extra:
        derived += f"; {extra}"
    return Row(name, us, derived)


# ---------------------------------------------------------------------------
# observability hooks (obs/): every bench artifact carries histogram rows;
# `run.py --trace` (or REPRO_TRACE=1) additionally captures op-lifecycle
# spans and drops a TRACE_<bench>.json next to the artifact
# ---------------------------------------------------------------------------

def trace_enabled() -> bool:
    from repro.obs import trace_enabled_from_env
    return trace_enabled_from_env()


def histogram_rows(obs, prefix: str = "") -> dict:
    """The registry's histogram snapshots (n/p50/p90/p99/max per name) in
    artifact shape — stamp under a ``"histograms"`` key."""
    return obs.registry.histogram_rows(prefix)


def export_trace(obs, name: str) -> Optional[str]:
    """Write the tracer ring as ``TRACE_<name>.json`` next to the bench
    artifacts when tracing is on; returns the path (None when disabled or
    nothing was recorded)."""
    tracer = obs.tracer
    if not tracer.enabled or tracer.recorded == 0:
        return None
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        f"TRACE_{name}.json")
    path = os.path.abspath(path)
    tracer.export_chrome_trace(path)
    return path
