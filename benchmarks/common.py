"""Shared benchmark harness: timing, key generation, CSV emission.

Every module exposes ``run() -> list[Row]``; benchmarks.run prints
``name,us_per_call,derived`` CSV (one row per measured configuration).
Sizes are tuned for the 1-core CPU container: the numbers demonstrate the
paper's RELATIVE effects (fingerprint speedups, load-factor stacks, O(1)
recovery); absolute Mops/s belongs to the TPU deployment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def unique_keys(rng: np.random.Generator, n: int) -> np.ndarray:
    out = np.unique(rng.integers(1, 2**63, size=int(n * 2.2) + 16,
                                 dtype=np.uint64))
    assert out.size >= n
    return out[:n]


def time_op(fn: Callable[[], object], repeats: int = 3,
            warmup: int = 1) -> float:
    """Median wall seconds of fn() (fn must block on device results)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def ops_row(name: str, seconds: float, n_ops: int, extra: str = "") -> Row:
    us = seconds / n_ops * 1e6
    mops = n_ops / seconds / 1e6
    derived = f"{mops:.3f} Mops/s"
    if extra:
        derived += f"; {extra}"
    return Row(name, us, derived)
