"""Pallas probe-kernel microbench (interpret mode on CPU — correctness-path
timing; the MXU/VPU design targets TPU, see kernels/probe.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH
from repro.core.hashing import np_split_keys
from repro.core import engine
from repro.kernels import ops
from .common import Row, ops_row, time_op, unique_keys


def run():
    cfg = DashConfig(max_segments=32, dir_depth_max=9)
    t = DashEH(cfg)
    keys = unique_keys(np.random.default_rng(81), 8000)
    t.insert(keys, np.arange(8000, dtype=np.uint32))
    hi, lo = np_split_keys(keys[:1024])
    hi, lo = jnp.asarray(hi), jnp.asarray(lo)

    # result-equivalence gate before any timing: the Pallas-routed path must
    # agree with the engine's per-key path on every kept lane (keep=False
    # lanes overflowed routing capacity and are untouched by design)
    f_eng, v_eng = engine.search_batch(cfg, "eh", t.state, hi, lo,
                                       batching="vmap")
    f_krn, v_krn, keep = ops.probe_routed(cfg, t.state, hi, lo, capacity=512)
    keep = np.asarray(keep)
    assert (np.asarray(f_eng)[keep] == np.asarray(f_krn)[keep]).all()
    hit = np.asarray(f_eng) & keep
    assert (np.asarray(v_eng)[hit] == np.asarray(v_krn)[hit]).all()
    assert not np.asarray(f_krn)[~keep].any()   # dropped lanes stay untouched

    s_eng = time_op(lambda: jax.block_until_ready(
        engine.search_batch(cfg, "eh", t.state, hi, lo, batching="vmap")))
    s_krn = time_op(lambda: jax.block_until_ready(
        ops.probe_routed(cfg, t.state, hi, lo, capacity=512)))
    return [ops_row("kernel/engine_search(vmap)", s_eng, 1024),
            ops_row("kernel/pallas_probe_routed(interpret)", s_krn, 1024)]
