"""Paper Table 1, measured END-TO-END through the durable serving stack:
restart cost vs data size with a real pool file surviving the process.

Four gated measurements (asserted before the artifact is written):

  * **ttfq** — time-to-first-served-query after a DIRTY ``persist.reopen``:
    map the pool, instant restart (read clean marker, bump V), build a
    ``DashFrontend``, serve one small read batch. Must be O(1) in stored
    keys: within 2x across 5k -> 60k (the pool is sized by the config, not
    the data; lazy recovery amortizes into subsequent batches, which the
    timeline series below shows).
  * **flush volume** — on a fill-driven split storm served through the
    frontend (flush-on-publish), total flushed bytes must be <= 0.25x the
    whole-pool rewrite the same publish cadence would pay without dirty
    tracking. Per-batch flush bytes are recorded next to the COW publish
    bytes (they track: both are O(dirty bucket rows); rebuilt SMO rows pay
    the 2x redo-log factor).
  * **checksummed reopen** — ``persist.reopen(verify=True)`` (the default:
    recompute every record row's checksum before serving) must cost <= 1.5x
    a ``verify=False`` reopen of the same pool (min of 3 trials each).
  * **torn crash** — a flush killed at several injection points must reopen
    to a pool where every PREVIOUSLY-acknowledged key is found (the full
    every-cut-point matrix runs in tests/test_persist.py).

Emits ``BENCH_durable_restart.json``.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro import persist
from repro.core import DashConfig, layout
from repro.persist import SimulatedCrash, WritebackEngine
from repro.persist.pool import PmPool
from repro.serving.frontend import INSERT, READ, DashFrontend, Op
from .common import Row, enable_compilation_cache, unique_keys, write_artifact

ARTIFACT = "BENCH_durable_restart.json"

CFG = DashConfig(max_segments=256, dir_depth_max=12)
SIZES = (5_000, 20_000, 60_000)
FIRST_BATCH = 8              # reads in the first served batch (ttfq)
STORM_CFG = DashConfig(max_segments=64, dir_depth_max=9)
STORM_LOAD = 8_192
STORM_FRESH = 8_192
STORM_BATCH = 256


def _build_pool(path: str, cfg: DashConfig, keys: np.ndarray) -> None:
    t = persist.create(path, cfg)
    vals = (np.arange(keys.size) % 2**31).astype(np.uint32) + 1
    for i in range(0, keys.size, 4000):
        t.insert(keys[i:i + 4000], vals[i:i + 4000])
        t.flush()                          # acknowledged durable per batch
    # no close(): the pool reopens DIRTY (the interesting restart)


def _ttfq(path: str, keys: np.ndarray, rng: np.random.Generator,
          warm: bool = True):
    """Reopen -> frontend -> first read batch served; then drain a few more
    batches to show recovery amortizing.

    ``warm`` first runs the identical reopen+serve cycle on a COPY of the
    pool, compiling this table shape's probe/recovery traces: the gate
    measures restart cost (map + superblock + publish + lazy recovery), not
    first-ever-jit of a differently-sized directory (production restarts
    run warm code)."""
    if warm:
        shutil.copyfile(path, path + ".warmcopy")
        _ttfq(path + ".warmcopy", keys, np.random.default_rng(99),
              warm=False)
        os.remove(path + ".warmcopy")
        # the build left megabytes of dirty pages behind; sync them NOW so
        # the measured fences pay for the restart's own stores, not the
        # builder's lingering writeback (tmp is disk-backed here)
        os.sync()
    t0 = time.perf_counter()
    table, info = persist.reopen(path)
    fe = DashFrontend(table, max_batch=STORM_BATCH)
    q = rng.choice(keys, FIRST_BATCH, replace=False)
    ops = [Op(READ, int(k)) for k in q]
    for op in ops:
        assert fe.submit(op)
    fe.drain()
    ttfq = time.perf_counter() - t0
    assert all(op.found for op in ops)
    assert not info["clean"]
    tail = []
    for _ in range(6):
        q = rng.choice(keys, 256, replace=False)
        ops = [Op(READ, int(k)) for k in q]
        t1 = time.perf_counter()
        for op in ops:
            fe.submit(op)
        fe.drain()
        tail.append(time.perf_counter() - t1)
        assert all(op.found for op in ops)
    return ttfq, tail, table.recovered_segments


def _storm(tmp: str):
    """Fill-driven split storm through the durable frontend; returns the
    flush/publish accounting."""
    rng = np.random.default_rng(0xD5)
    keys = unique_keys(rng, STORM_LOAD + STORM_FRESH)
    loaded, fresh = keys[:STORM_LOAD], keys[STORM_LOAD:]
    path = os.path.join(tmp, "storm.pool")
    t = persist.create(path, STORM_CFG)
    t.insert(loaded, np.ones(loaded.size, np.uint32))
    t.flush()
    fe = DashFrontend(t, max_batch=STORM_BATCH, queue_depth=1 << 16)
    wb = t.writeback
    base_bytes, base_flushes = wb.flushed_bytes, wb.flushes
    base_staged = wb.staged_bytes
    base_pub = fe.registry.publish_bytes
    per_batch = []
    splits0 = int(np.asarray(t.state.n_splits))
    for i in range(0, fresh.size, STORM_BATCH):
        ops = [Op(INSERT, int(k), 1) for k in fresh[i:i + STORM_BATCH]]
        for op in ops:
            assert fe.submit(op)
        b0 = wb.flushed_bytes
        fe.drain()
        per_batch.append(wb.flushed_bytes - b0)
    flushes = wb.flushes - base_flushes
    flushed = wb.flushed_bytes - base_bytes
    staged = wb.staged_bytes - base_staged
    return {
        "splits": int(np.asarray(t.state.n_splits)) - splits0,
        "flushes": flushes,
        "flushed_bytes": flushed,
        "flushed_bytes_per_batch": flushed / max(len(per_batch), 1),
        "staged_bytes": staged,
        "staged_ratio": staged / max(flushes * wb.pool.plane_bytes, 1),
        "publish_bytes": fe.registry.publish_bytes - base_pub,
        "pool_bytes": wb.pool.plane_bytes,
        "whole_pool_volume": flushes * wb.pool.plane_bytes,
        "volume_ratio": flushed / max(flushes * wb.pool.plane_bytes, 1),
        "logged_rows": wb.logged_rows,
        "flush_hint_misses": wb.flush_hint_misses,
        "per_batch_max": max(per_batch) if per_batch else 0,
    }


def _verify_cost(path: str):
    """Checksummed vs unchecked reopen on the same pool file: ``verify=True``
    recomputes every record row's checksum against the checksum region (one
    vectorized O(pool) scan) before serving. Min of 3 trials each; the
    acceptance gate bounds the overhead at 1.5x a plain reopen."""
    times = {True: [], False: []}
    for _ in range(3):
        for verify in (False, True):
            t0 = time.perf_counter()
            table, _ = persist.reopen(path, verify=verify)
            times[verify].append(time.perf_counter() - t0)
            table.writeback.pool.close()
    plain, checked = min(times[False]), min(times[True])
    return {"reopen_plain_s": plain, "reopen_verify_s": checked,
            "ratio": checked / max(plain, 1e-9)}


def _torn(tmp: str):
    """A handful of torn-flush injection points over an SMO-heavy batch;
    every acked key must survive each reopen."""
    cfg = DashConfig(max_segments=16, dir_depth_max=8, num_buckets=16,
                     num_slots=8)
    rng = np.random.default_rng(7)
    keys = unique_keys(rng, 1200)
    acked, torn = keys[:800], keys[800:]
    path = os.path.join(tmp, "torn.pool")
    t = persist.create(path, cfg)
    t.insert(acked, np.arange(acked.size, dtype=np.uint32) + 1)
    t.flush()
    base = path + ".base"
    shutil.copyfile(path, base)
    t.insert(torn, np.arange(torn.size, dtype=np.uint32) + 5000)
    # total store ops of the completed flush, counted on a scratch copy
    shutil.copyfile(base, path + ".scratch")
    probe = WritebackEngine(PmPool.open(path + ".scratch"))
    probe.inject_crash(1 << 30)
    probe.flush(t.state)
    ops_total = (1 << 30) - probe._ops_budget
    cuts = sorted(set([0, 1, ops_total // 2, ops_total - 1, ops_total]))
    survived = 0
    for k in cuts:
        shutil.copyfile(base, path)
        wb = WritebackEngine(PmPool.open(path))
        wb.inject_crash(k)
        try:
            wb.flush(t.state)
            assert k >= ops_total
        except SimulatedCrash:
            pass
        t2, _ = persist.reopen(path)
        f, v = t2.search(acked)
        assert f.all(), f"torn cut {k}: lost {int((~f).sum())} acked keys"
        assert (v == np.arange(acked.size, dtype=np.uint32) + 1).all()
        survived += 1
    return {"ops_per_flush": ops_total, "cuts_checked": survived}


def run():
    enable_compilation_cache()
    rows = []
    report = {"config": {"sizes": list(SIZES), "first_batch": FIRST_BATCH,
                         "max_segments": CFG.max_segments,
                         "pool_bytes": layout.pool_nbytes(CFG)}}
    tmp = tempfile.mkdtemp(prefix="dash_durable_")
    try:
        # warmup: compile the reopen/serve traces on a throwaway pool
        warm = unique_keys(np.random.default_rng(1), 4000)
        _build_pool(os.path.join(tmp, "warm.pool"), CFG, warm)
        _ttfq(os.path.join(tmp, "warm.pool"), warm, np.random.default_rng(2))

        ttfqs = {}
        for n in SIZES:
            keys = unique_keys(np.random.default_rng(n), n)
            path = os.path.join(tmp, f"t{n}.pool")
            _build_pool(path, CFG, keys)
            # best of two reopen cycles: the quantity under test is restart
            # cost (map + superblock + publish + lazy recovery), so take
            # the cycle least polluted by ambient I/O on the shared disk
            trials = [_ttfq(path, keys, np.random.default_rng(3))]
            trials.append(_ttfq(path, keys, np.random.default_rng(4),
                                warm=False))
            ttfq, tail, recovered = min(trials, key=lambda x: x[0])
            ttfqs[n] = ttfq
            report[f"ttfq/n{n}"] = {
                "seconds": ttfq, "trials": [t[0] for t in trials],
                "recovered": recovered, "tail_batch_seconds": tail}
            rows.append(Row(f"durable/ttfq/n{n}", ttfq * 1e6,
                            f"recovered={recovered}"))

        spread = max(ttfqs.values()) / min(ttfqs.values())
        report["ttfq_spread"] = spread

        vc = _verify_cost(os.path.join(tmp, f"t{max(SIZES)}.pool"))
        report["checksummed_reopen"] = vc
        rows.append(Row("durable/checksummed_reopen_ratio", vc["ratio"],
                        f"verify={vc['reopen_verify_s'] * 1e3:.1f}ms vs "
                        f"plain={vc['reopen_plain_s'] * 1e3:.1f}ms "
                        "(gate <= 1.5)"))

        storm = _storm(tmp)
        report["storm"] = storm
        rows.append(Row("durable/flush_volume_ratio",
                        storm["volume_ratio"],
                        f"{storm['flushed_bytes_per_batch']:.0f}B/batch vs "
                        f"{storm['pool_bytes']}B whole-pool"))
        rows.append(Row("durable/flush_staged_ratio",
                        storm["staged_ratio"],
                        "host bytes materialized per flush vs whole-pool "
                        "copy (gate <= 0.25)"))

        torn = _torn(tmp)
        report["torn"] = torn
        rows.append(Row("durable/torn_cuts_survived", torn["cuts_checked"],
                        f"{torn['ops_per_flush']} store ops per flush"))

        # acceptance gates — all asserted before the artifact lands
        assert spread <= 2.0, \
            f"ttfq spread {spread:.2f}x > 2x across sizes: " \
            + ", ".join(f"n{n}={s*1e3:.1f}ms" for n, s in ttfqs.items())
        assert storm["volume_ratio"] <= 0.25, \
            f"flush volume ratio {storm['volume_ratio']:.3f} > 0.25"
        # host staging rides the same O(dirty) budget: the flush gathers
        # dirty record rows on device and never np.asarray's a wide plane
        assert storm["staged_ratio"] <= 0.25, \
            f"host-staged ratio {storm['staged_ratio']:.3f} > 0.25"
        assert storm["flush_hint_misses"] == 0
        assert vc["ratio"] <= 1.5, \
            f"checksummed reopen {vc['ratio']:.2f}x > 1.5x plain reopen"
        rows.append(Row("durable/ttfq_spread", spread,
                        "max/min ttfq across 5k..60k (gate <= 2.0)"))
        write_artifact(ARTIFACT, report)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
