"""Paper Fig. 13: optimistic (lock-free reads, zero writes) vs pessimistic
(read-lock = version writes per probed bucket, serialized) search.

On PM the pessimistic cost is lock-word writes; here it shows up as (a) HBM
write traffic (measured via cost_analysis bytes) and (b) the serialization
of the batch (scan vs vmap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH, engine
from repro.core.hashing import np_split_keys
from .common import Row, ops_row, time_op, unique_keys

N = 16_000
BATCH = 2048


def run():
    rng = np.random.default_rng(41)
    keys = unique_keys(rng, N)
    t = DashEH(DashConfig(max_segments=128, dir_depth_max=10))
    t.insert(keys, (np.arange(N) % 2**32).astype(np.uint32))
    hi, lo = np_split_keys(keys[:BATCH])
    hi, lo = jnp.asarray(hi), jnp.asarray(lo)

    rows = []
    s_opt = time_op(lambda: jax.block_until_ready(
        engine.search_batch(t.cfg, "eh", t.state, hi, lo)))
    rows.append(ops_row("fig13/optimistic_search", s_opt, BATCH))

    state = t.state
    def pess():
        nonlocal state
        state, f, v = engine.search_batch_pessimistic(
            t.cfg, "eh", jax.tree.map(jnp.copy, state), hi, lo)
        jax.block_until_ready(f)
    s_pess = time_op(pess)
    rows.append(ops_row("fig13/pessimistic_search", s_pess, BATCH))
    rows.append(Row("fig13/speedup", 0.0,
                    f"{s_pess/s_opt:.1f}x optimistic over pessimistic"))

    # write-traffic accounting: pessimistic search WRITES version words
    c_opt = jax.jit(lambda st: engine.search_batch(t.cfg, "eh", st, hi, lo)
                    ).lower(t.state).compile().cost_analysis()
    c_pess = jax.jit(lambda st: engine.search_batch_pessimistic(
        t.cfg, "eh", st, hi, lo)).lower(t.state).compile().cost_analysis()
    if isinstance(c_opt, list):
        c_opt, c_pess = c_opt[0], c_pess[0]
    bo = c_opt.get("bytes accessed output {}", c_opt.get("bytes accessed", 0))
    bp = c_pess.get("bytes accessed output {}", c_pess.get("bytes accessed", 0))
    rows.append(Row("fig13/output_bytes", 0.0,
                    f"optimistic={bo:.3g}; pessimistic={bp:.3g}"))
    return rows
