"""SMO engine: bulk multi-segment split/merge vs the scalar SMO loop.

Three scenarios from the structural path the PR vectorizes:
  * ``splits@8`` — 8 concurrently pressured segments: one bulk dispatch
    (vmapped rebuild + single directory publish) vs 8 sequential scan-rehash
    SMOs. Before timing, asserts logical state equivalence (per-segment
    record sets + directory + depths) between the two paths.
  * ``fill64`` — grow a fresh 2-segment table to the full 64-segment pool
    (the directory-doubling scenario): wall time with ``smo_mode="scalar"`` vs
    ``smo_mode="bulk"`` tables, recorded in the same run.
  * ``shrink`` — delete 90% then merge everything mergeable: per-merge
    replanning + scan merges vs one-counts-pass rounds of bulk merges.

Emits ``BENCH_smo.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DashConfig, DashEH, TableFullError, dash_eh, engine, smo
from .common import (Row, enable_compilation_cache, write_artifact,
                     ops_row, time_op, unique_keys)

ARTIFACT = "BENCH_smo.json"

CFG = DashConfig(max_segments=64, dir_depth_max=9)
N_PRESSURED = 8


def _copy(state):
    return jax.tree.map(jnp.copy, state)


_recset = smo.segment_record_set


def _scalar_splits(state, segs, news):
    for o, n in zip(segs, news):
        state, ok = dash_eh.split_segment(CFG, state, o, n, impl="scan")
        assert bool(ok)
    return state


def _fill_to_pool(t, pool, batch=4096):
    """Insert a fixed keyset (sized to grow the table to the full segment
    pool); both SMO modes do identical work unless the pool runs out."""
    t0 = time.perf_counter()
    i = 0
    vals = np.arange(batch, dtype=np.uint32)
    while i < pool.size:
        try:
            t.insert(pool[i:i + batch], vals[:min(batch, pool.size - i)])
        except TableFullError:
            break                      # pool exhausted mid-batch: expected end
        i += batch
    return time.perf_counter() - t0, t.n_segments, i


def run():
    enable_compilation_cache()
    rng = np.random.default_rng(0x5140)
    report = {}
    rows = []

    # --- grow a base table, pick 8 pressured segments ------------------------
    t = DashEH(CFG)
    warm = unique_keys(rng, 22_000)
    t.insert(warm, np.arange(22_000, dtype=np.uint32))
    base = t.state
    wm = int(np.asarray(base.watermark))
    depths = np.asarray(base.local_depth)
    segs = [int(s) for s in np.unique(np.asarray(base.dir))
            if depths[s] < CFG.dir_depth_max][:N_PRESSURED]
    news = list(range(wm, wm + len(segs)))
    assert len(segs) == N_PRESSURED and news[-1] < CFG.max_segments
    report["segments"] = int(len(np.unique(np.asarray(base.dir))))

    # --- differential check before timing (logical state equivalence) -------
    s_scalar = _scalar_splits(_copy(base), segs, news)
    s_bulk, _ = smo.bulk_split(CFG, _copy(base), segs, news)
    assert (np.asarray(s_scalar.dir) == np.asarray(s_bulk.dir)).all()
    assert (np.asarray(s_scalar.local_depth)
            == np.asarray(s_bulk.local_depth)).all()
    assert int(s_scalar.n_items) == int(s_bulk.n_items)
    for seg in range(wm + len(segs)):
        assert _recset(CFG, s_scalar, seg) == _recset(CFG, s_bulk, seg), seg

    # --- timings (state copy cost included identically in both) -------------
    t_scalar = time_op(lambda: jax.block_until_ready(
        _scalar_splits(_copy(base), segs, news).meta))
    t_bulk = time_op(lambda: jax.block_until_ready(
        smo.bulk_split(CFG, _copy(base), segs, news)[0].meta))
    report["splits_at_8"] = {
        "scalar_s": t_scalar,
        "bulk_s": t_bulk,
        "scalar_splits_per_s": N_PRESSURED / t_scalar,
        "bulk_splits_per_s": N_PRESSURED / t_bulk,
        "speedup": t_scalar / t_bulk,
    }
    rows += [
        ops_row(f"smo/split_scalar@{N_PRESSURED}", t_scalar, N_PRESSURED),
        ops_row(f"smo/split_bulk@{N_PRESSURED}", t_bulk, N_PRESSURED,
                extra=f"{t_scalar / t_bulk:.2f}x vs scalar loop"),
    ]

    # --- fill-from-2-segments to the full pool (same run, both modes) -------
    pool = unique_keys(rng, 32_768)
    t_s = DashEH(CFG, smo_mode="scalar")
    fill_scalar_s, segs_s, used_s = _fill_to_pool(t_s, pool)
    t_b = DashEH(CFG, smo_mode="bulk")
    fill_bulk_s, segs_b, used_b = _fill_to_pool(t_b, pool)
    # the wall-time comparison is only meaningful over identical work
    assert used_s == used_b and segs_s == segs_b, (used_s, used_b, segs_s, segs_b)
    report["fill_to_pool"] = {
        "scalar_s": fill_scalar_s, "scalar_segments": int(segs_s),
        "bulk_s": fill_bulk_s, "bulk_segments": int(segs_b),
        "keys_scalar": int(used_s), "keys_bulk": int(used_b),
        "speedup": fill_scalar_s / fill_bulk_s,
    }
    rows += [
        Row("smo/fill_pool_scalar", fill_scalar_s * 1e6,
            f"{segs_s} segments, {used_s} keys"),
        Row("smo/fill_pool_bulk", fill_bulk_s * 1e6,
            f"{segs_b} segments, {used_b} keys; "
            f"{fill_scalar_s / fill_bulk_s:.2f}x vs scalar"),
    ]

    # --- shrink: bulk rounds vs per-merge replanning -------------------------
    shrink_times = {}
    for tag, tbl, keys_used in (("scalar", t_s, used_s), ("bulk", t_b, used_b)):
        tbl.delete(pool[:keys_used][np.arange(keys_used) % 10 != 0])
        t0 = time.perf_counter()
        merges = tbl.shrink(target_fill=0.8)
        shrink_times[tag] = {"seconds": time.perf_counter() - t0,
                             "merges": int(merges)}
        assert tbl.n_items == int(np.asarray(engine.recount_items(tbl.state)))
    report["shrink"] = shrink_times
    rows += [
        Row("smo/shrink_scalar", shrink_times["scalar"]["seconds"] * 1e6,
            f"{shrink_times['scalar']['merges']} merges"),
        Row("smo/shrink_bulk", shrink_times["bulk"]["seconds"] * 1e6,
            f"{shrink_times['bulk']['merges']} merges"),
    ]

    write_artifact(ARTIFACT, report)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
