"""Fused small-batch latency path: route→probe→verify / route→probe→scatter
in ONE dispatch.

Why this exists (ISSUE 7 / ROADMAP "fused kernel" item): the routed batch
paths win at large batches by amortizing the route / fingerprint / verify /
scatter stages across thousands of lanes, but the serving tick forms *small*
batches (64-256), where each extra XLA program launch is pure latency. At
batch 256 the routed search path measured 0.77x the plain vmap path and the
segment-parallel insert only 1.12x the sequential scan — fixed dispatch
overhead, not compute. IcebergHT (PAPERS.md) makes the same point for PM
hashing at low concurrency: per-op overhead governs latency.

Two entry points, both single-dispatch:

``fused_search``
    Reads. On TPU: ``fused_probe`` — one Pallas mega-kernel whose grid walks
    the segments the batch actually touches; each program fuses the one-hot
    MXU bucket gather (the route), the fingerprint compare (the probe), the
    16-bit-half key compare (the verify) and the value select, for the
    target bucket, the probing bucket and the stash rows. Pallas's grid
    pipeline double-buffers the next segment's plane block into VMEM while
    the current one computes. On non-TPU hosts: a direct-addressed jnp
    lowering — a single gather of the (window + stash) candidate rows per
    query and one dense compare, no lane planes at all (those only pay off
    as TPU VMEM blocking).

``fused_insert``
    Writes. One jitted program: segment routing (``ops.route_writes``), the
    dense uniqueness probe, free-slot/displacement/stash hints read straight
    from the packed metadata words, and a *merged commit* — the Alg. 1/2
    decision is computed as a code, then applied as one set of masked
    single-element scatters (out-of-bounds index + ``mode='drop'`` for the
    not-taken ops). This replaces the ``lax.switch`` insert body whose
    branches XLA merges into whole-plane selects under vmap — the actual
    cost driver at small batches, measured ~6x the useful work.

Differential contract: both paths are bit-identical to the reference
engines (``batching="vmap"`` reads, ``batching="scan"`` writes) for every
config they accept — asserted by tests/test_fused.py and re-asserted on
live state by the latency benchmark before timing. The one documented
caveat: the dense stash probe checks every *active* stash row instead of
walking overflow-fingerprint indications, so it relies on the metadata
invariant (every stash record is either ofp-indicated or covered by a
nonzero overflow count) that insert/delete maintain — the same invariant
``probe_in_segment``'s miss-path correctness already depends on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing, layout
from repro.core.layout import (DROPPED, EXISTS, INSERTED, NEED_SPLIT,
                               DashConfig, DashState, U32)

I32 = jnp.int32

BQ = 128          # queries per kernel program (full VPU/MXU row block)
ROWS = 128        # padded bucket rows per segment plane
LANES = 128       # padded slot lanes
NSLOTS = 14


# ---------------------------------------------------------------------------
# eligibility
# ---------------------------------------------------------------------------

def fused_search_eligible(cfg: DashConfig) -> bool:
    """The direct jnp read path covers every config: balanced pairs or
    linear-probe windows, fingerprints on/off, pointer mode (heap rows are
    gathered and compared like ``bucket.keys_equal``), stash on/off."""
    return True


def fused_kernel_eligible(cfg: DashConfig) -> bool:
    """Configs the Pallas mega-kernel spans: inline keys and a 2-bucket
    window (balanced pairs, or probe_len <= 2), planes within the padded
    tile. Fingerprints may be off — the wrapper feeds zero fp planes and
    zero query bytes so the compare degenerates to the allocated mask."""
    return (not cfg.pointer_mode
            and (cfg.use_balanced or cfg.probe_len <= 2)
            and cfg.buckets_total <= ROWS)


def fused_insert_eligible(cfg: DashConfig) -> bool:
    """The merged-commit write path covers the paper's main configuration:
    balanced two-bucket inserts (with or without displacement / stash /
    overflow metadata / fingerprints). Pointer mode keeps the sequential
    scan (its key heap is a global append log), and tiny tables where the
    b-1/b+2 displacement neighbors alias are excluded."""
    return (cfg.use_balanced and not cfg.pointer_mode
            and cfg.num_buckets >= 4)


# ---------------------------------------------------------------------------
# fused read — direct-addressed jnp lowering (the non-TPU execution path)
# ---------------------------------------------------------------------------

def _candidate_columns(cfg: DashConfig, b):
    """(Q, W) bucket-row indices per query: the probe window in order, then
    every stash row — the same visit order as ``probe_in_segment``."""
    NB = cfg.num_buckets
    cols = [(b + w) & (NB - 1) for w in range(cfg.probe_window)]
    cols += [jnp.full_like(b, NB + s) for s in range(cfg.num_stash)]
    return jnp.stack(cols, axis=1)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _fused_search_direct(cfg: DashConfig, mode: str, state: DashState,
                         keys_hi, keys_lo, words):
    """One gather of all candidate rows per query + one dense compare.

    Bit-identical to ``_search_batch_vmap``: column order encodes the
    window-then-stash probe priority, argmax over slots encodes
    ``bucket_probe``'s first-matching-slot rule.
    """
    SL, NB, ns = cfg.num_slots, cfg.num_buckets, cfg.num_stash
    window = cfg.probe_window
    if cfg.pointer_mode:        # identity pair folds the full key words
        keys_hi, keys_lo = hashing.key_identity_from_words(words)
    h1 = hashing.hash1(keys_hi, keys_lo)
    h2 = hashing.hash2(keys_hi, keys_lo)
    fpv = hashing.fingerprint(h2)

    from repro.kernels import ops
    seg, b = ops.locate_batch(cfg, mode, state, h1)
    bx = _candidate_columns(cfg, b)                      # (Q, W)
    W = bx.shape[1]
    segb = seg[:, None]

    alloc = layout.meta_alloc(state.meta[segb, bx])      # (Q, W)
    slot_bit = U32(1) << jnp.arange(SL, dtype=U32)
    live = (alloc[..., None] & slot_bit) != 0            # (Q, W, SL)
    cand = live
    if cfg.use_fingerprints:
        cand = cand & (state.fp[segb, bx, :SL] == fpv[:, None, None])
    s_hi = state.key_hi[segb, bx]                        # (Q, W, SL)
    s_lo = state.key_lo[segb, bx]
    if cfg.pointer_mode:
        rows = state.key_heap[s_lo % U32(max(cfg.key_heap_size, 1))]
        keq = (s_hi == keys_hi[:, None, None]) & jnp.all(
            rows == words[:, None, None, :], axis=-1)
    else:
        keq = (s_hi == keys_hi[:, None, None]) & (s_lo == keys_lo[:, None, None])
    m = cand & keq
    if ns:
        active = state.stash_active[seg]                 # (Q,)
        col_ok = jnp.concatenate(
            [jnp.ones((keys_hi.shape[0], window), jnp.bool_),
             jnp.arange(ns)[None, :] < active[:, None]], axis=1)
        m = m & col_ok[..., None]

    slot = jnp.argmax(m, axis=-1)                        # first matching slot
    okw = jnp.any(m, axis=-1)                            # (Q, W)
    vw = jnp.take_along_axis(state.val[segb, bx], slot[..., None],
                             axis=-1)[..., 0]
    found = jnp.zeros(keys_hi.shape[0], jnp.bool_)
    value = jnp.zeros(keys_hi.shape[0], U32)
    for w in range(W):                                   # window/stash priority
        take = okw[:, w] & ~found
        value = jnp.where(take, vw[:, w], value)
        found = found | okw[:, w]
    return found, value


# ---------------------------------------------------------------------------
# fused read — the Pallas mega-kernel (TPU path; interpret mode in tests)
# ---------------------------------------------------------------------------

def _fold_slots(eq, alloc_bits, va, vb, live):
    """First-matching-slot fold (bucket_probe's argmax rule) with the value
    assembled from its 16-bit halves. ``eq``: (BQ, NSLOTS) raw compares,
    ``alloc_bits``: (BQ,) packed alloc bitmaps, ``live``: (BQ,) lane mask."""
    ok = jnp.zeros(eq.shape[:1], jnp.bool_)
    val = jnp.zeros(eq.shape[:1], jnp.int32)
    for j in range(NSLOTS):
        hit = eq[:, j] & (((alloc_bits >> j) & 1) == 1) & live
        take = hit & ~ok
        val = jnp.where(take, va[:, j] | (vb[:, j] << 16), val)
        ok = ok | hit
    return ok, val


def _fused_read_block(fp_ref, alloc_ref, khia_ref, khib_ref, kloa_ref,
                      klob_ref, va_ref, vb_ref, qfp_ref, qb_ref, qpb_ref,
                      qhia_ref, qhib_ref, qloa_ref, qlob_ref,
                      found_ref, val_ref, *, nb: int, ns: int):
    """One (touched-segment, query-block) program: gather the target and
    probing bucket rows with one-hot MXU matmuls (fp + key halves + value
    halves share the one-hot), verify keys in 16-bit halves (exact in f32),
    then fold in the stash rows, which are static rows of the resident
    plane block — no gather at all."""
    fp = fp_ref[0].astype(jnp.float32)                   # (ROWS, LANES)
    alloc = alloc_ref[0]                                 # (ROWS,)
    planes = [r[0].astype(jnp.float32)
              for r in (khia_ref, khib_ref, kloa_ref, klob_ref, va_ref, vb_ref)]
    qfp = qfp_ref[0]
    q = [r[0] for r in (qhia_ref, qhib_ref, qloa_ref, qlob_ref)]  # (BQ,) i32
    live = qb_ref[0] >= 0
    rows = jax.lax.broadcasted_iota(jnp.int32, (BQ, ROWS), 1)

    def bucket_hits(qb):
        onehot = (rows == qb[:, None]).astype(jnp.float32)
        gfp = jnp.dot(onehot, fp, preferred_element_type=jnp.float32)
        gfp = gfp[:, :NSLOTS].astype(jnp.int32)
        g = [jnp.dot(onehot, p, preferred_element_type=jnp.float32)
             [:, :NSLOTS].astype(jnp.int32) for p in planes]
        galloc = jnp.sum(onehot.astype(jnp.int32) * alloc[None, :], axis=1)
        eq = ((gfp == qfp[:, None])
              & (g[0] == q[0][:, None]) & (g[1] == q[1][:, None])
              & (g[2] == q[2][:, None]) & (g[3] == q[3][:, None]))
        return _fold_slots(eq, galloc, g[4], g[5], live)

    ok_b, v_b = bucket_hits(qb_ref[0])
    ok_p, v_p = bucket_hits(qpb_ref[0])
    found = ok_b
    val = v_b
    val = jnp.where(ok_p & ~found, v_p, val)
    found = found | ok_p
    for s in range(ns):                                  # static stash rows
        r = nb + s
        ar = jnp.broadcast_to(alloc[r], (BQ,))
        fpr = fp[r, :NSLOTS].astype(jnp.int32)
        pr = [p[r, :NSLOTS].astype(jnp.int32) for p in planes]
        eq = ((fpr[None, :] == qfp[:, None])
              & (pr[0][None, :] == q[0][:, None]) & (pr[1][None, :] == q[1][:, None])
              & (pr[2][None, :] == q[2][:, None]) & (pr[3][None, :] == q[3][:, None]))
        ok_s, v_s = _fold_slots(
            eq, ar, jnp.broadcast_to(pr[4][None, :], (BQ, NSLOTS)),
            jnp.broadcast_to(pr[5][None, :], (BQ, NSLOTS)), live)
        val = jnp.where(ok_s & ~found, v_s, val)
        found = found | ok_s
    found_ref[0] = found.astype(jnp.int32)
    val_ref[0] = val


def _halves(x):
    """Split a uint32 plane into (lo16, hi16) int32 halves — exact in f32."""
    xi = x.astype(jnp.uint32)
    return ((xi & U32(0xFFFF)).astype(jnp.int32),
            (xi >> U32(16)).astype(jnp.int32))


def fused_plane_views(cfg: DashConfig, state: DashState, segments):
    """Compact, tile-padded plane views for the touched segments only.

    ``segments``: (U,) int32 segment ids (may repeat for padding). Stash
    rows beyond each segment's ``stash_active`` get a zero alloc bitmap so
    the kernel needs no activation logic. With fingerprints disabled the fp
    plane is zeroed (queries feed zero bytes -> compare is a no-op)."""
    BT, ns, NB = cfg.buckets_total, cfg.num_stash, cfg.num_buckets
    meta = state.meta[segments]                              # (U, BT)
    alloc = layout.meta_alloc(meta).astype(jnp.int32)
    if ns:
        srow = jnp.arange(BT) - NB                           # stash index or <0
        act = state.stash_active[segments][:, None]
        alloc = jnp.where((srow[None, :] >= 0) & (srow[None, :] >= act),
                          0, alloc)
    alloc = jnp.pad(alloc, ((0, 0), (0, ROWS - BT)))
    if cfg.use_fingerprints:
        fp = jnp.pad(state.fp[segments],
                     ((0, 0), (0, ROWS - BT), (0, LANES - state.fp.shape[-1])))
    else:
        fp = jnp.zeros((segments.shape[0], ROWS, LANES), jnp.uint8)

    def pad16(p):                                            # (U, BT, SL) i32
        return jnp.pad(p, ((0, 0), (0, ROWS - BT), (0, LANES - p.shape[-1])))

    khia, khib = _halves(state.key_hi[segments])
    kloa, klob = _halves(state.key_lo[segments])
    va, vb = _halves(state.val[segments])
    return (fp, alloc) + tuple(pad16(p) for p in (khia, khib, kloa, klob, va, vb))


@functools.partial(jax.jit, static_argnames=("nb", "ns", "interpret"))
def fused_probe(planes, q_fp, q_b, q_pb, q_hi, q_lo, *, nb: int, ns: int,
                interpret: bool = True):
    """The mega-kernel: route+probe+verify over compact touched segments.

    Args:
      planes: output of ``fused_plane_views`` — (fp, alloc, key/value
        half planes), each (U, ROWS[, LANES]).
      q_fp, q_b, q_pb: (U, C) int32 routed fingerprint bytes and bucket
        rows (-1 = padding lane).
      q_hi, q_lo: (U, C) uint32 routed key words.

    Returns (found, val): (U, C) int32 / uint32 per-lane results. The grid
    is (U, C // BQ) with per-segment plane blocks: Pallas's sequential grid
    pipeline prefetches segment u+1's block while u computes — the
    double-buffering this path is named for.
    """
    U, C = q_fp.shape
    assert C % BQ == 0
    qhia, qhib = _halves(q_hi)
    qloa, qlob = _halves(q_lo)
    grid = (U, C // BQ)
    pspec = pl.BlockSpec((1, ROWS, LANES), lambda s, c: (s, 0, 0))
    aspec = pl.BlockSpec((1, ROWS), lambda s, c: (s, 0))
    qspec = pl.BlockSpec((1, BQ), lambda s, c: (s, c))
    out_i32 = jax.ShapeDtypeStruct((U, C), jnp.int32)
    found, val = pl.pallas_call(
        functools.partial(_fused_read_block, nb=nb, ns=ns),
        grid=grid,
        in_specs=[pspec, aspec] + [pspec] * 6 + [qspec] * 7,
        out_specs=[qspec, qspec],
        out_shape=[out_i32, out_i32],
        interpret=interpret,
    )(*planes, q_fp, q_b, q_pb, qhia, qhib, qloa, qlob)
    return found, val.astype(U32)


@functools.partial(jax.jit, static_argnames=("nb", "ns"))
def fused_probe_jnp(planes, q_fp, q_b, q_pb, q_hi, q_lo, *, nb: int, ns: int):
    """Bit-identical jnp lowering of ``fused_probe`` (non-TPU stand-in,
    and the differential oracle the kernel is pinned against). Same visit
    order, same first-slot rule, same padded-lane masking."""
    fp, alloc = planes[0].astype(jnp.int32), planes[1]
    g16 = [p.astype(jnp.int32) for p in planes[2:]]       # (U, ROWS, LANES)
    qhia, qhib = _halves(q_hi)
    qloa, qlob = _halves(q_lo)
    qs = (qhia, qhib, qloa, qlob)
    live = q_b >= 0
    slot = jnp.arange(NSLOTS)

    def hits_at(qb):
        safe = jnp.clip(qb, 0, ROWS - 1)                    # (U, C)
        u = jnp.arange(safe.shape[0])[:, None]
        gfp = fp[u, safe][:, :, :NSLOTS]
        ga = alloc[u, safe]
        g = [p[u, safe][:, :, :NSLOTS] for p in g16]
        eq = ((gfp == q_fp[:, :, None])
              & (g[0] == qhia[:, :, None]) & (g[1] == qhib[:, :, None])
              & (g[2] == qloa[:, :, None]) & (g[3] == qlob[:, :, None])
              & (((ga[:, :, None] >> slot) & 1) == 1) & live[:, :, None])
        ok = jnp.any(eq, axis=-1)
        j = jnp.argmax(eq, axis=-1)
        gval = g[4] | (g[5] << 16)
        v = jnp.where(ok, jnp.take_along_axis(gval, j[:, :, None], axis=-1)[..., 0], 0)
        return ok, v

    ok_b, v_b = hits_at(q_b)
    ok_p, v_p = hits_at(q_pb)
    found, val = ok_b, v_b
    val = jnp.where(ok_p & ~found, v_p, val)
    found = found | ok_p
    for s in range(ns):
        r = nb + s
        ar = alloc[:, r][:, None]                        # (U, 1)
        eq = ((fp[:, r, None, :NSLOTS] == q_fp[:, :, None])
              & (g16[0][:, r, None, :NSLOTS] == qhia[:, :, None])
              & (g16[1][:, r, None, :NSLOTS] == qhib[:, :, None])
              & (g16[2][:, r, None, :NSLOTS] == qloa[:, :, None])
              & (g16[3][:, r, None, :NSLOTS] == qlob[:, :, None])
              & (((ar[:, :, None] >> slot) & 1) == 1) & live[:, :, None])
        ok_s = jnp.any(eq, axis=-1)
        j = jnp.argmax(eq, axis=-1)
        gval = g16[4][:, r, :NSLOTS] | (g16[5][:, r, :NSLOTS] << 16)  # (U, NSLOTS)
        v_s = jnp.where(ok_s, jnp.take_along_axis(
            jnp.broadcast_to(gval[:, None, :], eq.shape), j[:, :, None],
            axis=-1)[..., 0], 0)
        val = jnp.where(ok_s & ~found, v_s, val)
        found = found | ok_s
    return found.astype(jnp.int32), val.astype(U32)


# ---------------------------------------------------------------------------
# fused read — host-facing dispatch
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1, 6))
def _fused_search_routed(cfg: DashConfig, mode: str, state: DashState,
                         keys_hi, keys_lo, words, capacity: int):
    """TPU path: route queries to their segments, run the mega-kernel over
    the (compact) segment set, scatter results back. Capacity-overflow
    lanes fall back to the per-key probe, mirroring ``_search_batch_routed``."""
    from repro.kernels import ops
    h1 = hashing.hash1(keys_hi, keys_lo)
    h2 = hashing.hash2(keys_hi, keys_lo)
    fpv = (h2 & U32(0xFF)).astype(jnp.int32)
    seg, b = ops.locate_batch(cfg, mode, state, h1)
    NB = cfg.num_buckets
    lanes, src, keep = ops.route_lanes(
        seg, (fpv, b.astype(jnp.int32), keys_hi, keys_lo, seg >= 0),
        cfg.max_segments, capacity, (0, -1, 0, 0, False))
    q_fp, q_b, q_hi, q_lo, q_valid = lanes
    q_b = jnp.where(q_valid, q_b, -1)
    q_pb = jnp.where(q_valid, (q_b + 1) & (NB - 1), -1)
    segments = jnp.arange(cfg.max_segments, dtype=jnp.int32)
    planes = fused_plane_views(cfg, state, segments)
    interp = jax.default_backend() != "tpu"
    f, v = fused_probe(planes, jnp.where(q_valid, q_fp, -1), q_b, q_pb,
                       q_hi, q_lo, nb=NB, ns=cfg.num_stash, interpret=interp)
    n = keys_hi.shape[0]
    flatf, flatv = f.reshape(-1) != 0, v.reshape(-1)
    srcf = src.reshape(-1)
    ok = jnp.clip(srcf, 0)
    found = jnp.zeros((n,), jnp.bool_).at[ok].max(jnp.where(srcf >= 0, flatf, False))
    val = jnp.zeros((n,), U32).at[ok].max(jnp.where(srcf >= 0, flatv, U32(0)))
    direct = _fused_search_direct(cfg, mode, state, keys_hi, keys_lo, words)
    return (jnp.where(keep, found, direct[0]),
            jnp.where(keep, val, direct[1]))


def fused_search(cfg: DashConfig, mode: str, state: DashState,
                 keys_hi, keys_lo, words=None, capacity: int | None = None):
    """Single-dispatch batched lookup. Returns (found, values), bit-identical
    to ``engine.search_batch(batching="vmap")``.

    Non-TPU hosts always take the direct-addressed lowering (one gather +
    one dense compare — no routing, which is the whole point at small
    batches). TPU hosts take the routed mega-kernel when the config is in
    its span, the direct lowering otherwise."""
    n = keys_hi.shape[0]
    if words is None:
        words = jnp.zeros((n, cfg.key_heap_words), U32)
    if jax.default_backend() == "tpu" and fused_kernel_eligible(cfg):
        if capacity is None:
            capacity = max(BQ, 1 << (max(n - 1, 1)).bit_length())
        return _fused_search_routed(cfg, mode, state, keys_hi, keys_lo,
                                    words, capacity)
    return _fused_search_direct(cfg, mode, state, keys_hi, keys_lo, words)


# ---------------------------------------------------------------------------
# fused insert — merged-commit write path
# ---------------------------------------------------------------------------

def _ofp_set_word(cfg: DashConfig, om, stash_idx, member):
    """Word-level mirror of ``bucket.ofp_try_set`` (no state, no scatter):
    returns (ok, new_word, ofp_slot)."""
    oa = layout.ometa_ofp_alloc(om)
    ids = jnp.arange(cfg.num_ofp, dtype=U32)
    free = ((oa >> ids) & U32(1)) == 0
    ok = jnp.any(free)
    slot = jnp.argmax(free).astype(I32)
    new_oa = oa | (U32(1) << slot.astype(U32))
    omem = layout.ometa_ofp_member(om)
    new_omem = omem | jnp.where(member, U32(1) << slot.astype(U32), U32(0))
    om2 = om & ~((U32(0xF) << layout.OFPA_SHIFT) | (U32(0xF) << layout.OFPM_SHIFT))
    om2 = om2 | (new_oa << layout.OFPA_SHIFT) | (new_omem << layout.OFPM_SHIFT)
    om2 = layout.ometa_set_stash_idx(om2, slot, jnp.asarray(stash_idx).astype(U32))
    om2 = om2 | (U32(1) << layout.OVFB_SHIFT)
    return ok, jnp.where(ok, om2, om), slot


def _ovf_count_add_word(om):
    """Word-level mirror of ``bucket.ovf_count_add`` (+1)."""
    cnt = (layout.ometa_ovf_count(om).astype(I32) + 1).astype(U32)
    om = (om & ~(U32(0x7F) << layout.OVFC_SHIFT)) | ((cnt & U32(0x7F)) << layout.OVFC_SHIFT)
    return om | (U32(1) << layout.OVFB_SHIFT)


def _merged_insert_body(cfg: DashConfig, st: DashState, ln):
    """One routed lane against a single-segment view of the table — the
    ``lax.switch`` insert body re-expressed as straight-line code: compute
    the Alg. 1/2 decision code, then apply ONE masked set of single-element
    scatters (disabled ops get an out-of-bounds row index + ``mode='drop'``).

    Bit-identical to ``engine._insert_core`` (same candidate formulas, same
    priority, same packed-word and version-bump sequence) for every config
    ``fused_insert_eligible`` admits. The uniqueness probe is the dense
    window+stash compare — exact under the overflow-metadata invariant (see
    module docstring).
    """
    NB, SL, ns = cfg.num_buckets, cfg.num_slots, cfg.num_stash
    BT = cfg.buckets_total
    valid = ln["valid"]
    hi, lo, v = ln["hi"], ln["lo"], ln["val"]
    b = ln["b"]
    fpv = hashing.fingerprint(ln["h2"])
    pb = (b + 1) & (NB - 1)
    OOB = I32(BT)                                       # dropped scatter target

    meta = st.meta[0]                                   # (BT,)
    slot_ids = jnp.arange(SL, dtype=U32)

    def alloc_bits(w):
        return ((layout.meta_alloc(w) >> slot_ids) & U32(1)) == 1

    def count(w):
        return layout.meta_count(w).astype(I32)

    def ffs(w):
        free = ((layout.meta_alloc(w) >> slot_ids) & U32(1)) == 0
        return jnp.argmax(free).astype(I32)

    # ---- uniqueness probe (dense window + active stash rows) ----
    def probe_bucket(bx):
        cand = alloc_bits(meta[bx])
        if cfg.use_fingerprints:
            cand = cand & (st.fp[0, bx, :SL] == fpv)
        return jnp.any(cand & (st.key_hi[0, bx] == hi) & (st.key_lo[0, bx] == lo))

    exists = probe_bucket(b) | probe_bucket(pb)
    if ns > 0:
        active = st.stash_active[0]
        sl_live = ((layout.meta_alloc(meta[NB:NB + ns])[:, None]
                    >> slot_ids[None, :]) & U32(1)) == 1
        cand = sl_live
        if cfg.use_fingerprints:
            cand = cand & (st.fp[0, NB:NB + ns, :SL] == fpv)
        eq = (cand & (st.key_hi[0, NB:NB + ns] == hi)
              & (st.key_lo[0, NB:NB + ns] == lo)
              & (jnp.arange(ns) < active)[:, None])
        exists = exists | jnp.any(eq)
    else:
        active = I32(0)

    # ---- candidates (identical formulas to _insert_core) ----
    meta_b, meta_pb = meta[b], meta[pb]
    cb, cp = count(meta_b), count(meta_pb)
    pick_pb = (cp < cb) & (cp < SL) | ((cb >= SL) & (cp < SL))
    can_plain = (cb < SL) | (cp < SL)
    ins_b = jnp.where(pick_pb, pb, b)
    ins_member = pick_pb

    if cfg.use_displacement:
        pb2 = (b + 2) & (NB - 1)
        bm1 = (b - 1) & (NB - 1)

        def movable(w, want):
            a = alloc_bits(w)
            mset = ((layout.meta_member(w) >> slot_ids) & U32(1)) == 1
            ok = a & (mset == want)
            return jnp.any(ok), jnp.argmax(ok).astype(I32)

        okA_s, slotA = movable(meta_pb, False)
        okA = okA_s & (count(meta[pb2]) < SL)
        okB_s, slotB = movable(meta_b, True)
        okB = okB_s & (count(meta[bm1]) < SL)
    else:
        pb2 = bm1 = b
        slotA = slotB = I32(0)
        okA = okB = jnp.asarray(False)

    if ns > 0:
        st_counts = layout.meta_count(meta[NB:NB + ns]).astype(I32)
        stash_free = (st_counts < SL) & (jnp.arange(ns) < active)
        ok_stash = jnp.any(stash_free)
        st_j = jnp.argmax(stash_free).astype(I32)
        can_activate = active < ns
        ok_stash_or_new = ok_stash | can_activate
        st_j = jnp.where(ok_stash, st_j, active)
        stash_activates = ~ok_stash & can_activate
    else:
        ok_stash_or_new = jnp.asarray(False)
        st_j = I32(0)
        stash_activates = jnp.asarray(False)

    # ---- decision code (priority: exists > plain > dispA > dispB > stash) --
    code = jnp.where(
        exists, 0,
        jnp.where(can_plain, 1,
                  jnp.where(okA, 2,
                            jnp.where(okB, 3,
                                      jnp.where(ok_stash_or_new, 4, 5)))))
    committed = valid & (code >= 1) & (code <= 4)
    status = jnp.where(
        ~valid, I32(DROPPED),
        jnp.where(code == 0, I32(EXISTS),
                  jnp.where(code == 5, I32(NEED_SPLIT), I32(INSERTED))))

    # ---- merged commit: displacement move, clear, new record ----
    is_move = committed & ((code == 2) | (code == 3))
    mv_src_b = jnp.where(code == 2, pb, b)
    mv_src_slot = jnp.where(code == 2, slotA, slotB)
    mv_dst_b = jnp.where(code == 2, pb2, bm1)
    mv_dst_slot = ffs(meta[mv_dst_b])                   # pre-state; branch guarantees room
    mv_member = code == 2                               # dispA re-homes as member-set
    mk_hi = st.key_hi[0, mv_src_b, mv_src_slot]
    mk_lo = st.key_lo[0, mv_src_b, mv_src_slot]
    mk_v = st.val[0, mv_src_b, mv_src_slot]
    mk_fp = st.fp[0, mv_src_b, mv_src_slot]

    sb = NB + st_j
    new_b = jnp.where(code == 1, ins_b,
                      jnp.where(code == 2, pb,
                                jnp.where(code == 3, b, sb)))
    new_slot = jnp.where(code == 1, ffs(meta[ins_b]),
                         jnp.where(code == 2, slotA,
                                   jnp.where(code == 3, slotB, ffs(meta[sb]))))
    new_member = jnp.where(code == 1, ins_member, code == 2)

    mv_row = jnp.where(is_move, mv_dst_b, OOB)
    new_row = jnp.where(committed, new_b, OOB)

    def write2(plane, x_mv, x_new):
        plane = plane.at[0, mv_row, mv_dst_slot].set(x_mv, mode="drop")
        return plane.at[0, new_row, new_slot].set(x_new, mode="drop")

    key_hi = write2(st.key_hi, mk_hi, hi)
    key_lo = write2(st.key_lo, mk_lo, lo)
    val = write2(st.val, mk_v, v)
    fp = write2(st.fp, mk_fp, fpv)

    # packed metadata words (publish points), in _insert_core's store order
    bit = lambda s: U32(1) << s.astype(U32)
    w_mv = meta[mv_dst_b]
    w1 = layout.meta_pack(layout.meta_alloc(w_mv) | bit(mv_dst_slot),
                          layout.meta_member(w_mv)
                          | jnp.where(mv_member, bit(mv_dst_slot), U32(0)),
                          layout.meta_count(w_mv) + U32(1))
    w_src = meta[mv_src_b]
    wc = layout.meta_pack(layout.meta_alloc(w_src) & ~bit(mv_src_slot),
                          layout.meta_member(w_src) & ~bit(mv_src_slot),
                          layout.meta_count(w_src) - U32(1))
    # the displaced branches overwrite the just-cleared word at src == new_b
    w2_base = jnp.where(is_move, wc, meta[new_b])
    w2 = layout.meta_pack(layout.meta_alloc(w2_base) | bit(new_slot),
                          layout.meta_member(w2_base)
                          | jnp.where(new_member, bit(new_slot), U32(0)),
                          layout.meta_count(w2_base) + U32(1))
    meta_pl = st.meta
    meta_pl = meta_pl.at[0, mv_row].set(w1, mode="drop")
    meta_pl = meta_pl.at[0, jnp.where(is_move, mv_src_b, OOB)].set(wc, mode="drop")
    meta_pl = meta_pl.at[0, new_row].set(w2, mode="drop")

    # version bumps: +2 per constituent bucket op, exactly as the branches
    ver = st.version
    ver = ver.at[0, mv_row].add(U32(2), mode="drop")                 # move write
    ver = ver.at[0, jnp.where(is_move, mv_src_b, OOB)].add(U32(2), mode="drop")  # clear
    ver = ver.at[0, new_row].add(U32(2), mode="drop")                # new write

    st = st._replace(key_hi=key_hi, key_lo=key_lo, val=val, fp=fp,
                     meta=meta_pl)

    # stash activation + overflow metadata chain (br_stash)
    is_st = committed & (code == 4)
    if ns > 0:
        st = st._replace(stash_active=st.stash_active.at[0].set(
            jnp.where(is_st, jnp.maximum(active, st_j + 1), active)))
        if cfg.use_overflow_meta:
            OOB_NB = I32(NB)
            om_b, om_pb = st.ometa[0, b], st.ometa[0, pb]
            if cfg.num_ofp > 0:
                ok1, om_b_set, ofs1 = _ofp_set_word(cfg, om_b, st_j, member=False)
                ok2, om_pb_set, ofs2 = _ofp_set_word(cfg, om_pb, st_j, member=True)
            else:
                ok1 = ok2 = jnp.asarray(False)
                om_b_set, om_pb_set = om_b, om_pb
                ofs1 = ofs2 = I32(0)
            need_count = ~ok1 & ~ok2
            om_b_new = jnp.where(ok1, om_b_set, _ovf_count_add_word(om_b))
            ometa = st.ometa
            ometa = ometa.at[0, jnp.where(is_st & (ok1 | need_count), b, OOB_NB)
                             ].set(om_b_new, mode="drop")
            ometa = ometa.at[0, jnp.where(is_st & ~ok1 & ok2, pb, OOB_NB)
                             ].set(om_pb_set, mode="drop")
            ofp = st.ofp
            ofp = ofp.at[0, jnp.where(is_st & ok1, b, OOB_NB), ofs1
                         ].set(fpv, mode="drop")
            ofp = ofp.at[0, jnp.where(is_st & ~ok1 & ok2, pb, OOB_NB), ofs2
                         ].set(fpv, mode="drop")
            ver = ver.at[0, jnp.where(is_st, jnp.where(~ok1 & ok2, pb, b), OOB)
                         ].add(U32(2), mode="drop")
            st = st._replace(ometa=ometa, ofp=ofp)

    st = st._replace(version=ver,
                     n_items=st.n_items + (status == INSERTED).astype(I32))
    return st, (status, stash_activates & is_st)


@functools.partial(jax.jit, static_argnums=(0, 1, 8), donate_argnums=(2,))
def _fused_insert_jit(cfg: DashConfig, mode: str, state: DashState,
                      keys_hi, keys_lo, vals, words, valid, capacity: int):
    from repro.core import engine
    from repro.kernels import ops
    lanes, src, keep = ops.route_writes(
        cfg, mode, state, (keys_hi, keys_lo, vals, words, valid), capacity)

    def body(st, ln):
        return _merged_insert_body(cfg, st, ln)

    state, (statuses, acts) = engine._segment_parallel(cfg, state, lanes, body)
    return (state, engine._scatter_statuses(statuses, src, keys_hi.shape[0]),
            jnp.any(acts))


def fused_insert(cfg: DashConfig, mode: str, state: DashState,
                 keys_hi, keys_lo, vals, words=None, valid=None,
                 capacity: int | None = None):
    """Single-dispatch batch insert: route -> probe -> hint -> merged
    scatter commit, one jitted program. Returns (state, statuses,
    any_stash_activation) with the exact semantics (and bit pattern) of
    ``engine.insert_batch`` — falls back to the reference engines for
    configs outside ``fused_insert_eligible``."""
    from repro.core import engine
    n = keys_hi.shape[0]
    if words is None:
        words = jnp.zeros((n, cfg.key_heap_words), U32)
    if valid is None:
        valid = jnp.ones(n, jnp.bool_)
    if not fused_insert_eligible(cfg):
        return engine.insert_batch(cfg, mode, state, keys_hi, keys_lo, vals,
                                   words, valid, batching="scan")
    if capacity is None:
        capacity = engine._pow2_at_least(n)
    return _fused_insert_jit(cfg, mode, state, keys_hi, keys_lo, vals, words,
                             valid, min(capacity, engine._pow2_at_least(n)))
