"""Jit'd wrappers binding the Pallas kernels to Dash state.

``plane_views`` reshapes the table's fingerprint/metadata planes into the
hardware-aligned tiles the probe kernel wants (cheap, fusible pads).
``probe_routed`` is the end-to-end fast path used by the distributed hash
table: queries already routed per segment -> Pallas fingerprint scan ->
key verification only on fingerprint hits (gathers bounded by the match
bitmap, the paper's 'amortized one key load').

On this CPU container the kernels run in interpret mode (`interpret=True`
default); on TPU pass interpret=False — shapes/BlockSpecs are already
MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing, layout
from repro.core.layout import DashConfig, DashState
from . import probe as probe_kernel
from .hashmix import BLOCK, bulk_hash
from .probe import LANES, NSLOTS, ROWS, fingerprint_probe


@functools.partial(jax.jit, static_argnums=(0,))
def plane_views(cfg: DashConfig, state: DashState):
    """(fp_padded (S,128,128) u8, alloc (S,128) i32) from table state."""
    S, BT = cfg.max_segments, cfg.buckets_total
    fp = jnp.zeros((S, ROWS, LANES), jnp.uint8)
    fp = fp.at[:, :BT, :16].set(state.fp)
    alloc = jnp.zeros((S, ROWS), jnp.int32)
    alloc = alloc.at[:, :BT].set(layout.meta_alloc(state.meta).astype(jnp.int32))
    return fp, alloc


@functools.partial(jax.jit, static_argnums=(0, 4))
def route_queries(cfg: DashConfig, state: DashState, keys_hi, keys_lo,
                  capacity: int):
    """Group a query batch by segment with fixed capacity (MoE-style dispatch;
    the intra-host analog of the DHT's all_to_all routing).

    Returns (q_fp, q_b, q_pb, q_src): (S, C) planes; q_src maps back to the
    original batch position (-1 = empty lane)."""
    S = cfg.max_segments
    h1 = hashing.hash1(keys_hi, keys_lo)
    h2 = hashing.hash2(keys_hi, keys_lo)
    seg = state.dir[layout.dir_index(cfg, h1)]
    b = layout.bucket_index(cfg, h1)
    pb = (b + 1) & (cfg.num_buckets - 1)
    fp = (h2 & jnp.uint32(0xFF)).astype(jnp.int32)

    # position of each query within its segment's lane block
    onehot = jax.nn.one_hot(seg, S, dtype=jnp.int32)            # (Q, S)
    pos = jnp.cumsum(onehot, axis=0) - 1                         # running count
    slot = jnp.sum(pos * onehot, axis=1)                         # (Q,)
    keep = slot < capacity

    q_fp = jnp.zeros((S, capacity), jnp.int32)
    q_b = jnp.full((S, capacity), -1, jnp.int32)
    q_pb = jnp.full((S, capacity), -1, jnp.int32)
    q_src = jnp.full((S, capacity), -1, jnp.int32)
    idx = (jnp.where(keep, seg, 0), jnp.where(keep, slot, 0))
    q_fp = q_fp.at[idx].set(jnp.where(keep, fp, 0))
    q_b = q_b.at[idx].set(jnp.where(keep, b, -1))
    q_pb = q_pb.at[idx].set(jnp.where(keep, pb, -1))
    q_src = q_src.at[idx].set(jnp.where(keep, jnp.arange(keys_hi.shape[0]), -1))
    return q_fp, q_b, q_pb, q_src, keep


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def probe_routed(cfg: DashConfig, state: DashState, keys_hi, keys_lo,
                 capacity: int = 256, interpret: bool = True):
    """End-to-end batched search through the Pallas fingerprint kernel.

    Covers target+probing buckets and (rare) stash fallback via the engine's
    overflow metadata only when the bitmaps miss. Returns (found, values)
    aligned with the input batch. Queries overflowing the routing capacity
    are resolved by the caller via the plain engine path (`keep` lanes)."""
    from repro.core import engine  # local: avoid import cycle

    Q = keys_hi.shape[0]
    fp_pad, alloc = plane_views(cfg, state)
    q_fp, q_b, q_pb, q_src, keep = route_queries(cfg, state, keys_hi, keys_lo,
                                                 capacity)
    bits_b, bits_pb = fingerprint_probe(fp_pad, alloc, q_fp, q_b, q_pb,
                                        interpret=interpret)

    # verify fingerprint hits with real key compares (gather only on match)
    seg_ids = jnp.broadcast_to(jnp.arange(cfg.max_segments)[:, None], q_b.shape)

    def verify(seg, bqs, bits, hi, lo):
        ok = jnp.zeros((), jnp.bool_)
        val = jnp.zeros((), jnp.uint32)
        safe_b = jnp.clip(bqs, 0, cfg.buckets_total - 1)
        for j in range(NSLOTS):
            hit = ((bits >> j) & 1) == 1
            k_hi = state.key_hi[seg, safe_b, j]
            k_lo = state.key_lo[seg, safe_b, j]
            m = hit & (k_hi == hi) & (k_lo == lo)
            val = jnp.where(m & ~ok, state.val[seg, safe_b, j], val)
            ok = ok | m
        return ok, val

    flat_src = q_src.reshape(-1)
    hi_r = jnp.where(flat_src >= 0, keys_hi[jnp.clip(flat_src, 0)], 0)
    lo_r = jnp.where(flat_src >= 0, keys_lo[jnp.clip(flat_src, 0)], 0)
    vfn = jax.vmap(verify)
    ok_b, val_b = vfn(seg_ids.reshape(-1), q_b.reshape(-1), bits_b.reshape(-1), hi_r, lo_r)
    ok_p, val_p = vfn(seg_ids.reshape(-1), q_pb.reshape(-1), bits_pb.reshape(-1), hi_r, lo_r)
    ok = ok_b | ok_p
    val = jnp.where(ok_b, val_b, val_p)

    found = jnp.zeros((Q,), jnp.bool_)
    values = jnp.zeros((Q,), jnp.uint32)
    src_safe = jnp.clip(flat_src, 0)
    found = found.at[src_safe].max(ok & (flat_src >= 0))
    values = values.at[src_safe].max(jnp.where(ok & (flat_src >= 0), val, 0))

    # stash fallback for misses (uses overflow metadata; rare by design)
    def stash_lookup(hi, lo, miss):
        def go(_):
            q_hi, q_lo, h1, h2 = engine._query_parts(cfg, hi, lo,
                                                     jnp.zeros((cfg.key_heap_words,), jnp.uint32))
            seg, b = engine.locate(cfg, "eh", state, h1)
            f, v = engine.probe_in_segment(cfg, state, seg, b, h2, q_hi, q_lo,
                                           jnp.zeros((cfg.key_heap_words,), jnp.uint32))
            return f, v

        return jax.lax.cond(miss, go, lambda _: (jnp.asarray(False), jnp.uint32(0)), None)

    if cfg.num_stash > 0:
        sf, sv = jax.vmap(stash_lookup)(keys_hi, keys_lo, ~found & keep)
        values = jnp.where(sf & ~found, sv, values)
        found = found | sf
    return found, values, keep


def bulk_hash_padded(keys_hi, keys_lo, interpret: bool = True):
    """bulk_hash with automatic BLOCK padding (host convenience)."""
    n = keys_hi.shape[0]
    pad = (-n) % BLOCK
    hi = jnp.pad(keys_hi, (0, pad))
    lo = jnp.pad(keys_lo, (0, pad))
    h1, h2, fp = bulk_hash(hi, lo, interpret=interpret)
    return h1[:n], h2[:n], fp[:n]
