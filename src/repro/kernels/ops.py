"""Jit'd wrappers binding the Pallas kernels to Dash state.

``plane_views`` reshapes the table's fingerprint/metadata planes into the
hardware-aligned tiles the probe kernel wants (cheap, fusible pads).
``probe_routed`` is the end-to-end fast path: queries routed per segment ->
Pallas fingerprint scan -> key verification only on fingerprint hits
(gathers bounded by the match bitmap, the paper's 'amortized one key load').
It backs the default ``engine.search_batch`` read path on TPU;
``probe_direct`` is its direct-addressed jnp lowering for non-TPU hosts
(same fingerprint-first discipline, no per-segment lane blocking).

Routing is the shared MoE-style dispatcher of the whole repo: the same
``group_ranks``/``route_lanes`` pair groups queries by *segment* here, by
*owner shard* in distributed/dht.py, and carries full key/value lanes for
the segment-parallel write engine (core/engine.py) via ``route_writes``.
Ranking is sort-based (O(Q log Q)), not the dense one-hot+cumsum (O(Q*S))
it replaced, so routing cost scales with batch size, not directory size.

``interpret=True`` (the default off-TPU) swaps pl.pallas_call for the
bit-identical jnp lowerings — the Pallas interpreter's per-program overhead
is not the hot path's job; on TPU pass interpret=False, shapes/BlockSpecs
are already MXU/VPU aligned.

The fused small-batch latency path (kernels/fused.py) is re-exported here:
``fused_search`` / ``fused_insert`` collapse the route->probe->verify /
route->probe->hint->scatter pipelines into one dispatch — the path the
table planner picks when a batch is at or under its fused threshold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashing, layout
from repro.core.layout import DashConfig, DashState
from . import probe as probe_kernel
from .fused import (fused_insert, fused_insert_eligible,  # noqa: F401
                    fused_kernel_eligible, fused_probe, fused_probe_jnp,
                    fused_search, fused_search_eligible)
from .hashmix import BLOCK, bulk_hash
from .probe import LANES, NSLOTS, ROWS, fingerprint_probe

I32 = jnp.int32


@functools.partial(jax.jit, static_argnums=(0,))
def plane_views(cfg: DashConfig, state: DashState):
    """(fp_padded (S,128,128) u8, alloc (S,128) i32) from table state."""
    S, BT = cfg.max_segments, cfg.buckets_total
    fp = jnp.zeros((S, ROWS, LANES), jnp.uint8)
    fp = fp.at[:, :BT, :16].set(state.fp)
    alloc = jnp.zeros((S, ROWS), jnp.int32)
    alloc = alloc.at[:, :BT].set(layout.meta_alloc(state.meta).astype(jnp.int32))
    return fp, alloc


# ---------------------------------------------------------------------------
# shared MoE-style dispatcher (segments here, owner shards in the DHT)
# ---------------------------------------------------------------------------

def group_ranks(group_ids):
    """Rank of each item within its group, preserving input order.

    Sort-based (stable argsort + run-start cummax): O(Q log Q) regardless of
    the number of groups. The stable sort is what makes the segment-parallel
    write engine sequentially consistent: lanes of one segment keep batch
    order.
    """
    n = group_ids.shape[0]
    order = jnp.argsort(group_ids)                    # stable in jnp
    sorted_ids = group_ids[order]
    idx = jnp.arange(n, dtype=I32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sorted_ids[1:] != sorted_ids[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    return jnp.zeros((n,), I32).at[order].set(idx - run_start)


def route_lanes(group_ids, payloads, num_groups: int, capacity: int, fills):
    """Scatter per-item payload arrays into (num_groups, capacity) lane planes.

    Items past ``capacity`` in their group go to a trash slot *past the end*
    of the flat buffer — they can never clobber a live lane (the old dense
    router scattered them onto lane (0, 0)). Returns (planes, src, keep):
    ``src`` maps lanes back to batch positions (-1 = empty), ``keep[i]``
    is True iff item i received a lane.
    """
    n = group_ids.shape[0]
    group_ids = group_ids.astype(I32)
    rank = group_ranks(group_ids)
    keep = (rank < capacity) & (group_ids >= 0) & (group_ids < num_groups)
    trash = num_groups * capacity
    dst = jnp.where(keep, group_ids * capacity + rank, trash)
    outs = []
    for p, fill in zip(payloads, fills):
        flat = jnp.full((trash + 1,) + p.shape[1:], fill, p.dtype).at[dst].set(p)
        outs.append(flat[:-1].reshape((num_groups, capacity) + p.shape[1:]))
    src = jnp.full((trash + 1,), -1, I32).at[dst].set(jnp.arange(n, dtype=I32))
    return outs, src[:-1].reshape(num_groups, capacity), keep


def locate_batch(cfg: DashConfig, mode: str, state: DashState, h1):
    """Vectorized (seg, bucket) addressing for a batch of h1 hashes —
    engine.locate is pure jnp indexing, so it batches as-is; one copy of
    the EH/LH addressing rules."""
    from repro.core import engine    # local: core imports kernels lazily too
    return engine.locate(cfg, mode, state, h1)


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def route_queries(cfg: DashConfig, state: DashState, keys_hi, keys_lo,
                  capacity: int, mode: str = "eh"):
    """Group a query batch by segment with fixed capacity (MoE-style dispatch;
    the intra-host analog of the DHT's all_to_all routing).

    Returns (q_fp, q_b, q_pb, q_src, keep): (S, C) planes; q_src maps back to
    the original batch position (-1 = empty lane); ``keep`` is False for
    capacity-dropped queries (resolved by the caller on the per-key path)."""
    h1 = hashing.hash1(keys_hi, keys_lo)
    h2 = hashing.hash2(keys_hi, keys_lo)
    seg, b = locate_batch(cfg, mode, state, h1)
    pb = (b + 1) & (cfg.num_buckets - 1)
    fp = (h2 & jnp.uint32(0xFF)).astype(jnp.int32)
    (q_fp, q_b, q_pb), q_src, keep = route_lanes(
        seg, (fp, b, pb), cfg.max_segments, capacity, (0, -1, -1))
    return q_fp, q_b, q_pb, q_src, keep


@functools.partial(jax.jit, static_argnums=(0, 4, 5, 6))
def probe_routed(cfg: DashConfig, state: DashState, keys_hi, keys_lo,
                 capacity: int = 256, interpret: bool = True,
                 mode: str = "eh"):
    """End-to-end batched search through the Pallas fingerprint kernel.

    Covers target+probing buckets via the MXU gather and the (few) stash
    buckets via a dense VPU compare against the same routed lanes — stash
    rows are per-segment constants, so no gather is needed and the overflow
    metadata walk of the scalar path is unnecessary. Returns (found, values,
    keep) aligned with the input batch; ``keep=False`` lanes overflowed the
    routing capacity and are untouched (found=False) — the caller resolves
    them on the per-key path.

    Requires inline keys + fingerprints + a <=2 bucket probe window (the
    engine dispatcher gates on exactly that, falling back to the vmap path).

    ``interpret=True`` (non-TPU hosts) runs the kernel's bit-identical jnp
    lowering instead of the Pallas interpreter — same routed planes, same
    bitmaps, none of the per-program interpreter overhead.
    """
    Q = keys_hi.shape[0]
    S, NB, SL = cfg.max_segments, cfg.num_buckets, cfg.num_slots
    fp_pad, alloc = plane_views(cfg, state)
    q_fp, q_b, q_pb, q_src, keep = route_queries(cfg, state, keys_hi, keys_lo,
                                                 capacity, mode)
    if interpret:
        bits_b, bits_pb, _free_b, _free_pb = probe_kernel.fingerprint_probe_jnp(
            fp_pad, alloc, q_fp, q_b, q_pb)
    else:
        bits_b, bits_pb, _free_b, _free_pb = fingerprint_probe(
            fp_pad, alloc, q_fp, q_b, q_pb, interpret=False)

    # verify fingerprint hits with real key compares — one row gather per
    # plane (the paper's 'amortized one key load': only matched rows hit)
    seg_ids = jnp.broadcast_to(jnp.arange(S)[:, None], q_b.shape).reshape(-1)
    flat_src = q_src.reshape(-1)
    hi_r = jnp.where(flat_src >= 0, keys_hi[jnp.clip(flat_src, 0)], 0)
    lo_r = jnp.where(flat_src >= 0, keys_lo[jnp.clip(flat_src, 0)], 0)
    slot_ids = jnp.arange(cfg.num_slots)

    def verify(bqs, bits):
        safe_b = jnp.clip(bqs.reshape(-1), 0, cfg.buckets_total - 1)
        cand = ((bits.reshape(-1)[:, None] >> slot_ids) & 1) == 1  # (N, SL)
        k_hi = state.key_hi[seg_ids, safe_b]                       # (N, SL)
        k_lo = state.key_lo[seg_ids, safe_b]
        m = cand & (k_hi == hi_r[:, None]) & (k_lo == lo_r[:, None])
        vals_row = state.val[seg_ids, safe_b]
        val = jnp.max(jnp.where(m, vals_row, jnp.uint32(0)), axis=-1)
        return jnp.any(m, axis=-1), val

    ok_b, val_b = verify(q_b, bits_b)
    ok_p, val_p = verify(q_pb, bits_pb)
    ok = ok_b | ok_p
    val = jnp.where(ok_b, val_b, val_p)

    # --- stash lanes: dense compare, no gather (stash rows are per-segment
    # constants). Alloc-bitmap gating subsumes the stash_active check: a
    # never-activated stash bucket has no allocated slots.
    if cfg.num_stash > 0:
        C = q_fp.shape[1]
        st_alloc = layout.meta_alloc(state.meta[:, NB:NB + cfg.num_stash])
        slot_ids = jnp.arange(SL, dtype=jnp.uint32)
        st_live = ((st_alloc[..., None] >> slot_ids) & 1) == 1   # (S, ns, SL)
        st_hi = state.key_hi[:, NB:NB + cfg.num_stash, :SL]
        st_lo = state.key_lo[:, NB:NB + cfg.num_stash, :SL]
        st_val = state.val[:, NB:NB + cfg.num_stash, :SL]
        hi_l = hi_r.reshape(S, C)[:, :, None, None]
        lo_l = lo_r.reshape(S, C)[:, :, None, None]
        m = (st_live[:, None] & (st_hi[:, None] == hi_l) &
             (st_lo[:, None] == lo_l) & (q_src >= 0)[..., None, None])
        if cfg.use_fingerprints:
            st_fp = state.fp[:, NB:NB + cfg.num_stash, :SL].astype(jnp.int32)
            m = m & (st_fp[:, None] == q_fp[:, :, None, None])
        ok_s = jnp.any(m, axis=(2, 3)).reshape(-1)               # (S*C,)
        val_s = jnp.max(jnp.where(m, jnp.broadcast_to(st_val[:, None], m.shape),
                                  jnp.uint32(0)), axis=(2, 3)).reshape(-1)
        val = jnp.where(ok, val, val_s)
        ok = ok | ok_s

    found = jnp.zeros((Q,), jnp.bool_)
    values = jnp.zeros((Q,), jnp.uint32)
    src_safe = jnp.clip(flat_src, 0)
    found = found.at[src_safe].max(ok & (flat_src >= 0))
    values = values.at[src_safe].max(jnp.where(ok & (flat_src >= 0), val, 0))
    return found, values, keep


@functools.partial(jax.jit, static_argnums=(0, 4))
def probe_direct(cfg: DashConfig, state: DashState, keys_hi, keys_lo,
                 mode: str = "eh"):
    """Direct-addressed jnp lowering of the fingerprint read path (CPU hosts).

    Same read discipline as ``probe_routed`` — fingerprint match first, key
    loads only on candidates, stash covered by a dense compare — but
    per-query gathers instead of (S, C) lane planes: the fixed-capacity
    routing exists for the Pallas kernel's per-segment VMEM blocking, which
    buys nothing on XLA:CPU and pays ~S*C/Q lane overcapacity. Returns
    (found, values); never drops lanes (no routing capacity).
    """
    SL, NB = cfg.num_slots, cfg.num_buckets
    h1 = hashing.hash1(keys_hi, keys_lo)
    h2 = hashing.hash2(keys_hi, keys_lo)
    fpv = (h2 & jnp.uint32(0xFF)).astype(jnp.uint8)
    seg, b = locate_batch(cfg, mode, state, h1)
    slot_bit = jnp.uint32(1) << jnp.arange(SL, dtype=jnp.uint32)

    def bucket_hits(bx):
        alloc = layout.meta_alloc(state.meta[seg, bx])            # (Q,)
        live = (alloc[:, None] & slot_bit) != 0                   # (Q, SL)
        cand = live & (state.fp[seg, bx, :SL] == fpv[:, None])
        m = (cand & (state.key_hi[seg, bx] == keys_hi[:, None]) &
             (state.key_lo[seg, bx] == keys_lo[:, None]))
        val = jnp.max(jnp.where(m, state.val[seg, bx], jnp.uint32(0)), axis=-1)
        return jnp.any(m, axis=-1), val

    ok_b, val_b = bucket_hits(b)
    ok_p, val_p = bucket_hits((b + 1) & (NB - 1))
    found = ok_b | ok_p
    values = jnp.where(ok_b, val_b, val_p)

    if cfg.num_stash > 0:
        st_alloc = layout.meta_alloc(state.meta[:, NB:NB + cfg.num_stash])[seg]
        live = (st_alloc[..., None] & slot_bit) != 0              # (Q, ns, SL)
        cand = live & (state.fp[:, NB:NB + cfg.num_stash, :SL][seg]
                       == fpv[:, None, None])
        m = (cand &
             (state.key_hi[:, NB:NB + cfg.num_stash][seg] == keys_hi[:, None, None]) &
             (state.key_lo[:, NB:NB + cfg.num_stash][seg] == keys_lo[:, None, None]))
        ok_s = jnp.any(m, axis=(1, 2))
        val_s = jnp.max(jnp.where(m, state.val[:, NB:NB + cfg.num_stash][seg],
                                  jnp.uint32(0)), axis=(1, 2))
        values = jnp.where(found, values, val_s)
        found = found | ok_s
    return found, values


@functools.partial(jax.jit, static_argnums=(0, 1, 4, 5, 6))
def route_writes(cfg: DashConfig, mode: str, state: DashState,
                 payload, capacity: int, with_hints: bool = False,
                 interpret: bool = True):
    """Route a *write* batch by segment, carrying full key/value lanes.

    ``payload`` is (keys_hi, keys_lo, vals, words, valid). Returns
    ``(lanes, src, keep)`` where lanes is the dict the segment-parallel
    engine scans: hi/lo/val/words/b/h1/h2/valid, each (S, C[, W]).

    With ``with_hints=True`` the routed lanes are additionally pushed through
    the Pallas fingerprint pass over the *same* plane views the search path
    uses, returning per-lane (match_bits_b, match_bits_pb, free_slots_b,
    free_slots_pb). The free-slot bitmaps are advisory (pre-batch state —
    intra-batch inserts invalidate them): available to host-side admission
    and capacity prechecks, never for the commit decision.
    """
    keys_hi, keys_lo, vals, words, valid = payload
    h1 = hashing.hash1(keys_hi, keys_lo)
    h2 = hashing.hash2(keys_hi, keys_lo)
    seg, b = locate_batch(cfg, mode, state, h1)
    planes, src, keep = route_lanes(
        seg, (keys_hi, keys_lo, vals, words, b, h1, h2,
              valid & (seg >= 0)),
        cfg.max_segments, capacity,
        (0, 0, 0, 0, 0, 0, 0, False))
    lanes = dict(zip(("hi", "lo", "val", "words", "b", "h1", "h2", "valid"),
                     planes))
    if not with_hints:
        return lanes, src, keep
    fp_pad, alloc = plane_views(cfg, state)
    q_fp = (lanes["h2"] & jnp.uint32(0xFF)).astype(jnp.int32)
    q_b = jnp.where(lanes["valid"], lanes["b"].astype(jnp.int32), -1)
    q_pb = jnp.where(lanes["valid"],
                     (lanes["b"].astype(jnp.int32) + 1) & (cfg.num_buckets - 1),
                     -1)
    probe_fn = (probe_kernel.fingerprint_probe_jnp if interpret
                else functools.partial(fingerprint_probe, interpret=False))
    hints = probe_fn(fp_pad, alloc, q_fp, q_b, q_pb)
    return lanes, src, keep, hints


def bulk_hash_padded(keys_hi, keys_lo, interpret: bool = True):
    """bulk_hash with automatic BLOCK padding (host convenience)."""
    n = keys_hi.shape[0]
    pad = (-n) % BLOCK
    hi = jnp.pad(keys_hi, (0, pad))
    lo = jnp.pad(keys_lo, (0, pad))
    h1, h2, fp = bulk_hash(hi, lo, interpret=interpret)
    return h1[:n], h2[:n], fp[:n]
