"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package has a reference here with identical signature
and semantics; tests sweep shapes/dtypes and assert exact equality (these are
integer kernels — no tolerance needed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from .probe import NSLOTS


def fingerprint_probe_ref(fp_padded, alloc, q_fp, q_b, q_pb):
    """Oracle for probe.fingerprint_probe (plain gathers, no one-hot tricks)."""
    S, C = q_fp.shape

    def per_segment(fp_s, alloc_s, qfp_s, qb_s, qpb_s):
        def match(qb, qfp):
            safe = jnp.clip(qb, 0, fp_s.shape[0] - 1)
            row = fp_s[safe, :NSLOTS].astype(jnp.int32)       # (14,)
            a = alloc_s[safe]
            eq = (row == qfp) & (((a >> jnp.arange(NSLOTS)) & 1) == 1)
            bits = jnp.sum(eq.astype(jnp.int32) << jnp.arange(NSLOTS))
            free = (~a) & ((1 << NSLOTS) - 1)
            return jnp.where(qb < 0, 0, bits), jnp.where(qb < 0, 0, free)

        bb, fb = jax.vmap(match)(qb_s, qfp_s)
        bp, fp_ = jax.vmap(match)(qpb_s, qfp_s)
        return bb, bp, fb, fp_

    return jax.vmap(per_segment)(fp_padded, alloc, q_fp, q_b, q_pb)


def bulk_hash_ref(key_hi, key_lo):
    h1 = hashing.hash1(key_hi, key_lo)
    h2 = hashing.hash2(key_hi, key_lo)
    return h1, h2, (h2 & jnp.uint32(0xFF)).astype(jnp.int32)
