"""Pallas TPU kernels for the Dash probe hot path (the compute the paper
optimizes with SIMD on CPU; here mapped to MXU one-hot gathers + VPU compares).

probe.py   — fingerprint scan (one-hot MXU gather + VPU compare)
hashmix.py — bulk key hashing (murmur mixers on the VPU)
ref.py     — pure-jnp oracles (exact-match contract)
ops.py     — jit wrappers + routed end-to-end search
"""
from . import hashmix, ops, probe, ref

__all__ = ["hashmix", "ops", "probe", "ref"]
