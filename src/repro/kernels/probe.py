"""Pallas TPU kernel: fingerprint probe (the paper's SIMD fingerprint scan).

The paper's probe hot-path scans 18 one-byte fingerprints per bucket with
SIMD before touching any key (Sec. 4.2). On TPU the analogous unit is the VPU
(8x128 lanes) with the MXU doing the bucket-row *gather* as a one-hot matmul
— the idiomatic TPU replacement for random row gathers.

Layout adaptation (DESIGN.md Sec. 2): a segment's fingerprint plane is padded
to a (128, 128) uint8 tile — 128 bucket rows (64 normal + stash + pad) by 128
lanes (first 16 = slot fingerprints). 128 is the MXU's native dimension, so
the one-hot gather `one_hot(q_b) @ fp_plane` is a single aligned MXU pass,
and the fingerprint-compare runs on full VPU lanes. This mirrors the paper's
choice of a 256-byte bucket (the Optane block): size the probe unit to the
hardware's native transfer/compute block.

Grid: (segments, query_blocks). Each program probes a block of BQ queries,
already routed to their segment (the DHT dispatch of distributed/dht.py),
against that segment's resident fingerprint plane:

    out[s, q] = match bitmap of query q's fingerprint over the allocated
                slots of its target bucket (and probing bucket), 14 bits.

Queries with bucket id -1 are padding (bitmap 0). Key verification of the
(rare) matches happens outside — exactly the paper's "only access slots with
matching fingerprints".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128          # queries per program — one full VPU/MXU row block
ROWS = 128        # padded bucket rows per segment (64+stash -> 128)
LANES = 128       # padded fingerprint lanes (16 real -> 128)
NSLOTS = 14


def _probe_block(fp_ref, alloc_ref, qfp_ref, qb_ref, qpb_ref,
                 out_b_ref, out_pb_ref, free_b_ref, free_pb_ref):
    """One (segment, query-block) program."""
    fp = fp_ref[0].astype(jnp.float32)              # (ROWS, LANES) — small ints, exact in f32
    alloc = alloc_ref[0]                            # (ROWS,) int32 — 14-bit bitmaps
    qfp = qfp_ref[0]                                # (BQ,) int32 fingerprint values
    rows = jax.lax.broadcasted_iota(jnp.int32, (BQ, ROWS), 1)

    def gather_and_match(qb):
        onehot = (rows == qb[:, None]).astype(jnp.float32)          # (BQ, ROWS)
        gfp = jnp.dot(onehot, fp, preferred_element_type=jnp.float32)  # MXU gather
        gfp = gfp[:, :NSLOTS].astype(jnp.int32)                      # (BQ, 14)
        galloc = jnp.sum(onehot.astype(jnp.int32) * alloc[None, :], axis=1)  # (BQ,)
        eq = gfp == qfp[:, None]                                     # (BQ, 14)
        bits = jnp.zeros((BQ,), jnp.int32)
        for j in range(NSLOTS):
            abit = (galloc >> j) & 1
            bits = bits | ((eq[:, j].astype(jnp.int32) & abit) << j)
        # free-slot bitmap of the same gathered bucket (reused by the insert
        # router — same plane view, no extra gather); 0 for padding lanes
        free = jnp.where(qb < 0, 0, (~galloc) & ((1 << NSLOTS) - 1))
        return bits, free

    out_b_ref[0], free_b_ref[0] = gather_and_match(qb_ref[0])
    out_pb_ref[0], free_pb_ref[0] = gather_and_match(qpb_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def fingerprint_probe(fp_padded, alloc, q_fp, q_b, q_pb, *, interpret=True):
    """Batched fingerprint probe over routed queries.

    Args:
      fp_padded: (S, ROWS, LANES) uint8 — per-segment padded fp planes.
      alloc:     (S, ROWS) int32 — per-bucket allocation bitmaps (14 bits).
      q_fp:      (S, C) int32 — query fingerprint bytes, routed per segment.
      q_b, q_pb: (S, C) int32 — target/probing bucket rows (-1 = padding).

    Returns:
      (bits_b, bits_pb, free_b, free_pb): (S, C) int32 — per-query 14-bit
      match bitmaps for the target/probing bucket, plus the free-slot
      bitmaps of the same buckets (bit j set = slot j unallocated; 0 on
      padding lanes). The free bitmaps let the insert router reuse this
      single gather pass: ``ctz(free_b)`` is Alg. 1's first-free-slot.
    """
    S, C = q_fp.shape
    assert C % BQ == 0, "query capacity must be a multiple of BQ"
    grid = (S, C // BQ)
    qspec = pl.BlockSpec((1, BQ), lambda s, c: (s, c))
    out_i32 = jax.ShapeDtypeStruct((S, C), jnp.int32)
    return pl.pallas_call(
        _probe_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ROWS, LANES), lambda s, c: (s, 0, 0)),  # fp plane: VMEM-resident per segment
            pl.BlockSpec((1, ROWS), lambda s, c: (s, 0)),
            qspec, qspec, qspec,
        ],
        out_specs=[qspec, qspec, qspec, qspec],
        out_shape=[out_i32, out_i32, out_i32, out_i32],
        interpret=interpret,
    )(fp_padded, alloc, q_fp, q_b, q_pb)


def _match_jnp(fp_padded, alloc, q_fp, qb):
    safe = jnp.clip(qb, 0, fp_padded.shape[1] - 1)
    rows = jnp.take_along_axis(fp_padded.astype(jnp.int32),
                               safe[:, :, None], axis=1)[..., :NSLOTS]
    a = jnp.take_along_axis(alloc, safe, axis=1)                # (S, C)
    slot = jnp.arange(NSLOTS)
    eq = (rows == q_fp[:, :, None]) & (((a[:, :, None] >> slot) & 1) == 1)
    bits = jnp.sum(eq.astype(jnp.int32) << slot, axis=-1)
    free = (~a) & ((1 << NSLOTS) - 1)
    live = qb >= 0
    return jnp.where(live, bits, 0), jnp.where(live, free, 0)


@jax.jit
def fingerprint_probe_jnp(fp_padded, alloc, q_fp, q_b, q_pb):
    """Bit-identical jnp lowering of ``fingerprint_probe`` — the execution
    path on non-TPU hosts. ``pl.pallas_call(interpret=True)`` pays
    per-program interpreter overhead that defeats the kernel's purpose off
    TPU; this lowering expresses the same gather+compare as two
    ``take_along_axis`` passes that XLA:CPU fuses well. Tests pin it (and
    the interpreted Pallas kernel) against the same oracle."""
    bb, fb = _match_jnp(fp_padded, alloc, q_fp, q_b)
    bp, fp_ = _match_jnp(fp_padded, alloc, q_fp, q_pb)
    return bb, bp, fb, fp_
