"""Pallas TPU kernel: bulk key hashing (murmur-style mixers on the VPU).

Hashing is the other per-op fixed cost of the data path (Sec. 2.2 notes the
hash function is orthogonal but every op pays it). The mixer is pure
shift/xor/multiply — ideal VPU work. One program hashes a (BLOCK,) tile of
(hi, lo) key pairs into (h1, h2, fingerprint) with both seeds, fused so the
key words are read from VMEM once (the 'touch the bytes once' discipline the
paper applies to PM, applied to HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hashing

BLOCK = 1024


def _mix_block(hi_ref, lo_ref, h1_ref, h2_ref, fp_ref):
    hi = hi_ref[...]
    lo = lo_ref[...]
    h1 = hashing.hash_pair(hi, lo, hashing.SEED1)
    h2 = hashing.hash_pair(hi, lo, hashing.SEED2)
    h1_ref[...] = h1
    h2_ref[...] = h2
    fp_ref[...] = (h2 & jnp.uint32(0xFF)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bulk_hash(key_hi, key_lo, *, interpret=True):
    """(h1, h2, fp) for a (N,) uint32-pair key batch. N % BLOCK == 0."""
    n = key_hi.shape[0]
    assert n % BLOCK == 0, "pad key batches to BLOCK"
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _mix_block,
        grid=(n // BLOCK,),
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(key_hi, key_lo)
