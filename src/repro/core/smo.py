"""Device-parallel SMO engine: vectorized segment rebuild + bulk split/merge.

The data path went segment-parallel in PR 1; this module does the same for
the *structural* path (splits, merges, recovery redo — the SMOs of paper
Sec. 4.7). Two ideas:

**Vectorized rebuild.** A splitting/merging segment's records are extracted
once, partitioned by move-bit, and placed in a single pass: target buckets
and intra-bucket ranks come from the shared sort-based dispatcher
(``kernels/ops.group_ranks``), balanced-insert capacity is solved by a
carry recurrence over the bucket ring (the EDF schedule of the two-choice
b/b+1 placement — spill-in is served before home records, which dominates
the scan path's insert-order greedy + displacement), and the leftover goes
to the stash with overflow metadata rebuilt as one more rank/scatter.  No
per-record control flow: records of a feasible segment always fit, and the
rare infeasible rebuild is *not committed* (the caller falls back to the
retained scan rehash for exactly that segment).

**Bulk dispatch.** The rebuild is ``vmap``-ed across every segment pressured
in one batch round: one directory publish, one watermark bump, one
seg-state/version scatter — K splits cost one device dispatch instead of K.
The same machinery serves EH splits (``bulk_split``), LH round expansion
(``bulk_split_next``), buddy merges (``bulk_merge``) and crash-recovery redo
(``check_unique=True`` extracts *both* halves and dedupes before rebuilding,
the paper's "redo the rehashing with uniqueness check", Sec. 4.8).

Item accounting is incremental: SMOs move records, so ``n_items`` is never
recounted from the whole table (tests assert equality against the full
recount).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, hashing, layout
from .layout import (SEG_NEW, SEG_NORMAL, SEG_SPLITTING, DashConfig,
                     DashState, U32)

I32 = jnp.int32


def rebuild_eligible(cfg: DashConfig) -> bool:
    """Configs the one-pass rebuild covers exactly: the balanced b/(b+1)
    two-choice layout, or probe windows the single-spill schedule spans.
    Wider linear-probe ablations (CCEH probe-4) keep the scan rehash."""
    return cfg.use_balanced or cfg.probe_len <= 2


# ---------------------------------------------------------------------------
# vectorized rebuild of one segment-set (vmapped across the SMO batch)
# ---------------------------------------------------------------------------

def dedupe_records(hi, lo, valid):
    """Drop all-but-first copies of duplicate (hi, lo) keys (recovery redo:
    a crash between displacement steps or mid-SMO leaves the same record in
    two buckets/halves). Lex sort by (valid desc, hi, lo); duplicates are
    adjacent. Returns the pruned valid mask (input order)."""
    order = jnp.argsort(lo)
    order = order[jnp.argsort(hi[order])]
    order = order[jnp.argsort(~valid[order])]
    hi_s, lo_s, v_s = hi[order], lo[order], valid[order]
    dup = jnp.concatenate([
        jnp.zeros((1,), jnp.bool_),
        (hi_s[1:] == hi_s[:-1]) & (lo_s[1:] == lo_s[:-1]) & v_s[1:] & v_s[:-1]])
    return jnp.zeros_like(valid).at[order].set(v_s & ~dup)


def rebuild_records(cfg: DashConfig, T: int, stash_base: int,
                    hi, lo, val, valid, fpv, b, tgt):
    """Place N records into T fresh segment images in one pass.

    ``b`` is each record's home bucket, ``tgt`` its target segment index in
    [0, T).  Placement = EDF over the two-choice (b, b+1) ring: a carry
    recurrence computes per-bucket spill-in, ranks within (tgt, bucket)
    groups assign slots, the remainder ranks into the stash, and overflow
    metadata is rebuilt by one more grouped rank.  Returns
    (planes, stash_active (T,), ok); ``ok`` is False iff some record did not
    fit (caller must not commit the planes in that case).
    """
    from repro.kernels import ops
    NB, SL, BT, NS = (cfg.num_buckets, cfg.num_slots, cfg.buckets_total,
                      cfg.num_stash)
    window = cfg.probe_window
    spill = window >= 2

    valid = valid & (tgt >= 0) & (tgt < T)
    tgt_c = jnp.clip(tgt, 0, T - 1)
    gid = jnp.where(valid, tgt_c * NB + b, T * NB)
    r = ops.group_ranks(gid)
    cnt = jnp.zeros((T * NB + 1,), I32).at[gid].add(1)[:-1].reshape(T, NB)

    # carry recurrence around the bucket ring: o' = max(0, cnt - SL + min(o, SL)).
    # Two laps resolve the cyclic wrap; a non-converged carry only leaves
    # alloc-bitmap holes / extra stash spill — never a wrong placement.
    if spill:
        def lap(o0):
            def step(o, c):
                return jnp.maximum(0, c - SL + jnp.minimum(o, SL)), o
            return jax.lax.scan(step, o0, cnt.T)
        o_wrap, _ = lap(jnp.zeros((T,), I32))
        _, o_in = lap(o_wrap)
        s_in = jnp.minimum(o_in.T, SL)          # (T, NB) spill-in allotment
    else:
        s_in = jnp.zeros((T, NB), I32)
    h = jnp.minimum(cnt, SL - s_in)             # home placements per bucket

    pb = (b + 1) & (NB - 1)
    h_b = h[tgt_c, b]
    in_home = valid & (r < h_b)
    if spill:
        in_spill = valid & ~in_home & (r - h_b < s_in[tgt_c, pb])
    else:
        in_spill = jnp.zeros_like(valid)
    # home records sit after the spill-in block: slots [s_in[b], s_in[b]+h[b])
    dst_b = jnp.where(in_home, b, pb)
    dst_s = jnp.where(in_home, s_in[tgt_c, b] + r, r - h_b)
    placed = in_home | in_spill

    rest = valid & ~placed
    if NS > 0:
        sgid = jnp.where(rest, tgt_c, T)
        sr = ops.group_ranks(sgid)
        in_stash = rest & (sr < NS * SL)
        dst_b = jnp.where(in_stash, NB + sr // SL, dst_b)
        dst_s = jnp.where(in_stash, sr % SL, dst_s)
        placed = placed | in_stash
        stash_tot = jnp.zeros((T + 1,), I32).at[sgid].add(1)[:-1]
    else:
        in_stash = jnp.zeros_like(valid)
        sr = jnp.zeros_like(r)
        stash_tot = jnp.zeros((T,), I32)
    ok = ~jnp.any(valid & ~placed)

    # ---- scatter the record planes -----------------------------------------
    dst_su = jnp.clip(dst_s, 0, SL - 1).astype(U32)
    flat = jnp.where(placed, (tgt_c * BT + dst_b) * SL + dst_s, T * BT * SL)

    def scat(x, dtype):
        buf = jnp.zeros((T * BT * SL + 1,), dtype).at[flat].set(x.astype(dtype))
        return buf[:-1].reshape(T, BT, SL)

    p_hi, p_lo, p_val = scat(hi, U32), scat(lo, U32), scat(val, U32)
    p_fp = jnp.zeros((T, BT, 16), jnp.uint8).at[:, :, :SL].set(
        scat(fpv, jnp.uint8))

    bgid = jnp.where(placed, tgt_c * BT + dst_b, T * BT)
    slot_bit = U32(1) << dst_su
    alloc = jnp.zeros((T * BT + 1,), U32).at[bgid].add(slot_bit)[:-1]
    member = in_spill if cfg.use_balanced else jnp.zeros_like(in_spill)
    memb = jnp.zeros((T * BT + 1,), U32).at[
        jnp.where(member, bgid, T * BT)].add(slot_bit)[:-1]
    count = jnp.zeros((T * BT + 1,), U32).at[bgid].add(U32(1))[:-1]
    p_meta = layout.meta_pack(alloc, memb, count).reshape(T, BT)

    # ---- overflow metadata (Sec. 4.3): home-bucket ofp slots first, the
    # remainder is carried by the overflow counter (search's scan-all path)
    if NS > 0 and cfg.num_ofp > 0 and cfg.use_overflow_meta:
        ogid = jnp.where(in_stash, tgt_c * NB + b, T * NB)
        orank = ops.group_ranks(ogid)
        ocnt = jnp.zeros((T * NB + 1,), I32).at[ogid].add(1)[:-1].reshape(T, NB)
        in_ofp = in_stash & (orank < cfg.num_ofp)
        oidx = jnp.where(in_ofp, (tgt_c * NB + b) * 4 + orank, T * NB * 4)
        p_ofp = jnp.zeros((T * NB * 4 + 1,), jnp.uint8).at[oidx].set(
            fpv.astype(jnp.uint8))[:-1].reshape(T, NB, 4)
        n_used = jnp.minimum(ocnt, cfg.num_ofp).astype(U32)
        ofp_alloc = (U32(1) << n_used) - U32(1)
        sidx = (sr // SL).astype(U32) & U32(0x3)
        shift = (U32(layout.SIDX_SHIFT)
                 + U32(2) * jnp.clip(orank, 0, 3).astype(U32))
        sbits = jnp.zeros((T * NB + 1,), U32).at[
            jnp.where(in_ofp, tgt_c * NB + b, T * NB)].add(sidx << shift)[:-1]
        extra = jnp.maximum(ocnt - cfg.num_ofp, 0).astype(U32)
        p_ometa = ((ofp_alloc << layout.OFPA_SHIFT)
                   | sbits.reshape(T, NB)
                   | ((extra & U32(0x7F)) << layout.OVFC_SHIFT)
                   | ((ocnt > 0).astype(U32) << layout.OVFB_SHIFT))
    else:
        p_ofp = jnp.zeros((T, cfg.num_buckets, 4), jnp.uint8)
        p_ometa = jnp.zeros((T, cfg.num_buckets), U32)

    active = jnp.maximum(stash_base, -(-stash_tot // max(SL, 1)))
    planes = dict(key_hi=p_hi, key_lo=p_lo, val=p_val, fp=p_fp,
                  meta=p_meta, ometa=p_ometa, ofp=p_ofp)
    return planes, active, ok


def _extract(cfg: DashConfig, state: DashState, segs):
    """Records of each segment in ``segs`` (K,): (hi, lo, val, valid) with
    shape (K, BT*SL) — the batched gather twin of engine.segment_records."""
    sc = jnp.clip(segs, 0, cfg.max_segments - 1)
    K = segs.shape[0]
    hi = state.key_hi[sc].reshape(K, -1)
    lo = state.key_lo[sc].reshape(K, -1)
    val = state.val[sc].reshape(K, -1)
    alloc = layout.meta_alloc(state.meta[sc])
    slot_ids = jnp.arange(cfg.num_slots, dtype=U32)
    valid = (((alloc[..., None] >> slot_ids) & U32(1)) == 1).reshape(K, -1)
    return hi, lo, val, valid


def _scatter_planes(cfg: DashConfig, state: DashState, dst, planes):
    """Write rebuilt (M, ...) segment images at segment ids ``dst`` (M,);
    out-of-range ids (= masked-out SMOs) are dropped."""
    return state._replace(
        key_hi=state.key_hi.at[dst].set(planes["key_hi"], mode="drop"),
        key_lo=state.key_lo.at[dst].set(planes["key_lo"], mode="drop"),
        val=state.val.at[dst].set(planes["val"], mode="drop"),
        fp=state.fp.at[dst].set(planes["fp"], mode="drop"),
        meta=state.meta.at[dst].set(planes["meta"], mode="drop"),
        ometa=state.ometa.at[dst].set(planes["ometa"], mode="drop"),
        ofp=state.ofp.at[dst].set(planes["ofp"], mode="drop"),
        version=state.version.at[dst].add(U32(2), mode="drop"),
    )


# ---------------------------------------------------------------------------
# bulk EH split (phase 1 + phase 2, K segments per dispatch)
# ---------------------------------------------------------------------------

def bulk_split_phase1_local(cfg: DashConfig, state: DashState, old, new,
                            valid):
    """Unjitted body of :func:`bulk_split_phase1` — traceable inside a
    larger program (the distributed layer runs it per-shard under
    ``shard_map``). Allocate + initialize + link all K new segments in one
    dispatch (paper Sec. 4.7 step 1, vectorized). ``valid`` masks padding
    lanes."""
    S = cfg.max_segments
    o = jnp.where(valid, old, S)
    n = jnp.where(valid, new, S)
    ld = state.local_depth[jnp.clip(old, 0, S - 1)]
    side_old = state.side_link[jnp.clip(old, 0, S - 1)]
    return state._replace(
        seg_state=state.seg_state.at[o].set(SEG_SPLITTING, mode="drop")
                                 .at[n].set(SEG_NEW, mode="drop"),
        side_link=state.side_link.at[n].set(side_old, mode="drop")
                                 .at[o].set(new, mode="drop"),
        local_depth=state.local_depth.at[o].set(ld + 1, mode="drop")
                                      .at[n].set(ld + 1, mode="drop"),
        seg_version=state.seg_version.at[n].set(state.gver, mode="drop"),
        stash_active=state.stash_active.at[n].set(cfg.num_stash, mode="drop"),
        watermark=jnp.maximum(state.watermark,
                              jnp.max(jnp.where(valid, new, -1)) + 1),
    )


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def bulk_split_phase1(cfg: DashConfig, state: DashState, old, new, valid):
    """Jitted entry point over :func:`bulk_split_phase1_local`."""
    return bulk_split_phase1_local(cfg, state, old, new, valid)


def bulk_split_phase2_local(cfg: DashConfig, state: DashState, old, new,
                            valid, check_unique: bool = False):
    """Unjitted body of :func:`bulk_split_phase2` — traceable inside a
    larger program (shard-local splits under ``shard_map``).

    Rebuild + single directory publish for K splits. With
    ``check_unique=True`` (recovery redo) both halves are extracted and
    deduped first, making the phase idempotent.  Returns (state, ok (K,));
    a False lane was NOT committed (its source segment is untouched, still
    SPLITTING — the host falls back to the scan rehash for it)."""
    S = cfg.max_segments
    K = old.shape[0]
    ld_new = state.local_depth[jnp.clip(old, 0, S - 1)]

    hi, lo, val, vmask = _extract(cfg, state, old)
    if check_unique:
        hi2, lo2, val2, vmask2 = _extract(cfg, state, new)
        hi = jnp.concatenate([hi, hi2], axis=1)
        lo = jnp.concatenate([lo, lo2], axis=1)
        val = jnp.concatenate([val, val2], axis=1)
        vmask = jnp.concatenate([vmask, vmask2], axis=1)
        vmask = jax.vmap(dedupe_records)(hi, lo, vmask)

    h1, h2 = engine.record_hashes(cfg, state, hi, lo)
    tgt = ((h1 >> (U32(32) - ld_new[:, None].astype(U32))) & U32(1)).astype(I32)
    b = layout.bucket_index(cfg, h1)
    fpv = hashing.fingerprint(h2)
    planes, active, ok = jax.vmap(
        functools.partial(rebuild_records, cfg, 2, cfg.num_stash)
    )(hi, lo, val, vmask, fpv, b, tgt)

    commit = valid & ok
    dst = jnp.where(commit[:, None], jnp.stack([old, new], axis=1), S)
    dstf = dst.reshape(-1)
    state = _scatter_planes(
        cfg, state, dstf,
        {k: v.reshape((2 * K,) + v.shape[2:]) for k, v in planes.items()})

    # single directory publish: among entries owned by old[k], the half whose
    # (ld+1)-th MSB is 1 now points at new[k]
    idx = jnp.arange(cfg.dir_size, dtype=I32)
    bit = (idx[None, :] >> (cfg.dir_depth_max - ld_new[:, None])) & 1
    take = (state.dir[None, :] == old[:, None]) & (bit == 1) & commit[:, None]
    hit = jnp.any(take, axis=0)
    state = state._replace(dir=jnp.where(
        hit, new[jnp.argmax(take, axis=0)], state.dir))

    gd = state.global_depth
    mx = jnp.max(jnp.where(commit, ld_new, 0))
    state = state._replace(
        global_depth=jnp.maximum(gd, mx),
        n_doublings=state.n_doublings + jnp.maximum(mx - gd, 0),
        n_splits=state.n_splits + jnp.sum(commit.astype(I32)),
        seg_state=state.seg_state.at[jnp.where(commit, old, S)]
                                 .set(SEG_NORMAL, mode="drop")
                                 .at[jnp.where(commit, new, S)]
                                 .set(SEG_NORMAL, mode="drop"),
        seg_version=state.seg_version.at[dstf].set(state.gver, mode="drop"),
        stash_active=state.stash_active.at[dstf].set(
            active.reshape(-1), mode="drop"),
    )
    return state, ok | ~valid


@functools.partial(jax.jit, static_argnums=(0, 5), donate_argnums=(1,))
def bulk_split_phase2(cfg: DashConfig, state: DashState, old, new, valid,
                      check_unique: bool = False):
    """Jitted entry point over :func:`bulk_split_phase2_local`."""
    return bulk_split_phase2_local(cfg, state, old, new, valid, check_unique)


# ---------------------------------------------------------------------------
# shard-local split planning (device-resident DHT hot path)
# ---------------------------------------------------------------------------

def plan_local_splits(cfg: DashConfig, state: DashState, h1, want, k_max: int):
    """Plan up to ``k_max`` segment splits from pressured keys, entirely on
    device — the traced twin of the host ``np.unique`` planning loop in the
    DHT's ``split_for``.

    ``h1`` (N,) are hash1 values of this shard's keys, ``want`` (N,) the
    lanes demanding a split (status NEED_SPLIT).  Dedupes their directory
    targets to unique segment ids, assigns fresh ids off the watermark, and
    reports resource exhaustion as flags rather than committing a partial
    plan.  Returns ``(old, new, valid, depth_bad, pool_bad)`` with ``old`` /
    ``new`` / ``valid`` shaped (k_max,).  More than ``k_max`` pressured
    segments is fine: the surplus lanes stay NEED_SPLIT and are planned next
    round.
    """
    S = cfg.max_segments
    d = layout.dir_index(cfg, h1)
    seg = jnp.where(want, state.dir[d].astype(I32), S)
    seg_sorted = jnp.sort(seg)
    first = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                             seg_sorted[1:] != seg_sorted[:-1]])
    uniq = first & (seg_sorted < S)
    pos = jnp.cumsum(uniq.astype(I32)) - 1
    old = jnp.full((k_max,), -1, I32).at[
        jnp.where(uniq & (pos < k_max), pos, k_max)
    ].set(seg_sorted.astype(I32), mode="drop")
    valid = old >= 0
    k = jnp.sum(valid.astype(I32))
    new = jnp.where(valid,
                    state.watermark + jnp.cumsum(valid.astype(I32)) - 1, -1)
    depth_bad = jnp.any(valid & (state.local_depth[jnp.clip(old, 0, S - 1)]
                                 >= cfg.dir_depth_max))
    pool_bad = state.watermark + k > S
    return old, new, valid, depth_bad, pool_bad


def split_segments_local(cfg: DashConfig, state: DashState, old, new, valid):
    """Phase-1 + phase-2 of a bulk split as one traced body (no jit, no
    donation) — what the DHT's shard program runs on its local sub-state so
    all pressured shards split in a single dispatch.  Returns
    ``(state, ok (K,))`` with phase-2's not-committed semantics for False
    lanes (source still SPLITTING; the host repairs via the scan fallback).
    """
    state = bulk_split_phase1_local(cfg, state, old, new, valid)
    return bulk_split_phase2_local(cfg, state, old, new, valid, False)


class BulkSplitTask:
    """Staged EH bulk split: PHASE1 -> PHASE2 -> COMMIT, one device dispatch
    (or host sync) per ``pump`` call.

    Run to completion it is exactly ``bulk_split``; the point of the staging
    is the *online-resize* frontend (serving/frontend.py): between stages the
    caller keeps serving read batches against an epoch-pinned snapshot while
    the split publishes into the next directory version. Only the COMMIT
    stage blocks on device results (the ok mask -> scan-rehash fallback for
    infeasible packings; the fallback preserves exact old-path semantics).

    ``shortfall`` records how many pressured segments the caller could not
    allocate ids for (pool exhausted); the caller raises after commit so the
    feasible splits still land — same semantics as the inline path.
    """

    def __init__(self, cfg: DashConfig, old_ids, new_ids,
                 check_unique: bool = False, pad_to: int | None = None,
                 shortfall: int = 0):
        self.cfg = cfg
        self.old_np = np.asarray(old_ids, np.int32).reshape(-1)
        self.new_np = np.asarray(new_ids, np.int32).reshape(-1)
        K = self.old_np.size
        pad = (pad_to or engine._pow2_at_least(K, floor=1)) - K
        self.old = jnp.asarray(np.concatenate(
            [self.old_np, np.full(pad, -1, np.int32)]))
        self.new = jnp.asarray(np.concatenate(
            [self.new_np, np.full(pad, -1, np.int32)]))
        self.valid = jnp.asarray(np.arange(K + pad) < K)
        self.check_unique = check_unique
        self.shortfall = shortfall
        self.n_committed = K
        self._ok = None
        self.stage = "phase1"
        self.kind = "eh_bulk_split"

    def describe(self) -> dict:
        """Span/trace args: what this SMO is doing, sized."""
        return {"kind": self.kind, "segments": int(self.old_np.size),
                "shortfall": int(self.shortfall)}

    @property
    def touched(self) -> np.ndarray:
        """Segment ids this task rebuilds (source + target of every lane) —
        the dirty-plane footprint the COW publish accounts for (the task
        also republises the directory)."""
        return np.concatenate([self.old_np, self.new_np])

    def pump(self, state: DashState):
        """Advance one stage. Returns (state, done)."""
        from . import dash_eh
        if self.stage == "phase1":
            state = bulk_split_phase1(self.cfg, state, self.old, self.new,
                                      self.valid)
            self.stage = "phase2"
            return state, False
        if self.stage == "phase2":
            state, self._ok = bulk_split_phase2(
                self.cfg, state, self.old, self.new, self.valid,
                self.check_unique)
            self.stage = "commit"
            return state, False
        assert self.stage == "commit"
        ok_np = np.asarray(self._ok)
        for k in np.nonzero(~ok_np[:self.old_np.size])[0]:
            state, fit = dash_eh.split_phase2_scan(
                self.cfg, state, jnp.asarray(self.old_np[k], I32),
                jnp.asarray(self.new_np[k], I32), self.check_unique)
            if not bool(fit):
                raise AssertionError("split rehash failed to refit records")
        self.stage = "done"
        return state, True


class BulkSplitNextTask:
    """Staged LH round expansion: DISPATCH (``bulk_split_next``) -> COMMIT
    (ok sync + scan-rehash fallbacks) — the ``BulkSplitTask`` analog for the
    hybrid-expansion stride. ``R`` must respect the round/pool bounds (the
    table wrapper plans it)."""

    def __init__(self, cfg: DashConfig, R: int, touched=None):
        self.cfg = cfg
        self.R = R
        self.shortfall = 0
        self._ok = None
        self._old_phys = None
        self.stage = "dispatch"
        self.kind = "lh_split_next"
        #: dirty-plane footprint (split sources at Next.. + the new physical
        #: ids at the watermark); the planner (DashLH.make_smo_task) fills
        #: it from the host-visible lh_dir/watermark
        self.touched = np.zeros(0, np.int32) if touched is None \
            else np.asarray(touched, np.int32).reshape(-1)

    def describe(self) -> dict:
        """Span/trace args: what this SMO is doing, sized."""
        return {"kind": self.kind, "stride": int(self.R)}

    def pump(self, state: DashState):
        from . import dash_lh
        if self.stage == "dispatch":
            state, self._ok, self._old_phys = bulk_split_next(
                self.cfg, state, self.R)
            self.stage = "commit"
            return state, False
        assert self.stage == "commit"
        ok = np.asarray(self._ok)
        if not ok.all():
            old_phys = np.asarray(self._old_phys)
            for i in np.nonzero(~ok)[0]:
                state, ok1 = dash_lh.rehash_segment_scan(
                    self.cfg, state, int(old_phys[i]))
                if not bool(ok1):
                    raise AssertionError(
                        "LH split rehash failed to refit records")
        self.stage = "done"
        return state, True


def bulk_split(cfg: DashConfig, state: DashState, old_ids, new_ids,
               check_unique: bool = False, pad_to: int | None = None):
    """Host convenience: phase 1 + phase 2 for K splits, with scan-rehash
    fallback for any lane the rebuild could not fit (rare pathological
    packings). Pumps a BulkSplitTask to completion inline — the
    stop-the-world rendering of the staged pipeline. Returns
    (state, n_committed)."""
    task = BulkSplitTask(cfg, old_ids, new_ids, check_unique=check_unique,
                         pad_to=pad_to)
    done = False
    while not done:
        state, done = task.pump(state)
    return state, task.n_committed


# ---------------------------------------------------------------------------
# bulk LH round expansion (hybrid-expansion stride, Sec. 5.2/5.3)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 2), donate_argnums=(1,))
def bulk_split_next(cfg: DashConfig, state: DashState, R: int):
    """Split the R segments at Next..Next+R-1 in one dispatch and advance
    the packed (level, Next) word once — the hybrid-expansion analog of
    allocating a whole segment-array stride instead of one segment.  The
    caller guarantees R does not cross a round boundary and the pool holds
    R new segments.  Returns (state, ok (R,), old_phys (R,))."""
    S = cfg.max_segments
    level, nxt = layout.lh_level_next(state.lh_word)
    round_size = (I32(1 << cfg.lh_base_log2) << level)
    old_logical = nxt + jnp.arange(R, dtype=I32)
    new_logical = round_size + old_logical
    old_phys = state.lh_dir[jnp.clip(old_logical, 0, S - 1)]
    new_phys = state.watermark + jnp.arange(R, dtype=I32)
    base = min(cfg.num_stash, cfg.lh_base_stash)

    # advance the packed word FIRST (the atomic publish of Sec. 5.3); the
    # stash base reset is unconditional, matching split_next_scan — a failed
    # lane must not keep its elevated stash_active (the scan fallback
    # re-activates as it rehashes)
    nxt2 = nxt + R
    wrap = nxt2 >= round_size
    state = state._replace(
        lh_word=layout.lh_pack(level + wrap.astype(I32),
                               jnp.where(wrap, 0, nxt2)),
        lh_dir=state.lh_dir.at[new_logical].set(new_phys, mode="drop"),
        watermark=state.watermark + R,
        seg_version=state.seg_version.at[new_phys].set(state.gver,
                                                       mode="drop"),
        stash_active=state.stash_active.at[old_phys].set(base, mode="drop")
                                       .at[new_phys].set(base, mode="drop"),
    )

    hi, lo, val, vmask = _extract(cfg, state, old_phys)
    h1, h2 = engine.record_hashes(cfg, state, hi, lo)
    tgt = ((h1 >> (U32(cfg.lh_base_log2) + level.astype(U32)))
           & U32(1)).astype(I32)
    b = layout.lh_bucket_index(cfg, h1)
    fpv = hashing.fingerprint(h2)
    planes, active, ok = jax.vmap(
        functools.partial(rebuild_records, cfg, 2, base)
    )(hi, lo, val, vmask, fpv, b, tgt)

    dst = jnp.where(ok[:, None], jnp.stack([old_phys, new_phys], axis=1), S)
    dstf = dst.reshape(-1)
    state = _scatter_planes(
        cfg, state, dstf,
        {k: v.reshape((2 * R,) + v.shape[2:]) for k, v in planes.items()})
    state = state._replace(
        stash_active=state.stash_active.at[dstf].set(
            active.reshape(-1), mode="drop"),
        n_splits=state.n_splits + R,
    )
    return state, ok, old_phys


# ---------------------------------------------------------------------------
# bulk buddy merge (shrink SMO of Sec. 4.7)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def bulk_merge(cfg: DashConfig, state: DashState, keep, victim, valid):
    """Merge K disjoint buddy pairs in one dispatch: both segments' records
    rebuild into ``keep``, the victim planes are cleared, and all directory
    updates publish at once.  Returns (state, ok (K,)); a False lane was not
    committed (host falls back to the scan merge)."""
    S = cfg.max_segments
    K = keep.shape[0]
    hi_a, lo_a, val_a, va = _extract(cfg, state, keep)
    hi_b, lo_b, val_b, vb = _extract(cfg, state, victim)
    hi = jnp.concatenate([hi_a, hi_b], axis=1)
    lo = jnp.concatenate([lo_a, lo_b], axis=1)
    val = jnp.concatenate([val_a, val_b], axis=1)
    vmask = jnp.concatenate([va, vb], axis=1)

    h1, h2 = engine.record_hashes(cfg, state, hi, lo)
    tgt = jnp.zeros_like(h1, dtype=I32)
    b = layout.bucket_index(cfg, h1)
    fpv = hashing.fingerprint(h2)
    planes, active, ok = jax.vmap(
        functools.partial(rebuild_records, cfg, 1, cfg.num_stash)
    )(hi, lo, val, vmask, fpv, b, tgt)

    commit = valid & ok
    dk = jnp.where(commit, keep, S)
    dv = jnp.where(commit, victim, S)
    state = _scatter_planes(
        cfg, state, dk, {k: v[:, 0] for k, v in planes.items()})
    zero = dict(
        key_hi=jnp.zeros((K,) + state.key_hi.shape[1:], U32),
        key_lo=jnp.zeros((K,) + state.key_lo.shape[1:], U32),
        val=jnp.zeros((K,) + state.val.shape[1:], U32),
        fp=jnp.zeros((K,) + state.fp.shape[1:], jnp.uint8),
        meta=jnp.zeros((K,) + state.meta.shape[1:], U32),
        ometa=jnp.zeros((K,) + state.ometa.shape[1:], U32),
        ofp=jnp.zeros((K,) + state.ofp.shape[1:], jnp.uint8),
    )
    state = _scatter_planes(cfg, state, dv, zero)

    ld = state.local_depth[jnp.clip(keep, 0, S - 1)] - 1
    side_v = state.side_link[jnp.clip(victim, 0, S - 1)]
    take = (state.dir[None, :] == victim[:, None]) & commit[:, None]
    hit = jnp.any(take, axis=0)
    state = state._replace(
        dir=jnp.where(hit, keep[jnp.argmax(take, axis=0)], state.dir),
        local_depth=state.local_depth.at[dk].set(ld, mode="drop"),
        side_link=state.side_link.at[dk].set(side_v, mode="drop"),
        seg_state=state.seg_state.at[dv].set(SEG_NORMAL, mode="drop"),
        stash_active=state.stash_active.at[dk].set(active[:, 0], mode="drop"),
    )
    return state, ok | ~valid


def segment_record_set(cfg: DashConfig, state: DashState, seg: int):
    """Sorted (hi, lo, val) tuples of one segment's live records — the SMO
    engine's logical-equivalence contract (slot layout may differ between
    the rebuild and the scan reference; the record set must not). Used by
    the differential tests and the benchmark's pre-timing check."""
    hi, lo, val, valid = map(
        np.asarray, engine.segment_records(cfg, state, jnp.asarray(seg)))
    return sorted(zip(hi[valid], lo[valid], val[valid]))


# ---------------------------------------------------------------------------
# host-side planning: vectorized buddy-pair scan
# ---------------------------------------------------------------------------

def find_buddy_pairs(cfg: DashConfig, dirv: np.ndarray, depths: np.ndarray):
    """All mergeable buddy pairs in one vectorized pass over the directory.

    A segment's buddy owns the sibling prefix at the same local depth; under
    MSB indexing both ranges are adjacent, so one ``np.unique`` over the
    directory + one gather finds every pair (the old path re-scanned the
    whole directory per candidate segment). Pairs are naturally disjoint
    (the buddy relation at equal depth is a pairing). Returns an (M, 2)
    int array of [seg, buddy] with seg < buddy.
    """
    segs, first_idx = np.unique(dirv, return_index=True)
    ld = depths[segs]
    shift = cfg.dir_depth_max - ld
    prefix = first_idx >> shift
    sib_first = (prefix ^ 1) << shift
    buddy = dirv[np.clip(sib_first, 0, dirv.size - 1)]
    good = (ld > 0) & (buddy != segs) & (depths[buddy] == ld)
    pairs = np.stack([segs[good], buddy[good]], axis=1)
    pairs = pairs[pairs[:, 0] < pairs[:, 1]]        # dedupe symmetric pairs
    return pairs
