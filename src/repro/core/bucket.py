"""Bucket-level primitives for Dash (probe / insert / displace / stash math).

All functions are pure and operate on the full table state with ``(seg, b)``
indices; mutations return a new state (XLA turns the ``.at[].set`` chains into
in-place updates under donation). Per the paper's persistence discipline
(Alg. 2): record slots are written first, then the *single packed metadata
word* (alloc | membership | count) is published last — the word is the commit
point, and our crash simulator (recovery.py) is allowed to keep slot writes
while dropping the word, never the converse.

Version discipline (the optimistic-concurrency analog, Sec. 4.4, and the
copy-on-write snapshot contract): EVERY mutation of a bucket row — record
slots, the packed metadata word, overflow fingerprints, the packed overflow
word — bumps that bucket's version word by 2 (bit 0 stays the lock bit).
The version plane is therefore a complete change record: the snapshot
verify pass (serving/engine.py) and the O(dirty) publish
(core/epoch.py:SnapshotRegistry.publish_cow) both rely on "content changed
implies version changed"; a silent write would corrupt published snapshots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layout
from .layout import DashConfig, DashState, U32

I32 = jnp.int32


def slot_fp_matches(cfg: DashConfig, state: DashState, seg, b, fpv):
    """(SLOTS,) bool — allocated slots whose fingerprint matches.

    With fingerprinting disabled (ablation / CCEH baseline) every allocated
    slot is a candidate — modeling the extra key loads the paper avoids.
    """
    meta = state.meta[seg, b]
    alloc = layout.meta_alloc(meta)
    slot_ids = jnp.arange(cfg.num_slots, dtype=U32)
    allocated = ((alloc >> slot_ids) & U32(1)).astype(jnp.bool_)
    if not cfg.use_fingerprints:
        return allocated
    fps = jax.lax.dynamic_slice(state.fp, (seg, b, 0), (1, 1, 16))[0, 0, :cfg.num_slots]
    return allocated & (fps == fpv)


def keys_equal(cfg: DashConfig, state: DashState, seg, b, q_hi, q_lo, q_words):
    """(SLOTS,) bool — full key comparison for every slot (caller masks).

    Inline mode compares the (hi, lo) pair in the slot. Pointer mode treats
    ``key_lo`` as a key-heap handle and compares the heap row against
    ``q_words`` — the 'dereference the 8-byte pointer' path of Sec. 4.5.
    """
    s_hi = state.key_hi[seg, b]
    s_lo = state.key_lo[seg, b]
    if not cfg.pointer_mode:
        return (s_hi == q_hi) & (s_lo == q_lo)
    rows = state.key_heap[s_lo % U32(max(cfg.key_heap_size, 1))]   # (SLOTS, W)
    return (s_hi == q_hi) & jnp.all(rows == q_words[None, :], axis=-1)


def bucket_probe(cfg: DashConfig, state: DashState, seg, b, fpv, q_hi, q_lo, q_words):
    """Search one bucket. Returns (found, slot, value)."""
    cand = slot_fp_matches(cfg, state, seg, b, fpv)
    eq = cand & keys_equal(cfg, state, seg, b, q_hi, q_lo, q_words)
    found = jnp.any(eq)
    slot = jnp.argmax(eq).astype(I32)
    return found, slot, state.val[seg, b, slot]


def first_free_slot(cfg: DashConfig, state: DashState, seg, b):
    """(has_free, slot) — lowest clear bit of the alloc bitmap."""
    alloc = layout.meta_alloc(state.meta[seg, b])
    slot_ids = jnp.arange(cfg.num_slots, dtype=U32)
    free = ((alloc >> slot_ids) & U32(1)) == 0
    return jnp.any(free), jnp.argmax(free).astype(I32)


def bucket_count(state: DashState, seg, b):
    return layout.meta_count(state.meta[seg, b]).astype(I32)


def bump_version(state: DashState, seg, b):
    """+2 keeps the lock bit (bit 0) clear — release+version-increment analog."""
    return state._replace(version=state.version.at[seg, b].add(U32(2)))


def bucket_write(cfg: DashConfig, state: DashState, seg, b, slot,
                 k_hi, k_lo, v, fpv, member):
    """Write a record into a known-free slot and publish the metadata word.

    Mirrors Alg. 2 bucket::insert: (1) slot payload, (2) fingerprint,
    (3) one atomic store of alloc|membership|count, (4) version bump.
    """
    state = state._replace(
        key_hi=state.key_hi.at[seg, b, slot].set(k_hi),
        key_lo=state.key_lo.at[seg, b, slot].set(k_lo),
        val=state.val.at[seg, b, slot].set(v),
        fp=state.fp.at[seg, b, slot].set(fpv),
    )
    meta = state.meta[seg, b]
    alloc = layout.meta_alloc(meta) | (U32(1) << slot.astype(U32))
    memb = layout.meta_member(meta) | jnp.where(member, U32(1) << slot.astype(U32), U32(0))
    count = layout.meta_count(meta) + U32(1)
    state = state._replace(meta=state.meta.at[seg, b].set(layout.meta_pack(alloc, memb, count)))
    return bump_version(state, seg, b)


def bucket_clear_slot(cfg: DashConfig, state: DashState, seg, b, slot, clear_member=True):
    """Delete = clear alloc bit + decrement count in one packed-word store."""
    meta = state.meta[seg, b]
    bit = U32(1) << slot.astype(U32)
    alloc = layout.meta_alloc(meta) & ~bit
    memb = layout.meta_member(meta)
    memb = jnp.where(clear_member, memb & ~bit, memb)
    count = layout.meta_count(meta) - U32(1)
    state = state._replace(meta=state.meta.at[seg, b].set(layout.meta_pack(alloc, memb, count)))
    return bump_version(state, seg, b)


def find_movable_slot(cfg: DashConfig, state: DashState, seg, b, want_member_set):
    """Displacement helper (Alg. 2): pick an allocated slot whose membership
    bit equals ``want_member_set``. Scanning the bitmap only — no key loads
    (the paper's point: the membership bitmap avoids PM reads)."""
    meta = state.meta[seg, b]
    alloc = layout.meta_alloc(meta)
    memb = layout.meta_member(meta)
    slot_ids = jnp.arange(cfg.num_slots, dtype=U32)
    allocated = ((alloc >> slot_ids) & U32(1)) == 1
    mset = ((memb >> slot_ids) & U32(1)) == 1
    ok = allocated & (mset == want_member_set)
    return jnp.any(ok), jnp.argmax(ok).astype(I32)


def read_slot(state: DashState, seg, b, slot):
    return (state.key_hi[seg, b, slot], state.key_lo[seg, b, slot],
            state.val[seg, b, slot], state.fp[seg, b, slot])


# ---- overflow (stash) metadata on the home bucket --------------------------

def ofp_try_set(cfg: DashConfig, state: DashState, seg, b, fpv, stash_idx, member):
    """Try to record an overflow fingerprint on bucket ``b``.
    Returns (state, ok).

    A successful set bumps the bucket's version word: overflow metadata
    changes what a probe of ``b`` observes, so it must be visible to the
    version-plane verify pass and to the copy-on-write publish (which
    scatters exactly the version-changed bucket rows)."""
    if cfg.num_ofp == 0:
        return state, jnp.asarray(False)
    om = state.ometa[seg, b]
    oa = layout.ometa_ofp_alloc(om)
    ids = jnp.arange(cfg.num_ofp, dtype=U32)
    free = ((oa >> ids) & U32(1)) == 0
    ok = jnp.any(free)
    slot = jnp.argmax(free).astype(I32)
    new_oa = oa | (U32(1) << slot.astype(U32))
    omem = layout.ometa_ofp_member(om)
    new_omem = omem | jnp.where(member, U32(1) << slot.astype(U32), U32(0))
    om2 = (om & ~((U32(0xF) << layout.OFPA_SHIFT) | (U32(0xF) << layout.OFPM_SHIFT)))
    om2 = om2 | (new_oa << layout.OFPA_SHIFT) | (new_omem << layout.OFPM_SHIFT)
    om2 = layout.ometa_set_stash_idx(om2, slot, stash_idx.astype(U32))
    om2 = om2 | (U32(1) << layout.OVFB_SHIFT)
    om_out = jnp.where(ok, om2, om)
    st = state._replace(
        ometa=state.ometa.at[seg, b].set(om_out),
        ofp=jnp.where(ok, state.ofp.at[seg, b, slot].set(fpv), state.ofp),
        version=jnp.where(ok, state.version.at[seg, b].add(U32(2)),
                          state.version),
    )
    return st, ok


def ovf_count_add(state: DashState, seg, b, delta):
    """Adjust the overflow counter (records in stash with no ofp slot).
    Version-bumped like every metadata write (COW dirtiness contract)."""
    om = state.ometa[seg, b]
    cnt = (layout.ometa_ovf_count(om).astype(jnp.int32) + delta).astype(U32)
    om = (om & ~(U32(0x7F) << layout.OVFC_SHIFT)) | ((cnt & U32(0x7F)) << layout.OVFC_SHIFT)
    om = om | (U32(1) << layout.OVFB_SHIFT)
    return bump_version(state._replace(ometa=state.ometa.at[seg, b].set(om)),
                        seg, b)


def ofp_matches(cfg: DashConfig, state: DashState, seg, b, fpv, want_member):
    """(NOFP,) bool — overflow fingerprints on bucket ``b`` that match ``fpv``
    and whose membership equals ``want_member`` (Sec. 4.3 overflow probing)."""
    if cfg.num_ofp == 0:
        return jnp.zeros((0,), jnp.bool_)
    om = state.ometa[seg, b]
    oa = layout.ometa_ofp_alloc(om)
    omem = layout.ometa_ofp_member(om)
    ids = jnp.arange(cfg.num_ofp, dtype=U32)
    allocated = ((oa >> ids) & U32(1)) == 1
    mset = ((omem >> ids) & U32(1)) == 1
    fps = jax.lax.dynamic_slice(state.ofp, (seg, b, 0), (1, 1, 4))[0, 0, :cfg.num_ofp]
    return allocated & (mset == want_member) & (fps == fpv)


def ofp_clear(cfg: DashConfig, state: DashState, seg, b, slot):
    om = state.ometa[seg, b]
    bit = U32(1) << slot.astype(U32)
    oa = layout.ometa_ofp_alloc(om) & ~bit
    omem = layout.ometa_ofp_member(om) & ~bit
    om2 = (om & ~((U32(0xF) << layout.OFPA_SHIFT) | (U32(0xF) << layout.OFPM_SHIFT)))
    om2 = om2 | (oa << layout.OFPA_SHIFT) | (omem << layout.OFPM_SHIFT)
    return bump_version(state._replace(ometa=state.ometa.at[seg, b].set(om2)),
                        seg, b)
