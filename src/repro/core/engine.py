"""Per-key Dash operations and the segment-parallel batched engine.

The paper's Algorithm 1 (insert with bucket load balancing), Algorithm 3
(search) and the delete procedure (Sec. 4.6), expressed as pure functions.

Batching & parallelism model
----------------------------
Dash's scalability claim rests on the *segment* being the unit of
concurrency: operations on different segments never contend (Sec. 4.4).
The batched engine mirrors that exactly:

  - **segment = unit of parallelism.** Mutating batches are routed by
    segment on device (the shared MoE-style dispatcher in
    ``kernels/ops.py``) and all segments run in parallel (``vmap`` over the
    segment axis); only the lanes *within* one segment are applied
    sequentially (``lax.scan``) — the same granularity as the paper's
    per-segment locks. Per-batch critical-path length drops from O(batch)
    to O(max lanes per segment).
  - **batch = unit of consistency.** The routing sort is stable, so lanes
    of one segment keep batch order; segments are disjoint state, so the
    resulting table is bit-identical to the sequential reference
    (``batching="scan"``, kept for differential testing).
  - **reads go through the Pallas fingerprint kernel by default.**
    ``search_batch`` routes queries per segment and scans fingerprints on
    the MXU/VPU (``kernels/probe.py``); only fingerprint hits load keys.
    Stash lanes are covered by a dense compare inside the routed path;
    capacity-overflow lanes and non-eligible configs (pointer mode,
    fingerprints disabled, probe windows > 2) fall back to the per-key
    ``vmap`` path. Lookups stay lock-free/optimistic (Sec. 4.4); version
    verification for concurrent composition lives in serving/engine.py.

Decision structure: every insert computes all candidate placements first
(counts, movable slots, stash occupancy — all cheap packed-word reads), then a
single ``lax.switch`` commits one branch. This is the TPU-native rendering of
Alg. 1's if/elif chain: uniform control flow, no divergence.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import bucket as bk
from . import hashing, layout
from .layout import (DROPPED, EXISTS, INSERTED, NEED_SPLIT, NOT_FOUND,
                     DashConfig, DashState, U32)

I32 = jnp.int32


# ---------------------------------------------------------------------------
# addressing
# ---------------------------------------------------------------------------

def locate(cfg: DashConfig, mode: str, state: DashState, h1):
    """(seg, b) for a hash under EH (MSB directory) or LH (level/next) rules."""
    if mode == "eh":
        seg = state.dir[layout.dir_index(cfg, h1)]
        b = layout.bucket_index(cfg, h1)
    else:
        seg = state.lh_dir[layout.lh_logical_segment(cfg, h1, state.lh_word)]
        b = layout.lh_bucket_index(cfg, h1)
    return seg, b


def _wrap(cfg: DashConfig, b):
    return b & (cfg.num_buckets - 1)


# ---------------------------------------------------------------------------
# segment-scope probe (search + uniqueness check)
# ---------------------------------------------------------------------------

def probe_in_segment(cfg: DashConfig, state: DashState, seg, b, h2,
                     q_hi, q_lo, q_words):
    """Full lookup inside one segment: window buckets, then stash via
    overflow metadata (Alg. 3). Returns (found, value)."""
    fpv = hashing.fingerprint(h2)
    window = cfg.probe_window

    found = jnp.asarray(False)
    value = U32(0)
    for w in range(window):
        bw = _wrap(cfg, b + w)
        f, _, v = bk.bucket_probe(cfg, state, seg, bw, fpv, q_hi, q_lo, q_words)
        value = jnp.where(f & ~found, v, value)
        found = found | f

    if cfg.num_stash == 0:
        return found, value

    # --- stash probing, gated by overflow metadata (Sec. 4.3 / Alg. 3) ---
    if not cfg.use_overflow_meta:
        # ablation (Fig. 10 baseline): no metadata => always scan the stash
        active = state.stash_active[seg]
        for s in range(cfg.num_stash):
            f, _, v2 = bk.bucket_probe(cfg, state, seg, cfg.num_buckets + s,
                                       fpv, q_hi, q_lo, q_words)
            hit = f & (s < active) & ~found
            value = jnp.where(hit, v2, value)
            found = found | hit
        return found, value

    pb = _wrap(cfg, b + 1)
    m_home = bk.ofp_matches(cfg, state, seg, b, fpv, want_member=False)   # (NOFP,)
    m_prob = bk.ofp_matches(cfg, state, seg, pb, fpv, want_member=True)
    scan_all = layout.ometa_ovf_count(state.ometa[seg, b]) > 0

    om_home = state.ometa[seg, b]
    om_prob = state.ometa[seg, pb]
    # which stash buckets are indicated by matching overflow fingerprints
    indicated = jnp.zeros((cfg.num_stash,), jnp.bool_)
    for j in range(cfg.num_ofp):
        sj_h = layout.ometa_stash_idx(om_home, jnp.uint32(j)).astype(I32)
        sj_p = layout.ometa_stash_idx(om_prob, jnp.uint32(j)).astype(I32)
        for s in range(cfg.num_stash):
            indicated = indicated.at[s].set(
                indicated[s] | (m_home[j] & (sj_h == s)) | (m_prob[j] & (sj_p == s)))

    active = state.stash_active[seg]
    for s in range(cfg.num_stash):
        sb = cfg.num_buckets + s
        probe_it = (indicated[s] | scan_all) & (s < active)
        f, _, v = bk.bucket_probe(cfg, state, seg, sb, fpv, q_hi, q_lo, q_words)
        hit = probe_it & f & ~found
        value = jnp.where(hit, v, value)
        found = found | hit
    return found, value


# ---------------------------------------------------------------------------
# insert (Algorithm 1 + Algorithm 2)
# ---------------------------------------------------------------------------

def _write_record(cfg: DashConfig, state: DashState, seg, b, slot,
                  q_hi, q_lo, q_words, v, fpv, member, heap_append=True):
    """bucket_write + pointer-mode key-heap append."""
    if cfg.pointer_mode and heap_append:
        handle = state.heap_top.astype(U32)
        state = state._replace(
            key_heap=jax.lax.dynamic_update_slice(
                state.key_heap, q_words[None, :], (state.heap_top, 0)),
            heap_top=state.heap_top + 1,
        )
        k_lo = handle
    else:
        k_lo = q_lo
    return bk.bucket_write(cfg, state, seg, b, slot, q_hi, k_lo, v, fpv, member)


def _insert_core(cfg: DashConfig, state: DashState, seg, b, h1, h2,
                 q_hi, q_lo, q_words, v, check_unique=True, heap_append=True):
    """Insert into a known segment (used both by the public insert and by
    split-rehash, which bypasses the directory exactly like the paper)."""
    fpv = hashing.fingerprint(h2)
    pb = _wrap(cfg, b + 1)
    NB, SL = cfg.num_buckets, cfg.num_slots

    if check_unique:
        exists, _ = probe_in_segment(cfg, state, seg, b, h2, q_hi, q_lo, q_words)
    else:
        exists = jnp.asarray(False)

    # ---- candidate computation (cheap packed-word reads) ----
    if cfg.use_balanced:
        cb, cp = bk.bucket_count(state, seg, b), bk.bucket_count(state, seg, pb)
        pick_pb = (cp < cb) & (cp < SL) | ((cb >= SL) & (cp < SL))
        can_plain = (cb < SL) | (cp < SL)
        ins_b = jnp.where(pick_pb, pb, b)
        ins_member = pick_pb
    else:
        # linear-probing window (CCEH style / Fig. 11 '+Probing'); member unused
        counts = jnp.stack([bk.bucket_count(state, seg, _wrap(cfg, b + w))
                            for w in range(max(cfg.probe_len, 1))])
        free = counts < SL
        can_plain = jnp.any(free)
        woff = jnp.argmax(free).astype(I32)
        ins_b = _wrap(cfg, b + woff)
        ins_member = jnp.asarray(False)

    # displacement candidates (Alg. 2) — only meaningful in balanced mode
    if cfg.use_balanced and cfg.use_displacement:
        pb2 = _wrap(cfg, b + 2)
        bm1 = _wrap(cfg, b - 1)
        okA_slot, slotA = bk.find_movable_slot(cfg, state, seg, pb, want_member_set=False)
        okA = okA_slot & (bk.bucket_count(state, seg, pb2) < SL)
        okB_slot, slotB = bk.find_movable_slot(cfg, state, seg, b, want_member_set=True)
        okB = okB_slot & (bk.bucket_count(state, seg, bm1) < SL)
    else:
        pb2 = bm1 = b
        slotA = slotB = I32(0)
        okA = okB = jnp.asarray(False)

    # stash candidate: first active stash bucket with a free slot
    active = state.stash_active[seg]
    if cfg.num_stash > 0:
        stash_free = jnp.stack([
            (bk.bucket_count(state, seg, NB + s) < SL) & (s < active)
            for s in range(cfg.num_stash)])
        ok_stash = jnp.any(stash_free)
        st_j = jnp.argmax(stash_free).astype(I32)
        # activation analog for LH chaining: can we open one more stash bucket?
        can_activate = active < cfg.num_stash
        ok_stash_or_new = ok_stash | can_activate
        st_j = jnp.where(ok_stash, st_j, active)          # newly activated index
        stash_activates = ~ok_stash & can_activate
    else:
        ok_stash_or_new = jnp.asarray(False)
        st_j = I32(0)
        stash_activates = jnp.asarray(False)

    # ---- decision (priority: exists > plain > dispA > dispB > stash > split) ----
    code = jnp.where(
        exists, 0,
        jnp.where(can_plain, 1,
                  jnp.where(okA, 2,
                            jnp.where(okB, 3,
                                      jnp.where(ok_stash_or_new, 4, 5)))))

    def br_exists(st):
        return st, I32(EXISTS)

    def br_plain(st):
        _, slot = bk.first_free_slot(cfg, st, seg, ins_b)
        st = _write_record(cfg, st, seg, ins_b, slot, q_hi, q_lo, q_words, v, fpv, ins_member, heap_append)
        return st, I32(INSERTED)

    def br_dispA(st):
        # move a target=pb record from pb to its probing bucket pb2
        mk_hi, mk_lo, mk_v, mk_fp = bk.read_slot(st, seg, pb, slotA)
        _, fs = bk.first_free_slot(cfg, st, seg, pb2)
        st = bk.bucket_write(cfg, st, seg, pb2, fs, mk_hi, mk_lo, mk_v, mk_fp, member=True)
        st = bk.bucket_clear_slot(cfg, st, seg, pb, slotA)
        st = _write_record(cfg, st, seg, pb, slotA, q_hi, q_lo, q_words, v, fpv, member=True, heap_append=heap_append)
        return st, I32(INSERTED)

    def br_dispB(st):
        # move a target=b-1 record (sitting in b with membership set) home to b-1
        mk_hi, mk_lo, mk_v, mk_fp = bk.read_slot(st, seg, b, slotB)
        _, fs = bk.first_free_slot(cfg, st, seg, bm1)
        st = bk.bucket_write(cfg, st, seg, bm1, fs, mk_hi, mk_lo, mk_v, mk_fp, member=False)
        st = bk.bucket_clear_slot(cfg, st, seg, b, slotB)
        st = _write_record(cfg, st, seg, b, slotB, q_hi, q_lo, q_words, v, fpv, member=False, heap_append=heap_append)
        return st, I32(INSERTED)

    def br_stash(st):
        sb = NB + st_j
        st = st._replace(stash_active=st.stash_active.at[seg].set(
            jnp.maximum(st.stash_active[seg], st_j + 1)))
        _, slot = bk.first_free_slot(cfg, st, seg, sb)
        st = _write_record(cfg, st, seg, sb, slot, q_hi, q_lo, q_words, v, fpv, member=False, heap_append=heap_append)
        if not cfg.use_overflow_meta:      # Fig. 10 ablation
            return st, I32(INSERTED)
        # overflow metadata: home bucket first, then probing bucket (Sec. 4.3)
        st1, ok1 = bk.ofp_try_set(cfg, st, seg, b, fpv, st_j, member=False)

        def try_prob(_):
            st2, ok2 = bk.ofp_try_set(cfg, st1, seg, pb, fpv, st_j, member=True)
            st3 = bk.ovf_count_add(st2, seg, b, 1)
            return jax.lax.cond(ok2, lambda s: s[0], lambda s: s[1], (st2, st3))

        st = jax.lax.cond(ok1, lambda _: st1, try_prob, None)
        return st, I32(INSERTED)

    def br_split(st):
        return st, I32(NEED_SPLIT)

    branches = [br_exists, br_plain, br_dispA, br_dispB,
                br_stash if cfg.num_stash > 0 else br_split, br_split]
    state, status = jax.lax.switch(code, branches, state)
    state = state._replace(n_items=state.n_items + (status == INSERTED).astype(I32))
    return state, status, stash_activates & (status == INSERTED) & (code == 4)


# ---------------------------------------------------------------------------
# delete (Sec. 4.6)
# ---------------------------------------------------------------------------

def delete_in_segment(cfg: DashConfig, state: DashState, seg, b, h2,
                      q_hi, q_lo, q_words):
    fpv = hashing.fingerprint(h2)
    window = cfg.probe_window

    # locate in window buckets
    found_w = jnp.asarray(False)
    w_b = I32(0)
    w_slot = I32(0)
    for w in range(window):
        bw = _wrap(cfg, b + w)
        f, slot, _ = bk.bucket_probe(cfg, state, seg, bw, fpv, q_hi, q_lo, q_words)
        take = f & ~found_w
        w_b = jnp.where(take, bw, w_b)
        w_slot = jnp.where(take, slot, w_slot)
        found_w = found_w | f

    # locate in stash
    found_s = jnp.asarray(False)
    s_j = I32(0)
    s_slot = I32(0)
    if cfg.num_stash > 0:
        active = state.stash_active[seg]
        for s in range(cfg.num_stash):
            f, slot, _ = bk.bucket_probe(cfg, state, seg, cfg.num_buckets + s, fpv,
                                         q_hi, q_lo, q_words)
            take = f & (s < active) & ~found_s
            s_j = jnp.where(take, s, s_j)
            s_slot = jnp.where(take, slot, s_slot)
            found_s = found_s | (f & (s < active))

    code = jnp.where(found_w, 0, jnp.where(found_s, 1, 2))

    def br_window(st):
        return bk.bucket_clear_slot(cfg, st, seg, w_b, w_slot), I32(INSERTED)

    def br_stash(st):
        st = bk.bucket_clear_slot(cfg, st, seg, cfg.num_buckets + s_j, s_slot)
        if not cfg.use_overflow_meta:      # Fig. 10 ablation
            return st, I32(INSERTED)
        # clear the matching overflow fingerprint (home first, then probing),
        # else decrement the overflow counter (Sec. 4.6 delete)
        pb = _wrap(cfg, b + 1)
        m_home = bk.ofp_matches(cfg, st, seg, b, fpv, want_member=False)
        m_prob = bk.ofp_matches(cfg, st, seg, pb, fpv, want_member=True)
        om_h, om_p = st.ometa[seg, b], st.ometa[seg, pb]
        idx_h = jnp.stack([layout.ometa_stash_idx(om_h, jnp.uint32(j)).astype(I32)
                           for j in range(cfg.num_ofp)])
        idx_p = jnp.stack([layout.ometa_stash_idx(om_p, jnp.uint32(j)).astype(I32)
                           for j in range(cfg.num_ofp)])
        cand_h = m_home & (idx_h == s_j)
        cand_p = m_prob & (idx_p == s_j)
        has_h, has_p = jnp.any(cand_h), jnp.any(cand_p)
        j_h = jnp.argmax(cand_h).astype(I32)
        j_p = jnp.argmax(cand_p).astype(I32)

        def clear_home(s):
            return bk.ofp_clear(cfg, s, seg, b, j_h)

        def clear_prob_or_count(s):
            return jax.lax.cond(
                has_p,
                lambda x: bk.ofp_clear(cfg, x, seg, pb, j_p),
                lambda x: bk.ovf_count_add(x, seg, b, -1),
                s)

        st = jax.lax.cond(has_h, clear_home, clear_prob_or_count, st)
        return st, I32(INSERTED)

    def br_missing(st):
        return st, I32(NOT_FOUND)

    state, status = jax.lax.switch(
        code, [br_window, br_stash if cfg.num_stash > 0 else br_missing,
               br_missing], state)
    state = state._replace(n_items=state.n_items - (status == INSERTED).astype(I32))
    return state, jnp.where(status == I32(INSERTED), I32(INSERTED), I32(NOT_FOUND))


# ---------------------------------------------------------------------------
# top-level per-key ops (directory lookup + segment op)
# ---------------------------------------------------------------------------

def _query_parts(cfg: DashConfig, q_hi, q_lo, q_words):
    """(h1, h2) for a query. Pointer mode folds the full key words."""
    if cfg.pointer_mode:
        q_hi, q_lo = hashing.key_identity_from_words(q_words)
    h1 = hashing.hash1(q_hi, q_lo)
    h2 = hashing.hash2(q_hi, q_lo)
    return q_hi, q_lo, h1, h2


def insert_one(cfg: DashConfig, mode: str, state: DashState,
               q_hi, q_lo, q_words, v):
    q_hi, q_lo, h1, h2 = _query_parts(cfg, q_hi, q_lo, q_words)
    seg, b = locate(cfg, mode, state, h1)
    return _insert_core(cfg, state, seg, b, h1, h2, q_hi, q_lo, q_words, v)


def search_one(cfg: DashConfig, mode: str, state: DashState, q_hi, q_lo, q_words):
    q_hi, q_lo, h1, h2 = _query_parts(cfg, q_hi, q_lo, q_words)
    seg, b = locate(cfg, mode, state, h1)
    return probe_in_segment(cfg, state, seg, b, h2, q_hi, q_lo, q_words)


def delete_one(cfg: DashConfig, mode: str, state: DashState, q_hi, q_lo, q_words):
    q_hi, q_lo, h1, h2 = _query_parts(cfg, q_hi, q_lo, q_words)
    seg, b = locate(cfg, mode, state, h1)
    return delete_in_segment(cfg, state, seg, b, h2, q_hi, q_lo, q_words)


# ---------------------------------------------------------------------------
# batched APIs
# ---------------------------------------------------------------------------

def _dummy_words(cfg: DashConfig, n: int):
    return jnp.zeros((n, cfg.key_heap_words), U32)


def _pow2_at_least(n: int, floor: int = 8) -> int:
    n = max(int(n), 1)
    return max(floor, 1 << (n - 1).bit_length())


def pallas_search_eligible(cfg: DashConfig) -> bool:
    """Configs the Pallas fingerprint read path covers exactly: inline keys,
    fingerprints on, and a probe window the 2-bucket kernel spans. Everything
    else (ablation baselines, pointer mode) uses the per-key vmap path."""
    from repro.kernels.probe import ROWS
    return (cfg.use_fingerprints and not cfg.pointer_mode
            and (cfg.use_balanced or cfg.probe_len <= 2)
            and cfg.buckets_total <= ROWS)


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2,))
def _insert_batch_scan(cfg: DashConfig, mode: str, state: DashState,
                       keys_hi, keys_lo, vals, words, valid):
    """Sequential reference engine (lax.scan carry = the table). Kept as the
    ``batching="scan"`` mode for differential testing; also serves pointer
    mode, whose global key heap is not segment-local."""
    def step(st, xs):
        hi, lo, w, v, ok = xs

        def do(s):
            return insert_one(cfg, mode, s, hi, lo, w, v)

        def skip(s):
            return s, I32(DROPPED), jnp.asarray(False)

        st, status, act = jax.lax.cond(ok, do, skip, st)
        return st, (status, act)

    state, (statuses, acts) = jax.lax.scan(
        step, state, (keys_hi, keys_lo, words, vals, valid))
    return state, statuses, jnp.any(acts)


# mutable per-segment planes carried through the vmapped intra-segment scan
_SEG_PLANES = ("fp", "ofp", "key_hi", "key_lo", "val", "meta", "ometa",
               "version", "stash_active")


def _segment_parallel(cfg: DashConfig, state: DashState, lanes, body):
    """Run ``body`` over routed lanes: vmap over the segment axis, scan over
    the intra-segment lanes — Dash's locking granularity as a compute
    schedule. ``lanes`` is a pytree of (S, C, ...) planes; ``body`` operates
    on a single-segment view of the table (seg index 0) and must only touch
    ``_SEG_PLANES`` + ``n_items``. Returns (state, outs) where outs are the
    stacked per-lane outputs, shape (S, C, ...)."""
    planes = {k: getattr(state, k) for k in _SEG_PLANES}

    def per_seg(pl, ln):
        st = state._replace(n_items=jnp.asarray(0, I32),
                            **{k: v[None] for k, v in pl.items()})
        st, outs = jax.lax.scan(body, st, ln)
        return {k: getattr(st, k)[0] for k in _SEG_PLANES}, outs, st.n_items

    new_planes, outs, d_items = jax.vmap(per_seg)(planes, lanes)
    state = state._replace(n_items=state.n_items + jnp.sum(d_items),
                           **new_planes)
    return state, outs


def _scatter_statuses(statuses, src, n: int):
    """(S, C) lane statuses -> (Q,) batch statuses; lanes that never got a
    slot (capacity overflow) come back DROPPED so the host retry loop can
    aggregate them with NEED_SPLIT subsets."""
    flat = statuses.reshape(-1)
    src = src.reshape(-1)
    out = jnp.full((n,), -1, I32).at[jnp.clip(src, 0)].max(
        jnp.where(src >= 0, flat, -1))
    return jnp.where(out < 0, I32(DROPPED), out)


@functools.partial(jax.jit, static_argnums=(0, 1, 8), donate_argnums=(2,))
def _insert_batch_segments(cfg: DashConfig, mode: str, state: DashState,
                           keys_hi, keys_lo, vals, words, valid,
                           capacity: int):
    from repro.kernels import ops
    lanes, src, keep = ops.route_writes(
        cfg, mode, state, (keys_hi, keys_lo, vals, words, valid), capacity)

    def body(st, ln):
        def do(s):
            return _insert_core(cfg, s, 0, ln["b"], ln["h1"], ln["h2"],
                                ln["hi"], ln["lo"], ln["words"], ln["val"])

        def skip(s):
            return s, I32(DROPPED), jnp.asarray(False)

        st, status, act = jax.lax.cond(ln["valid"], do, skip, st)
        return st, (status, act)

    state, (statuses, acts) = _segment_parallel(cfg, state, lanes, body)
    return (state, _scatter_statuses(statuses, src, keys_hi.shape[0]),
            jnp.any(acts))


def insert_batch(cfg: DashConfig, mode: str, state: DashState,
                 keys_hi, keys_lo, vals, words=None, valid=None,
                 batching: str = "segment", capacity: int | None = None):
    """Sequentially-consistent batch insert. Returns (state, statuses,
    any_stash_activation).

    ``batching="segment"`` (default) routes by segment and runs all segments
    in parallel; ``"scan"`` is the sequential reference; ``"fused"`` is the
    single-dispatch merged-commit path (kernels/fused.py) the table planner
    selects for small batches. All produce
    bit-identical table state and statuses when ``capacity`` covers the
    largest per-segment lane count (the host wrapper sizes it exactly;
    the default ``capacity=None`` -> next pow2 >= batch covers any skew).
    ``valid`` masks out padding lanes (host pads retry subsets to pow2 sizes
    to avoid shape recompiles).

    Donation discipline: every mutating dispatch donates (consumes) the live
    state's buffers, so a published snapshot must OWN its planes — it can
    alias a previous snapshot's pool-managed buffers (core/epoch.py) but
    never the live arrays passed here."""
    n = keys_hi.shape[0]
    if words is None:
        words = _dummy_words(cfg, n)
    if valid is None:
        valid = jnp.ones(n, jnp.bool_)
    if batching == "fused":
        from repro.kernels import fused
        return fused.fused_insert(cfg, mode, state, keys_hi, keys_lo, vals,
                                  words, valid, capacity)
    if batching == "scan" or cfg.pointer_mode:
        return _insert_batch_scan(cfg, mode, state, keys_hi, keys_lo, vals,
                                  words, valid)
    if capacity is None:
        capacity = _pow2_at_least(n)
    return _insert_batch_segments(cfg, mode, state, keys_hi, keys_lo, vals,
                                  words, valid, min(capacity, _pow2_at_least(n)))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _search_batch_vmap(cfg: DashConfig, mode: str, state: DashState,
                       keys_hi, keys_lo, words):
    fn = lambda hi, lo, w: search_one(cfg, mode, state, hi, lo, w)
    return jax.vmap(fn)(keys_hi, keys_lo, words)


@functools.partial(jax.jit, static_argnums=(0, 1, 6))
def _search_batch_routed(cfg: DashConfig, mode: str, state: DashState,
                         keys_hi, keys_lo, words, capacity: int):
    from repro.kernels import ops
    # only reached on TPU (the dispatcher sends other hosts to probe_direct):
    # run the real Pallas kernel, not its interpreter/jnp stand-ins
    found, vals, keep = ops.probe_routed(cfg, state, keys_hi, keys_lo,
                                         capacity, False, mode)
    if capacity >= keys_hi.shape[0]:
        return found, vals          # no lane can overflow: keep is all-True

    # capacity-overflow lanes: per-key fallback, only traced into the branch
    # actually taken (scalar predicate -> real cond, not a vmap select)
    def fallback(_):
        return _search_batch_vmap(cfg, mode, state, keys_hi, keys_lo, words)

    def none(_):
        return jnp.zeros_like(found), jnp.zeros_like(vals)

    f2, v2 = jax.lax.cond(jnp.any(~keep), fallback, none, None)
    return jnp.where(keep, found, f2), jnp.where(keep, vals, v2)


def search_batch(cfg: DashConfig, mode: str, state: DashState,
                 keys_hi, keys_lo, words=None, batching: str = "auto",
                 capacity: int | None = None):
    """Lock-free batched lookup — pure reads, zero writes (optimistic path).

    Default read path is the Pallas fingerprint kernel over segment-routed
    lanes (``batching="pallas"``); ``"vmap"`` is the per-key path, used
    automatically for configs the kernel does not cover; ``"fused"`` is the
    single-dispatch latency path (kernels/fused.py) the table planner
    selects for small batches. On non-TPU hosts
    the pallas mode runs the kernel's direct-addressed jnp lowering
    (``kernels/ops.py:probe_direct``) — same fingerprint-first read
    discipline, no per-segment lane planes (those are the TPU VMEM
    blocking)."""
    if words is None:
        words = _dummy_words(cfg, keys_hi.shape[0])
    if batching == "fused":
        from repro.kernels import fused
        return fused.fused_search(cfg, mode, state, keys_hi, keys_lo, words,
                                  capacity)
    if batching == "pallas" and not pallas_search_eligible(cfg):
        batching = "vmap"      # fingerprint path would silently miss records
    if batching == "auto":
        batching = "pallas" if pallas_search_eligible(cfg) else "vmap"
    if batching == "vmap":
        return _search_batch_vmap(cfg, mode, state, keys_hi, keys_lo, words)
    if jax.default_backend() != "tpu":
        from repro.kernels import ops
        return ops.probe_direct(cfg, state, keys_hi, keys_lo, mode)
    if capacity is None:
        capacity = _pow2_at_least(keys_hi.shape[0], floor=128)
    return _search_batch_routed(cfg, mode, state, keys_hi, keys_lo, words,
                                capacity)


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2,))
def search_batch_pessimistic(cfg: DashConfig, mode: str, state: DashState,
                             keys_hi, keys_lo, words=None):
    """Fig. 13 baseline: read-locking searches. Every probe 'acquires/releases'
    a read lock = two version-word writes per touched bucket, which also
    serializes the batch (scan, not vmap). Models the PM-write cost the paper
    attributes to pessimistic locking."""
    if words is None:
        words = _dummy_words(cfg, keys_hi.shape[0])

    def step(st, xs):
        hi, lo, w = xs
        q_hi, q_lo, h1, h2 = _query_parts(cfg, hi, lo, w)
        seg, b = locate(cfg, mode, st, h1)
        pb = _wrap(cfg, b + 1)
        st = bk.bump_version(st, seg, b)      # acquire
        st = bk.bump_version(st, seg, pb)
        found, val = probe_in_segment(cfg, st, seg, b, h2, q_hi, q_lo, w)
        st = bk.bump_version(st, seg, b)      # release
        st = bk.bump_version(st, seg, pb)
        return st, (found, val)

    state, (found, vals) = jax.lax.scan(step, state, (keys_hi, keys_lo, words))
    return state, found, vals


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2,))
def _delete_batch_scan(cfg: DashConfig, mode: str, state: DashState,
                       keys_hi, keys_lo, words, valid):
    def step(st, xs):
        hi, lo, w, ok = xs

        def do(s):
            return delete_one(cfg, mode, s, hi, lo, w)

        def skip(s):
            return s, I32(DROPPED)

        st, status = jax.lax.cond(ok, do, skip, st)
        return st, status

    state, statuses = jax.lax.scan(step, state,
                                   (keys_hi, keys_lo, words, valid))
    return state, statuses


@functools.partial(jax.jit, static_argnums=(0, 1, 7), donate_argnums=(2,))
def _delete_batch_segments(cfg: DashConfig, mode: str, state: DashState,
                           keys_hi, keys_lo, words, valid, capacity: int):
    from repro.kernels import ops
    vals = jnp.zeros_like(keys_hi)     # deletes carry no payload
    lanes, src, _ = ops.route_writes(
        cfg, mode, state, (keys_hi, keys_lo, vals, words, valid), capacity)

    def body(st, ln):
        def do(s):
            return delete_in_segment(cfg, s, 0, ln["b"], ln["h2"],
                                     ln["hi"], ln["lo"], ln["words"])

        def skip(s):
            return s, I32(DROPPED)

        st, status = jax.lax.cond(ln["valid"], do, skip, st)
        return st, status

    state, statuses = _segment_parallel(cfg, state, lanes, body)
    return state, _scatter_statuses(statuses, src, keys_hi.shape[0])


def delete_batch(cfg: DashConfig, mode: str, state: DashState,
                 keys_hi, keys_lo, words=None, valid=None,
                 batching: str = "segment", capacity: int | None = None):
    n = keys_hi.shape[0]
    if words is None:
        words = _dummy_words(cfg, n)
    if valid is None:
        valid = jnp.ones(n, jnp.bool_)
    if batching == "scan" or cfg.pointer_mode:
        return _delete_batch_scan(cfg, mode, state, keys_hi, keys_lo, words,
                                  valid)
    if capacity is None:
        capacity = _pow2_at_least(n)
    return _delete_batch_segments(cfg, mode, state, keys_hi, keys_lo, words,
                                  valid, min(capacity, _pow2_at_least(n)))


def update_in_segment(cfg: DashConfig, state: DashState, seg, b, h2,
                      q_hi, q_lo, q_words, v):
    """Set the payload of an existing key within a known segment. The
    touched bucket's version word is bumped like every other write: the
    optimistic snapshot-verify path (Sec. 4.4, serving/) detects stale
    payloads only through version planes, so a silent in-place update would
    be invisible to concurrent readers."""
    fpv = hashing.fingerprint(h2)
    window = cfg.probe_window
    status = I32(NOT_FOUND)
    for wo in range(window):
        bw = _wrap(cfg, b + wo)
        f, slot, _ = bk.bucket_probe(cfg, state, seg, bw, fpv, q_hi, q_lo, q_words)
        do = f & (status == NOT_FOUND)
        state = state._replace(
            val=jnp.where(do, state.val.at[seg, bw, slot].set(v), state.val),
            version=jnp.where(do, state.version.at[seg, bw].add(U32(2)),
                              state.version))
        status = jnp.where(do, I32(INSERTED), status)
    for s in range(cfg.num_stash):
        sb = cfg.num_buckets + s
        f, slot, _ = bk.bucket_probe(cfg, state, seg, sb, fpv, q_hi, q_lo, q_words)
        do = f & (s < state.stash_active[seg]) & (status == NOT_FOUND)
        state = state._replace(
            val=jnp.where(do, state.val.at[seg, sb, slot].set(v), state.val),
            version=jnp.where(do, state.version.at[seg, sb].add(U32(2)),
                              state.version))
        status = jnp.where(do, I32(INSERTED), status)
    return state, status


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2,))
def _update_batch_scan(cfg: DashConfig, mode: str, state: DashState,
                       keys_hi, keys_lo, vals, words, valid):
    def step(st, xs):
        hi, lo, w, v, ok = xs

        def do(s):
            q_hi, q_lo, h1, h2 = _query_parts(cfg, hi, lo, w)
            seg, b = locate(cfg, mode, s, h1)
            return update_in_segment(cfg, s, seg, b, h2, q_hi, q_lo, w, v)

        def skip(s):
            return s, I32(DROPPED)

        st, status = jax.lax.cond(ok, do, skip, st)
        return st, status

    state, statuses = jax.lax.scan(
        step, state, (keys_hi, keys_lo, words, vals, valid))
    return state, statuses


@functools.partial(jax.jit, static_argnums=(0, 1, 8), donate_argnums=(2,))
def _update_batch_segments(cfg: DashConfig, mode: str, state: DashState,
                           keys_hi, keys_lo, vals, words, valid,
                           capacity: int):
    from repro.kernels import ops
    lanes, src, _ = ops.route_writes(
        cfg, mode, state, (keys_hi, keys_lo, vals, words, valid), capacity)

    def body(st, ln):
        def do(s):
            return update_in_segment(cfg, s, 0, ln["b"], ln["h2"],
                                     ln["hi"], ln["lo"], ln["words"],
                                     ln["val"])

        def skip(s):
            return s, I32(DROPPED)

        st, status = jax.lax.cond(ln["valid"], do, skip, st)
        return st, status

    state, statuses = _segment_parallel(cfg, state, lanes, body)
    return state, _scatter_statuses(statuses, src, keys_hi.shape[0])


def update_batch(cfg: DashConfig, mode: str, state: DashState,
                 keys_hi, keys_lo, vals, words=None, valid=None,
                 batching: str = "segment", capacity: int | None = None):
    """Set payload for existing keys (serving cache refresh path). ``valid``
    masks padding lanes exactly like ``insert_batch``, so host-side retry
    subsets can pad to pow2 sizes without recompiling on shape changes."""
    n = keys_hi.shape[0]
    if words is None:
        words = _dummy_words(cfg, n)
    if valid is None:
        valid = jnp.ones(n, jnp.bool_)
    if batching == "scan" or cfg.pointer_mode:
        return _update_batch_scan(cfg, mode, state, keys_hi, keys_lo, vals,
                                  words, valid)
    if capacity is None:
        capacity = _pow2_at_least(n)
    return _update_batch_segments(cfg, mode, state, keys_hi, keys_lo, vals,
                                  words, valid, min(capacity, _pow2_at_least(n)))


# ---------------------------------------------------------------------------
# segment record extraction (split rehash + recovery)
# ---------------------------------------------------------------------------

def segment_records(cfg: DashConfig, state: DashState, seg):
    """All records of a segment: (hi, lo, val, valid) with shape (BT*SLOTS,).
    Pointer-mode lo is the heap handle; rehashing recomputes identity by
    re-folding the heap row (the 'dereference on rehash' cost of Sec. 4.5)."""
    BT, SL = cfg.buckets_total, cfg.num_slots
    hi = jax.lax.dynamic_slice(state.key_hi, (seg, 0, 0), (1, BT, SL))[0].reshape(-1)
    lo = jax.lax.dynamic_slice(state.key_lo, (seg, 0, 0), (1, BT, SL))[0].reshape(-1)
    val = jax.lax.dynamic_slice(state.val, (seg, 0, 0), (1, BT, SL))[0].reshape(-1)
    meta = jax.lax.dynamic_slice(state.meta, (seg, 0), (1, BT))[0]
    alloc = layout.meta_alloc(meta)
    slot_ids = jnp.arange(SL, dtype=U32)[None, :]
    valid = (((alloc[:, None] >> slot_ids) & U32(1)) == 1).reshape(-1)
    return hi, lo, val, valid


def recount_items(state: DashState):
    """Exact global record count from the packed per-bucket counters.

    ``n_items`` is maintained incrementally everywhere (SMOs move records —
    net zero; crash-duplicated slots were never counted, so recovery's
    dedupe restores agreement without touching the total). This full
    recount is the *audit*: tests assert ``n_items == recount_items`` after
    split/merge/shrink/recovery workloads."""
    return jnp.sum(layout.meta_count(state.meta).astype(I32))


@jax.jit
def changed_rows(prev_version, live_version):
    """Flattened per-bucket-row dirty mask between two version planes.

    This is the ground truth the copy-on-write publish scatters by
    (core/epoch.py:SnapshotRegistry.publish_cow): every mutating path —
    insert/delete/update via the bucket helpers, SMO rebuilds via the
    whole-segment bump in ``smo._scatter_planes``, recovery via
    ``recover_segment`` — bumps the version word of every bucket row it
    touches (see core/bucket.py), so ``prev != live`` at the version plane
    is a complete O(dirty) change record with zero extra bookkeeping on the
    write path. The host-side dirty-segment hints (``table.DirtyTracker``,
    derived from the same routing that feeds ``route_lanes``) are audited
    against this mask but never replace it.

    Works for any leading shape: (S, BT) for one table, (n_shards, S, BT)
    for the sharded DHT."""
    return (prev_version != live_version).reshape(-1)


def record_hashes(cfg: DashConfig, state: DashState, hi, lo):
    """(h1, h2) for stored records (handles pointer mode re-fold)."""
    if cfg.pointer_mode:
        rows = state.key_heap[lo % U32(max(cfg.key_heap_size, 1))]
        f_hi = hashing.fold_words(rows, hashing.FOLD_SEED_HI)
        f_lo = hashing.fold_words(rows, hashing.FOLD_SEED_LO)
        return hashing.hash1(f_hi, f_lo), hashing.hash2(f_hi, f_lo)
    return hashing.hash1(hi, lo), hashing.hash2(hi, lo)
