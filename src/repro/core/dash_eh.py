"""Dash-EH: extendible hashing with Dash building blocks (paper Sec. 4).

Segment split is the paper's three-step SMO (Sec. 4.7), expressed as two
jitted phases with a crash-recoverable boundary between them:

  phase 1 (allocate + initialize + link):  mark S SPLITTING, allocate N at the
      pool watermark (PMDK allocate-activate analog: watermark and segment
      init commit atomically in one functional update), chain side links,
      set both local depths, mark N NEW.
  phase 2 (rehash + publish):  redistribute records by the (ld+1)-th MSB,
      update the directory prefix range to point at N, clear SMO states.

Recovery after a crash between (or inside) the phases re-runs phase 2 with
uniqueness checking — exactly the paper's "redo the rehashing with uniqueness
check" (Sec. 4.8). Phase 2 is idempotent under that discipline.

The same two-phase boundary is what makes splits *interleavable*: the staged
pipeline (core/smo.py:BulkSplitTask, pumped one stage per tick by the
online-resize frontend in serving/frontend.py) dispatches phase 1 and
phase 2 on separate scheduler ticks while read batches keep serving an
epoch-pinned snapshot in between.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, hashing, layout
from .layout import (EXISTS, INSERTED, NEED_SPLIT, SEG_NEW, SEG_NORMAL,
                     SEG_SPLITTING, DashConfig, DashState, U32)

I32 = jnp.int32


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def split_phase1(cfg: DashConfig, state: DashState, old_seg, new_seg=None):
    """Allocate + initialize the new segment; returns (state, new_seg).
    ``new_seg`` defaults to the pool watermark; the host may pass a recycled
    id from the merge free-list (PMDK allocate/free analog)."""
    if new_seg is None:
        new_seg = state.watermark
    ld = state.local_depth[old_seg]
    state = state._replace(
        seg_state=state.seg_state.at[old_seg].set(SEG_SPLITTING)
                                 .at[new_seg].set(SEG_NEW),
        side_link=state.side_link.at[new_seg].set(state.side_link[old_seg])
                                 .at[old_seg].set(new_seg),
        local_depth=state.local_depth.at[old_seg].set(ld + 1)
                                      .at[new_seg].set(ld + 1),
        seg_version=state.seg_version.at[new_seg].set(state.gver),
        stash_active=state.stash_active.at[new_seg].set(cfg.num_stash),
        watermark=jnp.maximum(state.watermark, new_seg + 1),
    )
    return state, new_seg


def _clear_segment(cfg: DashConfig, state: DashState, seg):
    """Zero a segment's planes (record identity + metadata words)."""
    BT, NB, SL = cfg.buckets_total, cfg.num_buckets, cfg.num_slots
    z8 = jnp.zeros((1, BT, 16), jnp.uint8)
    return state._replace(
        fp=jax.lax.dynamic_update_slice(state.fp, z8, (seg, 0, 0)),
        ofp=jax.lax.dynamic_update_slice(state.ofp, jnp.zeros((1, NB, 4), jnp.uint8),
                                         (seg, 0, 0)),
        meta=jax.lax.dynamic_update_slice(state.meta, jnp.zeros((1, BT), U32), (seg, 0)),
        ometa=jax.lax.dynamic_update_slice(state.ometa, jnp.zeros((1, NB), U32), (seg, 0)),
    )


@functools.partial(jax.jit, static_argnums=(0, 4), donate_argnums=(1,))
def split_phase2_scan(cfg: DashConfig, state: DashState, old_seg, new_seg,
                      check_unique: bool = False):
    """Per-record scan rehash + directory publish (the reference SMO path,
    retained for differential testing and as the fallback for configs /
    packings the vectorized rebuild does not cover). With
    ``check_unique=True`` (the recovery path) it is idempotent w.r.t.
    records already moved — the paper's "redo the rehashing with uniqueness
    check"; the normal path skips the probe.

    Returns (state, all_refit) — all_refit is False only if a record could not
    be placed in either half (cannot happen for a subset of a feasible
    segment; asserted by the host wrapper).
    """
    n0 = state.n_items                        # splits move records: net zero
    ld_new = state.local_depth[old_seg]       # already ld+1 after phase 1
    ld = ld_new - 1
    hi, lo, val, valid = engine.segment_records(cfg, state, old_seg)
    h1, h2 = engine.record_hashes(cfg, state, hi, lo)
    move_bit = ((h1 >> (U32(31) - ld.astype(U32))) & U32(1)) == 1

    state = _clear_segment(cfg, state, old_seg)

    def step(st, xs):
        r_hi, r_lo, r_val, r_valid, r_h1, r_h2, r_move = xs
        seg = jnp.where(r_move, new_seg, old_seg)
        b = layout.bucket_index(cfg, r_h1)

        def do(s):
            s2, status, _ = engine._insert_core(
                cfg, s, seg, b, r_h1, r_h2, r_hi, r_lo,
                jnp.zeros((cfg.key_heap_words,), U32), r_val,
                check_unique=check_unique, heap_append=False)
            return s2, status

        def skip(s):
            return s, I32(EXISTS)

        st, status = jax.lax.cond(r_valid, do, skip, st)
        return st, status != I32(NEED_SPLIT)

    state, fits = jax.lax.scan(step, state, (hi, lo, val, valid, h1, h2, move_bit))

    # directory publish: among entries owned by old_seg, the half whose
    # (ld+1)-th MSB is 1 now points at new_seg (contiguous under MSB indexing)
    idx = jnp.arange(cfg.dir_size, dtype=I32)
    bit = (idx >> (cfg.dir_depth_max - ld_new)) & 1
    take = (state.dir == old_seg) & (bit == 1)
    state = state._replace(dir=jnp.where(take, new_seg, state.dir))

    gd = state.global_depth
    state = state._replace(
        global_depth=jnp.maximum(gd, ld_new),
        n_doublings=state.n_doublings + (ld_new > gd).astype(I32),
        n_splits=state.n_splits + 1,
        seg_state=state.seg_state.at[old_seg].set(SEG_NORMAL)
                                 .at[new_seg].set(SEG_NORMAL),
        seg_version=state.seg_version.at[old_seg].set(state.gver)
                                     .at[new_seg].set(state.gver),
        n_items=n0,  # incremental accounting: a split never changes the count
        version=state.version.at[old_seg].add(U32(2)).at[new_seg].add(U32(2)),
    )
    return state, jnp.all(fits)


def split_phase2(cfg: DashConfig, state: DashState, old_seg, new_seg,
                 check_unique: bool = False):
    """Rehash + publish through the vectorized SMO engine (one-pass segment
    rebuild, core/smo.py); falls back to the scan rehash for configs or
    packings the rebuild does not cover. Returns (state, all_refit)."""
    from . import smo
    if not smo.rebuild_eligible(cfg):
        return split_phase2_scan(cfg, state, old_seg, new_seg, check_unique)
    old = jnp.asarray(old_seg, jnp.int32).reshape(1)
    new = jnp.asarray(new_seg, jnp.int32).reshape(1)
    state, ok = smo.bulk_split_phase2(cfg, state, old, new,
                                      jnp.ones((1,), jnp.bool_), check_unique)
    if not bool(ok[0]):
        return split_phase2_scan(cfg, state, old_seg, new_seg, check_unique)
    return state, jnp.asarray(True)


def split_segment(cfg: DashConfig, state: DashState, old_seg, new_seg=None,
                  impl: str = "rebuild"):
    """Full SMO = phase 1 + phase 2 (host-visible convenience).
    ``impl="scan"`` forces the per-record reference rehash."""
    if new_seg is not None:
        new_seg = jnp.asarray(new_seg, jnp.int32)
    state, new_seg = split_phase1(cfg, state, jnp.asarray(old_seg, jnp.int32),
                                  new_seg)
    phase2 = split_phase2_scan if impl == "scan" else split_phase2
    return phase2(cfg, state, jnp.asarray(old_seg, jnp.int32), new_seg)


# ---------------------------------------------------------------------------
# merge (the shrink SMO of Sec. 4.7: "when the load factor drops below a
# threshold, segments can be merged to save space")
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def merge_segments_scan(cfg: DashConfig, state: DashState, keep_seg,
                        victim_seg):
    """Per-record scan merge of ``victim`` into its buddy ``keep`` (same
    parent prefix, same local depth) — the reference path, retained for
    differential testing. The caller guarantees the pair is a buddy pair
    and that the combined records fit (host checks counts). The victim's
    directory range is pointed back at ``keep`` and both drop one depth
    level — the inverse of a split. Returns (state, all_refit)."""
    n0 = state.n_items
    hi, lo, val, valid = engine.segment_records(cfg, state, victim_seg)
    h1, h2 = engine.record_hashes(cfg, state, hi, lo)

    def step(st, xs):
        r_hi, r_lo, r_val, r_valid, r_h1, r_h2 = xs
        b = layout.bucket_index(cfg, r_h1)

        def do(s):
            s2, status, _ = engine._insert_core(
                cfg, s, keep_seg, b, r_h1, r_h2, r_hi, r_lo,
                jnp.zeros((cfg.key_heap_words,), U32), r_val,
                check_unique=False, heap_append=False)
            return s2, status

        st, status = jax.lax.cond(r_valid, do, lambda s: (s, I32(EXISTS)), st)
        return st, status != I32(NEED_SPLIT)

    state, fits = jax.lax.scan(step, state, (hi, lo, val, valid, h1, h2))
    state = _clear_segment(cfg, state, victim_seg)

    ld = state.local_depth[keep_seg] - 1
    state = state._replace(
        dir=jnp.where(state.dir == victim_seg, keep_seg, state.dir),
        local_depth=state.local_depth.at[keep_seg].set(ld),
        side_link=state.side_link.at[keep_seg].set(state.side_link[victim_seg]),
        seg_state=state.seg_state.at[victim_seg].set(SEG_NORMAL),
        # both rebuilt segments bump: the cleared victim planes must be as
        # version-visible as the repacked keeper (COW dirtiness contract)
        version=state.version.at[keep_seg].add(U32(2))
                             .at[victim_seg].add(U32(2)),
        n_items=n0,  # incremental accounting: a merge never changes the count
    )
    return state, jnp.all(fits)


def merge_segments(cfg: DashConfig, state: DashState, keep_seg, victim_seg):
    """Merge through the vectorized SMO engine (one-pass rebuild of the
    combined record set); scan fallback mirrors split_phase2."""
    from . import smo
    if not smo.rebuild_eligible(cfg):
        return merge_segments_scan(cfg, state, keep_seg, victim_seg)
    keep = jnp.asarray(keep_seg, jnp.int32).reshape(1)
    victim = jnp.asarray(victim_seg, jnp.int32).reshape(1)
    state, ok = smo.bulk_merge(cfg, state, keep, victim,
                               jnp.ones((1,), jnp.bool_))
    if not bool(ok[0]):
        return merge_segments_scan(cfg, state, keep_seg, victim_seg)
    return state, jnp.asarray(True)


def find_buddy(cfg: DashConfig, state: DashState, seg: int):
    """Host helper: the buddy of ``seg`` is the segment owning the sibling
    prefix at the same local depth (its directory range is adjacent).
    One directory gather — no per-entry scan (see smo.find_buddy_pairs for
    the all-pairs version the shrink planner uses)."""
    dirv = np.asarray(state.dir)
    depths = np.asarray(state.local_depth)
    ld = int(depths[seg])
    if ld == 0:
        return None
    first = int(np.argmax(dirv == seg))
    if dirv[first] != seg:                   # seg owns no directory range
        return None
    prefix = first >> (cfg.dir_depth_max - ld)
    sib_first = (prefix ^ 1) << (cfg.dir_depth_max - ld)
    buddy = int(dirv[sib_first])
    if buddy == seg or int(depths[buddy]) != ld:
        return None
    return buddy
