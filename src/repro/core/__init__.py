"""Dash on TPU — core hash-table library (the paper's contribution).

Public API:
    DashConfig           static configuration / feature flags
    DashEH, DashLH       host-facing dynamic hash tables
    make_state           raw functional state constructor
    engine               batched functional ops (insert/search/delete)
"""
from .layout import (DashConfig, DashState, make_state, load_factor,
                     INSERTED, EXISTS, NEED_SPLIT, DROPPED, NOT_FOUND)
from .table import DashEH, DashLH, DashTable, TableFullError
from . import (bucket, dash_eh, dash_lh, engine, hashing, layout, recovery,
               smo)

__all__ = [
    "DashConfig", "DashState", "make_state", "load_factor",
    "DashEH", "DashLH", "DashTable", "TableFullError",
    "INSERTED", "EXISTS", "NEED_SPLIT", "DROPPED", "NOT_FOUND",
    "bucket", "dash_eh", "dash_lh", "engine", "hashing", "layout", "recovery",
    "smo",
]
