"""Host-facing Dash tables: batch orchestration + split retry + lazy recovery.

The device does the data-plane work (batched probes/inserts, SMOs); the host
plays the role of the paper's "goto retry" loops (Alg. 1 line 31): when a
batch reports NEED_SPLIT, the host runs the SMO and retries the failed subset.
Per-segment lazy recovery (Sec. 4.8) also hooks in here: before touching a
segment whose version mismatches the global V, the accessing *batch* recovers
it — amortizing recovery over runtime exactly as the paper does over accesses.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops

from . import dash_eh, dash_lh, engine, hashing, layout, recovery, smo
from .epoch import DirtyHint
from .layout import (EXISTS, INSERTED, NEED_SPLIT, NOT_FOUND, DashConfig,
                     DashState)


class TableFullError(RuntimeError):
    pass


class DirtyTracker:
    """Host-side dirty-plane accounting for the copy-on-write publish.

    Every mutating path notes the segments it routed writes to (the same
    per-key segment ids that feed ``route_lanes``) plus whether the
    directory changed; the serving frontend drains this at publish time.
    The version-plane diff is the publish's ground truth — the tracker is
    the O(1) host mirror used for observability and audited against the
    device mask (``SnapshotRegistry.hint_misses``). ``note_full`` marks
    mutations outside the version discipline (crash simulation, restart),
    forcing the next publish to copy the whole state."""

    def __init__(self):
        self.segments: set = set()
        self.dir = False
        self.full = False

    def note_segments(self, ids):
        # one vectorized pass: per-key segment arrays arrive on every write
        # batch, but distinct values are bounded by the pool size
        ids = np.asarray(ids).reshape(-1)
        self.segments.update(np.unique(ids[ids >= 0]).tolist())

    def note_dir(self):
        self.dir = True

    def note_full(self):
        self.full = True

    @property
    def any(self) -> bool:
        return self.full or self.dir or bool(self.segments)

    def drain(self) -> DirtyHint:
        hint = DirtyHint(self.segments, self.dir, self.full)
        self.segments = set()
        self.dir = False
        self.full = False
        return hint


@dataclasses.dataclass
class InsertJob:
    """Resumable insert batch: the host state of one ``insert`` retry loop,
    factored out so callers can interleave other work between rounds.

    ``DashTable.insert`` pumps a job to completion inline (stop-the-world
    splits); the online-resize frontend (serving/frontend.py) runs one
    ``insert_round`` per scheduler tick and defers the pressured-segment SMO
    to a staged background task, serving reads from a pinned snapshot in
    between."""
    hi: np.ndarray
    lo: np.ndarray
    w: Optional[np.ndarray]
    vals: np.ndarray
    out: np.ndarray                  # per-input statuses (NEED_SPLIT until done)
    pending: np.ndarray              # input indices still unplaced
    first: bool = True               # first round: full batch, lazy recovery
    cap_used: Optional[int] = None   # sticky lane capacity across retry rounds
    rounds: int = 0

    @property
    def done(self) -> bool:
        return self.pending.size == 0


# Largest batch that takes the fused single-dispatch latency path by
# default. Calibrated on the batch_parallel latency rows: at 256 the fused
# insert ran ~6x the scan engine and the fused read ~1.3x vmap on CPU; by
# 4096 the routed/segment engines win on throughput. 1024 is the crossover
# region's conservative edge.
FUSED_THRESHOLD_DEFAULT = 1024


class DashTable:
    """Shared host logic; subclasses define addressing + pressure handling.

    ``smo_mode="bulk"`` (default) routes structural modifications through the
    device-parallel SMO engine (core/smo.py): all segments pressured in one
    batch round split in a single dispatch with one directory publish.
    ``smo_mode="scalar"`` keeps the per-segment reference path (one scan-rehash
    dispatch per SMO) — the differential baseline."""

    mode: str = "eh"

    def __init__(self, cfg: DashConfig, lazy_recovery: bool = True,
                 smo_mode: str = "bulk",
                 state: Optional[DashState] = None,
                 fused_threshold: Optional[int] = None):
        self.cfg = cfg
        # batches at or under this size take the fused single-dispatch
        # latency path (kernels/fused.py); 0 forces the routed/vmap paths,
        # a huge value forces fused everywhere. Default calibrated by
        # benchmarks/batch_parallel.py's latency rows (see README
        # "Latency path").
        self.fused_threshold = (FUSED_THRESHOLD_DEFAULT
                                if fused_threshold is None
                                else int(fused_threshold))
        # `state` restores a persisted table (persist.reopen) without
        # paying a throwaway full-pool allocation
        self.state: DashState = state if state is not None \
            else layout.make_state(cfg, self.mode)
        self.lazy_recovery = lazy_recovery
        self.smo_mode = smo_mode
        self.recovered_segments = 0   # stat: lazy recoveries performed
        self.free_segments: list = []  # merged-away ids, recycled by splits
        self.dirty = DirtyTracker()   # dirty planes since the last publish
        self.writeback = None         # durable PM-pool engine (persist/)
        self.lost_report: list = []   # quarantined rows from a verified reopen
        self.obs = None               # observability bundle (obs/), optional

    # -- key plumbing --------------------------------------------------------

    def _split_keys(self, keys):
        keys = np.asarray(keys, dtype=np.uint64)
        hi, lo = hashing.np_split_keys(keys)
        return jnp.asarray(hi), jnp.asarray(lo), None

    def _key_words(self, words):
        """Pointer mode: keys come as (n, W) uint32 padded word rows."""
        words = np.asarray(words, dtype=np.uint32)
        assert words.shape[1] == self.cfg.key_heap_words
        hi = hashing.np_fold_words(words, hashing.FOLD_SEED_HI)
        lo = hashing.np_fold_words(words, hashing.FOLD_SEED_LO)
        return jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(words)

    def _prep(self, keys=None, words=None):
        if self.cfg.pointer_mode:
            assert words is not None, "pointer mode takes `words` (n, W) uint32"
            return self._key_words(words)
        return self._split_keys(keys)

    # -- host-visible routing (lazy recovery + batch planning) ----------------

    def _segments_of(self, hi, lo) -> np.ndarray:
        """Physical segment of every key (host mirror of engine.locate)."""
        h1 = hashing.np_hash1(np.asarray(hi), np.asarray(lo))
        if self.mode == "eh":
            dirv = np.asarray(self.state.dir)
            return dirv[h1 >> np.uint32(32 - self.cfg.dir_depth_max)]
        word = int(np.asarray(self.state.lh_word))
        level, nxt = word >> 24, word & 0xFFFFFF
        mask_lo = (1 << (self.cfg.lh_base_log2 + level)) - 1
        seg = (h1 & np.uint32(mask_lo)).astype(np.int64)
        mask_hi = (mask_lo << 1) | 1
        seg2 = (h1 & np.uint32(mask_hi)).astype(np.int64)
        logical = np.where(seg < nxt, seg2, seg)
        return np.asarray(self.state.lh_dir)[logical]

    def _touched_segments(self, hi, lo) -> np.ndarray:
        return np.unique(self._segments_of(hi, lo))

    _pow2 = staticmethod(engine._pow2_at_least)

    @staticmethod
    def _lane_quantum(n: int, floor: int = 8) -> int:
        """Round lane capacity up to a pow2 or 1.5*pow2 level: capacity is
        the intra-segment critical path, so pure pow2 rounding wastes up to
        2x sequential steps; the extra half-steps keep jit recompiles to
        ~2 levels per octave."""
        n = max(int(n), 1)
        p = max(floor, 1 << (n - 1).bit_length())
        mid = p // 2 + p // 4          # the 1.5*pow2 level below p
        return mid if n <= mid and mid >= floor else p

    @staticmethod
    def _max_per_segment(seg: np.ndarray) -> int:
        live = seg[seg >= 0]
        return int(np.bincount(live).max()) if live.size else 1

    def _write_plan(self, seg: np.ndarray, n_total: int, fused_ok: bool = True):
        """(batching, capacity) for a mutating batch, from the per-key
        segment ids (computed once per op, shared with lazy recovery).

        The host sees the directory, so it can size the per-segment lane
        capacity exactly (max keys routed to one segment — padding lanes sit
        after real keys in batch order, so they can only overflow, never
        displace). Small batches (<= ``fused_threshold``) take the fused
        merged-commit path — one dispatch, no per-lane branch merging —
        sized with the same exact lane capacity. Segment-parallel wins when
        the critical path (capacity) is meaningfully shorter than the batch;
        a freshly-created table with 2 segments has no parallelism to
        exploit, so it stays on the scan engine until splits spread the
        directory. ``fused_ok=False`` (delete/update, which have no fused
        engine) skips the latency path."""
        capacity = self._lane_quantum(self._max_per_segment(seg))
        if (fused_ok and n_total <= self.fused_threshold
                and ops.fused_insert_eligible(self.cfg)):
            return "fused", capacity
        if capacity * 4 <= self._pow2(n_total):
            return "segment", capacity
        return "scan", None

    def _search_plan(self, seg: np.ndarray):
        """(batching, capacity) for a read batch: the fused single-dispatch
        path for small batches (its whole point is killing per-stage launch
        overhead), the Pallas fingerprint path for large batches on eligible
        configs, per-key vmap otherwise."""
        if seg.size <= self.fused_threshold and ops.fused_search_eligible(self.cfg):
            return "fused", None
        if seg.size >= 256 and engine.pallas_search_eligible(self.cfg):
            return "pallas", self._pow2(self._max_per_segment(seg), floor=128)
        return "vmap", None

    def _ensure_recovered(self, touched: np.ndarray):
        """Lazy per-segment recovery over precomputed touched segment ids."""
        if not self.lazy_recovery:
            return

        def note(seg, affected):
            # recovery may continue an in-flight SMO: the side-linked
            # neighbor (either direction) and the directory are fair game
            self.dirty.note_segments(affected)
            self.dirty.note_dir()

        self.state, recovered = recovery.lazy_recover_touched(
            self.cfg, self.mode, self.state, touched, note=note)
        self.recovered_segments += len(recovered)
        if self.obs is not None:
            for seg in recovered:
                self.obs.registry.counter("table.lazy_recoveries").inc()
                self.obs.tracer.instant("lazy_recovery", "recovery",
                                        segment=seg)

    # -- public ops -----------------------------------------------------------

    def insert_begin(self, keys=None, values=None, words=None) -> InsertJob:
        """Start a resumable insert batch (see InsertJob)."""
        hi_j, lo_j, w_j = self._prep(keys, words)
        hi, lo = np.asarray(hi_j), np.asarray(lo_j)
        w = None if w_j is None else np.asarray(w_j)
        vals = np.asarray(values, dtype=np.uint32)
        return InsertJob(hi=hi, lo=lo, w=w, vals=vals,
                         out=np.full(hi.shape[0], NEED_SPLIT, dtype=np.int32),
                         pending=np.arange(hi.shape[0]))

    def insert_round(self, job: InsertJob) -> bool:
        """One insert dispatch over the job's pending subset. Updates
        ``job.out``/``job.pending``; does NOT run SMOs — the caller decides
        whether to split inline (``insert``) or defer to a background task
        (the frontend). Returns the LH stash-activation signal."""
        hi, lo, w, vals, pending = job.hi, job.lo, job.w, job.vals, job.pending
        # per-key segments: recomputed each round (splits remap keys),
        # shared by recovery, the batch plan, and the failure hints
        seg = self._segments_of(hi[pending], lo[pending])
        self.dirty.note_segments(seg)            # the dispatch writes there
        if job.first:
            self._ensure_recovered(seg)
            idx, valid = pending, None           # full batch, no padding
        else:
            # pad retry subsets to pow2 so jit shapes are reused
            n = self._pow2(pending.size)
            idx = np.concatenate([pending, np.zeros(n - pending.size, np.int64)])
            valid = jnp.asarray(np.arange(n) < pending.size)
        batching, capacity = self._write_plan(seg, idx.size)
        if batching in ("segment", "fused"):
            # sticky lane capacity: splits shrink the per-segment max
            # every retry round, and each fresh capacity is a fresh jit
            # trace — reusing the first round's (clamped to the padded
            # batch) keeps the retry loop on already-compiled code
            if job.cap_used is not None and capacity < job.cap_used:
                capacity = min(job.cap_used, self._pow2(idx.size))
            job.cap_used = capacity
        self.state, statuses, activated = engine.insert_batch(
            self.cfg, self.mode, self.state,
            jnp.asarray(hi[idx]), jnp.asarray(lo[idx]),
            jnp.asarray(vals[idx]),
            None if w is None else jnp.asarray(w[idx]), valid,
            batching=batching, capacity=capacity)
        statuses = np.asarray(statuses)[:pending.size]
        job.out[pending] = statuses
        job.pending = pending[statuses == NEED_SPLIT]
        job.first = False
        job.rounds += 1
        return bool(activated)

    def pressure_hints(self, job: InsertJob) -> np.ndarray:
        """Touched segments of the job's pending keys, computed from the
        CURRENT directory: lazy recovery (or an LH activation split) may
        have republished it since the round was routed — stale hints would
        split the wrong segment."""
        return self._touched_segments(job.hi[job.pending], job.lo[job.pending])

    def insert(self, keys=None, values=None, words=None, max_retries: int = 256):
        """Stop-the-world insert: pump the resumable job, splitting inline
        whenever a round reports pressure (the paper's 'goto retry' loop)."""
        job = self.insert_begin(keys, values, words)
        for _ in range(max_retries):
            activated = self.insert_round(job)
            if activated:
                self._on_pressure(None)   # LH: stash-allocation split trigger
            if job.done:
                return job.out
            self._on_pressure(self.pressure_hints(job))
        raise TableFullError("insert retry budget exhausted")

    def search(self, keys=None, words=None):
        hi, lo, w = self._prep(keys, words)
        seg = self._segments_of(hi, lo)
        self._ensure_recovered(seg)
        batching, capacity = self._search_plan(seg)
        found, vals = engine.search_batch(self.cfg, self.mode, self.state,
                                          hi, lo, w, batching=batching,
                                          capacity=capacity)
        return np.asarray(found), np.asarray(vals)

    def delete(self, keys=None, words=None):
        hi, lo, w = self._prep(keys, words)
        seg = self._segments_of(hi, lo)
        self._ensure_recovered(seg)
        self.dirty.note_segments(seg)
        batching, capacity = self._write_plan(seg, seg.size, fused_ok=False)
        self.state, statuses = engine.delete_batch(
            self.cfg, self.mode, self.state, hi, lo, w,
            batching=batching, capacity=capacity)
        return np.asarray(statuses)

    def update(self, keys=None, values=None, words=None):
        hi, lo, w = self._prep(keys, words)
        seg = self._segments_of(hi, lo)
        self._ensure_recovered(seg)
        self.dirty.note_segments(seg)
        vals = jnp.asarray(np.asarray(values, dtype=np.uint32))
        batching, capacity = self._write_plan(seg, seg.size, fused_ok=False)
        self.state, statuses = engine.update_batch(
            self.cfg, self.mode, self.state, hi, lo, vals, w,
            batching=batching, capacity=capacity)
        return np.asarray(statuses)

    # -- lifecycle / stats ----------------------------------------------------

    def attach_writeback(self, wb):
        """Bind a durable PM-pool writeback engine (persist/writeback.py);
        ``flush()`` (and the serving frontend's publish) then mirror every
        acknowledged batch into the pool in O(dirty) bytes."""
        self.writeback = wb
        if self.obs is not None:
            wb.attach_obs(self.obs)

    def attach_obs(self, obs):
        """Bind an observability bundle (obs/): the table counts lazy
        recoveries and staged SMOs into its registry and propagates the
        bundle to an attached writeback (flush spans, scrub counters)."""
        self.obs = obs
        if self.writeback is not None:
            self.writeback.attach_obs(obs)

    def flush(self) -> int:
        """Make the live state durable: drain the dirty tracker and write
        only the dirty planes to the attached pool (ordered flush+fence —
        the acknowledgment point of the durable contract). Returns bytes
        written."""
        assert self.writeback is not None, "no pool attached (persist.create)"
        return self.writeback.flush(self.state, self.dirty.drain())

    def close(self):
        """Durable clean shutdown: set the clean marker and flush, so the
        next ``persist.reopen`` skips recovery entirely (paper Sec. 4.8's
        graceful path)."""
        self.graceful_shutdown()
        if self.writeback is not None:
            self.flush()
            self.writeback.pool.close()

    def graceful_shutdown(self):
        self.state = self.state._replace(clean=jnp.asarray(True))

    def restart(self):
        """Instant recovery (Sec. 4.8): O(1) work, constant in data size.
        (Volatile restart of the in-memory state; the durable equivalent —
        map the pool, read the superblock, same constant work — is
        ``persist.reopen``.)"""
        self.state, work = recovery.instant_restart(self.state)
        self.dirty.note_full()   # lazy recovery will rewrite at first touch
        return work

    def crash(self, rng: Optional[np.random.Generator] = None, **kw):
        # crash surgery rewrites planes WITHOUT version bumps — the next
        # COW publish (and durable flush) must not trust the version diff.
        # With a pool attached, `crash(); flush()` emulates the paper's
        # crash-with-artifacts-IN-PM: the artifacts land durably and the
        # reopened pool must lazily recover them (tests/test_persist.py).
        self.dirty.note_full()
        self.state = recovery.simulate_crash(self.cfg, self.mode, self.state,
                                             rng or np.random.default_rng(0), **kw)

    @property
    def load_factor(self) -> float:
        return float(np.asarray(layout.load_factor(self.cfg, self.state)))

    @property
    def n_items(self) -> int:
        return int(np.asarray(self.state.n_items))

    @property
    def n_segments(self) -> int:
        return int(np.asarray(self.state.watermark))

    def _on_pressure(self, seg_hint):
        raise NotImplementedError

    def smo_task_eligible(self) -> bool:
        """True iff pressure SMOs run through the staged bulk pipeline (the
        path the online-resize frontend can defer/interleave)."""
        return self.smo_mode == "bulk" and smo.rebuild_eligible(self.cfg)

    def make_smo_task(self, seg_hint):
        """Plan a deferred SMO for the pressured segments and return a staged
        task (``pump(state) -> (state, done)``; see core/smo.py). Returns
        None when the signal needs no SMO (e.g. EH stash activation).
        Raises TableFullError exactly like the inline path."""
        raise NotImplementedError

    def _pump_smo(self, task):
        """Stop-the-world rendering of a staged SMO task: run every stage
        inline, then surface a planning shortfall as pool exhaustion (the
        feasible splits still landed first, same as the old inline path)."""
        self.note_smo(task)
        done = False
        while not done:
            self.state, done = task.pump(self.state)
        if task.shortfall:
            raise TableFullError("segment pool exhausted")

    def note_smo(self, task):
        """Record a staged SMO's dirty footprint (rebuilt + directory
        planes) — callers pumping a task themselves (the online-resize
        frontend) invoke this once per task."""
        self.dirty.note_segments(task.touched)
        self.dirty.note_dir()
        if self.obs is not None:
            self.obs.registry.counter("table.smo_tasks").inc()
            self.obs.registry.counter("table.smo_segments").inc(
                int(np.asarray(task.touched).size))


class DashEH(DashTable):
    """Dash extendible hashing (paper Sec. 4)."""

    mode = "eh"

    def _check_depth(self, segs):
        """Shared depth-exhaustion guard of the inline and staged paths."""
        depths = np.asarray(self.state.local_depth)
        for seg in segs:
            if depths[seg] >= self.cfg.dir_depth_max:
                raise TableFullError("directory depth exhausted")

    def make_smo_task(self, seg_hint):
        """Bulk EH pressure plan: allocate every new id up front (recycled
        merge victims first, then the pool watermark) so all pressured
        segments split in one staged pipeline with one directory publish."""
        if seg_hint is None:
            return None                 # EH ignores stash-activation signals
        segs = [int(s) for s in np.asarray(seg_hint).reshape(-1)]
        self._check_depth(segs)
        wm = int(np.asarray(self.state.watermark))
        new_ids = []
        for _ in segs:
            if self.free_segments:
                new_ids.append(self.free_segments.pop())
            elif wm < self.cfg.max_segments:
                new_ids.append(wm)
                wm += 1
            else:
                break
        if not new_ids:
            raise TableFullError("segment pool exhausted")
        return smo.BulkSplitTask(self.cfg, segs[:len(new_ids)], new_ids,
                                 shortfall=len(segs) - len(new_ids))

    def _on_pressure(self, seg_hint):
        if seg_hint is None:
            return                      # EH ignores stash-activation signals
        if not self.smo_task_eligible():
            segs = [int(s) for s in np.asarray(seg_hint).reshape(-1)]
            self._check_depth(segs)
            return self._on_pressure_scalar(segs)
        task = self.make_smo_task(seg_hint)
        if task is not None:
            self._pump_smo(task)

    def _on_pressure_scalar(self, segs):
        """Reference path: one scan-rehash SMO dispatch per segment."""
        wm = int(np.asarray(self.state.watermark))
        for seg in segs:
            new_id = self.free_segments.pop() if self.free_segments else None
            if new_id is None and wm >= self.cfg.max_segments:
                raise TableFullError("segment pool exhausted")
            self.dirty.note_segments([seg, wm if new_id is None else new_id])
            self.dirty.note_dir()
            self.state, ok = dash_eh.split_segment(self.cfg, self.state, seg,
                                                   new_id, impl="scan")
            if not bool(ok):
                raise AssertionError("split rehash failed to refit records")
            wm += 1

    @property
    def global_depth(self) -> int:
        return int(np.asarray(self.state.global_depth))

    def shrink(self, target_fill: float = 0.8, max_merges: int = 10**6) -> int:
        """Merge buddy segment pairs while their combined records fit under
        ``target_fill`` of one segment (paper Sec. 4.7: merge on low load
        factor). Freed ids are recycled by future splits. Returns merges.

        Planning is one vectorized buddy-pair scan + one counts pass per
        round (not per merge), and the bulk path merges every fitting pair
        of a round in a single device dispatch; cascading merges (pairs that
        only become buddies after their neighbors merged) land in the next
        round."""
        cap = int(self.cfg.seg_capacity * target_fill)
        use_bulk = self.smo_mode == "bulk" and smo.rebuild_eligible(self.cfg)
        merges = 0
        while merges < max_merges:
            counts = self._segment_counts()
            dirv = np.asarray(self.state.dir)
            depths = np.asarray(self.state.local_depth)
            pairs = smo.find_buddy_pairs(self.cfg, dirv, depths)
            if pairs.size:
                pairs = pairs[counts[pairs[:, 0]] + counts[pairs[:, 1]] <= cap]
            if pairs.size == 0:
                return merges
            pairs = pairs[:max_merges - merges]
            c0, c1 = counts[pairs[:, 0]], counts[pairs[:, 1]]
            victim = np.where(c0 <= c1, pairs[:, 0], pairs[:, 1])
            keep = np.where(c0 <= c1, pairs[:, 1], pairs[:, 0])
            self.dirty.note_segments(pairs)
            self.dirty.note_dir()
            if use_bulk:
                # fixed-size chunks: every dispatch shares ONE jit trace
                # (per-round K values would each compile their own)
                C = 8
                for j in range(0, pairs.shape[0], C):
                    kc, vc = keep[j:j + C], victim[j:j + C]
                    K = kc.size
                    kj = jnp.asarray(np.concatenate(
                        [kc, np.full(C - K, -1)]).astype(np.int32))
                    vj = jnp.asarray(np.concatenate(
                        [vc, np.full(C - K, -1)]).astype(np.int32))
                    ok_mask = jnp.asarray(np.arange(C) < K)
                    self.state, ok = smo.bulk_merge(self.cfg, self.state,
                                                    kj, vj, ok_mask)
                    for i in np.nonzero(~np.asarray(ok)[:K])[0]:
                        self.state, ok1 = dash_eh.merge_segments_scan(
                            self.cfg, self.state, int(kc[i]), int(vc[i]))
                        assert bool(ok1)
            else:
                for k, v in zip(keep, victim):
                    self.state, ok1 = dash_eh.merge_segments_scan(
                        self.cfg, self.state, int(k), int(v))
                    assert bool(ok1)
            self.free_segments.extend(int(v) for v in victim)
            merges += pairs.shape[0]
        return merges

    def _segment_counts(self) -> np.ndarray:
        meta = np.asarray(self.state.meta)
        return ((meta >> layout.COUNT_SHIFT) & 0xF).sum(axis=1)


class DashLH(DashTable):
    """Dash linear hashing (paper Sec. 5)."""

    mode = "lh"

    #: bulk expansion stride (paper Sec. 5.2 hybrid expansion: grow by a
    #: segment-array stride, not one segment — dash_lh.
    #: hybrid_expansion_directory derives the stride-8 directory accounting)
    expansion_stride = 8

    def _check_headroom(self):
        """(level, nxt, round_size) after the pool/round bound checks the
        inline and deferred paths share."""
        cfg = self.cfg
        wm = int(np.asarray(self.state.watermark))
        if wm >= cfg.max_segments:
            raise TableFullError("segment pool exhausted")
        word = int(np.asarray(self.state.lh_word))
        level, nxt = word >> 24, word & 0xFFFFFF
        round_size = (1 << cfg.lh_base_log2) << level
        if round_size + nxt >= cfg.max_segments:
            raise TableFullError("lh directory exhausted")
        return wm, nxt, round_size

    def make_smo_task(self, seg_hint=None):
        """Bulk stride expansion plan: split Next..Next+R-1 in one staged
        dispatch, capped at the round boundary and the pool/directory
        headroom. LH pressure ignores the segment hint (it always splits at
        Next, Sec. 5.3)."""
        cfg = self.cfg
        wm, nxt, round_size = self._check_headroom()
        R = max(1, min(self.expansion_stride, round_size - nxt,
                       cfg.max_segments - wm,
                       cfg.max_segments - (round_size + nxt)))
        old_phys = np.asarray(self.state.lh_dir)[nxt:nxt + R]
        return smo.BulkSplitNextTask(
            cfg, R, touched=np.concatenate([old_phys, wm + np.arange(R)]))

    def _on_pressure(self, seg_hint):
        if not self.smo_task_eligible():
            wm, nxt, _ = self._check_headroom()
            self.dirty.note_segments(
                [int(np.asarray(self.state.lh_dir)[nxt]), wm])
            self.state, ok = dash_lh.split_next_scan(self.cfg, self.state)
            if not bool(ok):
                raise AssertionError("LH split rehash failed to refit records")
            return
        self._pump_smo(self.make_smo_task(seg_hint))

    @property
    def active_segments(self) -> int:
        return dash_lh.lh_active_segments(self.cfg, self.state)
