"""Dash table memory layout for TPU: packed metadata words + state pytree.

Mirrors the paper's bucket layout (Fig. 4) with TPU-native array planes:

  - a bucket has ``num_slots`` (default 14) record slots,
  - a contiguous fingerprint plane (1 byte/slot, padded to 16 lanes),
  - 4 overflow fingerprints ("ofp") summarizing this bucket's records that
    overflowed into the segment's stash buckets,
  - one *packed* 32-bit metadata word per bucket — the atomic publish point
    (alloc bitmap | membership bitmap | count), exactly the word Dash persists
    with a single CLWB (Alg. 2 line 16),
  - one packed overflow-metadata word ("ometa"),
  - a version word per bucket (bit 0 = lock bit, bits 1.. = version) for the
    optimistic-concurrency analog (Sec. 4.4).

A segment is ``num_buckets`` normal buckets followed by ``num_stash`` stash
buckets (same layout, paper Sec. 4.3). All segments live in one preallocated
pool (PM pool analog); "allocating" a segment bumps ``watermark``.

The extendible-hashing directory is stored *fully expanded* at
``2**dir_depth_max`` entries: entry ``i`` maps the ``dir_depth_max``-bit MSB
prefix ``i`` of ``h1`` to a physical segment id. Doubling the directory is
then metadata-only (``global_depth += 1``) and a segment split updates a
contiguous prefix range of entries — the TPU adaptation of "directory entries
pointing to the same segment are co-located under MSB addressing" (Sec. 4.7).

Feature flags reproduce the paper's ablation stack (Fig. 11): plain
bucketized -> +linear probing -> +balanced insert/displacement -> +stash,
and express the CCEH baseline (4 slots, probe-4, no fp/stash) in the same
engine so comparisons isolate the algorithm.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# Status codes returned by mutating ops.
INSERTED = 0
EXISTS = 1
NEED_SPLIT = 2     # no room even in stash: host must split and retry
DROPPED = 3        # insert_nosplit only: record dropped (counted)
NOT_FOUND = 4      # delete/update of an absent key

# Segment SMO states (Sec. 4.7).
SEG_NORMAL = 0
SEG_SPLITTING = 1
SEG_NEW = 2

U32 = jnp.uint32
_ONE = np.uint32(1)


@dataclasses.dataclass(frozen=True)
class DashConfig:
    """Static configuration (hashable; safe as a jit static arg)."""
    num_buckets: int = 64          # normal buckets / segment (power of 2)
    num_stash: int = 2             # stash buckets / segment (0 disables stashing)
    num_slots: int = 14            # record slots / bucket (<= 14: count fits 4 bits... 15 ok too)
    num_ofp: int = 4               # overflow fingerprint slots / bucket
    max_segments: int = 64         # preallocated segment pool size
    dir_depth_max: int = 12        # fully-expanded directory = 2**this entries
    init_depth: int = 1            # initial global/local depth (EH); init segs = 2**this
    # --- feature flags (paper Fig. 11 ablation stack) ---
    use_fingerprints: bool = True
    use_balanced: bool = True      # balanced insert (b vs b+1, pick emptier)
    use_displacement: bool = True
    use_overflow_meta: bool = True # Fig. 10: off => every probe scans stash
    probe_len: int = 2             # insert/search window when balanced=False (CCEH uses 4)
    # --- LH-specific ---
    lh_base_log2: int = 2          # N0 = 2**this initial segments for linear hashing
    lh_base_stash: int = 2         # fixed stash buckets before chaining (Sec. 5.1)
    # --- misc ---
    pointer_mode: bool = False     # variable-length keys via key-heap handles
    key_heap_size: int = 0         # number of key-heap entries (pointer mode)
    key_heap_words: int = 4        # u32 words per heap key (16 bytes default)

    def __post_init__(self):
        assert self.num_buckets & (self.num_buckets - 1) == 0, "num_buckets must be pow2"
        assert 1 <= self.num_slots <= 14
        assert 0 <= self.num_ofp <= 4
        assert self.init_depth <= self.dir_depth_max

    @property
    def buckets_total(self) -> int:
        return self.num_buckets + self.num_stash

    @property
    def bucket_bits(self) -> int:
        return int(np.log2(self.num_buckets))

    @property
    def dir_size(self) -> int:
        return 1 << self.dir_depth_max

    @property
    def probe_window(self) -> int:
        """Buckets a record may land in from its home bucket onward: the
        balanced b/(b+1) pair, or the linear-probing window. The single
        source of truth shared by search, delete, update, and the SMO
        rebuild's spill schedule."""
        return 2 if self.use_balanced else max(self.probe_len, 1)

    @property
    def seg_capacity(self) -> int:
        return self.buckets_total * self.num_slots

    def bytes_per_segment(self) -> int:
        bt, ns = self.buckets_total, self.num_slots
        return bt * 16 + self.num_buckets * 4 + bt * ns * 12 + bt * 12  # fp+ofp+records+words


# --- packed word: meta = alloc(14 bits) | membership(14 bits) | count(4 bits) ---
ALLOC_SHIFT, MEMBER_SHIFT, COUNT_SHIFT = 0, 14, 28
SLOT_MASK = (1 << 14) - 1


def meta_alloc(meta):
    return (meta >> ALLOC_SHIFT) & U32(SLOT_MASK)


def meta_member(meta):
    return (meta >> MEMBER_SHIFT) & U32(SLOT_MASK)


def meta_count(meta):
    return (meta >> COUNT_SHIFT) & U32(0xF)


def meta_pack(alloc, member, count):
    return (alloc.astype(U32) << ALLOC_SHIFT) | (member.astype(U32) << MEMBER_SHIFT) | (
        count.astype(U32) << COUNT_SHIFT)


# --- packed word: ometa = ofp_alloc(4) | ofp_member(4) | stash_idx(2b x4) | ovf_cnt(7) | ovf_bit(1) ---
OFPA_SHIFT, OFPM_SHIFT, SIDX_SHIFT, OVFC_SHIFT, OVFB_SHIFT = 0, 4, 8, 16, 23


def ometa_ofp_alloc(om):
    return (om >> OFPA_SHIFT) & U32(0xF)


def ometa_ofp_member(om):
    return (om >> OFPM_SHIFT) & U32(0xF)


def ometa_stash_idx(om, slot):
    return (om >> (U32(SIDX_SHIFT) + U32(2) * slot.astype(U32))) & U32(0x3)


def ometa_ovf_count(om):
    return (om >> OVFC_SHIFT) & U32(0x7F)


def ometa_ovf_bit(om):
    return (om >> OVFB_SHIFT) & U32(1)


def ometa_set_stash_idx(om, slot, sidx):
    sh = U32(SIDX_SHIFT) + U32(2) * slot.astype(U32)
    return (om & ~(U32(0x3) << sh)) | ((sidx.astype(U32) & U32(0x3)) << sh)


class DashState(NamedTuple):
    """The whole table as a pytree of arrays (one 'PM pool')."""
    # record planes: [max_segments, buckets_total, ...]
    fp: jnp.ndarray        # (S, BT, 16) uint8 — slot fingerprints (padded)
    ofp: jnp.ndarray       # (S, NB, 4)  uint8 — overflow fingerprints
    key_hi: jnp.ndarray    # (S, BT, SLOTS) uint32
    key_lo: jnp.ndarray    # (S, BT, SLOTS) uint32
    val: jnp.ndarray       # (S, BT, SLOTS) uint32 (opaque payload / heap handle)
    meta: jnp.ndarray      # (S, BT) uint32 packed — atomic publish word
    ometa: jnp.ndarray     # (S, NB) uint32 packed
    version: jnp.ndarray   # (S, BT) uint32 — bit0 lock, bits1.. version
    # segment metadata
    local_depth: jnp.ndarray   # (S,) int32
    seg_state: jnp.ndarray     # (S,) int32 {NORMAL, SPLITTING, NEW}
    side_link: jnp.ndarray     # (S,) int32 right-neighbor chain (-1 = none)
    seg_version: jnp.ndarray   # (S,) uint32 lazy-recovery version
    # directory / global metadata
    dir: jnp.ndarray           # (2**dir_depth_max,) int32 fully-expanded MSB directory
    global_depth: jnp.ndarray  # () int32
    watermark: jnp.ndarray     # () int32 — segment pool allocation bump pointer
    clean: jnp.ndarray         # () bool_ — clean-shutdown marker (Sec. 4.8)
    gver: jnp.ndarray          # () uint32 — global recovery version V
    lh_word: jnp.ndarray       # () uint32 — LH: level(8) | next(24), one atomic word (Sec. 5.3)
    lh_dir: jnp.ndarray        # (S,) int32 — LH logical seg -> physical (hybrid-expansion map)
    stash_active: jnp.ndarray  # (S,) int32 — LH: active stash buckets (chain length analog)
    # stats
    n_items: jnp.ndarray       # () int32
    n_splits: jnp.ndarray      # () int32
    n_doublings: jnp.ndarray   # () int32
    key_heap: jnp.ndarray      # (H, W) uint32 or (0,0) — variable-length key storage
    heap_top: jnp.ndarray      # () int32


def make_state(cfg: DashConfig, mode: str = "eh") -> DashState:
    """Fresh table. mode: 'eh' (2**init_depth segments) or 'lh' (N0 segments)."""
    S, BT, NB, NS = cfg.max_segments, cfg.buckets_total, cfg.num_buckets, cfg.num_slots
    if mode == "eh":
        n_init = 1 << cfg.init_depth
        dir0 = np.repeat(np.arange(n_init, dtype=np.int32), cfg.dir_size // n_init)
        gd = cfg.init_depth
    elif mode == "lh":
        n_init = 1 << cfg.lh_base_log2
        dir0 = np.zeros(cfg.dir_size, dtype=np.int32)  # unused by LH addressing
        gd = 0
    else:
        raise ValueError(mode)
    assert n_init <= S
    heap_h = cfg.key_heap_size if cfg.pointer_mode else 1
    lh_dir = np.full(S, -1, dtype=np.int32)
    lh_dir[:n_init] = np.arange(n_init)
    return DashState(
        fp=jnp.zeros((S, BT, 16), jnp.uint8),
        ofp=jnp.zeros((S, NB, 4), jnp.uint8),
        key_hi=jnp.zeros((S, BT, NS), U32),
        key_lo=jnp.zeros((S, BT, NS), U32),
        val=jnp.zeros((S, BT, NS), U32),
        meta=jnp.zeros((S, BT), U32),
        ometa=jnp.zeros((S, NB), U32),
        version=jnp.zeros((S, BT), U32),
        local_depth=jnp.full((S,), gd if mode == "eh" else 0, jnp.int32),
        seg_state=jnp.zeros((S,), jnp.int32),
        side_link=jnp.full((S,), -1, jnp.int32),
        seg_version=jnp.ones((S,), U32),
        dir=jnp.asarray(dir0),
        global_depth=jnp.asarray(gd, jnp.int32),
        watermark=jnp.asarray(n_init, jnp.int32),
        clean=jnp.asarray(True),
        gver=jnp.asarray(1, U32),
        lh_word=jnp.asarray(0, U32),
        lh_dir=jnp.asarray(lh_dir),
        stash_active=jnp.full((S,), min(cfg.num_stash, cfg.lh_base_stash)
                              if mode == "lh" else cfg.num_stash, jnp.int32),
        n_items=jnp.asarray(0, jnp.int32),
        n_splits=jnp.asarray(0, jnp.int32),
        n_doublings=jnp.asarray(0, jnp.int32),
        key_heap=jnp.zeros((heap_h, cfg.key_heap_words), U32),
        heap_top=jnp.asarray(0, jnp.int32),
    )


# --- addressing -------------------------------------------------------------

def dir_index(cfg: DashConfig, h1):
    """MSB prefix of h1 at the fully-expanded directory resolution."""
    return (h1 >> U32(32 - cfg.dir_depth_max)).astype(jnp.int32)


def bucket_index(cfg: DashConfig, h1):
    """In-segment bucket from the LSBs of h1 (as in the Dash implementation)."""
    return (h1 & U32(cfg.num_buckets - 1)).astype(jnp.int32)


def lh_level_next(lh_word):
    return (lh_word >> U32(24)).astype(jnp.int32), (lh_word & U32(0xFFFFFF)).astype(jnp.int32)


def lh_pack(level, nxt):
    return (level.astype(U32) << U32(24)) | (nxt.astype(U32) & U32(0xFFFFFF))


def lh_logical_segment(cfg: DashConfig, h1, lh_word):
    """Classic LH addressing with power-of-2 rounds: seg = h mod N0*2^l,
    re-hash with next round's mask if already split this round."""
    level, nxt = lh_level_next(lh_word)
    mask_lo = (U32(1) << (U32(cfg.lh_base_log2) + level.astype(U32))) - U32(1)
    seg = (h1 & mask_lo).astype(jnp.int32)
    mask_hi = (mask_lo << U32(1)) | U32(1)
    seg2 = (h1 & mask_hi).astype(jnp.int32)
    return jnp.where(seg < nxt, seg2, seg)


def lh_bucket_index(cfg: DashConfig, h1):
    """LH bucket bits live above the segment bits (independent for l<=24-6)."""
    return ((h1 >> U32(24)) & U32(cfg.num_buckets - 1)).astype(jnp.int32)


def load_factor(cfg: DashConfig, state: DashState):
    """records stored / capacity of *allocated* segments (paper's metric)."""
    return state.n_items.astype(jnp.float32) / (
        state.watermark.astype(jnp.float32) * cfg.seg_capacity)


# --- copy-on-write plane schema (PR 4) --------------------------------------
# The state pytree is grouped into individually publishable PLANES. The two
# record groups are scattered at bucket-row granularity by the COW publish
# (core/epoch.py:SnapshotRegistry.publish_cow): a row is copied into the next
# snapshot iff its version word changed (see core/bucket.py's version
# discipline), everything else is aliased or a cheap whole-copy. The leading
# axes before the bucket axis are arbitrary — (S, ...) for a single table,
# (n_shards, S, ...) for the device-sharded DHT — so one publish path serves
# both frontends.

#: record planes whose bucket axis spans buckets_total (normal + stash rows);
#: the flattened row index of version[..., b] addresses the same row in all.
BT_PLANES = ("fp", "key_hi", "key_lo", "val", "meta", "version")
#: record planes whose bucket axis spans only the num_buckets normal rows
#: (overflow metadata has no stash rows).
NB_PLANES = ("ofp", "ometa")
#: per-segment metadata: tiny, rewritten by SMOs/recovery without per-row
#: version words — always copied whole at publish.
SEG_META_PLANES = ("local_depth", "seg_state", "side_link", "seg_version",
                   "lh_dir", "stash_active")
#: the fully-expanded directory: aliased across versions until an SMO
#: publishes a new mapping (device-compared at publish).
DIR_PLANES = ("dir",)
#: everything else: scalars + the pointer-mode key heap — tiny (or version-
#: word-free), copied/flushed whole every publish.
SCALAR_PLANES = ("global_depth", "watermark", "clean", "gver", "lh_word",
                 "n_items", "n_splits", "n_doublings", "key_heap", "heap_top")
assert set(BT_PLANES + NB_PLANES + SEG_META_PLANES + DIR_PLANES
           + SCALAR_PLANES) == set(DashState._fields)


def log_routing_planes(cfg: DashConfig) -> tuple:
    """Planes a logged commit snapshots whole. The pointer-mode key heap
    is exempt: it is append-only and the writeback makes its tail durable
    in phase 1, before any handle publishes, so it needs no log atomicity
    — keeping it out keeps SMO-logged flushes O(dirty + heap-tail)
    instead of O(heap)."""
    planes = DIR_PLANES + SEG_META_PLANES + SCALAR_PLANES
    if cfg.pointer_mode:
        planes = tuple(n for n in planes if n != "key_heap")
    return planes


def state_nbytes(state: DashState) -> int:
    """Total device bytes of one table version — the whole-state copy cost a
    publish would pay without COW (the benchmark's baseline volume)."""
    import jax
    return int(sum(leaf.nbytes for leaf in jax.tree.leaves(state)))


# --- durable PM-pool file layout (PR 5) --------------------------------------
# The emulated-PM pool (persist/pool.py) persists every plane of the state
# pytree into one memory-mapped file: a superblock (config / clean marker /
# flush sequence) followed by the plane regions in ``DashState._fields``
# order, each aligned to PM-line granularity. This map is the single source
# of truth shared by the pool (region views) and the writeback engine (dirty
# bucket-row addressing: the flattened row index of ``version[..., b]``
# addresses the same file row in every BT plane, mirroring the COW publish's
# row index space).

POOL_ALIGN = 64            # emulated PM line (clwb granularity)
SUPERBLOCK_BYTES = 4096    # two checksummed superblock slots live here

#: record planes protected by the pool's per-row checksum region (PR 6):
#: every bucket-row store writes its row bytes AND its uint32 checksum in the
#: same emulated store op, so checksums stay consistent at every store
#: boundary of the crash matrix — only sub-store media faults (torn
#: cachelines, bit rot) can desynchronize them, which is exactly the signal
#: reopen verification and the background scrubber quarantine on.
CSUM_PLANES = BT_PLANES + NB_PLANES


@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """One plane's file region: ``[offset, offset + nbytes)`` holds the
    C-contiguous array bytes; ``group`` names the flush class (``bt`` /
    ``nb`` record planes flushed at bucket-row granularity, ``seg`` /
    ``dir`` compared-then-copied whole, ``scalar`` always copied)."""
    name: str
    offset: int
    shape: tuple
    dtype: np.dtype
    group: str

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * self.dtype.itemsize

    @property
    def rows(self) -> int:
        """Flush rows: bucket rows for record planes (leading axes up to and
        including the bucket axis), 1 for whole-copy planes."""
        if self.group == "bt" or self.group == "nb":
            return int(np.prod(self.shape[:self._bucket_axis + 1],
                               dtype=np.int64))
        return 1

    @property
    def _bucket_axis(self) -> int:
        # (S, BT, ...) single table or (n_shards, S, BT, ...) sharded: the
        # bucket axis is the last for meta/version (2D rows), else axis -2
        return len(self.shape) - 1 if self.name in ("meta", "version",
                                                    "ometa") else len(self.shape) - 2

    @property
    def row_nbytes(self) -> int:
        return self.nbytes // self.rows


def _plane_group(name: str) -> str:
    if name in BT_PLANES:
        return "bt"
    if name in NB_PLANES:
        return "nb"
    if name in SEG_META_PLANES:
        return "seg"
    if name in DIR_PLANES:
        return "dir"
    return "scalar"


@dataclasses.dataclass(frozen=True)
class LogLayout:
    """The pool's redo-log region (between the superblock and the planes):
    SMO-rebuilt rows — whose in-place rewrite can never be made atomic by
    store ordering alone — are staged here (struct-of-arrays sections:
    row ids, then each plane's rows contiguously), committed via the
    superblock, and only then applied to their home rows. Sized for the
    worst case (every row + the routing planes); the file is sparse, so
    unused capacity costs nothing."""
    offset: int
    bt_rows: int               # capacity, in rows
    nb_rows: int
    bt_row_nbytes: int         # per-row payload across all BT planes
    nb_row_nbytes: int
    routing_nbytes: int        # dir + seg-meta + scalar planes, contiguous

    @property
    def bt_offset(self) -> int:
        return self.offset

    @property
    def nb_offset(self) -> int:
        return self.bt_offset + self.bt_rows * (8 + self.bt_row_nbytes)

    @property
    def routing_offset(self) -> int:
        return self.nb_offset + self.nb_rows * (8 + self.nb_row_nbytes)

    @property
    def nbytes(self) -> int:
        return (self.routing_offset - self.offset) + self.routing_nbytes


@dataclasses.dataclass(frozen=True)
class ChecksumLayout:
    """The pool's per-row checksum region (PR 6, between the redo log and
    the planes): one ``uint32`` content checksum per bucket row of every
    ``CSUM_PLANES`` plane, stored per-plane (so a row store updates exactly
    one word) in ``CSUM_PLANES`` order. An all-zero row checksums to 0, so
    a freshly zero-filled pool verifies clean without initialization."""
    offset: int
    entries: tuple             # ((plane_name, file_offset, rows), ...)
    nbytes: int

    def offset_of(self, name: str) -> int:
        for n, off, _ in self.entries:
            if n == name:
                return off
        raise KeyError(name)

    def rows_of(self, name: str) -> int:
        for n, _, rows in self.entries:
            if n == name:
                return rows
        raise KeyError(name)


_CSUM_MULT = np.uint32(2654435761)  # Knuth's multiplicative constant


def np_row_checksum(rows: np.ndarray) -> np.ndarray:
    """Vectorized per-row content checksum: ``(n_rows, ...) -> (n_rows,)``
    uint32. Each u32 word is weighted by a distinct odd multiplier (so
    word-position swaps and torn-cacheline reverts change the sum), summed
    mod 2**32, then avalanched. Orders of magnitude faster than per-row
    ``zlib.crc32`` — it is on reopen's critical path for every row of every
    record plane — while still catching single-bit rot and torn lines.
    Zero rows hash to 0 by construction (see ChecksumLayout)."""
    a = np.ascontiguousarray(rows)
    n = a.shape[0]
    u8 = a.view(np.uint8).reshape(n, -1)
    assert u8.shape[1] % 4 == 0, "plane rows are u32-multiple sized"
    w = u8.view(np.uint32) if u8.flags.c_contiguous else \
        np.ascontiguousarray(u8).view(np.uint32)
    mult = (_CSUM_MULT * (np.arange(w.shape[1], dtype=np.uint32)
                          + np.uint32(1))) | np.uint32(1)
    h = (w * mult[None, :]).sum(axis=1, dtype=np.uint64).astype(np.uint32)
    h ^= h >> np.uint32(16)
    h *= np.uint32(0x45D9F3B)
    h ^= h >> np.uint32(16)
    return h


def pool_plane_specs(cfg: DashConfig, mode: str = "eh"):
    """``(specs, log, csum, total_bytes)``: the plane→file-offset map of a
    pool holding one table of this config, shapes derived abstractly (no
    allocation). File layout: superblock | redo log | per-row checksums |
    plane regions in ``DashState._fields`` order, each 64-aligned."""
    import jax

    def _align(n):
        return (n + POOL_ALIGN - 1) // POOL_ALIGN * POOL_ALIGN

    shapes = jax.eval_shape(lambda: make_state(cfg, mode))
    raw = {name: PlaneSpec(name=name, offset=0,
                           shape=tuple(getattr(shapes, name).shape),
                           dtype=np.dtype(getattr(shapes, name).dtype),
                           group=_plane_group(name))
           for name in DashState._fields}
    bt_rows = raw["version"].rows
    nb_rows = raw["ometa"].rows
    log = LogLayout(
        offset=SUPERBLOCK_BYTES,
        bt_rows=bt_rows, nb_rows=nb_rows,
        bt_row_nbytes=sum(raw[n].row_nbytes for n in BT_PLANES),
        nb_row_nbytes=sum(raw[n].row_nbytes for n in NB_PLANES),
        routing_nbytes=sum(raw[n].nbytes for n in log_routing_planes(cfg)))
    coff = _align(SUPERBLOCK_BYTES + log.nbytes)
    centries = []
    off = coff
    for name in CSUM_PLANES:
        rows = raw[name].rows
        centries.append((name, off, rows))
        off += _align(rows * 4)
    csum = ChecksumLayout(offset=coff, entries=tuple(centries),
                          nbytes=off - coff)
    specs = []
    for name in DashState._fields:
        spec = dataclasses.replace(raw[name], offset=off)
        specs.append(spec)
        off += _align(spec.nbytes)
    return tuple(specs), log, csum, off


def pool_nbytes(cfg: DashConfig, mode: str = "eh") -> int:
    """Plane-region bytes of one pool — the whole-pool rewrite cost a flush
    would pay without dirty tracking (the durable benchmark's baseline
    volume; the sparse redo-log capacity is excluded on purpose)."""
    specs, _, _, _ = pool_plane_specs(cfg, mode)
    return sum(s.nbytes for s in specs)
