"""32-bit-pair hashing for Dash on TPU.

The paper uses GCC's ``std::_Hash_bytes`` (Murmur) over 8-byte keys and draws
every address from the single 64-bit hash: directory index from the MSBs
(Dash addresses segments by MSBs, Sec. 4.7), in-segment bucket index from the
next bits, and the fingerprint from the least-significant byte.

JAX on TPU prefers 32-bit lanes (and we avoid the global ``jax_enable_x64``
switch because it changes default dtypes for the whole model stack), so a
64-bit key is carried as a ``(hi, lo)`` uint32 pair and we derive two
independent 32-bit hashes:

    h1 = mix(hi, lo, SEED1)   -> segment/bucket addressing (MSB-first)
    h2 = mix(hi, lo, SEED2)   -> fingerprint byte (+ spare bits)

``mix`` is a murmur3-style finalizer — cheap (shifts/xors/mults, all VPU
friendly), avalanching, and identical in numpy/jnp so tests can cross-check.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

SEED1 = np.uint32(0x9E3779B9)  # golden-ratio seed for addressing hash
SEED2 = np.uint32(0x85EBCA6B)  # murmur constant seed for fingerprint hash

_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_MASK32 = 0xFFFFFFFF


def _mix32(h):
    """Murmur3 fmix32 finalizer (jnp uint32)."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_C2)
    h = h ^ (h >> 16)
    return h


def hash_pair(key_hi, key_lo, seed):
    """Hash a (hi, lo) uint32 key pair into one uint32 with a boost-style combine."""
    key_hi = jnp.asarray(key_hi, jnp.uint32)
    key_lo = jnp.asarray(key_lo, jnp.uint32)
    seed = jnp.uint32(seed)
    h = _mix32(key_lo ^ seed)
    # hash_combine: h ^= mix(hi) + golden + (h<<6) + (h>>2)
    h = h ^ (_mix32(key_hi + seed) + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))
    return _mix32(h)


def hash1(key_hi, key_lo):
    """Addressing hash: directory/segment/bucket bits are drawn MSB-first."""
    return hash_pair(key_hi, key_lo, SEED1)


def hash2(key_hi, key_lo):
    """Fingerprint hash: low byte is the fingerprint (paper Sec. 4.2)."""
    return hash_pair(key_hi, key_lo, SEED2)


def fingerprint(h2):
    """Least-significant byte of the fingerprint hash, as uint8."""
    return (h2 & jnp.uint32(0xFF)).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# numpy mirrors (bit-exact) — used by tests and host-side tooling.
# ---------------------------------------------------------------------------

def _np_mix32(h):
    h = np.asarray(h, dtype=np.uint64) & _MASK32
    h ^= h >> np.uint64(16)
    h = (h * np.uint64(_C1)) & _MASK32
    h ^= h >> np.uint64(13)
    h = (h * np.uint64(_C2)) & _MASK32
    h ^= h >> np.uint64(16)
    return h & _MASK32


def np_hash_pair(key_hi, key_lo, seed):
    key_hi = np.asarray(key_hi, dtype=np.uint64) & _MASK32
    key_lo = np.asarray(key_lo, dtype=np.uint64) & _MASK32
    seed = np.uint64(int(seed))
    h = _np_mix32(key_lo ^ seed)
    h ^= (_np_mix32((key_hi + seed) & _MASK32) + np.uint64(0x9E3779B9)
          + ((h << np.uint64(6)) & _MASK32) + (h >> np.uint64(2))) & _MASK32
    h &= _MASK32
    return _np_mix32(h).astype(np.uint32)


def np_hash1(key_hi, key_lo):
    return np_hash_pair(key_hi, key_lo, int(SEED1))


def np_hash2(key_hi, key_lo):
    return np_hash_pair(key_hi, key_lo, int(SEED2))


def fold_words(words, seed):
    """Fold a (..., W) uint32 word array into one uint32 per row (jnp).

    Used by pointer mode (variable-length keys, Sec. 4.5): the (hi, lo)
    identity of a long key is (fold(words, SEED1'), fold(words, SEED2')).
    """
    words = jnp.asarray(words, jnp.uint32)
    h = jnp.full(words.shape[:-1], jnp.uint32(seed))
    for i in range(words.shape[-1]):
        h = _mix32(h ^ words[..., i]) + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2)
    return _mix32(h)


def np_fold_words(words, seed):
    words = np.asarray(words, dtype=np.uint64) & _MASK32
    h = np.full(words.shape[:-1], np.uint64(int(seed)), dtype=np.uint64)
    for i in range(words.shape[-1]):
        h = (_np_mix32(h ^ words[..., i]) + np.uint64(0x9E3779B9)
             + ((h << np.uint64(6)) & _MASK32) + (h >> np.uint64(2))) & _MASK32
    return _np_mix32(h).astype(np.uint32)


FOLD_SEED_HI = 0xDEADBEEF
FOLD_SEED_LO = 0x12345678


def key_identity_from_words(words):
    """(hi, lo) uint32 identity pair for a variable-length key (jnp)."""
    return fold_words(words, FOLD_SEED_HI), fold_words(words, FOLD_SEED_LO)


def np_key_identity_from_words(words):
    return np_fold_words(words, FOLD_SEED_HI), np_fold_words(words, FOLD_SEED_LO)


def split_key(key64: int):
    """Split a python int key (< 2**64) into (hi, lo) uint32."""
    key64 = int(key64) & 0xFFFFFFFFFFFFFFFF
    return np.uint32(key64 >> 32), np.uint32(key64 & _MASK32)


def np_split_keys(keys64: np.ndarray):
    keys64 = np.asarray(keys64, dtype=np.uint64)
    return (keys64 >> np.uint64(32)).astype(np.uint32), (keys64 & np.uint64(_MASK32)).astype(np.uint32)
