"""Baselines the paper compares against, ported to the same JAX substrate.

* CCEH-like — expressed as a ``DashConfig`` of the shared engine
  (``cceh_config``): 4-slot buckets ("64-byte, one cacheline"), linear
  probing of 4 buckets, no fingerprints, no balanced insert / displacement,
  no stash; split on probe-window exhaustion. This isolates the *algorithm*
  (probing-4 + premature splits) from implementation language, exactly what
  Figs. 7/8/12 compare.

* Level hashing — a two-level scheme with its own structure (this module):
  top level of 2^k 4-slot buckets, bottom level of 2^(k-1); each key has two
  candidate buckets per level (two hash functions); one movement attempt in
  the top level; **full-table rehash** on resize (new top = 2^(k+1), old top
  becomes the bottom) — the blocking rehash the paper contrasts with
  dynamic schemes (Sec. 2.2, Fig. 8's insert collapse).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing
from .layout import EXISTS, INSERTED, NEED_SPLIT, NOT_FOUND, DashConfig, U32

I32 = jnp.int32


def cceh_config(max_segments: int = 64, dir_depth_max: int = 12) -> DashConfig:
    """CCEH as a feature-flag point of the Dash engine (Sec. 2.3)."""
    return DashConfig(
        num_buckets=64, num_stash=0, num_slots=4, num_ofp=0,
        max_segments=max_segments, dir_depth_max=dir_depth_max,
        use_fingerprints=False, use_balanced=False, use_displacement=False,
        probe_len=4,
    )


def bucketized_config(**kw) -> DashConfig:
    """Fig. 11 'Bucketized': no probing, no balancing, no stash."""
    return DashConfig(num_stash=0, use_fingerprints=True, use_balanced=False,
                      use_displacement=False, probe_len=1, **kw)


# ---------------------------------------------------------------------------
# Level hashing
# ---------------------------------------------------------------------------

SLOTS = 4


@dataclasses.dataclass(frozen=True)
class LevelConfig:
    max_log2: int = 14          # max top-level log2 (pool is 2^max + 2^(max-1))
    init_log2: int = 6


class LevelState(NamedTuple):
    key_hi: jnp.ndarray   # (CAP, 4) uint32
    key_lo: jnp.ndarray
    val: jnp.ndarray
    alloc: jnp.ndarray    # (CAP,) uint32 bitmap (4 bits)
    k: jnp.ndarray        # () int32 — top level is 2^k buckets
    n_items: jnp.ndarray  # () int32
    n_rehashes: jnp.ndarray


def _cap(cfg: LevelConfig) -> int:
    return (1 << cfg.max_log2) + (1 << (cfg.max_log2 - 1))


def level_make_state(cfg: LevelConfig) -> LevelState:
    CAP = _cap(cfg)
    return LevelState(
        key_hi=jnp.zeros((CAP, SLOTS), U32),
        key_lo=jnp.zeros((CAP, SLOTS), U32),
        val=jnp.zeros((CAP, SLOTS), U32),
        alloc=jnp.zeros((CAP,), U32),
        k=jnp.asarray(cfg.init_log2, jnp.int32),
        n_items=jnp.asarray(0, jnp.int32),
        n_rehashes=jnp.asarray(0, jnp.int32),
    )


def _buckets_for(cfg: LevelConfig, state: LevelState, h1, h2):
    """The four candidate buckets: two top (offset 0), two bottom
    (offset 2^max_log2)."""
    kt = state.k.astype(U32)
    top_a = (h1 & ((U32(1) << kt) - 1)).astype(I32)
    top_b = (h2 & ((U32(1) << kt) - 1)).astype(I32)
    kb = kt - 1
    boff = 1 << cfg.max_log2
    bot_a = boff + (h1 & ((U32(1) << kb) - 1)).astype(I32)
    bot_b = boff + (h2 & ((U32(1) << kb) - 1)).astype(I32)
    return top_a, top_b, bot_a, bot_b


def _probe_bucket(state: LevelState, b, q_hi, q_lo):
    ids = jnp.arange(SLOTS, dtype=U32)
    allocated = ((state.alloc[b] >> ids) & U32(1)) == 1
    eq = allocated & (state.key_hi[b] == q_hi) & (state.key_lo[b] == q_lo)
    return jnp.any(eq), jnp.argmax(eq).astype(I32)


def _free_slot(state: LevelState, b):
    ids = jnp.arange(SLOTS, dtype=U32)
    free = ((state.alloc[b] >> ids) & U32(1)) == 0
    return jnp.any(free), jnp.argmax(free).astype(I32)


def _count(state: LevelState, b):
    ids = jnp.arange(SLOTS, dtype=U32)
    return jnp.sum(((state.alloc[b] >> ids) & U32(1)).astype(I32))


def _write(state: LevelState, b, slot, hi, lo, v):
    return state._replace(
        key_hi=state.key_hi.at[b, slot].set(hi),
        key_lo=state.key_lo.at[b, slot].set(lo),
        val=state.val.at[b, slot].set(v),
        alloc=state.alloc.at[b].set(state.alloc[b] | (U32(1) << slot.astype(U32))),
    )


def _clear(state: LevelState, b, slot):
    return state._replace(
        alloc=state.alloc.at[b].set(state.alloc[b] & ~(U32(1) << slot.astype(U32))))


def level_insert_one(cfg: LevelConfig, state: LevelState, hi, lo, v):
    h1, h2 = hashing.hash1(hi, lo), hashing.hash2(hi, lo)
    ta, tb, ba, bb = _buckets_for(cfg, state, h1, h2)

    # uniqueness
    exists = jnp.asarray(False)
    for b in (ta, tb, ba, bb):
        f, _ = _probe_bucket(state, b, hi, lo)
        exists = exists | f

    # insertion candidates: less-loaded top first (level hashing is 2-choice),
    # then bottom; then one movement attempt in the top level
    cta, ctb = _count(state, ta), _count(state, tb)
    top_first = jnp.where(cta <= ctb, ta, tb)
    top_second = jnp.where(cta <= ctb, tb, ta)
    order = [top_first, top_second, ba, bb]
    frees = [_free_slot(state, b) for b in order]

    can = jnp.stack([f for f, _ in frees])
    which = jnp.argmax(can).astype(I32)
    any_free = jnp.any(can)

    # movement: evict one record of ta to ITS alternate top bucket
    def movable(b):
        r_hi, r_lo = state.key_hi[b, 0], state.key_lo[b, 0]
        a1, a2 = hashing.hash1(r_hi, r_lo), hashing.hash2(r_hi, r_lo)
        mta, mtb, _, _ = _buckets_for(cfg, state, a1, a2)
        alt = jnp.where(mta == b, mtb, mta)
        ok, slot = _free_slot(state, alt)
        return ok, alt, slot

    mv_ok, mv_alt, mv_slot = movable(ta)

    code = jnp.where(exists, 0, jnp.where(any_free, 1, jnp.where(mv_ok, 2, 3)))

    def br_exists(st):
        return st, I32(EXISTS)

    def br_plain(st):
        b = jnp.stack(order)[which]
        slot = jnp.stack([s for _, s in frees])[which]
        return _write(st, b, slot, hi, lo, v), I32(INSERTED)

    def br_move(st):
        r_hi, r_lo, r_v = st.key_hi[ta, 0], st.key_lo[ta, 0], st.val[ta, 0]
        st = _write(st, mv_alt, mv_slot, r_hi, r_lo, r_v)
        st = _clear(st, ta, I32(0))
        return _write(st, ta, I32(0), hi, lo, v), I32(INSERTED)

    def br_resize(st):
        return st, I32(NEED_SPLIT)

    state, status = jax.lax.switch(code, [br_exists, br_plain, br_move, br_resize], state)
    state = state._replace(n_items=state.n_items + (status == INSERTED).astype(I32))
    return state, status


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def level_insert_batch(cfg: LevelConfig, state: LevelState, hi, lo, vals, valid=None):
    if valid is None:
        valid = jnp.ones(hi.shape[0], jnp.bool_)

    def step(st, xs):
        h, l, v, ok = xs
        st, status = jax.lax.cond(
            ok, lambda s: level_insert_one(cfg, s, h, l, v),
            lambda s: (s, I32(NOT_FOUND)), st)
        return st, status

    return jax.lax.scan(step, state, (hi, lo, vals, valid))


@functools.partial(jax.jit, static_argnums=(0,))
def level_search_batch(cfg: LevelConfig, state: LevelState, hi, lo):
    def one(h, l):
        h1, h2 = hashing.hash1(h, l), hashing.hash2(h, l)
        found = jnp.asarray(False)
        value = U32(0)
        for b in _buckets_for(cfg, state, h1, h2):
            f, slot = _probe_bucket(state, b, h, l)
            value = jnp.where(f & ~found, state.val[b, slot], value)
            found = found | f
        return found, value

    return jax.vmap(one)(hi, lo)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def level_rehash(cfg: LevelConfig, state: LevelState):
    """Full-table rehash: k -> k+1. Old top becomes the new bottom; old bottom
    records are re-inserted. This is the operation that blocks concurrent
    queries in level hashing (what Fig. 8 punishes)."""
    CAP = _cap(cfg)
    boff = 1 << cfg.max_log2

    old_hi, old_lo, old_val, old_alloc = (state.key_hi, state.key_lo,
                                          state.val, state.alloc)
    old_k = state.k

    fresh = LevelState(
        key_hi=jnp.zeros_like(old_hi), key_lo=jnp.zeros_like(old_lo),
        val=jnp.zeros_like(old_val), alloc=jnp.zeros_like(old_alloc),
        k=old_k + 1, n_items=jnp.asarray(0, jnp.int32),
        n_rehashes=state.n_rehashes + 1)

    # move old top -> new bottom (bucket index preserved: 2^k buckets).
    # At rehash time old_k <= max_log2-1, so the old top always fits the
    # bottom region of CAP-boff = 2^(max_log2-1) buckets.
    nbot = CAP - boff
    fresh = fresh._replace(
        key_hi=jax.lax.dynamic_update_slice(
            fresh.key_hi, jax.lax.dynamic_slice(old_hi, (0, 0), (nbot, SLOTS)),
            (boff, 0)),
        key_lo=jax.lax.dynamic_update_slice(
            fresh.key_lo, jax.lax.dynamic_slice(old_lo, (0, 0), (nbot, SLOTS)),
            (boff, 0)),
        val=jax.lax.dynamic_update_slice(
            fresh.val, jax.lax.dynamic_slice(old_val, (0, 0), (nbot, SLOTS)),
            (boff, 0)),
        alloc=jax.lax.dynamic_update_slice(
            fresh.alloc, jax.lax.dynamic_slice(old_alloc, (0,), (nbot,)), (boff,)),
    )
    # ... but only the first 2^old_k buckets were really the top; zero the rest
    idx = jnp.arange(CAP)
    in_new_bottom = (idx >= boff) & (idx < boff + (1 << cfg.max_log2 - 1))
    keep = in_new_bottom & ((idx - boff) < (1 << old_k.astype(I32)))
    fresh = fresh._replace(alloc=jnp.where((idx >= boff) & ~keep, U32(0), fresh.alloc))

    # re-insert old bottom records through the new geometry
    bot_hi = jax.lax.dynamic_slice(old_hi, (boff, 0), (CAP - boff, SLOTS)).reshape(-1)
    bot_lo = jax.lax.dynamic_slice(old_lo, (boff, 0), (CAP - boff, SLOTS)).reshape(-1)
    bot_val = jax.lax.dynamic_slice(old_val, (boff, 0), (CAP - boff, SLOTS)).reshape(-1)
    bot_alloc = jax.lax.dynamic_slice(old_alloc, (boff,), (CAP - boff,))
    ids = jnp.arange(SLOTS, dtype=U32)[None, :]
    bot_valid = (((bot_alloc[:, None] >> ids) & U32(1)) == 1).reshape(-1)

    def step(st, xs):
        h, l, v, ok = xs
        st, _ = jax.lax.cond(
            ok, lambda s: level_insert_one(cfg, s, h, l, v),
            lambda s: (s, I32(NOT_FOUND)), st)
        return st, ()

    fresh, _ = jax.lax.scan(step, fresh, (bot_hi, bot_lo, bot_val, bot_valid))

    # recount
    ids2 = jnp.arange(SLOTS, dtype=U32)[None, :]
    n = jnp.sum(((fresh.alloc[:, None] >> ids2) & U32(1)).astype(I32))
    return fresh._replace(n_items=n)


class LevelHashing:
    """Host wrapper mirroring the DashTable API surface."""

    def __init__(self, cfg: LevelConfig = LevelConfig()):
        self.cfg = cfg
        self.state = level_make_state(cfg)

    def insert(self, keys, values, max_retries: int = 8):
        hi, lo = hashing.np_split_keys(np.asarray(keys, np.uint64))
        vals = np.asarray(values, np.uint32)
        out = np.full(hi.shape[0], NEED_SPLIT, np.int32)
        pending = np.arange(hi.shape[0])
        first = True
        for _ in range(max_retries):
            if first:
                idx, valid = pending, None
            else:
                n = max(8, 1 << int(np.ceil(np.log2(max(pending.size, 1)))))
                idx = np.concatenate([pending, np.zeros(n - pending.size, np.int64)])
                valid = jnp.asarray(np.arange(n) < pending.size)
            self.state, st = level_insert_batch(
                self.cfg, self.state, jnp.asarray(hi[idx]), jnp.asarray(lo[idx]),
                jnp.asarray(vals[idx]), valid)
            st = np.asarray(st)[:pending.size]
            out[pending] = st
            failed = pending[st == NEED_SPLIT]
            if failed.size == 0:
                return out
            if int(np.asarray(self.state.k)) >= self.cfg.max_log2:
                raise RuntimeError("level hashing pool exhausted")
            self.state = level_rehash(self.cfg, self.state)
            pending = failed
            first = False
        raise RuntimeError("level insert retry budget exhausted")

    def search(self, keys):
        hi, lo = hashing.np_split_keys(np.asarray(keys, np.uint64))
        f, v = level_search_batch(self.cfg, self.state, jnp.asarray(hi), jnp.asarray(lo))
        return np.asarray(f), np.asarray(v)

    @property
    def n_items(self) -> int:
        return int(np.asarray(self.state.n_items))

    @property
    def load_factor(self) -> float:
        k = int(np.asarray(self.state.k))
        cap = ((1 << k) + (1 << (k - 1))) * SLOTS
        return self.n_items / cap
