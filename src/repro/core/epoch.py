"""Epoch-based reclamation + versioned snapshot registry (paper Sec. 4.4).

Dash readers hold no locks, so a snapshot being read must not be reclaimed
until every reader that could see it has exited. In our batched adaptation
the unit of protection is a STATE SNAPSHOT (the functional table version a
search batch runs against): writers publish new versions; old versions are
retired into the epoch's limbo list and freed two epochs later — the classic
3-epoch scheme.

Two layers live here:

``EpochManager``
    The grace-period core: readers ``pin()`` an epoch around a read critical
    section; writers ``retire()`` superseded payloads; a payload is reclaimed
    once no pinned reader can still reference it (2 epochs later).

``SnapshotRegistry``
    The serving-frontend contract on top: writers ``publish()`` whole table
    versions (monotonic version ids), readers ``acquire()`` the newest
    published version under an epoch pin and run against it while writers
    keep mutating the live state and SMOs publish *next* directory versions.
    Superseded versions flow into the EpochManager's limbo; reclamation
    deletes their device buffers (the PM-free analog). A reader that observes
    changed bucket version planes retries on a newer version — the
    snapshot-verify-retry path in ``serving/engine.py:snapshot_search`` and
    ``serving/frontend.py``.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Optional


class EpochManager:
    def __init__(self, reclaim: Optional[Callable[[Any], None]] = None):
        self._lock = threading.Lock()
        self.global_epoch = 0
        self._active = defaultdict(int)        # epoch -> active readers
        self._limbo = defaultdict(list)        # retire epoch -> payloads
        self._reclaim = reclaim or (lambda obj: None)
        self.reclaimed = 0

    # -- readers -----------------------------------------------------------

    def enter(self) -> int:
        with self._lock:
            e = self.global_epoch
            self._active[e] += 1
            return e

    def exit(self, epoch: int):
        with self._lock:
            self._active[epoch] -= 1
            if self._active[epoch] == 0:
                del self._active[epoch]
            self._try_advance_locked()

    class _Guard:
        def __init__(self, mgr):
            self.mgr = mgr

        def __enter__(self):
            self.epoch = self.mgr.enter()
            return self.epoch

        def __exit__(self, *exc):
            self.mgr.exit(self.epoch)

    def pin(self) -> "_Guard":
        """with epochs.pin(): ... — lock-free read critical section."""
        return self._Guard(self)

    @property
    def active_readers(self) -> int:
        with self._lock:
            return sum(self._active.values())

    @property
    def limbo_size(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._limbo.values())

    # -- writers -----------------------------------------------------------

    def retire(self, obj: Any):
        """Queue an old snapshot/segment for reclamation once safe."""
        with self._lock:
            self._limbo[self.global_epoch].append(obj)
            self._try_advance_locked()

    def _try_advance_locked(self):
        # advance when no reader is pinned at or before the current epoch;
        # reclaim limbo entries 2 epochs old (nobody can reference them)
        if not self._active or min(self._active) >= self.global_epoch:
            self.global_epoch += 1
        safe = self.global_epoch - 2
        for e in [e for e in self._limbo if e <= safe]:
            for obj in self._limbo.pop(e):
                self._reclaim(obj)
                self.reclaimed += 1

    def flush(self):
        """Reclaim everything (quiescent point: e.g. engine shutdown)."""
        with self._lock:
            assert not self._active, "readers still pinned"
            self.global_epoch += 3
            for e in list(self._limbo):
                for obj in self._limbo.pop(e):
                    self._reclaim(obj)
                    self.reclaimed += 1


class Snapshot:
    """One published table version: an immutable state pytree + the version
    id it was published under. Readers hold it only inside an epoch pin (or
    for as long as the frontend batch that acquired it is in flight)."""

    __slots__ = ("version", "state")

    def __init__(self, version: int, state: Any):
        self.version = version
        self.state = state

    def __repr__(self):  # pragma: no cover
        return f"Snapshot(v{self.version})"


def delete_buffers(snap: "Snapshot"):
    """Default reclaimer: free the snapshot's device buffers (PM-free
    analog). Safe on already-deleted or non-array leaves."""
    import jax
    for leaf in jax.tree.leaves(snap.state):
        try:
            leaf.delete()
        except Exception:
            pass


class SnapshotRegistry:
    """Monotonic published-version chain guarded by an EpochManager.

    ``publish(state)`` installs ``state`` as the newest version and retires
    the previous one into the epoch limbo (reclaimed — buffers deleted —
    once no pinned reader can reference it). ``acquire()`` returns the
    current Snapshot under an epoch pin; use as a context manager:

        with registry.acquire() as snap:
            found, vals = search_batch(cfg, mode, snap.state, ...)

    The registry never copies: the caller passes a state whose buffers it
    will not donate afterwards (the frontend copies once per publish since
    its write path donates the live buffers).
    """

    def __init__(self, epochs: Optional[EpochManager] = None,
                 reclaim: Optional[Callable[[Snapshot], None]] = None):
        self.epochs = epochs or EpochManager(reclaim=reclaim or delete_buffers)
        self._lock = threading.Lock()
        self._current: Optional[Snapshot] = None
        self._next_version = 0
        self.published = 0

    @property
    def current(self) -> Optional[Snapshot]:
        with self._lock:
            return self._current

    @property
    def version(self) -> int:
        with self._lock:
            return -1 if self._current is None else self._current.version

    def publish(self, state: Any) -> Snapshot:
        """Install ``state`` as the newest version; retire the old one."""
        with self._lock:
            snap = Snapshot(self._next_version, state)
            self._next_version += 1
            old, self._current = self._current, snap
            self.published += 1
        if old is not None:
            self.epochs.retire(old)
        return snap

    class _Acquired:
        def __init__(self, registry: "SnapshotRegistry"):
            self.registry = registry

        def __enter__(self) -> Snapshot:
            self.epoch = self.registry.epochs.enter()
            snap = self.registry.current
            assert snap is not None, "acquire() before first publish()"
            return snap

        def __exit__(self, *exc):
            self.registry.epochs.exit(self.epoch)

    def acquire(self) -> "_Acquired":
        """Pin an epoch and yield the newest published Snapshot."""
        return self._Acquired(self)

    @property
    def reclaimed(self) -> int:
        return self.epochs.reclaimed

    def flush(self):
        self.epochs.flush()
