"""Epoch-based reclamation (paper Sec. 4.4): lock-free readers + safe
segment/state retirement.

Dash readers hold no locks, so a snapshot being read must not be reclaimed
until every reader that could see it has exited. In our batched adaptation
the unit of protection is a STATE SNAPSHOT (the functional table version a
search batch runs against): writers publish new versions; old versions are
retired into the epoch's limbo list and freed two epochs later — the classic
3-epoch scheme.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Optional


class EpochManager:
    def __init__(self, reclaim: Optional[Callable[[Any], None]] = None):
        self._lock = threading.Lock()
        self.global_epoch = 0
        self._active = defaultdict(int)        # epoch -> active readers
        self._limbo = defaultdict(list)        # retire epoch -> payloads
        self._reclaim = reclaim or (lambda obj: None)
        self.reclaimed = 0

    # -- readers -----------------------------------------------------------

    def enter(self) -> int:
        with self._lock:
            e = self.global_epoch
            self._active[e] += 1
            return e

    def exit(self, epoch: int):
        with self._lock:
            self._active[epoch] -= 1
            if self._active[epoch] == 0:
                del self._active[epoch]
            self._try_advance_locked()

    class _Guard:
        def __init__(self, mgr):
            self.mgr = mgr

        def __enter__(self):
            self.epoch = self.mgr.enter()
            return self.epoch

        def __exit__(self, *exc):
            self.mgr.exit(self.epoch)

    def pin(self) -> "_Guard":
        """with epochs.pin(): ... — lock-free read critical section."""
        return self._Guard(self)

    # -- writers -----------------------------------------------------------

    def retire(self, obj: Any):
        """Queue an old snapshot/segment for reclamation once safe."""
        with self._lock:
            self._limbo[self.global_epoch].append(obj)
            self._try_advance_locked()

    def _try_advance_locked(self):
        # advance when no reader is pinned at or before the current epoch;
        # reclaim limbo entries 2 epochs old (nobody can reference them)
        if not self._active or min(self._active) >= self.global_epoch:
            self.global_epoch += 1
        safe = self.global_epoch - 2
        for e in [e for e in self._limbo if e <= safe]:
            for obj in self._limbo.pop(e):
                self._reclaim(obj)
                self.reclaimed += 1

    def flush(self):
        """Reclaim everything (quiescent point: e.g. engine shutdown)."""
        with self._lock:
            assert not self._active, "readers still pinned"
            self.global_epoch += 3
            for e in list(self._limbo):
                for obj in self._limbo.pop(e):
                    self._reclaim(obj)
                    self.reclaimed += 1
