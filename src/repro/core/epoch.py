"""Epoch-based reclamation + copy-on-write versioned snapshot registry.

Dash readers hold no locks, so a snapshot being read must not be reclaimed
until every reader that could see it has exited (paper Sec. 4.4). In our
batched adaptation the unit of protection is a STATE SNAPSHOT (the
functional table version a search batch runs against): writers publish new
versions; old versions are retired into the epoch's limbo list and freed two
epochs later — the classic 3-epoch scheme.

Three layers live here:

``EpochManager``
    The grace-period core: readers ``pin()`` an epoch around a read critical
    section; writers ``retire()`` superseded payloads; a payload is reclaimed
    once no pinned reader can still reference it (2 epochs later).

``PlanePool``
    Refcounts on published plane buffers. Copy-on-write versions SHARE
    planes: an untouched plane of version v_n is aliased (the same device
    array object) into v_n+1, v_n+2, ... Reclamation is therefore
    plane-level, not snapshot-level: retiring v_n releases one reference on
    each of its planes, and a plane's device buffer is deleted only when no
    newer snapshot still aliases it. (The pre-PR-4 whole-snapshot
    ``leaf.delete()`` would free planes still aliased by newer versions.)
    The live table state never enters the pool — the engine's mutating
    dispatches donate (consume) the live buffers, so snapshots always own
    or pool-share their planes, never the live arrays.

``SnapshotRegistry``
    The serving-frontend contract on top. ``publish_cow(cfg, live)`` installs
    the live state as the next version in O(dirty) bytes:

      * the per-bucket-row dirty mask is the version-plane diff against the
        previous version (``engine.changed_rows`` — every plane mutation
        bumps its bucket's version word, see core/bucket.py), so an insert
        batch republises a few hundred rows, an SMO republises exactly the
        rebuilt segments, and everything else is shared;
      * dirty rows of the record planes are scattered into the previous
        version's buffers IN PLACE when that version is unpinned and its
        planes are unshared (buffer donation — the common frontend cadence),
        otherwise into fresh copies (the pinned-reader slow path);
      * the directory and per-segment metadata planes carry no version
        words, so one bundled device compare decides alias-vs-copy for
        them; scalars are tiny and copied every publish.

    ``acquire()`` returns the current Snapshot under an epoch pin AND a
    per-snapshot pin count — the pin count is what makes in-place donation
    safe (a pinned version's planes are never donated). ``publish(state)``
    is the legacy whole-payload path (still used for arbitrary payloads).

Publish lifecycle (one write batch)::

    v_n (snapshot) ──alias──────────────► v_n+1   clean planes: refcount++
         │                                  ▲
         │ dirty rows (version-plane diff)  │
         └─────────scatter (donated)────────┘     O(dirty) bytes moved
    v_n retired ─► limbo ─► release planes (refcount--; delete at zero)
"""
from __future__ import annotations

import functools
import math
import threading
import time
from collections import defaultdict
from typing import Any, Callable, Optional

import numpy as np

from . import layout


class EpochManager:
    def __init__(self, reclaim: Optional[Callable[[Any], None]] = None):
        self._lock = threading.Lock()
        self.global_epoch = 0
        self._active = defaultdict(int)        # epoch -> active readers
        self._limbo = defaultdict(list)        # retire epoch -> payloads
        self._reclaim = reclaim or (lambda obj: None)
        self.reclaimed = 0

    # -- readers -----------------------------------------------------------

    def enter(self) -> int:
        with self._lock:
            e = self.global_epoch
            self._active[e] += 1
            return e

    def exit(self, epoch: int):
        with self._lock:
            self._active[epoch] -= 1
            if self._active[epoch] == 0:
                del self._active[epoch]
            self._try_advance_locked()

    class _Guard:
        def __init__(self, mgr):
            self.mgr = mgr

        def __enter__(self):
            self.epoch = self.mgr.enter()
            return self.epoch

        def __exit__(self, *exc):
            self.mgr.exit(self.epoch)

    def pin(self) -> "_Guard":
        """with epochs.pin(): ... — lock-free read critical section."""
        return self._Guard(self)

    @property
    def active_readers(self) -> int:
        with self._lock:
            return sum(self._active.values())

    @property
    def limbo_size(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._limbo.values())

    # -- writers -----------------------------------------------------------

    def retire(self, obj: Any):
        """Queue an old snapshot/segment for reclamation once safe."""
        with self._lock:
            self._limbo[self.global_epoch].append(obj)
            self._try_advance_locked()

    def _try_advance_locked(self):
        # advance when no reader is pinned at or before the current epoch;
        # reclaim limbo entries 2 epochs old (nobody can reference them)
        if not self._active or min(self._active) >= self.global_epoch:
            self.global_epoch += 1
        safe = self.global_epoch - 2
        for e in [e for e in self._limbo if e <= safe]:
            for obj in self._limbo.pop(e):
                self._reclaim(obj)
                self.reclaimed += 1

    def flush(self):
        """Reclaim everything (quiescent point: e.g. engine shutdown)."""
        with self._lock:
            assert not self._active, "readers still pinned"
            self.global_epoch += 3
            for e in list(self._limbo):
                for obj in self._limbo.pop(e):
                    self._reclaim(obj)
                    self.reclaimed += 1


def _try_delete(leaf):
    """Free one device buffer; safe on already-deleted (e.g. donated) arrays
    and on non-array leaves."""
    try:
        leaf.delete()
    except Exception:
        pass


class PlanePool:
    """Refcounts on published plane buffers, keyed by array identity.

    A plane enters the pool when a snapshot referencing it is published
    (``incref``); each snapshot that aliases the same array object adds a
    reference. ``decref`` releases one reference and deletes the device
    buffer only at zero — a plane shared by a newer snapshot survives the
    older snapshot's reclamation. Donated-away planes (their buffer was
    reused in place by a COW scatter) are already dead handles; deleting
    them at refcount zero is a no-op.
    """

    def __init__(self):
        self._refs: dict = {}          # id(arr) -> [arr, refcount]

    def incref(self, leaf):
        e = self._refs.get(id(leaf))
        if e is None:
            self._refs[id(leaf)] = [leaf, 1]
        else:
            e[1] += 1

    def decref(self, leaf) -> bool:
        """Release one reference; True iff the plane was freed."""
        e = self._refs.get(id(leaf))
        if e is None:               # never pooled (defensive): free directly
            _try_delete(leaf)
            return True
        e[1] -= 1
        if e[1] == 0:
            del self._refs[id(leaf)]
            _try_delete(leaf)
            return True
        return False

    def refcount(self, leaf) -> int:
        e = self._refs.get(id(leaf))
        return 0 if e is None else e[1]

    @property
    def live_planes(self) -> int:
        return len(self._refs)


class Snapshot:
    """One published table version: an immutable state pytree + the version
    id it was published under + a pin count. Readers hold it only inside an
    epoch pin (or for as long as the frontend batch that acquired it is in
    flight); ``pins`` > 0 blocks in-place buffer donation by the next
    publish."""

    __slots__ = ("version", "state", "pins")

    def __init__(self, version: int, state: Any):
        self.version = version
        self.state = state
        self.pins = 0

    def __repr__(self):  # pragma: no cover
        return f"Snapshot(v{self.version})"


def delete_buffers(snap: "Snapshot"):
    """Whole-snapshot reclaimer: free every device buffer of the snapshot.
    Correct ONLY for never-aliased snapshots (the legacy ``publish`` path
    with standalone payloads); pooled registries release plane-level
    references instead — see ``PlanePool``."""
    import jax
    for leaf in jax.tree.leaves(snap.state):
        _try_delete(leaf)


class DirtyHint:
    """Host-side dirty report drained from a table's ``DirtyTracker`` at
    publish: the segments the mutating paths routed writes to (plus whether
    the directory / the whole state changed). The version-plane diff is the
    publish's ground truth; the hint is audited against it
    (``SnapshotRegistry.hint_misses``) and drives the force-full escape for
    paths outside the version discipline (crash simulation, restart)."""

    __slots__ = ("segments", "dir", "full")

    def __init__(self, segments=frozenset(), dir=False, full=False):
        self.segments = frozenset(int(s) for s in segments)
        self.dir = bool(dir)
        self.full = bool(full)


# -- jitted COW helpers ------------------------------------------------------

def _scatter_body(bases, lives, ids, nlead):
    import jax.numpy as jnp
    out = []
    for base, live in zip(bases, lives):
        shape = base.shape
        rows = math.prod(shape[:nlead])
        br = base.reshape((rows,) + shape[nlead:])
        lr = live.reshape((rows,) + shape[nlead:])
        # padding lanes carry the sentinel id == rows: in-bounds for the
        # clipped gather, out-of-bounds (dropped) for the scatter — a
        # negative sentinel would WRAP to the last row and corrupt it
        picked = lr[jnp.clip(ids, 0, rows - 1)]
        out.append(br.at[ids].set(picked, mode="drop").reshape(shape))
    return tuple(out)


@functools.lru_cache(maxsize=None)
def _scatter_fns():
    import jax
    donate = jax.jit(_scatter_body, static_argnums=(3,), donate_argnums=(0,))
    copy = jax.jit(_scatter_body, static_argnums=(3,))
    return donate, copy


@functools.lru_cache(maxsize=None)
def _neq_many():
    """One bundled device compare: per-leaf 'did this plane change' bools
    for the version-word-free planes (directory + per-segment metadata)."""
    import jax
    import jax.numpy as jnp
    return jax.jit(lambda xs, ys: tuple(
        jnp.any(x != y) for x, y in zip(xs, ys)))


def _pad_ids(ids: np.ndarray, rows: int):
    """Pad dirty-row ids to quantized pow4 levels (floor 128, capped at the
    row count) so the scatter reuses a handful of jit traces; padding lanes
    carry the out-of-bounds sentinel ``rows`` (dropped by the scatter)."""
    import jax.numpy as jnp
    n = max(int(ids.size), 1)
    cap = 128
    while cap < n:
        cap *= 4
    cap = min(cap, rows)
    out = np.full(cap, rows, np.int32)
    out[:ids.size] = ids
    return jnp.asarray(out)


class SnapshotRegistry:
    """Monotonic published-version chain guarded by an EpochManager, with
    plane-pooled copy-on-write publishing.

    ``publish_cow(cfg, live)`` installs the live table state as the newest
    version copying only dirty planes (see module docstring); ``publish``
    is the legacy whole-payload path. ``acquire()`` returns the current
    Snapshot under an epoch pin; use as a context manager:

        with registry.acquire() as snap:
            found, vals = search_batch(cfg, mode, snap.state, ...)

    Superseded versions retire into the EpochManager's limbo; reclamation
    releases plane-level references (``PlanePool``) — a plane aliased by a
    newer snapshot survives. Passing a custom ``reclaim`` (or a caller-owned
    ``epochs``) keeps the legacy snapshot-level behavior for standalone
    payloads.

    Observability: ``publish_bytes`` / ``last_publish_bytes`` (bytes
    actually copied), ``planes_copied`` / ``planes_aliased`` (plane counts),
    ``publish_seconds``, ``hint_misses`` (dirty segments the host tracker
    failed to report — should stay 0), ``published`` / ``reclaimed``.
    """

    def __init__(self, epochs: Optional[EpochManager] = None,
                 reclaim: Optional[Callable[[Snapshot], None]] = None):
        self.pool = PlanePool()
        self._pooled = epochs is None and reclaim is None
        if self._pooled:
            self.epochs = EpochManager(reclaim=self._release)
        else:
            self.epochs = epochs or EpochManager(reclaim=reclaim
                                                 or delete_buffers)
        self._lock = threading.Lock()
        self._current: Optional[Snapshot] = None
        self._next_version = 0
        self.published = 0
        self.publish_bytes = 0
        self.last_publish_bytes = 0
        self.publish_seconds = 0.0
        self.planes_copied = 0
        self.planes_aliased = 0
        self.hint_misses = 0

    # -- plane-level reclamation ------------------------------------------

    def _release(self, snap: Snapshot):
        """Pooled reclaimer: drop one reference per plane; buffers are
        deleted only when the last aliasing snapshot releases them."""
        import jax
        for leaf in jax.tree.leaves(snap.state):
            self.pool.decref(leaf)

    @property
    def current(self) -> Optional[Snapshot]:
        with self._lock:
            return self._current

    @property
    def version(self) -> int:
        with self._lock:
            return -1 if self._current is None else self._current.version

    # -- publishing --------------------------------------------------------

    def _install(self, state: Any):
        """Register a fully-assembled state as the newest version (caller
        holds ``_lock``). Returns (snapshot, superseded-or-None)."""
        import jax
        snap = Snapshot(self._next_version, state)
        self._next_version += 1
        if self._pooled:
            for leaf in jax.tree.leaves(state):
                self.pool.incref(leaf)
        old, self._current = self._current, snap
        self.published += 1
        return snap, old

    def publish(self, state: Any) -> Snapshot:
        """Install ``state`` as the newest version; retire the old one.
        The caller passes a state whose buffers it will not donate
        afterwards (no copy is made here)."""
        with self._lock:
            snap, old = self._install(state)
        if old is not None:
            self.epochs.retire(old)
        return snap

    def publish_cow(self, cfg: layout.DashConfig, live: layout.DashState,
                    dirty_hint: Optional[DirtyHint] = None) -> Snapshot:
        """O(dirty) publish of the live table state (see module docstring).

        ``live`` is only read (gathered) — its buffers stay owned by the
        engine's donation chain. The first publish (and any ``dirty_hint``
        with ``full`` set, e.g. after a crash simulation that bypasses the
        version discipline, or pointer-mode tables whose key heap carries
        no version words) falls back to a whole-state copy.

        One publisher at a time (the frontends' write side is sequential);
        concurrent readers are supported. The device diff — which blocks on
        the write batch's pending dispatches — runs OUTSIDE the registry
        lock so readers acquiring mid-publish stall only for the assembly
        (the donated scatter must exclude new pins, so it stays inside).
        """
        import jax
        import jax.numpy as jnp
        assert self._pooled, "publish_cow needs the pool-managed registry"
        t0 = time.perf_counter()
        force_full = (dirty_hint is not None and dirty_hint.full) \
            or cfg.pointer_mode
        prev = self.current                # stable: single publisher

        if prev is None or force_full \
                or not isinstance(prev.state, layout.DashState):
            state = jax.tree.map(jnp.copy, live)
            nbytes = layout.state_nbytes(state)
            with self._lock:
                self.planes_copied += len(jax.tree.leaves(state))
                snap, old = self._install(state)
        else:
            diff = self._cow_diff(cfg, prev, live, dirty_hint)
            with self._lock:
                snap, old, nbytes = self._assemble_cow_locked(
                    cfg, prev, live, *diff)
        with self._lock:
            self.publish_bytes += nbytes
            self.last_publish_bytes = nbytes
            self.publish_seconds += time.perf_counter() - t0
        if old is not None:
            self.epochs.retire(old)
        return snap

    def _cow_diff(self, cfg, prev: Snapshot, live: layout.DashState,
                  dirty_hint: Optional[DirtyHint]):
        """Device diff + host id extraction (syncs on pending device work —
        called outside the registry lock)."""
        from . import engine

        NB, BT = cfg.num_buckets, cfg.buckets_total
        mask = np.asarray(engine.changed_rows(prev.state.version,
                                              live.version))
        # dir + per-segment metadata carry no version words: alias-vs-copy
        # is decided by one bundled content compare (tiny planes)
        meta_names = layout.DIR_PLANES + layout.SEG_META_PLANES
        meta_neq = [bool(x) for x in _neq_many()(
            tuple(getattr(prev.state, n) for n in meta_names),
            tuple(getattr(live, n) for n in meta_names))]
        lead_shape = live.version.shape[:-1]       # (S,) or (n_shards, S)
        m = mask.reshape(lead_shape + (BT,))
        ids_bt = np.flatnonzero(mask).astype(np.int32)
        ids_nb = np.flatnonzero(m[..., :NB]).astype(np.int32)

        # audit the host dirty hint against the device ground truth: every
        # device-dirty segment (and a changed directory) must have been
        # reported by some mutating path
        if dirty_hint is not None and len(lead_shape) == 1:
            if ids_bt.size:
                seen = set(np.unique(ids_bt // BT).tolist())
                self.hint_misses += len(seen - dirty_hint.segments)
            if meta_neq[0] and not dirty_hint.dir:   # DIR_PLANES lead
                self.hint_misses += 1
        return ids_bt, ids_nb, meta_neq

    def _assemble_cow_locked(self, cfg, prev: Snapshot,
                             live: layout.DashState,
                             ids_bt, ids_nb, meta_neq):
        import jax.numpy as jnp

        meta_names = layout.DIR_PLANES + layout.SEG_META_PLANES
        lead_shape = live.version.shape[:-1]
        new = {}
        copied_bytes = 0
        scatter_donate, scatter_copy = _scatter_fns()
        nlead = len(lead_shape) + 1
        for names, ids in ((layout.BT_PLANES, ids_bt),
                           (layout.NB_PLANES, ids_nb)):
            prev_leaves = tuple(getattr(prev.state, n) for n in names)
            if ids.size == 0:
                # nothing in this group changed: alias the previous
                # version's planes (refcounted by _install)
                for n, leaf in zip(names, prev_leaves):
                    new[n] = leaf
                self.planes_aliased += len(names)
                continue
            live_leaves = tuple(getattr(live, n) for n in names)
            rows = math.prod(live_leaves[0].shape[:nlead])
            pad = _pad_ids(ids, rows)
            donate = prev.pins == 0 and all(
                self.pool.refcount(l) == 1 for l in prev_leaves)
            if donate:
                # in-place: the previous version's buffers are exclusively
                # ours — reuse them, moving only the dirty rows
                outs = scatter_donate(prev_leaves, live_leaves, pad, nlead)
                copied_bytes += ids.size * sum(
                    l.nbytes // rows for l in live_leaves)
            else:
                # pinned / shared planes: scatter into fresh copies (XLA
                # copies the base — the honest whole-plane cost)
                outs = scatter_copy(prev_leaves, live_leaves, pad, nlead)
                copied_bytes += sum(l.nbytes for l in live_leaves)
            for n, out in zip(names, outs):
                new[n] = out
            self.planes_copied += len(names)

        for n, changed in zip(meta_names, meta_neq):
            if bool(changed):
                leaf = jnp.copy(getattr(live, n))
                new[n] = leaf
                copied_bytes += leaf.nbytes
                self.planes_copied += 1
            else:
                new[n] = getattr(prev.state, n)     # aliased, refcounted
                self.planes_aliased += 1

        # scalars + key heap: tiny, copied every publish — a snapshot must
        # never alias the live arrays (the engine donates those on the next
        # dispatch), and scalar counters change with almost every batch
        for n in live._fields:
            if n in new:
                continue
            leaf = jnp.copy(getattr(live, n))
            new[n] = leaf
            copied_bytes += leaf.nbytes
            self.planes_copied += 1

        snap, old = self._install(type(live)(**new))
        return snap, old, copied_bytes

    # -- readers -----------------------------------------------------------

    class _Acquired:
        def __init__(self, registry: "SnapshotRegistry"):
            self.registry = registry

        def __enter__(self) -> Snapshot:
            # epoch FIRST: from this point no retired version this reader
            # could still see is reclaimed. Pinning before entering would
            # leave a window where the pinned version's planes are freed
            # (reclamation consults epochs, pins only gate donation).
            self.epoch = self.registry.epochs.enter()
            try:
                with self.registry._lock:
                    snap = self.registry._current
                    assert snap is not None, "acquire() before first publish()"
                    snap.pins += 1
                    self.snap = snap
            except BaseException:
                self.registry.epochs.exit(self.epoch)   # don't leak the pin
                raise
            return snap

        def __exit__(self, *exc):
            with self.registry._lock:
                self.snap.pins -= 1
            self.registry.epochs.exit(self.epoch)

    def acquire(self) -> "_Acquired":
        """Pin an epoch (and the snapshot's pin count) and yield the newest
        published Snapshot."""
        return self._Acquired(self)

    @property
    def reclaimed(self) -> int:
        return self.epochs.reclaimed

    def flush(self):
        self.epochs.flush()

    def stats(self) -> dict:
        """One observability surface for benches and tests."""
        return {
            "published": self.published,
            "publish_bytes": self.publish_bytes,
            "last_publish_bytes": self.last_publish_bytes,
            "publish_seconds": self.publish_seconds,
            "planes_copied": self.planes_copied,
            "planes_aliased": self.planes_aliased,
            "reclaimed": self.reclaimed,
            "hint_misses": self.hint_misses,
            "live_planes": self.pool.live_planes,
        }
