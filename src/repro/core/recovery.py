"""Crash simulation + instant/lazy recovery (paper Sec. 4.8).

Instant recovery is a *constant* amount of work: read the ``clean`` marker and
possibly bump the one-byte global version ``V``. All real work (clearing
locks, removing duplicate records left by in-flight displacements, rebuilding
the non-persisted overflow metadata, finishing or rolling back SMOs) is
deferred to the first access of each segment (``seg_version != V``).

The crash simulator produces exactly the artifact classes the paper's
recovery handles:
  * locked buckets (lock bit left set),
  * duplicated records (displacement step 1 done, step 2 lost),
  * wiped overflow metadata (paper: "we do not explicitly persist it"),
  * an in-flight SMO (segment in SPLITTING with a NEW side-linked neighbor).

The durable path (src/repro/persist/) reuses this machinery unchanged: a
pool torn mid-flush reopens through ``instant_restart`` (the superblock's
clean marker overriding the possibly-stale plane scalar) and the same lazy
per-segment recovery absorbs the torn-flush artifact classes — they are a
subset of the simulator's (half-done displacements become in-segment dups,
stale overflow metadata is rebuilt, an interrupted SMO is finished or rolled
back from ``seg_state``/``side_link``).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import bucket as bk
from . import engine, hashing, layout
from .layout import (SEG_NEW, SEG_NORMAL, SEG_SPLITTING, DashConfig,
                     DashState, U32)

I32 = jnp.int32


# ---------------------------------------------------------------------------
# instant restart — O(1) regardless of table size (Table 1's 57 ms analog)
# ---------------------------------------------------------------------------

def instant_restart(state: DashState, clean_override=None):
    """Read ``clean``; bump ``V`` if the shutdown was dirty. Nothing else.

    ``clean_override`` is the durable path's hook (persist/): the pool
    superblock's clean marker is written post-fence at every commit and is
    therefore authoritative over the plane region's ``clean`` scalar, which
    a torn scalar flush can leave stale. Either way the restarted state is
    marked dirty-serving (``clean=False``): a crash from here on must
    recover."""
    t0 = time.perf_counter()
    was_clean = bool(np.asarray(state.clean)) if clean_override is None \
        else bool(clean_override)
    state = state._replace(clean=jnp.asarray(False))
    if not was_clean:
        state = state._replace(gver=state.gver + U32(1))
    return state, {"clean": was_clean, "seconds": time.perf_counter() - t0}


# ---------------------------------------------------------------------------
# per-segment lazy recovery (jitted data plane)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2,))
def recover_segment(cfg: DashConfig, mode: str, state: DashState, seg):
    """Steps 1–3 of Sec. 4.8 for one segment: clear locks, dedupe displaced
    records, rebuild overflow metadata. SMO continuation (step 4) is
    orchestrated by the host (recover_segment_host)."""
    NB, BT, SL = cfg.num_buckets, cfg.buckets_total, cfg.num_slots

    # (1) clear lock bits
    ver = jax.lax.dynamic_slice(state.version, (seg, 0), (1, BT))[0]
    state = state._replace(version=jax.lax.dynamic_update_slice(
        state.version, ((ver & ~U32(1)) + U32(2))[None], (seg, 0)))

    # (2) dedupe: a displaced record can appear in adjacent buckets (b, b+1);
    # fingerprints prefilter, full key compare confirms (both cheap here)
    hi = jax.lax.dynamic_slice(state.key_hi, (seg, 0, 0), (1, BT, SL))[0]
    lo = jax.lax.dynamic_slice(state.key_lo, (seg, 0, 0), (1, BT, SL))[0]
    meta = jax.lax.dynamic_slice(state.meta, (seg, 0), (1, BT))[0]
    slot_ids = jnp.arange(SL, dtype=U32)[None, :]
    alloc = ((layout.meta_alloc(meta)[:, None] >> slot_ids) & U32(1)) == 1
    member = ((layout.meta_member(meta)[:, None] >> slot_ids) & U32(1)) == 1

    nb_idx = jnp.arange(NB)
    nxt = (nb_idx + 1) % NB
    eq = ((hi[:NB][:, :, None] == hi[nxt][:, None, :])
          & (lo[:NB][:, :, None] == lo[nxt][:, None, :])
          & alloc[:NB][:, :, None] & alloc[nxt][:, None, :])
    dup_next = jnp.any(eq, axis=1)                       # (NB, SL) dup in bucket nxt[b]
    dup = jnp.zeros((BT, SL), jnp.bool_).at[nxt].set(dup_next)

    new_alloc = alloc & ~dup
    new_member = member & ~dup
    counts = jnp.sum(new_alloc, axis=1).astype(U32)
    packed = layout.meta_pack(
        jnp.sum(new_alloc.astype(U32) << slot_ids, axis=1),
        jnp.sum(new_member.astype(U32) << slot_ids, axis=1),
        counts)
    state = state._replace(meta=jax.lax.dynamic_update_slice(
        state.meta, packed[None], (seg, 0)))

    # (3) rebuild overflow metadata from stash contents
    state = state._replace(
        ometa=jax.lax.dynamic_update_slice(
            state.ometa, jnp.zeros((1, NB), U32), (seg, 0)),
        ofp=jax.lax.dynamic_update_slice(
            state.ofp, jnp.zeros((1, NB, 4), jnp.uint8), (seg, 0, 0)),
    )
    if cfg.num_stash > 0:
        s_ids = jnp.repeat(jnp.arange(cfg.num_stash), SL)
        slot_flat = jnp.tile(jnp.arange(SL), cfg.num_stash)

        def step(st, xs):
            s_j, sl = xs
            sb = NB + s_j
            a = (layout.meta_alloc(st.meta[seg, sb]) >> sl.astype(U32)) & U32(1)
            r_hi, r_lo = st.key_hi[seg, sb, sl], st.key_lo[seg, sb, sl]
            h1, h2 = engine.record_hashes(cfg, st, r_hi[None], r_lo[None])
            h1, h2 = h1[0], h2[0]
            if mode == "eh":
                b = layout.bucket_index(cfg, h1)
            else:
                b = layout.lh_bucket_index(cfg, h1)
            fpv = hashing.fingerprint(h2)

            def do(s):
                s1, ok1 = bk.ofp_try_set(cfg, s, seg, b, fpv, s_j, member=False)

                def try_prob(_):
                    pb = (b + 1) & (NB - 1)
                    s2, ok2 = bk.ofp_try_set(cfg, s1, seg, pb, fpv, s_j, member=True)
                    s3 = bk.ovf_count_add(s2, seg, b, 1)
                    return jax.lax.cond(ok2, lambda q: q[0], lambda q: q[1], (s2, s3))

                return jax.lax.cond(ok1, lambda _: s1, try_prob, None)

            st = jax.lax.cond(a == 1, do, lambda s: s, st)
            return st, ()

        state, _ = jax.lax.scan(step, state, (s_ids, slot_flat))

    # n_items stays put: crash artifacts (duplicate slots from half-done
    # displacements) were never counted, so removing them restores the meta
    # counts to agree with the incrementally-maintained total — no
    # whole-table recount (tests assert n_items == engine.recount_items).
    state = state._replace(
        seg_version=state.seg_version.at[seg].set(state.gver))
    return state


def recover_segment_host(cfg: DashConfig, mode: str, state: DashState, seg: int):
    """Step 4 orchestration: finish or roll back an in-flight SMO, then run
    the jitted per-segment recovery."""
    from . import dash_eh  # local import to avoid cycle

    seg_states = np.asarray(state.seg_state)
    side = np.asarray(state.side_link)

    if mode == "eh" and seg_states[seg] == SEG_NEW:
        # recover from the SPLITTING source side (it redoes the rehash)
        srcs = np.where((side == seg) & (seg_states == SEG_SPLITTING))[0]
        if srcs.size:
            return recover_segment_host(cfg, mode, state, int(srcs[0]))

    if mode == "eh" and seg_states[seg] == SEG_SPLITTING:
        nbr = int(side[seg])
        if nbr >= 0 and seg_states[nbr] == SEG_NEW:
            # continue the split: phase 2 is idempotent (uniqueness-checked).
            # split_phase2 dispatches to the vectorized SMO rebuild, which
            # extracts BOTH halves and dedupes before placing (the paper's
            # "redo the rehashing with uniqueness check").
            state, ok = dash_eh.split_phase2(
                cfg, state, jnp.asarray(seg, jnp.int32), jnp.asarray(nbr, jnp.int32),
                True)
            assert bool(ok)
        else:
            # roll back: reset the state variable (paper Sec. 4.8)
            state = state._replace(
                seg_state=state.seg_state.at[seg].set(SEG_NORMAL),
                local_depth=state.local_depth.at[seg].add(-1),
            )

    return recover_segment(cfg, mode, state, jnp.asarray(seg, jnp.int32))


def recover_all(cfg: DashConfig, mode: str, state: DashState):
    """Eager full recovery (used by benchmarks as the 'CCEH-style' contrast
    and by tests to reach a known-good state)."""
    wm = int(np.asarray(state.watermark))
    for seg in range(wm):
        state = recover_segment_host(cfg, mode, state, seg)
    return state


def dirty_touched_segments(state: DashState, touched) -> list:
    """Which of the ``touched`` segment ids still owe post-crash recovery
    (their ``seg_version`` lags the recovery generation)? Host-side gate of
    the per-access lazy hook — shared by the single-table access path and
    the DHT's per-shard ``ensure_recovered``."""
    gver = int(np.asarray(state.gver))
    seg_ver = np.asarray(state.seg_version)
    out = []
    for seg in np.unique(np.asarray(touched)):
        if seg >= 0 and int(seg_ver[seg]) != gver:
            out.append(int(seg))
    return out


def lazy_recover_touched(cfg: DashConfig, mode: str, state: DashState,
                         touched, note=None):
    """Recover exactly the dirty segments among ``touched`` (paper Sec. 4.8:
    recovery work proportional to data *accessed*, not data stored).

    ``note(seg, affected)``, if given, is called BEFORE each segment's
    recovery with the segment ids the repair may rewrite (the segment, its
    side-link, and any segment side-linked to it) — callers use it to mark
    copy-on-write rows dirty or emit trace events. Returns
    ``(state, recovered_ids)``."""
    recovered = []
    for seg in dirty_touched_segments(state, touched):
        if note is not None:
            side = np.asarray(state.side_link)
            affected = [seg, int(side[seg])]
            affected += [int(s) for s in np.nonzero(side == seg)[0]]
            note(seg, affected)
        state = recover_segment_host(cfg, mode, state, seg)
        recovered.append(seg)
    return state, recovered


# ---------------------------------------------------------------------------
# media-fault quarantine (PR 6): checksum-failing pool rows at reopen
# ---------------------------------------------------------------------------

def quarantine_rows(cfg: DashConfig, mode: str, state: DashState,
                    disk_version: np.ndarray,
                    bt_rows: np.ndarray, nb_rows: np.ndarray):
    """Host-side surgery after ``PmPool.verify_checksums`` flagged rows at
    reopen. The redo-log path has already rebuilt everything it could
    (``apply_log`` runs before verification and heals both data and
    checksums of every committed-logged row), so a row that still fails
    here has no durable recourse — we refuse to serve its bytes:

      * a **BT row** (bucket: records + publish words) is cleared — its
        meta word is zeroed so no slot is served — and every record it
        held is *explicitly lost*: the row goes into the returned report
        (the never-a-wrong-read half of the safety property; the
        lost-keys half is the report itself).
      * an **NB row** (overflow metadata) forces a metadata rebuild only:
        ometa/ofp are derived from stash contents, so zeroing them loses
        no keys — lazy recovery reconstructs them.

    Affected segments are marked for lazy recovery (``seg_version = 0``
    never matches ``gver >= 1``) and the quarantined rows' bucket version
    words (for NB rows: the bucket the overflow metadata belongs to) are
    set off the POOL's stored word, so the next flush rewrites the row
    (and its checksum) — quarantine self-heals on flush.

    Returns ``(state, report)``; report entries are dicts with ``plane``
    ("bt" / "nb"), ``seg``, ``bucket``, ``row``, and for BT rows the
    cleared record count (``lost_records``)."""
    BT, NB = cfg.buckets_total, cfg.num_buckets
    report = []
    segs = set()
    lost_records = 0
    if len(bt_rows):
        meta = np.asarray(state.meta).copy()
        version = np.asarray(state.version).copy()
        disk_v = np.asarray(disk_version).reshape(-1)
        for r in np.asarray(bt_rows).reshape(-1):
            r = int(r)
            s, b = r // BT, r % BT
            n_rec = int((meta[s, b] >> layout.COUNT_SHIFT) & 0xF)
            lost_records += n_rec
            meta[s, b] = 0
            # differs from the pool's word by construction, lock bit clear
            version[s, b] = np.uint32((int(disk_v[r]) + 2) & ~1)
            segs.add(s)
            report.append({"plane": "bt", "seg": s, "bucket": b, "row": r,
                           "lost_records": n_rec})
        n_items = max(0, int(np.asarray(state.n_items)) - lost_records)
        state = state._replace(meta=jnp.asarray(meta),
                               version=jnp.asarray(version),
                               n_items=jnp.asarray(n_items, jnp.int32))
    if len(nb_rows):
        ometa = np.asarray(state.ometa).copy()
        ofp = np.asarray(state.ofp).copy()
        version = np.asarray(state.version).copy()
        disk_v = np.asarray(disk_version).reshape(-1)
        for r in np.asarray(nb_rows).reshape(-1):
            r = int(r)
            s, b = r // NB, r % NB
            ometa[s, b] = 0
            ofp[s, b] = 0
            # NB rows ride their bucket's version diff in the writeback:
            # force the bucket dirty so the next flush rewrites ometa/ofp
            # (and their checksums) even when the records were untouched
            version[s, b] = np.uint32((int(disk_v[s * BT + b]) + 2) & ~1)
            segs.add(s)
            report.append({"plane": "nb", "seg": s, "bucket": b, "row": r})
        state = state._replace(ometa=jnp.asarray(ometa),
                               ofp=jnp.asarray(ofp),
                               version=jnp.asarray(version))
    if segs:
        seg_version = np.asarray(state.seg_version).copy()
        seg_version[sorted(segs)] = 0
        state = state._replace(seg_version=jnp.asarray(seg_version))
    return state, report


def heap_top_floor(cfg: DashConfig, state: DashState) -> DashState:
    """Pointer-mode reopen guard: raise ``heap_top`` past the highest heap
    handle any live record references.

    A flush dies between its publish fence (phase 2: meta rows, record
    visible) and its scalar/log commit (phase 3+), leaving published
    records whose bump-allocated handles exceed the durable ``heap_top``.
    Their heap ROWS are durable — the writeback places the heap tail in
    phase 1, before any handle publishes — but a reopen that trusted the
    stale scalar would hand those rows out again and silently corrupt the
    acked records pointing at them. Runs AFTER ``quarantine_rows``:
    quarantined rows have their meta zeroed, so a torn handle word can
    never inflate the floor."""
    if not cfg.pointer_mode or cfg.key_heap_size <= 0:
        return state
    meta = np.asarray(state.meta)
    alloc = np.asarray(layout.meta_alloc(meta), np.uint32)
    mask = ((alloc[..., None] >> np.arange(cfg.num_slots, dtype=np.uint32))
            & np.uint32(1)).astype(bool)
    handles = np.asarray(state.key_lo)[mask]
    floor = int(handles.max()) + 1 if handles.size else 0
    floor = min(floor, cfg.key_heap_size)
    top = np.asarray(state.heap_top)
    if floor > int(top):
        state = state._replace(
            heap_top=jnp.asarray(np.asarray(floor, top.dtype)))
    return state


# ---------------------------------------------------------------------------
# crash simulation (host-side, numpy surgery on the state)
# ---------------------------------------------------------------------------

def simulate_crash(cfg: DashConfig, mode: str, state: DashState,
                   rng: np.random.Generator, lock_frac: float = 0.05,
                   n_dups: int = 4, wipe_overflow: bool = True,
                   interrupt_smo: bool = False) -> DashState:
    from . import dash_eh

    wm = int(np.asarray(state.watermark))
    NB, SL = cfg.num_buckets, cfg.num_slots

    version = np.asarray(state.version).copy()
    n_lock = max(1, int(lock_frac * wm * cfg.buckets_total))
    segs = rng.integers(0, wm, n_lock)
    bks = rng.integers(0, cfg.buckets_total, n_lock)
    version[segs, bks] |= 1                     # locks left held

    fp = np.asarray(state.fp).copy()
    key_hi = np.asarray(state.key_hi).copy()
    key_lo = np.asarray(state.key_lo).copy()
    val = np.asarray(state.val).copy()
    meta = np.asarray(state.meta).copy()

    made = 0
    for _ in range(n_dups * 20):
        if made >= n_dups:
            break
        s = int(rng.integers(0, wm))
        b = int(rng.integers(0, NB))
        alloc = int(meta[s, b]) & layout.SLOT_MASK
        occupied = [i for i in range(SL) if alloc >> i & 1]
        if not occupied:
            continue
        i = occupied[int(rng.integers(0, len(occupied)))]
        nb = (b + 1) % NB
        alloc_n = int(meta[s, nb]) & layout.SLOT_MASK
        free = [j for j in range(SL) if not (alloc_n >> j & 1)]
        if not free:
            continue
        j = free[0]
        # displacement step 1 done (copy to neighbor, membership set),
        # step 2 (delete from source) lost in the crash:
        key_hi[s, nb, j] = key_hi[s, b, i]
        key_lo[s, nb, j] = key_lo[s, b, i]
        val[s, nb, j] = val[s, b, i]
        fp[s, nb, j] = fp[s, b, i]
        m = int(meta[s, nb])
        alloc_n |= 1 << j
        memb = ((m >> layout.MEMBER_SHIFT) & layout.SLOT_MASK) | (1 << j)
        cnt = ((m >> layout.COUNT_SHIFT) & 0xF) + 1
        meta[s, nb] = (alloc_n | (memb << layout.MEMBER_SHIFT)
                       | (cnt << layout.COUNT_SHIFT))
        made += 1

    new = state._replace(
        version=jnp.asarray(version),
        fp=jnp.asarray(fp), key_hi=jnp.asarray(key_hi),
        key_lo=jnp.asarray(key_lo), val=jnp.asarray(val),
        meta=jnp.asarray(meta),
        clean=jnp.asarray(False),
    )
    if wipe_overflow:
        new = new._replace(
            ometa=jnp.zeros_like(new.ometa),
            ofp=jnp.zeros_like(new.ofp),
        )
    if interrupt_smo and mode == "eh" and wm < cfg.max_segments:
        depths = np.asarray(new.local_depth)
        candidates = [s for s in range(wm) if depths[s] < cfg.dir_depth_max]
        if candidates:
            victim = int(rng.choice(candidates))
            new, _ = dash_eh.split_phase1(cfg, new, jnp.asarray(victim, jnp.int32))
    return new
