"""Dash-LH: linear hashing with Dash building blocks (paper Sec. 5).

Linear hashing always splits the segment at ``Next`` (not the overflowing
one). ``(level, Next)`` live packed in one 32-bit word — the paper packs
``(N, Next)`` into one 64-bit word for atomic update (Sec. 5.3); advancing the
word *is* the split's publish point, after which addressing routes re-hashed
keys with the next round's mask.

The paper's stash-chaining replaces classic per-record overflow chains: a
fixed base of stash buckets plus chained extras, and "a segment split is
triggered whenever a stash bucket is allocated". Our static-shape analog:
each segment owns ``num_stash`` preallocated stash buckets of which
``stash_active[seg]`` are live; activating one beyond the base emits a split
signal that the host wrapper turns into ``split_next`` (Sec. 5.3's
split-by-accessing-thread, serialized here by batch semantics). Under the
online-resize frontend the same signal plans a deferred stride expansion
(core/smo.py:BulkSplitNextTask via DashLH.make_smo_task) pumped between
read batches — the (level, Next) word advance stays the atomic publish
point readers verify against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import engine, layout
from .dash_eh import _clear_segment
from .layout import (EXISTS, NEED_SPLIT, SEG_NORMAL, DashConfig, DashState, U32)

I32 = jnp.int32


def _rehash_scan(cfg: DashConfig, state: DashState, seg):
    """Shared scan-rehash body: extract one segment's records, clear it,
    re-insert every record through *current* LH addressing. ``n_items`` is
    restored (a rehash moves records — net zero). Returns (state, ok).

    The whole cleared segment's version rows bump: rows a record moved OUT
    of change content without a bucket_write, and the copy-on-write publish
    scatters exactly the version-changed rows."""
    n0 = state.n_items
    hi, lo, val, valid = engine.segment_records(cfg, state, seg)
    h1, h2 = engine.record_hashes(cfg, state, hi, lo)
    state = _clear_segment(cfg, state, seg)
    state = state._replace(version=state.version.at[seg].add(U32(2)))

    def step(st, xs):
        r_hi, r_lo, r_val, r_valid, r_h1, r_h2 = xs
        dseg = st.lh_dir[layout.lh_logical_segment(cfg, r_h1, st.lh_word)]
        b = layout.lh_bucket_index(cfg, r_h1)

        def do(s):
            s2, status, _ = engine._insert_core(
                cfg, s, dseg, b, r_h1, r_h2, r_hi, r_lo,
                jnp.zeros((cfg.key_heap_words,), U32), r_val,
                check_unique=False, heap_append=False)
            return s2, status

        st, status = jax.lax.cond(r_valid, do, lambda s: (s, I32(EXISTS)), st)
        return st, status != I32(NEED_SPLIT)

    state, fits = jax.lax.scan(step, state, (hi, lo, val, valid, h1, h2))
    return state._replace(n_items=n0), jnp.all(fits)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def split_next_scan(cfg: DashConfig, state: DashState):
    """Split the segment at Next with the per-record scan rehash; advance
    (level, Next); returns (state, ok). Reference path, retained for
    differential testing against the vectorized SMO engine."""
    level, nxt = layout.lh_level_next(state.lh_word)
    n_round = 1 << cfg.lh_base_log2
    round_size = (n_round << level.astype(jnp.uint32)).astype(I32)

    old_logical = nxt
    new_logical = round_size + nxt
    old_phys = state.lh_dir[old_logical]
    new_phys = state.watermark

    # advance the packed word FIRST (the atomic publish of Sec. 5.3): from now
    # on, keys in the old logical bucket re-hash with the next round's mask.
    nxt2 = nxt + 1
    wrap = nxt2 >= round_size
    new_word = layout.lh_pack(level + wrap.astype(I32), jnp.where(wrap, 0, nxt2))
    state = state._replace(
        lh_word=new_word,
        lh_dir=state.lh_dir.at[new_logical].set(new_phys),
        watermark=state.watermark + 1,
        stash_active=state.stash_active
            .at[old_phys].set(min(cfg.num_stash, cfg.lh_base_stash))
            .at[new_phys].set(min(cfg.num_stash, cfg.lh_base_stash)),
        seg_version=state.seg_version.at[new_phys].set(state.gver),
    )

    state, fits = _rehash_scan(cfg, state, old_phys)
    return state._replace(n_splits=state.n_splits + 1), fits


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def rehash_segment_scan(cfg: DashConfig, state: DashState, seg):
    """Scan-rehash fallback for one lane of a bulk expansion whose
    vectorized rebuild reported an infeasible packing. The (level, Next)
    word is already advanced, so this is exactly the tail of
    split_next_scan. Returns (state, ok)."""
    return _rehash_scan(cfg, state, seg)


def split_next(cfg: DashConfig, state: DashState):
    """Split the segment at Next through the vectorized SMO engine
    (``smo.bulk_split_next`` with a stride of 1); scan fallback for configs
    or packings the rebuild does not cover. Returns (state, ok)."""
    from . import smo
    if not smo.rebuild_eligible(cfg):
        return split_next_scan(cfg, state)
    state, ok, old_phys = smo.bulk_split_next(cfg, state, 1)
    if not bool(ok[0]):
        return rehash_segment_scan(cfg, state, old_phys[0])
    return state, jnp.asarray(True)


def lh_active_segments(cfg: DashConfig, state: DashState) -> int:
    """Number of live logical segments (host-side helper)."""
    import numpy as np
    word = int(np.asarray(state.lh_word))
    level, nxt = word >> 24, word & 0xFFFFFF
    return (1 << cfg.lh_base_log2) * (1 << level) + nxt


def hybrid_expansion_directory(n_segments: int, stride: int = 8,
                               first_array: int = 64, entry_bytes: int = 8):
    """Paper Sec. 5.2 hybrid expansion accounting: directory entries point to
    segment ARRAYS; after every ``stride`` fixed-size expansions the array
    size doubles. Returns (entries, directory_bytes, largest_array).

    Reproduces the paper's claim: with 16KB segments, a 64-segment first
    array and stride 4-8, TB-scale data is indexed by a sub-KB, L1-resident
    directory."""
    entries = 0
    covered = 0
    array_size = first_array
    while covered < n_segments:
        for _ in range(stride):
            entries += 1
            covered += array_size
            if covered >= n_segments:
                return entries, entries * entry_bytes, array_size
        array_size *= 2
    return entries, entries * entry_bytes, array_size
