"""Fault-tolerant training loop: checkpoint/restart, failure handling,
straggler monitoring, elastic resize hooks.

Mirrors the paper's recovery philosophy at trainer scale:
  * periodic atomic commits (async), `clean` marker flipped on graceful stop;
  * a step failure (device loss, NaN, injected fault) triggers restore from
    the last commit — restore itself is *instant* (manifest only) and tensor
    bytes stream in lazily;
  * the straggler monitor tracks per-step wall time and flags hosts whose
    step time exceeds mean + k*sigma — at fleet scale the runbook response is
    hot-spare swap + elastic re-mesh (launch/elastic.py), which we exercise
    in tests by shrinking the device mesh and resharding the checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models.transformer import ModelConfig, init_params
from repro.train.steps import TrainState, make_train_step, train_state_init


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    max_restarts: int = 3
    straggler_window: int = 20
    straggler_sigma: float = 3.0
    peak_lr: float = 3e-4


class StragglerMonitor:
    """Per-step wall-time outlier detection (host-side)."""

    def __init__(self, window: int, sigma: float):
        self.times = deque(maxlen=window)
        self.sigma = sigma
        self.flagged = []

    def record(self, step: int, seconds: float) -> bool:
        is_straggler = False
        if len(self.times) >= max(5, self.times.maxlen // 2):
            mean = float(np.mean(self.times))
            std = float(np.std(self.times)) + 1e-9
            if seconds > mean + self.sigma * std:
                self.flagged.append((step, seconds, mean))
                is_straggler = True
        self.times.append(seconds)
        return is_straggler


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 data_iter: Iterator[dict],
                 fault_hook: Optional[Callable[[int], None]] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data_iter = data_iter
        self.fault_hook = fault_hook          # raises to simulate failures
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir, tcfg.keep_checkpoints)
        self.monitor = StragglerMonitor(tcfg.straggler_window,
                                        tcfg.straggler_sigma)
        self.step_fn = jax.jit(make_train_step(cfg, peak_lr=tcfg.peak_lr),
                               donate_argnums=(0,))
        params, _ = init_params(jax.random.PRNGKey(seed), cfg)
        self.state = train_state_init(params)
        self.metrics_log = []
        self.restarts = 0
        self.version = 1

    # -- recovery ---------------------------------------------------------

    def _restore(self):
        self.ckpt.wait()          # an in-flight async commit must land first
        manifest, lazy, secs = self.ckpt.restore_manifest()
        if manifest is None:
            raise RuntimeError("no checkpoint to restore from")
        self.version = manifest["version"]
        self.state = self.ckpt.restore_tree(self.state, lazy)
        return manifest["step"], secs

    def resume_if_possible(self) -> Optional[int]:
        if self.ckpt.latest_step() is None:
            return None
        step, secs = self._restore()
        return step

    # -- main loop --------------------------------------------------------

    def run(self) -> dict:
        step = int(np.asarray(self.state.step))
        while step < self.tcfg.total_steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in next(self.data_iter).items()}
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(np.asarray(metrics["loss"]))
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
            except Exception as e:                      # failure path
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                restored_step, secs = self._restore()
                self.metrics_log.append(
                    {"step": step, "event": "restart", "error": repr(e),
                     "restored_step": restored_step,
                     "manifest_restore_s": secs})
                step = restored_step
                # rebuild jit (a real device failure would re-init the mesh)
                self.step_fn = jax.jit(
                    make_train_step(self.cfg, peak_lr=self.tcfg.peak_lr),
                    donate_argnums=(0,))
                continue

            dt = time.perf_counter() - t0
            straggler = self.monitor.record(step, dt)
            self.metrics_log.append({"step": step, "loss": loss,
                                     "seconds": dt, "straggler": straggler})
            step += 1
            if step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step, self.state, clean=False,
                               version=self.version,
                               blocking=not self.tcfg.async_checkpoint)
        # graceful shutdown: final clean commit (paper's clean marker)
        self.ckpt.wait()
        self.ckpt.save(step, self.state, clean=True, version=self.version,
                       blocking=True)
        return {"final_step": step, "restarts": self.restarts,
                "stragglers": list(self.monitor.flagged),
                "log": self.metrics_log}
