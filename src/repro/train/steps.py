"""Composed jittable steps: train (fwd+bwd+AdamW), prefill, decode.

These are the functions the dry-run lowers and the trainer executes. All
sharding is carried by in/out shardings + logical constraints; the functions
themselves are mesh-agnostic.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.transformer import (ModelConfig, forward_train, loss_fn,
                                      serve_step)
from repro.optim import adamw
from repro.optim.schedule import cosine_warmup


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    step: jnp.ndarray


def train_state_init(params) -> TrainState:
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    peak_lr: float = 3e-4):
    """(state, batch) -> (state, metrics). Grad all-reduce over DP is implicit
    in the SPMD partition (mean over the global batch)."""

    def step(state: TrainState, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, batch)
        lr = cosine_warmup(state.step, peak_lr=peak_lr)
        params, opt, om = adamw.update(opt_cfg, grads, state.opt, state.params, lr)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "lr": lr, **om}
        return TrainState(params, opt, state.step + 1), metrics

    return step


def make_prefill_step(cfg: ModelConfig):
    """Forward-only (inference prefill): logits of the full prompt."""

    def step(params, batch):
        logits, _ = forward_train(params, cfg, batch)
        return logits

    return step


def make_serve_step(cfg: ModelConfig):
    """One decode token against the KV cache / recurrent state."""

    def step(params, state, inputs):
        return serve_step(params, cfg, state, inputs)

    return step
