"""Training loop substrate: composed steps + fault-tolerant trainer."""
from . import steps

__all__ = ["steps"]
