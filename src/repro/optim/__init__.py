"""Optimizer substrate."""
from . import adamw, schedule
from .adamw import AdamWConfig, AdamWState

__all__ = ["adamw", "schedule", "AdamWConfig", "AdamWState"]
