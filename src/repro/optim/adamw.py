"""AdamW with decoupled weight decay + global-norm clipping.

Optimizer state mirrors param structure (m, v in fp32), so parameter
sharding specs apply verbatim to the state — ZeRO-style sharded optimizer
falls out of the FSDP rules for free.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(m=z, v=jax.tree.map(jnp.copy, z),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params, lr):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = lr * (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        p2 = p.astype(jnp.float32) - step - lr * cfg.weight_decay * p.astype(jnp.float32)
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}
