"""Distributed Dash: the paper's "scalable hashing" scaled out to a TPU pod.

Every device owns an independent Dash-EH table (a shard). The top
log2(n_shards) bits of the addressing hash pick the owner — the distributed
extension of the MSB directory. Query batches start *sharded over devices*;
each device routes its local queries to owners with a fixed-capacity
``all_to_all`` (MoE-style dispatch), owners probe shard-locally (the Pallas
fingerprint path applies verbatim — shards are ordinary Dash tables), and a
second ``all_to_all`` routes results back.

Scalability argument mirrors the paper's: probes are bandwidth-bound and
shards touch disjoint memory; the only cross-chip cost is ~24 bytes/query
each way vs. the ~256-byte bucket traffic it replaces, so the fabric term
stays well under the local-HBM term (benchmarks/dht_roofline.py derives both
from the dry-run artifact).

SMOs stay shard-local: a segment split never moves keys across shards (the
owner bits are disjoint from the shard-local directory bits), so there is no
cross-shard coordination — this is what makes the design elastic: growing
from 1 to 2 pods adds one owner bit and moves only metadata.

**Device-resident hot path.** The steady-state serving loop runs INSIDE the
shard_map program — one dispatch per tick, zero host plane transfers:

* ``snap_search_fn`` probes an epoch-pinned snapshot AND verifies it against
  the live version planes in the same program (``serving.engine.
  buckets_changed_local`` inlined per shard), returning a device-resident
  retry mask instead of the old host-mirrored plane diff.
* ``insert_round_fn`` keeps per-key statuses and the pending mask on device
  across retry rounds; the host syncs a (n_shards, 3) flags array per round
  (any-retry / any-need-split / any-stale), not O(batch) statuses.
* ``split_fn`` plans AND commits every pressured shard's bulk splits in one
  dispatch (``core/smo.plan_local_splits`` + ``split_segments_local``) — no
  host ``np.asarray`` sub-state rebuild.
* Every owner-side probe carries a per-access lazy-recovery hook: lanes
  whose segment's ``seg_version`` lags the recovery generation are flagged
  (reads) or bounced (writes), and the host recovers exactly the touched
  segments — so ``persist.reopen_shards`` defaults to
  ``eager_recover_dirty=False`` and a dirty-shard reopen is O(1) in stored
  data, like the single-table path.

The host-mirror verify and the host split loop are retained (``ShardFrontend
(verify_mode="host")``, ``DistributedDash._split_for_host``) as the
differential references and the bench baseline.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import DashConfig, engine, hashing, layout, recovery, smo
from repro.core.layout import DashState
from repro.kernels import ops as kops
from repro.parallel import sharding
from repro.serving import engine as serving_engine
from repro.serving import frontend

I32 = jnp.int32
U32 = jnp.uint32


def make_sharded_state(cfg: DashConfig, n_shards: int) -> DashState:
    one = layout.make_state(cfg, "eh")
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_shards,) + x.shape).copy(), one)


def make_abstract(cfg: DashConfig, n_shards: int):
    one = jax.eval_shape(lambda: layout.make_state(cfg, "eh"))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_shards,) + x.shape, x.dtype), one)


def owner_of(keys_hi, keys_lo, n_shards: int):
    """Owner shard from the TOP bits of h1 — the distributed MSB directory.
    Shard-local directories consume the next dir_depth_max bits, so probing
    inside the owner uses the unchanged 32-bit hash."""
    h1 = hashing.hash1(keys_hi, keys_lo)
    return (h1 >> U32(32 - int(np.log2(n_shards)))).astype(I32)


def np_owner_of(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Host mirror of ``owner_of`` over raw uint64 keys (routing is pure
    hashing — the host can attribute keys to shards without touching any
    device plane)."""
    hi, lo = hashing.np_split_keys(np.asarray(keys, np.uint64))
    h1 = hashing.np_hash1(hi, lo)
    return (h1 >> np.uint32(32 - int(np.log2(n_shards)))).astype(np.int64)


def _local_dispatch(hi, lo, v, n_shards: int, capacity: int,
                    owner_mask=None):
    """Route this device's queries into (n_shards, capacity) buffers via the
    shared MoE-style dispatcher (kernels/ops.py) — the same sort-based
    router the engine uses to group by segment, here grouping by owner
    shard. ``owner_mask=False`` lanes route to owner -1 (dropped). Returns
    buffers + src map (-1 = empty lane) + kept mask."""
    owner = owner_of(hi, lo, n_shards)
    if owner_mask is not None:
        owner = jnp.where(owner_mask, owner, -1)
    (b_hi, b_lo, b_v), b_src, keep = kops.route_lanes(
        owner, (hi, lo, v), n_shards, capacity, (0, 0, 0))
    return b_hi, b_lo, b_v, b_src, keep


def auto_capacity(q_local: int, n_shards: int, slack: float = 4.0) -> int:
    """Routing lanes per (src, dst): expected q_local/n_shards with slack.
    Oversized lanes are pure wasted wire — right-sizing them was a 16x
    fabric-bytes win at 256 chips (EXPERIMENTS.md SSPerf, DHT cell)."""
    want = int(np.ceil(q_local / n_shards * slack))
    return max(8, 1 << int(np.ceil(np.log2(want))))


def build_dht_programs(cfg: DashConfig, mesh: Mesh, axes=("data",),
                       capacity: int | None = None, q_local_hint: int = 1024,
                       search_batching: str = "vmap", split_lanes: int = 8):
    """All jitted shard_map programs over a device-sharded table.

    Inputs: keys reshaped (n_shards, q_local), sharded on dim 0.
    Payloads are PACKED into one (n_shards, cap, W) word tensor so each
    direction is a single all_to_all (one launch on the ICI, not four).

    ``search_batching`` selects the shard-local read path; shards are
    ordinary Dash tables, so the Pallas fingerprint path applies verbatim
    (pass "pallas"/"auto" on TPU) and so does the fused single-dispatch
    probe (pass "fused" — the natural fit for the small shard-local
    sub-batch, and its direct gather is indifferent to the all_to_all
    padding lanes piling onto key 0's segment). The CPU default stays on
    the per-key path: interpret-mode MXU gathers lose on emulated
    devices, and routed paths would re-bucket the padding lanes.

    ``split_lanes`` bounds the distinct segments one shard splits per
    ``split_fn`` dispatch; surplus pressured segments stay NEED_SPLIT and
    are planned the next round (the retry loop converges regardless).
    """
    axes = tuple(axes)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if capacity is None:
        capacity = auto_capacity(q_local_hint, n_shards)
    st_spec = sharding.shard_specs(axes, make_abstract(cfg, n_shards))
    q_spec = P(axes)
    a2a = lambda x: jax.lax.all_to_all(x, axes, 0, 0, tiled=True)

    def _local(st):
        return jax.tree.map(lambda x: x[0], st)

    def _stale_lanes(local, h1, valid):
        """Per-access lazy-recovery hook: a lane whose segment's
        seg_version lags the recovery generation (gver) may observe a
        crash-wiped probe structure — flag it; the host recovers exactly
        the touched segments and the lane retries."""
        seg = local.dir[layout.dir_index(cfg, h1)]
        return valid & (local.seg_version[seg] != local.gver)

    def _scatter_back(b_src, cols, n_local):
        """Undo the routing: scatter (n_shards*capacity,) response columns
        back to this device's query lanes (-1 src = padding, dropped)."""
        src = b_src.reshape(-1)
        safe = jnp.clip(src, 0)
        live = src >= 0
        outs = []
        for col, dtype in cols:
            col = col.reshape(-1)
            if dtype is jnp.bool_:
                outs.append(jnp.zeros(n_local, jnp.bool_)
                            .at[safe].max((col > 0) & live))
            else:
                outs.append(jnp.zeros(n_local, dtype)
                            .at[safe].max(jnp.where(live, col, 0)))
        return outs

    def search_inner(st, hi, lo):
        hi, lo = hi[0], lo[0]                     # (q_local,)
        b_hi, b_lo, _, b_src, keep = _local_dispatch(
            hi, lo, jnp.zeros_like(hi), n_shards, capacity)
        req = a2a(jnp.stack([b_hi, b_lo], axis=-1))       # one payload out
        local = _local(st)
        rhi = req[..., 0].reshape(-1)
        rlo = req[..., 1].reshape(-1)
        found, vals = engine.search_batch(cfg, "eh", local, rhi, rlo,
                                          batching=search_batching)
        stale = _stale_lanes(local, hashing.hash1(rhi, rlo),
                             jnp.ones_like(found))
        resp = a2a(jnp.stack([found.astype(U32), vals, stale.astype(U32)],
                             axis=-1).reshape(n_shards, capacity, 3))
        out_f, out_v, out_s = _scatter_back(
            b_src, [(resp[..., 0], jnp.bool_), (resp[..., 1], U32),
                    (resp[..., 2], jnp.bool_)], hi.shape[0])
        return out_f[None], out_v[None], out_s[None], keep[None]

    def snap_search_inner(old_st, new_st, hi, lo):
        """ONE dispatch for the whole optimistic read tick: route once,
        probe the pinned snapshot, verify each routed query against the
        live version planes (buckets_changed inlined per shard), check the
        live recovery generation, and route the packed response back. The
        retry mask never leaves the device as plane bytes — the host pulls
        O(batch) result words only."""
        hi, lo = hi[0], lo[0]
        b_hi, b_lo, _, b_src, keep = _local_dispatch(
            hi, lo, jnp.zeros_like(hi), n_shards, capacity)
        req = a2a(jnp.stack([b_hi, b_lo], axis=-1))
        old_local, new_local = _local(old_st), _local(new_st)
        rhi = req[..., 0].reshape(-1)
        rlo = req[..., 1].reshape(-1)
        found, vals = engine.search_batch(cfg, "eh", old_local, rhi, rlo,
                                          batching=search_batching)
        changed = serving_engine.buckets_changed_local(
            cfg, "eh", old_local, new_local, rhi, rlo)
        stale = _stale_lanes(new_local, hashing.hash1(rhi, rlo),
                             jnp.ones_like(changed))
        resp = a2a(jnp.stack([found.astype(U32), vals, changed.astype(U32),
                              stale.astype(U32)], axis=-1)
                   .reshape(n_shards, capacity, 4))
        out_f, out_v, out_c, out_s = _scatter_back(
            b_src, [(resp[..., 0], jnp.bool_), (resp[..., 1], U32),
                    (resp[..., 2], jnp.bool_), (resp[..., 3], jnp.bool_)],
            hi.shape[0])
        return out_f[None], out_v[None], out_c[None], out_s[None], keep[None]

    def insert_inner(st, hi, lo, v, valid):
        hi, lo, v, valid = hi[0], lo[0], v[0], valid[0]
        # padded lanes (host pads the batch to n_shards*q_local) route to
        # owner -1: the dispatcher never grants them a lane, so padding can
        # never insert the zero key (statuses come back DROPPED, trimmed by
        # the host)
        b_hi, b_lo, b_v, b_src, keep = _local_dispatch(
            hi, lo, v, n_shards, capacity,
            owner_mask=valid)
        valid_lane = (b_src >= 0).astype(U32)
        req = a2a(jnp.stack([b_hi, b_lo, b_v, valid_lane], axis=-1))
        local = _local(st)
        # shard-level parallelism is already this function's dispatch axis;
        # the shard-local sub-batch is small and mostly padding lanes, so the
        # sequential engine is the right inner mode (the segment-parallel
        # engine pays off for large host batches where the host sizes lane
        # capacity from the directory — see DashTable._write_plan)
        local, statuses, _ = engine.insert_batch(
            cfg, "eh", local, req[..., 0].reshape(-1), req[..., 1].reshape(-1),
            req[..., 2].reshape(-1), None, req[..., 3].reshape(-1) > 0,
            batching="scan")
        s_back = a2a(statuses.reshape(n_shards, capacity))
        out = jnp.full(hi.shape[0], -1, I32)
        src = b_src.reshape(-1)
        out = out.at[jnp.clip(src, 0)].max(
            jnp.where(src >= 0, s_back.reshape(-1), -1))
        out = jnp.where(out < 0, layout.DROPPED, out)   # capacity-overflow lanes
        return jax.tree.map(lambda x: x[None], local), out[None], keep[None]

    def insert_round_inner(st, hi, lo, v, pending, out):
        """One insert retry round, statuses resident on device: only the
        pending lanes route (the shrinking retry subset resolves capacity
        overflows, same as the host loop), owners bounce lanes that land on
        an unrecovered segment, and the host syncs a (3,)-flag word per
        shard instead of O(batch) statuses."""
        hi, lo, v = hi[0], lo[0], v[0]
        pending, out = pending[0], out[0]
        b_hi, b_lo, b_v, b_src, _ = _local_dispatch(
            hi, lo, v, n_shards, capacity, owner_mask=pending)
        valid_lane = (b_src >= 0).astype(U32)
        req = a2a(jnp.stack([b_hi, b_lo, b_v, valid_lane], axis=-1))
        local = _local(st)
        rhi = req[..., 0].reshape(-1)
        rlo = req[..., 1].reshape(-1)
        rv = req[..., 2].reshape(-1)
        rvalid = req[..., 3].reshape(-1) > 0
        # a write must NOT land in a crash-dirty segment (the wiped overflow
        # metadata could hide its duplicate in the stash): bounce it DROPPED
        # and flag the shard — the lane stays pending and retries after the
        # host's per-access recovery
        lane_stale = _stale_lanes(local, hashing.hash1(rhi, rlo), rvalid)
        local, statuses, _ = engine.insert_batch(
            cfg, "eh", local, rhi, rlo, rv, None, rvalid & ~lane_stale,
            batching="scan")
        statuses = jnp.where(lane_stale, I32(layout.DROPPED), statuses)
        s_back = a2a(statuses.reshape(n_shards, capacity))
        res = jnp.full(hi.shape[0], -1, I32)
        src = b_src.reshape(-1)
        res = res.at[jnp.clip(src, 0)].max(
            jnp.where(src >= 0, s_back.reshape(-1), -1))
        res = jnp.where(res < 0, layout.DROPPED, res)
        out = jnp.where(pending, res, out)
        need = pending & (out == layout.NEED_SPLIT)
        pending = need | (pending & (out == layout.DROPPED))
        flags = jnp.stack([jnp.any(pending).astype(I32),
                           jnp.any(need).astype(I32),
                           jnp.any(lane_stale).astype(I32)])
        return (jax.tree.map(lambda x: x[None], local), out[None],
                pending[None], need[None], flags[None])

    def split_inner(st, hi, lo, want):
        """Shard-local bulk SMOs in one dispatch: route the pressured keys
        to their owners, plan the distinct segments to split on device
        (``smo.plan_local_splits``), and run phase1+phase2 on the local
        sub-state (``smo.split_segments_local``). A resource-exhausted
        shard commits NOTHING and raises through its flag word — same
        semantics as the host loop's raise-before-mutate."""
        hi, lo, want = hi[0], lo[0], want[0]
        b_hi, b_lo, _, b_src, _ = _local_dispatch(
            hi, lo, jnp.zeros_like(hi), n_shards, capacity, owner_mask=want)
        valid_lane = (b_src >= 0).astype(U32)
        req = a2a(jnp.stack([b_hi, b_lo, valid_lane], axis=-1))
        local = _local(st)
        rhi = req[..., 0].reshape(-1)
        rlo = req[..., 1].reshape(-1)
        rwant = req[..., 2].reshape(-1) > 0
        old, new, valid, depth_bad, pool_bad = smo.plan_local_splits(
            cfg, local, hashing.hash1(rhi, rlo), rwant, split_lanes)
        stuck = depth_bad | pool_bad
        commit = valid & ~stuck
        local, ok = smo.split_segments_local(cfg, local, old, new, commit)
        flags = jnp.stack([depth_bad.astype(I32), pool_bad.astype(I32),
                           jnp.any(commit & ~ok).astype(I32)])
        return jax.tree.map(lambda x: x[None], local), flags[None]

    def _wrap(fn, in_specs, out_specs, donate=()):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False),
                       donate_argnums=donate)

    q = q_spec
    return dict(
        n_shards=n_shards, capacity=capacity,
        search_fn=_wrap(search_inner, (st_spec, q, q), (q, q, q, q)),
        snap_search_fn=_wrap(snap_search_inner, (st_spec, st_spec, q, q),
                             (q, q, q, q, q)),
        insert_fn=_wrap(insert_inner, (st_spec, q, q, q, q),
                        (st_spec, q, q), donate=(0,)),
        insert_round_fn=_wrap(insert_round_inner, (st_spec, q, q, q, q, q),
                              (st_spec, q, q, q, q), donate=(0,)),
        split_fn=_wrap(split_inner, (st_spec, q, q, q), (st_spec, q),
                       donate=(0,)),
    )


def build_dht_ops(cfg: DashConfig, mesh: Mesh, axes=("data",),
                  capacity: int | None = None, q_local_hint: int = 1024,
                  search_batching: str = "vmap"):
    """Back-compat surface: jitted (search_fn, insert_fn, n_shards) over a
    device-sharded table (see ``build_dht_programs`` for the full set)."""
    progs = build_dht_programs(cfg, mesh, axes, capacity, q_local_hint,
                               search_batching)
    return progs["search_fn"], progs["insert_fn"], progs["n_shards"]


class DistributedDash:
    """Host wrapper: device-sharded Dash with shard-local SMO handling.

    ``state`` lets a caller restore a previously persisted sharded state
    (``persist.reopen_shards`` stacks one host pytree from the per-shard
    pools); ``attach_pools`` binds one durable pool per shard — flushed
    independently, so a dirty shard restart recovers shard-locally and
    never touches its neighbors' pools.

    A restored state may be crash-dirty: construction detects lagging
    shards from the SMALL planes only (seg_version / watermark / gver — a
    few KB), and every access lazily recovers exactly the segments it
    touches (``ensure_recovered``), with the shard_map programs' stale
    mask as the in-dispatch audit. ``lazy_recovery=False`` keeps the
    detection but expects the caller to recover eagerly."""

    def __init__(self, cfg: DashConfig, mesh: Mesh, axes=("data",),
                 capacity: int | None = None, q_local_hint: int = 1024,
                 search_batching: str = "vmap", state: DashState | None = None,
                 lazy_recovery: bool = True, split_lanes: int = 8):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = tuple(axes)
        progs = build_dht_programs(cfg, mesh, self.axes, capacity,
                                   q_local_hint, search_batching, split_lanes)
        self.n_shards = progs["n_shards"]
        self.search_fn = progs["search_fn"]
        self.snap_search_fn = progs["snap_search_fn"]
        self.insert_fn = progs["insert_fn"]
        self.insert_round_fn = progs["insert_round_fn"]
        self.split_fn = progs["split_fn"]
        self._device_smo = smo.rebuild_eligible(cfg)
        sh = NamedSharding(mesh, P(self.axes))
        restored = state is not None
        if state is None:
            state = make_sharded_state(cfg, self.n_shards)
        else:
            assert state.version.shape[0] == self.n_shards, \
                "restored state shard count != mesh shard count"
        self.state = jax.device_put(state, sh)
        self.writebacks = None        # per-shard durable pools (persist/)
        self.lazy_recovery = lazy_recovery
        self.recovered_segments = 0
        self._dirty_shards: set = (
            self._detect_dirty_shards() if restored else set())

    # -- durable pools ------------------------------------------------------

    def attach_pools(self, writebacks):
        """Bind one durable pool per shard and mark the serving period
        dirty (the clean markers go durable only via ``close_pools``).
        Fresh pools get the current state flushed immediately, so a crash
        before the first ``flush_pools`` reopens to a valid table instead
        of an all-zeros plane region (mirrors ``persist.create``)."""
        assert len(writebacks) == self.n_shards
        self.writebacks = list(writebacks)
        self.state = self.state._replace(
            clean=jnp.zeros_like(self.state.clean))
        if any(wb.pool.sb.flush_seq == 0 for wb in self.writebacks):
            self.flush_pools()

    def flush_pools(self) -> int:
        """Flush every shard into its own pool (O(dirty) per shard: each
        shard's version-plane diff runs against its own pool mirror).
        Fault isolation: a shard whose pool degrades (I/O retry budget
        exhausted) is skipped — the OTHER shards still flush — and the
        degraded shard keeps serving from device state until
        ``recover_pools`` brings its pool back."""
        from repro import persist
        assert self.writebacks is not None, "no pools attached"
        return persist.flush_shards(self.state, self.writebacks)

    def recover_pools(self) -> int:
        """Probe every degraded shard pool and force-resync the ones that
        answer (``persist.recover_shards``). Returns shards recovered."""
        from repro import persist
        assert self.writebacks is not None, "no pools attached"
        return persist.recover_shards(self.state, self.writebacks)

    def degraded_shards(self) -> list:
        """Indices of shards whose pools are currently degraded."""
        if self.writebacks is None:
            return []
        return [i for i, wb in enumerate(self.writebacks) if wb.degraded]

    def close_pools(self):
        """Durable clean shutdown of every shard pool."""
        assert self.writebacks is not None, "no pools attached"
        self.state = self.state._replace(
            clean=jnp.ones_like(self.state.clean))
        self.flush_pools()
        for wb in self.writebacks:
            wb.pool.close()

    # -- lazy crash recovery ------------------------------------------------

    def _detect_dirty_shards(self) -> set:
        """Shards whose recovery generation lags — a host scan of the SMALL
        planes only (seg_version (S,), watermark, gver per shard; never the
        record planes). Runs once at restore; afterwards the set shrinks as
        accesses recover and the device stale mask audits it."""
        sv = np.asarray(self.state.seg_version)
        wm = np.asarray(self.state.watermark)
        gv = np.asarray(self.state.gver)
        return {i for i in range(self.n_shards)
                if (sv[i, :int(wm[i])] != gv[i]).any()}

    def ensure_recovered(self, keys=None) -> int:
        """Per-access lazy recovery (the host half of the device hook): for
        the dirty shards the keys route to, recover exactly the touched
        segments through the shared SMO-continuation orchestration
        (``core/recovery.lazy_recover_touched``) and re-stack the shard.
        ``keys=None`` recovers every dirty shard fully. Returns segments
        recovered."""
        if not self._dirty_shards:
            return 0
        if keys is None:
            owners, h1 = None, None
            shards = sorted(self._dirty_shards)
        else:
            keys = np.asarray(keys, np.uint64)
            khi, klo = hashing.np_split_keys(keys)
            h1 = hashing.np_hash1(khi, klo)
            owners = (h1 >> np.uint32(32 - int(np.log2(self.n_shards)))
                      ).astype(np.int64)
            shards = sorted(set(np.unique(owners).tolist())
                            & self._dirty_shards)
        total = 0
        for shard in shards:
            sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[shard]),
                               self.state)
            if owners is None:
                touched = np.arange(int(np.asarray(sub.watermark)))
            else:
                touched = np.asarray(sub.dir)[
                    h1[owners == shard]
                    >> np.uint32(32 - self.cfg.dir_depth_max)]
            sub, recovered = recovery.lazy_recover_touched(
                self.cfg, "eh", sub, touched)
            if recovered:
                self.state = jax.tree.map(
                    lambda full, s: full.at[shard].set(s), self.state, sub)
                total += len(recovered)
                self.recovered_segments += len(recovered)
            sv = np.asarray(sub.seg_version)
            wm = int(np.asarray(sub.watermark))
            if not (sv[:wm] != np.asarray(sub.gver)).any():
                self._dirty_shards.discard(shard)
        return total

    # -- batch API ----------------------------------------------------------

    def _shape_queries(self, keys):
        keys = np.asarray(keys, np.uint64)
        q_local = -(-keys.size // self.n_shards)
        pad = q_local * self.n_shards - keys.size
        keys_p = np.concatenate([keys, np.zeros(pad, np.uint64)])
        hi, lo = hashing.np_split_keys(keys_p)
        shape = (self.n_shards, q_local)
        return (jnp.asarray(hi).reshape(shape), jnp.asarray(lo).reshape(shape),
                keys.size, pad)

    def insert_once(self, keys, vals):
        """ONE sharded insert dispatch — no SMOs, no retries. Returns the
        per-key statuses; NEED_SPLIT/DROPPED lanes are the caller's to
        retry. This is the HOST-SYNC reference round (O(batch) statuses
        pulled per call) — the device-resident loop (``insert``) keeps
        statuses on device and syncs a flags word instead."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32)
        hi, lo, n, pad = self._shape_queries(keys)
        v = jnp.asarray(np.concatenate(
            [vals, np.zeros(pad, np.uint32)])).reshape(hi.shape)
        valid = jnp.asarray(np.arange(n + pad) < n).reshape(hi.shape)
        self.state, statuses, keep = self.insert_fn(self.state, hi, lo, v,
                                                    valid)
        return np.asarray(statuses).reshape(-1)[:n]

    def insert(self, keys, vals, max_rounds: int = 8):
        """Batch insert with shard-local SMO retries, statuses resident on
        device across rounds: each round syncs only the (n_shards, 3) flag
        word (any-retry / any-need-split / any-stale); the per-key statuses
        are pulled ONCE when the batch completes. Statuses are aligned with
        the *input* batch; capacity-DROPPED lanes retry too (the smaller
        retry subset routes without overflow)."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32)
        if self.lazy_recovery and self._dirty_shards:
            self.ensure_recovered(keys)
        hi, lo, n, pad = self._shape_queries(keys)
        v = jnp.asarray(np.concatenate(
            [vals, np.zeros(pad, np.uint32)])).reshape(hi.shape)
        pending = jnp.asarray(np.arange(n + pad) < n).reshape(hi.shape)
        out = jnp.full(hi.shape, layout.DROPPED, I32)
        for _ in range(max_rounds):
            self.state, out, pending, need, flags = self.insert_round_fn(
                self.state, hi, lo, v, pending, out)
            fl = np.asarray(flags)    # (n_shards, 3): the per-round sync
            if fl[:, 2].any():
                # owner saw a crash-dirty segment: recover it, lane retries
                self._dirty_shards |= self._detect_dirty_shards()
                self.ensure_recovered(keys)
            if fl[:, 1].any():
                self._dispatch_splits(hi, lo, need, keys)
            if not fl[:, 0].any():
                return np.asarray(out).reshape(-1)[:n]
        raise RuntimeError("dht insert retry budget exhausted")

    # -- shard-local SMOs ----------------------------------------------------

    def _check_split_flags(self, fl: np.ndarray):
        if fl[:, 0].any():
            raise RuntimeError("shard directory depth exhausted")
        if fl[:, 1].any():
            raise RuntimeError("shard segment pool exhausted")
        if fl[:, 2].any():
            self._repair_splits()

    def _dispatch_splits(self, hi, lo, want, keys):
        """Device bulk splits for the wanted lanes; ablation configs the
        one-pass rebuild doesn't cover take the retained host loop (the
        want mask is pulled once — O(batch) bools — only on that path)."""
        if not self._device_smo:
            need_np = np.asarray(want).reshape(-1)[:keys.size] > 0
            return self._split_for_host(keys[need_np])
        self.state, sflags = self.split_fn(self.state, hi, lo, want)
        self._check_split_flags(np.asarray(sflags))

    def split_for(self, keys):
        """Shard-local splits on the owners of failed keys. All pressured
        segments of every pressured shard split in ONE device dispatch:
        planning (directory dedupe + id assignment) and both split phases
        run inside the shard program — no host sub-state rebuild."""
        keys = np.asarray(keys, np.uint64)
        if not self._device_smo:
            return self._split_for_host(keys)
        hi, lo, n, pad = self._shape_queries(keys)
        want = jnp.asarray(np.arange(n + pad) < n).reshape(hi.shape)
        self.state, sflags = self.split_fn(self.state, hi, lo, want)
        self._check_split_flags(np.asarray(sflags))

    _split_for = split_for            # back-compat alias

    def _split_for_host(self, keys):
        """Retained host-driven split loop (differential reference + bench
        baseline + ablation fallback): rebuilds each pressured shard's
        sub-state through host copies and bulk-splits it."""
        from repro.core import dash_eh
        keys = np.asarray(keys, np.uint64)
        owners = np_owner_of(keys, self.n_shards)
        hi, lo = hashing.np_split_keys(keys)
        h1 = hashing.np_hash1(hi, lo)
        for shard in np.unique(owners):
            sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[shard]),
                               self.state)
            mask = owners == shard
            segs = np.unique(np.asarray(sub.dir)[
                h1[mask] >> np.uint32(32 - self.cfg.dir_depth_max)])
            depths = np.asarray(sub.local_depth)
            if (depths[segs] >= self.cfg.dir_depth_max).any():
                raise RuntimeError("shard directory depth exhausted")
            wm = int(np.asarray(sub.watermark))
            if wm + segs.size > self.cfg.max_segments:
                raise RuntimeError("shard segment pool exhausted")
            if self._device_smo:
                sub, _ = smo.bulk_split(self.cfg, sub, segs,
                                        wm + np.arange(segs.size))
            else:
                for seg in segs:
                    sub, ok = dash_eh.split_segment(self.cfg, sub, int(seg))
                    assert bool(ok)
            self.state = jax.tree.map(
                lambda full, s: full.at[shard].set(s), self.state, sub)

    def _repair_splits(self):
        """Scan-rehash fallback for shards whose one-pass rebuild could not
        fit a segment (rare pathological packings): finish each in-flight
        split exactly as BulkSplitTask's commit stage does — the source is
        still SPLITTING with its SEG_NEW neighbor side-linked."""
        from repro.core import dash_eh
        ss = np.asarray(self.state.seg_state)
        side = np.asarray(self.state.side_link)
        for shard in range(self.n_shards):
            srcs = np.nonzero(ss[shard] == layout.SEG_SPLITTING)[0]
            if not srcs.size:
                continue
            sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[shard]),
                               self.state)
            for seg in srcs:
                nbr = int(side[shard, seg])
                assert nbr >= 0 and ss[shard, nbr] == layout.SEG_NEW, \
                    "un-repairable split leftover"
                sub, fit = dash_eh.split_phase2_scan(
                    self.cfg, sub, jnp.asarray(int(seg), I32),
                    jnp.asarray(nbr, I32), False)
                if not bool(fit):
                    raise AssertionError(
                        "split rehash failed to refit records")
            self.state = jax.tree.map(
                lambda full, s: full.at[shard].set(s), self.state, sub)

    # -- reads ---------------------------------------------------------------

    def search_on(self, state, keys):
        """Search against a caller-supplied sharded state (e.g. an
        epoch-pinned snapshot); ``search`` is the live-state shorthand.
        The shard_map'd probe takes any state of the right shapes and
        never donates it, so snapshots survive the call."""
        hi, lo, n, _ = self._shape_queries(keys)
        f, v, _stale, _keep = self.search_fn(state, hi, lo)
        return (np.asarray(f).reshape(-1)[:n], np.asarray(v).reshape(-1)[:n])

    def search(self, keys):
        """Live-state search with the per-access recovery hook closed on
        host: dirty shards the keys route to are recovered BEFORE the
        dispatch; the in-program stale mask is the audit (it re-probes iff
        something re-dirtied behind the host's back)."""
        if self.lazy_recovery and self._dirty_shards:
            self.ensure_recovered(keys)
        hi, lo, n, _ = self._shape_queries(keys)
        f, v, stale, _ = self.search_fn(self.state, hi, lo)
        if bool(np.asarray(stale).reshape(-1)[:n].any()):
            self._dirty_shards |= self._detect_dirty_shards()
            self.ensure_recovered(keys)
            f, v, stale, _ = self.search_fn(self.state, hi, lo)
        return (np.asarray(f).reshape(-1)[:n], np.asarray(v).reshape(-1)[:n])

    def snap_search_on(self, snap_state, keys):
        """One-dispatch snapshot probe + in-program verify + recovery
        audit: (found, vals, changed, stale) host bool/word arrays —
        O(batch) result words, zero plane bytes."""
        hi, lo, n, _ = self._shape_queries(keys)
        f, v, c, s, _ = self.snap_search_fn(snap_state, self.state, hi, lo)
        cut = lambda x: np.asarray(x).reshape(-1)[:n]
        return cut(f), cut(v), cut(c), cut(s)

    @property
    def n_items(self) -> int:
        return int(np.sum(np.asarray(self.state.n_items)))


class ShardFrontend(frontend.FrontendBase):
    """The online-resize frontend (serving/frontend.py) adopted for the
    device-sharded table: epoch-guarded snapshot reads + deferred shard
    SMOs over ``DistributedDash``. Admission lanes, batch forming, the
    read-priority scheduler, and latency/retry accounting come from the
    shared ``FrontendBase``.

    ``verify_mode`` selects the read-tick machinery:

    * ``"device"`` (default) — ONE shard_map dispatch per read batch:
      snapshot probe, version-plane verify, and the lazy-recovery check all
      run inside the program (``snap_search_fn``); only O(batch) result
      words reach the host, never a plane. Write ticks run the
      device-resident retry round (statuses stay on device, flags-word
      sync) with shard-local bulk splits deferred to their own ticks.
    * ``"host"`` — the retained host-mirror baseline: probe dispatch, then
      a host copy of the dir/version planes diffed per query
      (``_changed_mask``, the host mirror of ``serving.engine.
      buckets_changed`` — the differential test keeps the two in
      lockstep), then a retry dispatch; insert rounds pull O(batch)
      statuses per round (``insert_once``). Every plane pull is metered
      into the ``frontend.host_plane_bytes`` counter — the device path
      never increments it, which is the bench's zero-copy gate.

    Insert + read lanes (the DHT serving surface); updates/deletes stay on
    the table API. Reads also attribute their sojourn to the owner shard's
    registry (``shard_registries`` / ``Registry.aggregate`` fleet view).
    """

    def __init__(self, dht: DistributedDash, *, max_batch: int = 256,
                 queue_depth: int = 4096, obs=None,
                 verify_mode: str = "device"):
        from repro.obs import Registry
        assert verify_mode in ("device", "host")
        super().__init__(max_batch=max_batch, queue_depth=queue_depth,
                         obs=obs)
        self.dht = dht
        self.verify_mode = verify_mode
        self._dirty = True
        # host-plane-transfer meter: every byte of dir/version plane the
        # verify path copies to host (the device path transfers none)
        self._host_plane_bytes = self.obs.registry.scope(
            "frontend").counter("host_plane_bytes")
        # per-shard registries: read-sojourn histograms recorded by owner
        # (host-visible routing), wb counters mirrored in on export
        self._shard_regs = [Registry() for _ in range(dht.n_shards)]
        self._shard_read_hists = [
            r.scope("shard").histogram("read_sojourn_s")
            for r in self._shard_regs]
        # per-shard degraded transitions (satellite of the quarantine/
        # transition surfacing): counts every shard that ENTERS degraded,
        # not just the frontend-level health flip
        self.shard_degraded_transitions = 0
        self._degraded_prev: set = set()
        self._publish()
        self._pending = None          # in-flight insert batch host state
        self._split_keys = None       # host mode: keys owing a bulk split
        self._split_want = None       # device mode: want mask owing splits

    def _publish(self):
        """Per-shard copy-on-write publish: the sharded state's planes have
        a (n_shards, S, ...) leading shape, and the same version-plane diff
        drives the O(dirty) scatter — an insert burst republises only the
        bucket rows its owners wrote, a shard split storm only the rebuilt
        segments (plus each shard's directory when it changed). With pools
        attached, every publish also flushes each shard into its own pool
        (flush-on-publish: acknowledged DHT ops are durable)."""
        tr = self.obs.tracer
        with tr.span("publish", "epoch") as psp:
            self.registry.publish_cow(self.dht.cfg, self.dht.state)
            self._publishes.inc()
            self._publish_bytes.inc(self.registry.last_publish_bytes)
            if self.dht.writebacks is not None:
                for wb in self.dht.writebacks:
                    if wb.obs is None:
                        # per-shard flush spans nest under this publish
                        wb.attach_obs(self.obs)
                before = sum(w.flushed_bytes for w in self.dht.writebacks)
                self.dht.flush_pools()
                self._flush_bytes.inc(
                    sum(w.flushed_bytes for w in self.dht.writebacks)
                    - before)
                degraded = set(self.dht.degraded_shards())
                self.shard_degraded_transitions += len(
                    degraded - self._degraded_prev)
                self._degraded_prev = degraded
                if degraded:
                    if self.health == frontend.HEALTHY:
                        self._set_health(frontend.DEGRADED)
                    self.unflushed_publishes += 1
                elif self.health == frontend.DEGRADED:
                    self._set_health(frontend.HEALTHY)
            if psp is not None:
                psp.args["bytes"] = self.registry.last_publish_bytes
        self._dirty = False

    def submit(self, op) -> bool:
        """Reject kinds outside the DHT serving surface at admission time
        (an admitted op must never strand mid-drain)."""
        if op.kind not in (frontend.READ, frontend.INSERT):
            self.writes.rejected += 1
            return False
        return super().submit(op)

    def stats(self) -> dict:
        out = super().stats()
        out["shard_degraded_transitions"] = self.shard_degraded_transitions
        out["host_plane_bytes"] = self._host_plane_bytes.value
        out["recovered_segments"] = self.dht.recovered_segments
        if self.dht.writebacks is not None:
            out["flushes"] = sum(w.flushes for w in self.dht.writebacks)
            out["flushed_bytes"] = sum(w.flushed_bytes
                                       for w in self.dht.writebacks)
            out["pool_bytes"] = sum(w.pool.plane_bytes
                                    for w in self.dht.writebacks)
            degraded = self.dht.degraded_shards()
            out["shards_degraded"] = degraded
            out["health"] = (frontend.DEGRADED if degraded
                             else frontend.HEALTHY)
            out["flush_io_errors"] = sum(w.flush_io_errors
                                         for w in self.dht.writebacks)
            out["degraded_flushes"] = sum(w.degraded_flushes
                                          for w in self.dht.writebacks)
            # durable quarantine evidence, fleet-wide (satellite: chaos
            # runs assert on the aggregate without reaching into pools)
            out["lost_records"] = sum(w.pool.sb.lost_records
                                      for w in self.dht.writebacks)
            out["quarantined_bt"] = sum(len(w.pool.sb.lost_bt)
                                        for w in self.dht.writebacks)
            out["quarantined_nb"] = sum(len(w.pool.sb.lost_nb)
                                        for w in self.dht.writebacks)
        return out

    def shard_registries(self) -> list:
        """One ``Registry`` per shard — the persistent per-shard
        read-sojourn histograms plus (with pools attached) the writeback's
        cumulative counters mirrored in — so ``Registry.aggregate`` sums a
        fleet view, histograms included."""
        if self.dht.writebacks is not None:
            for r, wb in zip(self._shard_regs, self.dht.writebacks):
                r.ingest(wb.stats(), prefix="wb.", counters=True)
        return list(self._shard_regs)

    def obs_snapshot(self) -> dict:
        from repro.obs import Registry
        self.obs.registry.ingest(self.stats(), prefix="stats.")
        out = self.obs.snapshot()
        regs = self.shard_registries()
        if regs:
            out["shards"] = Registry.aggregate(regs).snapshot()
            out["per_shard"] = [r.snapshot() for r in regs]
        return out

    def try_recover(self) -> bool:
        """Re-probe degraded shard pools; True when every shard is back
        HEALTHY. Healthy shards were never interrupted — recovery is
        strictly per-shard (fault isolation)."""
        if self.dht.writebacks is None:
            return True
        if self.dht.degraded_shards():
            self.dht.recover_pools()
        ok = not self.dht.degraded_shards()
        if ok:
            self._degraded_prev = set()
            self._set_health(frontend.HEALTHY)
        return ok

    def _write_pending(self) -> bool:
        return (self._pending is not None or self._split_keys is not None
                or self._split_want is not None)

    def _finish_reads(self, ops, found, vals, n_changed: int):
        super()._finish_reads(ops, found, vals, n_changed)
        # attribute each read's sojourn to its owner shard (pure host
        # hashing — no device traffic) for the per-shard fleet view
        keys = np.asarray([op.key for op in ops], np.uint64)
        owner = np_owner_of(keys, self.dht.n_shards)
        lats = np.asarray([op.latency for op in ops], np.float64)
        for shard in np.unique(owner):
            self._shard_read_hists[int(shard)].observe_many(
                lats[owner == shard])

    # -- read path -----------------------------------------------------------

    def _changed_mask(self, snap_state, keys) -> np.ndarray:
        """HOST-MIRROR verify (the ``verify_mode="host"`` baseline and the
        differential reference for the device mask): a host copy of the
        owner shards' dir + version planes, diffed per query — the same
        contract as serving.engine.buckets_changed (a contract change
        there MUST land here too; the shard consistency test guards it).
        Every plane byte copied is metered into ``host_plane_bytes``."""
        cfg = self.dht.cfg
        keys = np.asarray(keys, np.uint64)
        hi, lo = hashing.np_split_keys(keys)
        h1 = hashing.np_hash1(hi, lo)
        owner = np_owner_of(keys, self.dht.n_shards)
        d = (h1 >> np.uint32(32 - cfg.dir_depth_max)).astype(np.int64)
        old_dir, new_dir = np.asarray(snap_state.dir), np.asarray(
            self.dht.state.dir)
        seg = old_dir[owner, d].astype(np.int64)
        changed = seg != new_dir[owner, d]
        oldv = np.asarray(snap_state.version)
        newv = np.asarray(self.dht.state.version)
        self._host_plane_bytes.inc(old_dir.nbytes + new_dir.nbytes
                                   + oldv.nbytes + newv.nbytes)
        NB = cfg.num_buckets
        b = (h1 & np.uint32(NB - 1)).astype(np.int64)
        for w in range(cfg.probe_window):
            bw = (b + w) & (NB - 1)
            changed |= oldv[owner, seg, bw] != newv[owner, seg, bw]
        for s in range(cfg.num_stash):
            changed |= oldv[owner, seg, NB + s] != newv[owner, seg, NB + s]
        return changed

    def _serve_reads(self, ops):
        keys = np.asarray([op.key for op in ops], np.uint64)
        if self.dht.lazy_recovery and self.dht._dirty_shards:
            # per-access recovery BEFORE pinning: recovered segments bump
            # their version words, so the verify pass below redirects any
            # query that probes them to the (recovered) live state
            if self.dht.ensure_recovered(keys):
                self._dirty = True
        if self.verify_mode == "host":
            with self.registry.acquire() as snap:
                found, vals = self.dht.search_on(snap.state, keys)
                n_changed = 0
                if self._dirty:
                    changed = self._changed_mask(snap.state, keys)
                    n_changed = int(changed.sum())
                if n_changed:
                    f2, v2 = self.dht.search(keys)
                    found = np.where(changed, f2, found)
                    vals = np.where(changed, v2, vals)
            self._finish_reads(ops, found, vals, n_changed)
            return
        # device path: ONE dispatch probes the snapshot, verifies it
        # against the live planes, and checks the recovery generation —
        # the masks come back as O(batch) bools, never as plane bytes
        with self.registry.acquire() as snap:
            found, vals, changed, stale = self.dht.snap_search_on(
                snap.state, keys)
            changed = changed | stale
            n_changed = int(changed.sum())
            if n_changed:
                f2, v2 = self.dht.search(keys)
                found = np.where(changed, f2, found)
                vals = np.where(changed, v2, vals)
        self._finish_reads(ops, found, vals, n_changed)

    # -- write path ----------------------------------------------------------

    def _pump_write(self) -> bool:
        if self.verify_mode == "host":
            return self._pump_write_host()
        if self._split_want is not None and self._pending is not None:
            # the deferred storm: every pressured owner splits all its
            # pressured segments in one bulk dispatch (device-planned)
            ops, keys, vals, hi, lo, v, pend, out, rounds = self._pending
            self.dht._dispatch_splits(hi, lo, self._split_want, keys)
            self._split_want = None
            self._dirty = True
            self._publish()
            return True
        if self._pending is not None:
            ops, keys, vals, hi, lo, v, pend, out, rounds = self._pending
            if rounds > 32:
                raise RuntimeError("dht insert retry budget exhausted")
            self.dht.state, out, pend, need, flags = self.dht.insert_round_fn(
                self.dht.state, hi, lo, v, pend, out)
            self._dirty = True
            fl = np.asarray(flags)
            if fl[:, 2].any():
                self.dht._dirty_shards |= self.dht._detect_dirty_shards()
                self.dht.ensure_recovered(keys)
            if fl[:, 1].any():
                self._split_want = need
            if not fl[:, 0].any():
                self._finish_writes(ops,
                                    np.asarray(out).reshape(-1)[:keys.size])
                self._pending = None
                self._split_want = None
                self._publish()
            else:
                self._pending = (ops, keys, vals, hi, lo, v, pend, out,
                                 rounds + 1)
            return True
        ops = self.former.form(self.writes)
        if not ops:
            return False
        assert ops[0].kind == frontend.INSERT, \
            "shard frontend lanes cover read + insert"
        keys = np.asarray([op.key for op in ops], np.uint64)
        vals = np.asarray([op.value for op in ops], np.uint32)
        if self.dht.lazy_recovery and self.dht._dirty_shards:
            if self.dht.ensure_recovered(keys):
                self._dirty = True
        hi, lo, n, pad = self.dht._shape_queries(keys)
        v = jnp.asarray(np.concatenate(
            [vals, np.zeros(pad, np.uint32)])).reshape(hi.shape)
        pend = jnp.asarray(np.arange(n + pad) < n).reshape(hi.shape)
        out = jnp.full(hi.shape, layout.DROPPED, I32)
        self._pending = (ops, keys, vals, hi, lo, v, pend, out, 0)
        return self._pump_write()

    def _pump_write_host(self) -> bool:
        """Retained host-sync write tick (``verify_mode="host"``): one
        ``insert_once`` per round with O(batch) statuses pulled to host,
        and pressured shards split through the host sub-state loop — the
        full pre-device-resident baseline the bench gates against. (The
        split PLAN is identical to the device path's, so the two modes
        still land bit-identical states.)"""
        if self._split_keys is not None:
            self.dht._split_for_host(self._split_keys)
            self._split_keys = None
            self._dirty = True
            self._publish()
            return True
        if self._pending is not None:
            keys, vals, out, pending, ops, rounds = self._pending
            if rounds > 32:
                raise RuntimeError("dht insert retry budget exhausted")
            statuses = self.dht.insert_once(keys[pending], vals[pending])
            self._dirty = True
            out[pending] = statuses
            need = statuses == layout.NEED_SPLIT
            retry = need | (statuses == layout.DROPPED)
            if not retry.any():
                self._finish_writes(ops, out)
                self._pending = None
                self._publish()
            else:
                if need.any():
                    self._split_keys = keys[pending[need]]
                self._pending = (keys, vals, out, pending[retry], ops,
                                 rounds + 1)
            return True
        ops = self.former.form(self.writes)
        if not ops:
            return False
        assert ops[0].kind == frontend.INSERT, \
            "shard frontend lanes cover read + insert"
        keys = np.asarray([op.key for op in ops], np.uint64)
        vals = np.asarray([op.value for op in ops], np.uint32)
        if self.dht.lazy_recovery and self.dht._dirty_shards:
            if self.dht.ensure_recovered(keys):
                self._dirty = True
        self._pending = (keys, vals,
                         np.full(keys.size, layout.DROPPED, np.int32),
                         np.arange(keys.size), ops, 0)
        return self._pump_write_host()
