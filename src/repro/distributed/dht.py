"""Distributed Dash: the paper's "scalable hashing" scaled out to a TPU pod.

Every device owns an independent Dash-EH table (a shard). The top
log2(n_shards) bits of the addressing hash pick the owner — the distributed
extension of the MSB directory. Query batches start *sharded over devices*;
each device routes its local queries to owners with a fixed-capacity
``all_to_all`` (MoE-style dispatch), owners probe shard-locally (the Pallas
fingerprint path applies verbatim — shards are ordinary Dash tables), and a
second ``all_to_all`` routes results back.

Scalability argument mirrors the paper's: probes are bandwidth-bound and
shards touch disjoint memory; the only cross-chip cost is ~24 bytes/query
each way vs. the ~256-byte bucket traffic it replaces, so the fabric term
stays well under the local-HBM term (benchmarks/dht_roofline.py derives both
from the dry-run artifact).

SMOs stay shard-local: a segment split never moves keys across shards (the
owner bits are disjoint from the shard-local directory bits), so there is no
cross-shard coordination — this is what makes the design elastic: growing
from 1 to 2 pods adds one owner bit and moves only metadata.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import DashConfig, engine, hashing, layout
from repro.core.layout import DashState
from repro.kernels import ops as kops
from repro.serving import frontend

I32 = jnp.int32
U32 = jnp.uint32


def make_sharded_state(cfg: DashConfig, n_shards: int) -> DashState:
    one = layout.make_state(cfg, "eh")
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_shards,) + x.shape).copy(), one)


def make_abstract(cfg: DashConfig, n_shards: int):
    one = jax.eval_shape(lambda: layout.make_state(cfg, "eh"))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_shards,) + x.shape, x.dtype), one)


def owner_of(keys_hi, keys_lo, n_shards: int):
    """Owner shard from the TOP bits of h1 — the distributed MSB directory.
    Shard-local directories consume the next dir_depth_max bits, so probing
    inside the owner uses the unchanged 32-bit hash."""
    h1 = hashing.hash1(keys_hi, keys_lo)
    return (h1 >> U32(32 - int(np.log2(n_shards)))).astype(I32)


def _local_dispatch(hi, lo, v, n_shards: int, capacity: int,
                    owner_mask=None):
    """Route this device's queries into (n_shards, capacity) buffers via the
    shared MoE-style dispatcher (kernels/ops.py) — the same sort-based
    router the engine uses to group by segment, here grouping by owner
    shard. ``owner_mask=False`` lanes route to owner -1 (dropped). Returns
    buffers + src map (-1 = empty lane) + kept mask."""
    owner = owner_of(hi, lo, n_shards)
    if owner_mask is not None:
        owner = jnp.where(owner_mask, owner, -1)
    (b_hi, b_lo, b_v), b_src, keep = kops.route_lanes(
        owner, (hi, lo, v), n_shards, capacity, (0, 0, 0))
    return b_hi, b_lo, b_v, b_src, keep


def auto_capacity(q_local: int, n_shards: int, slack: float = 4.0) -> int:
    """Routing lanes per (src, dst): expected q_local/n_shards with slack.
    Oversized lanes are pure wasted wire — right-sizing them was a 16x
    fabric-bytes win at 256 chips (EXPERIMENTS.md SSPerf, DHT cell)."""
    want = int(np.ceil(q_local / n_shards * slack))
    return max(8, 1 << int(np.ceil(np.log2(want))))


def build_dht_ops(cfg: DashConfig, mesh: Mesh, axes=("data",),
                  capacity: int | None = None, q_local_hint: int = 1024,
                  search_batching: str = "vmap"):
    """jitted (search_fn, insert_fn) over a device-sharded table.

    Inputs: keys reshaped (n_shards, q_local), sharded on dim 0.
    Payloads are PACKED into one (n_shards, cap, W) word tensor so each
    direction is a single all_to_all (one launch on the ICI, not four).

    ``search_batching`` selects the shard-local read path; shards are
    ordinary Dash tables, so the Pallas fingerprint path applies verbatim
    (pass "pallas"/"auto" on TPU) and so does the fused single-dispatch
    probe (pass "fused" — the natural fit for the small shard-local
    sub-batch, and its direct gather is indifferent to the all_to_all
    padding lanes piling onto key 0's segment). The CPU default stays on
    the per-key path: interpret-mode MXU gathers lose on emulated
    devices, and routed paths would re-bucket the padding lanes."""
    axes = tuple(axes)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if capacity is None:
        capacity = auto_capacity(q_local_hint, n_shards)
    st_spec = jax.tree.map(lambda _: P(axes), make_abstract(cfg, n_shards))
    q_spec = P(axes)
    a2a = lambda x: jax.lax.all_to_all(x, axes, 0, 0, tiled=True)

    def search_inner(st, hi, lo):
        hi, lo = hi[0], lo[0]                     # (q_local,)
        b_hi, b_lo, _, b_src, keep = _local_dispatch(
            hi, lo, jnp.zeros_like(hi), n_shards, capacity)
        req = a2a(jnp.stack([b_hi, b_lo], axis=-1))       # one payload out
        local = jax.tree.map(lambda x: x[0], st)
        found, vals = engine.search_batch(cfg, "eh", local,
                                          req[..., 0].reshape(-1),
                                          req[..., 1].reshape(-1),
                                          batching=search_batching)
        resp = a2a(jnp.stack([found.astype(U32), vals], axis=-1)
                   .reshape(n_shards, capacity, 2))       # one payload back
        out_f = jnp.zeros(hi.shape[0], jnp.bool_)
        out_v = jnp.zeros(hi.shape[0], U32)
        src = b_src.reshape(-1)
        safe = jnp.clip(src, 0)
        out_f = out_f.at[safe].max((resp[..., 0].reshape(-1) > 0) & (src >= 0))
        out_v = out_v.at[safe].max(jnp.where(src >= 0, resp[..., 1].reshape(-1), 0))
        return out_f[None], out_v[None], keep[None]

    def insert_inner(st, hi, lo, v, valid):
        hi, lo, v, valid = hi[0], lo[0], v[0], valid[0]
        # padded lanes (host pads the batch to n_shards*q_local) route to
        # owner -1: the dispatcher never grants them a lane, so padding can
        # never insert the zero key (statuses come back DROPPED, trimmed by
        # the host)
        b_hi, b_lo, b_v, b_src, keep = _local_dispatch(
            hi, lo, v, n_shards, capacity,
            owner_mask=valid)
        valid_lane = (b_src >= 0).astype(U32)
        req = a2a(jnp.stack([b_hi, b_lo, b_v, valid_lane], axis=-1))
        local = jax.tree.map(lambda x: x[0], st)
        # shard-level parallelism is already this function's dispatch axis;
        # the shard-local sub-batch is small and mostly padding lanes, so the
        # sequential engine is the right inner mode (the segment-parallel
        # engine pays off for large host batches where the host sizes lane
        # capacity from the directory — see DashTable._write_plan)
        local, statuses, _ = engine.insert_batch(
            cfg, "eh", local, req[..., 0].reshape(-1), req[..., 1].reshape(-1),
            req[..., 2].reshape(-1), None, req[..., 3].reshape(-1) > 0,
            batching="scan")
        s_back = a2a(statuses.reshape(n_shards, capacity))
        out = jnp.full(hi.shape[0], -1, I32)
        src = b_src.reshape(-1)
        out = out.at[jnp.clip(src, 0)].max(
            jnp.where(src >= 0, s_back.reshape(-1), -1))
        out = jnp.where(out < 0, layout.DROPPED, out)   # capacity-overflow lanes
        return jax.tree.map(lambda x: x[None], local), out[None], keep[None]

    search_fn = jax.jit(shard_map(
        search_inner, mesh=mesh, in_specs=(st_spec, q_spec, q_spec),
        out_specs=(q_spec, q_spec, q_spec), check_rep=False))
    insert_fn = jax.jit(shard_map(
        insert_inner, mesh=mesh,
        in_specs=(st_spec, q_spec, q_spec, q_spec, q_spec),
        out_specs=(st_spec, q_spec, q_spec), check_rep=False),
        donate_argnums=(0,))
    return search_fn, insert_fn, n_shards


class DistributedDash:
    """Host wrapper: device-sharded Dash with shard-local SMO handling.

    ``state`` lets a caller restore a previously persisted sharded state
    (``persist.reopen_shards`` stacks one host pytree from the per-shard
    pools); ``attach_pools`` binds one durable pool per shard — flushed
    independently, so a dirty shard restart recovers shard-locally and
    never touches its neighbors' pools."""

    def __init__(self, cfg: DashConfig, mesh: Mesh, axes=("data",),
                 capacity: int | None = None, q_local_hint: int = 1024,
                 search_batching: str = "vmap", state: DashState | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = tuple(axes)
        self.search_fn, self.insert_fn, self.n_shards = build_dht_ops(
            cfg, mesh, self.axes, capacity, q_local_hint, search_batching)
        sh = NamedSharding(mesh, P(self.axes))
        if state is None:
            state = make_sharded_state(cfg, self.n_shards)
        else:
            assert state.version.shape[0] == self.n_shards, \
                "restored state shard count != mesh shard count"
        self.state = jax.device_put(state, sh)
        self.writebacks = None        # per-shard durable pools (persist/)

    def attach_pools(self, writebacks):
        """Bind one durable pool per shard and mark the serving period
        dirty (the clean markers go durable only via ``close_pools``).
        Fresh pools get the current state flushed immediately, so a crash
        before the first ``flush_pools`` reopens to a valid table instead
        of an all-zeros plane region (mirrors ``persist.create``)."""
        assert len(writebacks) == self.n_shards
        self.writebacks = list(writebacks)
        self.state = self.state._replace(
            clean=jnp.zeros_like(self.state.clean))
        if any(wb.pool.sb.flush_seq == 0 for wb in self.writebacks):
            self.flush_pools()

    def flush_pools(self) -> int:
        """Flush every shard into its own pool (O(dirty) per shard: each
        shard's version-plane diff runs against its own pool mirror).
        Fault isolation: a shard whose pool degrades (I/O retry budget
        exhausted) is skipped — the OTHER shards still flush — and the
        degraded shard keeps serving from device state until
        ``recover_pools`` brings its pool back."""
        from repro import persist
        assert self.writebacks is not None, "no pools attached"
        return persist.flush_shards(self.state, self.writebacks)

    def recover_pools(self) -> int:
        """Probe every degraded shard pool and force-resync the ones that
        answer (``persist.recover_shards``). Returns shards recovered."""
        from repro import persist
        assert self.writebacks is not None, "no pools attached"
        return persist.recover_shards(self.state, self.writebacks)

    def degraded_shards(self) -> list:
        """Indices of shards whose pools are currently degraded."""
        if self.writebacks is None:
            return []
        return [i for i, wb in enumerate(self.writebacks) if wb.degraded]

    def close_pools(self):
        """Durable clean shutdown of every shard pool."""
        import jax.numpy as jnp
        assert self.writebacks is not None, "no pools attached"
        self.state = self.state._replace(
            clean=jnp.ones_like(self.state.clean))
        self.flush_pools()
        for wb in self.writebacks:
            wb.pool.close()

    def _shape_queries(self, keys):
        keys = np.asarray(keys, np.uint64)
        q_local = -(-keys.size // self.n_shards)
        pad = q_local * self.n_shards - keys.size
        keys_p = np.concatenate([keys, np.zeros(pad, np.uint64)])
        hi, lo = hashing.np_split_keys(keys_p)
        shape = (self.n_shards, q_local)
        return (jnp.asarray(hi).reshape(shape), jnp.asarray(lo).reshape(shape),
                keys.size, pad)

    def insert_once(self, keys, vals):
        """ONE sharded insert dispatch — no SMOs, no retries. Returns the
        per-key statuses; NEED_SPLIT/DROPPED lanes are the caller's to
        retry (``insert`` loops inline; the shard frontend defers the
        owner splits to their own scheduler ticks)."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32)
        hi, lo, n, pad = self._shape_queries(keys)
        v = jnp.asarray(np.concatenate(
            [vals, np.zeros(pad, np.uint32)])).reshape(hi.shape)
        valid = jnp.asarray(np.arange(n + pad) < n).reshape(hi.shape)
        self.state, statuses, keep = self.insert_fn(self.state, hi, lo, v,
                                                    valid)
        return np.asarray(statuses).reshape(-1)[:n]

    def insert(self, keys, vals, max_rounds: int = 8):
        """Batch insert with shard-local SMO retries. Statuses are aligned
        with the *input* batch across retry rounds; capacity-DROPPED lanes
        are retried too (the smaller retry subset routes without overflow)."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32)
        out = np.full(keys.size, layout.DROPPED, np.int32)
        pending = np.arange(keys.size)
        for _ in range(max_rounds):
            statuses = self.insert_once(keys[pending], vals[pending])
            out[pending] = statuses
            need = statuses == layout.NEED_SPLIT
            retry = need | (statuses == layout.DROPPED)
            if not retry.any():
                return out
            if need.any():
                self.split_for(keys[pending[need]])
            pending = pending[retry]
        raise RuntimeError("dht insert retry budget exhausted")

    def split_for(self, keys):
        """Shard-local splits on the owners of failed keys (host-driven).
        All pressured segments of a shard split in ONE bulk SMO dispatch
        (core/smo.py) — the per-segment split loop is gone."""
        from repro.core import dash_eh, smo
        hi, lo = hashing.np_split_keys(np.asarray(keys, np.uint64))
        owners = np.asarray(owner_of(jnp.asarray(hi), jnp.asarray(lo),
                                     self.n_shards))
        h1 = hashing.np_hash1(hi, lo)
        for shard in np.unique(owners):
            sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[shard]),
                               self.state)
            mask = owners == shard
            segs = np.unique(np.asarray(sub.dir)[
                h1[mask] >> np.uint32(32 - self.cfg.dir_depth_max)])
            depths = np.asarray(sub.local_depth)
            if (depths[segs] >= self.cfg.dir_depth_max).any():
                raise RuntimeError("shard directory depth exhausted")
            wm = int(np.asarray(sub.watermark))
            if wm + segs.size > self.cfg.max_segments:
                raise RuntimeError("shard segment pool exhausted")
            if smo.rebuild_eligible(self.cfg):
                sub, _ = smo.bulk_split(self.cfg, sub, segs,
                                        wm + np.arange(segs.size))
            else:
                for seg in segs:
                    sub, ok = dash_eh.split_segment(self.cfg, sub, int(seg))
                    assert bool(ok)
            self.state = jax.tree.map(
                lambda full, s: full.at[shard].set(s), self.state, sub)

    _split_for = split_for            # back-compat alias

    def search_on(self, state, keys):
        """Search against a caller-supplied sharded state (e.g. an
        epoch-pinned snapshot); ``search`` is the live-state shorthand.
        The shard_map'd probe takes any state of the right shapes and
        never donates it, so snapshots survive the call."""
        hi, lo, n, _ = self._shape_queries(keys)
        f, v, keep = self.search_fn(state, hi, lo)
        return (np.asarray(f).reshape(-1)[:n], np.asarray(v).reshape(-1)[:n])

    def search(self, keys):
        return self.search_on(self.state, keys)

    @property
    def n_items(self) -> int:
        return int(np.sum(np.asarray(self.state.n_items)))


class ShardFrontend(frontend.FrontendBase):
    """The online-resize frontend (serving/frontend.py) adopted for the
    device-sharded table: epoch-guarded snapshot reads + deferred shard
    SMOs over ``DistributedDash``. Admission lanes, batch forming, the
    read-priority scheduler, and latency/retry accounting come from the
    shared ``FrontendBase``.

    Read batches pin the newest published snapshot of the *sharded* state
    and probe it through the unchanged shard_map program; the verify pass
    compares the owner shard's bucket version planes (host mirror of
    ``serving.engine.buckets_changed`` — keep the two in lockstep: a
    contract change there MUST land here too, the shard consistency test
    guards it) and retries only changed queries on the live state. Write
    batches run ONE sharded dispatch per tick (``insert_once``); pressured
    owners' bulk splits (``split_for``) are deferred to their own ticks, so
    read batches interleave with a shard split storm exactly as in the
    single-table frontend. Insert + read lanes (the DHT serving surface);
    updates/deletes stay on the table API.
    """

    def __init__(self, dht: DistributedDash, *, max_batch: int = 256,
                 queue_depth: int = 4096, obs=None):
        super().__init__(max_batch=max_batch, queue_depth=queue_depth,
                         obs=obs)
        self.dht = dht
        self._dirty = True
        # per-shard degraded transitions (satellite of the quarantine/
        # transition surfacing): counts every shard that ENTERS degraded,
        # not just the frontend-level health flip
        self.shard_degraded_transitions = 0
        self._degraded_prev: set = set()
        self._publish()
        self._pending = None          # in-flight insert batch host state
        self._split_keys = None       # keys whose owners need a bulk split

    def _publish(self):
        """Per-shard copy-on-write publish: the sharded state's planes have
        a (n_shards, S, ...) leading shape, and the same version-plane diff
        drives the O(dirty) scatter — an insert burst republises only the
        bucket rows its owners wrote, a shard split storm only the rebuilt
        segments (plus each shard's directory when it changed). With pools
        attached, every publish also flushes each shard into its own pool
        (flush-on-publish: acknowledged DHT ops are durable)."""
        tr = self.obs.tracer
        with tr.span("publish", "epoch") as psp:
            self.registry.publish_cow(self.dht.cfg, self.dht.state)
            self._publishes.inc()
            self._publish_bytes.inc(self.registry.last_publish_bytes)
            if self.dht.writebacks is not None:
                for wb in self.dht.writebacks:
                    if wb.obs is None:
                        # per-shard flush spans nest under this publish
                        wb.attach_obs(self.obs)
                before = sum(w.flushed_bytes for w in self.dht.writebacks)
                self.dht.flush_pools()
                self._flush_bytes.inc(
                    sum(w.flushed_bytes for w in self.dht.writebacks)
                    - before)
                degraded = set(self.dht.degraded_shards())
                self.shard_degraded_transitions += len(
                    degraded - self._degraded_prev)
                self._degraded_prev = degraded
                if degraded:
                    if self.health == frontend.HEALTHY:
                        self._set_health(frontend.DEGRADED)
                    self.unflushed_publishes += 1
                elif self.health == frontend.DEGRADED:
                    self._set_health(frontend.HEALTHY)
            if psp is not None:
                psp.args["bytes"] = self.registry.last_publish_bytes
        self._dirty = False

    def submit(self, op) -> bool:
        """Reject kinds outside the DHT serving surface at admission time
        (an admitted op must never strand mid-drain)."""
        if op.kind not in (frontend.READ, frontend.INSERT):
            self.writes.rejected += 1
            return False
        return super().submit(op)

    def stats(self) -> dict:
        out = super().stats()
        out["shard_degraded_transitions"] = self.shard_degraded_transitions
        if self.dht.writebacks is not None:
            out["flushes"] = sum(w.flushes for w in self.dht.writebacks)
            out["flushed_bytes"] = sum(w.flushed_bytes
                                       for w in self.dht.writebacks)
            out["pool_bytes"] = sum(w.pool.plane_bytes
                                    for w in self.dht.writebacks)
            degraded = self.dht.degraded_shards()
            out["shards_degraded"] = degraded
            out["health"] = (frontend.DEGRADED if degraded
                             else frontend.HEALTHY)
            out["flush_io_errors"] = sum(w.flush_io_errors
                                         for w in self.dht.writebacks)
            out["degraded_flushes"] = sum(w.degraded_flushes
                                          for w in self.dht.writebacks)
            # durable quarantine evidence, fleet-wide (satellite: chaos
            # runs assert on the aggregate without reaching into pools)
            out["lost_records"] = sum(w.pool.sb.lost_records
                                      for w in self.dht.writebacks)
            out["quarantined_bt"] = sum(len(w.pool.sb.lost_bt)
                                        for w in self.dht.writebacks)
            out["quarantined_nb"] = sum(len(w.pool.sb.lost_nb)
                                        for w in self.dht.writebacks)
        return out

    def shard_registries(self) -> list:
        """One mirror ``Registry`` per shard (the writeback's cumulative
        counters ingested as Counters), so ``Registry.aggregate`` sums a
        fleet view — the per-shard observability surface."""
        from repro.obs import Registry
        regs = []
        for wb in (self.dht.writebacks or []):
            r = Registry()
            r.ingest(wb.stats(), prefix="wb.", counters=True)
            regs.append(r)
        return regs

    def obs_snapshot(self) -> dict:
        from repro.obs import Registry
        self.obs.registry.ingest(self.stats(), prefix="stats.")
        out = self.obs.snapshot()
        regs = self.shard_registries()
        if regs:
            out["shards"] = Registry.aggregate(regs).snapshot()
            out["per_shard"] = [r.snapshot() for r in regs]
        return out

    def try_recover(self) -> bool:
        """Re-probe degraded shard pools; True when every shard is back
        HEALTHY. Healthy shards were never interrupted — recovery is
        strictly per-shard (fault isolation)."""
        if self.dht.writebacks is None:
            return True
        if self.dht.degraded_shards():
            self.dht.recover_pools()
        ok = not self.dht.degraded_shards()
        if ok:
            self._degraded_prev = set()
            self._set_health(frontend.HEALTHY)
        return ok

    def _write_pending(self) -> bool:
        return self._pending is not None or self._split_keys is not None

    def _changed_mask(self, snap_state, keys) -> np.ndarray:
        """Host mirror of serving.engine.buckets_changed over the owner
        shard's planes (shard count is host-visible; the compare is a few
        gathers over the copied version planes)."""
        cfg = self.dht.cfg
        keys = np.asarray(keys, np.uint64)
        hi, lo = hashing.np_split_keys(keys)
        h1 = hashing.np_hash1(hi, lo)
        owner = (h1 >> np.uint32(32 - int(np.log2(self.dht.n_shards)))
                 ).astype(np.int64)
        d = (h1 >> np.uint32(32 - cfg.dir_depth_max)).astype(np.int64)
        old_dir, new_dir = np.asarray(snap_state.dir), np.asarray(
            self.dht.state.dir)
        seg = old_dir[owner, d].astype(np.int64)
        changed = seg != new_dir[owner, d]
        oldv = np.asarray(snap_state.version)
        newv = np.asarray(self.dht.state.version)
        NB = cfg.num_buckets
        b = (h1 & np.uint32(NB - 1)).astype(np.int64)
        for w in range(cfg.probe_window):
            bw = (b + w) & (NB - 1)
            changed |= oldv[owner, seg, bw] != newv[owner, seg, bw]
        for s in range(cfg.num_stash):
            changed |= oldv[owner, seg, NB + s] != newv[owner, seg, NB + s]
        return changed

    def _serve_reads(self, ops):
        keys = np.asarray([op.key for op in ops], np.uint64)
        with self.registry.acquire() as snap:
            found, vals = self.dht.search_on(snap.state, keys)
            n_changed = 0
            if self._dirty:
                changed = self._changed_mask(snap.state, keys)
                n_changed = int(changed.sum())
            if n_changed:
                f2, v2 = self.dht.search(keys)
                found = np.where(changed, f2, found)
                vals = np.where(changed, v2, vals)
        self._finish_reads(ops, found, vals, n_changed)

    def _pump_write(self) -> bool:
        if self._split_keys is not None:
            # the deferred storm: every pressured owner splits all its
            # pressured segments in one bulk dispatch
            self.dht.split_for(self._split_keys)
            self._split_keys = None
            self._dirty = True
            self._publish()
            return True
        if self._pending is not None:
            keys, vals, out, pending, ops, rounds = self._pending
            if rounds > 32:
                raise RuntimeError("dht insert retry budget exhausted")
            statuses = self.dht.insert_once(keys[pending], vals[pending])
            self._dirty = True
            out[pending] = statuses
            need = statuses == layout.NEED_SPLIT
            retry = need | (statuses == layout.DROPPED)
            if not retry.any():
                self._finish_writes(ops, out)
                self._pending = None
                self._publish()
            else:
                if need.any():
                    self._split_keys = keys[pending[need]]
                self._pending = (keys, vals, out, pending[retry], ops,
                                 rounds + 1)
            return True
        ops = self.former.form(self.writes)
        if not ops:
            return False
        assert ops[0].kind == frontend.INSERT, \
            "shard frontend lanes cover read + insert"
        keys = np.asarray([op.key for op in ops], np.uint64)
        vals = np.asarray([op.value for op in ops], np.uint32)
        self._pending = (keys, vals,
                         np.full(keys.size, layout.DROPPED, np.int32),
                         np.arange(keys.size), ops, 0)
        return self._pump_write()
