"""Distributed Dash: the paper's "scalable hashing" scaled out to a TPU pod.

Every device owns an independent Dash-EH table (a shard). The top
log2(n_shards) bits of the addressing hash pick the owner — the distributed
extension of the MSB directory. Query batches start *sharded over devices*;
each device routes its local queries to owners with a fixed-capacity
``all_to_all`` (MoE-style dispatch), owners probe shard-locally (the Pallas
fingerprint path applies verbatim — shards are ordinary Dash tables), and a
second ``all_to_all`` routes results back.

Scalability argument mirrors the paper's: probes are bandwidth-bound and
shards touch disjoint memory; the only cross-chip cost is ~24 bytes/query
each way vs. the ~256-byte bucket traffic it replaces, so the fabric term
stays well under the local-HBM term (benchmarks/dht_roofline.py derives both
from the dry-run artifact).

SMOs stay shard-local: a segment split never moves keys across shards (the
owner bits are disjoint from the shard-local directory bits), so there is no
cross-shard coordination — this is what makes the design elastic: growing
from 1 to 2 pods adds one owner bit and moves only metadata.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import DashConfig, engine, hashing, layout
from repro.core.layout import DashState
from repro.kernels import ops as kops

I32 = jnp.int32
U32 = jnp.uint32


def make_sharded_state(cfg: DashConfig, n_shards: int) -> DashState:
    one = layout.make_state(cfg, "eh")
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_shards,) + x.shape).copy(), one)


def make_abstract(cfg: DashConfig, n_shards: int):
    one = jax.eval_shape(lambda: layout.make_state(cfg, "eh"))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_shards,) + x.shape, x.dtype), one)


def owner_of(keys_hi, keys_lo, n_shards: int):
    """Owner shard from the TOP bits of h1 — the distributed MSB directory.
    Shard-local directories consume the next dir_depth_max bits, so probing
    inside the owner uses the unchanged 32-bit hash."""
    h1 = hashing.hash1(keys_hi, keys_lo)
    return (h1 >> U32(32 - int(np.log2(n_shards)))).astype(I32)


def _local_dispatch(hi, lo, v, n_shards: int, capacity: int,
                    owner_mask=None):
    """Route this device's queries into (n_shards, capacity) buffers via the
    shared MoE-style dispatcher (kernels/ops.py) — the same sort-based
    router the engine uses to group by segment, here grouping by owner
    shard. ``owner_mask=False`` lanes route to owner -1 (dropped). Returns
    buffers + src map (-1 = empty lane) + kept mask."""
    owner = owner_of(hi, lo, n_shards)
    if owner_mask is not None:
        owner = jnp.where(owner_mask, owner, -1)
    (b_hi, b_lo, b_v), b_src, keep = kops.route_lanes(
        owner, (hi, lo, v), n_shards, capacity, (0, 0, 0))
    return b_hi, b_lo, b_v, b_src, keep


def auto_capacity(q_local: int, n_shards: int, slack: float = 4.0) -> int:
    """Routing lanes per (src, dst): expected q_local/n_shards with slack.
    Oversized lanes are pure wasted wire — right-sizing them was a 16x
    fabric-bytes win at 256 chips (EXPERIMENTS.md SSPerf, DHT cell)."""
    want = int(np.ceil(q_local / n_shards * slack))
    return max(8, 1 << int(np.ceil(np.log2(want))))


def build_dht_ops(cfg: DashConfig, mesh: Mesh, axes=("data",),
                  capacity: int | None = None, q_local_hint: int = 1024,
                  search_batching: str = "vmap"):
    """jitted (search_fn, insert_fn) over a device-sharded table.

    Inputs: keys reshaped (n_shards, q_local), sharded on dim 0.
    Payloads are PACKED into one (n_shards, cap, W) word tensor so each
    direction is a single all_to_all (one launch on the ICI, not four).

    ``search_batching`` selects the shard-local read path; shards are
    ordinary Dash tables, so the Pallas fingerprint path applies verbatim
    (pass "pallas"/"auto" on TPU). The CPU default stays on the per-key
    path: interpret-mode MXU gathers lose on emulated devices, and the
    all_to_all padding lanes (key 0) would pile onto one segment."""
    axes = tuple(axes)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if capacity is None:
        capacity = auto_capacity(q_local_hint, n_shards)
    st_spec = jax.tree.map(lambda _: P(axes), make_abstract(cfg, n_shards))
    q_spec = P(axes)
    a2a = lambda x: jax.lax.all_to_all(x, axes, 0, 0, tiled=True)

    def search_inner(st, hi, lo):
        hi, lo = hi[0], lo[0]                     # (q_local,)
        b_hi, b_lo, _, b_src, keep = _local_dispatch(
            hi, lo, jnp.zeros_like(hi), n_shards, capacity)
        req = a2a(jnp.stack([b_hi, b_lo], axis=-1))       # one payload out
        local = jax.tree.map(lambda x: x[0], st)
        found, vals = engine.search_batch(cfg, "eh", local,
                                          req[..., 0].reshape(-1),
                                          req[..., 1].reshape(-1),
                                          batching=search_batching)
        resp = a2a(jnp.stack([found.astype(U32), vals], axis=-1)
                   .reshape(n_shards, capacity, 2))       # one payload back
        out_f = jnp.zeros(hi.shape[0], jnp.bool_)
        out_v = jnp.zeros(hi.shape[0], U32)
        src = b_src.reshape(-1)
        safe = jnp.clip(src, 0)
        out_f = out_f.at[safe].max((resp[..., 0].reshape(-1) > 0) & (src >= 0))
        out_v = out_v.at[safe].max(jnp.where(src >= 0, resp[..., 1].reshape(-1), 0))
        return out_f[None], out_v[None], keep[None]

    def insert_inner(st, hi, lo, v, valid):
        hi, lo, v, valid = hi[0], lo[0], v[0], valid[0]
        # padded lanes (host pads the batch to n_shards*q_local) route to
        # owner -1: the dispatcher never grants them a lane, so padding can
        # never insert the zero key (statuses come back DROPPED, trimmed by
        # the host)
        b_hi, b_lo, b_v, b_src, keep = _local_dispatch(
            hi, lo, v, n_shards, capacity,
            owner_mask=valid)
        valid_lane = (b_src >= 0).astype(U32)
        req = a2a(jnp.stack([b_hi, b_lo, b_v, valid_lane], axis=-1))
        local = jax.tree.map(lambda x: x[0], st)
        # shard-level parallelism is already this function's dispatch axis;
        # the shard-local sub-batch is small and mostly padding lanes, so the
        # sequential engine is the right inner mode (the segment-parallel
        # engine pays off for large host batches where the host sizes lane
        # capacity from the directory — see DashTable._write_plan)
        local, statuses, _ = engine.insert_batch(
            cfg, "eh", local, req[..., 0].reshape(-1), req[..., 1].reshape(-1),
            req[..., 2].reshape(-1), None, req[..., 3].reshape(-1) > 0,
            batching="scan")
        s_back = a2a(statuses.reshape(n_shards, capacity))
        out = jnp.full(hi.shape[0], -1, I32)
        src = b_src.reshape(-1)
        out = out.at[jnp.clip(src, 0)].max(
            jnp.where(src >= 0, s_back.reshape(-1), -1))
        out = jnp.where(out < 0, layout.DROPPED, out)   # capacity-overflow lanes
        return jax.tree.map(lambda x: x[None], local), out[None], keep[None]

    search_fn = jax.jit(shard_map(
        search_inner, mesh=mesh, in_specs=(st_spec, q_spec, q_spec),
        out_specs=(q_spec, q_spec, q_spec), check_rep=False))
    insert_fn = jax.jit(shard_map(
        insert_inner, mesh=mesh,
        in_specs=(st_spec, q_spec, q_spec, q_spec, q_spec),
        out_specs=(st_spec, q_spec, q_spec), check_rep=False),
        donate_argnums=(0,))
    return search_fn, insert_fn, n_shards


class DistributedDash:
    """Host wrapper: device-sharded Dash with shard-local SMO handling."""

    def __init__(self, cfg: DashConfig, mesh: Mesh, axes=("data",),
                 capacity: int | None = None, q_local_hint: int = 1024,
                 search_batching: str = "vmap"):
        self.cfg = cfg
        self.mesh = mesh
        self.axes = tuple(axes)
        self.search_fn, self.insert_fn, self.n_shards = build_dht_ops(
            cfg, mesh, self.axes, capacity, q_local_hint, search_batching)
        sh = NamedSharding(mesh, P(self.axes))
        self.state = jax.device_put(make_sharded_state(cfg, self.n_shards),
                                    sh)

    def _shape_queries(self, keys):
        keys = np.asarray(keys, np.uint64)
        q_local = -(-keys.size // self.n_shards)
        pad = q_local * self.n_shards - keys.size
        keys_p = np.concatenate([keys, np.zeros(pad, np.uint64)])
        hi, lo = hashing.np_split_keys(keys_p)
        shape = (self.n_shards, q_local)
        return (jnp.asarray(hi).reshape(shape), jnp.asarray(lo).reshape(shape),
                keys.size, pad)

    def insert(self, keys, vals, max_rounds: int = 8):
        """Batch insert with shard-local SMO retries. Statuses are aligned
        with the *input* batch across retry rounds; capacity-DROPPED lanes
        are retried too (the smaller retry subset routes without overflow)."""
        keys = np.asarray(keys, np.uint64)
        vals = np.asarray(vals, np.uint32)
        out = np.full(keys.size, layout.DROPPED, np.int32)
        pending = np.arange(keys.size)
        for _ in range(max_rounds):
            hi, lo, n, pad = self._shape_queries(keys[pending])
            v = jnp.asarray(np.concatenate(
                [vals[pending], np.zeros(pad, np.uint32)])).reshape(hi.shape)
            valid = jnp.asarray(np.arange(n + pad) < n).reshape(hi.shape)
            self.state, statuses, keep = self.insert_fn(self.state, hi, lo, v,
                                                        valid)
            statuses = np.asarray(statuses).reshape(-1)[:n]
            out[pending] = statuses
            need = statuses == layout.NEED_SPLIT
            retry = need | (statuses == layout.DROPPED)
            if not retry.any():
                return out
            if need.any():
                self._split_for(keys[pending[need]])
            pending = pending[retry]
        raise RuntimeError("dht insert retry budget exhausted")

    def _split_for(self, keys):
        """Shard-local splits on the owners of failed keys (host-driven).
        All pressured segments of a shard split in ONE bulk SMO dispatch
        (core/smo.py) — the per-segment split loop is gone."""
        from repro.core import dash_eh, smo
        hi, lo = hashing.np_split_keys(np.asarray(keys, np.uint64))
        owners = np.asarray(owner_of(jnp.asarray(hi), jnp.asarray(lo),
                                     self.n_shards))
        h1 = hashing.np_hash1(hi, lo)
        for shard in np.unique(owners):
            sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[shard]),
                               self.state)
            mask = owners == shard
            segs = np.unique(np.asarray(sub.dir)[
                h1[mask] >> np.uint32(32 - self.cfg.dir_depth_max)])
            depths = np.asarray(sub.local_depth)
            if (depths[segs] >= self.cfg.dir_depth_max).any():
                raise RuntimeError("shard directory depth exhausted")
            wm = int(np.asarray(sub.watermark))
            if wm + segs.size > self.cfg.max_segments:
                raise RuntimeError("shard segment pool exhausted")
            if smo.rebuild_eligible(self.cfg):
                sub, _ = smo.bulk_split(self.cfg, sub, segs,
                                        wm + np.arange(segs.size))
            else:
                for seg in segs:
                    sub, ok = dash_eh.split_segment(self.cfg, sub, int(seg))
                    assert bool(ok)
            self.state = jax.tree.map(
                lambda full, s: full.at[shard].set(s), self.state, sub)

    def search(self, keys):
        hi, lo, n, _ = self._shape_queries(keys)
        f, v, keep = self.search_fn(self.state, hi, lo)
        return (np.asarray(f).reshape(-1)[:n], np.asarray(v).reshape(-1)[:n])

    @property
    def n_items(self) -> int:
        return int(np.sum(np.asarray(self.state.n_items)))
