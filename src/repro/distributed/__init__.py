"""Distributed Dash (shard_map all_to_all routed hash table)."""
from .dht import (DistributedDash, ShardFrontend, build_dht_ops,
                  make_sharded_state, owner_of)

__all__ = ["DistributedDash", "ShardFrontend", "build_dht_ops",
           "make_sharded_state", "owner_of"]
