"""Op-lifecycle tracing: causally-linked spans, ring-buffered, Chrome-trace
export.

A batch's journey — enqueue → batch-form → dispatch → publish → flush → ack —
was invisible before this module: each stage stamped its own
``perf_counter`` and threw the relationship away. A ``Tracer`` records that
journey as spans:

  * ``begin(name)`` / ``end(span)`` — an explicit span for work that crosses
    scheduler ticks (a write batch whose insert rounds interleave with SMO
    stages); the parent defaults to the innermost open ``span()`` context.
  * ``with tracer.span(name):`` — a scoped child span (probe, verify, one
    SMO stage, one flush phase).
  * ``instant(name)`` — a point event (redo-log commit, health transition,
    quarantine report), parented to the innermost open span.
  * ``link(span, *others)`` — extra causal edges beyond the tree: an ack
    span links back to its batch span AND the publish/flush spans that made
    its effects visible/durable.

Memory is bounded: closed spans land in a ring (``capacity`` entries, oldest
dropped first, drops counted) and open spans are only ever the live stack +
the handful of cross-tick spans the frontend holds. A disabled tracer
(``enabled=False``, the default for production serving) is a few ``None``
checks per call — the hot path stays cheap enough to leave call sites
unconditional.

``export_chrome_trace`` renders the ring as Chrome-trace JSON ("traceEvents"
with complete/instant/flow events) for drop-into-``chrome://tracing`` /
Perfetto inspection; span ids and causal links also ride in each event's
``args`` so tests (and scripts) can verify linkage without a trace viewer.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

__all__ = ["Span", "Tracer", "export_chrome_trace"]


class Span:
    """One traced operation: half-open [t0, t1) plus causal edges."""

    __slots__ = ("sid", "parent", "name", "cat", "t0", "t1", "tid", "args",
                 "links")

    def __init__(self, sid: int, parent: Optional[int], name: str, cat: str,
                 t0: float, tid: int, args: Optional[dict]):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1 = t0
        self.tid = tid
        self.args = args or {}
        self.links = []


class Tracer:
    """Span recorder with a bounded ring of closed spans. Single-writer by
    design (the frontends are cooperative schedulers); concurrent producers
    should each own a tracer and merge exports."""

    def __init__(self, enabled: bool = True, capacity: int = 1 << 16,
                 clock=time.perf_counter):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.clock = clock
        self._ring: deque = deque(maxlen=self.capacity)
        self._stack: list = []          # innermost open scoped spans
        self._next_sid = 1
        self.recorded = 0               # spans closed into the ring
        self.dropped = 0                # ring evictions (bounded memory)

    # -- recording --------------------------------------------------------

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    def begin(self, name: str, cat: str = "", parent=None, tid: int = 0,
              **args) -> Optional[Span]:
        """Open a span. ``parent`` is a Span, a span id, or None (inherit
        the innermost open scoped span). The span is NOT pushed on the
        scope stack — it may stay open across scheduler ticks; close it
        with ``end``. Returns None when disabled."""
        if not self.enabled:
            return None
        if parent is None:
            cur = self.current()
            parent = cur.sid if cur is not None else None
        elif isinstance(parent, Span):
            parent = parent.sid
        sp = Span(self._next_sid, parent, name, cat, self.clock(), tid, args)
        self._next_sid += 1
        return sp

    def end(self, sp: Optional[Span], **args):
        """Close a span into the ring (no-op on None — disabled tracer)."""
        if sp is None:
            return
        sp.t1 = self.clock()
        if args:
            sp.args.update(args)
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(sp)
        self.recorded += 1

    @contextmanager
    def span(self, name: str, cat: str = "", parent=None, **args):
        """Scoped child span: pushed on the stack so nested spans/instants
        parent to it automatically. Yields the Span (None when disabled)."""
        sp = self.begin(name, cat, parent=parent, **args)
        if sp is not None:
            self._stack.append(sp)
        try:
            yield sp
        finally:
            if sp is not None:
                self._stack.pop()
            self.end(sp)

    def instant(self, name: str, cat: str = "", parent=None, **args
                ) -> Optional[Span]:
        """Zero-duration event (health transition, log commit, quarantine);
        parented like ``begin``."""
        sp = self.begin(name, cat, parent=parent, **args)
        self.end(sp)
        return sp

    @staticmethod
    def link(sp: Optional[Span], *others):
        """Add causal edges from ``sp`` back to ``others`` (Spans, ids, or
        None — Nones are skipped, so call sites stay unconditional)."""
        if sp is None:
            return
        for o in others:
            if o is None:
                continue
            sp.links.append(o.sid if isinstance(o, Span) else int(o))

    # -- export -----------------------------------------------------------

    def spans(self) -> list:
        return list(self._ring)

    def clear(self):
        self._ring.clear()

    def export_chrome_trace(self, path: Optional[str] = None,
                            pid: int = 0) -> dict:
        return export_chrome_trace(self, path, pid=pid)

    def stats(self) -> dict:
        return {"trace_enabled": self.enabled,
                "trace_recorded": self.recorded,
                "trace_buffered": len(self._ring),
                "trace_dropped": self.dropped,
                "trace_capacity": self.capacity}


def export_chrome_trace(tracer: Tracer, path: Optional[str] = None,
                        pid: int = 0) -> dict:
    """Render the tracer's ring as a Chrome-trace JSON object and (when
    ``path`` is given) write it.

    Event mapping: spans become complete events (``ph: "X"``, microsecond
    ``ts``/``dur``) carrying ``sid``/``parent``/``links`` in ``args``;
    zero-duration spans become instants (``ph: "i"``); every causal link
    additionally becomes a flow pair (``ph: "s"`` at the source span,
    ``ph: "f"`` at the linking span) so Perfetto draws the arrows. The
    object form ({"traceEvents": [...]}) is used so metadata rides along.
    """
    events = []
    spans = tracer.spans()
    have = {sp.sid for sp in spans}
    by_sid = {sp.sid: sp for sp in spans}
    flow_id = 0
    for sp in spans:
        ts = sp.t0 * 1e6
        dur = max(sp.t1 - sp.t0, 0.0) * 1e6
        args = dict(sp.args)
        args["sid"] = sp.sid
        if sp.parent is not None:
            args["parent"] = sp.parent
        if sp.links:
            args["links"] = list(sp.links)
        ev = {"name": sp.name, "cat": sp.cat or "span", "pid": pid,
              "tid": sp.tid, "ts": ts, "args": args}
        if dur == 0.0:
            events.append({**ev, "ph": "i", "s": "t"})
        else:
            events.append({**ev, "ph": "X", "dur": dur})
        for target in sp.links:
            if target not in have:
                continue          # linked span evicted from the ring
            src = by_sid[target]
            flow_id += 1
            events.append({"name": f"{src.name}->{sp.name}", "cat": "flow",
                           "ph": "s", "id": flow_id, "pid": pid,
                           "tid": src.tid, "ts": src.t1 * 1e6})
            events.append({"name": f"{src.name}->{sp.name}", "cat": "flow",
                           "ph": "f", "bp": "e", "id": flow_id, "pid": pid,
                           "tid": sp.tid, "ts": ts})
    out = {"traceEvents": events, "displayTimeUnit": "ms",
           "metadata": {"recorded": tracer.recorded,
                        "dropped": tracer.dropped}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(out, f)
    return out
