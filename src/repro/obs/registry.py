"""Metrics registry: named counters, gauges, log-bucketed latency histograms.

The repo's measured claims (tail latency under split storms, O(dirty) publish
and flush volume, bounded scrub latency) all need the same three primitives,
and before this layer each component grew its own ad-hoc rendering — a dozen
disconnected ``stats()`` dicts and bare ``time.perf_counter`` deltas that
recorded only means. This module is the shared substrate:

``Counter``
    Monotonic count (ops completed, bytes published, health transitions).

``Gauge``
    Last-write-wins level (queue depth, epoch limbo depth, health state).

``Histogram``
    Log-bucketed distribution with cheap hot-path recording and
    p50/p90/p99/max extraction. Buckets are geometric — ``bpo`` buckets per
    octave (power of two), so the worst-case quantile error is the half-
    bucket ratio ``2**(1/(2*bpo)) - 1`` (±2.2% at the default 16/octave —
    comfortably inside the 10% agreement gate the online-resize bench
    asserts against its exact-sample percentiles). ``observe`` is a couple
    of float ops + one array increment; ``observe_many`` takes a vector
    through one ``np.bincount``. This is what turns bench artifacts from
    means into tail rows — the PM range-index evaluation's core lesson
    (PAPERS.md): tails, not means, distinguish designs under load.

``Registry``
    A flat namespace of the above (dotted names: ``frontend.read_sojourn_s``,
    ``wb.flush_bytes``). ``scope(prefix)`` gives a component its own
    namespace over the same store; ``ingest(stats_dict)`` absorbs the
    existing ``stats()`` surfaces (frontend publish/COW counters, writeback
    flush counters, scrubber, fault-plan counters) into gauges WITHOUT
    changing those dict APIs; ``merge`` sums registries — the DHT aggregates
    one registry per shard into a fleet view. ``snapshot()`` /
    ``histogram_rows()`` are the export surface benches stamp into
    ``BENCH_*.json``.

Everything here is plain host Python + numpy — recording never touches a
device or takes a lock (the frontends are cooperative single-thread
schedulers; cross-thread use should shard registries and ``merge``).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]

#: default histogram geometry: 16 buckets/octave from 0.1 us to ~7000 s —
#: wide enough for sojourn times, byte counts, and row counts alike
HIST_LO = 1e-7
HIST_OCTAVES = 36
HIST_BPO = 16


class Counter:
    """Monotonic counter. ``inc`` is the only mutator."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1):
        self.value += n

    def merge(self, other: "Counter"):
        self.value += other.value


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, v):
        self.value = v

    def merge(self, other: "Gauge"):
        self.value = other.value


class Histogram:
    """Log-bucketed distribution (see module docstring).

    Values below ``lo`` land in the underflow bucket (index 0 — reported as
    ``lo``); values above the range land in the top bucket. Exact min/max
    are tracked alongside, so ``percentile(100)`` is the true max and
    quantile extraction clamps into the observed [min, max] envelope (the
    clamp is what keeps single-bucket distributions exact)."""

    __slots__ = ("name", "lo", "bpo", "counts", "n", "total", "vmin", "vmax")

    def __init__(self, name: str, lo: float = HIST_LO,
                 octaves: int = HIST_OCTAVES, bpo: int = HIST_BPO):
        self.name = name
        self.lo = float(lo)
        self.bpo = int(bpo)
        self.counts = np.zeros(int(octaves) * self.bpo, np.int64)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # -- recording --------------------------------------------------------

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.log2(v / self.lo) * self.bpo)
        return i if i < self.counts.size else self.counts.size - 1

    def observe(self, v: float):
        """Scalar hot path: two float ops + one increment."""
        v = float(v)
        self.counts[self._index(v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def observe_many(self, vs):
        """Vectorized batch recording: one log2 + one bincount."""
        vs = np.asarray(vs, np.float64).reshape(-1)
        if vs.size == 0:
            return
        idx = np.zeros(vs.size, np.int64)
        pos = vs > self.lo
        idx[pos] = np.minimum(
            (np.log2(vs[pos] / self.lo) * self.bpo).astype(np.int64),
            self.counts.size - 1)
        self.counts += np.bincount(idx, minlength=self.counts.size)
        self.n += int(vs.size)
        self.total += float(vs.sum())
        self.vmin = min(self.vmin, float(vs.min()))
        self.vmax = max(self.vmax, float(vs.max()))

    # -- extraction -------------------------------------------------------

    def _bucket_value(self, i: int) -> float:
        # geometric midpoint of the bucket — halves the worst-case error
        return self.lo * 2.0 ** ((i + 0.5) / self.bpo)

    def percentile(self, q: float, counts: Optional[np.ndarray] = None,
                   ) -> float:
        """Value at percentile ``q`` (0..100) from the bucket counts
        (optionally a caller-supplied windowed copy). NaN when empty."""
        c = self.counts if counts is None else counts
        n = int(c.sum())
        if n == 0:
            return math.nan
        if q >= 100.0 and counts is None:
            return self.vmax
        rank = max(1, math.ceil(q / 100.0 * n))
        i = int(np.searchsorted(np.cumsum(c), rank))
        v = self._bucket_value(i)
        if counts is None and self.n == n:
            v = min(max(v, self.vmin), self.vmax)
        return v

    def snapshot(self, counts: Optional[np.ndarray] = None) -> dict:
        """The standard artifact row: count/sum/mean + p50/p90/p99/max."""
        c = self.counts if counts is None else counts
        n = int(c.sum())
        out = {"n": n,
               "sum": self.total if counts is None else math.nan,
               "mean": (self.total / self.n
                        if counts is None and self.n else math.nan),
               "p50": self.percentile(50, counts),
               "p90": self.percentile(90, counts),
               "p99": self.percentile(99, counts),
               "max": self.vmax if counts is None and self.n else
               self.percentile(100, counts)}
        return out

    def merge(self, other: "Histogram"):
        assert (self.lo == other.lo
                and self.counts.size == other.counts.size), \
            "merging histograms with different geometry"
        self.counts += other.counts
        self.n += other.n
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)


class _Scope:
    """Prefix view over a registry: ``scope.counter("x")`` is
    ``registry.counter("prefix.x")``."""

    __slots__ = ("_reg", "_prefix")

    def __init__(self, reg: "Registry", prefix: str):
        self._reg = reg
        self._prefix = prefix.rstrip(".") + "."

    def counter(self, name: str) -> Counter:
        return self._reg.counter(self._prefix + name)

    def gauge(self, name: str) -> Gauge:
        return self._reg.gauge(self._prefix + name)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._reg.histogram(self._prefix + name, **kw)

    def ingest(self, stats: dict, counters: bool = False):
        self._reg.ingest(stats, prefix=self._prefix, counters=counters)


class Registry:
    """Flat get-or-create store of named metrics (see module docstring)."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name)
            self._metrics[name] = m
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = Histogram(name, **kw)
            self._metrics[name] = m
        assert isinstance(m, Histogram)
        return m

    def scope(self, prefix: str) -> _Scope:
        return _Scope(self, prefix)

    def ingest(self, stats: dict, prefix: str = "", counters: bool = False):
        """Absorb an existing ``stats()`` dict: numeric values become
        gauges (the dicts are cumulative — last write wins is correct),
        bools become 0/1 gauges, everything else is skipped. The dict APIs
        stay authoritative; this mirrors them into the one namespace.

        ``counters=True`` lands the numbers in Counters instead (value
        overwritten, not added — a mirror, not an increment): the shape a
        per-shard mirror registry needs so ``aggregate`` SUMS the fleet
        (gauges would take the last shard's value)."""
        for k, v in stats.items():
            if isinstance(v, bool):
                self.gauge(prefix + k).set(int(v))
            elif isinstance(v, (int, float, np.integer, np.floating)):
                v = float(v) if isinstance(v, (float, np.floating)) else int(v)
                if counters:
                    self.counter(prefix + k).value = v
                else:
                    self.gauge(prefix + k).set(v)

    def merge(self, other: "Registry"):
        """Sum ``other`` into this registry (counters/histograms add,
        gauges take the other's value) — the per-shard aggregation path."""
        for name, m in other._metrics.items():
            self._get(name, type(m)).merge(m)
        return self

    @staticmethod
    def aggregate(regs: Iterable["Registry"]) -> "Registry":
        out = Registry()
        for r in regs:
            out.merge(r)
        return out

    # -- export -----------------------------------------------------------

    def names(self):
        return sorted(self._metrics)

    def get(self, name: str):
        return self._metrics.get(name)

    def snapshot(self) -> dict:
        """Flat dict of every metric: counters/gauges as values,
        histograms as their standard row."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = m.snapshot()
            else:
                out[name] = m.value
        return out

    def histogram_rows(self, prefix: str = "") -> dict:
        """Just the histograms (optionally filtered by name prefix) — the
        rows benches stamp into their JSON artifacts."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())
                if isinstance(m, Histogram) and name.startswith(prefix)}
