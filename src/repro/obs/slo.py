"""Per-tick health snapshot + SLO monitor.

The frontend ticks this alongside the scrubber. Each evaluation assembles a
snapshot of the system's *recent* behavior — rolling read/write sojourn
percentiles, publish/flush byte rates, epoch limbo depth, health-state dwell
— and evaluates declarative ``SloRule``s against it, flagging violations
into the snapshot (and a cumulative counter) instead of raising: an SLO
breach is an observation, not an exception.

Rolling percentiles come from the same cumulative histograms the registry
already holds: the monitor snapshots each watched histogram's bucket counts
at window rotation and evaluates on the *diff* — recent ops only, no second
recording path, no extra hot-path cost. Rates are cumulative-counter diffs
over the rotation's wall-time. When a window saw no ops the previous full
window's result is served, so the snapshot never flaps to NaN between
batches.
"""
from __future__ import annotations

import math
import time
from typing import Dict, Optional

import numpy as np

from .registry import Counter, Histogram, Registry

__all__ = ["SloRule", "SloMonitor"]


class SloRule:
    """Declarative bound on one snapshot field.

    ``field`` is a dotted path into the snapshot ("read_sojourn.p99_s",
    "rates.flush_bytes_per_s", "limbo_depth"). A rule with ``max`` fires
    when the value exceeds it; with ``min`` when the value falls below.
    Missing/NaN fields never fire (no data is not a violation)."""

    __slots__ = ("name", "field", "max", "min")

    def __init__(self, name: str, field: str, max: Optional[float] = None,
                 min: Optional[float] = None):
        assert max is not None or min is not None, f"rule {name}: no bound"
        self.name = name
        self.field = field
        self.max = max
        self.min = min

    def check(self, snapshot: dict) -> Optional[dict]:
        v = snapshot
        for part in self.field.split("."):
            if not isinstance(v, dict) or part not in v:
                return None
            v = v[part]
        if not isinstance(v, (int, float)) or (isinstance(v, float)
                                               and math.isnan(v)):
            return None
        if self.max is not None and v > self.max:
            return {"rule": self.name, "field": self.field, "value": v,
                    "bound": self.max, "kind": "max"}
        if self.min is not None and v < self.min:
            return {"rule": self.name, "field": self.field, "value": v,
                    "bound": self.min, "kind": "min"}
        return None


class _Window:
    """Rotation state for one watched histogram: counts snapshot at the
    last rotation + the last non-empty windowed result."""

    __slots__ = ("hist", "base", "last")

    def __init__(self, hist: Histogram):
        self.hist = hist
        self.base = hist.counts.copy()
        self.last: dict = {}

    def rotate(self) -> dict:
        delta = self.hist.counts - self.base
        n = int(delta.sum())
        if n > 0:
            self.last = {"n": n,
                         "p50_s": self.hist.percentile(50, delta),
                         "p90_s": self.hist.percentile(90, delta),
                         "p99_s": self.hist.percentile(99, delta)}
            self.base = self.hist.counts.copy()
        return dict(self.last)


class _Rate:
    """Rotation state for one watched counter → per-second rate."""

    __slots__ = ("counter", "base", "last")

    def __init__(self, counter: Counter):
        self.counter = counter
        self.base = counter.value
        self.last = 0.0

    def rotate(self, dt: float) -> float:
        if dt > 0:
            self.last = (self.counter.value - self.base) / dt
            self.base = self.counter.value
        return self.last


class SloMonitor:
    """Ticked by the frontend; evaluates every ``eval_interval`` ticks.

    ``tick(extra)`` is O(1) between evaluations (a counter bump); an
    evaluation rotates the watched windows, assembles the snapshot, and
    runs the rules. ``extra`` carries per-tick facts the registry doesn't
    own (health string, limbo depth)."""

    def __init__(self, registry: Registry, rules=(), eval_interval: int = 64,
                 clock=time.perf_counter):
        self.registry = registry
        self.rules = list(rules)
        self.eval_interval = max(1, int(eval_interval))
        self.clock = clock
        self._windows: Dict[str, _Window] = {}
        self._rates: Dict[str, _Rate] = {}
        self._ticks = 0
        self._evals = 0
        self._last_eval_t = clock()
        self._snapshot: dict = {"tick": 0, "evals": 0, "violations": []}
        self.violation_count = 0
        # health dwell accounting: state -> cumulative seconds
        self._health = None
        self._health_since = clock()
        self._dwell: Dict[str, float] = {}

    # -- configuration ----------------------------------------------------

    def watch_histogram(self, alias: str, hist: Histogram):
        self._windows[alias] = _Window(hist)

    def watch_rate(self, alias: str, counter: Counter):
        self._rates[alias] = _Rate(counter)

    def add_rule(self, rule: SloRule):
        self.rules.append(rule)

    # -- ticking ----------------------------------------------------------

    def note_health(self, state: str, now: Optional[float] = None):
        """Called on every health transition (and lazily at eval) to keep
        per-state dwell-time accounting."""
        if now is None:
            now = self.clock()
        if self._health is not None:
            self._dwell[self._health] = (self._dwell.get(self._health, 0.0)
                                         + now - self._health_since)
        self._health = state
        self._health_since = now

    def tick(self, extra=None) -> Optional[dict]:
        """Cheap per-tick entry point (one counter bump between
        evaluations); returns the new snapshot on evaluation ticks, None
        otherwise. ``extra`` may be a dict or a zero-arg callable — a
        callable is only invoked on evaluation ticks, so the frontend's
        per-tick cost stays flat."""
        self._ticks += 1
        if self._ticks % self.eval_interval:
            return None
        return self.evaluate(extra() if callable(extra) else extra)

    def evaluate(self, extra: Optional[dict] = None) -> dict:
        now = self.clock()
        dt = now - self._last_eval_t
        self._last_eval_t = now
        self._evals += 1
        extra = extra or {}
        health = extra.get("health")
        if health is not None and health != self._health:
            self.note_health(health, now)
        elif health is None:
            health = self._health     # transitions noted out-of-band count too
        snap: dict = {"tick": self._ticks, "evals": self._evals,
                      "window_s": dt}
        if health is not None:
            snap["health"] = health
            snap["health_dwell_s"] = {
                **self._dwell,
                **({self._health: self._dwell.get(self._health, 0.0)
                    + now - self._health_since}
                   if self._health is not None else {})}
        for k, v in extra.items():
            if k != "health":
                snap[k] = v
        for alias, win in self._windows.items():
            snap[alias] = win.rotate()
        if self._rates:
            snap["rates"] = {alias: r.rotate(dt)
                             for alias, r in self._rates.items()}
        violations = []
        for rule in self.rules:
            hit = rule.check(snap)
            if hit is not None:
                violations.append(hit)
        snap["violations"] = violations
        self.violation_count += len(violations)
        snap["violation_count"] = self.violation_count
        self._snapshot = snap
        return snap

    def snapshot(self) -> dict:
        """Last evaluated snapshot (evaluates once if none yet)."""
        if self._evals == 0:
            return self.evaluate()
        return dict(self._snapshot)
