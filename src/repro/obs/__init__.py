"""Unified observability layer: metrics registry, op-lifecycle tracing,
SLO monitoring.

``Observability`` is the per-frontend bundle the serving/persistence stack
threads through itself: one ``Registry`` (counters/gauges/histograms — the
substrate behind every ``stats()`` dict and ``BENCH_*.json`` histogram row),
one ``Tracer`` (enqueue→batch-form→dispatch→publish→flush→ack spans; off by
default, enabled explicitly or via ``REPRO_TRACE=1``), and one ``SloMonitor``
the frontend ticks alongside the scrubber.

``now()`` is the one clock helper every op timestamp goes through —
``enqueue_t``/``done_t`` stamping, span timing, and SLO window rotation all
share it, so sojourn histograms and bench percentiles are measuring the
same thing.
"""
from __future__ import annotations

import os
import time

from .registry import Counter, Gauge, Histogram, Registry
from .slo import SloMonitor, SloRule
from .trace import Span, Tracer, export_chrome_trace

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "SloMonitor",
           "SloRule", "Span", "Tracer", "export_chrome_trace",
           "Observability", "now", "trace_enabled_from_env"]

#: the single op-timestamp clock (satellite: sojourn-timing unification)
now = time.perf_counter


def trace_enabled_from_env() -> bool:
    return os.environ.get("REPRO_TRACE", "") not in ("", "0")


class Observability:
    """Registry + tracer + SLO monitor for one frontend (or shard).

    ``trace=None`` defers to ``REPRO_TRACE`` so benches and CI can turn
    span capture on without plumbing a flag through every constructor."""

    def __init__(self, trace=None, trace_capacity: int = 1 << 16,
                 slo_rules=(), slo_interval: int = 64):
        self.registry = Registry()
        if trace is None:
            trace = trace_enabled_from_env()
        self.tracer = Tracer(enabled=bool(trace), capacity=trace_capacity,
                             clock=now)
        self.slo = SloMonitor(self.registry, rules=slo_rules,
                              eval_interval=slo_interval, clock=now)
        self.clock = now

    def now(self) -> float:
        return self.clock()

    def snapshot(self) -> dict:
        """Registry snapshot + last SLO snapshot + tracer stats — the
        export surface for ``obs_snapshot()`` / bench artifacts."""
        return {"metrics": self.registry.snapshot(),
                "slo": self.slo.snapshot(),
                "trace": self.tracer.stats()}
