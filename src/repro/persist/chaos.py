"""Randomized chaos schedules over the durable table (ISSUE 6 tentpole).

``run_schedule`` drives one seeded fault schedule: an SMO-heavy workload
(small segments force split storms) against one durable pool while a
``FaultPlan`` tears fences, flips persisted bits, injects transient EIO
bursts and ENOSPC — then checks the safety property the whole PR exists
to enforce:

    every acknowledged key is served with an acknowledged value, or its
    loss is EXPLICITLY reported (quarantined rows / log-loss) — never a
    silent wrong read, never a silent disappearance.

Acknowledged means ``table.flush()`` returned: the model snapshots the
key->value map at every successful flush (``committed``) and tracks the
live map (``live``) between flushes. At every reopen (torn crash or clean
restart) the harness searches every key it ever wrote and classifies each
outcome against ``{committed, live}``:

  - committed-stable key (no op since the last ack) served with any OTHER
    value            -> ``wrong_reads``  (hard failure)
  - committed-stable key absent with no quarantined row among its
    reachable slots (home probe window + stash of its current segment)
    and no log loss  -> ``silent_lost`` (hard failure)
  - in-flight key (insert/update/delete between ack and crash) may
    resolve to either side of the ack boundary; anything else counts in
    ``indeterminate_pending`` (reported, not a failure: un-acked writes
    carry no durability contract — README 'Fault model').

Determinism: the schedule derives entirely from ``seed`` (workload rng and
``FaultPlan`` share it), so a failing seed replays exactly.

Shapes are kept uniform (fixed insert batch, fixed padded search chunks)
so jit caches carry across the hundreds of schedules the chaos bench and
CI smoke run.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import numpy as np

from repro.core import hashing
from repro.core.layout import DashConfig
from repro.core.table import TableFullError
from repro import persist
from repro.persist.faults import FaultPlan
from repro.persist.pool import FlushError, PoolError
from repro.persist.writeback import Scrubber, SimulatedCrash, \
    WritebackDegraded

#: Small segments + shallow directory: a few hundred inserts drive real
#: split storms, so fault windows overlap SMOs (the hard case).
CHAOS_CFG = DashConfig(max_segments=16, dir_depth_max=8, num_buckets=16,
                       num_slots=8)

_SEARCH_CHUNK = 256


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one seeded schedule. ``wrong_reads`` and ``silent_lost``
    are the safety gates — any nonzero value is a correctness bug (the
    chaos tests assert 0)."""
    seed: int
    ops: int = 0
    flushes: int = 0
    crashes: int = 0             # torn-persist reopens
    clean_restarts: int = 0
    tears: int = 0
    flips: int = 0
    eio_raised: int = 0
    enospc_raised: int = 0
    degraded_events: int = 0
    recoveries: int = 0
    reported_lost: int = 0       # acked keys lost WITH a quarantine report
    wrong_reads: int = 0         # MUST be 0
    silent_lost: int = 0         # MUST be 0
    indeterminate_pending: int = 0
    scrub_repaired: int = 0
    log_lost_events: int = 0
    pointer_mode: bool = False
    table_full: bool = False


def _words_of(keys, w: int) -> np.ndarray:
    """Deterministic u64-key -> (n, W) word embedding for pointer-mode
    schedules (bijective, so the harness's integer key model carries)."""
    keys = np.asarray(keys, np.uint64)
    out = np.zeros((keys.size, w), np.uint32)
    out[:, 0] = (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    if w > 1:
        out[:, 1] = (keys >> np.uint64(32)).astype(np.uint32)
    return out


def _op(table, name, keys, vals=None):
    """Dispatch insert/update/delete/search through either key surface."""
    cfg = table.cfg
    kw = ({"words": _words_of(keys, cfg.key_heap_words)}
          if cfg.pointer_mode else {"keys": np.asarray(keys, np.uint64)})
    if vals is not None:
        kw["values"] = vals
    return getattr(table, name)(**kw)


def _search_all(table, keys):
    """Fixed-chunk padded search (uniform shapes -> one jit cache entry)."""
    keys = np.asarray(keys, np.uint64)
    found = np.zeros(keys.size, bool)
    vals = np.zeros(keys.size, np.uint32)
    for lo in range(0, keys.size, _SEARCH_CHUNK):
        chunk = keys[lo:lo + _SEARCH_CHUNK]
        pad = _SEARCH_CHUNK - chunk.size
        if pad:
            chunk = np.concatenate([chunk, np.full(pad, chunk[0], np.uint64)])
        f, v = _op(table, "search", chunk)
        found[lo:lo + _SEARCH_CHUNK - pad] = f[:_SEARCH_CHUNK - pad]
        vals[lo:lo + _SEARCH_CHUNK - pad] = v[:_SEARCH_CHUNK - pad]
    return found, vals


def _reported_lost(cfg, state, report, key) -> bool:
    """True iff a quarantined bt row sits among the slots ``key`` could
    legally occupy — its current segment's home probe window or stash.
    That is exactly the reachable set of the search path, so a quarantine
    hit there explains an absence; one elsewhere does not."""
    if not report:
        return False
    if any(r.get("overflow") for r in report):
        return True     # per-row evidence capped out; any loss is covered
    if cfg.pointer_mode:
        w = _words_of(np.array([key], np.uint64), cfg.key_heap_words)
        hi = hashing.np_fold_words(w, hashing.FOLD_SEED_HI)
        lo = hashing.np_fold_words(w, hashing.FOLD_SEED_LO)
    else:
        hi, lo = hashing.np_split_keys(np.array([key], np.uint64))
    h1 = hashing.np_hash1(hi, lo)
    d = int(h1[0] >> np.uint32(32 - cfg.dir_depth_max))
    seg = int(np.asarray(state.dir)[d])
    nb = cfg.num_buckets
    b = int(h1[0] & np.uint32(nb - 1))
    cand = {(b + w) & (nb - 1) for w in range(cfg.probe_window)}
    cand |= set(range(nb, nb + cfg.num_stash))
    return any(r["plane"] == "bt" and r["seg"] == seg and r["bucket"] in cand
               for r in report)


def _classify(table, info, committed, live, res, cfg):
    """Post-reopen audit: search every tracked key, enforce the safety
    property, and return the observed map (the new committed AND live —
    the reopen's internal healing flush made the served state durable)."""
    report = getattr(table, "lost_report", [])
    log_lost = bool(info.get("log_lost", False))
    if log_lost:
        res.log_lost_events += 1
    keys = sorted(set(committed) | set(live))
    if not keys:
        return {}
    found, vals = _search_all(table, keys)
    observed = {}
    for i, k in enumerate(keys):
        c, l = committed.get(k), live.get(k)
        if found[i]:
            observed[k] = int(vals[i])
        stable = c is not None and c == l
        if stable:
            if found[i] and int(vals[i]) != c:
                res.wrong_reads += 1
            elif not found[i]:
                if _reported_lost(cfg, table.state, report, k):
                    res.reported_lost += 1
                else:
                    res.silent_lost += 1
        else:
            # in-flight across the ack boundary: either side may surface
            allowed = {v for v in (c, l) if v is not None}
            if found[i] and int(vals[i]) not in allowed:
                res.indeterminate_pending += 1
            elif not found[i] and c is not None and l is not None \
                    and not log_lost \
                    and not _reported_lost(cfg, table.state, report, k):
                # an in-flight UPDATE should not vanish the key outright
                res.indeterminate_pending += 1
    return observed


def _restart(path, plan, res, committed, live, cfg, torn: bool):
    """Reopen (retrying through tears/EIO hitting the healing flush) and
    audit. Returns (table, committed', live') — identical maps: everything
    the audit observed is durable again."""
    res.crashes += 1 if torn else 0
    if not torn:
        res.clean_restarts += 1
    table = info = None
    for _ in range(16):
        try:
            table, info = persist.reopen(path, faults=plan)
            break
        except SimulatedCrash:
            res.crashes += 1        # tear landed inside the healing flush
        except (WritebackDegraded, FlushError):
            continue                # burst drains across attempts
    assert table is not None, f"seed {res.seed}: reopen never converged"
    observed = _classify(table, info, committed, live, res, cfg)
    return table, dict(observed), dict(observed)


def run_schedule(seed: int, tmpdir: str, cfg: DashConfig = CHAOS_CFG,
                 n_batches: int = 8, batch: int = 48,
                 min_tears: int = 0, min_flips: int = 0,
                 scrub: bool = True, allow_pointer_mode: bool = True,
                 p_tear: float = 0.30, p_eio: float = 0.20,
                 p_flip: float = 0.35, p_clean_restart: float = 0.15
                 ) -> ScheduleResult:
    """Run ONE seeded chaos schedule; raises AssertionError on any safety
    violation and returns the counters otherwise."""
    rng = np.random.default_rng(np.uint64(seed) ^ np.uint64(0xC8A05))
    res = ScheduleResult(seed=seed)
    plan = FaultPlan(seed=seed)
    if allow_pointer_mode and rng.random() < 0.25:
        cfg = dataclasses.replace(cfg, pointer_mode=True,
                                  key_heap_size=4096, key_heap_words=2)
        res.pointer_mode = True
    path = os.path.join(tmpdir, f"chaos_{seed}.pool")

    # ENOSPC rehearsal on ~1/4 of seeds: the failed create must clean up
    # and a retry on the same path must succeed.
    if rng.random() < 0.25:
        plan.enospc_creates = 1
        try:
            persist.create(path, cfg, faults=plan)
            raise AssertionError("injected ENOSPC did not surface")
        except PoolError:
            assert not os.path.exists(path), "partial pool file left behind"

    table = persist.create(path, cfg, faults=plan)
    scrubber = Scrubber(table.writeback, rows_per_tick=256) if scrub else None
    committed: Dict[int, int] = {}
    live: Dict[int, int] = {}
    next_key = 1
    tears_armed = flips_done = 0

    for bi in range(n_batches):
        # -- arm this round's faults (relative to the live fence clock) ---
        want_tear = tears_armed < min_tears or rng.random() < p_tear
        want_eio = not want_tear and rng.random() < p_eio
        if want_tear:
            idx = plan.fence_calls + int(rng.integers(0, 12))
            plan.torn_fences = frozenset(set(plan.torn_fences) | {idx})
            tears_armed += 1
        elif want_eio:
            idx = plan.fence_calls + int(rng.integers(0, 6))
            plan.eio_fences[idx] = int(rng.choice([2, 8]))
        if flips_done < min_flips or rng.random() < p_flip:
            n = int(rng.integers(1, 4))
            plan.flip_bits(table.writeback.pool, n=n)
            flips_done += n

        # -- mutate: fresh inserts + updates/deletes of committed keys ----
        if not res.table_full:
            ins = np.arange(next_key, next_key + batch, dtype=np.uint64)
            next_key += batch
            vals = ((ins % np.uint64(2**31 - 1)) + np.uint64(1)
                    ).astype(np.uint32)
            try:
                _op(table, "insert", ins, vals)
                live.update(zip(ins.tolist(), vals.tolist()))
                res.ops += batch
            except TableFullError:
                res.table_full = True
        pool_keys = list(committed)
        if len(pool_keys) >= 8:
            pick = rng.choice(len(pool_keys), size=8, replace=False)
            upd = np.array([pool_keys[i] for i in pick[:4]], np.uint64)
            dele = np.array([pool_keys[i] for i in pick[4:]], np.uint64)
            nv = (np.asarray(upd % np.uint64(997), np.uint32)
                  + np.uint32(bi + 2))
            _op(table, "update", upd, nv)
            live.update(zip(upd.tolist(), nv.tolist()))
            _op(table, "delete", dele)
            for k in dele.tolist():
                live.pop(k, None)
            res.ops += 8

        # -- flush = acknowledgment point ---------------------------------
        try:
            table.flush()
            res.flushes += 1
            committed = dict(live)
        except SimulatedCrash:
            table, committed, live = _restart(
                path, plan, res, committed, live, cfg, torn=True)
            scrubber = (Scrubber(table.writeback, rows_per_tick=256)
                        if scrub else None)
            continue
        except WritebackDegraded:
            res.degraded_events += 1
            # degraded-mode serving: live keys still read back volatile
            probe = list(live)[:32]
            if probe:
                f, v = _search_all(table, probe)
                assert f.all(), "degraded table stopped serving live keys"
            recovered = False
            for _ in range(12):
                try:
                    if table.writeback.try_recover(table.state):
                        recovered = True
                        break
                except SimulatedCrash:
                    break
            if table.writeback.dead:
                table, committed, live = _restart(
                    path, plan, res, committed, live, cfg, torn=True)
                scrubber = (Scrubber(table.writeback, rows_per_tick=256)
                            if scrub else None)
                continue
            if recovered:
                res.recoveries += 1
                committed = dict(live)
            continue

        # -- background scrub + occasional clean restart ------------------
        if scrubber is not None and rng.random() < 0.5:
            try:
                scrubber.tick(table.state)
            except SimulatedCrash:
                table, committed, live = _restart(
                    path, plan, res, committed, live, cfg, torn=True)
                scrubber = Scrubber(table.writeback, rows_per_tick=256)
                continue
        if rng.random() < p_clean_restart:
            closed_ok = True
            try:
                table.close()
            except (SimulatedCrash, WritebackDegraded, FlushError):
                closed_ok = False     # fall through: reopen audits either way
            table, committed, live = _restart(
                path, plan, res, committed, live, cfg, torn=not closed_ok)
            scrubber = (Scrubber(table.writeback, rows_per_tick=256)
                        if scrub else None)

    # -- final verdict: force one last crash-free audit -----------------------
    try:
        table.close()
    except (SimulatedCrash, WritebackDegraded, FlushError):
        pass
    plan.torn_fences = frozenset()    # the audit itself must not tear
    plan.eio_fences.clear()
    table, committed, live = _restart(
        path, plan, res, committed, live, cfg, torn=False)
    bad = table.writeback.pool.verify_checksums()
    assert bad["bt"].size == 0 and bad["nb"].size == 0, \
        f"seed {seed}: reopen left unhealed checksums"
    table.close()
    os.unlink(path)

    res.tears = plan.tears
    res.flips = plan.flips
    res.eio_raised = plan.eio_raised
    res.enospc_raised = plan.enospc_raised
    if scrubber is not None:
        res.scrub_repaired = scrubber.repaired_rows
    assert res.wrong_reads == 0, \
        f"seed {seed}: {res.wrong_reads} SILENT WRONG READS"
    assert res.silent_lost == 0, \
        f"seed {seed}: {res.silent_lost} acked keys silently lost"
    return res


def run_many(seeds, tmpdir: str, **kw) -> dict:
    """Aggregate a batch of schedules (the chaos bench / CI smoke driver)."""
    agg: Dict[str, int] = {}
    results = []
    for s in seeds:
        r = run_schedule(int(s), tmpdir, **kw)
        results.append(r)
        for f in dataclasses.fields(ScheduleResult):
            v = getattr(r, f.name)
            if isinstance(v, bool):
                v = int(v)
            if f.name != "seed":
                agg[f.name] = agg.get(f.name, 0) + int(v)
    agg["schedules"] = len(results)
    return agg
