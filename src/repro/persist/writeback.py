"""O(dirty) flush-on-publish: ordered dirty-plane writeback into the PM pool.

``WritebackEngine.flush(state, hint)`` makes the live table state durable in
bytes proportional to what changed since the last flush — the durable
rendering of PR 4's O(dirty) COW publish. The dirty ground truth is the same:
every plane mutation bumps its bucket's version word (core/bucket.py), so the
diff of the live version plane against the POOL's version plane is a complete
change record; the host ``DirtyTracker`` hint is audited against it
(``flush_hint_misses``) and carries the force-full escape for paths outside
the version discipline (crash simulation, pointer mode).

**Crash consistency.** Every dirty bucket row is classified against the
pool's current contents:

  * **append** — the row only gains records; every slot the pool's meta word
    claims keeps its exact key/fingerprint bytes. Normal inserts and
    displacement destinations.
  * **clear**  — the row loses alloc bits but surviving slots keep their
    bytes. Deletes and displacement sources.
  * **rebuilt** — some pool-allocated slot's key/fp bytes CHANGED: the
    vectorized SMO rebuild (split source, merge keep, cleared merge victim)
    relaid the segment. No store order makes an in-place rewrite of such a
    row crash-atomic — old meta claims slots whose bytes a partial write
    already scrambled — so rebuilt rows are staged through the pool's redo
    log instead (PMDK's allocate-activate discipline, scoped to exactly the
    rows that need it; in-place value updates stay in place — a torn value
    is an in-flight op's indeterminacy, not a lost key).

Stores are then ordered into fenced phases; a crash at ANY inter-store point
leaves a pool in which every previously-acknowledged key is reachable (an op
is acknowledged durable only after its flush's commit fence — in-flight ops
of a torn flush may land partially, exactly like in-flight stores on PM):

  1. append+clear rows: data planes (key/value/fp/ofp). New bytes land only
     in slots the pool's meta words consider free — invisible until
     published (the paper's record-then-CLWB-the-meta-word order, Alg. 2).
  2. append rows: meta/ometa/version. Records become visible; nothing
     becomes unreachable.
  3. routing (directory, per-segment metadata, scalars incl. the LH
     level/next word and the watermark) — in place ONLY when no rebuilt
     rows exist this flush (a torn directory then mixes old/new 4-byte
     entries, each routing to an intact segment); with rebuilt rows the
     routing planes ride in the redo log so they flip together with the
     rebuilt segments.
  4. clear rows: meta/ometa/version. Only now can a record leave a row —
     its displacement copy (if any) was published in phase 2. Acked deletes
     of previous flushes stay deleted; this flush's deletes are unacked
     until commit either way.
  5. redo log: rebuilt rows (+ routing planes when any), one staged write.
  6. commit — the superblock slot (flush_seq, clean marker, V, log
     descriptor + CRC), fenced: the acknowledgment point.
  7. apply the log to the home rows, fence. A crash inside the apply is
     repaired at the next open: a committed log is re-applied idempotently
     (absolute row contents).

The emulated store granularity is one plane scatter between fences (a clwb
train); ``inject_crash(after_ops)`` kills the engine after that many stores,
which is what the crash-matrix test sweeps every cut point of. Per-store
tearing WITHIN one scatter (real PM's finer failure model) is out of the
emulation's store model — Dash's per-record fence protocol collapses into
the phase ordering here.

Recovery after a torn flush needs nothing new: the pool's superblock says
dirty, reopen bumps V, and the existing per-segment lazy recovery
(core/recovery.py) clears locks, dedupes the half-displaced records phases
2/4 can leave behind, and rebuilds the overflow metadata.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core import layout
from repro.core.epoch import DirtyHint
from repro.core.layout import DashState

from .pool import PmPool

#: phase-1 record planes, in flush order (keys/values before anything that
#: could publish them)
DATA_BT = ("fp", "key_hi", "key_lo", "val")
#: publish planes: the meta word is the visibility point; version is the
#: dirty-diff ground truth and lands LAST so a torn row is re-flushed
PUBLISH_BT = ("meta", "version")


class SimulatedCrash(RuntimeError):
    """Raised when an injected crash point is reached mid-flush; the engine
    is dead afterwards (the process 'died' — reopen the pool to continue)."""


def _slot_bits(meta_rows: np.ndarray, num_slots: int) -> np.ndarray:
    """(n, num_slots) bool alloc matrix from packed meta words."""
    alloc = layout.meta_alloc(meta_rows.astype(np.uint32))
    return (alloc[:, None] >> np.arange(num_slots, dtype=np.uint32)) & 1 == 1


class WritebackEngine:
    """Flush-on-publish engine bound to one ``PmPool``.

    Counters (the bench/test observability surface): ``flushes``,
    ``flushed_bytes`` / ``last_flush_bytes`` (bytes actually written,
    including the doubled cost of logged rebuilt rows), ``flushed_rows``,
    ``logged_rows``, ``flush_seconds``, ``flush_hint_misses`` (device-dirty
    segments the host tracker failed to report — should stay 0), and the
    pool's ``fences``.
    """

    def __init__(self, pool: PmPool):
        self.pool = pool
        self.cfg = pool.cfg
        self.mode = pool.mode
        self.flushes = 0
        self.flushed_bytes = 0
        self.last_flush_bytes = 0
        self.last_flush_rows = 0      # per-plane row writes of the last flush
        self.last_dirty_rows = 0      # distinct dirty bucket rows last flush
        self.flushed_rows = 0
        self.logged_rows = 0
        self.flush_seconds = 0.0
        self.flush_hint_misses = 0
        self._ops_budget: Optional[int] = None
        self.dead = False

    # -- crash injection ---------------------------------------------------

    def inject_crash(self, after_ops: int):
        """Die (raise ``SimulatedCrash``) after ``after_ops`` further
        emulated stores; 0 dies before the next store lands."""
        self._ops_budget = int(after_ops)

    def _store(self):
        """One emulated store op is about to land; the crash point sits
        BEFORE it (the op that would exceed the budget never lands)."""
        if self._ops_budget is not None:
            if self._ops_budget <= 0:
                self.dead = True
                raise SimulatedCrash("injected crash mid-flush")
            self._ops_budget -= 1

    def _account(self, nbytes: int, rows: int = 0):
        self.flushed_bytes += nbytes
        self.last_flush_bytes += nbytes
        self.flushed_rows += rows
        self.last_flush_rows += rows

    def _write_rows(self, name: str, ids: np.ndarray, live: np.ndarray):
        if ids.size == 0:
            return
        self._store()
        self._account(self.pool.write_rows(name, ids, live), ids.size)

    def _write_plane(self, name: str, live: np.ndarray):
        self._store()
        self._account(self.pool.write_plane(name, live))

    # -- the flush ---------------------------------------------------------

    def flush(self, state: DashState, hint: Optional[DirtyHint] = None) -> int:
        """Write every dirty plane of ``state`` to the pool in the fenced
        phase order above; returns bytes written. O(dirty) I/O: row-granular
        for the record planes (version diff vs the pool), compare-then-copy
        for directory/segment metadata, always-copy for scalars."""
        if self.dead:
            raise SimulatedCrash("writeback engine died in a previous flush")
        t0 = time.perf_counter()
        self.last_flush_bytes = 0
        self.last_flush_rows = 0
        cfg = self.cfg
        NB, BT, SL = cfg.num_buckets, cfg.buckets_total, cfg.num_slots

        live = {n: np.asarray(getattr(state, n)) for n in DashState._fields}
        full = (self.pool.sb.flush_seq == 0 or cfg.pointer_mode
                or (hint is not None and hint.full))

        # dirty rows = version-plane diff against the pool (the durable
        # mirror of engine.changed_rows); force-full writes every row
        disk_ver = self.pool.rows("version").reshape(-1)
        live_ver = live["version"].reshape(-1)
        if full:
            ids_bt = np.arange(live_ver.size, dtype=np.int64)
        else:
            ids_bt = np.flatnonzero(disk_ver != live_ver).astype(np.int64)
        seg_of = ids_bt // BT
        b_of = ids_bt % BT
        nb_mask = b_of < NB
        ids_nb = (seg_of * NB + b_of)[nb_mask]
        self.last_dirty_rows = int(ids_bt.size)

        if hint is not None and not full and ids_bt.size:
            seen = set(np.unique(seg_of).tolist())
            self.flush_hint_misses += len(seen - hint.segments)

        rowview = {n: live[n].reshape(self.pool.spec(n).rows, -1)
                   for n in DATA_BT + PUBLISH_BT + layout.NB_PLANES}

        # -- classification vs the pool's current contents -----------------
        disk_bits = _slot_bits(self.pool.rows("meta").reshape(-1)[ids_bt], SL)
        live_bits = _slot_bits(live["meta"].reshape(-1)[ids_bt], SL)
        changed = np.zeros_like(disk_bits)
        for n in ("key_hi", "key_lo"):
            changed |= (self.pool.rows(n)[ids_bt]
                        != live[n].reshape(-1, SL)[ids_bt])
        # fp rows are lane-padded to 16; compare the record slots only
        changed |= (self.pool.rows("fp")[ids_bt][:, :SL]
                    != live["fp"].reshape(-1, 16)[ids_bt][:, :SL])
        # any POOL-allocated slot with changed key/fp bytes forces the log:
        # an in-place data store there would scramble a visible record even
        # if the live row no longer keeps that slot
        rebuilt = (disk_bits & changed).any(axis=1)
        loses = (disk_bits & ~live_bits).any(axis=1)
        a_bt = ids_bt[~rebuilt & ~loses]        # append rows
        c_bt = ids_bt[~rebuilt & loses]         # clear rows
        r_bt = ids_bt[rebuilt]                  # rebuilt rows -> redo log
        a_nb = ids_nb[(~rebuilt & ~loses)[nb_mask]]
        c_nb = ids_nb[(~rebuilt & loses)[nb_mask]]
        r_nb = ids_nb[rebuilt[nb_mask]]

        log_routing = r_bt.size > 0
        routing_dirty = not log_routing and (full or any(
            not np.array_equal(self.pool.plane(n), live[n])
            for n in layout.DIR_PLANES + layout.SEG_META_PLANES))

        # phase 1: data planes of the in-place rows (new bytes land only in
        # pool-free slots — invisible until a publish word flips)
        ip_bt = np.concatenate([a_bt, c_bt])
        ip_nb = np.concatenate([a_nb, c_nb])
        for n in DATA_BT:
            self._write_rows(n, ip_bt, rowview[n])
        self._write_rows("ofp", ip_nb, rowview["ofp"])
        self.pool.fence()

        # phase 2: publish the append rows
        self._write_rows("meta", a_bt, rowview["meta"])
        self._write_rows("ometa", a_nb, rowview["ometa"])
        self._write_rows("version", a_bt, rowview["version"])
        self.pool.fence()

        # phase 3: routing + per-segment metadata + scalars, in place only
        # when no rebuilt rows ride this flush (else they go via the log)
        if not log_routing:
            if routing_dirty:
                for n in layout.DIR_PLANES + layout.SEG_META_PLANES:
                    if full or not np.array_equal(self.pool.plane(n), live[n]):
                        self._write_plane(n, live[n])
            for n in layout.SCALAR_PLANES:
                self._write_plane(n, live[n])
            self.pool.fence()

        # phase 4: clear rows — records may leave, their displacement copies
        # (if any) are already published
        self._write_rows("meta", c_bt, rowview["meta"])
        self._write_rows("ometa", c_nb, rowview["ometa"])
        self._write_rows("version", c_bt, rowview["version"])
        self.pool.fence()

        # phase 5: stage rebuilt rows (+ routing) in the redo log
        log_bt = log_nb = 0
        log_crc = 0
        if log_routing:
            self._store()
            nbytes, log_crc = self.pool.write_log(r_bt, r_nb, True, live)
            self._account(nbytes, r_bt.size)
            self.logged_rows += int(r_bt.size)
            log_bt, log_nb = int(r_bt.size), int(r_nb.size)
            self.pool.fence()

        # phase 6: commit record (acknowledgment point)
        self._store()
        self.pool.commit(gver=int(live["gver"]), clean=bool(live["clean"]),
                         log_bt=log_bt, log_nb=log_nb,
                         log_routing=log_routing, log_crc=log_crc)
        self.pool.fence()

        # phase 7: apply the committed log to the home rows (idempotent —
        # a crash inside the apply is redone at the next open)
        if log_routing:
            self._store()
            self._account(self.pool.apply_log())
            self.pool.fence()

        self.flushes += 1
        self.flush_seconds += time.perf_counter() - t0
        return self.last_flush_bytes

    def stats(self) -> dict:
        return {
            "flushes": self.flushes,
            "flushed_bytes": self.flushed_bytes,
            "last_flush_bytes": self.last_flush_bytes,
            "flushed_rows": self.flushed_rows,
            "last_dirty_rows": self.last_dirty_rows,
            "logged_rows": self.logged_rows,
            "flush_seconds": self.flush_seconds,
            "flush_hint_misses": self.flush_hint_misses,
            "fences": self.pool.fences,
            "pool_bytes": self.pool.plane_bytes,
            "flush_seq": self.pool.sb.flush_seq,
        }
