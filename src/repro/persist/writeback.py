"""O(dirty) flush-on-publish: ordered dirty-plane writeback into the PM pool.

``WritebackEngine.flush(state, hint)`` makes the live table state durable in
bytes proportional to what changed since the last flush — the durable
rendering of PR 4's O(dirty) COW publish. The dirty ground truth is the same:
every plane mutation bumps its bucket's version word (core/bucket.py), so the
diff of the live version plane against the POOL's version plane is a complete
change record; the host ``DirtyTracker`` hint is audited against it
(``flush_hint_misses``) and carries the force-full escape for paths outside
the version discipline (crash simulation, degraded-mode resync). The
pointer-mode key heap carries no version words but is append-only, so its
tail above the pool's durable ``heap_top`` is the exact dirty set —
pointer-mode flushes are O(dirty rows + heap tail), not O(pool).

Host staging is O(dirty) too, not just the pool I/O: the wide record planes
(key/value/fingerprint/overflow-fingerprint — ~95% of the pool's bytes) are
never ``np.asarray``'d whole. Once the version diff names the dirty rows, a
jitted device gather (``_gather_rows``) pulls exactly those rows and only
they cross the host boundary, wrapped in row-indexable ``_GatheredRows``
proxies the phase writes and the redo-log encoder index like full planes.
Only the narrow planes (4-byte publish words, routing, scalars) are copied
whole; the pointer-mode heap is device-sliced at its tail. ``staged_bytes``
/ ``last_staged_bytes`` count every host-materialized byte — the
observability surface tests/test_persist.py's staged≈flushed assertion and
the durable-restart split-storm gate audit.

**Crash consistency.** Every dirty bucket row is classified against the
pool's current contents:

  * **append** — the row only gains records; every slot the pool's meta word
    claims keeps its exact key/fingerprint bytes. Normal inserts and
    displacement destinations.
  * **clear**  — the row loses alloc bits but surviving slots keep their
    bytes. Deletes and displacement sources.
  * **rebuilt** — some pool-allocated slot's key/fp bytes CHANGED: the
    vectorized SMO rebuild (split source, merge keep, cleared merge victim)
    relaid the segment. No store order makes an in-place rewrite of such a
    row crash-atomic — old meta claims slots whose bytes a partial write
    already scrambled — so rebuilt rows are staged through the pool's redo
    log instead (PMDK's allocate-activate discipline, scoped to exactly the
    rows that need it; in-place value updates stay in place — a torn value
    is an in-flight op's indeterminacy, not a lost key).

Stores are then ordered into fenced phases; a crash at ANY inter-store point
leaves a pool in which every previously-acknowledged key is reachable (an op
is acknowledged durable only after its flush's commit fence — in-flight ops
of a torn flush may land partially, exactly like in-flight stores on PM):

  1. append+clear rows: data planes (key/value/fp/ofp). New bytes land only
     in slots the pool's meta words consider free — invisible until
     published (the paper's record-then-CLWB-the-meta-word order, Alg. 2).
  2. append rows: meta/ometa/version. Records become visible; nothing
     becomes unreachable.
  3. routing (directory, per-segment metadata, scalars incl. the LH
     level/next word and the watermark) — in place ONLY when no rebuilt
     rows exist this flush (a torn directory then mixes old/new 4-byte
     entries, each routing to an intact segment); with rebuilt rows the
     routing planes ride in the redo log so they flip together with the
     rebuilt segments.
  4. clear rows: meta/ometa/version. Only now can a record leave a row —
     its displacement copy (if any) was published in phase 2. Acked deletes
     of previous flushes stay deleted; this flush's deletes are unacked
     until commit either way. In place ONLY when no rebuilt rows exist this
     flush: a moved record's destination may be a rebuilt row that lives
     solely in the (uncommitted) redo log, so with a log the clears join
     the logged set and land atomically with the commit instead.
  5. redo log: rebuilt + clear rows (+ routing planes when any), one
     staged write.
  6. commit — the superblock slot (flush_seq, clean marker, V, log
     descriptor + CRC), fenced: the acknowledgment point.
  7. apply the log to the home rows, fence. A crash inside the apply is
     repaired at the next open: a committed log is re-applied idempotently
     (absolute row contents).
  8. commit again with the log descriptor cleared (PR 6): the applied log
     is retired, so a descriptor seen at open always refers to live log
     bytes — a CRC mismatch there is media loss, never staleness.

The emulated store granularity is one plane scatter between fences (a clwb
train); ``inject_crash(after_ops)`` kills the engine after that many stores,
which is what the crash-matrix test sweeps every cut point of. Per-store
tearing WITHIN one scatter (real PM's finer failure model) is out of the
emulation's store model — Dash's per-record fence protocol collapses into
the phase ordering here.

Recovery after a torn flush needs nothing new: the pool's superblock says
dirty, reopen bumps V, and the existing per-segment lazy recovery
(core/recovery.py) clears locks, dedupes the half-displaced records phases
2/4 can leave behind, and rebuilds the overflow metadata.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.epoch import DirtyHint
from repro.core.layout import DashState

from .pool import FlushError, PmPool

#: phase-1 record planes, in flush order (keys/values before anything that
#: could publish them)
DATA_BT = ("fp", "key_hi", "key_lo", "val")
#: publish planes: the meta word is the visibility point; version is the
#: dirty-diff ground truth and lands LAST so a torn row is re-flushed
PUBLISH_BT = ("meta", "version")

#: big record planes (wide rows — ~95% of the pool's bytes): staged
#: host-side at DIRTY-ROW granularity via a device gather, never copied
#: whole. Everything else (4-byte-row publish planes, routing, scalars)
#: is copied whole per flush — a few percent of the pool.
GATHER_BT = DATA_BT
GATHER_NB = ("ofp",)


@jax.jit
def _gather_rows(planes, ids):
    """Device-side dirty-row gather: one take per (pre-reshaped) plane.
    ``ids`` is pow2-padded so the trace count stays bounded; pad lanes
    read row 0 and are sliced off host-side."""
    return tuple(jnp.take(p, ids, axis=0, mode="clip") for p in planes)


class _GatheredRows:
    """Row-indexable stand-in for a full host copy of one record plane:
    holds only the gathered dirty rows. Supports exactly the access
    patterns of the flush and of ``PmPool.write_rows`` / ``_encode_log``
    — fancy-index by any subset of the gathered ids, plus a
    shape-preserving ``reshape`` (the row-major layout is already the
    gathered one). Indexing an id that was not gathered is a staging
    bug, not a fallback — it asserts."""

    def __init__(self, ids: np.ndarray, rows: np.ndarray):
        self._ids = ids               # sorted (flatnonzero order)
        self._rows = rows             # (ids.size, row_elems)
        self.shape = rows.shape
        self.dtype = rows.dtype

    def __getitem__(self, ids):
        ids = np.asarray(ids)
        flat = ids.reshape(-1)
        pos = np.searchsorted(self._ids, flat)
        if flat.size:
            hit = np.minimum(pos, self._ids.size - 1)
            assert np.array_equal(self._ids[hit], flat), \
                "row indexed outside the gathered dirty set"
        return self._rows[pos].reshape(ids.shape + self._rows.shape[1:])

    def reshape(self, *shape):
        return self                   # rows are already row-major


class SimulatedCrash(RuntimeError):
    """Raised when an injected crash point is reached mid-flush; the engine
    is dead afterwards (the process 'died' — reopen the pool to continue)."""


class WritebackDegraded(RuntimeError):
    """A flush fence kept failing past the bounded retry budget: the engine
    is DEGRADED. The pool's durable image is the last committed flush
    (phases land between fences, so nothing half-acknowledged exists);
    serving must continue volatile. ``try_recover`` probes the device and,
    on success, resynchronizes with one force-full flush."""


def _slot_bits(meta_rows: np.ndarray, num_slots: int) -> np.ndarray:
    """(n, num_slots) bool alloc matrix from packed meta words."""
    alloc = layout.meta_alloc(meta_rows.astype(np.uint32))
    return (alloc[:, None] >> np.arange(num_slots, dtype=np.uint32)) & 1 == 1


class WritebackEngine:
    """Flush-on-publish engine bound to one ``PmPool``.

    Counters (the bench/test observability surface): ``flushes``,
    ``flushed_bytes`` / ``last_flush_bytes`` (bytes actually written,
    including the doubled cost of logged rebuilt rows), ``flushed_rows``,
    ``logged_rows``, ``flush_seconds``, ``flush_hint_misses`` (device-dirty
    segments the host tracker failed to report — should stay 0), and the
    pool's ``fences``.
    """

    def __init__(self, pool: PmPool, retry_limit: int = 4,
                 retry_base_s: float = 0.002):
        self.pool = pool
        self.cfg = pool.cfg
        self.mode = pool.mode
        self.retry_limit = retry_limit      # fence retries before DEGRADED
        self.retry_base_s = retry_base_s    # backoff base (doubles per retry)
        self.flushes = 0
        self.flushed_bytes = 0
        self.last_flush_bytes = 0
        self.staged_bytes = 0         # host bytes materialized from device
        self.last_staged_bytes = 0    # ... by the last flush (O(dirty) gate)
        self.last_flush_rows = 0      # per-plane row writes of the last flush
        self.last_dirty_rows = 0      # distinct dirty bucket rows last flush
        self.last_heap_tail_rows = 0  # pointer-mode heap rows of last flush
        self.flushed_rows = 0
        self.logged_rows = 0
        self.flush_seconds = 0.0
        self.flush_hint_misses = 0
        self.flush_io_errors = 0      # fence attempts that raised FlushError
        self.flush_retries = 0        # fences retried after a transient error
        self.degraded_flushes = 0     # flush calls refused while degraded
        self.recoveries = 0           # successful DEGRADED -> healthy returns
        self.degraded = False
        self._ops_budget: Optional[int] = None
        self.dead = False
        self.obs = None               # observability bundle (obs/), optional
        self.last_flush_sid = None    # span id of the last committed flush

    def attach_obs(self, obs):
        """Bind an observability bundle: each flush becomes a traced span
        (with redo-log commit / log-apply instants) and quarantine events
        surface through the pool."""
        self.obs = obs
        self.pool.obs = obs

    # -- crash injection ---------------------------------------------------

    def inject_crash(self, after_ops: int):
        """Die (raise ``SimulatedCrash``) after ``after_ops`` further
        emulated stores; 0 dies before the next store lands."""
        self._ops_budget = int(after_ops)

    def _store(self):
        """One emulated store op is about to land; the crash point sits
        BEFORE it (the op that would exceed the budget never lands)."""
        if self._ops_budget is not None:
            if self._ops_budget <= 0:
                self.dead = True
                raise SimulatedCrash("injected crash mid-flush")
            self._ops_budget -= 1

    def _account(self, nbytes: int, rows: int = 0):
        self.flushed_bytes += nbytes
        self.last_flush_bytes += nbytes
        self.flushed_rows += rows
        self.last_flush_rows += rows

    def _stage(self, arr: np.ndarray) -> np.ndarray:
        """Materialize one device array host-side, counting the bytes —
        the flush's host-staging cost the O(dirty) gate audits."""
        out = np.asarray(arr)
        self.staged_bytes += out.nbytes
        self.last_staged_bytes += out.nbytes
        return out

    def _stage_gathered(self, state: DashState, names, ids: np.ndarray
                        ) -> dict:
        """Stage ONLY the dirty rows of the big record planes: one jitted
        device gather over the pow2-padded id vector, one host transfer
        per plane of just those rows. Returns row-indexable proxies."""
        pad = 1
        while pad < max(int(ids.size), 1):
            pad <<= 1
        idp = np.zeros(pad, dtype=np.int64)
        idp[:ids.size] = ids
        planes = tuple(
            jnp.reshape(jnp.asarray(getattr(state, n)),
                        (self.pool.spec(n).rows, -1))
            for n in names)
        out = _gather_rows(planes, jnp.asarray(idp))
        return {n: _GatheredRows(ids, self._stage(g)[:ids.size])
                for n, g in zip(names, out)}

    def _write_rows(self, name: str, ids: np.ndarray, live: np.ndarray):
        if ids.size == 0:
            return
        self._store()
        self._account(self.pool.write_rows(name, ids, live), ids.size)

    def _write_plane(self, name: str, live: np.ndarray):
        self._store()
        self._account(self.pool.write_plane(name, live))

    # -- fence with bounded retry / graceful degradation -------------------

    def _fence(self):
        """Fence with bounded retry + exponential backoff on transient
        flush errors (EIO and friends). The mapping still holds every
        store, so a retried msync re-persists them — retrying the fence IS
        retrying the writes. Past the budget the engine goes DEGRADED and
        raises ``WritebackDegraded``; the pool keeps its last committed
        image and serving continues volatile."""
        delay = self.retry_base_s
        attempt = 0
        while True:
            try:
                self.pool.fence()
                return
            except SimulatedCrash:
                self.dead = True
                raise
            except FlushError as e:
                self.flush_io_errors += 1
                if attempt >= self.retry_limit:
                    self.degraded = True
                    raise WritebackDegraded(
                        f"fence on {self.pool.path} failed "
                        f"{attempt + 1}x (last: {e}); engine degraded"
                    ) from e
                attempt += 1
                self.flush_retries += 1
                time.sleep(delay)
                delay *= 2

    def try_recover(self, state: DashState) -> bool:
        """Attempt DEGRADED -> healthy: probe the fence once and, if the
        device answers, resynchronize the pool with one force-full flush
        (the degraded window may have left partial uncommitted phases in
        the mapping; a full rewrite + commit supersedes them). Returns
        True when the engine is healthy afterwards."""
        if self.dead:
            return False
        if not self.degraded:
            return True
        try:
            self.pool.fence()
        except SimulatedCrash:
            self.dead = True
            raise
        except FlushError:
            return False
        self.degraded = False
        try:
            self.flush(state, DirtyHint(segments=set(), dir=False, full=True))
        except WritebackDegraded:
            return False
        self.recoveries += 1
        return True

    # -- the flush ---------------------------------------------------------

    def flush(self, state: DashState, hint: Optional[DirtyHint] = None) -> int:
        """Write every dirty plane of ``state`` to the pool in the fenced
        phase order above; returns bytes written. O(dirty) I/O: row-granular
        for the record planes (version diff vs the pool), compare-then-copy
        for directory/segment metadata, always-copy for scalars."""
        if self.dead:
            raise SimulatedCrash("writeback engine died in a previous flush")
        if self.degraded:
            self.degraded_flushes += 1
            raise WritebackDegraded(
                f"pool {self.pool.path} is degraded; call try_recover first")
        tr = self.obs.tracer if self.obs is not None else None
        fsp = tr.begin("flush", "persist") if tr is not None else None
        try:
            return self._flush_inner(state, hint, tr, fsp)
        except WritebackDegraded:
            if tr is not None:
                tr.end(fsp, degraded=True)
            raise

    def _flush_inner(self, state: DashState, hint, tr, fsp) -> int:
        t0 = time.perf_counter()
        self.last_flush_bytes = 0
        self.last_flush_rows = 0
        self.last_heap_tail_rows = 0
        self.last_staged_bytes = 0
        cfg = self.cfg
        NB, BT, SL = cfg.num_buckets, cfg.buckets_total, cfg.num_slots

        # host staging is O(dirty), not O(pool): only the narrow planes
        # (4-byte rows, routing, scalars — a few percent of the pool) are
        # copied whole; the wide record planes are staged row-granularly
        # by a device gather once the dirty set is known. The pointer-mode
        # key heap is device-sliced at its tail (never copied whole).
        small = tuple(n for n in DashState._fields
                      if n not in GATHER_BT + GATHER_NB
                      and not (n == "key_heap" and cfg.pointer_mode))
        live = {n: self._stage(getattr(state, n)) for n in small}
        full = (self.pool.sb.flush_seq == 0
                or (hint is not None and hint.full))

        # dirty rows = version-plane diff against the pool (the durable
        # mirror of engine.changed_rows); force-full writes every row
        disk_ver = self.pool.rows("version").reshape(-1)
        live_ver = live["version"].reshape(-1)
        if full:
            ids_bt = np.arange(live_ver.size, dtype=np.int64)
        else:
            ids_bt = np.flatnonzero(disk_ver != live_ver).astype(np.int64)
        seg_of = ids_bt // BT
        b_of = ids_bt % BT
        nb_mask = b_of < NB
        ids_nb = (seg_of * NB + b_of)[nb_mask]
        self.last_dirty_rows = int(ids_bt.size)

        if hint is not None and not full and ids_bt.size:
            seen = set(np.unique(seg_of).tolist())
            self.flush_hint_misses += len(seen - hint.segments)

        live.update(self._stage_gathered(state, GATHER_BT, ids_bt))
        live.update(self._stage_gathered(state, GATHER_NB, ids_nb))
        rowview = {n: live[n].reshape(self.pool.spec(n).rows, -1)
                   for n in DATA_BT + PUBLISH_BT + layout.NB_PLANES}

        # -- classification vs the pool's current contents -----------------
        disk_bits = _slot_bits(self.pool.rows("meta").reshape(-1)[ids_bt], SL)
        live_bits = _slot_bits(live["meta"].reshape(-1)[ids_bt], SL)
        changed = np.zeros_like(disk_bits)
        for n in ("key_hi", "key_lo"):
            changed |= (self.pool.rows(n)[ids_bt]
                        != live[n].reshape(-1, SL)[ids_bt])
        # fp rows are lane-padded to 16; compare the record slots only
        changed |= (self.pool.rows("fp")[ids_bt][:, :SL]
                    != live["fp"].reshape(-1, 16)[ids_bt][:, :SL])
        # any POOL-allocated slot with changed key/fp bytes forces the log:
        # an in-place data store there would scramble a visible record even
        # if the live row no longer keeps that slot
        rebuilt = (disk_bits & changed).any(axis=1)
        loses = (disk_bits & ~live_bits).any(axis=1)
        a_bt = ids_bt[~rebuilt & ~loses]        # append rows
        c_bt = ids_bt[~rebuilt & loses]         # clear rows
        r_bt = ids_bt[rebuilt]                  # rebuilt rows -> redo log
        a_nb = ids_nb[(~rebuilt & ~loses)[nb_mask]]
        c_nb = ids_nb[(~rebuilt & loses)[nb_mask]]
        r_nb = ids_nb[rebuilt[nb_mask]]

        log_routing = r_bt.size > 0
        routing_dirty = not log_routing and (full or any(
            not np.array_equal(self.pool.plane(n), live[n])
            for n in layout.DIR_PLANES + layout.SEG_META_PLANES))

        # phase 1: data planes of the in-place rows (new bytes land only in
        # pool-free slots — invisible until a publish word flips)
        ip_bt = np.concatenate([a_bt, c_bt])
        ip_nb = np.concatenate([a_nb, c_nb])
        for n in DATA_BT:
            self._write_rows(n, ip_bt, rowview[n])
        self._write_rows("ofp", ip_nb, rowview["ofp"])
        # pointer mode: the key heap is append-only (handles are bump-
        # allocated), so only the tail above the pool's durable high water
        # needs writing — O(heap-tail) instead of O(heap), and it lands in
        # phase 1 so any handle a later phase publishes already has its
        # heap row durable
        if cfg.pointer_mode and cfg.key_heap_size > 0:
            disk_top = int(self.pool.plane("heap_top")[()])
            live_top = int(live["heap_top"])
            lo = 0 if full else max(0, min(disk_top, live_top))
            hi = int(state.key_heap.shape[0]) if full else live_top
            if hi > lo:
                # device-sliced tail: stage the [lo, hi) rows only — the
                # heap is append-only, so everything below lo is already
                # durable and never crosses the host boundary again
                tail = self._stage(state.key_heap[lo:hi])
                self._store()
                self._account(self.pool.write_span("key_heap", lo, hi, tail))
                self.last_heap_tail_rows = hi - lo
        self._fence()

        # phase 2: publish the append rows
        self._write_rows("meta", a_bt, rowview["meta"])
        self._write_rows("ometa", a_nb, rowview["ometa"])
        self._write_rows("version", a_bt, rowview["version"])
        self._fence()

        # phase 3: routing + per-segment metadata + scalars, in place only
        # when no rebuilt rows ride this flush (else they go via the log)
        if not log_routing:
            if routing_dirty:
                for n in layout.DIR_PLANES + layout.SEG_META_PLANES:
                    if full or not np.array_equal(self.pool.plane(n), live[n]):
                        self._write_plane(n, live[n])
            for n in layout.SCALAR_PLANES:
                if n == "key_heap" and cfg.pointer_mode:
                    continue           # tail already written in phase 1
                self._write_plane(n, live[n])
            self._fence()

        # phase 4: clear rows — records may leave, their displacement copies
        # (if any) are already published. In place ONLY when no log rides
        # this flush: with rebuilt rows, a moved record's destination may
        # exist solely in the not-yet-committed log, so a durable clear
        # before the commit fence can orphan an acked record (the chaos
        # matrix found exactly this: torn fence between the clears and the
        # commit). With a log, the clears join the logged set instead and
        # land atomically with the commit at apply time.
        if not log_routing:
            self._write_rows("meta", c_bt, rowview["meta"])
            self._write_rows("ometa", c_nb, rowview["ometa"])
            self._write_rows("version", c_bt, rowview["version"])
            self._fence()

        # phase 5: stage rebuilt (+ clear) rows (+ routing) in the redo log
        log_bt = log_nb = 0
        log_crc = 0
        if log_routing:
            l_bt = np.concatenate([r_bt, c_bt])
            l_nb = np.concatenate([r_nb, c_nb])
            self._store()
            nbytes, log_crc = self.pool.write_log(l_bt, l_nb, True, live)
            self._account(nbytes, l_bt.size)
            self.logged_rows += int(l_bt.size)
            log_bt, log_nb = int(l_bt.size), int(l_nb.size)
            self._fence()

        # phase 6: commit record (acknowledgment point)
        self._store()
        self.pool.commit(gver=int(live["gver"]), clean=bool(live["clean"]),
                         log_bt=log_bt, log_nb=log_nb,
                         log_routing=log_routing, log_crc=log_crc)
        self._fence()
        if tr is not None:
            tr.instant("redo_log_commit", "persist", parent=fsp,
                       logged=log_routing, log_rows=log_bt)

        # phase 7: apply the committed log to the home rows (idempotent —
        # a crash inside the apply is redone at the next open)
        # phase 8: clear the log descriptor with a second commit. After
        # this, a later flush's staging (phase 5) can never be confused
        # with a committed-but-unapplied log — so a descriptor whose CRC
        # fails at open is REAL log-region media loss, not staleness
        # (pool.apply_log sets ``log_lost`` on exactly that signal).
        if log_routing:
            self._store()
            self._account(self.pool.apply_log())
            self._fence()
            self._store()
            self.pool.commit(gver=int(live["gver"]),
                             clean=bool(live["clean"]))
            self._fence()
            if tr is not None:
                tr.instant("log_apply", "persist", parent=fsp)

        self.flushes += 1
        self.flush_seconds += time.perf_counter() - t0
        if tr is not None:
            tr.end(fsp, bytes=self.last_flush_bytes,
                   rows=self.last_flush_rows,
                   dirty_rows=self.last_dirty_rows)
            self.last_flush_sid = fsp.sid if fsp is not None else None
        return self.last_flush_bytes

    def stats(self) -> dict:
        return {
            "flushes": self.flushes,
            "flushed_bytes": self.flushed_bytes,
            "last_flush_bytes": self.last_flush_bytes,
            "staged_bytes": self.staged_bytes,
            "last_staged_bytes": self.last_staged_bytes,
            "flushed_rows": self.flushed_rows,
            "last_dirty_rows": self.last_dirty_rows,
            "last_heap_tail_rows": self.last_heap_tail_rows,
            "logged_rows": self.logged_rows,
            "flush_seconds": self.flush_seconds,
            "flush_hint_misses": self.flush_hint_misses,
            "flush_io_errors": self.flush_io_errors,
            "flush_retries": self.flush_retries,
            "degraded": self.degraded,
            "degraded_flushes": self.degraded_flushes,
            "recoveries": self.recoveries,
            "fences": self.pool.fences,
            "pool_bytes": self.pool.plane_bytes,
            "flush_seq": self.pool.sb.flush_seq,
        }


class Scrubber:
    """Incremental background media scrub over the pool's checksummed
    planes. Each ``tick`` verifies a window of bucket rows (every record
    plane at those rows) against the stored per-row checksums; a mismatch
    is media rot that crept in SINCE the row was written (data + checksum
    travel in one store op, so they never disagree at a store boundary).

    While the table is live the serving state is authoritative, so a bad
    row is repaired in place from ``state`` — detection latency is then
    bounded by one full pass (``rows_total / rows_per_tick`` ticks), which
    is what benchmarks/chaos.py measures. Repairs are fenced immediately.
    """

    def __init__(self, wb: WritebackEngine, rows_per_tick: int = 512):
        self.wb = wb
        self.rows_per_tick = int(rows_per_tick)
        self.bt_rows = wb.pool.csum.rows_of("version")
        self.nb_rows = wb.pool.csum.rows_of("ometa")
        self.rows_total = self.bt_rows + self.nb_rows
        self.pos = 0                  # scan cursor in [0, rows_total)
        self.cycles = 0               # completed full passes
        self.scanned_rows = 0
        self.mismatched_rows = 0
        self.repaired_rows = 0

    def _scrub_group(self, names, lo, hi, state) -> int:
        pool = self.wb.pool
        ids = np.arange(lo, hi, dtype=np.int64)
        repaired = 0
        for n in names:
            have = layout.np_row_checksum(pool.rows(n)[ids])
            bad = ids[have != pool.csum_rows(n)[ids]]
            if bad.size:
                self.mismatched_rows += int(bad.size)
                # repair needs the live bytes of the BAD rows only — a
                # device gather of those rows, not a whole-plane copy
                rows = self.wb._stage_gathered(state, (n,), bad)[n]
                pool.write_rows(n, bad, rows)
                repaired += int(bad.size)
        return repaired

    def tick(self, state: DashState) -> dict:
        """Scrub the next window; returns the per-tick report. Safe to call
        while the engine is degraded — repairs are volatile stores either
        way until a fence succeeds, and the fence failure is swallowed
        (the rows stay dirty-diffable; recovery's force-full rewrites
        them)."""
        if self.wb.dead or self.rows_total == 0:
            return {"scanned": 0, "repaired": 0}
        lo = self.pos
        hi = min(lo + self.rows_per_tick, self.rows_total)
        repaired = 0
        if lo < self.bt_rows:
            repaired += self._scrub_group(
                layout.BT_PLANES, lo, min(hi, self.bt_rows), state)
        if hi > self.bt_rows:
            repaired += self._scrub_group(
                layout.NB_PLANES, max(lo - self.bt_rows, 0),
                hi - self.bt_rows, state)
        self.scanned_rows += hi - lo
        self.repaired_rows += repaired
        self.pos = hi % self.rows_total
        if self.pos == 0:
            self.cycles += 1
        obs = self.wb.obs
        if obs is not None:
            obs.registry.counter("scrub.scanned_rows").inc(hi - lo)
            if repaired:
                obs.registry.counter("scrub.repaired_rows").inc(repaired)
                obs.tracer.instant("scrub_repair", "persist",
                                   rows=repaired, window=(lo, hi))
        if repaired:
            try:
                self.wb.pool.fence()
            except SimulatedCrash:
                self.wb.dead = True
                raise
            except FlushError:
                pass                  # degraded device; repair stays volatile
        return {"scanned": hi - lo, "repaired": repaired}

    def stats(self) -> dict:
        return {"scrub_cycles": self.cycles,
                "scrub_scanned_rows": self.scanned_rows,
                "scrub_mismatched_rows": self.mismatched_rows,
                "scrub_repaired_rows": self.repaired_rows}
