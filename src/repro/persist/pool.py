"""Emulated-PM pool file: memory-mapped plane regions + checksummed superblock.

The pool is the durable mirror of one ``DashState`` (the paper's PM pool,
emulated with ``np.memmap`` over an ordinary file — on a PM-backed mount the
same code is real persistent memory programming modulo the DAX flush path):

  * byte 0: two 2 KB **superblock slots**, written alternately with a
    monotonic ``flush_seq`` and a CRC32 over the payload. A torn superblock
    write can only corrupt the slot being written; ``open`` picks the valid
    slot with the highest sequence — the 8-byte-atomic commit record of real
    PM, emulated at slot granularity.
  * from ``layout.SUPERBLOCK_BYTES``: one region per state plane, laid out
    by ``core/layout.py:pool_plane_specs`` (the plane↔file-offset map) in
    ``DashState._fields`` order, 64-byte aligned. Record planes are
    addressed at bucket-row granularity: the flattened row index of
    ``version[..., b]`` addresses the same row in every BT plane — the same
    row index space the COW publish scatters (PR 4).

The pool itself is policy-free: ``write_rows`` / ``write_plane`` land bytes
in the mapping (emulated stores), ``fence`` flushes the mapping (emulated
``sfence`` after a ``clwb`` train), ``commit`` writes the next superblock
slot. The ORDER of those calls — what makes a torn crash recoverable — is
the writeback engine's contract (persist/writeback.py).

The superblock payload also carries the table config + mode, so ``open``
reconstructs the exact ``DashConfig`` the pool was created with: a reopened
pool needs no out-of-band schema.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Optional

import numpy as np

from repro.core import layout
from repro.core.layout import DashConfig, DashState

MAGIC = b"DASHPM01"
FORMAT = 1
SLOT_BYTES = 2048                      # two slots fit in SUPERBLOCK_BYTES
assert 2 * SLOT_BYTES <= layout.SUPERBLOCK_BYTES
_HDR = 16                              # magic(8) + crc(4) + payload_len(4)


class PoolError(RuntimeError):
    pass


@dataclasses.dataclass
class Superblock:
    """The durable commit record. ``clean`` is authoritative over the state
    region's ``clean`` scalar at reopen (a torn scalar flush can leave the
    plane region stale; the superblock is written last, post-fence).

    ``log_*`` describe the redo-log contents this commit staged (SMO-rebuilt
    rows + routing planes): committed-but-unapplied entries are re-applied
    at open (idempotent — the log holds absolute row contents)."""
    mode: str
    cfg: dict
    flush_seq: int = 0                 # 0 = created, never flushed
    gver: int = 1
    clean: bool = True
    log_bt: int = 0                    # logged BT-row entries
    log_nb: int = 0                    # logged NB-row entries
    log_routing: bool = False          # routing/scalar planes logged too
    log_crc: int = 0                   # crc32 over the used log bytes

    def encode(self) -> bytes:
        payload = json.dumps(dataclasses.asdict(self)).encode()
        if _HDR + len(payload) > SLOT_BYTES:
            raise PoolError("superblock payload too large")
        hdr = MAGIC + zlib.crc32(payload).to_bytes(4, "little") + \
            len(payload).to_bytes(4, "little")
        return hdr + payload

    @classmethod
    def decode(cls, raw: bytes) -> Optional["Superblock"]:
        """None on an invalid/torn slot (bad magic, length, or CRC)."""
        if raw[:8] != MAGIC:
            return None
        crc = int.from_bytes(raw[8:12], "little")
        n = int.from_bytes(raw[12:16], "little")
        if n <= 0 or _HDR + n > SLOT_BYTES:
            return None
        payload = raw[_HDR:_HDR + n]
        if zlib.crc32(payload) != crc:
            return None
        try:
            return cls(**json.loads(payload.decode()))
        except (ValueError, TypeError):
            return None


class PmPool:
    """One memory-mapped pool file holding one table's planes.

    ``create`` allocates and zero-fills (a fresh PM allocation); ``open``
    maps an existing file and validates/loads the superblock. Plane views
    write through the mapping; ``fence()`` is the ordering point.
    """

    def __init__(self, path: str, sb: Superblock):
        self.path = path
        self.sb = sb
        self.cfg = DashConfig(**sb.cfg)
        self.mode = sb.mode
        self.specs, self.log, self.total_bytes = layout.pool_plane_specs(
            self.cfg, self.mode)
        self.plane_bytes = sum(s.nbytes for s in self.specs)
        self._by_name = {s.name: s for s in self.specs}
        self._mm = np.memmap(path, dtype=np.uint8, mode="r+",
                             shape=(self.total_bytes,))
        self._views = {}
        for s in self.specs:
            raw = self._mm[s.offset:s.offset + s.nbytes]
            self._views[s.name] = raw.view(s.dtype).reshape(s.shape)
        self.fences = 0
        self.apply_log()               # redo a committed-but-unapplied log

    # -- lifecycle ---------------------------------------------------------

    @classmethod
    def create(cls, path: str, cfg: DashConfig, mode: str = "eh") -> "PmPool":
        if os.path.exists(path):
            raise PoolError(f"pool exists: {path}")
        sb = Superblock(mode=mode, cfg=dataclasses.asdict(cfg))
        _, _, total = layout.pool_plane_specs(cfg, mode)
        with open(path, "wb") as f:
            f.truncate(total)
        pool = cls(path, sb)
        pool._write_slot(0, sb)
        pool.fence()
        return pool

    @classmethod
    def open(cls, path: str) -> "PmPool":
        if not os.path.exists(path):
            raise PoolError(f"no pool at {path}")
        with open(path, "rb") as f:
            head = f.read(2 * SLOT_BYTES)
        slots = [Superblock.decode(head[i * SLOT_BYTES:(i + 1) * SLOT_BYTES])
                 for i in range(2)]
        valid = [s for s in slots if s is not None]
        if not valid:
            raise PoolError(f"no valid superblock in {path}")
        sb = max(valid, key=lambda s: s.flush_seq)
        return cls(path, sb)

    def close(self):
        self.fence()
        self._views.clear()
        self._mm = None

    # -- emulated stores ---------------------------------------------------

    def plane(self, name: str) -> np.ndarray:
        """Writable view of one plane region (writes land in the mapping)."""
        return self._views[name]

    def spec(self, name: str) -> layout.PlaneSpec:
        return self._by_name[name]

    def rows(self, name: str) -> np.ndarray:
        """Row-major (rows, row_bytes…) view of a record plane."""
        s = self._by_name[name]
        return self._views[name].reshape(s.rows, -1)

    def write_rows(self, name: str, ids: np.ndarray, live_rows: np.ndarray
                   ) -> int:
        """Scatter dirty rows of ``live_rows`` (same row-major layout) into
        the plane region; returns bytes written. One call = one emulated
        ordered-store op (a clwb train over the dirty lines)."""
        if ids.size == 0:
            return 0
        self.rows(name)[ids] = live_rows[ids]
        return int(ids.size) * self._by_name[name].row_nbytes

    def write_plane(self, name: str, live: np.ndarray) -> int:
        """Overwrite one whole plane region; returns bytes written."""
        view = self._views[name]
        view[...] = live.reshape(view.shape)
        return self._by_name[name].nbytes

    def fence(self):
        """Ordering point: every store issued before this is durable before
        any store issued after (msync as the clwb+sfence analog)."""
        if self._mm is not None:
            self._mm.flush()
        self.fences += 1

    # -- redo log ----------------------------------------------------------
    # SMO-rebuilt rows are staged here instead of being rewritten in place:
    # an in-place segment rebuild overwrites slots still claimed by the old
    # meta word, so no store order makes it crash-atomic. The log section
    # is struct-of-arrays: int64 row ids, then each plane's logged rows
    # contiguously; routing planes (when logged) are whole-plane snapshots.

    _LOG_ROUTING = (layout.DIR_PLANES + layout.SEG_META_PLANES
                    + layout.SCALAR_PLANES)

    def _encode_log(self, ids_bt, ids_nb, routing: bool, live: dict) -> bytes:
        parts = [np.ascontiguousarray(ids_bt.astype(np.int64))]
        for n in layout.BT_PLANES:
            parts.append(np.ascontiguousarray(
                live[n].reshape(self.log.bt_rows, -1)[ids_bt]))
        parts.append(np.ascontiguousarray(ids_nb.astype(np.int64)))
        for n in layout.NB_PLANES:
            parts.append(np.ascontiguousarray(
                live[n].reshape(self.log.nb_rows, -1)[ids_nb]))
        if routing:
            for n in self._LOG_ROUTING:
                parts.append(np.ascontiguousarray(live[n]))
        return b"".join(p.tobytes() for p in parts)

    def write_log(self, ids_bt, ids_nb, routing: bool, live: dict) -> tuple:
        """Stage rebuilt rows (+ optionally the routing planes) into the
        log region; returns (nbytes, crc) for the commit record. One
        emulated store op (the caller fences before committing)."""
        enc = self._encode_log(ids_bt, ids_nb, routing, live)
        self._mm[self.log.offset:self.log.offset + len(enc)] = \
            np.frombuffer(enc, dtype=np.uint8)
        return len(enc), zlib.crc32(enc)

    def apply_log(self):
        """Redo a committed log: scatter the logged rows/planes into their
        home regions. Idempotent (absolute contents); called at open and by
        the writeback right after its commit fence.

        A checksum MISMATCH means the region was overwritten by a LATER
        flush's staging (phase 5) that never committed — and a later flush
        can only run after the committed log was applied (phase 7, or this
        very method at a previous open), so the mismatching log is stale
        and safely skipped. Within the emulated-store crash model nothing
        else writes the region; media corruption is out of scope."""
        sb = self.sb
        if not (sb.log_bt or sb.log_nb or sb.log_routing):
            return 0
        off = self.log.offset
        raw = self._mm[off:off + self.log.nbytes]
        if zlib.crc32(raw[:self._log_used_bytes(sb)].tobytes()) != sb.log_crc:
            return 0                   # stale log of an already-applied commit
        pos = 0

        def take(nbytes):
            nonlocal pos
            out = raw[pos:pos + nbytes]
            pos += nbytes
            return out

        applied = 0
        ids_bt = take(8 * sb.log_bt).view(np.int64)
        for n in layout.BT_PLANES:
            rb = self._by_name[n].row_nbytes
            rows = take(rb * sb.log_bt).reshape(sb.log_bt, rb)
            self.rows(n).view(np.uint8).reshape(
                self.log.bt_rows, -1)[ids_bt] = rows
            applied += rows.nbytes
        ids_nb = take(8 * sb.log_nb).view(np.int64)
        for n in layout.NB_PLANES:
            rb = self._by_name[n].row_nbytes
            rows = take(rb * sb.log_nb).reshape(sb.log_nb, rb)
            self.rows(n).view(np.uint8).reshape(
                self.log.nb_rows, -1)[ids_nb] = rows
            applied += rows.nbytes
        if sb.log_routing:
            for n in self._LOG_ROUTING:
                s = self._by_name[n]
                self._mm[s.offset:s.offset + s.nbytes] = take(s.nbytes)
                applied += s.nbytes
        return applied

    def _log_used_bytes(self, sb: Superblock) -> int:
        used = sb.log_bt * (8 + self.log.bt_row_nbytes) \
            + sb.log_nb * (8 + self.log.nb_row_nbytes)
        if sb.log_routing:
            used += self.log.routing_nbytes
        return used

    # -- commit record -----------------------------------------------------

    def _write_slot(self, slot: int, sb: Superblock):
        enc = sb.encode()
        self._mm[slot * SLOT_BYTES:slot * SLOT_BYTES + len(enc)] = \
            np.frombuffer(enc, dtype=np.uint8)

    def commit(self, gver: int, clean: bool, log_bt: int = 0, log_nb: int = 0,
               log_routing: bool = False, log_crc: int = 0) -> int:
        """Write the next superblock slot (flush_seq + 1) — the flush's
        atomic commit point, carrying the redo-log descriptor. The caller
        fences before (data + log durable first) and after (commit durable
        before acknowledging). Returns the new sequence number."""
        nxt = dataclasses.replace(self.sb, flush_seq=self.sb.flush_seq + 1,
                                  gver=int(gver), clean=bool(clean),
                                  log_bt=int(log_bt), log_nb=int(log_nb),
                                  log_routing=bool(log_routing),
                                  log_crc=int(log_crc))
        self._write_slot(nxt.flush_seq % 2, nxt)
        self.sb = nxt
        return nxt.flush_seq

    # -- state I/O ---------------------------------------------------------

    def read_state(self) -> DashState:
        """Materialize the pool's planes as a fresh ``DashState`` (device
        arrays). The copy is bounded by the pool size — constant in the
        number of stored keys for a fixed config, which is what keeps the
        durable restart O(1) in data size."""
        import jax.numpy as jnp
        return DashState(**{s.name: jnp.asarray(np.array(self._views[s.name]))
                            for s in self.specs})

    def disk_plane(self, name: str) -> np.ndarray:
        """Read-only host copy of one plane (diff/classification input)."""
        return np.array(self._views[name])
