"""Emulated-PM pool file: memory-mapped plane regions + checksummed superblock.

The pool is the durable mirror of one ``DashState`` (the paper's PM pool,
emulated with ``np.memmap`` over an ordinary file — on a PM-backed mount the
same code is real persistent memory programming modulo the DAX flush path):

  * byte 0: two 2 KB **superblock slots**, written alternately with a
    monotonic ``flush_seq`` and a CRC32 over the payload. A torn superblock
    write can only corrupt the slot being written; ``open`` picks the valid
    slot with the highest sequence — the 8-byte-atomic commit record of real
    PM, emulated at slot granularity.
  * a **per-row checksum region** (PR 6): one uint32 content checksum per
    bucket row of every record plane (``layout.CSUM_PLANES``), maintained
    atomically with the row's store (same emulated store op), verified by
    ``verify_checksums`` at reopen and by the background scrubber. Checksums
    detect *media* faults — torn cachelines inside one store, bit rot — which
    the crash-only model of PR 5 never exercises.
  * from there: one region per state plane, laid out by
    ``core/layout.py:pool_plane_specs`` (the plane↔file-offset map) in
    ``DashState._fields`` order, 64-byte aligned. Record planes are
    addressed at bucket-row granularity: the flattened row index of
    ``version[..., b]`` addresses the same row in every BT plane — the same
    row index space the COW publish scatters (PR 4).

The pool itself is policy-free: ``write_rows`` / ``write_plane`` land bytes
in the mapping (emulated stores), ``fence`` flushes the mapping (emulated
``sfence`` after a ``clwb`` train), ``commit`` writes the next superblock
slot. The ORDER of those calls — what makes a torn crash recoverable — is
the writeback engine's contract (persist/writeback.py).

Fault injection (PR 6): a ``persist/faults.py:FaultPlan`` attached at
create/open hooks the fence path (torn msyncs, transient EIO) and the
create path (ENOSPC). While a tear is scheduled the pool journals the
pre-image of every store since the last fence, so the plan can revert a
seeded subset of the written cachelines — emulating the lines that never
left the CPU's write pending queue.

The superblock payload also carries the table config + mode, so ``open``
reconstructs the exact ``DashConfig`` the pool was created with: a reopened
pool needs no out-of-band schema.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Optional

import numpy as np

from repro.core import layout
from repro.core.layout import DashConfig, DashState

MAGIC = b"DASHPM01"
FORMAT = 1
SLOT_BYTES = 2048                      # two slots fit in SUPERBLOCK_BYTES
assert 2 * SLOT_BYTES <= layout.SUPERBLOCK_BYTES
_HDR = 16                              # magic(8) + crc(4) + payload_len(4)

#: above this many scattered rows, journal the whole plane span instead of
#: per-row extents (bounds journaling cost on full flushes)
_JOURNAL_ROW_CAP = 1024


class PoolError(RuntimeError):
    pass


class FlushError(PoolError):
    """The fence (msync analog) or a pool write failed at the media level.
    Carries ``err`` (an errno) so the writeback's retry policy can tell
    transient faults (EIO) from permanent ones. Stores issued before the
    failed fence are NOT durable; they remain in the mapping, so a retried
    fence re-persists them — which is exactly what the writeback's bounded
    retry-with-backoff does."""

    def __init__(self, msg: str, err: Optional[int] = None):
        super().__init__(msg)
        self.err = err


@dataclasses.dataclass
class Superblock:
    """The durable commit record. ``clean`` is authoritative over the state
    region's ``clean`` scalar at reopen (a torn scalar flush can leave the
    plane region stale; the superblock is written last, post-fence).

    ``log_*`` describe the redo-log contents this commit staged (SMO-rebuilt
    rows + routing planes): committed-but-unapplied entries are re-applied
    at open (idempotent — the log holds absolute row contents). Since PR 6
    the writeback clears the descriptor with a second commit right after
    applying (phase 8), so a descriptor that survives to open marks a crash
    inside the tiny commit→apply→commit window, not a stale leftover."""
    mode: str
    cfg: dict
    flush_seq: int = 0                 # 0 = created, never flushed
    gver: int = 1
    clean: bool = True
    log_bt: int = 0                    # logged BT-row entries
    log_nb: int = 0                    # logged NB-row entries
    log_routing: bool = False          # routing/scalar planes logged too
    log_crc: int = 0                   # crc32 over the used log bytes
    # durable quarantine evidence (PR 6): rows media rot has cost records
    # in, committed BEFORE the reopen's healing flush rewrites them — a
    # crash mid-recovery must never turn an explicit loss into a silent
    # one. Capped (slot budget); ``lost_overflow`` marks a truncated list.
    lost_bt: list = dataclasses.field(default_factory=list)
    lost_nb: list = dataclasses.field(default_factory=list)
    lost_records: int = 0              # cumulative cleared-record count
    lost_overflow: bool = False

    def encode(self) -> bytes:
        payload = json.dumps(dataclasses.asdict(self)).encode()
        if _HDR + len(payload) > SLOT_BYTES:
            raise PoolError("superblock payload too large")
        hdr = MAGIC + zlib.crc32(payload).to_bytes(4, "little") + \
            len(payload).to_bytes(4, "little")
        return hdr + payload

    @classmethod
    def decode(cls, raw: bytes) -> Optional["Superblock"]:
        """None on an invalid/torn slot (bad magic, length, or CRC)."""
        if raw[:8] != MAGIC:
            return None
        crc = int.from_bytes(raw[8:12], "little")
        n = int.from_bytes(raw[12:16], "little")
        if n <= 0 or _HDR + n > SLOT_BYTES:
            return None
        payload = raw[_HDR:_HDR + n]
        if zlib.crc32(payload) != crc:
            return None
        try:
            return cls(**json.loads(payload.decode()))
        except (ValueError, TypeError):
            return None


class PmPool:
    """One memory-mapped pool file holding one table's planes.

    ``create`` allocates and zero-fills (a fresh PM allocation); ``open``
    maps an existing file and validates/loads the superblock. Plane views
    write through the mapping; ``fence()`` is the ordering point.
    """

    def __init__(self, path: str, sb: Superblock, faults=None):
        self.path = path
        self.sb = sb
        self.obs = None               # observability bundle, set via writeback
        self.cfg = DashConfig(**sb.cfg)
        self.mode = sb.mode
        self.specs, self.log, self.csum, self.total_bytes = \
            layout.pool_plane_specs(self.cfg, self.mode)
        self.plane_bytes = sum(s.nbytes for s in self.specs)
        self._by_name = {s.name: s for s in self.specs}
        have = os.path.getsize(path)
        if have < self.total_bytes:
            raise PoolError(
                f"pool file truncated: {path} holds {have} bytes but the "
                f"superblock config needs {self.total_bytes} "
                f"(mode={self.mode!r}); refusing to map a short file")
        self._mm = np.memmap(path, dtype=np.uint8, mode="r+",
                             shape=(self.total_bytes,))
        self._views = {}
        for s in self.specs:
            raw = self._mm[s.offset:s.offset + s.nbytes]
            self._views[s.name] = raw.view(s.dtype).reshape(s.shape)
        self._csum_views = {}
        for name, off, rows in self.csum.entries:
            self._csum_views[name] = self._mm[off:off + 4 * rows].view(
                np.uint32)
        self.faults = faults
        self._journal = []             # (offset, pre-image bytes) since fence
        self.fences = 0
        self.log_lost = False          # committed log failed its CRC at open
        self.apply_log()               # redo a committed-but-unapplied log

    # -- lifecycle ---------------------------------------------------------

    @classmethod
    def create(cls, path: str, cfg: DashConfig, mode: str = "eh",
               faults=None) -> "PmPool":
        if os.path.exists(path):
            raise PoolError(f"pool exists: {path}")
        sb = Superblock(mode=mode, cfg=dataclasses.asdict(cfg))
        _, _, _, total = layout.pool_plane_specs(cfg, mode)
        try:
            if faults is not None:
                faults.on_create(path, total)
            with open(path, "wb") as f:
                f.truncate(total)
        except OSError as e:
            # never leave a partial pool file behind a failed allocation
            if os.path.exists(path):
                os.unlink(path)
            raise PoolError(
                f"pool create failed at {path} ({total} bytes): {e}") from e
        pool = cls(path, sb, faults=faults)
        pool._write_slot(0, sb)
        pool.fence()
        return pool

    @classmethod
    def open(cls, path: str, faults=None) -> "PmPool":
        if not os.path.exists(path):
            raise PoolError(f"no pool at {path}")
        size = os.path.getsize(path)
        if size < 2 * SLOT_BYTES:
            raise PoolError(
                f"pool file truncated: {path} holds {size} bytes, smaller "
                f"than the {2 * SLOT_BYTES}-byte superblock region")
        with open(path, "rb") as f:
            head = f.read(2 * SLOT_BYTES)
        slots = [Superblock.decode(head[i * SLOT_BYTES:(i + 1) * SLOT_BYTES])
                 for i in range(2)]
        valid = [s for s in slots if s is not None]
        if not valid:
            raise PoolError(
                f"no valid superblock in {path}: both slots failed "
                f"magic/CRC validation (corrupt or not a pool file)")
        sb = max(valid, key=lambda s: s.flush_seq)
        return cls(path, sb, faults=faults)

    def close(self):
        self.fence()
        self._views.clear()
        self._csum_views.clear()
        self._mm = None

    # -- emulated stores ---------------------------------------------------

    def plane(self, name: str) -> np.ndarray:
        """Writable view of one plane region (writes land in the mapping)."""
        return self._views[name]

    def spec(self, name: str) -> layout.PlaneSpec:
        return self._by_name[name]

    def rows(self, name: str) -> np.ndarray:
        """Row-major (rows, row_bytes…) view of a record plane."""
        s = self._by_name[name]
        return self._views[name].reshape(s.rows, -1)

    def csum_rows(self, name: str) -> np.ndarray:
        """Writable uint32 view of one plane's per-row checksum words."""
        return self._csum_views[name]

    def _journaling(self) -> bool:
        return self.faults is not None and self.faults.journal_needed()

    def _j_span(self, off: int, nbytes: int):
        """Journal the pre-image of [off, off+nbytes) for tear-revert."""
        self._journal.append((off, bytes(self._mm[off:off + nbytes])))

    def _j_rows(self, name: str, ids: np.ndarray):
        s = self._by_name[name]
        if ids.size > _JOURNAL_ROW_CAP:
            self._j_span(s.offset, s.nbytes)
            coff = self.csum.offset_of(name) if name in self._csum_views \
                else None
            if coff is not None:
                self._j_span(coff, 4 * s.rows)
            return
        coff = self.csum.offset_of(name) if name in self._csum_views else None
        rb = s.row_nbytes
        for i in np.asarray(ids).reshape(-1):
            i = int(i)
            self._j_span(s.offset + i * rb, rb)
            if coff is not None:
                self._j_span(coff + 4 * i, 4)

    def write_rows(self, name: str, ids: np.ndarray, live_rows: np.ndarray
                   ) -> int:
        """Scatter dirty rows of ``live_rows`` (same row-major layout) into
        the plane region; returns bytes written. One call = one emulated
        ordered-store op (a clwb train over the dirty lines). For
        checksummed planes the rows' checksum words are part of the same
        op — checksums never lag the data at a store boundary."""
        if ids.size == 0:
            return 0
        if self._journaling():
            self._j_rows(name, ids)
        src = live_rows[ids]
        self.rows(name)[ids] = src
        n = int(ids.size) * self._by_name[name].row_nbytes
        cs = self._csum_views.get(name)
        if cs is not None:
            cs[ids] = layout.np_row_checksum(src)
            n += 4 * int(ids.size)
        return n

    def write_plane(self, name: str, live: np.ndarray) -> int:
        """Overwrite one whole plane region; returns bytes written."""
        s = self._by_name[name]
        if self._journaling():
            self._j_span(s.offset, s.nbytes)
            if name in self._csum_views:
                self._j_span(self.csum.offset_of(name), 4 * s.rows)
        view = self._views[name]
        view[...] = live.reshape(view.shape)
        n = s.nbytes
        cs = self._csum_views.get(name)
        if cs is not None:
            cs[...] = layout.np_row_checksum(self.rows(name))
            n += 4 * s.rows
        return n

    def write_span(self, name: str, lo: int, hi: int, tail: np.ndarray
                   ) -> int:
        """Overwrite the contiguous leading-axis span ``[lo, hi)`` of one
        plane with ``tail`` — the span's rows only (shape ``(hi-lo, ...)``),
        so the caller stages just the pointer-mode key heap's append-only
        tail, never the whole heap. One emulated store op; returns bytes
        written."""
        if hi <= lo:
            return 0
        s = self._by_name[name]
        view = self._views[name]
        per_row = s.nbytes // view.shape[0]
        if self._journaling():
            self._j_span(s.offset + lo * per_row, (hi - lo) * per_row)
        view[lo:hi] = np.asarray(tail).reshape(view[lo:hi].shape)
        return (hi - lo) * per_row

    def fence(self):
        """Ordering point: every store issued before this is durable before
        any store issued after (msync as the clwb+sfence analog). Raises
        ``FlushError`` when the flush fails — the return code is checked
        and propagated, not swallowed, so acked-durability is never a lie
        on a failing device. An attached FaultPlan may tear (revert seeded
        cachelines + simulated crash) or inject transient EIO here."""
        if self._mm is None:
            return
        if self.faults is not None:
            self.faults.on_fence(self)  # may raise FlushError / TornPersist
        try:
            self._mm.flush()
        except (OSError, ValueError) as e:
            raise FlushError(f"msync failed on {self.path}: {e}",
                             err=getattr(e, "errno", None)) from e
        self.fences += 1
        if self._journal:
            self._journal.clear()

    # -- media verification ------------------------------------------------

    def verify_checksums(self, names=None) -> dict:
        """Recompute every row checksum of the named planes (default: all
        checksummed planes) against the stored checksum words. Returns
        ``{"bt": row_ids, "nb": row_ids, "planes": {name: row_ids}}`` —
        the union of mismatching rows per record-row space. A mismatch
        means a sub-store media fault (torn cacheline, bit rot): the crash
        matrix alone can never produce one, because data + checksum travel
        in the same emulated store op."""
        bad_bt, bad_nb, per_plane = set(), set(), {}
        for name in (names or layout.CSUM_PLANES):
            have = layout.np_row_checksum(self.rows(name))
            bad = np.flatnonzero(have != self._csum_views[name])
            if bad.size:
                per_plane[name] = bad
                (bad_bt if name in layout.BT_PLANES else bad_nb).update(
                    int(i) for i in bad)
        return {"bt": np.array(sorted(bad_bt), dtype=np.int64),
                "nb": np.array(sorted(bad_nb), dtype=np.int64),
                "planes": per_plane}

    # -- redo log ----------------------------------------------------------
    # SMO-rebuilt rows are staged here instead of being rewritten in place:
    # an in-place segment rebuild overwrites slots still claimed by the old
    # meta word, so no store order makes it crash-atomic. The log section
    # is struct-of-arrays: int64 row ids, then each plane's logged rows
    # contiguously; routing planes (when logged) are whole-plane snapshots
    # (``layout.log_routing_planes`` — the pointer-mode heap is exempt).

    def _encode_log(self, ids_bt, ids_nb, routing: bool, live: dict) -> bytes:
        parts = [np.ascontiguousarray(ids_bt.astype(np.int64))]
        for n in layout.BT_PLANES:
            parts.append(np.ascontiguousarray(
                live[n].reshape(self.log.bt_rows, -1)[ids_bt]))
        parts.append(np.ascontiguousarray(ids_nb.astype(np.int64)))
        for n in layout.NB_PLANES:
            parts.append(np.ascontiguousarray(
                live[n].reshape(self.log.nb_rows, -1)[ids_nb]))
        if routing:
            for n in layout.log_routing_planes(self.cfg):
                parts.append(np.ascontiguousarray(live[n]))
        return b"".join(p.tobytes() for p in parts)

    def write_log(self, ids_bt, ids_nb, routing: bool, live: dict) -> tuple:
        """Stage rebuilt rows (+ optionally the routing planes) into the
        log region; returns (nbytes, crc) for the commit record. One
        emulated store op (the caller fences before committing)."""
        enc = self._encode_log(ids_bt, ids_nb, routing, live)
        if self._journaling():
            self._j_span(self.log.offset, len(enc))
        self._mm[self.log.offset:self.log.offset + len(enc)] = \
            np.frombuffer(enc, dtype=np.uint8)
        return len(enc), zlib.crc32(enc)

    def apply_log(self):
        """Redo a committed log: scatter the logged rows/planes into their
        home regions (checksum words updated with each scatter — the redo
        heals both data and checksums). Idempotent (absolute contents);
        called at open and by the writeback right after its commit fence.

        With the phase-8 descriptor-clearing commit (PR 6) a CRC mismatch
        on a committed descriptor is no longer explainable as a stale
        leftover: it marks log-region media loss. We skip the apply (never
        scatter garbage), set ``log_lost``, and let the reopen path surface
        the affected segments in the lost-keys report."""
        sb = self.sb
        if not (sb.log_bt or sb.log_nb or sb.log_routing):
            return 0
        off = self.log.offset
        raw = self._mm[off:off + self.log.nbytes]
        if zlib.crc32(raw[:self._log_used_bytes(sb)].tobytes()) != sb.log_crc:
            self.log_lost = True
            return 0                   # never apply a corrupt log
        pos = 0

        def take(nbytes):
            nonlocal pos
            out = raw[pos:pos + nbytes]
            pos += nbytes
            return out

        applied = 0
        ids_bt = take(8 * sb.log_bt).view(np.int64)
        for n in layout.BT_PLANES:
            rb = self._by_name[n].row_nbytes
            rows = take(rb * sb.log_bt).reshape(sb.log_bt, rb)
            if self._journaling() and sb.log_bt:
                self._j_rows(n, ids_bt)
            self.rows(n).view(np.uint8).reshape(
                self.log.bt_rows, -1)[ids_bt] = rows
            if sb.log_bt:
                self._csum_views[n][ids_bt] = layout.np_row_checksum(rows)
            applied += rows.nbytes
        ids_nb = take(8 * sb.log_nb).view(np.int64)
        for n in layout.NB_PLANES:
            rb = self._by_name[n].row_nbytes
            rows = take(rb * sb.log_nb).reshape(sb.log_nb, rb)
            if self._journaling() and sb.log_nb:
                self._j_rows(n, ids_nb)
            self.rows(n).view(np.uint8).reshape(
                self.log.nb_rows, -1)[ids_nb] = rows
            if sb.log_nb:
                self._csum_views[n][ids_nb] = layout.np_row_checksum(rows)
            applied += rows.nbytes
        if sb.log_routing:
            for n in layout.log_routing_planes(self.cfg):
                s = self._by_name[n]
                if self._journaling():
                    self._j_span(s.offset, s.nbytes)
                self._mm[s.offset:s.offset + s.nbytes] = take(s.nbytes)
                applied += s.nbytes
        return applied

    def _log_used_bytes(self, sb: Superblock) -> int:
        used = sb.log_bt * (8 + self.log.bt_row_nbytes) \
            + sb.log_nb * (8 + self.log.nb_row_nbytes)
        if sb.log_routing:
            used += self.log.routing_nbytes
        return used

    # -- commit record -----------------------------------------------------

    def _write_slot(self, slot: int, sb: Superblock):
        enc = sb.encode()
        off = slot * SLOT_BYTES
        if self._journaling():
            self._j_span(off, len(enc))
        self._mm[off:off + len(enc)] = np.frombuffer(enc, dtype=np.uint8)

    def commit(self, gver: int, clean: bool, log_bt: int = 0, log_nb: int = 0,
               log_routing: bool = False, log_crc: int = 0) -> int:
        """Write the next superblock slot (flush_seq + 1) — the flush's
        atomic commit point, carrying the redo-log descriptor. The caller
        fences before (data + log durable first) and after (commit durable
        before acknowledging). Returns the new sequence number."""
        nxt = dataclasses.replace(self.sb, flush_seq=self.sb.flush_seq + 1,
                                  gver=int(gver), clean=bool(clean),
                                  log_bt=int(log_bt), log_nb=int(log_nb),
                                  log_routing=bool(log_routing),
                                  log_crc=int(log_crc))
        self._write_slot(nxt.flush_seq % 2, nxt)
        self.sb = nxt
        return nxt.flush_seq

    # -- durable quarantine evidence ---------------------------------------

    LOST_CAP = 64                      # per-kind rows kept in the slot

    def record_lost(self, report) -> None:
        """Merge a fresh quarantine report into the superblock's durable
        lost-row lists and commit+fence IMMEDIATELY — before any healing
        store. Ordering is the point: if recovery crashes after the rows
        are rewritten (checksums healed) but the evidence only lived in
        memory, the next reopen would see a clean pool and the loss would
        become silent. Committing first makes the report at least as
        durable as the healing that erases its trigger."""
        if not report:
            return
        sb = self.sb
        bt = sorted({*sb.lost_bt,
                     *(r["row"] for r in report if r["plane"] == "bt")})
        nb = sorted({*sb.lost_nb,
                     *(r["row"] for r in report if r["plane"] == "nb")})
        cap = self.LOST_CAP
        self.sb = dataclasses.replace(
            sb, lost_bt=bt[:cap], lost_nb=nb[:cap],
            lost_records=sb.lost_records
            + sum(r.get("lost_records", 0) for r in report),
            lost_overflow=sb.lost_overflow or len(bt) > cap or len(nb) > cap)
        # pass the log descriptor through untouched: retiring it is the
        # healing flush's job (and a lost descriptor must stay visible)
        self.commit(gver=sb.gver, clean=False, log_bt=sb.log_bt,
                    log_nb=sb.log_nb, log_routing=sb.log_routing,
                    log_crc=sb.log_crc)
        self.fence()
        if self.obs is not None:
            self.obs.registry.counter("pool.quarantine_events").inc()
            self.obs.registry.counter("pool.quarantined_rows").inc(
                len(report))
            self.obs.tracer.instant(
                "quarantine", "persist", rows=len(report),
                lost_records=sum(r.get("lost_records", 0) for r in report))

    def lost_entries(self) -> list:
        """The durable lost-keys report, decoded to quarantine-report shape
        (``plane``/``seg``/``bucket``/``row``; a trailing ``overflow``
        sentinel when the row list was truncated)."""
        BT, NB = self.cfg.buckets_total, self.cfg.num_buckets
        out = [{"plane": "bt", "seg": r // BT, "bucket": r % BT, "row": r}
               for r in self.sb.lost_bt]
        out += [{"plane": "nb", "seg": r // NB, "bucket": r % NB, "row": r}
                for r in self.sb.lost_nb]
        if self.sb.lost_overflow:
            out.append({"plane": "any", "overflow": True})
        return out

    # -- state I/O ---------------------------------------------------------

    def read_state(self) -> DashState:
        """Materialize the pool's planes as a fresh ``DashState`` (device
        arrays). The copy is bounded by the pool size — constant in the
        number of stored keys for a fixed config, which is what keeps the
        durable restart O(1) in data size."""
        import jax.numpy as jnp
        return DashState(**{s.name: jnp.asarray(np.array(self._views[s.name]))
                            for s in self.specs})

    def disk_plane(self, name: str) -> np.ndarray:
        """Read-only host copy of one plane (diff/classification input)."""
        return np.array(self._views[name])
