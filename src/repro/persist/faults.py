"""Deterministic fault injection for the emulated-PM pool (PR 6).

PR 5's crash matrix only kills a flush at emulated-store boundaries — the
crash-only model. Real persistent memory also fails *inside* and *around*
stores ("Data Structure Primitives on Persistent Memory", PAPERS.md):

  torn persist   — at a scheduled fence, a seeded subset of the cachelines
                   written since the previous fence never reach media and
                   the process dies (``TornPersist``). Reopen sees a file
                   where individual 64-byte lines of a row are old while
                   neighbors are new — the failure the per-row checksum
                   region exists to catch.
  bit rot        — ``flip_bits`` flips seeded bits inside persisted
                   record-plane bytes (or, with ``flip_csum_frac``
                   probability, inside the stored checksum word itself —
                   both sides of the compare are untrusted media).
  transient EIO  — scheduled fences raise ``FlushError(errno.EIO)`` a
                   bounded number of times. Short bursts are absorbed by
                   the writeback's retry-with-backoff; longer ones trip the
                   DEGRADED path (serving continues volatile).
  ENOSPC         — pool create fails with ``ENOSPC``; the pool layer must
                   clean up the partial file and raise a diagnosable error.

A ``FaultPlan`` is seeded and fully deterministic: the same seed replays
the same faults, which is what makes the chaos matrix (tests/test_faults.py,
benchmarks/chaos.py) debuggable. One plan may span several pool generations
(create → crash → reopen → …): ``fence_calls`` counts fences plan-globally,
so schedules are addressed in absolute fence time.

The plan is intrusive on purpose — it reverts bytes in the pool's mapping
using the pool's store journal (pre-images of every store since the last
fence, maintained while ``journal_needed()``) — but the pool never imports
this module: plans are attached by callers, keeping production paths free
of injection logic.
"""
from __future__ import annotations

import dataclasses
import errno
from typing import Dict, FrozenSet

import numpy as np

from repro.core import layout
from .pool import FlushError
from .writeback import SimulatedCrash

LINE = layout.POOL_ALIGN               # torn-persist granularity (64 B)


class TornPersist(SimulatedCrash):
    """A fence tore: some cachelines of the pre-fence store window were
    reverted to their pre-images and the process 'died'. Like every
    SimulatedCrash the engine that observes it becomes dead; the harness
    reopens the pool file, which now holds the torn image."""


@dataclasses.dataclass
class FaultPlan:
    """Seeded, deterministic fault schedule for one pool lineage.

    ``torn_fences`` / ``eio_fences`` are addressed by plan-global fence
    index (the value of ``fence_calls`` when the fence is attempted).
    An EIO entry is a burst: the fence at that index fails ``n`` times
    (retries included — the index does not advance on failure) before
    succeeding, so ``n <= retry_limit`` is transparent to callers and
    ``n > retry_limit`` forces the writeback into DEGRADED."""
    seed: int = 0
    torn_fences: FrozenSet[int] = frozenset()
    torn_line_frac: float = 0.5        # P(revert) per written cacheline
    eio_fences: Dict[int, int] = dataclasses.field(default_factory=dict)
    enospc_creates: int = 0            # next N creates fail with ENOSPC
    flip_csum_frac: float = 0.15       # P(a flip targets the checksum word)
    # -- counters (observability; not part of the schedule) --
    fence_calls: int = 0
    tears: int = 0
    eio_raised: int = 0
    flips: int = 0
    enospc_raised: int = 0
    torn_bytes: int = 0

    # -- hooks called by PmPool -------------------------------------------

    def journal_needed(self) -> bool:
        """True while a tear is still scheduled at or after the current
        fence index — the pool keeps store pre-images only when a future
        tear might need them."""
        return any(f >= self.fence_calls for f in self.torn_fences)

    def on_create(self, path: str, nbytes: int):
        if self.enospc_creates > 0:
            self.enospc_creates -= 1
            self.enospc_raised += 1
            raise OSError(errno.ENOSPC,
                          f"no space left on device (injected; {nbytes} "
                          f"bytes requested)", path)

    def on_fence(self, pool):
        idx = self.fence_calls
        burst = self.eio_fences.get(idx, 0)
        if burst > 0:
            # failed fences do not advance the index: a retry storms the
            # same schedule entry until its burst budget drains
            self.eio_fences[idx] = burst - 1
            self.eio_raised += 1
            raise FlushError(
                f"injected transient I/O error at fence {idx} "
                f"({burst - 1} left in burst)", err=errno.EIO)
        self.fence_calls += 1
        if idx in self.torn_fences:
            self._tear(pool, idx)

    # -- fault mechanics ---------------------------------------------------

    def _tear(self, pool, idx: int):
        """Revert a seeded subset of the cachelines written since the last
        successful fence (their pre-images live in the pool's journal),
        then die. Lines are independent: one store op can land partially —
        precisely the sub-store atomicity violation checksums detect."""
        rng = np.random.default_rng((self.seed << 16) ^ (0x7EA2 + idx))
        reverted = 0
        for off, old in pool._journal:
            n = len(old)
            if n == 0:
                continue
            first, last = off // LINE, (off + n - 1) // LINE
            drop = rng.random(last - first + 1) < self.torn_line_frac
            for j in np.flatnonzero(drop):
                ln = first + int(j)
                a = max(off, ln * LINE)
                b = min(off + n, (ln + 1) * LINE)
                pool._mm[a:b] = np.frombuffer(old[a - off:b - off],
                                              dtype=np.uint8)
                reverted += b - a
        self.tears += 1
        self.torn_bytes += reverted
        raise TornPersist(
            f"torn msync at fence {idx}: {reverted} bytes of "
            f"{len(pool._journal)} store extents reverted to pre-images")

    def flip_bits(self, pool, n: int = 1) -> int:
        """Flip ``n`` seeded bits in persisted record-plane bytes (media
        rot). With probability ``flip_csum_frac`` a flip lands in the
        stored checksum word instead of the row data — either way the
        row verifies bad, which is the property that matters (we never
        trust a row whose pair disagrees). The redo-log and superblock
        regions are deliberately out of scope: the superblock has its own
        CRC'd two-slot scheme (tested separately) and log loss is modeled
        at descriptor granularity (``PmPool.log_lost``)."""
        rng = np.random.default_rng((self.seed << 16) ^ (0xB17 + self.flips))
        names = list(layout.CSUM_PLANES)
        weights = np.array([pool.spec(nm).nbytes for nm in names], np.float64)
        weights /= weights.sum()
        for _ in range(n):
            nm = names[int(rng.choice(len(names), p=weights))]
            s = pool.spec(nm)
            row = int(rng.integers(s.rows))
            if rng.random() < self.flip_csum_frac:
                off = pool.csum.offset_of(nm) + 4 * row + \
                    int(rng.integers(4))
            else:
                off = s.offset + row * s.row_nbytes + \
                    int(rng.integers(s.row_nbytes))
            pool._mm[off] ^= np.uint8(1 << int(rng.integers(8)))
            self.flips += 1
        return n

    def stats(self) -> dict:
        return {"fence_calls": self.fence_calls, "tears": self.tears,
                "eio_raised": self.eio_raised, "flips": self.flips,
                "enospc_raised": self.enospc_raised,
                "torn_bytes": self.torn_bytes}
