"""Durable PM-pool persistence: flush-on-publish + instant-restart serving.

The subsystem closing ISSUE-5: ``pool.py`` emulates a persistent-memory pool
(memory-mapped plane regions + checksummed superblock), ``writeback.py``
flushes only the dirty planes per publish (O(dirty) bytes to durable media,
with a fenced phase order that keeps torn crashes recoverable), and this
module is the lifecycle API:

    table = persist.create("t.pool", DashConfig(...))        # fresh pool
    table.insert(keys, vals); table.flush()                  # ack durable
    table.close()                                            # clean marker

    table, info = persist.reopen("t.pool")                   # O(1) restart
    table.search(keys)                  # lazy per-segment recovery on access

``reopen`` is the paper's Table-1 instant restart, end-to-end durable: map
the pool, read the superblock's clean marker, bump V if dirty (constant
work), and return a table that serves immediately — segments are recovered
on first access by the existing lazy path (core/recovery.py). Handing the
table to ``serving.frontend.DashFrontend`` gives flush-on-publish: every
acknowledged batch is durable before its ops complete.

Media hardening (PR 6): ``reopen(verify=True)`` additionally checks every
record row against the pool's per-row checksum region. Rows the redo log
could not rebuild are quarantined (cleared + scheduled for re-flush) and
surfaced in ``table.lost_report`` / ``info`` — an explicit lost-keys report
instead of silently serving bit-rotted bytes. A seeded
``faults.FaultPlan`` can be attached to any create/open to inject torn
persists, bit rot, transient EIO, and ENOSPC (tests/test_faults.py,
benchmarks/chaos.py).

The sharded DHT gets one pool per shard (``create_shard_pools`` /
``reopen_shards``), created, flushed, and reopened independently — a shard
restart never touches its neighbors' pools, a shard's media fault degrades
only that shard, and per-shard reopen retries transient faults with
backoff.
"""
from __future__ import annotations

import glob
import os
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import recovery
from repro.core.layout import DashConfig, DashState
from repro.core.table import DashEH, DashLH, DashTable

from .faults import FaultPlan, TornPersist
from .pool import FlushError, PmPool, PoolError, Superblock
from .writeback import (Scrubber, SimulatedCrash, WritebackDegraded,
                        WritebackEngine)

__all__ = [
    "PmPool", "PoolError", "FlushError", "Superblock", "WritebackEngine",
    "WritebackDegraded", "SimulatedCrash", "Scrubber", "FaultPlan",
    "TornPersist", "create", "reopen", "durable_open", "shard_pool_paths",
    "create_shard_pools", "open_shard_pools", "flush_shards",
    "recover_shards", "reopen_shards",
]

_CLS = {"eh": DashEH, "lh": DashLH}


def create(path: str, cfg: DashConfig, mode: str = "eh", faults=None,
           **table_kw) -> DashTable:
    """Allocate a fresh pool at ``path`` and return a durable table bound to
    it. The table is marked dirty-serving immediately (clean goes durable
    only through ``table.close()``), and the empty state is flushed so a
    crash before the first ``flush()`` reopens to a valid empty table.
    A failed allocation (e.g. ENOSPC) raises ``PoolError`` and leaves no
    partial file behind."""
    import jax.numpy as jnp
    pool = PmPool.create(path, cfg, mode, faults=faults)
    table = _CLS[mode](cfg, **table_kw)
    table.state = table.state._replace(clean=jnp.asarray(False))
    table.attach_writeback(WritebackEngine(pool))
    table.flush()
    return table


def reopen(path: str, verify: bool = True, faults=None,
           **table_kw) -> Tuple[DashTable, dict]:
    """Instant restart from a pool file: constant work before the table can
    serve (map + superblock + V bump + a scalars-only flush to mark the new
    serving period dirty). All real recovery is deferred to first access of
    each segment (``DashTable._ensure_recovered``); ``info['seconds']``
    times exactly the blocking part.

    ``verify=True`` (the default) additionally recomputes every record
    row's checksum against the pool's checksum region — still O(pool
    size), not O(keys) — and quarantines mismatching rows
    (``recovery.quarantine_rows``): corrupted buckets are cleared and
    reported via ``table.lost_report`` (and ``info['quarantined_bt'/'_nb']``,
    ``info['lost_records']``) rather than served. The quarantined rows'
    version words are forced off the pool's, so the marker flush below
    immediately rewrites them (healing the checksums).

    Merged-away segment ids (``free_segments``) are not persisted: a
    reopened table re-allocates from the watermark and re-learns free ids
    from future merges — capacity conservatism, never a correctness issue.
    """
    t0 = time.perf_counter()
    pool = PmPool.open(path, faults=faults)
    if pool.sb.flush_seq == 0:
        raise PoolError(f"pool at {path} was never flushed")
    state = pool.read_state()
    state, work = recovery.instant_restart(state,
                                           clean_override=pool.sb.clean)
    report = []
    if verify:
        bad = pool.verify_checksums()
        if bad["bt"].size or bad["nb"].size:
            state, report = recovery.quarantine_rows(
                pool.cfg, pool.mode, state, pool.disk_plane("version"),
                bad["bt"], bad["nb"])
            # persist the loss evidence BEFORE the healing flush below: a
            # crash after the heal but before the next verify would
            # otherwise reopen a clean-looking pool and turn this explicit
            # loss into a silent one
            pool.record_lost(report)
    # after quarantine (a torn handle word must never inflate the floor):
    # published records may reference heap rows above the stale scalar
    state = recovery.heap_top_floor(pool.cfg, state)
    work["quarantined_bt"] = sum(1 for r in report if r["plane"] == "bt")
    work["quarantined_nb"] = sum(1 for r in report if r["plane"] == "nb")
    work["lost_records"] = sum(r.get("lost_records", 0) for r in report)
    work["lost_records_total"] = pool.sb.lost_records
    work["log_lost"] = pool.log_lost
    table = _CLS[pool.mode](pool.cfg, state=state, **table_kw)
    # merged view: rows quarantined now + evidence persisted by any earlier
    # (possibly crashed) reopen of this pool
    table.lost_report = pool.lost_entries()
    table.attach_writeback(WritebackEngine(pool))
    if report:
        table.dirty.note_segments([r["seg"] for r in report])
    # commit the dirty-serving marker (and the bumped V) BEFORE serving: a
    # crash from here on must reopen as dirty. The version diff vs the pool
    # is empty (clean reopen) or exactly the quarantined rows, so this
    # flush writes scalars + quarantine repairs + commit only.
    table.flush()
    work["seconds"] = time.perf_counter() - t0
    work["flush_seq"] = pool.sb.flush_seq
    return table, work


def durable_open(path: str, cfg: Optional[DashConfig] = None,
                 mode: str = "eh", **table_kw) -> Tuple[DashTable, dict]:
    """Open-or-create: ``reopen`` when a pool exists at ``path``, else
    ``create`` (which then requires ``cfg``)."""
    if os.path.exists(path):
        return reopen(path, **table_kw)
    assert cfg is not None, "creating a pool needs a config"
    return create(path, cfg, mode, **table_kw), {"created": True,
                                                 "clean": True, "seconds": 0.0}


# -- sharded DHT: one pool per shard ------------------------------------------

def shard_pool_paths(dirpath: str, n_shards: int) -> List[str]:
    return [os.path.join(dirpath, f"shard_{i:04d}.pool")
            for i in range(n_shards)]


def create_shard_pools(dirpath: str, cfg: DashConfig, n_shards: int,
                       faults: Optional[list] = None
                       ) -> List[WritebackEngine]:
    """One independent pool per shard (all EH — the DHT's shard type).
    ``faults`` optionally attaches one FaultPlan per shard."""
    os.makedirs(dirpath, exist_ok=True)
    paths = shard_pool_paths(dirpath, n_shards)
    return [WritebackEngine(PmPool.create(p, cfg, "eh",
                                          faults=faults[i] if faults else None))
            for i, p in enumerate(paths)]


def open_shard_pools(dirpath: str, faults: Optional[list] = None
                     ) -> List[WritebackEngine]:
    paths = sorted(glob.glob(os.path.join(dirpath, "shard_*.pool")))
    if not paths:
        raise PoolError(f"no shard pools under {dirpath}")
    return [WritebackEngine(PmPool.open(p,
                                        faults=faults[i] if faults else None))
            for i, p in enumerate(paths)]


def flush_shards(state: DashState, wbs: List[WritebackEngine]) -> int:
    """Flush a device-sharded state (leading ``(n_shards, ...)`` axes) into
    the per-shard pools — each shard's dirty diff runs against its own pool,
    so an insert burst that only touched two owners flushes two pools'
    dirty rows and commits the rest with a scalars-only write.

    Per-shard fault isolation: a shard whose pool trips the degraded path
    is skipped (its engine reports ``degraded``; its pool keeps the last
    committed image) while every healthy neighbor still flushes — one
    failing device never blocks the fleet's durability."""
    host = {n: np.asarray(getattr(state, n)) for n in DashState._fields}
    total = 0
    for i, wb in enumerate(wbs):
        if wb.degraded:
            wb.degraded_flushes += 1
            continue
        shard = DashState(**{n: host[n][i] for n in DashState._fields})
        try:
            total += wb.flush(shard)
        except WritebackDegraded:
            continue                   # this shard only; neighbors proceed
    return total


def recover_shards(state: DashState, wbs: List[WritebackEngine]) -> int:
    """Probe every degraded shard engine (``try_recover``: fence probe +
    force-full resync flush). Returns how many shards came back healthy."""
    host = {n: np.asarray(getattr(state, n)) for n in DashState._fields}
    back = 0
    for i, wb in enumerate(wbs):
        if not wb.degraded:
            continue
        shard = DashState(**{n: host[n][i] for n in DashState._fields})
        if wb.try_recover(shard):
            back += 1
    return back


def reopen_shards(dirpath: str, eager_recover_dirty: bool = False,
                  verify: bool = True, faults: Optional[list] = None,
                  retries: int = 2, retry_base_s: float = 0.002
                  ) -> Tuple[DashState, List[WritebackEngine], dict]:
    """Reopen every shard pool independently and stack the shard states
    into one ``(n_shards, ...)`` host pytree (the caller device_puts it with
    its mesh sharding — see ``DistributedDash``).

    Per-shard recovery is LAZY by default, like the single-table path: the
    shard_map probe carries a per-access hook (a lane whose segment's
    ``seg_version`` lags the recovery generation is flagged/bounced), and
    ``DistributedDash.ensure_recovered`` repairs exactly the touched
    segments on first access — so a dirty fleet reopen is O(1) in stored
    data. Pass ``eager_recover_dirty=True`` for the CCEH-style contrast
    (full ``recovery.recover_all`` per dirty shard at reopen). Clean shards
    pay nothing either way. ``info['dirty_shard_ids']`` lists which shards
    reopened dirty.

    Fault isolation (PR 6): each shard's reopen is retried ``retries``
    times with exponential backoff on transient flush errors; a shard that
    still cannot commit its dirty-serving marker is left attached but
    DEGRADED (volatile until ``recover_shards``) instead of failing the
    whole fleet. ``verify`` runs the per-shard checksum scan; quarantined
    rows are reported per shard in ``info['lost_reports']``."""
    import jax.numpy as jnp
    paths = sorted(glob.glob(os.path.join(dirpath, "shard_*.pool")))
    if not paths:
        raise PoolError(f"no shard pools under {dirpath}")
    wbs, shards = [], []
    dirty = degraded = 0
    dirty_ids = []
    lost_reports = {}
    for i, p in enumerate(paths):
        plan = faults[i] if faults else None
        wb = st = None
        delay = retry_base_s
        for attempt in range(retries + 1):
            try:
                wb = WritebackEngine(PmPool.open(p, faults=plan))
                pool = wb.pool
                if pool.sb.flush_seq == 0:
                    raise PoolError(f"shard pool {p} was never flushed")
                st = pool.read_state()
                st, work = recovery.instant_restart(
                    st, clean_override=pool.sb.clean)
                if verify:
                    bad = pool.verify_checksums()
                    if bad["bt"].size or bad["nb"].size:
                        st, rep = recovery.quarantine_rows(
                            pool.cfg, "eh", st,
                            pool.disk_plane("version"),
                            bad["bt"], bad["nb"])
                        pool.record_lost(rep)    # durable before healing
                    persisted = pool.lost_entries()
                    if persisted:
                        lost_reports[i] = persisted
                st = recovery.heap_top_floor(pool.cfg, st)
                if not work["clean"]:
                    dirty += 1
                    dirty_ids.append(i)
                    if eager_recover_dirty:
                        st = recovery.recover_all(pool.cfg, "eh", st)
                wb.flush(st)           # dirty-serving marker, per shard
                break
            except (FlushError, WritebackDegraded):
                if attempt >= retries:
                    # keep the shard attached but degraded: it serves the
                    # reopened state volatile; neighbors are unaffected
                    if wb is not None and st is not None:
                        wb.degraded = True
                        degraded += 1
                        break
                    raise
                time.sleep(delay)
                delay *= 2
        shards.append(st)
        wbs.append(wb)
    stacked = DashState(*[jnp.stack([getattr(s, n) for s in shards])
                          for n in DashState._fields])
    return stacked, wbs, {"n_shards": len(wbs), "dirty_shards": dirty,
                          "dirty_shard_ids": dirty_ids,
                          "degraded_shards": degraded,
                          "lost_reports": lost_reports,
                          "cfg": wbs[0].pool.cfg}
