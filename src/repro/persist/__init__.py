"""Durable PM-pool persistence: flush-on-publish + instant-restart serving.

The subsystem closing ISSUE-5: ``pool.py`` emulates a persistent-memory pool
(memory-mapped plane regions + checksummed superblock), ``writeback.py``
flushes only the dirty planes per publish (O(dirty) bytes to durable media,
with a fenced phase order that keeps torn crashes recoverable), and this
module is the lifecycle API:

    table = persist.create("t.pool", DashConfig(...))        # fresh pool
    table.insert(keys, vals); table.flush()                  # ack durable
    table.close()                                            # clean marker

    table, info = persist.reopen("t.pool")                   # O(1) restart
    table.search(keys)                  # lazy per-segment recovery on access

``reopen`` is the paper's Table-1 instant restart, end-to-end durable: map
the pool, read the superblock's clean marker, bump V if dirty (constant
work), and return a table that serves immediately — segments are recovered
on first access by the existing lazy path (core/recovery.py). Handing the
table to ``serving.frontend.DashFrontend`` gives flush-on-publish: every
acknowledged batch is durable before its ops complete.

The sharded DHT gets one pool per shard (``create_shard_pools`` /
``reopen_shards``), created, flushed, and reopened independently — a shard
restart never touches its neighbors' pools.
"""
from __future__ import annotations

import glob
import os
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import recovery
from repro.core.layout import DashConfig, DashState
from repro.core.table import DashEH, DashLH, DashTable

from .pool import PmPool, PoolError, Superblock
from .writeback import SimulatedCrash, WritebackEngine

__all__ = [
    "PmPool", "PoolError", "Superblock", "WritebackEngine", "SimulatedCrash",
    "create", "reopen", "durable_open", "shard_pool_paths",
    "create_shard_pools", "open_shard_pools", "flush_shards",
    "reopen_shards",
]

_CLS = {"eh": DashEH, "lh": DashLH}


def create(path: str, cfg: DashConfig, mode: str = "eh",
           **table_kw) -> DashTable:
    """Allocate a fresh pool at ``path`` and return a durable table bound to
    it. The table is marked dirty-serving immediately (clean goes durable
    only through ``table.close()``), and the empty state is flushed so a
    crash before the first ``flush()`` reopens to a valid empty table."""
    import jax.numpy as jnp
    pool = PmPool.create(path, cfg, mode)
    table = _CLS[mode](cfg, **table_kw)
    table.state = table.state._replace(clean=jnp.asarray(False))
    table.attach_writeback(WritebackEngine(pool))
    table.flush()
    return table


def reopen(path: str, **table_kw) -> Tuple[DashTable, dict]:
    """Instant restart from a pool file: constant work before the table can
    serve (map + superblock + V bump + a scalars-only flush to mark the new
    serving period dirty). All real recovery is deferred to first access of
    each segment (``DashTable._ensure_recovered``); ``info['seconds']``
    times exactly the blocking part.

    Merged-away segment ids (``free_segments``) are not persisted: a
    reopened table re-allocates from the watermark and re-learns free ids
    from future merges — capacity conservatism, never a correctness issue.
    """
    t0 = time.perf_counter()
    pool = PmPool.open(path)
    if pool.sb.flush_seq == 0:
        raise PoolError(f"pool at {path} was never flushed")
    state = pool.read_state()
    state, work = recovery.instant_restart(state,
                                           clean_override=pool.sb.clean)
    table = _CLS[pool.mode](pool.cfg, state=state, **table_kw)
    table.attach_writeback(WritebackEngine(pool))
    # commit the dirty-serving marker (and the bumped V) BEFORE serving: a
    # crash from here on must reopen as dirty. The version diff vs the pool
    # is empty, so this flush writes scalars + commit only.
    table.flush()
    work["seconds"] = time.perf_counter() - t0
    work["flush_seq"] = pool.sb.flush_seq
    return table, work


def durable_open(path: str, cfg: Optional[DashConfig] = None,
                 mode: str = "eh", **table_kw) -> Tuple[DashTable, dict]:
    """Open-or-create: ``reopen`` when a pool exists at ``path``, else
    ``create`` (which then requires ``cfg``)."""
    if os.path.exists(path):
        return reopen(path, **table_kw)
    assert cfg is not None, "creating a pool needs a config"
    return create(path, cfg, mode, **table_kw), {"created": True,
                                                 "clean": True, "seconds": 0.0}


# -- sharded DHT: one pool per shard ------------------------------------------

def shard_pool_paths(dirpath: str, n_shards: int) -> List[str]:
    return [os.path.join(dirpath, f"shard_{i:04d}.pool")
            for i in range(n_shards)]


def create_shard_pools(dirpath: str, cfg: DashConfig,
                       n_shards: int) -> List[WritebackEngine]:
    """One independent pool per shard (all EH — the DHT's shard type)."""
    os.makedirs(dirpath, exist_ok=True)
    return [WritebackEngine(PmPool.create(p, cfg, "eh"))
            for p in shard_pool_paths(dirpath, n_shards)]


def open_shard_pools(dirpath: str) -> List[WritebackEngine]:
    paths = sorted(glob.glob(os.path.join(dirpath, "shard_*.pool")))
    if not paths:
        raise PoolError(f"no shard pools under {dirpath}")
    return [WritebackEngine(PmPool.open(p)) for p in paths]


def flush_shards(state: DashState, wbs: List[WritebackEngine]) -> int:
    """Flush a device-sharded state (leading ``(n_shards, ...)`` axes) into
    the per-shard pools — each shard's dirty diff runs against its own pool,
    so an insert burst that only touched two owners flushes two pools'
    dirty rows and commits the rest with a scalars-only write."""
    host = {n: np.asarray(getattr(state, n)) for n in DashState._fields}
    total = 0
    for i, wb in enumerate(wbs):
        shard = DashState(**{n: host[n][i] for n in DashState._fields})
        total += wb.flush(shard)
    return total


def reopen_shards(dirpath: str, eager_recover_dirty: bool = True
                  ) -> Tuple[DashState, List[WritebackEngine], dict]:
    """Reopen every shard pool independently and stack the shard states
    into one ``(n_shards, ...)`` host pytree (the caller device_puts it with
    its mesh sharding — see ``DistributedDash``).

    Per-shard recovery: a shard whose pool reopened dirty is eagerly
    recovered here (``recovery.recover_all``) — the sharded data plane has
    no per-access lazy hook (reads run inside one shard_map dispatch), so
    the work lands at reopen, shard-local and independent. Clean shards pay
    nothing."""
    import jax.numpy as jnp
    wbs = open_shard_pools(dirpath)
    shards, dirty = [], 0
    for wb in wbs:
        pool = wb.pool
        if pool.sb.flush_seq == 0:
            raise PoolError(f"shard pool {pool.path} was never flushed")
        st = pool.read_state()
        st, work = recovery.instant_restart(st, clean_override=pool.sb.clean)
        if not work["clean"]:
            dirty += 1
            if eager_recover_dirty:
                st = recovery.recover_all(pool.cfg, "eh", st)
        shards.append(st)
        wb.flush(st)                 # dirty-serving marker, per shard
    stacked = DashState(*[jnp.stack([getattr(s, n) for s in shards])
                          for n in DashState._fields])
    return stacked, wbs, {"n_shards": len(wbs), "dirty_shards": dirty,
                          "cfg": wbs[0].pool.cfg}
