"""Checkpointing with Dash-style instant recovery (paper Sec. 4.8, applied to
the trainer itself).

Design goals mirrored from the paper:
  * atomic commit — per-tensor files written to a staging dir, manifest last,
    then one atomic rename; a crash mid-save never corrupts the latest commit
    (the allocate-activate discipline of PMDK).
  * instant restart — ``restore_manifest`` reads ONLY the manifest (a clean
    marker + global version + tensor index): O(1) in model size. Tensor bytes
    are loaded lazily per-tensor on first access via memory-mapped ``.npy``
    files (the lazy per-segment recovery analog: work is amortized onto first
    use, so time-to-first-request does not scale with checkpoint size).
  * clean marker + version V — a dirty restart bumps V; trainer components
    (e.g. the Dash prefix cache) compare their own version and rebuild
    lazily, exactly like segment recovery.

Async saves run on a background thread (snapshot -> serialize off the
critical path), with retention of the newest K commits.

Commit protocol (what tests/test_checkpoint.py crash-tests): tensors land in
a staging dir, the manifest is written last, ONE atomic ``rename`` publishes
the commit, and ``LATEST`` is repointed with an atomic ``os.replace``. A
crash anywhere between the first tensor write and the final replace restores
the PREVIOUS step (``latest_step`` also survives a dangling/missing LATEST
by scanning for the newest directory with a valid manifest).

How this differs from the PM pool (src/repro/persist/): this manager takes
GENERIC ASYNC TREE SNAPSHOTS — whole-model copies of an arbitrary pytree,
each commit a fresh immutable directory, atomicity by rename, cost O(model)
per save. The PM pool is IN-PLACE INCREMENTAL PLANES — one fixed-layout
memory-mapped file per table, flushed at dirty-bucket-row granularity with
ordered stores + a redo log for rebuilt rows, cost O(dirty) per publish.
Checkpoints suit the trainer (low save frequency, full-state restores,
sharded reload); the pool suits the serving table (per-batch durability,
instant restart, lazy recovery).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class LazyTensor:
    """Handle that materializes (mmap) its tensor on first access."""

    __slots__ = ("path", "_arr")

    def __init__(self, path: Path):
        self.path = path
        self._arr = None

    def get(self) -> np.ndarray:
        if self._arr is None:
            self._arr = np.load(self.path, mmap_mode="r")
        return self._arr


def _flatten(tree, prefix=""):
    """Stable path->leaf flattening."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._recover_crashed_saves()

    def _recover_crashed_saves(self):
        """Sweep the artifacts a crash mid-save can leave: stage dirs are
        uncommitted garbage (dropped); a ``.trash_<step>`` whose step dir is
        MISSING is the only copy of that step — the crash hit between the
        move-aside and the commit rename — and is restored."""
        for d in self.dir.iterdir():
            if not d.is_dir():
                continue
            if d.name.startswith(".stage_"):
                shutil.rmtree(d, ignore_errors=True)
            elif d.name.startswith(".trash_"):
                step = int(d.name.split("_")[1])
                final = self.dir / f"step_{step:010d}"
                if final.exists():
                    shutil.rmtree(d, ignore_errors=True)
                else:
                    d.rename(final)

    # ----- save ---------------------------------------------------------

    def save(self, step: int, tree: Any, *, clean: bool = True,
             version: int = 1, blocking: bool = True):
        """Snapshot on the caller thread (cheap: device_get), serialize on a
        background thread unless blocking."""
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}   # snapshot

        def work():
            self._write_commit(step, host, clean, version)

        if blocking:
            work()
        else:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write_commit(self, step: int, host: dict, clean: bool, version: int):
        stage = self.dir / f".stage_{step}_{os.getpid()}"
        if stage.exists():
            shutil.rmtree(stage)
        stage.mkdir(parents=True)
        index = {}
        for k, arr in host.items():
            fn = k.replace("/", "__") + ".npy"
            np.save(stage / fn, arr)
            index[k] = {"file": fn, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)}
        manifest = {"step": step, "clean": clean, "version": version,
                    "created": time.time(), "tensors": index}
        (stage / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step:010d}"
        trash = None
        if final.exists():
            # re-saving an existing step: move the old commit aside instead
            # of deleting it — a crash between rmtree and rename must not
            # lose the only copy of the step
            trash = self.dir / f".trash_{step}_{os.getpid()}"
            if trash.exists():
                shutil.rmtree(trash)
            final.rename(trash)
        stage.rename(final)                                   # atomic commit
        (self.dir / "LATEST.tmp").write_text(final.name)
        os.replace(self.dir / "LATEST.tmp", self.dir / "LATEST")
        if trash is not None:
            shutil.rmtree(trash, ignore_errors=True)
        self._gc()

    def _gc(self):
        commits = sorted(d for d in self.dir.iterdir()
                         if d.is_dir() and d.name.startswith("step_"))
        for d in commits[:-self.keep]:
            shutil.rmtree(d, ignore_errors=True)

    # ----- restore ------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        """Newest committed step. LATEST is the fast path; when it is
        missing or dangling (crash between the commit rename and the
        ``os.replace``), fall back to the newest ``step_*`` directory whose
        manifest parses — a committed rename IS a valid commit even if the
        pointer write was lost."""
        latest = self.dir / "LATEST"
        if latest.exists():
            name = latest.read_text().strip()
            if (self.dir / name / "manifest.json").exists():
                return int(name.split("_")[-1])
        best = None
        for d in sorted(self.dir.iterdir(), reverse=True):
            if not (d.is_dir() and d.name.startswith("step_")):
                continue
            try:
                json.loads((d / "manifest.json").read_text())
            except (OSError, ValueError):
                continue
            best = int(d.name.split("_")[-1])
            break
        return best

    def restore_manifest(self):
        """INSTANT restore: read manifest only, bump version if dirty.
        Returns (manifest, lazy_tensors, restore_seconds)."""
        t0 = time.perf_counter()
        step = self.latest_step()
        if step is None:
            return None, None, time.perf_counter() - t0
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if not manifest["clean"]:
            manifest["version"] += 1      # the paper's V bump on dirty restart
        lazy = {k: LazyTensor(d / v["file"])
                for k, v in manifest["tensors"].items()}
        return manifest, lazy, time.perf_counter() - t0

    def restore_tree(self, template: Any, lazy: dict, shardings=None):
        """Materialize the full tree (eager path for the trainer restart).
        Per-tensor mmap loads; device_put with shardings when given."""
        flat_t, treedef = _flatten(template)
        leaves = []
        for k, tmpl in flat_t.items():
            arr = lazy[k].get()
            leaves.append(np.asarray(arr))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    def mark_dirty(self, step: int):
        """Flip the latest commit's clean marker (called when training starts
        — mirrors 'set clean=false and start handling requests')."""
        s = self.latest_step()
        if s is None:
            return
        d = self.dir / f"step_{s:010d}"
        m = json.loads((d / "manifest.json").read_text())
        m["clean"] = False
        (d / "manifest.json").write_text(json.dumps(m))
