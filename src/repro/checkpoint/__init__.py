"""Checkpoint substrate: atomic commits + instant recovery (Dash Sec. 4.8)."""
from .manager import CheckpointManager, LazyTensor

__all__ = ["CheckpointManager", "LazyTensor"]
