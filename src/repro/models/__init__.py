"""Model zoo: composable decoder blocks + the 10 assigned architectures."""
from . import layers, moe, rglru, rwkv6, transformer
from .transformer import (ModelConfig, init_params, abstract_params,
                          param_specs, forward_train, loss_fn,
                          decode_state_init, serve_step)

__all__ = ["layers", "moe", "rglru", "rwkv6", "transformer", "ModelConfig",
           "init_params", "abstract_params", "param_specs", "forward_train",
           "loss_fn", "decode_state_init", "serve_step"]
