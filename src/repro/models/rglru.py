"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
first-order linear recurrence — computed with ``jax.lax.associative_scan``
over (a, b) pairs (log-depth on TPU), giving O(S) work: this is why the
hybrid architecture runs the long_500k shape that full attention cannot.

Block = [conv1d(width 4) -> RG-LRU] on the recurrent branch, gated by a GeLU
branch, as in the paper. Decode carries (h, conv_tail) per layer: O(1) state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import EMBED, RNN, truncated_normal

C_CONST = 8.0   # Griffin's recurrence sharpness constant


def rglru_init(key, d, d_rnn, conv_width: int = 4):
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    params = {
        "w_x": truncated_normal(ks[0], (d, d_rnn), s),        # recurrent branch in
        "w_gate": truncated_normal(ks[1], (d, d_rnn), s),     # GeLU gate branch
        "w_out": truncated_normal(ks[2], (d_rnn, d), 1.0 / math.sqrt(d_rnn)),
        "conv_w": truncated_normal(ks[3], (conv_width, d_rnn), 1.0 / math.sqrt(conv_width)),
        "w_rg": truncated_normal(ks[4], (d_rnn, d_rnn), 1.0 / math.sqrt(d_rnn)),
        "w_ig": truncated_normal(ks[5], (d_rnn, d_rnn), 1.0 / math.sqrt(d_rnn)),
        # Lambda parametrizes a in (0,1): a = sigmoid(lam) ** (c * r_t)
        "lam": 0.65 + 0.2 * jax.random.uniform(ks[6], (d_rnn,), jnp.float32),
    }
    specs = {"w_x": (EMBED, RNN), "w_gate": (EMBED, RNN), "w_out": (RNN, EMBED),
             "conv_w": (None, RNN), "w_rg": (RNN, RNN), "w_ig": (RNN, RNN),
             "lam": (RNN,)}
    return params, specs


def _causal_conv(w, x, tail=None):
    """width-W causal depthwise conv. x: (B, S, d). tail: (B, W-1, d)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return out, xp[:, -(W - 1):]


def _rg_lru_scan(params, u, h0=None):
    """u: (B, S, d_rnn) post-conv activations; returns (y, h_last)."""
    f32 = jnp.float32
    r = jax.nn.sigmoid((u @ params["w_rg"].astype(u.dtype)).astype(f32))
    i = jax.nn.sigmoid((u @ params["w_ig"].astype(u.dtype)).astype(f32))
    log_a0 = jax.nn.log_sigmoid(params["lam"].astype(f32))          # (d,)
    log_a = C_CONST * r * log_a0[None, None, :]                     # (B,S,d)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(f32))

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(f32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_train(params, x, return_state=False):
    """Full block over a sequence: (B, S, d) -> (B, S, d)."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_x"].astype(x.dtype)
    u, tail = _causal_conv(params["conv_w"], u)
    h, h_last = _rg_lru_scan(params, u)
    out = (h * gate) @ params["w_out"].astype(x.dtype)
    if return_state:
        return out, (h_last, tail)
    return out


def rglru_decode(params, x, h_prev, conv_tail):
    """One-step decode. x: (B, 1, d); h_prev: (B, d_rnn);
    conv_tail: (B, W-1, d_rnn). Returns (out, h, conv_tail)."""
    gate = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_x"].astype(x.dtype)
    u, new_tail = _causal_conv(params["conv_w"], u, conv_tail)
    h_seq, h_last = _rg_lru_scan(params, u, h0=h_prev)
    out = (h_seq * gate) @ params["w_out"].astype(x.dtype)
    return out, h_last, new_tail


def rglru_state_init(batch, d_rnn, conv_width, dtype):
    return (jnp.zeros((batch, d_rnn), jnp.float32),
            jnp.zeros((batch, conv_width - 1, d_rnn), dtype))
