"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Dispatch avoids the (tokens, experts, capacity) one-hot cube: position-in-
expert is a cumsum over the router assignment (the same trick Dash's
kernels/ops.py uses to route hash queries), then tokens scatter into a dense
(E, capacity, d) block that runs as one batched einsum — expert-parallel
friendly (EXPERT is a sharded logical axis; with EP the scatter becomes an
all_to_all, handled by the partitioner from the sharding annotations).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint
from .layers import EMBED, EXPERT, MLP, truncated_normal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25


def moe_init(key, d, d_ff, cfg: MoEConfig):
    ks = jax.random.split(key, 4)
    E = cfg.n_experts
    s = 1.0 / math.sqrt(d)
    params = {
        "router": truncated_normal(ks[0], (d, E), s),
        "w_gate": truncated_normal(ks[1], (E, d, d_ff), s),
        "w_up": truncated_normal(ks[2], (E, d, d_ff), s),
        "w_down": truncated_normal(ks[3], (E, d_ff, d), 1.0 / math.sqrt(d_ff)),
    }
    specs = {
        "router": (EMBED, None),
        "w_gate": (EXPERT, EMBED, MLP),
        "w_up": (EXPERT, EMBED, MLP),
        "w_down": (EXPERT, MLP, EMBED),
    }
    return params, specs


def moe_capacity(cfg: MoEConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(128, ((cap + 127) // 128) * 128)   # MXU-aligned


def _moe_math(cfg: MoEConfig, x, router_w, wg, wu, wd, cap):
    """Device-local MoE math: router -> row-local dispatch -> expert FFN ->
    weighted collect. Callers provide use-ready (bf16, gathered) weights."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k

    logits = (x @ router_w).astype(jnp.float32)                           # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, K)                          # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux_loss = E * jnp.sum(me * ce)

    flat_exp = experts.reshape(B, S * K)
    onehot = jax.nn.one_hot(flat_exp, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1
    slot = jnp.sum(pos * onehot, axis=-1)
    keep = slot < cap
    dst = jnp.where(keep, flat_exp * cap + slot, E * cap)
    tok_flat = jnp.repeat(jnp.arange(S), K)

    def dispatch_row(xr, dstr):
        return jnp.zeros((E * cap + 1, d), x.dtype).at[dstr].set(xr[tok_flat])

    buf = jax.vmap(dispatch_row)(x, dst)
    eb = buf[:, :E * cap].reshape(B, E, cap, d)

    g = jnp.einsum("becd,edf->becf", eb, wg)
    u = jnp.einsum("becd,edf->becf", eb, wu)
    yb = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, wd)

    ysrc = yb.reshape(B, E * cap, d)
    w = gate_vals.reshape(B, S * K)[..., None].astype(x.dtype)

    def collect_row(ysr, dstr, keepr, wr):
        vals = jnp.where(keepr[:, None],
                         ysr[jnp.clip(dstr, 0, E * cap - 1)], 0.0) * wr
        return jnp.zeros((S, d), x.dtype).at[tok_flat].add(vals)

    y = jax.vmap(collect_row)(ysrc, dst, keep, w)
    return y, aux_loss


def moe_apply_shardmap(params, cfg: MoEConfig, x, mesh, batch_axes,
                       weight_axes=None):
    """Explicit data-parallel MoE under shard_map (production path for the
    'train_dp' layout; EXPERIMENTS.md SSPerf records why).

    Each device owns its batch rows and an FSDP shard of the expert weights.
    The block all-gathers the bf16-cast weights (the transpose of all_gather
    is psum_scatter, so weight gradients reduce-scatter in bf16 for free —
    half the wire of fp32 grad sync), runs the dispatch/FFN entirely locally,
    and touches the fabric for nothing else. SPMD partitioner guessing is out
    of the loop — the collective schedule is exactly what is written here."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    cap = moe_capacity(cfg, S)
    dt = x.dtype
    bx = tuple(batch_axes)                 # x rows sharded over these
    wx = tuple(weight_axes or batch_axes)  # FSDP weight shards over these

    def inner(xl, router, wg, wu, wd):
        from repro.parallel.compression import fsdp_gather_int8
        router = jax.lax.all_gather(router.astype(dt), wx, axis=0, tiled=True)
        wg = fsdp_gather_int8(wg, wx, 1, dt)    # int8 wire, bf16 use,
        wu = fsdp_gather_int8(wu, wx, 1, dt)    # bwd = bf16 reduce-scatter
        wd = fsdp_gather_int8(wd, wx, 2, dt)
        y, aux = _moe_math(cfg, xl, router, wg, wu, wd, cap)
        return y, jax.lax.pmean(aux, bx)

    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bx), P(wx, None), P(None, wx, None),
                  P(None, wx, None), P(None, None, wx)),
        out_specs=(P(bx), P()),
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, aux


def moe_apply_ep_shardmap(params, cfg: MoEConfig, x, mesh, bx, ep_axis,
                          fsdp_axes):
    """True expert parallelism under shard_map: each rank of ``ep_axis`` owns
    E/n experts (FSDP-sharded over ``fsdp_axes`` on the embed dim); tokens
    travel to their experts with one all_to_all each way — activations move
    (~2*S*K*d bf16/device/layer) instead of expert weights, which wins when
    expert weights >> routed activations (phi3.5: 16 experts of 6400-ff vs
    4k tokens). Requires n_experts % size(ep_axis) == 0."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_ep = mesh.shape[ep_axis]
    assert E % n_ep == 0
    E_local = E // n_ep
    cap = moe_capacity(cfg, S)
    dt = x.dtype
    bx = tuple(bx)
    fx = tuple(fsdp_axes)

    def inner(xl, router, wg, wu, wd):
        from repro.parallel.compression import fsdp_gather_int8
        router = jax.lax.all_gather(router.astype(dt), fx, axis=0, tiled=True)
        wg = fsdp_gather_int8(wg, fx, 1, dt)      # (E_local, d, ff)
        wu = fsdp_gather_int8(wu, fx, 1, dt)
        wd = fsdp_gather_int8(wd, fx, 2, dt)

        Bl = xl.shape[0]
        logits = (xl @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, experts = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                            1e-9)
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32),
                      axis=(0, 1))
        aux = E * jnp.sum(me * ce)

        flat_exp = experts.reshape(Bl, S * K)
        onehot = jax.nn.one_hot(flat_exp, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) - 1
        slot = jnp.sum(pos * onehot, axis=-1)
        keep = slot < cap
        dst = jnp.where(keep, flat_exp * cap + slot, E * cap)
        tok_flat = jnp.repeat(jnp.arange(S), K)

        def dispatch_row(xr, dstr):
            return jnp.zeros((E * cap + 1, d), dt).at[dstr].set(xr[tok_flat])

        buf = jax.vmap(dispatch_row)(xl, dst)[:, :E * cap]
        # -> experts to their owners: one a2a out (activations, not weights)
        buf = buf.reshape(Bl, n_ep, E_local * cap, d)
        recv = jax.lax.all_to_all(buf, ep_axis, split_axis=1, concat_axis=0,
                                  tiled=True)              # (Bl*n_ep, 1, ...)
        eb = recv.reshape(Bl * n_ep, E_local, cap, d)

        g = jnp.einsum("becd,edf->becf", eb, wg)
        u = jnp.einsum("becd,edf->becf", eb, wu)
        yb = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, wd)

        # route results home: inverse a2a
        yb = yb.reshape(Bl * n_ep, 1, E_local * cap, d)
        back = jax.lax.all_to_all(yb, ep_axis, split_axis=0, concat_axis=1,
                                  tiled=True)               # (Bl, n_ep, ...)
        ysrc = back.reshape(Bl, E * cap, d)

        w = gate_vals.reshape(Bl, S * K)[..., None].astype(dt)

        def collect_row(ysr, dstr, keepr, wr):
            vals = jnp.where(keepr[:, None],
                             ysr[jnp.clip(dstr, 0, E * cap - 1)], 0.0) * wr
            return jnp.zeros((S, d), dt).at[tok_flat].add(vals)

        y = jax.vmap(collect_row)(ysrc, dst, keep, w)
        return y, jax.lax.pmean(aux, bx)

    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bx), P(fx, None), P(ep_axis, fx, None),
                  P(ep_axis, fx, None), P(ep_axis, None, fx)),
        out_specs=(P(bx), P()),
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, aux


def moe_apply_dense(params, cfg: MoEConfig, x):
    """Dispatch-free MoE for the serving path: compute EVERY expert and
    gate-weight the results. Costs E/k more expert FLOPs but removes all
    scatter/gather — the collective schedule equals a dense TP MLP (the
    vmap-dispatch form inflated MoE prefill to 80 s/step of collectives under
    TP rules; dense-MoE restores dense-level traffic at bounded extra
    compute, the standard trade for inference). No tokens are dropped."""
    from .layers import wuse
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    x = logical_constraint(x, ("batch", None, "act_embed"))
    router = wuse(params["router"], x.dtype, (None, None))
    wg = wuse(params["w_gate"], x.dtype, ("expert", None, "mlp"))
    wu = wuse(params["w_up"], x.dtype, ("expert", None, "mlp"))
    wd = wuse(params["w_down"], x.dtype, ("expert", "mlp", None))

    logits = (x @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, _ = jax.lax.top_k(probs, K)
    thresh = topv[..., -1:]
    gates = jnp.where(probs >= thresh, probs, 0.0)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # (B,S,E)

    g = jnp.einsum("bsd,edf->bsef", x, wg)
    u = jnp.einsum("bsd,edf->bsef", x, wu)
    h = (jax.nn.silu(g) * u) * gates.astype(x.dtype)[..., None]
    y = jnp.einsum("bsef,efd->bsd", h, wd)
    y = logical_constraint(y, ("batch", "seq", "act_embed"))
    return y, jnp.zeros((), jnp.float32)


def moe_apply(params, cfg: MoEConfig, x):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss.

    SPMD-partitioned path: row-local dispatch + gathered-at-use weights.
    Perf history on mixtral x train_4k (EXPERIMENTS.md SSPerf): a flat
    (T, E*cap) scatter replicated the dispatch cube (4.5 TB/dev all-reduce);
    constraint pinning made it worse; only true batch-dim scatters (vmap)
    plus gathered-at-use weights tame it — and the fully explicit
    ``moe_apply_shardmap`` below is the production choice for the pure-DP
    layout (selected by the '_moe_shardmap' rules flag)."""
    from repro.parallel import sharding as shd
    mesh = shd.active_mesh()
    if mesh is not None and shd.flag("_moe_dense"):
        return moe_apply_dense(params, cfg, x)
    if (mesh is not None and shd.flag("_moe_ep")
            and "model" in mesh.axis_names
            and cfg.n_experts % mesh.shape["model"] == 0):
        bx = shd.batch_axes(x.shape[0])
        fx = shd.axes_for("embed", params["w_gate"].shape[1])
        if bx and fx:
            return moe_apply_ep_shardmap(params, cfg, x, mesh, bx, "model", fx)
    if mesh is not None and shd.flag("_moe_shardmap"):
        bx = shd.batch_axes(x.shape[0])
        wx = shd.axes_for("embed", params["w_gate"].shape[1])
        if bx and wx:
            return moe_apply_shardmap(params, cfg, x, mesh, bx, wx)

    B, S, d = x.shape
    cap = moe_capacity(cfg, S)
    # Megatron-SP discipline: gather the sequence-sharded residual once at
    # layer entry so the row-local dispatch stays device-local.
    x = logical_constraint(x, ("batch", None, "act_embed"))
    from .layers import wuse
    router = wuse(params["router"], x.dtype, (None, None))
    wg = wuse(params["w_gate"], x.dtype, ("expert", None, "mlp"))
    wu = wuse(params["w_up"], x.dtype, ("expert", None, "mlp"))
    wd = wuse(params["w_down"], x.dtype, ("expert", "mlp", None))
    y, aux_loss = _moe_math(cfg, x, router, wg, wu, wd, cap)
    y = logical_constraint(y, ("batch", "seq", "act_embed"))   # back to SP
    return y, aux_loss
