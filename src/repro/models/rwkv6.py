"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay, + squared-ReLU channel mixing.

Time mixing is computed in **chunked linear-attention form** (the standard
GLA/RWKV chunk trick): within a chunk of length C the intra-chunk term is a
masked (C x C) matmul weighted by cumulative decays; across chunks a per-head
(hd x hd) state carries, updated with the chunk's total decay. Work is
O(S * C * hd) — sub-quadratic, so rwkv6 runs the long_500k shape.

Decode is the plain recurrence on the (H, hd, hd) state: O(1) per token.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import EMBED, HEADS, MLP, truncated_normal

HEAD_SIZE = 64
CHUNK = 64
# Per-step log-decay clamp: the matmul form uses exp(-cum) factors whose
# exponents are bounded by |logw|*CHUNK; clamping keeps them inside f32 range
# (|0.35|*64 ~ e^22). Real RWKV kernels avoid this with sequential fp32 state;
# our TPU chunk form trades a bounded decay floor for MXU throughput
# (deviation documented in DESIGN.md; decay_base init makes the clamp
# inactive at initialization).
LOGW_MIN = -0.35


def rwkv6_init(key, d, d_ff):
    H = d // HEAD_SIZE
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    params = {
        # time mixing
        "w_r": truncated_normal(ks[0], (d, d), s),
        "w_k": truncated_normal(ks[1], (d, d), s),
        "w_v": truncated_normal(ks[2], (d, d), s),
        "w_g": truncated_normal(ks[3], (d, d), s),
        "w_o": truncated_normal(ks[4], (d, d), s),
        "w_decay": truncated_normal(ks[5], (d, d), 0.1 * s),   # data-dependent decay
        "decay_base": -6.0 + jax.random.uniform(ks[6], (d,), jnp.float32),
        "bonus_u": 0.5 * jax.random.uniform(ks[7], (d,), jnp.float32),
        # token-shift mix coefficients (static flavor of v6 LoRA mixing)
        "mix_r": jax.random.uniform(ks[8], (d,), jnp.float32),
        "mix_kv": jax.random.uniform(ks[9], (d,), jnp.float32),
        # channel mixing
        "cm_k": truncated_normal(ks[10], (d, d_ff), s),
        "cm_v": truncated_normal(ks[11], (d_ff, d), 1.0 / math.sqrt(d_ff)),
    }
    specs = {
        "w_r": (EMBED, HEADS), "w_k": (EMBED, HEADS), "w_v": (EMBED, HEADS),
        "w_g": (EMBED, HEADS), "w_o": (HEADS, EMBED), "w_decay": (EMBED, HEADS),
        "decay_base": (HEADS,), "bonus_u": (HEADS,),
        "mix_r": (EMBED,), "mix_kv": (EMBED,),
        "cm_k": (EMBED, MLP), "cm_v": (MLP, EMBED),
    }
    return params, specs


def _token_shift(x, mix, last=None):
    """x_t' = x_t * mix + x_{t-1} * (1-mix). last: (B, 1, d) carry."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    return x * mix.astype(x.dtype) + prev * (1.0 - mix).astype(x.dtype), x[:, -1:]


def _heads(x, H):
    B, S, d = x.shape
    return x.reshape(B, S, H, HEAD_SIZE).transpose(0, 2, 1, 3)   # (B,H,S,hd)


def _wkv_chunked(r, k, v, w, u, state0=None):
    """Chunked WKV. r,k,v,w: (B,H,S,hd) f32; w = per-step decay in (0,1);
    u: (H, hd) bonus. Returns (out (B,H,S,hd), state (B,H,hd,hd))."""
    B, H, S, hd = r.shape
    C = min(CHUNK, S)
    n = S // C
    rc = r.reshape(B, H, n, C, hd)
    kc = k.reshape(B, H, n, C, hd)
    vc = v.reshape(B, H, n, C, hd)
    logw = jnp.maximum(jnp.log(jnp.maximum(w, 1e-8)), LOGW_MIN).reshape(B, H, n, C, hd)
    cum = jnp.cumsum(logw, axis=3)                      # inclusive decay prefix
    total = cum[:, :, :, -1:]                           # (B,H,n,1,hd)

    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def chunk_step(state, ci):
        rs, ks_, vs, cs, tot = (rc[:, :, ci], kc[:, :, ci], vc[:, :, ci],
                                cum[:, :, ci], total[:, :, ci])
        # inter-chunk: r_t decayed into the carried state
        r_dec = rs * jnp.exp(cs - logw.reshape(B, H, n, C, hd)[:, :, ci])  # decay BEFORE t
        inter = jnp.einsum("bhck,bhkd->bhcd", r_dec, state)
        # intra-chunk: A[t,s] = sum_c r[t,c] e^{cum_t - logw_t - cum_s} k[s,c], s<t
        r_w = rs * jnp.exp(cs - logw.reshape(B, H, n, C, hd)[:, :, ci])
        k_w = ks_ * jnp.exp(-cs)
        A = jnp.einsum("bhtc,bhsc->bhts", r_w, k_w)
        mask = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)
        A = A * mask[None, None]
        intra = jnp.einsum("bhts,bhsd->bhtd", A, vs)
        # current-token bonus: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bhtc,bhtc->bht", rs, u[None, :, None, :] * ks_)
        out = inter + intra + bonus[..., None] * vs
        # state update: S' = diag(e^{tot}) S + sum_s e^{tot - cum_s} k_s v_s^T
        k_dec = ks_ * jnp.exp(tot - cs)
        state = jnp.exp(tot).transpose(0, 1, 3, 2) * state + jnp.einsum(
            "bhsc,bhsd->bhcd", k_dec, vs)
        return state, out

    state, outs = jax.lax.scan(chunk_step, state0, jnp.arange(n))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    return out, state


def rwkv6_time_mix(params, x, shift_last=None, wkv_state=None):
    """(B, S, d) -> (B, S, d); returns (out, (shift_last, wkv_state))."""
    B, S, d = x.shape
    H = d // HEAD_SIZE
    xr, last = _token_shift(x, params["mix_r"], shift_last)
    xkv, _ = _token_shift(x, params["mix_kv"], shift_last)

    r = _heads(xr @ params["w_r"].astype(x.dtype), H).astype(jnp.float32)
    k = _heads(xkv @ params["w_k"].astype(x.dtype), H).astype(jnp.float32)
    v = _heads(xkv @ params["w_v"].astype(x.dtype), H).astype(jnp.float32)
    g = jax.nn.silu(x @ params["w_g"].astype(x.dtype))

    # data-dependent decay (v6): w_t = exp(-exp(base + W_d x_t)) in (0,1)
    dd = (xkv @ params["w_decay"].astype(x.dtype)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(params["decay_base"][None, None] + dd))
    w = _heads(w.astype(jnp.float32), H)
    u = params["bonus_u"].reshape(H, HEAD_SIZE)

    out, state = _wkv_chunked(r, k, v, w, u, wkv_state)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, d).astype(x.dtype)
    out = (out * g) @ params["w_o"].astype(x.dtype)
    return out, (last, state)


def rwkv6_time_mix_decode(params, x, shift_last, wkv_state):
    """O(1) recurrence for one token. x: (B, 1, d)."""
    B, _, d = x.shape
    H = d // HEAD_SIZE
    mix_r, mix_kv = params["mix_r"], params["mix_kv"]
    xr = x * mix_r.astype(x.dtype) + shift_last * (1 - mix_r).astype(x.dtype)
    xkv = x * mix_kv.astype(x.dtype) + shift_last * (1 - mix_kv).astype(x.dtype)

    r = _heads(xr @ params["w_r"].astype(x.dtype), H)[:, :, 0].astype(jnp.float32)
    k = _heads(xkv @ params["w_k"].astype(x.dtype), H)[:, :, 0].astype(jnp.float32)
    v = _heads(xkv @ params["w_v"].astype(x.dtype), H)[:, :, 0].astype(jnp.float32)
    g = jax.nn.silu(x @ params["w_g"].astype(x.dtype))

    dd = (xkv @ params["w_decay"].astype(x.dtype)).astype(jnp.float32)
    w = jnp.exp(jnp.maximum(-jnp.exp(params["decay_base"][None, None] + dd),
                            LOGW_MIN))   # same clamp as the chunked form
    w = _heads(w, H)[:, :, 0]                                     # (B,H,hd)
    u = params["bonus_u"].reshape(H, HEAD_SIZE)

    kv = jnp.einsum("bhc,bhd->bhcd", k, v)
    out = jnp.einsum("bhc,bhcd->bhd", r, wkv_state + u[None, :, :, None] * kv)
    new_state = w[..., None] * wkv_state + kv
    out = out.reshape(B, 1, d).astype(x.dtype)
    out = (out * g) @ params["w_o"].astype(x.dtype)
    return out, (x, new_state)


def rwkv6_channel_mix(params, x, shift_last=None):
    xs, last = _token_shift(x, params["mix_kv"], shift_last)
    h = jnp.square(jax.nn.relu(xs @ params["cm_k"].astype(x.dtype)))
    return h @ params["cm_v"].astype(x.dtype), last


def rwkv6_state_init(batch, d, dtype):
    H = d // HEAD_SIZE
    return (jnp.zeros((batch, 1, d), dtype),                     # token-shift tail
            jnp.zeros((batch, H, HEAD_SIZE, HEAD_SIZE), jnp.float32))  # wkv state
