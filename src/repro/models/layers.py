"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full / sliding
window / banded-flash), SwiGLU MLP — pure functional JAX with explicit
logical-axis sharding specs.

Conventions:
  * params are dicts of fp32 arrays; compute casts to ``cfg.dtype`` (bf16).
  * every init returns (params, specs) where specs mirrors params with tuples
    of *logical* axis names; parallel/sharding.py maps them to mesh axes.
  * attention is 'flash-style': an online-softmax scan over KV chunks, with a
    **static band** optimization for sliding-window layers (only the chunks
    intersecting the window are visited — this is what makes long_500k
    sub-quadratic for SWA/local-attention architectures).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# logical axis names (resolved by parallel/sharding.py)
EMBED, HEADS, KV, HEAD_DIM, MLP, VOCAB, EXPERT, LAYERS, RNN = (
    "embed", "heads", "kv", "head_dim", "mlp", "vocab", "expert", "layers", "rnn")


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def wuse(w, dtype, names):
    """Cast a weight for use and pin its use-layout: the FSDP-sharded dims
    (logical 'embed') are GATHERED here (bf16 wire), never contracted while
    sharded — XLA otherwise may choose partial-sum + all-reduce of the fp32
    activations, which measured 10-50 TB/step on MoE cells (EXPERIMENTS.md)."""
    from repro.parallel.sharding import logical_constraint
    return logical_constraint(w.astype(dtype), names)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": (EMBED,)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs       # (..., S, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None = full causal
    q_chunk: int = 512
    kv_chunk: int = 1024


def attention_init(key, cfg: AttnConfig):
    d, H, G, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    params = {
        "wq": truncated_normal(ks[0], (d, H * hd), s),
        "wk": truncated_normal(ks[1], (d, G * hd), s),
        "wv": truncated_normal(ks[2], (d, G * hd), s),
        "wo": truncated_normal(ks[3], (H * hd, d), 1.0 / math.sqrt(H * hd)),
    }
    specs = {"wq": (EMBED, HEADS), "wk": (EMBED, KV), "wv": (EMBED, KV),
             "wo": (HEADS, EMBED)}
    return params, specs


def _chunked_attention(q, k, v, q_start, kv_start, causal_offset, window):
    """Online-softmax over KV chunks for one query block.

    q: (B, H, Tq, hd); k, v: (B, G, Skv, hd) with H % G == 0.
    Positions: query i sits at q_start + i, key j at kv_start + j; causal
    constraint is key_pos <= query_pos + causal_offset (offset 0 normally).
    """
    B, H, Tq, hd = q.shape
    G = k.shape[1]
    rep = H // G
    scale = 1.0 / math.sqrt(hd)
    Skv = k.shape[2]
    kc = min(Skv, 1024)
    pad = (-Skv) % kc
    if pad:
        # padded keys sit at positions >= real length: masked by causality
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Skv + pad) // kc
    q32 = q.astype(jnp.float32) * scale

    def body(carry, ci):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ci * kc, kc, axis=2)
        vs = jax.lax.dynamic_slice_in_dim(v, ci * kc, kc, axis=2)
        ks = jnp.repeat(ks, rep, axis=1).astype(jnp.float32)
        vs = jnp.repeat(vs, rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, ks)
        qpos = q_start + jnp.arange(Tq)
        kpos = kv_start + ci * kc + jnp.arange(kc)
        mask = kpos[None, :] <= (qpos[:, None] + causal_offset)
        if window is not None:
            mask &= kpos[None, :] > (qpos[:, None] + causal_offset - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + jnp.sum(p, axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vs)
        return (m2, l2, acc2), None

    init = (jnp.full((B, H, Tq), -1e30, jnp.float32),
            jnp.zeros((B, H, Tq), jnp.float32),
            jnp.zeros((B, H, Tq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def attention_train(params, cfg: AttnConfig, x, positions, return_kv=False):
    """Full-sequence causal attention (training / prefill).

    Sliding-window layers use a *static band*: each query block only visits
    the KV slice [block_start - window, block_end), so cost is O(S * window)
    instead of O(S^2).

    Sharding: Megatron-SP pattern pinned by explicit constraints — the
    sequence-sharded residual stream is all-gathered once at attention entry,
    q shards on heads, k/v on kv-heads when divisible (else replicated: GQA
    k/v are small). Without these pins SPMD propagation materializes fully
    replicated K/V inside the flash loops (measured: ~450x collective blowup)."""
    from repro.parallel.sharding import logical_constraint
    B, S, _ = x.shape
    H, G, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ wuse(params["wq"], x.dtype, (None, "heads"))).reshape(
        B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ wuse(params["wk"], x.dtype, (None, "kv"))).reshape(
        B, S, G, hd).transpose(0, 2, 1, 3)
    v = (x @ wuse(params["wv"], x.dtype, (None, "kv"))).reshape(
        B, S, G, hd).transpose(0, 2, 1, 3)
    q = logical_constraint(q, ("batch", "act_heads", None, None))
    k = logical_constraint(k, ("batch", "act_kv", None, None))
    v = logical_constraint(v, ("batch", "act_kv", None, None))
    q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)

    S_real = S
    qc = min(cfg.q_chunk, S)
    qpad = (-S) % qc
    if qpad:
        # pad queries (outputs trimmed) — padded keys are causally invisible
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
        S = S + qpad
    n_q = S // qc
    W = cfg.sliding_window

    if W is not None and W < S:
        band = int(2 ** math.ceil(math.log2(W + qc)))   # static KV band
        band = min(band, k.shape[2])

        def qblock(qi):
            qs = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=2)
            start = jnp.maximum(qi * qc + qc - band, 0)
            ks = jax.lax.dynamic_slice_in_dim(k, start, band, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, start, band, axis=2)
            return _chunked_attention(qs, ks, vs, qi * qc, start, 0, W)

        out = jax.lax.map(qblock, jnp.arange(n_q))        # (n_q, B, H, qc, hd)
    else:
        def qblock(qi):
            qs = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=2)
            return _chunked_attention(qs, k, v, qi * qc, 0, 0, W)

        out = jax.lax.map(qblock, jnp.arange(n_q))

    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    out = out[:, :, :S_real]
    out = out.transpose(0, 2, 1, 3).reshape(B, S_real, H * hd)
    out = out @ wuse(params["wo"], x.dtype, ("heads", None))
    if return_kv:
        # roped K/V (B, G, S, hd) for cache assembly
        return out, (k[:, :, :S_real], v[:, :, :S_real])
    return out


def attention_decode(params, cfg: AttnConfig, x, cache_k, cache_v, cache_len):
    """One-token decode against a KV cache.

    cache_k/v: (B, G, C, hd) — C = full context for dense layers, or the
    ring-buffer window for SWA layers. Returns (out, new_k, new_v)."""
    B, _, _ = x.shape
    H, G, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    C = cache_k.shape[2]
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, 1, G, hd).transpose(0, 2, 1, 3)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, 1, G, hd).transpose(0, 2, 1, 3)
    pos = cache_len[:, None, None]                       # (B,1,1) true position
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    # ring-buffer write via where (a one-hot MULTIPLY update made SPMD
    # all-gather the whole cache in f32 — 17 GB/token on yi decode_32k;
    # the where + explicit cache-layout pins keep the update shard-local)
    from repro.parallel.sharding import logical_constraint
    slot = jnp.mod(cache_len, C)                         # (B,)
    is_slot = (jnp.arange(C)[None, :] == slot[:, None])[:, None, :, None]
    cache_k = jnp.where(is_slot, k, cache_k)
    cache_v = jnp.where(is_slot, v, cache_v)
    cache_k = logical_constraint(cache_k, ("batch", "kv_heads", "cache", "head_dim"))
    cache_v = logical_constraint(cache_v, ("batch", "kv_heads", "cache", "head_dim"))

    rep = H // G
    # Grouped-query einsum DIRECTLY against the cache: no jnp.repeat — the
    # broadcast made SPMD all-gather the f32-converted cache along its
    # sharded length (2 x 17 GB/token measured on yi decode_32k). bf16 reads
    # with f32 accumulation also halve the dominant HBM (cache-stream) term.
    q5 = (q / math.sqrt(hd)).astype(cache_k.dtype).reshape(B, G, rep, 1, hd)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q5, cache_k,
                   preferred_element_type=jnp.float32)
    # valid = slots < cache_len+1 (ring: all slots valid once wrapped)
    ages = jnp.arange(C)[None, :]
    valid = ages < jnp.minimum(cache_len + 1, C)[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(B, H, 1, hd).transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d, d_ff):
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    params = {
        "w_gate": truncated_normal(ks[0], (d, d_ff), s),
        "w_up": truncated_normal(ks[1], (d, d_ff), s),
        "w_down": truncated_normal(ks[2], (d_ff, d), 1.0 / math.sqrt(d_ff)),
    }
    specs = {"w_gate": (EMBED, MLP), "w_up": (EMBED, MLP), "w_down": (MLP, EMBED)}
    return params, specs


def mlp(params, x):
    dt = x.dtype
    g = x @ wuse(params["w_gate"], dt, (None, "mlp"))
    u = x @ wuse(params["w_up"], dt, (None, "mlp"))
    return (jax.nn.silu(g) * u) @ wuse(params["w_down"], dt, ("mlp", None))


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d):
    params = {"table": truncated_normal(key, (vocab, d), 1.0)}
    return params, {"table": (VOCAB, EMBED)}


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed_init(key, d, vocab):
    params = {"w": truncated_normal(key, (d, vocab), 1.0 / math.sqrt(d))}
    return params, {"w": (EMBED, VOCAB)}


def unembed(params, x):
    return x @ wuse(params["w"], x.dtype, (None, "vocab"))
