"""Composable decoder stack: dense / MoE / hybrid-recurrent / RWKV blocks,
scan-over-layers, train forward + loss, and O(1)-state serve step.

The stack is declared by ``pattern`` — a repeating tuple of block kinds:

    dense:  [RMSNorm -> GQA attention -> +] [RMSNorm -> SwiGLU -> +]
    local:  same, attention windowed to cfg.local_window
    moe:    attention block + top-k MoE FFN
    rglru:  RG-LRU recurrent block + SwiGLU
    rwkv:   RWKV6 time mix + RWKV6 channel mix

``n_layers = len(pattern) * n_blocks + len(tail)``; the majority runs under a
single ``lax.scan`` over stacked block params (small HLO, fast SPMD compile),
the remainder (``n_layers mod len(pattern)``) as explicit tail layers —
e.g. recurrentgemma-9b's 38 = (rglru, rglru, local) x 12 + (rglru, rglru).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint
from . import layers as L
from . import moe as M
from . import rglru as R
from . import rwkv6 as W


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 1_000_000.0
    sliding_window: Optional[int] = None     # SWA for 'dense' blocks
    pattern: tuple = ("dense",)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    d_rnn: int = 0                 # rglru width (0 -> d_model)
    conv_width: int = 4
    local_window: int = 2048
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "dots"            # full | dots | none
    q_chunk: int = 512
    kv_chunk: int = 1024
    num_patches: int = 576         # vlm stub patches (prepended)
    sub_quadratic: bool = False    # may run long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> tuple:
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def attn_cfg(self, local: bool) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            rope_theta=self.rope_theta,
            sliding_window=self.local_window if local else self.sliding_window,
            q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)

    def moe_cfg(self) -> M.MoEConfig:
        return M.MoEConfig(self.n_experts, self.top_k, self.capacity_factor)

    def param_count(self) -> int:
        """Analytic total parameters (for 6ND roofline accounting)."""
        d, ff, hd = self.d_model, self.d_ff, self.hd
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        mlp = 3 * d * ff
        per = {"dense": attn + mlp, "local": attn + mlp,
               "moe": attn + d * self.n_experts + 3 * d * ff * self.n_experts,
               "rglru": 2 * d * (self.d_rnn or d) + (self.d_rnn or d) * d
                        + 2 * (self.d_rnn or d) ** 2 + mlp,
               "rwkv": 6 * d * d + 3 * d * ff}
        kinds = list(self.pattern) * self.n_blocks + list(self.tail)
        total = sum(per[k] for k in kinds)
        total += self.vocab_size * d                      # embed
        total += d * self.vocab_size                      # lm head
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if "moe" not in self.pattern:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        full = self.param_count()
        inactive = (self.n_experts - self.top_k) * 3 * d * ff
        n_moe = sum(1 for k in list(self.pattern) * self.n_blocks + list(self.tail)
                    if k == "moe")
        return full - n_moe * inactive


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p, s = {}, {}
    p["norm1"], s["norm1"] = L.rmsnorm_init(d)
    p["norm2"], s["norm2"] = L.rmsnorm_init(d)
    if kind in ("dense", "local", "moe"):
        p["attn"], s["attn"] = L.attention_init(ks[0], cfg.attn_cfg(kind == "local"))
        if kind == "moe":
            p["moe"], s["moe"] = M.moe_init(ks[1], d, cfg.d_ff, cfg.moe_cfg())
        else:
            p["mlp"], s["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff)
    elif kind == "rglru":
        p["rnn"], s["rnn"] = R.rglru_init(ks[0], d, cfg.d_rnn or d, cfg.conv_width)
        p["mlp"], s["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff)
    elif kind == "rwkv":
        p["tm"], s["tm"] = W.rwkv6_init(ks[0], d, cfg.d_ff)
    else:
        raise ValueError(kind)
    return p, s


def init_params(key, cfg: ModelConfig):
    """Returns (params, specs) — specs hold logical-axis tuples."""
    keys = jax.random.split(key, 8)
    params: dict = {}
    specs: dict = {}

    if cfg.family != "audio":
        params["embed"], specs["embed"] = L.embed_init(keys[0], cfg.vocab_size,
                                                       cfg.d_model)
    # stacked pattern blocks (specs are static: take them from one example
    # init — dead-code-eliminated under jit/eval_shape)
    for pi, kind in enumerate(cfg.pattern):
        bkeys = jax.random.split(jax.random.fold_in(keys[1], pi), cfg.n_blocks)
        p = jax.vmap(lambda k: _block_init(k, cfg, kind)[0])(bkeys)
        s = _block_init(jax.random.PRNGKey(0), cfg, kind)[1]
        params[f"blocks_{pi}"] = p
        specs[f"blocks_{pi}"] = jax.tree.map(
            lambda names: (L.LAYERS,) + tuple(names), s,
            is_leaf=lambda x: isinstance(x, tuple))
    # tail blocks
    for ti, kind in enumerate(cfg.tail):
        p, s = _block_init(jax.random.fold_in(keys[2], ti), cfg, kind)
        params[f"tail_{ti}"] = p
        specs[f"tail_{ti}"] = s

    params["norm_f"], specs["norm_f"] = L.rmsnorm_init(cfg.d_model)
    params["lm_head"], specs["lm_head"] = L.unembed_init(keys[3], cfg.d_model,
                                                         cfg.vocab_size)
    return params, specs


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct params, specs) — dry-run: no allocation.

    Specs are static python data assembled at trace time; capture them from
    the eval_shape trace (arrays abstracted, specs side-channeled)."""
    captured = {}

    def build(key):
        p, s = init_params(key, cfg)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def param_specs(cfg: ModelConfig):
    return abstract_params(cfg)[1]


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------

def _apply_block(p, cfg: ModelConfig, kind: str, x, positions):
    if kind in ("dense", "local", "moe"):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + L.attention_train(p["attn"], cfg.attn_cfg(kind == "local"), h,
                                  positions)
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            y, aux = M.moe_apply(p["moe"], cfg.moe_cfg(), h)
            return x + y, aux
        return x + L.mlp(p["mlp"], h), 0.0
    if kind == "rglru":
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + R.rglru_train(p["rnn"], h)
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        return x + L.mlp(p["mlp"], h), 0.0
    if kind == "rwkv":
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, _ = W.rwkv6_time_mix(p["tm"], h)
        x = x + y
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, _ = W.rwkv6_channel_mix(p["tm"], h)
        return x + y, 0.0
    raise ValueError(kind)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def inputs_to_embeddings(params, cfg: ModelConfig, batch):
    """Map the modality front (stubbed for vlm/audio) to (B, S, d) + positions."""
    dt = cfg.compute_dtype
    if cfg.family == "audio":
        x = batch["frame_embeds"].astype(dt)
    elif cfg.family == "vlm":
        tok = L.embed(params["embed"], batch["tokens"], dt)
        x = jnp.concatenate([batch["patch_embeds"].astype(dt), tok], axis=1)
    else:
        x = L.embed(params["embed"], batch["tokens"], dt)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def forward_train(params, cfg: ModelConfig, batch):
    """Full-sequence forward. Returns (logits_f32, aux_loss)."""
    x, positions = inputs_to_embeddings(params, cfg, batch)
    x = logical_constraint(x, ("batch", "seq", "act_embed"))
    aux = jnp.zeros((), jnp.float32)

    def body(carry, block_ps):
        x, aux = carry
        for pi, kind in enumerate(cfg.pattern):
            x, a = _apply_block(block_ps[pi], cfg, kind, x, positions)
            x = logical_constraint(x, ("batch", "seq", "act_embed"))
            aux = aux + a
        return (x, aux), None

    # xs = tuple of per-pattern-position stacks (heterogeneous structures ok)
    xs = tuple(params[f"blocks_{pi}"] for pi in range(len(cfg.pattern)))
    (x, aux), _ = jax.lax.scan(_remat(cfg, body), (x, aux), xs)

    for ti, kind in enumerate(cfg.tail):
        x, a = _apply_block(params[f"tail_{ti}"], cfg, kind, x, positions)
        aux = aux + a

    x = L.rmsnorm(params["norm_f"], x, cfg.norm_eps)
    logits = L.unembed(params["lm_head"], x).astype(jnp.float32)
    logits = logical_constraint(logits, ("batch", "seq", "vocab"))
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, aux_weight: float = 0.01,
            z_weight: float = 1e-4):
    """Causal-LM cross entropy (+ MoE aux + z-loss). labels < 0 are masked.
    For vlm, labels cover only the text positions (suffix of the sequence)."""
    logits, aux = forward_train(params, cfg, batch)
    labels = batch["labels"]
    if cfg.family == "vlm":
        logits = logits[:, -labels.shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    zl = jnp.sum(jnp.square(lse) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux_weight * aux + z_weight * zl, (ce, aux)


def _apply_block_prefill(p, cfg: ModelConfig, kind: str, x, positions,
                         cache_len: int):
    """Like _apply_block but also emits the decode-state entry (ring cache /
    recurrent state) so serving can continue from a prefill."""
    S = x.shape[1]

    def ring(k):
        # place position p at ring slot p % C (decode's write discipline)
        C = cache_len if kind != "local" else min(cache_len, cfg.local_window)
        if kind in ("dense", "moe") and cfg.sliding_window is not None:
            C = min(cache_len, cfg.sliding_window)
        C = min(C, cache_len)
        lastC = k[:, :, -min(C, S):]
        if lastC.shape[2] < C:
            lastC = jnp.pad(lastC, ((0, 0), (0, 0), (0, C - lastC.shape[2]),
                                    (0, 0)))
            return lastC          # S <= C: slots 0..S-1 already correct
        return jnp.roll(lastC, S % C, axis=2)

    if kind in ("dense", "local", "moe"):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        a, (k, v) = L.attention_train(p["attn"], cfg.attn_cfg(kind == "local"),
                                      h, positions, return_kv=True)
        x = x + a
        st = {"k": ring(k), "v": ring(v)}
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            y, _ = M.moe_apply(p["moe"], cfg.moe_cfg(), h)
            return x + y, st
        return x + L.mlp(p["mlp"], h), st
    if kind == "rglru":
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, (hl, tail) = R.rglru_train(p["rnn"], h, return_state=True)
        x = x + y
        st = {"h": hl, "conv": tail}
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        return x + L.mlp(p["mlp"], h), st
    if kind == "rwkv":
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, (shift, wkv) = W.rwkv6_time_mix(p["tm"], h)
        x = x + y
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, cm_shift = W.rwkv6_channel_mix(p["tm"], h2)
        return x + y, {"shift_tm": shift, "wkv": wkv, "shift_cm": cm_shift}
    raise ValueError(kind)


def forward_prefill(params, cfg: ModelConfig, batch, cache_len: int):
    """Full-prompt forward that ALSO builds the decode state (KV ring caches
    at their correct slots / final recurrent states). Returns
    (logits_f32, decode_state) ready for serve_step continuation."""
    x, positions = inputs_to_embeddings(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]

    def body(x, block_ps):
        sts = []
        for pi, kind in enumerate(cfg.pattern):
            x, st = _apply_block_prefill(block_ps[pi], cfg, kind, x, positions,
                                         cache_len)
            sts.append(st)
        return x, tuple(sts)

    xs = tuple(params[f"blocks_{pi}"] for pi in range(len(cfg.pattern)))
    x, stacked_states = jax.lax.scan(body, x, xs)

    state = {"pos": jnp.full((B,), S, jnp.int32)}
    for pi in range(len(cfg.pattern)):
        state[f"blocks_{pi}"] = stacked_states[pi]
    for ti, kind in enumerate(cfg.tail):
        x, st = _apply_block_prefill(params[f"tail_{ti}"], cfg, kind, x,
                                     positions, cache_len)
        state[f"tail_{ti}"] = st

    x = L.rmsnorm(params["norm_f"], x, cfg.norm_eps)
    logits = L.unembed(params["lm_head"], x).astype(jnp.float32)
    return logits, state


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def _block_state_init(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    dt = cfg.compute_dtype
    G, hd = cfg.n_kv_heads, cfg.hd
    if kind in ("dense", "moe"):
        C = cache_len if cfg.sliding_window is None else min(
            cache_len, cfg.sliding_window)
        return {"k": jnp.zeros((batch, G, C, hd), dt),
                "v": jnp.zeros((batch, G, C, hd), dt)}
    if kind == "local":
        C = min(cache_len, cfg.local_window)
        return {"k": jnp.zeros((batch, G, C, hd), dt),
                "v": jnp.zeros((batch, G, C, hd), dt)}
    if kind == "rglru":
        h, tail = R.rglru_state_init(batch, cfg.d_rnn or cfg.d_model,
                                     cfg.conv_width, dt)
        return {"h": h, "conv": tail}
    if kind == "rwkv":
        s1, wkv = W.rwkv6_state_init(batch, cfg.d_model, dt)
        return {"shift_tm": s1, "wkv": wkv,
                "shift_cm": jnp.zeros_like(s1)}
    raise ValueError(kind)


def decode_state_init(cfg: ModelConfig, batch: int, cache_len: int):
    """Per-layer decode state + the position counter."""
    state = {}
    for pi, kind in enumerate(cfg.pattern):
        one = _block_state_init(cfg, kind, batch, cache_len)
        state[f"blocks_{pi}"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (cfg.n_blocks,) + t.shape), one)
    for ti, kind in enumerate(cfg.tail):
        state[f"tail_{ti}"] = _block_state_init(cfg, kind, batch, cache_len)
    state["pos"] = jnp.zeros((batch,), jnp.int32)
    return state


def _block_state_specs(kind: str):
    if kind in ("dense", "moe", "local"):
        return {"k": ("batch", "kv_heads", "cache", "head_dim"),
                "v": ("batch", "kv_heads", "cache", "head_dim")}
    if kind == "rglru":
        return {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
    if kind == "rwkv":
        return {"shift_tm": ("batch", None, "act_embed"),
                "wkv": ("batch", "heads", None, None),
                "shift_cm": ("batch", None, "act_embed")}
    raise ValueError(kind)


def decode_state_specs(cfg: ModelConfig):
    """Logical-axis spec tree matching decode_state_init."""
    specs = {}
    for pi, kind in enumerate(cfg.pattern):
        specs[f"blocks_{pi}"] = jax.tree.map(
            lambda names: ("layers",) + tuple(names), _block_state_specs(kind),
            is_leaf=lambda x: isinstance(x, tuple))
    for ti, kind in enumerate(cfg.tail):
        specs[f"tail_{ti}"] = _block_state_specs(kind)
    specs["pos"] = ("batch",)
    return specs


def _apply_block_decode(p, st, cfg: ModelConfig, kind: str, x, pos):
    if kind in ("dense", "local", "moe"):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        a, nk, nv = L.attention_decode(p["attn"], cfg.attn_cfg(kind == "local"),
                                       h, st["k"], st["v"], pos)
        x = x + a
        st = {"k": nk, "v": nv}
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            y, _ = M.moe_apply(p["moe"], cfg.moe_cfg(), h)
            return x + y, st
        return x + L.mlp(p["mlp"], h), st
    if kind == "rglru":
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, nh, ntail = R.rglru_decode(p["rnn"], h, st["h"], st["conv"])
        x = x + y
        st = {"h": nh, "conv": ntail}
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        return x + L.mlp(p["mlp"], h), st
    if kind == "rwkv":
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, (nshift, nwkv) = W.rwkv6_time_mix_decode(p["tm"], h, st["shift_tm"],
                                                    st["wkv"])
        x = x + y
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        y, ncm = W.rwkv6_channel_mix(p["tm"], h, st["shift_cm"])
        return x + y, {"shift_tm": nshift, "wkv": nwkv, "shift_cm": ncm}
    raise ValueError(kind)


def serve_step(params, cfg: ModelConfig, state, inputs):
    """One decode step: new token(s) in, logits + updated state out."""
    dt = cfg.compute_dtype
    if cfg.family == "audio":
        x = inputs["frame_embeds"].astype(dt)          # (B, 1, d)
    else:
        x = L.embed(params["embed"], inputs["token"][:, None], dt)
    pos = state["pos"]
    x = logical_constraint(x, ("batch", None, "act_embed"))

    new_state = {"pos": pos + 1}

    def body(x, xs):
        block_ps, block_sts = xs
        sts = []
        for pi, kind in enumerate(cfg.pattern):
            x, ns = _apply_block_decode(block_ps[pi], block_sts[pi], cfg, kind,
                                        x, pos)
            sts.append(ns)
        return x, tuple(sts)

    xs_p = tuple(params[f"blocks_{pi}"] for pi in range(len(cfg.pattern)))
    xs_s = tuple(state[f"blocks_{pi}"] for pi in range(len(cfg.pattern)))
    x, out_states = jax.lax.scan(body, x, (xs_p, xs_s))
    for pi in range(len(cfg.pattern)):
        new_state[f"blocks_{pi}"] = out_states[pi]

    for ti, kind in enumerate(cfg.tail):
        x, ns = _apply_block_decode(params[f"tail_{ti}"], state[f"tail_{ti}"],
                                    cfg, kind, x, pos)
        new_state[f"tail_{ti}"] = ns

    x = L.rmsnorm(params["norm_f"], x, cfg.norm_eps)
    logits = L.unembed(params["lm_head"], x).astype(jnp.float32)[:, 0]
    return logits, new_state
