"""Dash-LH document dedup: the paper's insert-heavy workload as a real
pipeline stage. Key = 64-bit content hash of the token stream; value = first
occurrence index (diagnostics). `is_duplicate` = insert; EXISTS -> duplicate.
"""
from __future__ import annotations

import numpy as np

from repro.core import DashConfig, DashLH, EXISTS
from repro.core.hashing import np_hash_pair


def content_hash64(tokens: np.ndarray) -> int:
    """FNV-1a over token bytes, mixed once more for avalanche."""
    h = np.uint64(0xCBF29CE484222325)
    data = np.asarray(tokens, np.int32).tobytes()
    arr = np.frombuffer(data, np.uint8).astype(np.uint64)
    for chunk in np.array_split(arr, max(1, arr.size // 4096)):
        for b in chunk:
            h = (h ^ b) * np.uint64(0x100000001B3)
    return int(h)


def content_hash64_fast(tokens: np.ndarray) -> int:
    """Vectorized polynomial hash (used by default; exact choice orthogonal,
    as the paper notes for hash functions)."""
    t = np.asarray(tokens, np.int64) + 1
    powers = np.power(np.int64(1099511628211), np.arange(t.size) % 31,
                      dtype=np.int64)
    return int(np.uint64(np.sum(t * powers).astype(np.int64)) &
               np.uint64(0xFFFFFFFFFFFFFFFF))


class DedupFilter:
    def __init__(self, cfg: DashConfig = None, batch: int = 256):
        cfg = cfg or DashConfig(max_segments=512, dir_depth_max=14, num_stash=4)
        self.table = DashLH(cfg)
        self.batch = batch
        self._pending_keys = []
        self._pending_flags = []

    def is_duplicate(self, doc: np.ndarray) -> bool:
        key = content_hash64_fast(doc)
        st = self.table.insert(np.array([key], np.uint64),
                               np.array([0], np.uint32))
        return int(st[0]) == EXISTS

    @property
    def unique_docs(self) -> int:
        return self.table.n_items
