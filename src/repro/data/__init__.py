"""Data substrate: synthetic sharded corpus, packing, Dash-LH dedup."""
from . import dedup, pipeline
from .pipeline import PackedBatcher, PipelineConfig, SyntheticCorpus
from .dedup import DedupFilter

__all__ = ["dedup", "pipeline", "PackedBatcher", "PipelineConfig",
           "SyntheticCorpus", "DedupFilter"]
