"""Deterministic synthetic data pipeline: sharded corpus -> packed batches.

Production shape: seeded per-shard document streams (so any host can
regenerate its shard deterministically — elastic resharding needs no data
movement), sequence packing to fixed seq_len, checkpointable cursor, and a
Dash-LH dedup stage (data/dedup.py) on document content hashes — the paper's
sustained-insert workload embedded in a real pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_size: int              # per-host batch
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 1234
    doc_len_min: int = 64
    doc_len_max: int = 2048
    dup_fraction: float = 0.0    # synthetic duplicate rate (dedup benchmark)


class SyntheticCorpus:
    """Seeded document stream; documents are reproducible by (shard, index)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    def doc(self, index: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, cfg.shard_id, index if cfg.dup_fraction == 0.0
             else self._dedup_index(index)))
        n = int(rng.integers(cfg.doc_len_min, cfg.doc_len_max))
        return rng.integers(1, cfg.vocab_size, n, dtype=np.int32)

    def _dedup_index(self, index: int) -> int:
        """With dup_fraction > 0, some indices alias earlier documents."""
        cfg = self.cfg
        h = np.random.default_rng((cfg.seed, 7, index)).random()
        if index > 10 and h < cfg.dup_fraction:
            return int(h * 10)   # alias to one of the first docs
        return index


class PackedBatcher:
    """Greedy sequence packing with EOS=0 separators; checkpointable."""

    def __init__(self, cfg: PipelineConfig, corpus: Optional[SyntheticCorpus] = None,
                 dedup=None):
        self.cfg = cfg
        self.corpus = corpus or SyntheticCorpus(cfg)
        self.dedup = dedup
        self.cursor = 0          # next document index
        self.buffer = np.zeros(0, np.int32)
        self.docs_seen = 0
        self.docs_skipped = 0

    def state_dict(self):
        return {"cursor": self.cursor, "buffer": self.buffer.copy(),
                "docs_seen": self.docs_seen, "docs_skipped": self.docs_skipped}

    def load_state_dict(self, s):
        self.cursor = int(s["cursor"])
        self.buffer = np.asarray(s["buffer"], np.int32).copy()
        self.docs_seen = int(s["docs_seen"])
        self.docs_skipped = int(s["docs_skipped"])

    def _fill(self, need: int):
        while self.buffer.size < need:
            doc = self.corpus.doc(self.cursor)
            self.cursor += 1
            self.docs_seen += 1
            if self.dedup is not None and self.dedup.is_duplicate(doc):
                self.docs_skipped += 1
                continue
            self.buffer = np.concatenate([self.buffer, doc, np.zeros(1, np.int32)])

    def next_batch(self) -> dict:
        cfg = self.cfg
        need = cfg.batch_size * (cfg.seq_len + 1)
        self._fill(need)
        flat = self.buffer[:need].reshape(cfg.batch_size, cfg.seq_len + 1)
        self.buffer = self.buffer[need:]
        return {"tokens": flat[:, :-1].copy(),
                "labels": flat[:, 1:].astype(np.int32).copy()}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
