"""Serving substrate: Dash prefix cache + paged KV pool + batched engine +
the online-resize concurrent frontend."""
from . import engine, frontend, kv_cache, prefix_cache
from .engine import Request, ServingEngine, buckets_changed, snapshot_search
from .frontend import (AdmissionQueue, BatchFormer, DashFrontend, Op,
                       StopTheWorldFrontend)
from .prefix_cache import BLOCK, DashPrefixCache

__all__ = ["engine", "frontend", "kv_cache", "prefix_cache", "Request",
           "ServingEngine", "snapshot_search", "buckets_changed",
           "AdmissionQueue", "BatchFormer", "DashFrontend", "Op",
           "StopTheWorldFrontend", "BLOCK", "DashPrefixCache"]
