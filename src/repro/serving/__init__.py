"""Serving substrate: Dash prefix cache + paged KV pool + batched engine."""
from . import engine, kv_cache, prefix_cache
from .engine import Request, ServingEngine, snapshot_search
from .prefix_cache import BLOCK, DashPrefixCache

__all__ = ["engine", "kv_cache", "prefix_cache", "Request", "ServingEngine",
           "snapshot_search", "BLOCK", "DashPrefixCache"]
