"""Online-resize serving frontend: epoch-guarded concurrent Dash table.

The stop-the-world path (``DashTable.insert``) holds every queued operation
hostage while a split storm runs: the host retry loop splits, retries, and
only then admits the next batch. This frontend serves reads and writes
*while* bulk SMOs run — the system-level rendering of the paper's claim that
readers are lock-free against structural modifications (Sec. 4.4, Fig. 13):

  * **Epoch-pinned snapshot reads.** Read batches acquire the newest
    published table version under an epoch pin (``core/epoch.py:
    SnapshotRegistry``) and probe it through the default fingerprint read
    path. A verify pass (``serving/engine.py:buckets_changed``) compares the
    snapshot's bucket version planes against the live state; only queries
    whose buckets changed are retried on the live version — the
    snapshot-verify-retry contract. Every result is therefore either
    pre-SMO-consistent or post-SMO-consistent; a torn read is impossible
    because both probes run against immutable functional versions.
  * **O(dirty) copy-on-write publish.** Installing a new version costs
    bytes proportional to what the write batch actually touched, not to the
    table size: ``SnapshotRegistry.publish_cow`` scatters exactly the
    version-changed bucket rows into the previous version's buffers
    (donated in place when unpinned) and aliases every untouched plane —
    the directory after a non-SMO batch, the overflow metadata after an
    update burst, whole record planes after a metadata-only tick.
    Reclamation is plane-level (refcounted ``PlanePool``): retiring v_n
    never frees a plane v_n+1 still aliases. ``stats()`` exposes
    ``publish_bytes`` / ``planes_copied`` / ``planes_aliased`` /
    ``reclaimed`` for the benchmarks' publish-volume gate.
  * **Deferred background SMOs.** A write batch that reports pressure does
    NOT split inline: the frontend plans a staged bulk-split task
    (``core/smo.py:BulkSplitTask`` / ``BulkSplitNextTask``) and pumps ONE
    stage per scheduler tick. Read batches admitted between stages keep
    serving the pinned snapshot without ever waiting on the split's device
    work (their inputs carry no data dependency on it — JAX async dispatch
    free of ``jax.block_until_ready``); the split publishes into the *next*
    directory version, which readers adopt through verify-retry after the
    commit stage publishes a fresh snapshot.
  * **Admission pipeline.** A bounded admission queue feeds two lanes
    (reads / writes); a batch former pulls maximal same-kind runs from the
    lane head. Reads may overtake a write stalled behind a resize — that is
    the point: FIFO holds within a lane, freshness across lanes is governed
    by the verify pass (acknowledged writes are always visible; in-flight
    writes surface once acknowledged).

Epoch lifecycle per write batch::

    publish(v_n) ──► reads pin v_n ──► write batch mutates live (donated)
         ▲                                    │ pressure?
         │                                    ▼
    commit stage ◄─ phase2 (next dir) ◄─ phase1 (staged, one stage/tick)
         │            ... reads keep pinning v_n between stages ...
         ▼
    publish(v_n+1) — v_n retired into epoch limbo, reclaimed 2 epochs later

``StopTheWorldFrontend`` drives the identical op stream through the inline
path (single FIFO, full split storms inside write batches) — the baseline
``benchmarks/online_resize.py`` measures p50/p99 read latency against.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.core import engine as dash_engine
from repro.core import hashing
from repro.core.epoch import SnapshotRegistry
from repro.core.layout import DROPPED, INSERTED, NOT_FOUND
from repro.core.table import DashTable, TableFullError

from .engine import buckets_changed

READ, INSERT, UPDATE, DELETE, RMW = "read", "insert", "update", "delete", "rmw"


def _read_batching(table: DashTable, max_batch: int,
                   fused_reads: Optional[bool]) -> str:
    """Read-path selection for a frontend tick. ``fused_reads=None`` picks
    the fused single-dispatch probe exactly when the table's planner would
    (batch fits under ``table.fused_threshold`` and the config is fused-
    eligible); True/False force the fused or routed path — the forcing
    knob the fused-on/off equivalence tests drive. The decision is made
    once at construction: read batches are padded to ``max_batch``, so
    every tick shares one shape and one plan."""
    if fused_reads is False:
        return "auto"
    if fused_reads is True:
        return "fused"
    from repro.kernels import ops as kernel_ops
    if (max_batch <= table.fused_threshold
            and kernel_ops.fused_search_eligible(table.cfg)):
        return "fused"
    return "auto"

#: frontend health states (PR 6). Guarantees:
#:   HEALTHY  — every acknowledged write is durable (flush-on-publish ran
#:              through its commit fence) and reads serve verified state.
#:   DEGRADED — the durable device stopped accepting flushes past the retry
#:              budget: serving CONTINUES (reads + writes, full speed) but
#:              acknowledgments are volatile until ``try_recover`` brings
#:              the pool back (then one force-full flush resynchronizes).
#:              The pool's on-media image stays the last committed flush.
#:   READONLY — capacity exhaustion (segment pool / retry budget) with
#:              ``readonly_on_full``: writes are rejected at admission and
#:              in-flight writes fail explicitly (DROPPED); reads keep
#:              serving. Terminal until operator action (resize/restart).
HEALTHY, DEGRADED, READONLY = "healthy", "degraded", "readonly"


@dataclasses.dataclass
class Op:
    """One client operation. The frontend stamps admission/completion times;
    ``latency`` is the sojourn (queue wait + service), the quantity the
    online-resize benchmark quotes p50/p99 over."""
    kind: str
    key: int
    value: int = 0
    enqueue_t: float = 0.0
    done_t: float = 0.0
    status: int = -1
    found: bool = False
    result: int = 0

    @property
    def latency(self) -> float:
        return self.done_t - self.enqueue_t


class AdmissionQueue:
    """Bounded FIFO admission lane. ``offer`` rejects when full — the
    backpressure is surfaced to the caller (shed/retry upstream) instead of
    letting the queue grow without bound during a split storm."""

    def __init__(self, depth: int = 4096):
        self.depth = depth
        self._q: deque = deque()
        self.admitted = 0
        self.rejected = 0

    def offer(self, op: Op) -> bool:
        if len(self._q) >= self.depth:
            self.rejected += 1
            return False
        op.enqueue_t = obs_mod.now()
        self._q.append(op)
        self.admitted += 1
        return True

    def __len__(self) -> int:
        return len(self._q)

    def peek(self) -> Optional[Op]:
        return self._q[0] if self._q else None

    def pop(self) -> Op:
        return self._q.popleft()


class BatchFormer:
    """Pulls the maximal same-kind run from a lane head, up to
    ``max_batch`` — admission order is preserved within the lane and every
    formed batch is homogeneous (one engine dispatch kind)."""

    def __init__(self, max_batch: int = 256):
        self.max_batch = max_batch

    def form(self, lane: AdmissionQueue) -> List[Op]:
        head = lane.peek()
        if head is None:
            return []
        ops = []
        while (len(ops) < self.max_batch and lane.peek() is not None
               and lane.peek().kind == head.kind):
            ops.append(lane.pop())
        return ops


def _keys_arrays(ops: List[Op], pad_to: int = 0):
    """Key planes for a batch, zero-padded to ``pad_to`` so every read
    batch shares one jit trace (the shape-specialized probe path)."""
    keys = np.zeros(max(pad_to, len(ops)), dtype=np.uint64)
    keys[:len(ops)] = [op.key for op in ops]
    hi, lo = hashing.np_split_keys(keys)
    return jnp.asarray(hi), jnp.asarray(lo)


class FrontendBase:
    """Shared cooperative scheduler of the single-table and sharded
    frontends: bounded read/write admission lanes, batch forming,
    read-priority ticks, sojourn stamping + snapshot/retry stats.
    Subclasses provide the probe/verify/write machinery (``_serve_reads``,
    ``_pump_write``) and report in-flight write work via
    ``_write_pending``."""

    def __init__(self, *, max_batch: int = 256, queue_depth: int = 4096,
                 obs: Optional[obs_mod.Observability] = None):
        self.reads = AdmissionQueue(queue_depth)
        self.writes = AdmissionQueue(queue_depth)
        self.former = BatchFormer(max_batch)
        self.registry = SnapshotRegistry()
        self.health = HEALTHY
        self.degraded_events = 0     # HEALTHY -> DEGRADED transitions
        self.readonly_events = 0     # -> READONLY transitions (terminal)
        self.unflushed_publishes = 0  # publishes acked volatile while degraded
        self.snapshot_reads = 0      # queries answered from the snapshot
        self.retried_reads = 0       # queries re-run on the live version
        self.read_latencies: List[float] = []
        self.write_latencies: List[float] = []
        # observability bundle: metrics registry + tracer + SLO monitor
        # (obs/). The sojourn histograms are fed by the same _finish_*
        # stamps the latency lists come from — one clock, one code path.
        self.obs = obs if obs is not None else obs_mod.Observability()
        scope = self.obs.registry.scope("frontend")
        self._read_hist = scope.histogram("read_sojourn_s")
        self._write_hist = scope.histogram("write_sojourn_s")
        self._publish_bytes = scope.counter("publish_bytes")
        self._flush_bytes = scope.counter("flush_bytes")
        self._publishes = scope.counter("publishes")
        self.obs.slo.watch_histogram("read_sojourn", self._read_hist)
        self.obs.slo.watch_histogram("write_sojourn", self._write_hist)
        self.obs.slo.watch_rate("publish_bytes_per_s", self._publish_bytes)
        self.obs.slo.watch_rate("flush_bytes_per_s", self._flush_bytes)
        self.obs.slo.note_health(self.health)

    def _set_health(self, new: str):
        """The one health-transition path: keeps the transition counters
        the fault tests assert on, the SLO monitor's dwell accounting, and
        the trace instant in sync."""
        if new == self.health:
            return
        if new == DEGRADED:
            self.degraded_events += 1
        elif new == READONLY:
            self.readonly_events += 1
        self.obs.tracer.instant("health_transition", "health",
                                frm=self.health, to=new)
        self.obs.slo.note_health(new)
        self.health = new

    def submit(self, op: Op) -> bool:
        if self.health == READONLY and op.kind != READ:
            self.writes.rejected += 1
            return False
        lane = self.reads if op.kind == READ else self.writes
        return lane.offer(op)

    def _write_pending(self) -> bool:
        return False

    @property
    def busy(self) -> bool:
        return bool(len(self.reads) or len(self.writes)
                    or self._write_pending())

    def stats(self) -> dict:
        """One observability surface (benches + tests): the registry's
        copy-on-write publish counters, the read-path snapshot/retry split,
        and — when a durable pool is attached — the writeback's flush
        counters."""
        out = self.registry.stats()
        out["snapshot_reads"] = self.snapshot_reads
        out["retried_reads"] = self.retried_reads
        out["health"] = self.health
        out["degraded_events"] = self.degraded_events
        out["readonly_events"] = self.readonly_events
        out["unflushed_publishes"] = self.unflushed_publishes
        table = getattr(self, "table", None)
        if table is not None:
            report = getattr(table, "lost_report", [])
            out["lost_rows"] = sum(1 for r in report
                                   if r.get("plane") == "bt")
            out["lost_records"] = sum(r.get("lost_records", 0)
                                      for r in report)
        wb = getattr(table, "writeback", None)
        if wb is not None:
            # superblock counts are the durable cumulative truth (survive
            # the healing flush and later restarts); prefer them when present
            out["lost_records"] = max(out.get("lost_records", 0),
                                      wb.pool.sb.lost_records)
            out["quarantined_bt"] = len(wb.pool.sb.lost_bt)
            out["quarantined_nb"] = len(wb.pool.sb.lost_nb)
            out["quarantine_overflow"] = wb.pool.sb.lost_overflow
            out.update(wb.stats())
        scrubber = getattr(self, "scrubber", None)
        if scrubber is not None:
            out.update(scrubber.stats())
        return out

    def obs_snapshot(self) -> dict:
        """Full observability export: registry metrics (with the stats()
        surfaces mirrored in under ``stats.``), the last SLO snapshot, and
        tracer occupancy."""
        self.obs.registry.ingest(self.stats(), prefix="stats.")
        return self.obs.snapshot()

    def _finish_reads(self, ops: List[Op], found, vals, n_changed: int):
        now = self.obs.now()
        for i, op in enumerate(ops):
            op.found = bool(found[i])
            op.result = int(vals[i])
            op.status = INSERTED if op.found else NOT_FOUND
            op.done_t = now
            self.read_latencies.append(op.latency)
        self._read_hist.observe_many([op.latency for op in ops])
        self.snapshot_reads += len(ops) - n_changed
        self.retried_reads += n_changed

    def _finish_writes(self, ops: List[Op], statuses):
        now = self.obs.now()
        for op, st in zip(ops, statuses):
            op.status = int(st)
            op.done_t = now
            self.write_latencies.append(op.latency)
        self._write_hist.observe_many([op.latency for op in ops])

    def step(self) -> bool:
        """One tick: a read batch first (latency priority — it never waits
        on the write side), then one write-side unit. Returns True if any
        work ran."""
        did = False
        read_ops = self.former.form(self.reads)
        if read_ops:
            self._serve_reads(read_ops)
            did = True
        return self._pump_write() or did

    def drain(self):
        """Run the scheduler until every admitted op completed and no SMO
        is in flight."""
        while self.busy:
            self.step()


class DashFrontend(FrontendBase):
    """Concurrent serving frontend over one ``DashTable`` (EH or LH).

    Cooperative scheduler: ``step()`` is one tick — serve one read batch
    from the pinned snapshot, then advance the write side by exactly one
    unit (one SMO stage, one insert round, or one new write batch). The
    interleaving is deterministic, which is what the no-torn-reads property
    test schedules against. ``drain()`` runs ticks until idle.

    Requires the staged bulk SMO path (``table.smo_task_eligible()``);
    scan-mode / rebuild-ineligible tables fall back to inline splits inside
    the write tick (the frontend still works, reads still serve the
    snapshot, but a storm then lands inside one tick).

    The frontend assumes it is the table's only writer: the clean-snapshot
    fast path (skip the verify dispatch when nothing was written since the
    last publish) is tracked by a host-side dirty flag that direct
    ``table.insert(...)`` calls would bypass.
    """

    def __init__(self, table: DashTable, *, max_batch: int = 256,
                 queue_depth: int = 4096, readonly_on_full: bool = False,
                 scrub_interval: int = 0, scrub_rows: int = 512,
                 fused_reads: Optional[bool] = None,
                 obs: Optional[obs_mod.Observability] = None):
        super().__init__(max_batch=max_batch, queue_depth=queue_depth,
                         obs=obs)
        self.table = table
        table.attach_obs(self.obs)
        self.cfg = table.cfg
        self.mode = table.mode
        # read-path selection (fused single-dispatch probe vs routed
        # auto path); writes already take the fused path through the
        # table planner (DashTable._write_plan)
        self.read_batching = _read_batching(table, max_batch, fused_reads)
        # capacity exhaustion policy: False preserves the raise-through
        # behavior; True turns it into the READONLY health state (reads
        # keep serving, writes fail explicitly)
        self.readonly_on_full = readonly_on_full
        # background media scrub: every `scrub_interval` ticks verify+repair
        # one `scrub_rows` window of the attached pool (0 disables)
        self.scrub_interval = scrub_interval
        self._scrub_countdown = scrub_interval
        self.scrubber = None
        if scrub_interval > 0 and table.writeback is not None:
            from repro.persist.writeback import Scrubber
            self.scrubber = Scrubber(table.writeback, rows_per_tick=scrub_rows)
        self._dirty = True            # live state diverged from the snapshot
        # trace state: the batch/SMO spans stay open across ticks; the last
        # publish/flush span ids are what ack spans causally link back to
        self._batch_span = None
        self._smo_span = None
        self._last_publish_sid = None
        self._last_flush_sid = None
        self._publish()
        # in-flight write machinery (at most one of each at a time)
        self._insert_job = None
        self._insert_ops: List[Op] = []
        self._smo_task = None
        self.smo_stages = 0          # staged SMO pumps
        self.smo_dispatches = 0      # completed SMO tasks

    def _write_pending(self) -> bool:
        return self._insert_job is not None or self._smo_task is not None

    # -- snapshot lifecycle ------------------------------------------------

    def _publish(self):
        """Install the live state as the next published version in O(dirty)
        bytes: the COW publish scatters only version-changed bucket rows and
        aliases untouched planes (core/epoch.py). The table's host-side
        dirty tracker is drained ONCE and feeds both consumers (audited
        against the device ground truth; it also carries the force-full
        escape after crash/restart). Superseded versions retire through the
        epoch manager; their planes are freed only when no newer version
        aliases them.

        Flush-on-publish: with a durable pool attached (persist/), the same
        dirty hint drives the pool writeback right after the publish — an
        op acknowledged by this frontend is durable, and the flush volume
        tracks the publish volume (both are O(dirty bucket rows)).

        Graceful degradation (PR 6): a flush that exhausts its transient-
        error retry budget marks the frontend DEGRADED instead of failing
        the publish — serving continues volatile (the pool keeps its last
        committed image; acknowledgments stop implying durability until
        ``try_recover`` succeeds). The hint loss is harmless: recovery
        resynchronizes with a force-full flush."""
        tr = self.obs.tracer
        self._last_publish_sid = None
        self._last_flush_sid = None
        with tr.span("publish", "epoch") as psp:
            hint = self.table.dirty.drain()
            self.registry.publish_cow(self.cfg, self.table.state,
                                      dirty_hint=hint)
            self._publishes.inc()
            self._publish_bytes.inc(self.registry.last_publish_bytes)
            if psp is not None:
                psp.args["bytes"] = self.registry.last_publish_bytes
                self._last_publish_sid = psp.sid
            wb = self.table.writeback
            if wb is not None:
                if wb.degraded:
                    self.unflushed_publishes += 1
                else:
                    from repro.persist.writeback import WritebackDegraded
                    before = wb.flushed_bytes
                    try:
                        # the writeback opens its own "flush" span — nested
                        # under this publish span via the tracer stack
                        # (flush-on-publish, rendered literally)
                        wb.flush(self.table.state, hint)
                        self._last_flush_sid = wb.last_flush_sid
                    except WritebackDegraded:
                        if self.health == HEALTHY:
                            self._set_health(DEGRADED)
                        self.unflushed_publishes += 1
                    self._flush_bytes.inc(wb.flushed_bytes - before)
        self._dirty = False

    def try_recover(self) -> bool:
        """Attempt DEGRADED -> HEALTHY: probe the pool's fence and, on
        success, resynchronize it with one force-full flush
        (``WritebackEngine.try_recover``). READONLY is terminal — capacity,
        not media. Returns True when the frontend is healthy afterwards."""
        if self.health == READONLY:
            return False
        wb = self.table.writeback
        if wb is None or not wb.degraded:
            self._set_health(HEALTHY)
            return True
        if wb.try_recover(self.table.state):
            self._set_health(HEALTHY)
            return True
        return False

    # -- read lane ---------------------------------------------------------

    def _serve_reads(self, ops: List[Op]):
        tr = self.obs.tracer
        with tr.span("read_batch", "serving", n=len(ops)) as rsp:
            n_changed = self._serve_reads_inner(ops)
        ack = tr.begin("ack", "serving", kind=READ, n=len(ops),
                       retried=n_changed)
        tr.link(ack, rsp)
        tr.end(ack)

    def _serve_reads_inner(self, ops: List[Op]) -> int:
        hi, lo = _keys_arrays(ops, pad_to=self.former.max_batch)
        if self.table.lazy_recovery:
            # lazy per-segment recovery hooks the READ path too (Sec. 4.8):
            # after a dirty restart the frontend serves immediately and the
            # touched segments recover here; the verify pass below then
            # retries the recovered buckets on the live version (recovery
            # bumps their version words), so results are never served from
            # unrecovered state. No-op (one np gather) on recovered tables.
            before = self.table.recovered_segments
            self.table._ensure_recovered(self.table._segments_of(
                np.asarray(hi)[:len(ops)], np.asarray(lo)[:len(ops)]))
            if self.table.recovered_segments != before:
                self._dirty = True
        with self.registry.acquire() as snap:
            found, vals = dash_engine.search_batch(
                self.cfg, self.mode, snap.state, hi, lo,
                batching=self.read_batching)
            found, vals = np.asarray(found).copy(), np.asarray(vals).copy()
            n_changed = 0
            if self._dirty:
                # verify only when the live state diverged since publish
                # (a clean snapshot is the live state by construction)
                changed = np.asarray(buckets_changed(
                    self.cfg, self.mode, snap.state, self.table.state,
                    hi, lo)).copy()
                changed[len(ops):] = False        # padding lanes never retry
                n_changed = int(changed.sum())
            if n_changed:
                # lazy retry: one extra dispatch ONLY when the verify pass
                # flagged queries — this is the only read-path dependency on
                # in-flight writes/SMOs
                f2, v2 = dash_engine.search_batch(
                    self.cfg, self.mode, self.table.state, hi, lo,
                    batching=self.read_batching)
                found[changed] = np.asarray(f2)[changed]
                vals[changed] = np.asarray(v2)[changed]
        self._finish_reads(ops, found, vals, n_changed)
        return n_changed

    # -- write lane --------------------------------------------------------

    def _pump_write(self) -> bool:
        """Advance the write side by one unit. Returns True if work ran.
        With ``readonly_on_full``, capacity exhaustion (segment pool /
        insert retry budget) transitions to READONLY instead of raising:
        in-flight write ops fail explicitly (DROPPED — never silently),
        queued writes are rejected, reads keep serving."""
        try:
            return self._pump_write_inner()
        except TableFullError:
            if not self.readonly_on_full:
                raise
            self._set_health(READONLY)
            tr = self.obs.tracer
            if self._insert_ops:
                self._finish_writes(self._insert_ops,
                                    [DROPPED] * len(self._insert_ops))
            tr.end(self._batch_span, dropped=True)
            tr.end(self._smo_span, dropped=True)
            self._batch_span = self._smo_span = None
            self._insert_job, self._insert_ops = None, []
            self._smo_task = None
            while len(self.writes):
                op = self.writes.pop()
                op.status = DROPPED
                op.done_t = self.obs.now()
                self.writes.rejected += 1
            self._dirty = True       # surgery may have run mid-SMO
            self._publish()
            return True

    def _begin_smo_span(self):
        task = self._smo_task
        if task is not None:
            self._smo_span = self.obs.tracer.begin("smo", "smo",
                                                   **task.describe())

    def _emit_write_ack(self, batch_span, kind: str, n: int):
        """The acknowledgment trace event: an acked batch links back to its
        batch span, the publish that made it visible, and (when durable)
        the flush that made it durable — the causal chain the acceptance
        gate verifies end-to-end."""
        tr = self.obs.tracer
        if not tr.enabled:
            return
        ack = tr.begin("ack", "serving", parent=batch_span, kind=kind, n=n)
        tr.link(ack, batch_span, self._last_publish_sid,
                self._last_flush_sid)
        tr.end(ack)

    def _pump_write_inner(self) -> bool:
        tr = self.obs.tracer
        if self._smo_task is not None:
            with tr.span("smo_stage", "smo", parent=self._smo_span,
                         stage=self._smo_task.stage):
                self.table.state, done = self._smo_task.pump(
                    self.table.state)
            self.smo_stages += 1
            self._dirty = True
            if done:
                shortfall = self._smo_task.shortfall
                self._smo_task = None
                self.smo_dispatches += 1
                tr.end(self._smo_span, shortfall=shortfall)
                self._smo_span = None
                # the next directory version is live: publish so subsequent
                # read batches pin it instead of paying the retry dispatch
                self._publish()
                if shortfall:
                    raise TableFullError("segment pool exhausted")
            return True

        if self._insert_job is not None:
            job = self._insert_job
            if job.rounds > 256:
                raise TableFullError("insert retry budget exhausted")
            with tr.span("insert_round", "serving",
                         parent=self._batch_span):
                activated = self.table.insert_round(job)
            self._dirty = True
            staged = self.table.smo_task_eligible()
            if job.done:
                n_ops = len(self._insert_ops)
                self._finish_writes(self._insert_ops, job.out)
                bsp = self._batch_span
                tr.end(bsp, rounds=job.rounds)
                self._batch_span = None
                self._insert_job, self._insert_ops = None, []
                self._publish()
                self._emit_write_ack(bsp, INSERT, n_ops)
                if activated:   # LH stash activation still demands a split
                    if staged:
                        self._smo_task = self.table.make_smo_task(None)
                        if self._smo_task is not None:
                            self.table.note_smo(self._smo_task)
                            self._begin_smo_span()
                    else:
                        self.table._on_pressure(None)
                        self._dirty = True
            elif staged:
                # defer the storm: plan the bulk SMO, pump it on later ticks
                self._smo_task = self.table.make_smo_task(
                    self.table.pressure_hints(job))
                self.table.note_smo(self._smo_task)
                self._begin_smo_span()
            else:
                # scalar / rebuild-ineligible configs keep the inline SMO
                # (splits land inside this tick; reads still serve snapshots)
                self.table._on_pressure(self.table.pressure_hints(job))
            return True

        ops = self.former.form(self.writes)
        if not ops:
            return False
        kind = ops[0].kind
        if kind == INSERT:
            self._batch_span = tr.begin("write_batch", "serving",
                                        kind=kind, n=len(ops))
            self._insert_job = self.table.insert_begin(
                [op.key for op in ops], [op.value for op in ops])
            self._insert_ops = ops
            # first round runs this tick; pressure (if any) defers to a task
            return self._pump_write()
        bsp = tr.begin("write_batch", "serving", kind=kind, n=len(ops))
        keys = [op.key for op in ops]
        self._dirty = True
        if kind == UPDATE:
            statuses = self.table.update(keys, [op.value for op in ops])
        elif kind == DELETE:
            statuses = self.table.delete(keys)
        else:                                   # RMW: read live, write back
            found, vals = self.table.search(keys)
            for op, f, v in zip(ops, found, vals):
                op.found, op.result = bool(f), int(v)
            statuses = self.table.update(
                keys, [op.value for op in ops])
        self._finish_writes(ops, np.asarray(statuses))
        tr.end(bsp)
        self._publish()
        self._emit_write_ack(bsp, kind, len(ops))
        return True

    def _slo_extra(self) -> dict:
        """Per-tick facts the SLO snapshot carries beyond the registry:
        health, epoch limbo depth, queue occupancy. Built lazily — only on
        SLO evaluation ticks."""
        return {"health": self.health,
                "limbo_depth": self.registry.epochs.limbo_size,
                "queue_depth": len(self.reads) + len(self.writes),
                "unflushed_publishes": self.unflushed_publishes}

    def step(self) -> bool:
        did = super().step()
        if self.scrubber is not None:
            self._scrub_countdown -= 1
            if self._scrub_countdown <= 0:
                self._scrub_countdown = self.scrub_interval
                self.scrubber.tick(self.table.state)
        # the SLO monitor ticks alongside the scrubber: one counter bump
        # per tick, a windowed evaluation every eval_interval ticks
        self.obs.slo.tick(self._slo_extra)
        return did

    def shutdown(self):
        self.drain()
        self.registry.flush()


class StopTheWorldFrontend(FrontendBase):
    """Baseline for ``benchmarks/online_resize.py``: the same admission
    stream served strictly in order through the inline path — ONE FIFO (no
    lane separation: everything lands in the base's write lane), writes run
    ``DashTable.insert`` (split storms inside the batch), reads route
    against the live state. A read admitted behind a storm waits for the
    whole storm; its sojourn latency shows it."""

    def __init__(self, table: DashTable, *, max_batch: int = 256,
                 queue_depth: int = 4096,
                 fused_reads: Optional[bool] = None):
        super().__init__(max_batch=max_batch, queue_depth=queue_depth)
        self.table = table
        self.cfg = table.cfg
        self.mode = table.mode
        self.queue = self.writes          # the single FIFO, reads included
        self.read_batching = _read_batching(table, max_batch, fused_reads)

    def submit(self, op: Op) -> bool:
        return self.queue.offer(op)

    def _serve_reads(self, ops: List[Op]):
        hi, lo = _keys_arrays(ops, pad_to=self.former.max_batch)
        if self.table.lazy_recovery:
            self.table._ensure_recovered(self.table._segments_of(
                np.asarray(hi)[:len(ops)], np.asarray(lo)[:len(ops)]))
        found, vals = dash_engine.search_batch(
            self.cfg, self.mode, self.table.state, hi, lo,
            batching=self.read_batching)
        self._finish_reads(ops, np.asarray(found), np.asarray(vals), 0)

    def _pump_write(self) -> bool:
        ops = self.former.form(self.queue)
        if not ops:
            return False
        kind = ops[0].kind
        if kind == READ:
            self._serve_reads(ops)
            return True
        keys = [op.key for op in ops]
        if kind == INSERT:
            statuses = self.table.insert(keys, [op.value for op in ops])
        elif kind == UPDATE:
            statuses = self.table.update(keys, [op.value for op in ops])
        elif kind == DELETE:
            statuses = self.table.delete(keys)
        else:                                   # RMW
            found, vals = self.table.search(keys)
            for op, f, v in zip(ops, found, vals):
                op.found, op.result = bool(f), int(v)
            statuses = self.table.update(keys, [op.value for op in ops])
        self._finish_writes(ops, np.asarray(statuses))
        return True
