"""Batched serving engine with Dash prefix-cache reuse.

Request flow:
  1. ``match_prefix`` (Dash probe batch — the fingerprint hot path) finds the
     longest cached token-block chain; those pages are gathered into the
     request's decode state, and **prefill runs only on the uncached
     suffix** — the compute saved is tracked per request.
  2. The suffix prefill's K/V (or recurrent state) is admitted back into the
     pool under chained block hashes (Dash insert batch).
  3. Greedy decode proceeds with the shared ``serve_step``.

Optimistic-concurrency composition (paper Sec. 4.4 at system level): lookups
run against a *snapshot* of the directory while admissions build the next
version; ``verify`` compares bucket version planes and retries queries whose
buckets changed — implemented in ``snapshot_search`` below and exercised by
tests/benchmarks (Fig. 13 analog).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as dash_engine
from repro.models.transformer import (ModelConfig, decode_state_init,
                                      forward_prefill, serve_step)
from .kv_cache import PagePool, PagePoolConfig
from .prefix_cache import BLOCK, DashPrefixCache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int = 16
    generated: Optional[List[int]] = None
    cached_tokens: int = 0
    prefilled_tokens: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, cache_len: int = 512,
                 num_pages: int = 1024, batch_size: int = 4):
        assert cfg.family not in ("vlm", "audio"), \
            "engine demo covers token-in archs; stubs served via prefill API"
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self.batch = batch_size
        self.prefix = DashPrefixCache(num_pages)
        self.pool = PagePool(PagePoolConfig(num_pages, cfg))
        # epoch-based reclamation (paper Sec. 4.4): lock-free lookups pin an
        # epoch; superseded directory snapshots retire 2 epochs later
        from repro.core.epoch import EpochManager
        self.epochs = EpochManager()
        self._prefill = jax.jit(
            lambda p, b: forward_prefill(p, cfg, b, cache_len))
        self._decode = jax.jit(lambda p, s, i: serve_step(p, cfg, s, i))
        self.flops_saved_tokens = 0

    # -- single-request path (batched decode below) -----------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a batch of requests (prefix reuse + batched greedy decode)."""
        B = self.batch
        assert len(requests) <= B
        state = decode_state_init(self.cfg, B, self.cache_len)

        # 1) prefix match + suffix prefill per request (lookup under an
        # epoch pin — admissions below retire superseded snapshots safely)
        for bi, req in enumerate(requests):
            with self.epochs.pin():
                pages, n_cached = self.prefix.match_prefix(req.prompt)
            n_cached = min(n_cached, len(req.prompt) - 1)  # always prefill >=1
            n_cached = (n_cached // BLOCK) * BLOCK
            req.cached_tokens = n_cached
            self.flops_saved_tokens += n_cached

            # gather cached pages into this request's lane
            for pi, kind in enumerate(self.cfg.pattern):
                state[f"blocks_{pi}"] = self.pool.gather_into_cache(
                    pages[: n_cached // BLOCK], pi, kind,
                    state[f"blocks_{pi}"], bi)

            # prefill the uncached suffix (dominant cost without the cache)
            suffix = req.prompt[n_cached:]
            req.prefilled_tokens = len(suffix)
            sb = {"tokens": jnp.asarray(suffix, jnp.int32)[None, :],
                  "labels": jnp.zeros((1, len(suffix)), jnp.int32)}
            logits, pstate = self._prefill(self.params, sb)

            # merge suffix state into lane bi (suffix-only demo: exact when
            # n_cached == 0; cached case splices pages + suffix kv)
            for pi, kind in enumerate(self.cfg.pattern):
                src = pstate[f"blocks_{pi}"]
                dst = state[f"blocks_{pi}"]
                state[f"blocks_{pi}"] = jax.tree.map(
                    lambda d, s: d.at[:, bi].set(s[:, 0]), dst, src)
            for ti, kind in enumerate(self.cfg.tail):
                src = pstate[f"tail_{ti}"]
                state[f"tail_{ti}"] = jax.tree.map(
                    lambda d, s: d.at[bi].set(s[0]), state[f"tail_{ti}"], src)
            state["pos"] = state["pos"].at[bi].set(len(req.prompt))

            # 3) admit the new blocks back into the pool; the pre-admission
            # directory version is retired through the epoch manager
            old_state = self.prefix.table.state
            new_pages = self.prefix.admit(req.prompt,
                                          first_new_block=n_cached // BLOCK)
            self.epochs.retire(old_state)
            for pi, kind in enumerate(self.cfg.pattern):
                self.pool.store_request(new_pages, pstate[f"blocks_{pi}"],
                                        pi, kind, 0, len(req.prompt))
            req.generated = [int(jnp.argmax(logits[0, -1]))]

        # 2) batched greedy decode
        max_new = max(r.max_new_tokens for r in requests)
        tokens = jnp.asarray([r.generated[0] for r in requests] +
                             [0] * (B - len(requests)), jnp.int32)
        for _ in range(max_new - 1):
            logits, state = self._decode(self.params, state,
                                         {"token": tokens})
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for bi, req in enumerate(requests):
                if len(req.generated) < req.max_new_tokens:
                    req.generated.append(int(tokens[bi]))
        return requests


# ---------------------------------------------------------------------------
# optimistic snapshot search (system-level Sec. 4.4)
# ---------------------------------------------------------------------------

def buckets_changed_local(cfg, mode, old_state, new_state, keys_hi, keys_lo):
    """Unjitted body of :func:`buckets_changed` — pure ``jnp``, traceable
    inside a larger program (the distributed layer inlines it per-shard
    under ``shard_map`` so the verify never leaves the device).

    Per-query bool mask: could this query observe different records on
    ``new_state`` than on the ``old_state`` snapshot?

    This is the verify step of the snapshot-verify-retry contract (the
    serving frontend's default read path): a query is 'changed' iff its
    addressing moved (directory entry / LH word remap) or any bucket it may
    probe — the probe window AND the segment's stash buckets, whose version
    words are the only trace a stash insert leaves for this home bucket —
    carries a different version word. False negatives would be torn reads;
    false positives only cost a retry, so the stash compare is segment-wide
    rather than per-indicated-bucket.

    Copy-on-write versions (core/epoch.py) ALIAS unchanged planes between
    snapshots; the compare is oblivious to that — aliased planes are
    ordinary arrays that happen to share buffers, and the version planes it
    reads are exactly the rows the COW publish keeps current. The frontend
    skips the whole dispatch when nothing was written since the last
    publish (its host-side dirty gate), so this only runs against a live
    state that genuinely diverged."""
    from repro.core import hashing, layout
    h1 = hashing.hash1(keys_hi, keys_lo)
    if mode == "eh":
        d = layout.dir_index(cfg, h1)
        seg = old_state.dir[d]
        b = layout.bucket_index(cfg, h1)
        changed = seg != new_state.dir[d]
    else:
        seg = old_state.lh_dir[
            layout.lh_logical_segment(cfg, h1, old_state.lh_word)]
        b = layout.lh_bucket_index(cfg, h1)
        new_seg = new_state.lh_dir[
            layout.lh_logical_segment(cfg, h1, new_state.lh_word)]
        changed = seg != new_seg
    for w in range(cfg.probe_window):
        bw = (b + w) & (cfg.num_buckets - 1)
        changed = changed | (old_state.version[seg, bw]
                             != new_state.version[seg, bw])
    for s in range(cfg.num_stash):
        sb = cfg.num_buckets + s
        changed = changed | (old_state.version[seg, sb]
                             != new_state.version[seg, sb])
    return changed


@functools.partial(jax.jit, static_argnums=(0, 1))
def buckets_changed(cfg, mode, old_state, new_state, keys_hi, keys_lo):
    """Jitted entry point over :func:`buckets_changed_local` — the host-side
    verify used by the single-table frontends (and the DHT's retained
    host-mirror baseline)."""
    return buckets_changed_local(cfg, mode, old_state, new_state,
                                 keys_hi, keys_lo)


def snapshot_search(cfg, old_state, new_state, keys_hi, keys_lo,
                    batching: str = "auto", mode: str = "eh"):
    """Search against a snapshot while writers published ``new_state``;
    verify per-touched-bucket versions (``buckets_changed``) and retry
    changed queries on the new version. Returns (found, values, n_retried).

    Both lookups go through ``engine.search_batch`` with the caller's
    ``batching`` — ``"fused"`` for the single-dispatch small-batch path
    (what the frontend selects under ``DashTable.fused_threshold``),
    ``"auto"`` for the segment-routed Pallas kernel on eligible configs —
    so the optimistic snapshot composition rides the fast path too; the
    version-plane verification reads bucket version words, not records.
    The serving frontend uses the lazy two-phase variant (retry dispatched
    only when the mask is non-empty) via ``buckets_changed`` directly."""
    found, vals = dash_engine.search_batch(cfg, mode, old_state, keys_hi,
                                           keys_lo, batching=batching)
    changed = buckets_changed(cfg, mode, old_state, new_state,
                              keys_hi, keys_lo)
    f2, v2 = dash_engine.search_batch(cfg, mode, new_state, keys_hi, keys_lo,
                                      batching=batching)
    found = jnp.where(changed, f2, found)
    vals = jnp.where(changed, v2, vals)
    return found, vals, jnp.sum(changed)
