"""Dash-backed prefix cache: the paper's hash table as the serving-side
KV-page directory (vLLM-style prefix caching).

Keying: token-block chain hashes. A prompt is chunked into BLOCK-token
blocks; block i's key is hash(chain_{i-1}, tokens_i) so a hit at block i
implies the whole prefix matches (content addressing, no tree walk). Each
key maps to a page id in the page pool. Lookups are *negative-search heavy*
(most prompts diverge quickly) — precisely the workload fingerprinting
accelerates (paper Sec. 4.2, Figs. 7/9), which is why Dash-EH is the right
index here.

For attention-free archs (rwkv6, recurrentgemma) the payload is a *state
snapshot id* instead of a KV page: the same directory, different pool —
handled by the engine (DESIGN.md SS5 arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import DashConfig, DashEH, EXISTS, INSERTED
from repro.core.hashing import np_hash_pair

BLOCK = 16          # tokens per cache block


def _chain_hashes(tokens: np.ndarray) -> np.ndarray:
    """64-bit chained block hashes: h_i = mix(h_{i-1}, tokens[i*B:(i+1)*B])."""
    tokens = np.asarray(tokens, np.int64)
    n = tokens.size // BLOCK
    out = np.zeros(n, np.uint64)
    h = np.uint64(0x9E3779B97F4A7C15)
    for i in range(n):
        blk = tokens[i * BLOCK:(i + 1) * BLOCK]
        lo = np.uint32(np.bitwise_and(np.sum(blk * np.arange(1, BLOCK + 1)),
                                      0xFFFFFFFF))
        hi = np.uint32(np.bitwise_and(np.sum((blk + 13) ** 2), 0xFFFFFFFF))
        mixed = np_hash_pair(np.uint32(h >> np.uint64(32)) ^ hi,
                             np.uint32(h & np.uint64(0xFFFFFFFF)) ^ lo, 0xABCD)
        h = (np.uint64(mixed) << np.uint64(32)) | np.uint64(
            np_hash_pair(hi, lo, int(mixed)))
        out[i] = h
    return out


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0
    hit_blocks: int = 0
    miss_blocks: int = 0
    insertions: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hit_blocks + self.miss_blocks
        return self.hit_blocks / total if total else 0.0


class DashPrefixCache:
    """token-block chain hash -> page id, with LRU eviction."""

    def __init__(self, num_pages: int, dash_cfg: Optional[DashConfig] = None):
        self.table = DashEH(dash_cfg or DashConfig(
            max_segments=256, dir_depth_max=12, num_stash=4))
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages))
        self.lru: dict[int, int] = {}          # page -> last-use tick
        self.page_owner: dict[int, int] = {}   # page -> key (for eviction)
        self.tick = 0
        self.stats = PrefixCacheStats()

    # -- lookup -----------------------------------------------------------

    def match_prefix(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached prefix: returns (page_ids, n_cached_tokens)."""
        self.tick += 1
        self.stats.lookups += 1
        keys = _chain_hashes(tokens)
        if keys.size == 0:
            return [], 0
        found, vals = self.table.search(keys)
        pages = []
        for i in range(keys.size):
            if not found[i]:
                break
            pages.append(int(vals[i]))
            self.lru[int(vals[i])] = self.tick
        self.stats.hit_blocks += len(pages)
        self.stats.miss_blocks += keys.size - len(pages)
        return pages, len(pages) * BLOCK

    # -- admission ---------------------------------------------------------

    def admit(self, tokens: np.ndarray, first_new_block: int = 0) -> List[int]:
        """Insert pages for blocks [first_new_block:]; returns their page ids."""
        keys = _chain_hashes(tokens)[first_new_block:]
        out = []
        for j, k in enumerate(np.asarray(keys)):
            page = self._alloc_page()
            st = self.table.insert(np.array([k], np.uint64),
                                   np.array([page], np.uint32))
            if int(st[0]) == EXISTS:          # raced/duplicate: reuse existing
                self.free.append(page)
                _, v = self.table.search(np.array([k], np.uint64))
                page = int(v[0])
            else:
                self.stats.insertions += 1
                self.page_owner[page] = int(k)
            self.lru[page] = self.tick
            out.append(page)
        return out

    def _alloc_page(self) -> int:
        if self.free:
            return self.free.pop()
        # LRU eviction: delete the directory entry, recycle the page
        victim = min(self.lru, key=self.lru.get)
        key = self.page_owner.pop(victim, None)
        if key is not None:
            self.table.delete(np.array([key], np.uint64))
        self.lru.pop(victim, None)
        self.stats.evictions += 1
        return victim

    @property
    def load_factor(self) -> float:
        return self.table.load_factor
