"""Paged KV storage: a page pool per layer-stack + gather-based assembly.

Pages hold BLOCK tokens of roped K/V for every layer (stacked layout matches
the decode state: (n_blocks_layers, B?, G, BLOCK, hd) per page, flattened to
a pool). Assembly of a request's contiguous ring cache from its page list is
one gather — the compute saved is the prefill of the cached prefix, which the
engine accounts for (that is the paper's payoff in the serving integration).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig
from .prefix_cache import BLOCK


@dataclasses.dataclass
class PagePoolConfig:
    num_pages: int
    cfg: ModelConfig


class PagePool:
    """Device-resident page pool for one attention-pattern position.

    storage: dict per pattern position pi ->
        k/v: (num_pages, n_blocks, G, BLOCK, hd)
    Recurrent archs store per-page final states instead (state snapshots)."""

    def __init__(self, pc: PagePoolConfig):
        self.pc = pc
        cfg = pc.cfg
        dt = cfg.compute_dtype
        self.storage = {}
        for pi, kind in enumerate(cfg.pattern):
            if kind in ("dense", "local", "moe"):
                shape = (pc.num_pages, cfg.n_blocks, cfg.n_kv_heads, BLOCK, cfg.hd)
                self.storage[pi] = {"k": jnp.zeros(shape, dt),
                                    "v": jnp.zeros(shape, dt)}
            elif kind == "rglru":
                d = cfg.d_rnn or cfg.d_model
                self.storage[pi] = {
                    "h": jnp.zeros((pc.num_pages, cfg.n_blocks, d), jnp.float32),
                    "conv": jnp.zeros((pc.num_pages, cfg.n_blocks,
                                       cfg.conv_width - 1, d), dt)}
            elif kind == "rwkv":
                H = cfg.d_model // 64
                self.storage[pi] = {
                    "shift_tm": jnp.zeros((pc.num_pages, cfg.n_blocks, 1,
                                           cfg.d_model), dt),
                    "wkv": jnp.zeros((pc.num_pages, cfg.n_blocks, H, 64, 64),
                                     jnp.float32),
                    "shift_cm": jnp.zeros((pc.num_pages, cfg.n_blocks, 1,
                                           cfg.d_model), dt)}

    def store_request(self, pages: List[int], state_entry: dict, pi: int,
                      kind: str, batch_index: int, n_prompt: int):
        """Write a finished prefill's cache into pages (one request).
        For attention: page j holds tokens [j*BLOCK, (j+1)*BLOCK).
        For recurrent: page j holds the state SNAPSHOT after block j —
        here we store the final state into the last page (snapshot chain
        is refined incrementally in production; simplified to final-state)."""
        if kind in ("dense", "local", "moe"):
            k = state_entry["k"][:, batch_index]      # (L, G, C, hd) ring
            v = state_entry["v"][:, batch_index]
            C = k.shape[2]
            for j, page in enumerate(pages):
                sl = [(j * BLOCK + t) % C for t in range(BLOCK)]
                self.storage[pi]["k"] = self.storage[pi]["k"].at[page].set(
                    jnp.transpose(k[:, :, jnp.asarray(sl)], (0, 1, 2, 3)))
                self.storage[pi]["v"] = self.storage[pi]["v"].at[page].set(
                    v[:, :, jnp.asarray(sl)])
        elif kind == "rglru":
            if pages:
                self.storage[pi]["h"] = self.storage[pi]["h"].at[pages[-1]].set(
                    state_entry["h"][:, batch_index])
                self.storage[pi]["conv"] = self.storage[pi]["conv"].at[pages[-1]].set(
                    state_entry["conv"][:, batch_index])
        elif kind == "rwkv":
            if pages:
                for f in ("shift_tm", "wkv", "shift_cm"):
                    self.storage[pi][f] = self.storage[pi][f].at[pages[-1]].set(
                        state_entry[f][:, batch_index])

    def gather_into_cache(self, pages: List[int], pi: int, kind: str,
                          state_entry: dict, batch_index: int):
        """Assemble the cached prefix into a request's decode-state entry."""
        if not pages:
            return state_entry
        if kind in ("dense", "local", "moe"):
            pk = self.storage[pi]["k"][jnp.asarray(pages)]   # (P, L, G, B, hd)
            pv = self.storage[pi]["v"][jnp.asarray(pages)]
            C = state_entry["k"].shape[3]
            flat_k = jnp.concatenate([pk[j] for j in range(len(pages))], axis=2)
            flat_v = jnp.concatenate([pv[j] for j in range(len(pages))], axis=2)
            n = flat_k.shape[2]
            k = state_entry["k"].at[:, batch_index, :, :min(n, C)].set(
                flat_k[:, :, :min(n, C)])
            v = state_entry["v"].at[:, batch_index, :, :min(n, C)].set(
                flat_v[:, :, :min(n, C)])
            return {"k": k, "v": v}
        if kind == "rglru":
            return {
                "h": state_entry["h"].at[:, batch_index].set(
                    self.storage[pi]["h"][pages[-1]]),
                "conv": state_entry["conv"].at[:, batch_index].set(
                    self.storage[pi]["conv"][pages[-1]])}
        if kind == "rwkv":
            out = dict(state_entry)
            for f in ("shift_tm", "wkv", "shift_cm"):
                out[f] = state_entry[f].at[:, batch_index].set(
                    self.storage[pi][f][pages[-1]])
            return out
        return state_entry
