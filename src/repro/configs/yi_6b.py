"""yi-6b — llama-arch dense GQA [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000. Full attention
=> long_500k skipped (quadratic; DESIGN.md SS5)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=4, d_ff=11008, vocab_size=64000, head_dim=128,
    rope_theta=5_000_000.0, pattern=("dense",), sub_quadratic=False)

REDUCED = ModelConfig(
    name="yi-6b-smoke", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512, head_dim=64,
    rope_theta=5_000_000.0, pattern=("dense",), q_chunk=64, kv_chunk=64,
    remat="none")
