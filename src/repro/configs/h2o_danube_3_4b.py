"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000. SWA(4096) is
sub-quadratic => long_500k runs."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, d_ff=10240, vocab_size=32000, head_dim=120,
    rope_theta=500_000.0, sliding_window=4096, pattern=("dense",),
    sub_quadratic=True)

REDUCED = ModelConfig(
    name="h2o-danube-3-4b-smoke", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512, head_dim=64,
    rope_theta=500_000.0, sliding_window=64, pattern=("dense",),
    q_chunk=64, kv_chunk=64, sub_quadratic=True, remat="none")
