"""rwkv6-7b — Finch: attention-free, data-dependent decay
[arXiv:2404.05892; hf].

32L d_model=4096 (64 heads of 64) d_ff=14336 vocab=65536. O(1) recurrent
state => long_500k runs. The serving prefix cache stores state snapshots
instead of KV pages (DESIGN.md SS5)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096, n_heads=64,
    n_kv_heads=64, d_ff=14336, vocab_size=65536, head_dim=64,
    pattern=("rwkv",), sub_quadratic=True)

REDUCED = ModelConfig(
    name="rwkv6-7b-smoke", family="ssm", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=4, d_ff=512, vocab_size=512, head_dim=64, pattern=("rwkv",),
    sub_quadratic=True, remat="none")
