"""glm4-9b — RoPE + GQA, 151k vocab [hf:THUDM/glm-4-9b; hf].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552. Full attention
=> long_500k skipped. The 151k vocab stresses vocab-TP (lm head)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096, n_heads=32,
    n_kv_heads=2, d_ff=13696, vocab_size=151552, head_dim=128,
    rope_theta=10_000.0, pattern=("dense",), sub_quadratic=False)

REDUCED = ModelConfig(
    name="glm4-9b-smoke", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=1024, head_dim=64,
    rope_theta=10_000.0, pattern=("dense",), q_chunk=64, kv_chunk=64,
    remat="none")
