"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (kv=32: full MHA) d_ff=8192 vocab=2048. The EnCodec
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, S, d); labels are codebook tokens over vocab 2048. Full attention =>
long_500k skipped."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048, head_dim=64,
    rope_theta=10_000.0, pattern=("dense",), sub_quadratic=False)

REDUCED = ModelConfig(
    name="musicgen-large-smoke", family="audio", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=256, head_dim=64,
    rope_theta=10_000.0, pattern=("dense",), q_chunk=64, kv_chunk=64,
    remat="none")
