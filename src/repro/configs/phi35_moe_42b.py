"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert vocab=32064, MoE 16e top-2.
Full attention => long_500k skipped. Expert axis shards over 'model' (EP)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab_size=32064, head_dim=128,
    rope_theta=10_000.0, pattern=("moe",), n_experts=16, top_k=2,
    sub_quadratic=False)

REDUCED = ModelConfig(
    name="phi3.5-moe-smoke", family="moe", n_layers=2, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=512, vocab_size=512, head_dim=64, rope_theta=10_000.0,
    pattern=("moe",), n_experts=4, top_k=2, q_chunk=64, kv_chunk=64,
    remat="none")
