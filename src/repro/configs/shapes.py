"""The assigned input-shape grid (4 shapes x 10 archs = 40 cells) and
``input_specs`` — ShapeDtypeStruct stand-ins for every model input
(shardable, weak-type-correct, zero allocation; dry-run contract).

  train_4k     seq=4096    global_batch=256   lowers train_step
  prefill_32k  seq=32768   global_batch=32    lowers prefill_step (fwd only)
  decode_32k   seq=32768   global_batch=128   lowers serve_step (1 token, KV=seq)
  long_500k    seq=524288  global_batch=1     lowers serve_step; only for
                                              sub-quadratic archs (SWA/hybrid/ssm)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, decode_state_init


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic attention (skips noted in DESIGN.md)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str):
    """Abstract inputs for (arch, shape). For train/prefill this is the token
    batch (+ stub modality embeddings); for decode it is one token plus the
    abstract decode state (KV cache of seq_len / recurrent state)."""
    case = SHAPES[shape]
    B, S = case.global_batch, case.seq_len
    d = cfg.d_model

    if case.kind in ("train", "prefill"):
        if cfg.family == "audio":
            batch = {"frame_embeds": _sds((B, S, d), jnp.bfloat16),
                     "labels": _sds((B, S), jnp.int32)}
        elif cfg.family == "vlm":
            P = cfg.num_patches
            batch = {"tokens": _sds((B, S - P), jnp.int32),
                     "patch_embeds": _sds((B, P, d), jnp.bfloat16),
                     "labels": _sds((B, S - P), jnp.int32)}
        else:
            batch = {"tokens": _sds((B, S), jnp.int32),
                     "labels": _sds((B, S), jnp.int32)}
        return {"batch": batch}

    # decode: one new token against a cache of S
    state = jax.eval_shape(lambda: decode_state_init(cfg, B, S))
    if cfg.family == "audio":
        inputs = {"frame_embeds": _sds((B, 1, d), jnp.bfloat16)}
    else:
        inputs = {"token": _sds((B,), jnp.int32)}
    return {"state": state, "inputs": inputs}


def concrete_inputs(cfg: ModelConfig, shape: str, rng=None):
    """Small-scale concrete version of input_specs (smoke tests/examples)."""
    import numpy as np
    rng = rng or np.random.default_rng(0)
    spec = input_specs(cfg, shape)

    def realize(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, max(cfg.vocab_size - 1, 2),
                                            s.shape, dtype=np.int32))
        return jnp.asarray(rng.normal(0, 1, s.shape).astype(np.float32),
                           dtype=s.dtype)

    return jax.tree.map(realize, spec)
