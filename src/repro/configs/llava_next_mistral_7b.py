"""llava-next-mistral-7b — VLM backbone (mistral-7b) with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone only: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 576, d) prepended to the text sequence; labels cover only
the text suffix. Full attention => long_500k skipped."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000, head_dim=128,
    rope_theta=1_000_000.0, pattern=("dense",), num_patches=576,
    sub_quadratic=False)

REDUCED = ModelConfig(
    name="llava-next-mistral-7b-smoke", family="vlm", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512, head_dim=64,
    rope_theta=1_000_000.0, pattern=("dense",), num_patches=16,
    q_chunk=64, kv_chunk=64, remat="none")
