"""Architecture registry: the 10 assigned archs (--arch <id>) + shape grid."""
from __future__ import annotations

import importlib

from .shapes import SHAPES, ShapeCase, input_specs, concrete_inputs, shape_applicable

_MODULES = {
    "yi-6b": "yi_6b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "glm4-9b": "glm4_9b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "mixtral-8x7b": "mixtral_8x7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-large": "musicgen_large",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, reduced: bool = False):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_cells():
    """Every (arch, shape) cell; inapplicable cells flagged (not dropped)."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            out.append((a, s, shape_applicable(cfg, s)))
    return out


__all__ = ["SHAPES", "ShapeCase", "input_specs", "concrete_inputs",
           "shape_applicable", "ARCH_IDS", "get_config", "all_cells"]
