"""mistral-nemo-12b — 128k-context dense GQA
[hf:mistralai/Mistral-Nemo-Base-2407; hf].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; head_dim=128
(explicit — Nemo does NOT use d_model/n_heads=160). Full attention =>
long_500k skipped."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072, head_dim=128,
    rope_theta=1_000_000.0, pattern=("dense",), sub_quadratic=False)

REDUCED = ModelConfig(
    name="mistral-nemo-12b-smoke", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1024, head_dim=64,
    rope_theta=1_000_000.0, pattern=("dense",), q_chunk=64, kv_chunk=64,
    remat="none")
