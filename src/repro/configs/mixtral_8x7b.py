"""mixtral-8x7b — 8-expert top-2 MoE with SWA [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert vocab=32000, MoE 8e top-2.
SWA(4096) as assigned => sub-quadratic => long_500k runs."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab_size=32000, head_dim=128,
    rope_theta=1_000_000.0, sliding_window=4096, pattern=("moe",),
    n_experts=8, top_k=2, sub_quadratic=True)

REDUCED = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512, head_dim=64,
    rope_theta=1_000_000.0, sliding_window=64, pattern=("moe",), n_experts=4,
    top_k=2, q_chunk=64, kv_chunk=64, sub_quadratic=True, remat="none")
