"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1:2 ratio
[arXiv:2402.19427; unverified].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. Pattern
(rglru, rglru, local) x 12 + (rglru, rglru) tail = 38 layers. O(1)/windowed
state => long_500k runs."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab_size=256000, head_dim=256,
    rope_theta=10_000.0, pattern=("rglru", "rglru", "local"),
    local_window=2048, d_rnn=4096, conv_width=4, sub_quadratic=True)

REDUCED = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid", n_layers=5, d_model=256,
    n_heads=4, n_kv_heads=1, d_ff=512, vocab_size=512, head_dim=64,
    rope_theta=10_000.0, pattern=("rglru", "rglru", "local"), local_window=64,
    d_rnn=256, conv_width=4, q_chunk=64, kv_chunk=64, sub_quadratic=True,
    remat="none")
